//! Service invariants (ISSUE 4 acceptance):
//!  - `Service` submit-in-any-order + flush yields bit-identical per-job
//!    outcomes to `run_queue` for mixed MVC/MIS/MaxCut jobs at P in {1, 2},
//!    dense and sparse (solutions, objectives, eval counts — everything
//!    except the pack index, which legitimately depends on launch order);
//!  - a second drain on a warm `Service` re-uploads strictly fewer h2d
//!    bytes than the cold first drain (the shared-θ residency);
//!  - OnFill packs stream outcomes before flush;
//!  - admission errors are contextful and carry the job id.
//!
//! Runtime-dependent tests skip when artifacts are not built (same
//! convention as e2e.rs / batch_equivalence.rs).

// The shared bench/test job-set generator (`mixed_jobs`) — one source so
// what bench_queue measures is exactly the mix these tests pin.
#[path = "../benches/common.rs"]
mod common;

use common::mixed_jobs;
use oggm::batch::{run_queue, BatchCfg, Job};
use oggm::coordinator::shard::Storage;
use oggm::env::Scenario;
use oggm::graph::generators;
use oggm::model::Params;
use oggm::runtime::Runtime;
use oggm::service::{LaunchCause, LaunchPolicy, Options, Service, SubmitMeta};
use oggm::util::rng::Pcg32;
use std::time::Duration;

fn setup() -> Option<Runtime> {
    if !std::path::Path::new("artifacts/manifest.tsv").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Runtime::new("artifacts").unwrap())
}

fn has_batch_shapes(rt: &Runtime, bucket: usize, p: usize, b: usize) -> bool {
    let ok = rt.manifest.batch_sizes(bucket, bucket / p).last().copied().unwrap_or(0) >= b;
    if !ok {
        eprintln!(
            "skipping: no compiled batch-{b} shapes at N={bucket}, P={p} (re-run make artifacts)"
        );
    }
    ok
}

/// Deterministic order shuffle (fixed odd stride, coprime to len).
fn permuted<T: Clone>(xs: &[T], stride: usize) -> Vec<T> {
    (0..xs.len()).map(|i| xs[(i * stride + 1) % xs.len()].clone()).collect()
}

#[test]
fn service_matches_run_queue_bit_exact() {
    let Some(rt) = setup() else { return };
    let jobs = mixed_jobs(9, 0x5E);
    let params = Params::init(32, &mut Pcg32::seeded(41));
    for p in [1usize, 2] {
        if !has_batch_shapes(&rt, 24, p, 8) {
            return;
        }
        for storage in [Storage::Dense, Storage::Sparse] {
            // 3 jobs per (scenario, bucket) group open at capacity 4 and
            // may compact through 2 and 1 — the sparse arm needs shapes at
            // each of those batch sizes.
            if storage == Storage::Sparse
                && [1usize, 2, 4].iter().any(|&b| rt.manifest.sparse_config(b, 24 / p, 32).is_err())
            {
                eprintln!("skipping sparse arm: sparse artifacts not compiled at N=24, P={p}");
                continue;
            }
            let mut cfg = BatchCfg::new(p, 2);
            cfg.storage = storage;
            let reference = run_queue(&rt, &cfg, &params, &jobs).unwrap();

            // Submit in a different order than the reference saw, then
            // flush: per-job outcomes must be bit-identical anyway (the
            // block-diagonal pack has no cross-graph terms, so pack
            // membership cannot leak into a job's trajectory).
            let mut svc = Service::with_cfg(&rt, params.clone(), cfg);
            for job in permuted(&jobs, 4) {
                svc.submit(job).unwrap();
            }
            let events = svc.drain();
            assert_eq!(events.len(), jobs.len(), "P={p} {storage:?}: event count");
            for ev in events {
                let got = ev.result.expect("service job failed");
                let want = reference
                    .outcomes
                    .iter()
                    .find(|o| o.id == got.id)
                    .expect("unknown job id in stream");
                assert_eq!(got.scenario, want.scenario, "job {}", got.id);
                assert_eq!(got.nodes, want.nodes, "job {}", got.id);
                assert_eq!(got.edges, want.edges, "job {}", got.id);
                assert_eq!(
                    got.solution, want.solution,
                    "P={p} {storage:?} job {}: solution diverged from run_queue",
                    got.id
                );
                assert_eq!(got.solution_size, want.solution_size, "job {}", got.id);
                assert_eq!(got.objective, want.objective, "job {}", got.id);
                assert_eq!(got.valid, want.valid, "job {}", got.id);
                assert_eq!(got.evaluations, want.evaluations, "job {}", got.id);
                assert_eq!(got.selections, want.selections, "job {}", got.id);
            }
        }
    }
}

#[test]
fn run_queue_wrapper_reproduces_historical_grouping() {
    // The OnFlush wrapper must reproduce the one-shot grouping exactly:
    // packs in (scenario, bucket) key order, chunked to the largest
    // compiled capacity, outcomes in submission order.
    let Some(rt) = setup() else { return };
    if !has_batch_shapes(&rt, 24, 1, 8) {
        return;
    }
    let jobs = mixed_jobs(9, 0x77);
    let params = Params::init(32, &mut Pcg32::seeded(9));
    let cfg = BatchCfg::new(1, 2);
    let report = run_queue(&rt, &cfg, &params, &jobs).unwrap();
    assert_eq!(report.outcomes.len(), jobs.len());
    for (i, o) in report.outcomes.iter().enumerate() {
        assert_eq!(o.id, format!("j{i}"), "outcomes out of order");
    }
    // 3 scenarios at <= 8 jobs each -> one pack per scenario, in Ord order.
    assert_eq!(report.packs.len(), 3);
    let scenarios: Vec<Scenario> = report.packs.iter().map(|p| p.scenario).collect();
    assert_eq!(scenarios, Scenario::ALL.to_vec());
    for (i, p) in report.packs.iter().enumerate() {
        assert_eq!(p.pack, i, "pack numbering must follow key order");
    }
}

#[test]
fn warm_service_re_uploads_less_than_cold() {
    let Some(rt) = setup() else { return };
    if !has_batch_shapes(&rt, 24, 1, 8) {
        return;
    }
    let jobs = mixed_jobs(6, 0x91);
    let params = Params::init(32, &mut Pcg32::seeded(5));
    let mut svc = Service::with_cfg(&rt, params, BatchCfg::new(1, 2));

    let snap = rt.stats();
    for job in jobs.clone() {
        svc.submit(job).unwrap();
    }
    let cold_events = svc.drain();
    let cold = rt.stats().since(&snap);
    assert!(cold_events.iter().all(|e| e.result.is_ok()));
    assert!(cold.h2d_bytes > 0, "cold drain moved no bytes");

    // Same jobs again on the SAME service: θ is already device-resident
    // under the service's ThetaCache, so the second drain must move
    // strictly fewer h2d bytes (it pays A/S/C uploads but not θ).
    let snap = rt.stats();
    for job in jobs.clone() {
        svc.submit(job).unwrap();
    }
    let warm_events = svc.drain();
    let warm = rt.stats().since(&snap);
    assert!(warm_events.iter().all(|e| e.result.is_ok()));
    assert!(
        warm.h2d_bytes < cold.h2d_bytes,
        "warm drain did not re-upload less: warm {} vs cold {} h2d bytes",
        warm.h2d_bytes,
        cold.h2d_bytes
    );
    assert!(warm.cache_hits > cold.cache_hits, "warm drain should hit the θ cache");

    // And the outcomes are identical run to run — warmth is a pure
    // transfer optimization.
    for (c, w) in cold_events.iter().zip(&warm_events) {
        let (c, w) = (c.result.as_ref().unwrap(), w.result.as_ref().unwrap());
        assert_eq!(c.solution, w.solution, "job {}: warm solve diverged", c.id);
        assert_eq!(c.evaluations, w.evaluations);
    }
}

#[test]
fn on_fill_streams_before_flush() {
    let Some(rt) = setup() else { return };
    if !has_batch_shapes(&rt, 24, 1, 2) {
        return;
    }
    let params = Params::init(32, &mut Pcg32::seeded(3));
    let max_cap = rt.manifest.batch_sizes(24, 24).last().copied().unwrap();
    let mut svc = Service::with_cfg(&rt, params, BatchCfg::new(1, 2));
    let jobs = mixed_jobs(max_cap + 1, 0x13);
    // All same scenario so they share one open pack.
    for (i, mut job) in jobs.into_iter().enumerate() {
        job.scenario = Scenario::Mvc;
        assert_eq!(svc.submit(job).unwrap().index(), i);
    }
    // The first max_cap submissions filled a pack -> it launched and its
    // outcomes are already pollable; the +1 job still rides an open pack.
    assert_eq!(svc.ready_len(), max_cap, "filled pack did not stream before flush");
    assert_eq!(svc.pending(), 1);
    assert_eq!(svc.packs().len(), 1);
    let first = svc.poll().unwrap();
    assert_eq!(first.job.index(), 0, "events stream in admission order");
    assert!(first.result.is_ok());
    // Flush solves the straggler.
    let rest = svc.drain();
    assert_eq!(rest.len(), max_cap, "{} ready + 1 flushed", max_cap - 1);
    assert_eq!(svc.pending(), 0);
    assert_eq!(svc.packs().len(), 2);
}

#[test]
fn on_flush_ignores_max_wait() {
    // OnFlush promises "nothing launches before flush()" — a max-wait
    // deadline must not perturb it (the run_queue wrapper's bit-exact
    // grouping depends on this).
    let Some(rt) = setup() else { return };
    if !has_batch_shapes(&rt, 24, 1, 2) {
        return;
    }
    let params = Params::init(32, &mut Pcg32::seeded(6));
    let opts = Options::new().launch(LaunchPolicy::OnFlush).max_wait(0.0);
    let mut svc = Service::new(&rt, params, &opts);
    for job in mixed_jobs(4, 0x21) {
        svc.submit(job).unwrap();
    }
    svc.tick();
    assert_eq!(svc.packs().len(), 0, "OnFlush launched before flush()");
    assert_eq!(svc.ready_len(), 0);
    assert_eq!(svc.pending(), 4);
    let events = svc.drain();
    assert_eq!(events.len(), 4);
    assert!(events.iter().all(|e| e.result.is_ok()));
}

#[test]
fn deadline_launches_before_fill() {
    let Some(rt) = setup() else { return };
    // Capacity must exceed the 2 submitted jobs or fill fires first.
    if !has_batch_shapes(&rt, 24, 1, 4) {
        return;
    }
    let params = Params::init(32, &mut Pcg32::seeded(14));
    let mut svc = Service::with_cfg(&rt, params, BatchCfg::new(1, 2));
    let mut jobs = mixed_jobs(2, 0x61);
    for j in &mut jobs {
        j.scenario = Scenario::Mvc; // one shared open pack
    }
    let mut jobs = jobs.into_iter();
    svc.submit(jobs.next().unwrap()).unwrap();
    assert_eq!(svc.ready_len(), 0, "nothing is due yet");
    // A zero deadline launches the open pack inside submit, well short of
    // the compiled fill capacity.
    let meta = SubmitMeta { tenant: 0, max_latency: Some(Duration::ZERO) };
    svc.submit_with(jobs.next().unwrap(), meta).unwrap();
    assert_eq!(svc.ready_len(), 2, "zero deadline must launch the open pack");
    assert_eq!(svc.pending(), 0);
    assert_eq!(svc.packs()[0].cause, LaunchCause::Deadline);
    assert_eq!(svc.admission().deadline_launches, 1);
    let ev = svc.poll().unwrap();
    assert!(ev.result.is_ok());
    assert!(ev.wait_ms >= 0.0);
}

#[test]
fn max_wait_vs_deadline_precedence() {
    let Some(rt) = setup() else { return };
    if !has_batch_shapes(&rt, 24, 1, 1) {
        return;
    }
    let params = Params::init(32, &mut Pcg32::seeded(15));
    let mut jobs = mixed_jobs(2, 0x62).into_iter();

    // An expired max-wait beats a far-future deadline: cause MaxWait.
    let opts = Options::new().max_wait(0.0);
    let mut svc = Service::new(&rt, params.clone(), &opts);
    let meta = SubmitMeta { tenant: 0, max_latency: Some(Duration::from_secs(3600)) };
    svc.submit_with(jobs.next().unwrap(), meta).unwrap();
    assert_eq!(svc.packs()[0].cause, LaunchCause::MaxWait);
    assert_eq!(svc.admission().max_wait_launches, 1);

    // An expired deadline beats a far-future max-wait: cause Deadline
    // (exact ties also go to the deadline — pinned at the Admitter level).
    let opts = Options::new().max_wait(3600.0);
    let mut svc = Service::new(&rt, params, &opts);
    let meta = SubmitMeta { tenant: 0, max_latency: Some(Duration::ZERO) };
    svc.submit_with(jobs.next().unwrap(), meta).unwrap();
    assert_eq!(svc.packs()[0].cause, LaunchCause::Deadline);
    assert_eq!(svc.admission().deadline_launches, 1);
}

#[test]
fn quota_reject_is_retryable_after_drain() {
    let Some(rt) = setup() else { return };
    if !has_batch_shapes(&rt, 24, 1, 1) {
        return;
    }
    let params = Params::init(32, &mut Pcg32::seeded(16));
    // Quota 1 under OnFlush: the first job occupies the tenant's only
    // slot while queued, so the second must bounce with backpressure.
    let opts = Options::new().launch(LaunchPolicy::OnFlush).quota(1);
    let mut svc = Service::new(&rt, params, &opts);
    let mut jobs = mixed_jobs(3, 0x63).into_iter();
    svc.submit(jobs.next().unwrap()).unwrap();
    let err = svc.submit(jobs.next().unwrap()).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("quota"), "reject reason lost: {msg}");
    assert_eq!(svc.admission().rejected, 1);
    assert_eq!(svc.submitted(), 1, "rejected job must not consume an id");
    // Draining emits the outcome, freeing the tenant's slot.
    assert_eq!(svc.drain().len(), 1);
    svc.submit(jobs.next().unwrap()).unwrap();
    let events = svc.drain();
    assert_eq!(events.len(), 1);
    assert!(events[0].result.is_ok(), "service unusable after a quota reject");
}

#[test]
fn admission_error_is_contextful_and_isolated() {
    let Some(rt) = setup() else { return };
    if !has_batch_shapes(&rt, 24, 1, 1) {
        return;
    }
    let params = Params::init(32, &mut Pcg32::seeded(8));
    let mut svc = Service::with_cfg(&rt, params, BatchCfg::new(1, 2));
    // A graph far above every compiled bucket cannot be admitted; the
    // error must carry the job id and leave the service usable. (BA keeps
    // generation O(n·d) at this size.)
    let huge = generators::barabasi_albert(12_000, 2, &mut Pcg32::seeded(99));
    let err = svc
        .submit(Job { id: "whale".into(), scenario: Scenario::Mvc, graph: huge })
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("whale"), "admission error lost the job id: {msg}");
    assert_eq!(svc.submitted(), 0, "failed admission must not consume a job id");

    let ok = generators::erdos_renyi(20, 0.2, &mut Pcg32::seeded(100));
    svc.submit(Job { id: "ok".into(), scenario: Scenario::Mvc, graph: ok }).unwrap();
    let events = svc.drain();
    assert_eq!(events.len(), 1);
    assert!(events[0].result.is_ok(), "service unusable after a rejected job");
}
