//! Batching invariants (ISSUE 1 acceptance):
//!  - block-diagonal pack/unpack round-trips node ids and per-graph blocks;
//!  - batched inference over B graphs produces identical per-graph
//!    solutions to B sequential single-graph runs (same seeds) for MVC,
//!    MaxCut, and MIS at P in {1, 2, 4};
//!  - eviction/compaction changes the schedule, never the solutions.
//!
//! Equivalence is asserted on solutions, not raw scores: the b=1 and b>=2
//! executables differ by ~2e-7 (XLA reduction patterns vary per batch
//! size; see DESIGN.md §4 Numerics), which argmax selection absorbs.
//!
//! Runtime-dependent tests skip when artifacts are not built (same
//! convention as e2e.rs) or when the batched shapes are not compiled.

use oggm::batch::{run_queue, solve_pack, BatchCfg, Job};
use oggm::coordinator::infer::{solve_scenario, InferCfg};
use oggm::coordinator::selection::SelectionPolicy;
use oggm::coordinator::shard::{shards_for_graph, ShardState};
use oggm::env::Scenario;
use oggm::graph::{generators, Graph, PackLayout, Partition};
use oggm::model::Params;
use oggm::runtime::Runtime;
use oggm::solvers::verify;
use oggm::util::rng::Pcg32;

fn setup() -> Option<Runtime> {
    if !std::path::Path::new("artifacts/manifest.tsv").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Runtime::new("artifacts").unwrap())
}

/// Skip unless the batched fwd shapes for (bucket, p) reach capacity `b`.
fn has_batch_shapes(rt: &Runtime, bucket: usize, p: usize, b: usize) -> bool {
    let ok = rt.manifest.batch_sizes(bucket, bucket / p).last().copied().unwrap_or(0) >= b;
    if !ok {
        eprintln!(
            "skipping: no compiled batch-{b} shapes at N={bucket}, P={p} (re-run make artifacts)"
        );
    }
    ok
}

fn test_graphs(count: usize, seed: u64) -> Vec<Graph> {
    let mut rng = Pcg32::seeded(seed);
    (0..count)
        .map(|i| {
            if i % 2 == 0 {
                generators::erdos_renyi(20, 0.2, &mut rng)
            } else {
                generators::barabasi_albert(20, 3, &mut rng)
            }
        })
        .collect()
}

#[test]
fn packed_blocks_match_single_graph_shards() {
    // Pure host-side invariant (no runtime): each graph's block of the
    // packed shard state is byte-identical to the shard built for that
    // graph alone, and the pack layout round-trips its node ids.
    let graphs = test_graphs(4, 17);
    let part = Partition::new(24, 2);
    let layout = PackLayout::new(24, graphs.iter().map(|g| g.n).collect());
    for slot in 0..layout.slots() {
        for v in 0..layout.sizes[slot] {
            assert_eq!(layout.unpack_id(layout.pack_id(slot, v)), (slot, v));
        }
    }

    let removed: Vec<Vec<bool>> = graphs.iter().map(|g| vec![false; g.n]).collect();
    let sol = removed.clone();
    let cand: Vec<Vec<bool>> = graphs
        .iter()
        .map(|g| (0..g.n).map(|v| g.degree(v) > 0).collect())
        .collect();
    for shard in 0..part.p {
        let g_refs: Vec<&Graph> = graphs.iter().collect();
        let r_refs: Vec<&[bool]> = removed.iter().map(|v| v.as_slice()).collect();
        let s_refs: Vec<&[bool]> = sol.iter().map(|v| v.as_slice()).collect();
        let c_refs: Vec<&[bool]> = cand.iter().map(|v| v.as_slice()).collect();
        let packed = ShardState::from_graphs(part, shard, &g_refs, &r_refs, &s_refs, &c_refs);
        let (n, ni) = (part.n, part.ni());
        for (slot, g) in graphs.iter().enumerate() {
            let single = shards_for_graph(part, g, &removed[slot], &sol[slot], &cand[slot]);
            assert_eq!(
                &packed.a[slot * ni * n..(slot + 1) * ni * n],
                &single[shard].a[..],
                "adjacency block diverged (shard {shard}, slot {slot})"
            );
            assert_eq!(&packed.s[slot * ni..(slot + 1) * ni], &single[shard].s[..]);
            assert_eq!(&packed.c[slot * ni..(slot + 1) * ni], &single[shard].c[..]);
        }
    }
}

fn assert_batch_matches_sequential(scenario: Scenario, policy: SelectionPolicy) {
    let Some(rt) = setup() else { return };
    let graphs = test_graphs(8, 23);
    let params = Params::init(32, &mut Pcg32::seeded(42));
    for p in [1usize, 2, 4] {
        if !has_batch_shapes(&rt, 24, p, 8) {
            return;
        }
        let mut bcfg = BatchCfg::new(p, 2);
        bcfg.policy = policy;
        let batched = solve_pack(&rt, &bcfg, &params, scenario, graphs.clone(), 24).unwrap();
        assert_eq!(batched.per_graph.len(), graphs.len());

        let mut icfg = InferCfg::new(p, 2);
        icfg.policy = policy;
        for (i, g) in graphs.iter().enumerate() {
            let seq = solve_scenario(&rt, &icfg, &params, g, 24, scenario).unwrap();
            let b = &batched.per_graph[i];
            assert!(b.valid, "{scenario} graph {i} invalid at P={p}");
            assert_eq!(
                b.solution, seq.solution,
                "{scenario} graph {i} diverged from sequential at P={p}"
            );
            assert_eq!(
                b.evaluations, seq.evaluations,
                "{scenario} graph {i} used a different eval count at P={p}"
            );
            assert_eq!(b.objective, seq.objective);
            // Independent feasibility check (solvers::verify, not the
            // engine's own `valid` flag).
            let mask = verify::ids_to_mask(g.n, &b.solution);
            assert!(
                verify::feasible(scenario, g, &mask),
                "{scenario} graph {i}: engine solution fails verify at P={p}"
            );
        }
    }
}

#[test]
fn batched_equals_sequential_mvc() {
    assert_batch_matches_sequential(Scenario::Mvc, SelectionPolicy::Single);
}

#[test]
fn batched_equals_sequential_maxcut() {
    assert_batch_matches_sequential(Scenario::MaxCut, SelectionPolicy::Single);
}

#[test]
fn batched_equals_sequential_mis() {
    assert_batch_matches_sequential(Scenario::Mis, SelectionPolicy::Single);
}

#[test]
fn batched_equals_sequential_multi_select() {
    assert_batch_matches_sequential(Scenario::Mvc, SelectionPolicy::AdaptiveMulti);
}

#[test]
fn compaction_preserves_solutions_and_shrinks_rounds() {
    let Some(rt) = setup() else { return };
    if !has_batch_shapes(&rt, 24, 2, 8) {
        return;
    }
    let graphs = test_graphs(8, 31);
    let params = Params::init(32, &mut Pcg32::seeded(7));
    let mut on = BatchCfg::new(2, 2);
    on.compact = true;
    let mut off = on;
    off.compact = false;
    let a = solve_pack(&rt, &on, &params, Scenario::Mvc, graphs.clone(), 24).unwrap();
    let b = solve_pack(&rt, &off, &params, Scenario::Mvc, graphs.clone(), 24).unwrap();
    for (x, y) in a.per_graph.iter().zip(&b.per_graph) {
        assert_eq!(x.solution, y.solution, "compaction changed a solution");
        assert_eq!(x.evaluations, y.evaluations);
    }
    assert_eq!(b.repacks, 0);
    // A graph is active in a contiguous prefix of rounds (rounds 0..evals),
    // so the active count at round r is #{g : evals_g > r}. With the
    // compiled capacity ladder {1,2,4,8}, compaction must fire exactly when
    // some executed round has <= 4 graphs active — i.e. when fewer than 5
    // graphs survive to the final round.
    let mut evals: Vec<usize> = a.per_graph.iter().map(|r| r.evaluations).collect();
    evals.sort_unstable_by(|x, y| y.cmp(x));
    if evals[4] < evals[0] {
        assert!(a.repacks > 0, "straggler tail <= 4 active but no compaction: {evals:?}");
    } else {
        assert_eq!(a.repacks, 0, "compaction fired with > 4 graphs always active");
    }
}

#[test]
fn multi_select_compaction_preserves_solutions() {
    // Regression for the adaptive-d live-count fix at a repack boundary:
    // under AdaptiveMulti the select count is derived from each graph's
    // LIVE node count, which must be identical whether or not a compaction
    // repack happens — so compacted and uncompacted runs (and hence runs
    // straddling the repack boundary) pick the same nodes.
    let Some(rt) = setup() else { return };
    if !has_batch_shapes(&rt, 24, 2, 8) {
        return;
    }
    let graphs = test_graphs(8, 53);
    let params = Params::init(32, &mut Pcg32::seeded(13));
    let mut on = BatchCfg::new(2, 2);
    on.policy = SelectionPolicy::AdaptiveMulti;
    on.compact = true;
    let mut off = on;
    off.compact = false;
    let a = solve_pack(&rt, &on, &params, Scenario::Mvc, graphs.clone(), 24).unwrap();
    let b = solve_pack(&rt, &off, &params, Scenario::Mvc, graphs, 24).unwrap();
    for (i, (x, y)) in a.per_graph.iter().zip(&b.per_graph).enumerate() {
        assert_eq!(x.solution, y.solution, "graph {i}: repack changed a multi-select solution");
        assert_eq!(x.selections, y.selections);
    }
}

#[test]
fn queue_groups_and_returns_in_order() {
    let Some(rt) = setup() else { return };
    if !has_batch_shapes(&rt, 24, 1, 8) {
        return;
    }
    let params = Params::init(32, &mut Pcg32::seeded(9));
    let graphs = test_graphs(6, 77);
    // Interleave scenarios so grouping has to reorder internally.
    let scenarios =
        [Scenario::Mvc, Scenario::Mis, Scenario::Mvc, Scenario::Mis, Scenario::Mvc, Scenario::Mvc];
    let jobs: Vec<Job> = graphs
        .iter()
        .zip(scenarios)
        .enumerate()
        .map(|(i, (g, s))| Job { id: format!("j{i}"), scenario: s, graph: g.clone() })
        .collect();
    let cfg = BatchCfg::new(1, 2);
    let report = run_queue(&rt, &cfg, &params, &jobs).unwrap();
    assert_eq!(report.outcomes.len(), jobs.len());
    for (i, o) in report.outcomes.iter().enumerate() {
        assert_eq!(o.id, format!("j{i}"), "outcomes out of order");
        assert_eq!(o.scenario, jobs[i].scenario);
        assert!(o.valid);
        assert_eq!(o.solution.len(), o.solution_size);
        // Re-verify every streamed outcome with the canonical checkers.
        let mask = verify::ids_to_mask(jobs[i].graph.n, &o.solution);
        assert!(
            verify::feasible(o.scenario, &jobs[i].graph, &mask),
            "job {}: outcome fails verify",
            o.id
        );
    }
    // Two scenario groups → at least two packs.
    assert!(report.packs.len() >= 2);
    let json = report.to_json().render();
    assert!(json.contains("\"jobs\""));
}
