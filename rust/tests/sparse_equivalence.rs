//! Sparse-storage invariants (ISSUE 3 acceptance):
//!  - the CSR compute path produces the same scores (to fp tolerance) and
//!    the SAME solutions/selections as the dense oracle for MVC, MaxCut,
//!    and MIS at P in {1, 2, 4}, through removal steps;
//!  - the batched sparse engine matches the batched dense engine through
//!    eviction/compaction repacks;
//!  - the sparse device-resident path is bit-exact vs the sparse
//!    fresh-upload path (same stage programs, same input bits).
//!
//! Solution-level equivalence (not raw-score bit equality) is the dense-vs-
//! sparse contract: the scatter's summation order differs from the
//! matmul's at the ulp level, which argmax selection absorbs — the same
//! convention DESIGN.md §4 Numerics establishes for b=1 vs b>=2
//! executables. Runtime-dependent tests skip when artifacts (or the sparse
//! shapes) are not built, like e2e.rs.

use oggm::batch::{solve_pack, BatchCfg};
use oggm::coordinator::infer::{solve_scenario, InferCfg};
use oggm::coordinator::selection::SelectionPolicy;
use oggm::coordinator::shard::Storage;
use oggm::env::Scenario;
use oggm::graph::{generators, Graph};
use oggm::model::Params;
use oggm::runtime::Runtime;
use oggm::solvers::verify;
use oggm::util::rng::Pcg32;

fn setup() -> Option<Runtime> {
    if !std::path::Path::new("artifacts/manifest.tsv").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Runtime::new("artifacts").unwrap())
}

/// Skip unless the sparse stages are compiled for (bucket, p) at batch b.
fn has_sparse_shapes(rt: &Runtime, bucket: usize, p: usize, b: usize) -> bool {
    let ok = rt.manifest.sparse_config(b, bucket / p, 32).is_ok();
    if !ok {
        eprintln!(
            "skipping: no sparse shapes at N={bucket}, P={p}, B={b} (re-run make artifacts)"
        );
    }
    ok
}

fn test_graphs(count: usize, seed: u64) -> Vec<Graph> {
    let mut rng = Pcg32::seeded(seed);
    (0..count)
        .map(|i| {
            if i % 2 == 0 {
                generators::erdos_renyi(20, 0.2, &mut rng)
            } else {
                generators::barabasi_albert(20, 3, &mut rng)
            }
        })
        .collect()
}

/// Sequential solves: the sparse path must retrace the dense oracle's
/// trajectory (same solution, same objective, same evaluation count) —
/// every step after the first exercises removal-mutated sparse state.
fn assert_sparse_matches_dense_sequential(scenario: Scenario, policy: SelectionPolicy) {
    let Some(rt) = setup() else { return };
    let graphs = test_graphs(6, 41);
    let params = Params::init(32, &mut Pcg32::seeded(42));
    for p in [1usize, 2, 4] {
        if !has_sparse_shapes(&rt, 24, p, 1) {
            return;
        }
        let mut dense_cfg = InferCfg::new(p, 2);
        dense_cfg.policy = policy;
        let mut sparse_cfg = dense_cfg;
        sparse_cfg.storage = Storage::Sparse;
        for (i, g) in graphs.iter().enumerate() {
            let want = solve_scenario(&rt, &dense_cfg, &params, g, 24, scenario).unwrap();
            let got = solve_scenario(&rt, &sparse_cfg, &params, g, 24, scenario).unwrap();
            assert_eq!(
                got.solution, want.solution,
                "{scenario} graph {i} sparse solution diverged at P={p}"
            );
            assert_eq!(got.objective, want.objective);
            assert_eq!(got.evaluations, want.evaluations);
            assert_eq!(got.selections, want.selections);
            // Matching dense is not enough: both must be feasible per the
            // canonical checkers.
            let mask = verify::ids_to_mask(g.n, &got.solution);
            assert!(
                verify::feasible(scenario, g, &mask),
                "{scenario} graph {i}: sparse solution fails verify at P={p}"
            );
        }
    }
}

#[test]
fn sparse_equals_dense_mvc() {
    assert_sparse_matches_dense_sequential(Scenario::Mvc, SelectionPolicy::Single);
}

#[test]
fn sparse_equals_dense_maxcut() {
    assert_sparse_matches_dense_sequential(Scenario::MaxCut, SelectionPolicy::Single);
}

#[test]
fn sparse_equals_dense_mis() {
    assert_sparse_matches_dense_sequential(Scenario::Mis, SelectionPolicy::Single);
}

#[test]
fn sparse_equals_dense_multi_select() {
    assert_sparse_matches_dense_sequential(Scenario::Mvc, SelectionPolicy::AdaptiveMulti);
}

#[test]
fn sparse_batched_matches_dense_through_repacks() {
    // The batched engine under sparse storage must match the dense batched
    // engine per graph — including across compaction repacks, which rebuild
    // the sparse edge tiles at a smaller capacity.
    let Some(rt) = setup() else { return };
    let graphs = test_graphs(8, 47);
    let params = Params::init(32, &mut Pcg32::seeded(48));
    for p in [1usize, 2, 4] {
        if !has_sparse_shapes(&rt, 24, p, 8) || !has_sparse_shapes(&rt, 24, p, 1) {
            return;
        }
        for scenario in [Scenario::Mvc, Scenario::Mis, Scenario::MaxCut] {
            let dense_cfg = BatchCfg::new(p, 2);
            let mut sparse_cfg = dense_cfg;
            sparse_cfg.storage = Storage::Sparse;
            let want = solve_pack(&rt, &dense_cfg, &params, scenario, graphs.clone(), 24).unwrap();
            let got = solve_pack(&rt, &sparse_cfg, &params, scenario, graphs.clone(), 24).unwrap();
            assert_eq!(got.rounds, want.rounds, "{scenario} P={p} rounds diverge");
            assert_eq!(got.repacks, want.repacks, "{scenario} P={p} repacks diverge");
            for (i, (x, y)) in got.per_graph.iter().zip(&want.per_graph).enumerate() {
                assert!(x.valid, "{scenario} graph {i} invalid at P={p} (sparse)");
                assert_eq!(
                    x.solution, y.solution,
                    "{scenario} graph {i} sparse≠dense at P={p}"
                );
                assert_eq!(x.objective, y.objective);
                assert_eq!(x.evaluations, y.evaluations);
                let mask = verify::ids_to_mask(graphs[i].n, &x.solution);
                assert!(
                    verify::feasible(scenario, &graphs[i], &mask),
                    "{scenario} graph {i}: sparse pack solution fails verify at P={p}"
                );
            }
            assert_eq!(got.pack_edges, want.pack_edges);
        }
    }
}

#[test]
fn sparse_state_bytes_scale_with_edges() {
    // The §7 memory observable on a real pack: sparse shard-state bytes
    // must undercut the dense O(B·NI·N) state on sparse inputs.
    let Some(rt) = setup() else { return };
    if !has_sparse_shapes(&rt, 252, 1, 1) {
        return;
    }
    let mut rng = Pcg32::seeded(51);
    let g = generators::barabasi_albert(250, 4, &mut rng);
    let params = Params::init(32, &mut Pcg32::seeded(52));
    let dense_cfg = BatchCfg::new(1, 2);
    let mut sparse_cfg = dense_cfg;
    sparse_cfg.storage = Storage::Sparse;
    let d = solve_pack(&rt, &dense_cfg, &params, Scenario::Mvc, vec![g.clone()], 252).unwrap();
    let s = solve_pack(&rt, &sparse_cfg, &params, Scenario::Mvc, vec![g], 252).unwrap();
    assert_eq!(
        d.per_graph[0].solution, s.per_graph[0].solution,
        "memory-scaling pack diverged"
    );
    assert!(
        s.state_bytes * 5 <= d.state_bytes,
        "sparse state {} B is not >=5x below dense {} B",
        s.state_bytes,
        d.state_bytes
    );
}
