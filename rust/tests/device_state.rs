//! Device-residency invariants (ISSUE 2 acceptance):
//!  - cached-path (DeviceState) scores are bit-exact vs the fresh-upload
//!    path at P in {1, 2, 4} for all three scenarios, including mid-solve
//!    states reached through dirty-delta syncs;
//!  - cached-path solutions are identical to fresh-upload solutions, and
//!    survive an eviction/compaction repack (which invalidates and rebuilds
//!    the device buffers);
//!  - steady-state h2d bytes/step drop >= 10x vs step 1 on a 200-node MVC
//!    solve (the ExecStats byte-counter criterion).
//!
//! Runtime-dependent tests skip when artifacts are not built (same
//! convention as e2e.rs); the byte-counter test additionally needs the
//! a_mask artifact (re-run `make artifacts` after updating configs.py).

use oggm::coordinator::fwd::{forward, forward_dev, DeviceState};
use oggm::coordinator::infer::{solve_scenario, InferCfg};
use oggm::coordinator::shard::{mirror_selection, shards_for_graph, ShardState};
use oggm::env::{GraphEnv, Scenario};
use oggm::graph::{generators, Graph, Partition};
use oggm::model::Params;
use oggm::runtime::{artifact_name, Runtime};
use oggm::util::rng::Pcg32;

fn setup() -> Option<Runtime> {
    if !std::path::Path::new("artifacts/manifest.tsv").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Runtime::new("artifacts").unwrap())
}

fn test_graphs(count: usize, seed: u64) -> Vec<Graph> {
    let mut rng = Pcg32::seeded(seed);
    (0..count)
        .map(|i| {
            if i % 2 == 0 {
                generators::erdos_renyi(20, 0.2, &mut rng)
            } else {
                generators::barabasi_albert(20, 3, &mut rng)
            }
        })
        .collect()
}

/// Greedy-drive up to `max_steps` selections of `scenario` over a
/// DeviceState-backed solve, exactly mirroring `solve_env`'s state updates
/// (sync → cached forward → greedy pick → mirror → candidate refresh).
/// `on_step` runs after each synced cached forward with the shard states
/// and the cached scores — the hooks below compare against the fresh path
/// and snapshot byte counters.
fn drive_cached(
    rt: &Runtime,
    scenario: Scenario,
    p: usize,
    g: &Graph,
    params: &Params,
    bucket: usize,
    max_steps: usize,
    mut on_step: impl FnMut(&[ShardState], &[f32]),
) {
    let part = Partition::new(bucket, p);
    let cfg = oggm::coordinator::engine::EngineCfg::new(p, 2);
    let mut env = scenario.make_env(g.clone());
    let candidates: Vec<bool> = (0..g.n).map(|v| env.is_candidate(v)).collect();
    let mut shards: Vec<ShardState> =
        shards_for_graph(part, g, env.removed_mask(), env.solution_mask(), &candidates);
    let mut removed_prev: Vec<bool> = env.removed_mask().to_vec();
    let mut dev = DeviceState::new(rt, params, &mut shards).unwrap();

    for _ in 0..max_steps {
        if env.done() {
            break;
        }
        dev.sync(&mut shards).unwrap();
        let out = forward_dev(rt, &cfg, params, &shards, false, true, Some(&dev)).unwrap();
        on_step(&shards, &out.scores);
        // Greedy-select the best candidate and mirror it (dirty deltas).
        let v = (0..g.n)
            .filter(|&v| env.is_candidate(v))
            .max_by(|&a, &b| out.scores[a].partial_cmp(&out.scores[b]).unwrap())
            .expect("env not done but no candidates");
        env.step(v);
        mirror_selection(&mut shards, 0, v, &*env, &mut removed_prev);
        for sh in shards.iter_mut() {
            sh.refresh_candidates(0, |v| env.is_candidate(v));
        }
    }
}

/// After every state change the device-resident forward must reproduce the
/// fresh-upload scores bit-exactly (f32 ==).
fn assert_scores_bit_exact(rt: &Runtime, scenario: Scenario, p: usize) {
    let g = generators::erdos_renyi(20, 0.25, &mut Pcg32::seeded(0xD5));
    let params = Params::init(32, &mut Pcg32::seeded(0xD6));
    let cfg = oggm::coordinator::engine::EngineCfg::new(p, 2);
    let mut step = 0;
    drive_cached(rt, scenario, p, &g, &params, 24, 4, |shards, scores| {
        let fresh = forward(rt, &cfg, &params, shards, false, true).unwrap();
        assert_eq!(
            scores,
            &fresh.scores[..],
            "{scenario} P={p} step {step}: cached scores diverge from fresh"
        );
        step += 1;
    });
    assert!(step >= 3, "{scenario} P={p}: solve ended after {step} steps");
}

#[test]
fn cached_scores_bit_exact_all_scenarios() {
    let Some(rt) = setup() else { return };
    for scenario in [Scenario::Mvc, Scenario::Mis, Scenario::MaxCut] {
        for p in [1usize, 2, 4] {
            assert_scores_bit_exact(&rt, scenario, p);
        }
    }
}

#[test]
fn cached_solutions_equal_fresh_all_scenarios() {
    let Some(rt) = setup() else { return };
    let graphs = test_graphs(4, 0xE1);
    let params = Params::init(32, &mut Pcg32::seeded(0xE2));
    for scenario in [Scenario::Mvc, Scenario::Mis, Scenario::MaxCut] {
        for p in [1usize, 2, 4] {
            let mut cached = InferCfg::new(p, 2);
            cached.device_resident = true;
            let mut fresh = cached;
            fresh.device_resident = false;
            for (i, g) in graphs.iter().enumerate() {
                let a = solve_scenario(&rt, &cached, &params, g, 24, scenario).unwrap();
                let b = solve_scenario(&rt, &fresh, &params, g, 24, scenario).unwrap();
                assert_eq!(
                    a.solution, b.solution,
                    "{scenario} graph {i} P={p}: cached solve diverged"
                );
                assert_eq!(a.evaluations, b.evaluations);
                assert_eq!(a.objective, b.objective);
            }
        }
    }
}

#[test]
fn repack_invalidation_preserves_solutions() {
    // A compaction repack rebuilds the device buffers; solutions must match
    // both the fresh-upload batched path and the PR-1-style sequential path.
    use oggm::batch::{solve_pack, BatchCfg};
    let Some(rt) = setup() else { return };
    if rt.manifest.batch_sizes(24, 12).last().copied().unwrap_or(0) < 8 {
        eprintln!("skipping: no compiled batch-8 shapes at N=24, P=2");
        return;
    }
    let graphs = test_graphs(8, 31);
    let params = Params::init(32, &mut Pcg32::seeded(7));
    let mut cached = BatchCfg::new(2, 2);
    cached.compact = true;
    cached.device_resident = true;
    let mut fresh = cached;
    fresh.device_resident = false;
    let a = solve_pack(&rt, &cached, &params, Scenario::Mvc, graphs.clone(), 24).unwrap();
    let b = solve_pack(&rt, &fresh, &params, Scenario::Mvc, graphs.clone(), 24).unwrap();
    assert_eq!(a.repacks, b.repacks, "residency changed the compaction schedule");
    for (i, (x, y)) in a.per_graph.iter().zip(&b.per_graph).enumerate() {
        assert!(x.valid, "graph {i} invalid on the cached path");
        assert_eq!(x.solution, y.solution, "graph {i}: repack broke the cached path");
        assert_eq!(x.evaluations, y.evaluations);
    }
    // The cached path must also match sequential single-graph solves.
    let icfg = InferCfg::new(2, 2);
    for (i, g) in graphs.iter().enumerate() {
        let seq = solve_scenario(&rt, &icfg, &params, g, 24, Scenario::Mvc).unwrap();
        assert_eq!(
            a.per_graph[i].solution, seq.solution,
            "graph {i}: cached batched diverged from sequential"
        );
    }
}

#[test]
fn steady_state_h2d_drops_10x_on_200_node_mvc() {
    let Some(rt) = setup() else { return };
    let n = 200usize;
    let p = 1usize;
    let Ok(bucket) = rt.manifest.bucket_for(n, p, 1) else {
        eprintln!("skipping: no compiled bucket for n={n}");
        return;
    };
    if !rt.manifest.has(&artifact_name("a_mask", 1, bucket, bucket / p, 32)) {
        eprintln!("skipping: a_mask artifact not built (re-run make artifacts)");
        return;
    }
    let g = generators::erdos_renyi(n, 0.15, &mut Pcg32::seeded(0xF1));
    let params = Params::init(32, &mut Pcg32::seeded(0xF2));

    // Per-step deltas: step 1's window opens before DeviceState::new, so it
    // carries the one-time θ/A upload; steps 2+ carry only the deltas.
    let mut per_step_h2d: Vec<u64> = Vec::new();
    let mut snap = rt.stats();
    drive_cached(&rt, Scenario::Mvc, p, &g, &params, bucket, 6, |_, _| {
        per_step_h2d.push(rt.stats().since(&snap).h2d_bytes);
        snap = rt.stats();
    });
    assert!(per_step_h2d.len() >= 3, "solve finished too quickly: {per_step_h2d:?}");
    let step1 = per_step_h2d[0];
    for (i, &later) in per_step_h2d[1..].iter().enumerate() {
        assert!(
            later * 10 <= step1,
            "step {} h2d {later} B not >= 10x below step 1 {step1} B ({per_step_h2d:?})",
            i + 2
        );
    }
}
