//! Rank-transport equivalence (ISSUE 9) and remote-rank recovery
//! (ISSUE 10): the TCP transport must be bit-identical to the in-process
//! transport — same solutions, same collective counts — the frame codec
//! must reject malformed, truncated, and version-mismatched input with
//! contextful errors, and a dead or hung worker must be detected within
//! `--rank-timeout`, replaced through the rejoin window, and the retried
//! pack re-solved bit-identically (DESIGN.md §12).
//!
//! The codec and handshake tests run everywhere; the solve-equivalence
//! and liveness tests are artifact-gated like every execution test
//! (without `artifacts/`, or with the offline xla stub, they return
//! early).

use oggm::batch::{solve_pack_session, BatchCfg, SessionState};
use oggm::collective::fault::FaultPlan;
use oggm::coordinator::engine::{Engine, EngineCfg};
use oggm::coordinator::shard::{
    shards_for_graph, sparse_shards_for_graph, ShardSet, Storage,
};
use oggm::env::Scenario;
use oggm::graph::{generators, Graph, Partition};
use oggm::model::Params;
use oggm::parallel::{reconnect_backoff, remote_worker, remote_worker_with, RankPool};
use oggm::runtime::Runtime;
use oggm::service::retryable_fault;
use oggm::transport::frame::{self, HEADER_LEN, VERSION};
use oggm::transport::TcpCfg;
use oggm::util::prop;
use oggm::util::rng::Pcg32;
use std::io::Cursor;
use std::path::PathBuf;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

// ---------------------------------------------------------------- codec --

#[test]
fn frame_codec_round_trips_random_frames() {
    prop::check_msg(
        "frame-round-trip",
        200,
        |r| {
            let len = r.gen_range(2048);
            let payload: Vec<u8> = (0..len).map(|_| r.gen_range(256) as u8).collect();
            (r.gen_range(1 << 16) as u16, r.gen_range(64) as u32, payload)
        },
        |(kind, rank, payload)| {
            let mut buf = Vec::new();
            let n = frame::write_frame(&mut buf, *kind, *rank, payload)
                .map_err(|e| format!("write: {e:#}"))?;
            if n != (HEADER_LEN + payload.len()) as u64 {
                return Err(format!("wrote {n} bytes, expected {}", HEADER_LEN + payload.len()));
            }
            let f = frame::read_frame(&mut Cursor::new(&buf))
                .map_err(|e| format!("read: {e:#}"))?;
            if f.kind != *kind || f.rank != *rank || f.payload != *payload {
                return Err(format!("round-trip mismatch: {f:?}"));
            }
            Ok(())
        },
    );
}

#[test]
fn corrupt_magic_and_version_are_rejected_with_context() {
    prop::check_msg(
        "frame-corruption",
        100,
        |r| {
            let len = r.gen_range(64);
            let payload: Vec<u8> = (0..len).map(|_| r.gen_range(256) as u8).collect();
            let mut buf = Vec::new();
            frame::write_frame(&mut buf, 3, 1, &payload).unwrap();
            // Corrupt one magic byte, or bump the version field.
            let site = r.gen_range(5);
            (buf, site)
        },
        |(buf, site)| {
            let mut bad = buf.clone();
            if *site < 4 {
                bad[*site] ^= 0xFF;
            } else {
                let v = (VERSION + 1).to_le_bytes();
                bad[4..6].copy_from_slice(&v);
            }
            let err = match frame::read_frame(&mut Cursor::new(&bad)) {
                Ok(f) => return Err(format!("corrupt frame decoded: {f:?}")),
                Err(e) => format!("{e:#}"),
            };
            let want = if *site < 4 { "bad frame magic" } else { "version mismatch" };
            if !err.contains(want) {
                return Err(format!("uncontextful error (wanted '{want}'): {err}"));
            }
            Ok(())
        },
    );
}

#[test]
fn truncated_frames_error_instead_of_blocking_or_panicking() {
    prop::check_msg(
        "frame-truncation",
        100,
        |r| {
            let len = 1 + r.gen_range(256);
            let payload: Vec<u8> = (0..len).map(|_| r.gen_range(256) as u8).collect();
            let mut buf = Vec::new();
            frame::write_frame(&mut buf, 2, 0, &payload).unwrap();
            let cut = r.gen_range(buf.len()); // strictly shorter than the frame
            (buf, cut)
        },
        |(buf, cut)| {
            let err = match frame::read_frame(&mut Cursor::new(&buf[..*cut])) {
                Ok(f) => return Err(format!("truncated frame decoded: {f:?}")),
                Err(e) => format!("{e:#}"),
            };
            if !err.contains("truncated frame") {
                return Err(format!("uncontextful truncation error: {err}"));
            }
            Ok(())
        },
    );
}

// ------------------------------------------------------------ handshake --

/// Shrink the rank-connect wait window once per process so handshake
/// failures resolve in seconds instead of the 60 s production default.
fn fast_rank_wait() {
    static ONCE: std::sync::Once = std::sync::Once::new();
    ONCE.call_once(|| std::env::set_var("OGGM_RANK_WAIT_SECS", "4"));
}

/// An ephemeral loopback address (bound once to reserve, then released).
fn alloc_addr() -> String {
    let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let a = l.local_addr().unwrap();
    drop(l);
    a.to_string()
}

/// Run one coordinator group-formation attempt on a fresh ephemeral
/// address, returning its error text and the address it listened on.
fn coord_attempt(dir: std::path::PathBuf) -> (JoinHandle<String>, String) {
    let addr = alloc_addr();
    let spec = format!("tcp:{addr}");
    let h = std::thread::spawn(move || match RankPool::new_tcp(dir, 1, 2, None, &spec) {
        Ok(_) => "unexpectedly formed a group from rejected workers".into(),
        Err(e) => format!("{e:#}"),
    });
    (h, addr)
}

#[test]
fn handshake_rejects_world_and_fingerprint_mismatches() {
    fast_rank_wait();
    // Two artifact directories with different manifest fingerprints: the
    // coordinator's (empty — no manifest.tsv) and a worker's with one.
    // A rejected worker fails the whole group formation (fail-fast: a
    // misconfigured launch should not sit half-formed until timeout), so
    // each mismatch gets its own coordinator attempt.
    let base = std::env::temp_dir().join(format!("oggm_transport_{}", std::process::id()));
    let dir_a = base.join("coord");
    let dir_b = base.join("worker");
    std::fs::create_dir_all(&dir_a).unwrap();
    std::fs::create_dir_all(&dir_b).unwrap();
    std::fs::write(dir_b.join("manifest.tsv"), "stage\tother\n").unwrap();

    // Round 1: matching fingerprint, wrong world size. Both sides name
    // both sizes.
    let (coord, addr) = coord_attempt(dir_a.clone());
    let err = remote_worker(dir_a.clone(), &addr, 0, Some(3), None).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("rejected"), "no rejection context: {msg}");
    assert!(msg.contains("world size mismatch"), "world mismatch not named: {msg}");
    assert!(msg.contains("P=3") && msg.contains("P=1"), "sizes not named: {msg}");
    let msg = coord.join().unwrap();
    assert!(msg.contains("world size mismatch"), "coordinator side silent: {msg}");

    // Round 2: matching world size, different artifact manifest.
    let (coord, addr) = coord_attempt(dir_a.clone());
    let err = remote_worker(dir_b, &addr, 0, Some(1), None).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("rejected"), "no rejection context: {msg}");
    assert!(msg.contains("fingerprint mismatch"), "fingerprint mismatch not named: {msg}");
    let msg = coord.join().unwrap();
    assert!(msg.contains("fingerprint mismatch"), "coordinator side silent: {msg}");

    // Round 3: nobody dials in. The coordinator times out with a message
    // telling the operator what to launch.
    let (coord, _addr) = coord_attempt(dir_a);
    let msg = coord.join().unwrap();
    assert!(msg.contains("timed out waiting for rank workers"), "{msg}");
    assert!(msg.contains("oggm rank"), "no launch hint: {msg}");
    std::fs::remove_dir_all(&base).ok();
}

/// [`coord_attempt`] with explicit liveness/auth knobs.
fn coord_attempt_with(dir: std::path::PathBuf, cfg: TcpCfg) -> (JoinHandle<String>, String) {
    let addr = alloc_addr();
    let spec = format!("tcp:{addr}");
    let h = std::thread::spawn(move || {
        match RankPool::new_tcp_with(dir, 1, 2, None, &spec, cfg) {
            Ok(_) => "unexpectedly formed a group from rejected workers".into(),
            Err(e) => format!("{e:#}"),
        }
    });
    (h, addr)
}

#[test]
fn reconnect_backoff_is_exponential_and_capped() {
    assert_eq!(reconnect_backoff(0), Duration::from_millis(250));
    assert_eq!(reconnect_backoff(1), Duration::from_millis(500));
    assert_eq!(reconnect_backoff(2), Duration::from_millis(1000));
    assert_eq!(reconnect_backoff(4), Duration::from_millis(4000));
    assert_eq!(reconnect_backoff(5), Duration::from_millis(5000));
    assert_eq!(reconnect_backoff(500), Duration::from_millis(5000), "cap holds");
    for a in 0..10 {
        assert!(
            reconnect_backoff(a) <= reconnect_backoff(a + 1),
            "backoff not monotone at attempt {a}"
        );
    }
}

#[test]
fn handshake_rejects_token_mismatches_in_both_directions() {
    fast_rank_wait();
    let base =
        std::env::temp_dir().join(format!("oggm_transport_auth_{}", std::process::id()));
    std::fs::create_dir_all(&base).unwrap();
    let secured = || TcpCfg { token: "sekrit".into(), ..TcpCfg::default() };

    // Coordinator demands a token, worker presents none: both sides name
    // the auth failure and the worker is told which flag to pass.
    let (coord, addr) = coord_attempt_with(base.clone(), secured());
    let err = remote_worker_with(base.clone(), &addr, 0, Some(1), None, "", 0).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("rejected"), "no rejection context: {msg}");
    assert!(msg.contains("authentication token mismatch"), "auth not named: {msg}");
    assert!(msg.contains("--token"), "no flag hint: {msg}");
    let msg = coord.join().unwrap();
    assert!(msg.contains("authentication token mismatch"), "coordinator silent: {msg}");

    // Wrong secret is the same failure as no secret.
    let (coord, addr) = coord_attempt_with(base.clone(), secured());
    let err =
        remote_worker_with(base.clone(), &addr, 0, Some(1), None, "sekrat", 0).unwrap_err();
    assert!(format!("{err:#}").contains("authentication token mismatch"), "{err:#}");
    coord.join().unwrap();

    // Coordinator without a token rejects a worker that presents one
    // (auth is mutual configuration, not worker-optional).
    let (coord, addr) = coord_attempt(base.clone());
    let err =
        remote_worker_with(base.clone(), &addr, 0, Some(1), None, "sekrit", 0).unwrap_err();
    assert!(format!("{err:#}").contains("authentication token mismatch"), "{err:#}");
    coord.join().unwrap();

    // Matching token clears auth and falls through to the next handshake
    // check (world size here) — pinning the check order: auth first.
    let (coord, addr) = coord_attempt_with(base.clone(), secured());
    let err =
        remote_worker_with(base.clone(), &addr, 0, Some(3), None, "sekrit", 0).unwrap_err();
    let msg = format!("{err:#}");
    assert!(!msg.contains("authentication token mismatch"), "auth failed on a match: {msg}");
    assert!(msg.contains("world size mismatch"), "next check not reached: {msg}");
    coord.join().unwrap();
    std::fs::remove_dir_all(&base).ok();
}

#[test]
fn rank_spec_validation_names_the_problem() {
    fast_rank_wait();
    let err = RankPool::new_tcp(PathBuf::from("artifacts"), 2, 2, None, "tcp:nocolon")
        .unwrap_err();
    assert!(format!("{err:#}").contains("is not host:port"), "{err:#}");
    let err = RankPool::new_tcp(
        PathBuf::from("artifacts"),
        1,
        2,
        None,
        "tcp:127.0.0.1:1,tcp:127.0.0.1:2",
    )
    .unwrap_err();
    assert!(format!("{err:#}").contains("expected 1..=1"), "{err:#}");
}

// ------------------------------------------------------- solve equality --

fn runtime() -> Option<Runtime> {
    if !std::path::Path::new("artifacts/manifest.tsv").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Runtime::new("artifacts").unwrap())
}

/// An in-process pool, or None when the environment cannot run one
/// (offline xla stub).
fn inproc_pool(p: usize) -> Option<RankPool> {
    match RankPool::new("artifacts", p) {
        Ok(pool) => Some(pool),
        Err(e) => {
            eprintln!("skipping: rank pool unavailable: {e:#}");
            None
        }
    }
}

/// A TCP pool over `p` worker threads running the real `oggm rank` entry
/// point against loopback, plus their join handles (joined after the
/// pool drops and the workers see the coordinator disconnect).
fn tcp_pool(
    p: usize,
    fault: Option<Arc<FaultPlan>>,
) -> Option<(RankPool, Vec<JoinHandle<()>>)> {
    fast_rank_wait();
    let addr = alloc_addr();
    let workers: Vec<JoinHandle<()>> = (0..p)
        .map(|rank| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                if let Err(e) = remote_worker("artifacts", &addr, rank, Some(p), None) {
                    eprintln!("worker {rank} exited with: {e:#}");
                }
            })
        })
        .collect();
    match RankPool::new_tcp(PathBuf::from("artifacts"), p, 2, fault, &format!("tcp:{addr}")) {
        Ok(pool) => Some((pool, workers)),
        Err(e) => {
            eprintln!("skipping: TCP rank group unavailable: {e:#}");
            for w in workers {
                let _ = w.join();
            }
            None
        }
    }
}

fn fresh_set(rt: &Runtime, storage: Storage, part: Partition, g: &Graph) -> Option<ShardSet> {
    let removed = vec![false; g.n];
    let sol = vec![false; g.n];
    let cand: Vec<bool> = (0..g.n).map(|v| g.degree(v) > 0).collect();
    match storage {
        Storage::Dense => {
            Some(ShardSet::Dense(shards_for_graph(part, g, &removed, &sol, &cand)))
        }
        Storage::Sparse => {
            let Ok((chunk, caps)) = rt.manifest.sparse_config(1, part.ni(), 32) else {
                eprintln!("skipping: sparse artifacts not compiled");
                return None;
            };
            Some(ShardSet::Sparse(sparse_shards_for_graph(
                part, g, &removed, &sol, &cand, chunk, &caps,
            )))
        }
    }
}

#[test]
fn tcp_solves_are_bit_identical_to_inproc() {
    // The tentpole acceptance: the same packs through the in-process and
    // TCP transports produce identical solutions (exact equality, not a
    // tolerance — the hub's rank-order fold matches the in-proc chunked
    // fold bitwise) and identical collective counts, dense and sparse,
    // P ∈ {1, 2, 4}.
    let Some(rt) = runtime() else { return };
    let params = Params::init(32, &mut Pcg32::seeded(91));
    let mut rng = Pcg32::seeded(92);
    let graphs: Vec<Graph> = [8usize, 20, 10, 18, 12]
        .iter()
        .map(|&n| generators::erdos_renyi(n, 0.3, &mut rng))
        .collect();
    for p in [1usize, 2, 4] {
        let Some(inproc) = inproc_pool(p) else { return };
        let Some((tcp, workers)) = tcp_pool(p, None) else { return };
        for storage in [Storage::Dense, Storage::Sparse] {
            if storage == Storage::Sparse && rt.manifest.sparse_config(8, 24 / p, 32).is_err() {
                eprintln!("skipping sparse at P={p}: artifacts not compiled");
                continue;
            }
            let mut cfg = BatchCfg::new(p, 2);
            cfg.storage = storage;
            cfg.engine.mode = Engine::RankParallel;
            let want = solve_pack_session(
                &rt,
                &cfg,
                &params,
                Scenario::Mvc,
                graphs.clone(),
                24,
                SessionState { theta: None, pool: Some(&inproc) },
            )
            .unwrap();
            let got = solve_pack_session(
                &rt,
                &cfg,
                &params,
                Scenario::Mvc,
                graphs.clone(),
                24,
                SessionState { theta: None, pool: Some(&tcp) },
            )
            .unwrap();
            assert_eq!(got.rounds, want.rounds, "P={p} {storage:?}: round counts diverge");
            assert_eq!(
                got.timing.collectives, want.timing.collectives,
                "P={p} {storage:?}: collective counts diverge"
            );
            assert_eq!(
                got.timing.comm_bytes, want.timing.comm_bytes,
                "P={p} {storage:?}: collective bytes diverge"
            );
            for (i, (g1, w1)) in got.per_graph.iter().zip(&want.per_graph).enumerate() {
                assert_eq!(
                    g1.solution, w1.solution,
                    "P={p} {storage:?} graph {i}: solutions diverge across transports"
                );
                assert_eq!(
                    g1.objective, w1.objective,
                    "P={p} {storage:?} graph {i}: objectives diverge across transports"
                );
            }
        }
        // Transport counters are live on both links: the TCP pool counts
        // real socket bytes, the in-proc pool prices the same payloads.
        let ts = tcp.stats().unwrap();
        assert!(ts.tx_bytes > 0 && ts.rx_bytes > 0, "P={p}: TCP traffic not counted: {ts:?}");
        let is = inproc.stats().unwrap();
        assert!(is.tx_bytes > 0 && is.rx_bytes > 0, "P={p}: in-proc traffic not counted");
        drop(tcp);
        for w in workers {
            let _ = w.join();
        }
    }
}

#[test]
fn forward_scores_match_bitwise_across_transports() {
    // One policy evaluation, compared at full precision: the collective
    // fold order is pinned (rank-order left fold), so the scores must be
    // equal bit for bit, not merely close.
    let Some(rt) = runtime() else { return };
    let g = generators::erdos_renyi(20, 0.25, &mut Pcg32::seeded(93));
    let params = Params::init(32, &mut Pcg32::seeded(94));
    for p in [2usize, 4] {
        let Some(inproc) = inproc_pool(p) else { return };
        let Some((tcp, workers)) = tcp_pool(p, None) else { return };
        let part = Partition::new(24, p);
        let cfg = EngineCfg::new(p, 2);
        let mut set_a = fresh_set(&rt, Storage::Dense, part, &g).unwrap();
        inproc.install(0, &params, &mut set_a, true).unwrap();
        let want = inproc.forward(0, &cfg, &set_a, false, true).unwrap();
        let mut set_b = fresh_set(&rt, Storage::Dense, part, &g).unwrap();
        tcp.install(0, &params, &mut set_b, true).unwrap();
        let got = tcp.forward(0, &cfg, &set_b, false, true).unwrap();
        assert_eq!(got.scores, want.scores, "P={p}: TCP scores diverge bitwise");
        assert_eq!(got.timing.collectives, want.timing.collectives, "P={p}");
        drop(tcp);
        for w in workers {
            let _ = w.join();
        }
    }
}

#[test]
fn dropped_frame_is_retryable_and_recovery_is_bit_identical() {
    // Satellite drill: a scripted transport drop (rank 0's first frame)
    // fails the install with a retryable "injected fault ... dropped"
    // error; the next install resets the group over the live sockets and
    // the solve lands on the clean pool's exact scores.
    let Some(rt) = runtime() else { return };
    let g = generators::erdos_renyi(20, 0.25, &mut Pcg32::seeded(95));
    let params = Params::init(32, &mut Pcg32::seeded(96));
    let p = 2usize;
    let Some(clean) = inproc_pool(p) else { return };
    let part = Partition::new(24, p);
    let cfg = EngineCfg::new(p, 2);
    let mut set = fresh_set(&rt, Storage::Dense, part, &g).unwrap();
    clean.install(0, &params, &mut set, true).unwrap();
    let want = clean.forward(0, &cfg, &set, false, true).unwrap();

    let plan = Arc::new(FaultPlan::parse("rank=0,kind=drop").unwrap());
    let Some((tcp, workers)) = tcp_pool(p, Some(plan)) else { return };
    let mut set2 = fresh_set(&rt, Storage::Dense, part, &g).unwrap();
    let err = tcp.install(0, &params, &mut set2, true).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("injected fault"), "not marked injected (retryable): {msg}");
    assert!(msg.contains("dropped"), "drop site not named: {msg}");
    // The one-shot fault is spent; the group resets on the next install.
    let mut set3 = fresh_set(&rt, Storage::Dense, part, &g).unwrap();
    tcp.install(0, &params, &mut set3, true).unwrap();
    let got = tcp.forward(0, &cfg, &set3, false, true).unwrap();
    assert_eq!(got.scores, want.scores, "post-retry TCP scores diverge");
    drop(tcp);
    for w in workers {
        let _ = w.join();
    }
}

// ----------------------------------------------------- liveness / rejoin --

/// [`tcp_pool`] with explicit liveness/auth knobs, a per-worker fault
/// plan, and a worker redial budget (the `--reconnect` path). Also hands
/// back the listen address so tests can dial replacement workers at it.
fn tcp_pool_cfg(
    p: usize,
    cfg: TcpCfg,
    reconnect: usize,
    worker_fault: impl Fn(usize) -> Option<Arc<FaultPlan>>,
) -> Option<(RankPool, Vec<JoinHandle<()>>, String)> {
    fast_rank_wait();
    let addr = alloc_addr();
    let token = cfg.token.clone();
    let workers: Vec<JoinHandle<()>> = (0..p)
        .map(|rank| {
            let addr = addr.clone();
            let fault = worker_fault(rank);
            let token = token.clone();
            std::thread::spawn(move || {
                if let Err(e) = remote_worker_with(
                    "artifacts",
                    &addr,
                    rank,
                    Some(p),
                    fault,
                    &token,
                    reconnect,
                ) {
                    eprintln!("worker {rank} exited with: {e:#}");
                }
            })
        })
        .collect();
    match RankPool::new_tcp_with(
        PathBuf::from("artifacts"),
        p,
        2,
        None,
        &format!("tcp:{addr}"),
        cfg,
    ) {
        Ok(pool) => Some((pool, workers, addr)),
        Err(e) => {
            eprintln!("skipping: TCP rank group unavailable: {e:#}");
            for w in workers {
                let _ = w.join();
            }
            None
        }
    }
}

#[test]
fn stalled_worker_trips_the_rank_timeout_and_window_expiry_is_terminal() {
    // Liveness drill: rank 1 stops sending anything — responses AND
    // heartbeats — while still reading (the hung-process shape a plain
    // EOF check can never catch). The coordinator's --rank-timeout
    // deadline declares it dead with a contextful, retryable error
    // instead of hanging; with nobody redialing, the rejoin window then
    // expires into a terminal (non-retryable) error with a relaunch hint.
    let Some(rt) = runtime() else { return };
    let p = 2usize;
    let cfg_tcp = TcpCfg {
        timeout: Duration::from_millis(600),
        rejoin_window: Duration::from_millis(400),
        token: String::new(),
    };
    let Some((tcp, workers, _addr)) = tcp_pool_cfg(p, cfg_tcp, 0, |r| {
        (r == 1).then(|| Arc::new(FaultPlan::parse("rank=1,kind=stall").unwrap()))
    }) else {
        return;
    };
    let g = generators::erdos_renyi(20, 0.25, &mut Pcg32::seeded(99));
    let params = Params::init(32, &mut Pcg32::seeded(100));
    let part = Partition::new(24, p);
    let cfg = EngineCfg::new(p, 2);
    let mut set = fresh_set(&rt, Storage::Dense, part, &g).unwrap();
    let started = std::time::Instant::now();
    let err = tcp
        .install(0, &params, &mut set, true)
        .and_then(|_| tcp.forward(0, &cfg, &set, false, true).map(|_| ()))
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("unreachable for"), "liveness reason not named: {msg}");
    assert!(msg.contains("--rank-timeout"), "no knob hint: {msg}");
    assert!(retryable_fault(&msg), "liveness death should be retryable: {msg}");
    assert!(
        started.elapsed() < Duration::from_secs(10),
        "the deadline did not bound the stall: took {:?}",
        started.elapsed()
    );
    // Nobody redials: the next install holds the 400ms window open, then
    // fails terminally.
    let mut set2 = fresh_set(&rt, Storage::Dense, part, &g).unwrap();
    let err = tcp.install(0, &params, &mut set2, true).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("rejoin window expired"), "expiry not named: {msg}");
    assert!(msg.contains("--reconnect"), "no relaunch hint: {msg}");
    assert!(!retryable_fault(&msg), "window expiry must be terminal: {msg}");
    drop(tcp);
    for w in workers {
        let _ = w.join();
    }
}

#[test]
fn killed_worker_rejoins_and_the_resolve_is_bit_identical() {
    // The tentpole acceptance: a worker that dies mid-solve (scripted
    // kind=disconnect — the kill -9 analogue) is detected, its redialing
    // `--reconnect` replacement re-handshakes into the same rank slot
    // inside the rejoin window, and the retried pack lands bit-identical
    // to the in-process engine — dense and sparse, P ∈ {2, 4}, with the
    // shared token on both sides of every handshake.
    let Some(rt) = runtime() else { return };
    let params = Params::init(32, &mut Pcg32::seeded(91));
    let mut rng = Pcg32::seeded(92);
    let graphs: Vec<Graph> = [8usize, 20, 10, 18, 12]
        .iter()
        .map(|&n| generators::erdos_renyi(n, 0.3, &mut rng))
        .collect();
    for p in [2usize, 4] {
        let Some(inproc) = inproc_pool(p) else { return };
        for storage in [Storage::Dense, Storage::Sparse] {
            if storage == Storage::Sparse && rt.manifest.sparse_config(8, 24 / p, 32).is_err() {
                eprintln!("skipping sparse at P={p}: artifacts not compiled");
                continue;
            }
            let cfg_tcp = TcpCfg {
                timeout: Duration::from_secs(5),
                rejoin_window: Duration::from_secs(15),
                token: "sekrit".into(),
            };
            let victim = p - 1;
            let spec = format!("rank={victim},kind=disconnect,frame=3");
            let Some((tcp, workers, _addr)) = tcp_pool_cfg(p, cfg_tcp, 2, |r| {
                (r == victim).then(|| Arc::new(FaultPlan::parse(&spec).unwrap()))
            }) else {
                return;
            };
            let mut cfg = BatchCfg::new(p, 2);
            cfg.storage = storage;
            cfg.engine.mode = Engine::RankParallel;
            let want = solve_pack_session(
                &rt,
                &cfg,
                &params,
                Scenario::Mvc,
                graphs.clone(),
                24,
                SessionState { theta: None, pool: Some(&inproc) },
            )
            .unwrap();
            // The first attempt hits the scripted death; each failure
            // must classify retryable (the Executor's retry loop,
            // emulated here), and the recovered attempt must succeed.
            let mut failures = 0usize;
            let got = loop {
                match solve_pack_session(
                    &rt,
                    &cfg,
                    &params,
                    Scenario::Mvc,
                    graphs.clone(),
                    24,
                    SessionState { theta: None, pool: Some(&tcp) },
                ) {
                    Ok(r) => break r,
                    Err(e) => {
                        let msg = format!("{e:#}");
                        assert!(retryable_fault(&msg), "rank death not retryable: {msg}");
                        failures += 1;
                        assert!(failures <= 3, "solve never recovered: {msg}");
                    }
                }
            };
            assert!(failures >= 1, "P={p} {storage:?}: the scripted death never fired");
            assert_eq!(got.rounds, want.rounds, "P={p} {storage:?}: round counts diverge");
            for (i, (g1, w1)) in got.per_graph.iter().zip(&want.per_graph).enumerate() {
                assert_eq!(
                    g1.solution, w1.solution,
                    "P={p} {storage:?} graph {i}: post-rejoin solutions diverge"
                );
                assert_eq!(
                    g1.objective, w1.objective,
                    "P={p} {storage:?} graph {i}: post-rejoin objectives diverge"
                );
            }
            // The recovery is observable: one remote restart, nonzero
            // time inside the rejoin window.
            let ts = tcp.stats().unwrap();
            assert!(ts.remote_restarts >= 1, "rejoin not counted: {ts:?}");
            assert!(ts.rejoin_time > Duration::ZERO, "rejoin wait not booked: {ts:?}");
            drop(tcp);
            for w in workers {
                let _ = w.join();
            }
        }
    }
}

#[test]
fn rejoin_rejects_bad_handshakes_but_admits_the_real_replacement() {
    // A rejected rejoin attempt (wrong token here) must not burn the
    // window or abort the group: the coordinator logs and skips it,
    // keeps listening, and admits the correctly-credentialed replacement
    // — operator-driven restart, no --reconnect on the victim.
    let Some(rt) = runtime() else { return };
    let p = 2usize;
    let cfg_tcp = TcpCfg {
        timeout: Duration::from_secs(5),
        rejoin_window: Duration::from_secs(15),
        token: "sekrit".into(),
    };
    let Some((tcp, workers, addr)) = tcp_pool_cfg(p, cfg_tcp, 0, |r| {
        (r == 1).then(|| Arc::new(FaultPlan::parse("rank=1,kind=disconnect").unwrap()))
    }) else {
        return;
    };
    let g = generators::erdos_renyi(20, 0.25, &mut Pcg32::seeded(101));
    let params = Params::init(32, &mut Pcg32::seeded(102));
    let part = Partition::new(24, p);
    let cfg = EngineCfg::new(p, 2);
    let Some(inproc) = inproc_pool(p) else { return };
    let mut set = fresh_set(&rt, Storage::Dense, part, &g).unwrap();
    inproc.install(0, &params, &mut set, true).unwrap();
    let want = inproc.forward(0, &cfg, &set, false, true).unwrap();

    // Drive the victim into its scripted death.
    let mut set2 = fresh_set(&rt, Storage::Dense, part, &g).unwrap();
    let err = tcp
        .install(0, &params, &mut set2, true)
        .and_then(|_| tcp.forward(0, &cfg, &set2, false, true).map(|_| ()))
        .unwrap_err();
    assert!(retryable_fault(&format!("{err:#}")), "{err:#}");

    // Interloper first (wrong token), real replacement 300ms behind it:
    // the rejoin loop inside the next install reads them in arrival
    // order, rejects the first, admits the second.
    let bad_addr = addr.clone();
    let interloper = std::thread::spawn(move || {
        remote_worker_with("artifacts", &bad_addr, 1, Some(2), None, "wrong", 0).unwrap_err()
    });
    std::thread::sleep(Duration::from_millis(300));
    let good_addr = addr.clone();
    let replacement = std::thread::spawn(move || {
        if let Err(e) =
            remote_worker_with("artifacts", &good_addr, 1, Some(2), None, "sekrit", 0)
        {
            eprintln!("replacement exited with: {e:#}");
        }
    });
    let mut set3 = fresh_set(&rt, Storage::Dense, part, &g).unwrap();
    tcp.install(0, &params, &mut set3, true).unwrap();
    let got = tcp.forward(0, &cfg, &set3, false, true).unwrap();
    assert_eq!(got.scores, want.scores, "post-rejoin scores diverge bitwise");
    let msg = format!("{:#}", interloper.join().unwrap());
    assert!(msg.contains("authentication token mismatch"), "interloper not told why: {msg}");
    drop(tcp);
    let _ = replacement.join();
    for w in workers {
        let _ = w.join();
    }
}

#[test]
fn delayed_frame_only_slows_the_step() {
    // kind=delay is an observability fault: the step completes with the
    // same result, later.
    let Some(rt) = runtime() else { return };
    let g = generators::erdos_renyi(20, 0.25, &mut Pcg32::seeded(97));
    let params = Params::init(32, &mut Pcg32::seeded(98));
    let p = 2usize;
    let Some(clean) = inproc_pool(p) else { return };
    let plan = Arc::new(FaultPlan::parse("rank=1,kind=delay,ms=60").unwrap());
    let delayed = match RankPool::new_with("artifacts", p, 2, Some(plan)) {
        Ok(pool) => pool,
        Err(e) => {
            eprintln!("skipping: rank pool unavailable: {e:#}");
            return;
        }
    };
    let part = Partition::new(24, p);
    let cfg = EngineCfg::new(p, 2);
    let mut set = fresh_set(&rt, Storage::Dense, part, &g).unwrap();
    clean.install(0, &params, &mut set, true).unwrap();
    let want = clean.forward(0, &cfg, &set, false, true).unwrap();
    let started = std::time::Instant::now();
    let mut set2 = fresh_set(&rt, Storage::Dense, part, &g).unwrap();
    delayed.install(0, &params, &mut set2, true).unwrap();
    let got = delayed.forward(0, &cfg, &set2, false, true).unwrap();
    assert!(started.elapsed().as_millis() >= 60, "delay fault never slowed the step");
    assert_eq!(got.scores, want.scores, "delay fault changed the result");
}
