//! Golden solution-quality pins (ISSUE 8 acceptance): the evaluation
//! harness run on fixed, seeded instances must reproduce approximation
//! ratios inside tolerance bounds — the exact solver proves optimality at
//! these sizes, so the classical baselines are scored against the true
//! optimum and drift in any solver (or in the harness's ratio math) fails
//! the pin. Bounds are chosen with slack for ties, not for regressions:
//! the 2-approximation bound (2.0) is mathematical, the greedy bounds are
//! empirical with headroom.
//!
//! The RL section is artifact-gated like every execution test: it scores
//! the Service-path solutions on the same instances and requires
//! feasibility plus a loose ratio ceiling (untrained parameters still must
//! emit valid covers — the environments enforce that structurally).

use oggm::analysis::quality::{evaluate, Baseline, EvalCfg, Instance};
use oggm::env::Scenario;
use oggm::graph::generators;
use oggm::service::Options;
use oggm::util::json::Json;
use oggm::util::rng::Pcg32;

/// Fixed instance set: mixed models, deterministic seeds, sizes where the
/// exact solver always proves optimality within the default budget.
fn golden_instances() -> Vec<Instance> {
    let mut rng = Pcg32::seeded(0x60D);
    vec![
        Instance { name: "er30".into(), graph: generators::erdos_renyi(30, 0.2, &mut rng) },
        Instance { name: "er50".into(), graph: generators::erdos_renyi(50, 0.12, &mut rng) },
        Instance { name: "ba40".into(), graph: generators::barabasi_albert(40, 3, &mut rng) },
        Instance { name: "hk40".into(), graph: generators::holme_kim(40, 3, 0.25, &mut rng) },
    ]
}

#[test]
fn mvc_ratios_stay_pinned() {
    let cfg = EvalCfg::new(Scenario::Mvc);
    let report = evaluate(None, None, &Options::default(), &cfg, &golden_instances()).unwrap();
    assert_eq!(report.infeasible_count(), 0);
    for inst in &report.instances {
        assert!(inst.ref_optimal, "{}: exact did not prove optimality", inst.name);
        for s in &inst.scores {
            assert!(s.ratio >= 1.0, "{} {}: ratio {} below 1", inst.name, s.solver, s.ratio);
        }
        let greedy = inst.scores.iter().find(|s| s.solver == "greedy").unwrap();
        assert!(
            greedy.ratio <= 1.75,
            "{}: greedy MVC ratio {} drifted past 1.75",
            inst.name,
            greedy.ratio
        );
        let approx = inst.scores.iter().find(|s| s.solver == "approx2").unwrap();
        assert!(
            approx.ratio <= 2.0,
            "{}: 2-approx ratio {} broke its mathematical bound",
            inst.name,
            approx.ratio
        );
    }
    assert!(
        report.mean_ratio("greedy").unwrap() <= 1.5,
        "mean greedy MVC ratio {} drifted past 1.5",
        report.mean_ratio("greedy").unwrap()
    );
}

#[test]
fn mis_ratios_stay_pinned() {
    let cfg = EvalCfg::new(Scenario::Mis);
    let report = evaluate(None, None, &Options::default(), &cfg, &golden_instances()).unwrap();
    assert_eq!(report.infeasible_count(), 0);
    for inst in &report.instances {
        assert!(inst.ref_optimal, "{}: exact did not prove optimality", inst.name);
        let greedy = inst.scores.iter().find(|s| s.solver == "greedy").unwrap();
        assert!(
            greedy.ratio <= 1.75,
            "{}: greedy MIS ratio {} drifted past 1.75",
            inst.name,
            greedy.ratio
        );
    }
    assert!(report.mean_ratio("greedy").unwrap() <= 1.4);
}

#[test]
fn maxcut_ratios_stay_pinned() {
    let cfg = EvalCfg::new(Scenario::MaxCut);
    let report = evaluate(None, None, &Options::default(), &cfg, &golden_instances()).unwrap();
    assert_eq!(report.infeasible_count(), 0);
    for inst in &report.instances {
        // Both baselines guarantee >= m/2 and no cut exceeds m, so every
        // ratio against the best feasible cut is mathematically <= 2.
        for s in &inst.scores {
            assert!(
                (1.0..=2.0).contains(&s.ratio),
                "{} {}: MaxCut ratio {} outside [1, 2]",
                inst.name,
                s.solver,
                s.ratio
            );
        }
    }
    assert!(report.worst_ratio() <= 2.0);
}

#[test]
fn harness_is_deterministic() {
    // Identical config + instances → identical objectives and ratios
    // (wall times vary; the quality numbers must not).
    let cfg = EvalCfg::new(Scenario::Mvc);
    let a = evaluate(None, None, &Options::default(), &cfg, &golden_instances()).unwrap();
    let b = evaluate(None, None, &Options::default(), &cfg, &golden_instances()).unwrap();
    for (x, y) in a.instances.iter().zip(&b.instances) {
        assert_eq!(x.ref_objective, y.ref_objective);
        for (s, t) in x.scores.iter().zip(&y.scores) {
            assert_eq!(s.solver, t.solver);
            assert_eq!(s.objective, t.objective);
            assert_eq!(s.ratio, t.ratio);
        }
    }
}

#[test]
fn report_json_round_trips_through_parser() {
    let cfg = EvalCfg::new(Scenario::Mvc);
    let report =
        evaluate(None, None, &Options::default(), &cfg, &golden_instances()).unwrap();
    let parsed = Json::parse(&report.to_json().render()).unwrap();
    assert_eq!(parsed.get("scenario").and_then(Json::as_str), Some("mvc"));
    let summary = parsed.get("summary").unwrap();
    assert_eq!(summary.get("infeasible").and_then(Json::as_u64), Some(0));
    assert_eq!(summary.get("instances").and_then(Json::as_u64), Some(4));
}

#[test]
fn rl_scores_are_feasible_and_bounded() {
    // Artifact-gated: the RL path through the Service engine, scored by
    // the same harness. Untrained parameters give weak covers, but the
    // environments make infeasible output impossible — the harness must
    // agree, and the ratio stays under a loose ceiling.
    if !std::path::Path::new("artifacts/manifest.tsv").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let mut rng = Pcg32::seeded(0x60E);
    let instances: Vec<Instance> = (0..4)
        .map(|i| Instance {
            name: format!("rl{i}"),
            graph: generators::erdos_renyi(20, 0.2, &mut rng),
        })
        .collect();
    let rt = oggm::runtime::Runtime::new("artifacts").unwrap();
    let params = oggm::model::Params::init(32, &mut Pcg32::seeded(0x60F));
    let cfg = EvalCfg::new(Scenario::Mvc);
    let opts = Options::default();
    let report = evaluate(Some(&rt), Some(&params), &opts, &cfg, &instances).unwrap();
    for inst in &report.instances {
        let rl = inst.scores.iter().find(|s| s.solver == "rl").unwrap();
        assert!(rl.feasible, "{}: RL solution failed verification", inst.name);
        assert!(
            rl.ratio <= 4.0,
            "{}: RL ratio {} beyond the loose ceiling",
            inst.name,
            rl.ratio
        );
        assert!(rl.evaluations.unwrap() > 0);
    }
}

#[test]
fn baseline_list_surface_is_stable() {
    // The CLI surface `--baselines` must keep accepting the documented
    // names and defaults (README/EXPERIMENTS reference them).
    for (names, scenario) in [
        ("exact,greedy,approx2", Scenario::Mvc),
        ("greedy,localsearch", Scenario::MaxCut),
        ("default", Scenario::Mis),
    ] {
        let list = Baseline::parse_list(names, scenario).unwrap();
        assert!(list.len() >= 2, "{names}: fewer than two baselines");
    }
}
