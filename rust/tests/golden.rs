//! Cross-language golden-vector tests: the Rust distributed fwd/bwd must
//! reproduce the monolithic JAX model's scores, loss, and jax.grad exactly
//! (python/compile/aot.py emit_goldens wrote the vectors at build time).
//!
//! This is the end-to-end proof that the three layers compose: Pallas/JAX
//! stage artifacts + Rust collectives + hand-rolled collective adjoints ==
//! single-device JAX autodiff.

use oggm::coordinator::bwd::backward;
use oggm::coordinator::engine::EngineCfg;
use oggm::coordinator::fwd::forward;
use oggm::coordinator::shard::ShardState;
use oggm::graph::Partition;
use oggm::model::Params;
use oggm::runtime::Runtime;
use oggm::util::binio;

fn setup() -> Option<Runtime> {
    if !std::path::Path::new("artifacts/manifest.tsv").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Runtime::new("artifacts").unwrap())
}

fn load_params(tensors: &[binio::Tensor]) -> Params {
    let flat = binio::find(tensors, "params").unwrap().data.clone();
    assert_eq!(flat.len(), Params::len_for_k(32));
    Params { k: 32, flat }
}

#[test]
fn inference_scores_match_jax_all_p() {
    let Some(rt) = setup() else { return };
    let g = binio::load("artifacts/golden_infer.oggm").unwrap();
    let params = load_params(&g);
    let a = &binio::find(&g, "a").unwrap().data;
    let s = &binio::find(&g, "s").unwrap().data;
    let c = &binio::find(&g, "c").unwrap().data;
    let want = &binio::find(&g, "scores").unwrap().data;
    let n = 24usize;
    for p in [1usize, 2, 3, 4, 6] {
        let part = Partition::new(n, p);
        let shards: Vec<ShardState> =
            (0..p).map(|i| ShardState::from_dense(part, i, 1, a, s, c)).collect();
        let cfg = EngineCfg::new(p, 2);
        let out = forward(&rt, &cfg, &params, &shards, false, false).unwrap();
        let diff = oggm::util::max_abs_diff(&out.scores, want);
        assert!(diff < 1e-4, "P={p}: scores diverge from JAX by {diff}");
    }
}

#[test]
fn training_loss_and_grads_match_jax_grad() {
    let Some(rt) = setup() else { return };
    let g = binio::load("artifacts/golden_train.oggm").unwrap();
    let params = load_params(&g);
    let a = &binio::find(&g, "a").unwrap().data;
    let s = &binio::find(&g, "s").unwrap().data;
    let c = &binio::find(&g, "c").unwrap().data;
    let onehot = &binio::find(&g, "onehot").unwrap().data;
    let targets = &binio::find(&g, "targets").unwrap().data;
    let want_scores = &binio::find(&g, "scores").unwrap().data;
    let want_loss = binio::find(&g, "loss").unwrap().data[0];
    let want_grads = &binio::find(&g, "grads").unwrap().data;
    let (b, n) = (8usize, 24usize);

    for p in [1usize, 2, 3] {
        let part = Partition::new(n, p);
        let shards: Vec<ShardState> =
            (0..p).map(|i| ShardState::from_dense(part, i, b, a, s, c)).collect();
        let cfg = EngineCfg::new(p, 2);
        let fwd = forward(&rt, &cfg, &params, &shards, true, false).unwrap();
        let sdiff = oggm::util::max_abs_diff(&fwd.scores, want_scores);
        assert!(sdiff < 1e-3, "P={p}: scores diverge by {sdiff}");

        let out = backward(&rt, &cfg, &params, &shards, fwd.acts.as_ref().unwrap(),
                           onehot, targets)
            .unwrap();
        assert!(
            (out.loss - want_loss).abs() < 1e-3 * want_loss.abs().max(1.0),
            "P={p}: loss {} vs jax {want_loss}",
            out.loss
        );
        let rel = oggm::util::rel_l2(&out.grads, want_grads);
        assert!(rel < 1e-3, "P={p}: gradient rel-l2 error {rel}");
    }
}

#[test]
fn skip_zero_layer_matches_goldens_too() {
    let Some(rt) = setup() else { return };
    let g = binio::load("artifacts/golden_infer.oggm").unwrap();
    let params = load_params(&g);
    let a = &binio::find(&g, "a").unwrap().data;
    let s = &binio::find(&g, "s").unwrap().data;
    let c = &binio::find(&g, "c").unwrap().data;
    let want = &binio::find(&g, "scores").unwrap().data;
    let part = Partition::new(24, 3);
    let shards: Vec<ShardState> =
        (0..3).map(|i| ShardState::from_dense(part, i, 1, a, s, c)).collect();
    let cfg = EngineCfg::new(3, 2);
    let out = forward(&rt, &cfg, &params, &shards, false, true).unwrap();
    let diff = oggm::util::max_abs_diff(&out.scores, want);
    assert!(diff < 1e-4, "skip-zero-layer diverges from JAX by {diff}");
}
