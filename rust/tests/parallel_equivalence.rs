//! Lockstep-vs-rank-parallel equivalence (ISSUE 5): the persistent rank
//! pool must reproduce the lockstep engine's solutions and scores across
//! storage modes, scenarios, device counts, batched packs, and repacks —
//! and its failure path must error contextfully instead of deadlocking.
//!
//! Artifact-gated like every execution test: without `artifacts/` (or with
//! the offline xla stub) each test returns early.

use oggm::batch::{solve_pack, solve_pack_session, BatchCfg, SessionState};
use oggm::coordinator::engine::{Engine, EngineCfg};
use oggm::coordinator::fwd::forward_set;
use oggm::coordinator::infer::{solve_scenario, InferCfg};
use oggm::coordinator::shard::{
    shards_for_graph, sparse_shards_for_graph, ShardSet, Storage,
};
use oggm::coordinator::train::{TrainCfg, Trainer};
use oggm::env::Scenario;
use oggm::graph::{generators, Graph, Partition};
use oggm::model::Params;
use oggm::parallel::RankPool;
use oggm::runtime::Runtime;
use oggm::solvers::verify;
use oggm::util::rng::Pcg32;

fn runtime() -> Option<Runtime> {
    if !std::path::Path::new("artifacts/manifest.tsv").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Runtime::new("artifacts").unwrap())
}

/// A pool, or None when the environment cannot run one (xla stub).
fn pool(p: usize) -> Option<RankPool> {
    match RankPool::new("artifacts", p) {
        Ok(pool) => Some(pool),
        Err(e) => {
            eprintln!("skipping: rank pool unavailable: {e:#}");
            None
        }
    }
}

fn fresh_set(rt: &Runtime, storage: Storage, part: Partition, g: &Graph) -> Option<ShardSet> {
    let removed = vec![false; g.n];
    let sol = vec![false; g.n];
    let cand: Vec<bool> = (0..g.n).map(|v| g.degree(v) > 0).collect();
    match storage {
        Storage::Dense => {
            Some(ShardSet::Dense(shards_for_graph(part, g, &removed, &sol, &cand)))
        }
        Storage::Sparse => {
            let Ok((chunk, caps)) = rt.manifest.sparse_config(1, part.ni(), 32) else {
                eprintln!("skipping: sparse artifacts not compiled");
                return None;
            };
            Some(ShardSet::Sparse(sparse_shards_for_graph(
                part, g, &removed, &sol, &cand, chunk, &caps,
            )))
        }
    }
}

#[test]
fn rank_forward_matches_lockstep() {
    // One policy evaluation: identical scores from the single-threaded
    // lockstep orchestrator and the concurrent rank pool (the rank-order
    // deterministic all-reduce pins the fp summation order).
    let Some(rt) = runtime() else { return };
    let g = generators::erdos_renyi(20, 0.25, &mut Pcg32::seeded(71));
    let params = Params::init(32, &mut Pcg32::seeded(72));
    for p in [1usize, 2, 4] {
        let Some(pool) = pool(p) else { return };
        for storage in [Storage::Dense, Storage::Sparse] {
            let part = Partition::new(24, p);
            let Some(mut set) = fresh_set(&rt, storage, part, &g) else { continue };
            let cfg = EngineCfg::new(p, 2);
            let want = forward_set(&rt, &cfg, &params, &set, false, true, None).unwrap();
            pool.install(0, &params, &mut set, true).unwrap();
            let got = pool.forward(0, &cfg, &set, false, true).unwrap();
            let d = oggm::util::max_abs_diff(&got.scores, &want.scores);
            assert!(d < 1e-4, "P={p} {storage:?}: rank scores diverge by {d}");
            // Per-rank compute attribution is populated like the lockstep
            // engine's per-shard columns.
            assert_eq!(got.timing.compute.len(), p);
            assert!(got.timing.compute.iter().all(|&c| c > 0.0));
            assert_eq!(got.timing.collectives, want.timing.collectives);
            pool.uninstall(0).unwrap();
        }
    }
}

#[test]
fn rank_solutions_match_lockstep_all_scenarios() {
    // Full solves: identical solutions and objectives (within 1e-4) across
    // dense/sparse × {MVC, MIS, MaxCut} × P∈{1,2,4}, resident and fresh.
    let Some(rt) = runtime() else { return };
    let g = generators::erdos_renyi(20, 0.25, &mut Pcg32::seeded(73));
    let params = Params::init(32, &mut Pcg32::seeded(74));
    for p in [1usize, 2, 4] {
        for storage in [Storage::Dense, Storage::Sparse] {
            if storage == Storage::Sparse && rt.manifest.sparse_config(1, 24 / p, 32).is_err() {
                eprintln!("skipping sparse at P={p}: artifacts not compiled");
                continue;
            }
            for scenario in Scenario::ALL {
                let mut lockstep = InferCfg::new(p, 2);
                lockstep.storage = storage;
                let want = solve_scenario(&rt, &lockstep, &params, &g, 24, scenario).unwrap();
                let mut ranks = lockstep;
                ranks.engine.mode = Engine::RankParallel;
                let got = match solve_scenario(&rt, &ranks, &params, &g, 24, scenario) {
                    Ok(r) => r,
                    Err(e) => {
                        eprintln!("skipping: rank pool unavailable: {e:#}");
                        return;
                    }
                };
                assert_eq!(
                    got.solution, want.solution,
                    "P={p} {storage:?} {scenario}: solutions diverge"
                );
                assert_eq!(got.evaluations, want.evaluations);
                assert!(
                    (got.objective - want.objective).abs() < 1e-4,
                    "P={p} {storage:?} {scenario}: objective diverges"
                );
                // Both engines' solutions must pass the canonical
                // feasibility checkers, not just match each other.
                let mask = verify::ids_to_mask(g.n, &got.solution);
                assert!(
                    verify::feasible(scenario, &g, &mask),
                    "P={p} {storage:?} {scenario}: rank solution fails verify"
                );
            }
        }
    }
    // Fresh-upload mode drives the same math without device residency.
    let mut lockstep = InferCfg::new(2, 2);
    lockstep.device_resident = false;
    let want = solve_scenario(&rt, &lockstep, &params, &g, 24, Scenario::Mvc).unwrap();
    let mut ranks = lockstep;
    ranks.engine.mode = Engine::RankParallel;
    let got = solve_scenario(&rt, &ranks, &params, &g, 24, Scenario::Mvc).unwrap();
    assert_eq!(got.solution, want.solution, "fresh-mode solutions diverge");
}

#[test]
fn rank_pack_with_repack_matches_lockstep() {
    // Batched packs (B>1) through a compaction repack mid-solve: per-graph
    // outcomes identical between engines, for both storage modes.
    let Some(rt) = runtime() else { return };
    let params = Params::init(32, &mut Pcg32::seeded(75));
    let mut rng = Pcg32::seeded(76);
    // Mixed sizes finish at different rounds, forcing a repack under
    // compaction once a smaller compiled capacity fits the survivors.
    let graphs: Vec<Graph> = [8usize, 20, 10, 18, 12]
        .iter()
        .map(|&n| generators::erdos_renyi(n, 0.3, &mut rng))
        .collect();
    for storage in [Storage::Dense, Storage::Sparse] {
        if storage == Storage::Sparse && rt.manifest.sparse_config(8, 12, 32).is_err() {
            eprintln!("skipping sparse pack: artifacts not compiled");
            continue;
        }
        let mut lockstep = BatchCfg::new(2, 2);
        lockstep.storage = storage;
        let want =
            solve_pack(&rt, &lockstep, &params, Scenario::Mvc, graphs.clone(), 24).unwrap();
        assert!(want.repacks > 0, "{storage:?}: test pack never repacked");
        let mut ranks = lockstep;
        ranks.engine.mode = Engine::RankParallel;
        let got = match solve_pack(&rt, &ranks, &params, Scenario::Mvc, graphs.clone(), 24) {
            Ok(r) => r,
            Err(e) => {
                eprintln!("skipping: rank pool unavailable: {e:#}");
                return;
            }
        };
        assert_eq!(got.rounds, want.rounds, "{storage:?}: round counts diverge");
        assert_eq!(got.repacks, want.repacks, "{storage:?}: repack counts diverge");
        for (i, (res, w)) in got.per_graph.iter().zip(&want.per_graph).enumerate() {
            assert_eq!(res.solution, w.solution, "{storage:?} graph {i}: solutions diverge");
            assert!((res.objective - w.objective).abs() < 1e-4, "{storage:?} graph {i}");
            assert!(res.valid, "{storage:?} graph {i}: invalid solution");
            let mask = verify::ids_to_mask(graphs[i].n, &res.solution);
            assert!(
                verify::feasible(Scenario::Mvc, &graphs[i], &mask),
                "{storage:?} graph {i}: rank pack solution fails verify"
            );
        }
        // Rank-engine transfer accounting is populated from the workers.
        assert!(got.exec.executions > 0);
        assert!(got.exec.h2d_bytes > 0);
    }
}

#[test]
fn warm_pool_skips_theta_reupload() {
    // The warm-pool property: a second identical pack on the same pool
    // moves strictly fewer h2d bytes per rank — at least θ's worth, since
    // each rank's θ cache serves it without a transfer.
    let Some(rt) = runtime() else { return };
    let params = Params::init(32, &mut Pcg32::seeded(77));
    let theta_bytes = 4 * params.flat.len() as u64;
    let mut rng = Pcg32::seeded(78);
    let graphs: Vec<Graph> =
        (0..2).map(|_| generators::erdos_renyi(20, 0.25, &mut rng)).collect();
    for p in [1usize, 2] {
        let Some(pool) = pool(p) else { return };
        let mut cfg = BatchCfg::new(p, 2);
        cfg.engine.mode = Engine::RankParallel;
        let session = SessionState { theta: None, pool: Some(&pool) };
        let before = pool.rank_stats().unwrap();
        let first = solve_pack_session(
            &rt, &cfg, &params, Scenario::Mvc, graphs.clone(), 24, session,
        )
        .unwrap();
        let mid = pool.rank_stats().unwrap();
        let second = solve_pack_session(
            &rt, &cfg, &params, Scenario::Mvc, graphs.clone(), 24, session,
        )
        .unwrap();
        let after = pool.rank_stats().unwrap();
        // Identical trajectories (same graphs, same params).
        for (a, b) in first.per_graph.iter().zip(&second.per_graph) {
            assert_eq!(a.solution, b.solution, "warm pack diverged from cold");
        }
        for rank in 0..p {
            let cold = mid[rank].since(&before[rank]).h2d_bytes;
            let warm = after[rank].since(&mid[rank]).h2d_bytes;
            assert!(
                warm < cold,
                "P={p} rank {rank}: warm pack moved {warm} B, cold moved {cold} B"
            );
            assert!(
                cold - warm >= theta_bytes,
                "P={p} rank {rank}: warm pack saved {} B, expected ≥ θ ({theta_bytes} B)",
                cold - warm
            );
            let hits = after[rank].since(&mid[rank]).cache_hits;
            assert!(hits >= 7, "P={p} rank {rank}: θ cache hits {hits} < 7");
        }
    }
}

#[test]
fn failing_rank_errors_without_deadlock() {
    // The abort path end to end: a rank that fails mid-step surfaces as a
    // contextful solve error (the sibling ranks blocked in the collective
    // are woken), and the pool recovers for the next pack.
    let Some(rt) = runtime() else { return };
    let g = generators::erdos_renyi(20, 0.25, &mut Pcg32::seeded(79));
    let params = Params::init(32, &mut Pcg32::seeded(80));
    for p in [2usize, 4] {
        let Some(pool) = pool(p) else { return };
        let part = Partition::new(24, p);
        let cfg = EngineCfg::new(p, 2);
        let mut set = fresh_set(&rt, Storage::Dense, part, &g).unwrap();
        pool.install(0, &params, &mut set, true).unwrap();
        let ok = pool.forward(0, &cfg, &set, false, true).unwrap();
        pool.inject_failure(1).unwrap();
        let err = pool.forward(0, &cfg, &set, false, true).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("injected failure"), "P={p}: uncontextful error: {msg}");
        assert!(msg.contains("rank 1"), "P={p}: failing rank not named: {msg}");
        // The pool recovers transparently at the next install.
        let mut set2 = fresh_set(&rt, Storage::Dense, part, &g).unwrap();
        pool.install(0, &params, &mut set2, true).unwrap();
        let again = pool.forward(0, &cfg, &set2, false, true).unwrap();
        assert_eq!(again.scores, ok.scores, "P={p}: recovered pool diverges");
    }
}

#[test]
fn scripted_rank_panic_is_replaced_within_budget() {
    // The ISSUE 7 supervision path end to end at the pool level: a
    // FaultPlan kills rank 1 mid-forward (real rank death, not the
    // cooperative inject_failure hook); the error is contextful, the next
    // install spawns a replacement rank, restart counters tick, and the
    // replaced pool reproduces the pre-fault scores exactly.
    let Some(rt) = runtime() else { return };
    let g = generators::erdos_renyi(20, 0.25, &mut Pcg32::seeded(85));
    let params = Params::init(32, &mut Pcg32::seeded(86));
    for p in [2usize, 4] {
        let plan = oggm::collective::fault::FaultPlan::parse("rank=1,step=1,kind=panic").unwrap();
        let pool = match RankPool::new_with("artifacts", p, 2, Some(std::sync::Arc::new(plan))) {
            Ok(pool) => pool,
            Err(e) => {
                eprintln!("skipping: rank pool unavailable: {e:#}");
                return;
            }
        };
        let part = Partition::new(24, p);
        let cfg = EngineCfg::new(p, 2);
        let mut set = fresh_set(&rt, Storage::Dense, part, &g).unwrap();
        pool.install(0, &params, &mut set, true).unwrap();
        // Step 0 is clean; the scripted panic fires at step 1.
        let ok = pool.forward(0, &cfg, &set, false, true).unwrap();
        let err = pool.forward(0, &cfg, &set, false, true).unwrap_err();
        let msg = format!("{err:#}");
        assert!(
            msg.contains("injected") || msg.contains("rank 1") || msg.contains("panicked"),
            "P={p}: uncontextful fault error: {msg}"
        );
        // The supervisor replaces the dead rank on the next install and
        // the pool solves on — bit-identically.
        let mut set2 = fresh_set(&rt, Storage::Dense, part, &g).unwrap();
        pool.install(0, &params, &mut set2, true).unwrap();
        let again = pool.forward(0, &cfg, &set2, false, true).unwrap();
        assert_eq!(again.scores, ok.scores, "P={p}: replacement rank diverges");
        let (restarts, recovery) = pool.restart_stats();
        assert!(restarts >= 1, "P={p}: no restart recorded");
        assert!(recovery.as_nanos() > 0, "P={p}: no recovery time recorded");
    }
}

#[test]
fn rank_training_matches_lockstep() {
    // End-to-end training: rank-parallel minibatch fwd/bwd + gradient
    // all-reduce must land on the lockstep parameters (fp tolerance, same
    // bound as the trainer's own P-parity test).
    let Some(rt) = runtime() else { return };
    let run = |mode: Engine| -> Option<Vec<f32>> {
        let mut rng = Pcg32::seeded(81);
        let graphs: Vec<Graph> =
            (0..3).map(|_| generators::erdos_renyi(20, 0.15, &mut rng)).collect();
        let mut cfg = TrainCfg::new(2, 24);
        cfg.seed = 5;
        cfg.engine.mode = mode;
        let params = Params::init(32, &mut Pcg32::seeded(82));
        let mut tr = match Trainer::new(&rt, cfg, graphs, params) {
            Ok(tr) => tr,
            Err(e) => {
                eprintln!("skipping: rank pool unavailable: {e:#}");
                return None;
            }
        };
        tr.run_episodes(2, |_| {}).unwrap();
        Some(tr.params.flat)
    };
    let Some(want) = run(Engine::Lockstep) else { return };
    let Some(got) = run(Engine::RankParallel) else { return };
    let d = oggm::util::max_abs_diff(&got, &want);
    assert!(d < 5e-3, "rank-parallel training diverged from lockstep by {d}");
}

#[test]
fn service_rank_engine_streams_identical_outcomes() {
    // The service boundary: the same job set through a rank-parallel
    // session streams the same outcomes as the lockstep session.
    let Some(rt) = runtime() else { return };
    let params = Params::init(32, &mut Pcg32::seeded(83));
    let mut rng = Pcg32::seeded(84);
    let jobs: Vec<oggm::batch::Job> = (0..6)
        .map(|i| oggm::batch::Job {
            id: format!("j{i}"),
            scenario: Scenario::ALL[i % Scenario::ALL.len()],
            graph: generators::erdos_renyi(20, 0.2, &mut rng),
        })
        .collect();
    let drain = |engine: Engine| -> Option<Vec<(String, Vec<usize>)>> {
        let opts = oggm::service::Options::new().p(2).engine(engine);
        let mut svc = oggm::service::Service::new(&rt, params.clone(), &opts);
        for job in &jobs {
            svc.submit(job.clone()).unwrap();
        }
        let mut out = Vec::new();
        for ev in svc.drain() {
            match ev.result {
                Ok(o) => out.push((o.id, o.solution)),
                Err(e) if e.contains("rank-parallel worker pool") => {
                    eprintln!("skipping: rank pool unavailable: {e}");
                    return None;
                }
                Err(e) => panic!("job {} failed: {e}", ev.id),
            }
        }
        out.sort();
        Some(out)
    };
    let Some(want) = drain(Engine::Lockstep) else { return };
    let Some(got) = drain(Engine::RankParallel) else { return };
    assert_eq!(got, want, "service outcomes diverge between engines");
}
