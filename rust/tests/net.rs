//! Networked serve front door invariants (ISSUE 6 acceptance):
//!  - **continuous batching** — while a pack is in flight on the solver
//!    thread, new arrivals keep admitting and a second pack launches
//!    (pinned with a gated stub solver over a real socket);
//!  - per-tenant quota rejects surface as `"rejected":true` JSONL lines
//!    with queue-depth context, and never kill the connection;
//!  - per-job deadlines launch packs with NO client traffic (the tick
//!    driver, not a piggybacked request, fires the clock);
//!  - jobs submitted over the socket produce bit-identical outcomes to
//!    `run_queue` at P in {1, 2} under both engines (artifact-gated).
//!
//! The first three run artifact-less: `serve_with` injects a stub solver,
//! and admission packs against a synthetic manifest — everything else
//! (threads, sockets, wire protocol, launch clocks, quotas) is real.

use oggm::batch::{parse_manifest, run_queue, BatchCfg, Job};
use oggm::batch::queue::JobOutcome;
use oggm::coordinator::engine::Engine;
use oggm::env::Scenario;
use oggm::model::Params;
use oggm::net::{serve, serve_with};
use oggm::runtime::{Manifest, Runtime};
use oggm::service::{JobEvent, LaunchPolicy, Options, PackDone, PackRun};
use oggm::util::json::Json;
use oggm::util::rng::Pcg32;
use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::mpsc;
use std::thread;

/// Synthetic manifest: one N=24 bucket with batch capacities 1/2/4 at P=1,
/// so admission (fill at 4) runs without compiled artifacts.
fn test_manifest(tag: &str) -> Manifest {
    let dir = std::env::temp_dir().join(format!("oggm_net_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(
        dir.join("manifest.tsv"),
        "# oggm artifact manifest\tk=32\tl=2\n\
         q_scores_b1_n24_ni24_k32\tq_scores\t1\t24\t24\t32\t1\tq1.hlo.txt\n\
         q_scores_b2_n24_ni24_k32\tq_scores\t2\t24\t24\t32\t1\tq2.hlo.txt\n\
         q_scores_b4_n24_ni24_k32\tq_scores\t4\t24\t24\t32\t1\tq4.hlo.txt\n",
    )
    .unwrap();
    let m = Manifest::load(&dir).unwrap();
    std::fs::remove_dir_all(&dir).ok();
    m
}

/// Stub solve: echo every member back as a trivial valid outcome (the
/// admission/batching machinery under test is upstream of the solve).
fn echo_done(run: PackRun) -> PackDone {
    let PackRun { pack, scenario, members, .. } = run;
    let events = members
        .into_iter()
        .map(|m| JobEvent {
            job: m.job,
            id: m.id.clone(),
            scenario,
            tenant: m.tenant,
            wait_ms: m.submitted.elapsed().as_secs_f64() * 1e3,
            result: Ok(JobOutcome {
                id: m.id,
                scenario,
                nodes: m.graph.n,
                edges: m.graph.m,
                pack,
                solution: Vec::new(),
                solution_size: 0,
                objective: 0.0,
                valid: true,
                evaluations: 0,
                selections: 0,
            }),
        })
        .collect();
    PackDone { events, stat: None, retries: 0, faults: 0 }
}

#[test]
fn continuous_batching_launches_while_pack_in_flight() {
    let manifest = test_manifest("cb");
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let (started_tx, started_rx) = mpsc::channel::<usize>();
    let (gate_tx, gate_rx) = mpsc::channel::<()>();
    let opts = Options::new().max_conns(1).quota(64);
    let server = thread::spawn(move || {
        serve_with(
            listener,
            manifest,
            &opts,
            Box::new(move |run: PackRun| {
                // Report the launch, then hold the pack "solving" until the
                // test releases it — admission must keep going meanwhile.
                started_tx.send(run.pack).unwrap();
                gate_rx.recv().unwrap();
                echo_done(run)
            }),
        )
        .unwrap()
    });

    let mut sock = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(sock.try_clone().unwrap());
    // Fill pack 0 (synthetic capacity 4): it launches, and the solver
    // blocks holding it in flight.
    for i in 0..4 {
        writeln!(sock, "gen er n=20 seed={i} id=a{i}").unwrap();
    }
    sock.flush().unwrap();
    assert_eq!(started_rx.recv().unwrap(), 0, "pack 0 did not launch");

    // With pack 0 still in flight, four more jobs arrive and fill pack 1.
    // The stats request is queued behind them on the same connection, so
    // its answer observes the post-launch counters.
    for i in 0..4 {
        writeln!(sock, "gen er n=20 seed={} id=b{i}", 10 + i).unwrap();
    }
    writeln!(sock, "{{\"op\":\"stats\"}}").unwrap();
    sock.flush().unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let stats = Json::parse(line.trim()).unwrap();
    assert_eq!(stats.get("op").unwrap().as_str(), Some("stats"), "{line}");
    let s = stats.get("stats").unwrap();
    assert_eq!(
        s.get("launched").unwrap().as_u64(),
        Some(2),
        "pack 1 must launch while pack 0 is still solving: {line}"
    );
    assert_eq!(s.get("in_flight").unwrap().as_u64(), Some(8), "{line}");
    assert_eq!(s.get("rejected").unwrap().as_u64(), Some(0), "no rejects below quota: {line}");

    // Release both packs; all eight outcomes stream back, then EOF.
    gate_tx.send(()).unwrap();
    gate_tx.send(()).unwrap();
    sock.shutdown(Shutdown::Write).unwrap();
    let mut ids = Vec::new();
    for line in reader.lines() {
        let line = line.unwrap();
        let j = Json::parse(&line).unwrap();
        assert!(j.get("error").is_none(), "unexpected error line: {line}");
        assert_eq!(j.get("tenant").unwrap().as_u64(), Some(1), "{line}");
        assert!(j.get("wait_ms").unwrap().as_f64().is_some(), "{line}");
        ids.push(j.get("id").unwrap().as_str().unwrap().to_string());
    }
    assert_eq!(ids, ["a0", "a1", "a2", "a3", "b0", "b1", "b2", "b3"]);

    let summary = server.join().unwrap();
    assert_eq!(summary.conns, 1);
    assert_eq!(summary.jobs, 8);
    assert_eq!(summary.failed, 0);
    assert_eq!(summary.snapshot.fill_launches, 2);
    assert_eq!(summary.snapshot.in_flight, 0);
    assert_eq!(summary.snapshot.pending, 0);
}

#[test]
fn quota_rejects_surface_as_retryable_lines() {
    let manifest = test_manifest("quota");
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    // Quota 1 under OnFlush: the first job sits in an open pack holding
    // the tenant's only slot; the second must bounce.
    let opts = Options::new().max_conns(1).quota(1).launch(LaunchPolicy::OnFlush);
    let server = thread::spawn(move || {
        serve_with(listener, manifest, &opts, Box::new(echo_done)).unwrap()
    });

    let mut sock = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(sock.try_clone().unwrap());
    writeln!(sock, "gen er n=20 seed=1 id=a").unwrap();
    writeln!(sock, "gen er n=20 seed=2 id=b").unwrap();
    sock.flush().unwrap();

    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let j = Json::parse(line.trim()).unwrap();
    assert_eq!(j.get("id").unwrap().as_str(), Some("b"), "{line}");
    assert_eq!(j.get("rejected").unwrap().as_bool(), Some(true), "{line}");
    assert_eq!(j.get("queue_depth").unwrap().as_u64(), Some(1), "{line}");
    assert_eq!(j.get("tenant_load").unwrap().as_u64(), Some(1), "{line}");
    assert!(j.get("error").unwrap().as_str().unwrap().contains("quota"), "{line}");

    // The connection survives the reject: EOF flushes the admitted job.
    sock.shutdown(Shutdown::Write).unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let j = Json::parse(line.trim()).unwrap();
    assert_eq!(j.get("id").unwrap().as_str(), Some("a"), "{line}");
    assert!(j.get("error").is_none(), "{line}");
    assert_eq!(reader.read_line(&mut line).unwrap(), 0, "expected EOF");

    let summary = server.join().unwrap();
    assert_eq!(summary.jobs, 2);
    assert_eq!(summary.failed, 1, "the reject line counts as failed");
    assert_eq!(summary.snapshot.rejected, 1);
    assert_eq!(summary.snapshot.flush_launches, 1);
}

#[test]
fn deadline_launches_with_no_client_traffic() {
    let manifest = test_manifest("deadline");
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let opts = Options::new().max_conns(1);
    let server = thread::spawn(move || {
        serve_with(listener, manifest, &opts, Box::new(echo_done)).unwrap()
    });

    let mut sock = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(sock.try_clone().unwrap());
    // One job (capacity 4, so fill can never fire), then silence: only the
    // tick driver's clock can launch it.
    writeln!(sock, "{{\"id\":\"d\",\"n\":20,\"seed\":3,\"max_latency_ms\":60}}").unwrap();
    sock.flush().unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let j = Json::parse(line.trim()).unwrap();
    assert_eq!(j.get("id").unwrap().as_str(), Some("d"), "{line}");
    assert!(j.get("error").is_none(), "{line}");
    assert!(
        j.get("wait_ms").unwrap().as_f64().unwrap() >= 55.0,
        "launched before the deadline: {line}"
    );

    sock.shutdown(Shutdown::Write).unwrap();
    let mut tail = String::new();
    assert_eq!(reader.read_line(&mut tail).unwrap(), 0, "expected EOF");
    let summary = server.join().unwrap();
    assert_eq!(summary.snapshot.deadline_launches, 1);
    assert_eq!(summary.snapshot.launched, 1);
}

#[test]
fn graceful_drain_under_live_traffic() {
    let manifest = test_manifest("drain");
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let (started_tx, started_rx) = mpsc::channel::<usize>();
    let (gate_tx, gate_rx) = mpsc::channel::<()>();
    // NO max_conns: without the drain request this server would run
    // forever — exiting at all is the property under test.
    let opts = Options::new().quota(64);
    let server = thread::spawn(move || {
        serve_with(
            listener,
            manifest,
            &opts,
            Box::new(move |run: PackRun| {
                started_tx.send(run.pack).unwrap();
                gate_rx.recv().unwrap();
                echo_done(run)
            }),
        )
        .unwrap()
    });

    let mut sock = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(sock.try_clone().unwrap());
    // Pack 0 fills (capacity 4) and launches into the gated solver; two
    // more jobs sit pending in the open pack when the drain arrives.
    for i in 0..4 {
        writeln!(sock, "gen er n=20 seed={i} id=a{i}").unwrap();
    }
    sock.flush().unwrap();
    assert_eq!(started_rx.recv().unwrap(), 0, "pack 0 did not launch");
    writeln!(sock, "gen er n=20 seed=10 id=b0").unwrap();
    writeln!(sock, "gen er n=20 seed=11 id=b1").unwrap();
    writeln!(sock, "{{\"op\":\"drain\"}}").unwrap();
    // A job arriving after the drain request must get a terminal error
    // line, not silence (exactly one line per request, always).
    writeln!(sock, "gen er n=20 seed=12 id=late").unwrap();
    sock.flush().unwrap();

    // The drain ack reports the work still owed: 2 pending (open pack,
    // flushed by the drain), 4 in flight (gated pack 0).
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let ack = Json::parse(line.trim()).unwrap();
    assert_eq!(ack.get("op").unwrap().as_str(), Some("drain"), "{line}");
    assert_eq!(ack.get("draining").unwrap().as_bool(), Some(true), "{line}");
    assert_eq!(ack.get("pending").unwrap().as_u64(), Some(2), "{line}");
    assert_eq!(ack.get("in_flight").unwrap().as_u64(), Some(4), "{line}");

    // Release both packs only now — every admitted job must still stream
    // exactly one outcome before the server exits.
    gate_tx.send(()).unwrap();
    gate_tx.send(()).unwrap();

    // The client never closes its side: the DRAIN ends the connection.
    let mut ids = Vec::new();
    for line in reader.lines() {
        let line = line.unwrap();
        let j = Json::parse(&line).unwrap();
        let id = j.get("id").unwrap().as_str().unwrap().to_string();
        if id == "late" {
            let err = j.get("error").unwrap().as_str().unwrap();
            assert!(err.contains("draining"), "{line}");
        } else {
            assert!(j.get("error").is_none(), "unexpected error line: {line}");
        }
        ids.push(id);
    }
    assert_eq!(ids, ["late", "a0", "a1", "a2", "a3", "b0", "b1"]);

    let summary = server.join().unwrap();
    assert!(summary.drained, "summary must record the drain exit");
    assert_eq!(summary.jobs, 7);
    assert_eq!(summary.failed, 1, "only the post-drain job fails");
    assert_eq!(summary.snapshot.in_flight, 0);
    assert_eq!(summary.snapshot.pending, 0);
    assert_eq!(summary.snapshot.launched, 2, "the open pack flushed on drain");
}

#[test]
fn socket_jobs_match_run_queue_bit_exact() {
    if !std::path::Path::new("artifacts/manifest.tsv").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let rt = Runtime::new("artifacts").unwrap();
    let params = Params::init(32, &mut Pcg32::seeded(41));
    // The exact request lines a client would send; the reference run
    // materializes the same specs, so both sides solve identical graphs.
    let lines: Vec<String> = (0..6)
        .map(|i| {
            let model = if i % 2 == 0 { "er" } else { "ba" };
            let scenario = Scenario::ALL[i % Scenario::ALL.len()].name();
            format!("gen {model} n=20 d=3 seed={} id=j{i} {scenario}", 94 + i)
        })
        .collect();
    let specs = parse_manifest(&lines.join("\n")).unwrap();

    for p in [1usize, 2] {
        if rt.manifest.batch_sizes(24, 24 / p).last().copied().unwrap_or(0) < 4 {
            eprintln!("skipping P={p}: no compiled batch shapes at N=24");
            continue;
        }
        for engine in [Engine::Lockstep, Engine::RankParallel] {
            let opts = Options::new().p(p).engine(engine).max_conns(1);
            let jobs: Vec<Job> = specs
                .iter()
                .map(|s| Job {
                    id: s.id.clone(),
                    scenario: s.scenario,
                    graph: s.materialize().unwrap(),
                })
                .collect();
            let reference = run_queue(&rt, &BatchCfg::from(&opts), &params, &jobs).unwrap();

            let listener = TcpListener::bind("127.0.0.1:0").unwrap();
            let addr = listener.local_addr().unwrap();
            let (params2, opts2) = (params.clone(), opts.clone());
            let server =
                thread::spawn(move || serve(listener, "artifacts", params2, &opts2).unwrap());
            let mut sock = TcpStream::connect(addr).unwrap();
            let reader = BufReader::new(sock.try_clone().unwrap());
            for l in &lines {
                writeln!(sock, "{l}").unwrap();
            }
            sock.flush().unwrap();
            sock.shutdown(Shutdown::Write).unwrap();

            let mut got: HashMap<String, Json> = HashMap::new();
            for line in reader.lines() {
                let line = line.unwrap();
                let j = Json::parse(&line).unwrap();
                assert!(j.get("error").is_none(), "P={p} {engine:?}: error line {line}");
                got.insert(j.get("id").unwrap().as_str().unwrap().to_string(), j);
            }
            assert_eq!(got.len(), jobs.len(), "P={p} {engine:?}: outcome count");
            for want in &reference.outcomes {
                let g = &got[&want.id];
                let sol: Vec<u64> = match g.get("solution").unwrap() {
                    Json::Arr(xs) => xs.iter().map(|x| x.as_u64().unwrap()).collect(),
                    other => panic!("solution is not an array: {other:?}"),
                };
                let want_sol: Vec<u64> = want.solution.iter().map(|&v| v as u64).collect();
                assert_eq!(
                    sol, want_sol,
                    "P={p} {engine:?} job {}: solution diverged from run_queue",
                    want.id
                );
                assert_eq!(
                    g.get("solution_size").unwrap().as_u64(),
                    Some(want.solution_size as u64),
                    "job {}",
                    want.id
                );
                assert_eq!(
                    g.get("objective").unwrap().as_f64(),
                    Some(want.objective),
                    "job {}",
                    want.id
                );
                assert_eq!(g.get("valid").unwrap().as_bool(), Some(want.valid), "job {}", want.id);
                assert_eq!(
                    g.get("evaluations").unwrap().as_u64(),
                    Some(want.evaluations as u64),
                    "job {}",
                    want.id
                );
                assert_eq!(
                    g.get("selections").unwrap().as_u64(),
                    Some(want.selections as u64),
                    "job {}",
                    want.id
                );
            }
            let summary = server.join().unwrap();
            assert_eq!(summary.jobs, jobs.len() as u64, "P={p} {engine:?}");
            assert_eq!(summary.failed, 0, "P={p} {engine:?}");
        }
    }
}
