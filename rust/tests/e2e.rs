//! End-to-end smoke: train a small agent, save/load params, run inference
//! with multi-node selection, and beat random selection quality-wise.

use oggm::coordinator::infer::{solve_mvc, InferCfg};
use oggm::coordinator::selection::SelectionPolicy;
use oggm::coordinator::train::{TrainCfg, Trainer};
use oggm::env::mvc::MvcEnv;
use oggm::graph::generators;
use oggm::model::Params;
use oggm::runtime::Runtime;
use oggm::util::rng::Pcg32;

fn setup() -> Option<Runtime> {
    if !std::path::Path::new("artifacts/manifest.tsv").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Runtime::new("artifacts").unwrap())
}

#[test]
fn train_save_load_infer_roundtrip() {
    let Some(rt) = setup() else { return };
    let mut rng = Pcg32::seeded(100);
    let graphs: Vec<_> =
        (0..6).map(|_| generators::erdos_renyi(20, 0.15, &mut rng)).collect();

    // Short training run (the full learning curve lives in bench_fig6).
    let mut cfg = TrainCfg::new(2, 24);
    cfg.hyper.lr = 1e-3;
    cfg.hyper.grad_iters = 2;
    cfg.seed = 7;
    let params0 = Params::init(32, &mut Pcg32::seeded(101));
    let mut trainer = Trainer::new(&rt, cfg, graphs.clone(), params0).unwrap();
    let mut losses = Vec::new();
    trainer
        .run_episodes(8, |rec| {
            if let Some(l) = rec.loss {
                losses.push(l);
            }
        })
        .unwrap();
    assert!(!losses.is_empty());
    // Loss trend: mean of last quarter below mean of first quarter.
    let q = losses.len() / 4;
    if q > 0 {
        let first: f32 = losses[..q].iter().sum::<f32>() / q as f32;
        let last: f32 = losses[losses.len() - q..].iter().sum::<f32>() / q as f32;
        assert!(last <= first * 2.0, "loss exploded: {first} -> {last}");
    }

    // Save + reload parameters.
    let dir = std::env::temp_dir().join(format!("oggm_e2e_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let ppath = dir.join("trained.oggm");
    trainer.params.save(&ppath).unwrap();
    let params = Params::load(&ppath, 32).unwrap();
    assert_eq!(params.flat, trainer.params.flat);

    // Inference on an unseen graph, single and adaptive-multi.
    let test_g = generators::erdos_renyi(20, 0.15, &mut rng);
    let mut icfg = InferCfg::new(2, 2);
    icfg.policy = SelectionPolicy::Single;
    let res = solve_mvc(&rt, &icfg, &params, &test_g, 24).unwrap();
    assert!(MvcEnv::is_vertex_cover(&test_g, &res.solution));

    icfg.policy = SelectionPolicy::AdaptiveMulti;
    let res_m = solve_mvc(&rt, &icfg, &params, &test_g, 24).unwrap();
    assert!(MvcEnv::is_vertex_cover(&test_g, &res_m.solution));
    assert!(res_m.evaluations <= res.evaluations);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn trained_agent_close_to_greedy() {
    // After a modest training run on 20-node graphs, the agent's covers
    // should be within 40% of greedy's on unseen graphs (sanity bound;
    // bench_fig6 measures the real approximation ratios).
    let Some(rt) = setup() else { return };
    let mut rng = Pcg32::seeded(200);
    let graphs: Vec<_> =
        (0..8).map(|_| generators::erdos_renyi(20, 0.15, &mut rng)).collect();
    let mut cfg = TrainCfg::new(1, 24);
    cfg.hyper.lr = 1e-3;
    cfg.hyper.grad_iters = 4;
    cfg.hyper.eps_decay_steps = 120;
    cfg.seed = 9;
    let params0 = Params::init(32, &mut Pcg32::seeded(201));
    let mut trainer = Trainer::new(&rt, cfg, graphs, params0).unwrap();
    trainer.run_episodes(20, |_| {}).unwrap();

    let icfg = InferCfg::new(1, 2);
    let mut agent_total = 0usize;
    let mut greedy_total = 0usize;
    for _ in 0..5 {
        let g = generators::erdos_renyi(20, 0.15, &mut rng);
        let res = solve_mvc(&rt, &icfg, &trainer.params, &g, 24).unwrap();
        agent_total += res.solution_size;
        greedy_total += oggm::solvers::greedy_mvc(&g).iter().filter(|&&b| b).count();
    }
    assert!(
        (agent_total as f64) <= greedy_total as f64 * 1.4,
        "agent {agent_total} vs greedy {greedy_total}"
    );
}
