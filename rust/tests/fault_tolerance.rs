//! Fault-tolerance invariants (ISSUE 7 acceptance):
//!  - with a deterministic injected rank failure mid-pack
//!    (`--fault-plan`), the session's rank pool replaces the dead rank
//!    and the retried pack's solutions are **bit-identical** to a
//!    fault-free run — dense and sparse, P in {2, 4};
//!  - the retry is visible in the books: `PackStat::retries`, the pool's
//!    restart counters, and the admission snapshot's `retried_packs` /
//!    `pack_faults`;
//!  - a non-fatal injected worker error (kind=err) retries the pack
//!    without needing a rank replacement.
//!
//! Runtime-dependent tests skip when artifacts are not built (same
//! convention as service.rs / parallel_equivalence.rs). Fault plans are
//! passed through `Options::fault_plan` — never the environment — so
//! concurrent tests cannot contaminate each other.

#[path = "../benches/common.rs"]
mod common;

use common::mixed_jobs;
use oggm::batch::{run_queue, BatchCfg, Job};
use oggm::coordinator::engine::Engine;
use oggm::coordinator::shard::Storage;
use oggm::model::Params;
use oggm::runtime::Runtime;
use oggm::service::{Options, Service};
use oggm::util::rng::Pcg32;

fn setup() -> Option<Runtime> {
    if !std::path::Path::new("artifacts/manifest.tsv").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Runtime::new("artifacts").unwrap())
}

fn has_batch_shapes(rt: &Runtime, bucket: usize, p: usize, b: usize) -> bool {
    let ok = rt.manifest.batch_sizes(bucket, bucket / p).last().copied().unwrap_or(0) >= b;
    if !ok {
        eprintln!("skipping: no compiled batch-{b} shapes at N={bucket}, P={p}");
    }
    ok
}

/// The shared scaffold: solve `jobs` fault-free with `run_queue`, then
/// again through a `Service` with `plan` injected and retries enabled;
/// assert every outcome is bit-identical and return the faulted service
/// for counter assertions.
fn assert_faulted_run_matches<'r>(
    rt: &'r Runtime,
    jobs: &[Job],
    p: usize,
    storage: Storage,
    plan: &str,
) -> Service<'r> {
    let params = Params::init(32, &mut Pcg32::seeded(41));
    let opts = Options::new().p(p).engine(Engine::RankParallel).storage(storage);
    let reference = run_queue(rt, &BatchCfg::from(&opts), &params, jobs).unwrap();

    let faulted = opts.retries(2).max_rank_restarts(2).fault_plan(plan);
    let mut svc = Service::new(rt, params, &faulted);
    for job in jobs.iter().cloned() {
        svc.submit(job).unwrap();
    }
    let events = svc.drain();
    assert_eq!(events.len(), jobs.len(), "P={p} {storage:?} [{plan}]: event count");
    for ev in events {
        let got = ev.result.unwrap_or_else(|e| {
            panic!("P={p} {storage:?} [{plan}]: job failed despite retry budget: {e}")
        });
        let want = reference.outcomes.iter().find(|o| o.id == got.id).expect("unknown job id");
        assert_eq!(
            got.solution, want.solution,
            "P={p} {storage:?} [{plan}] job {}: retried solution diverged from fault-free run",
            got.id
        );
        assert_eq!(got.solution_size, want.solution_size, "job {}", got.id);
        assert_eq!(got.objective, want.objective, "job {}", got.id);
        assert_eq!(got.valid, want.valid, "job {}", got.id);
        assert_eq!(got.evaluations, want.evaluations, "job {}", got.id);
        assert_eq!(got.selections, want.selections, "job {}", got.id);
    }
    svc
}

#[test]
fn injected_rank_panic_is_replaced_and_retried_bit_identical() {
    let Some(rt) = setup() else { return };
    let jobs = mixed_jobs(9, 0x5E);
    for p in [2usize, 4] {
        if !has_batch_shapes(&rt, 24, p, 4) {
            continue;
        }
        for storage in [Storage::Dense, Storage::Sparse] {
            if storage == Storage::Sparse
                && [1usize, 2, 4].iter().any(|&b| rt.manifest.sparse_config(b, 24 / p, 32).is_err())
            {
                eprintln!("skipping sparse arm: sparse artifacts not compiled at N=24, P={p}");
                continue;
            }
            // Rank 1 panics at its second forward step: mid-pack, after
            // real work started. One-shot, so exactly one pack is hit.
            let svc =
                assert_faulted_run_matches(&rt, &jobs, p, storage, "rank=1,step=1,kind=panic");

            let packs = svc.packs();
            let retried: usize = packs.iter().map(|s| s.retries).sum();
            assert!(retried >= 1, "P={p} {storage:?}: no pack recorded a retry");
            let restarts: u64 = packs.iter().map(|s| s.exec.restarts).sum();
            assert!(restarts >= 1, "P={p} {storage:?}: the dead rank was never replaced");
            assert!(
                packs.iter().any(|s| s.exec.recovery_time.as_nanos() > 0),
                "P={p} {storage:?}: recovery time not recorded"
            );
            let snap = svc.admission();
            assert!(snap.retried_packs >= 1, "P={p} {storage:?}: {snap:?}");
            assert!(snap.pack_faults >= 1, "P={p} {storage:?}: {snap:?}");
        }
    }
}

#[test]
fn injected_worker_error_retries_without_rank_replacement() {
    let Some(rt) = setup() else { return };
    let jobs = mixed_jobs(6, 0x2B);
    let p = 2;
    if !has_batch_shapes(&rt, 24, p, 4) {
        return;
    }
    // kind=err aborts the collective round but the worker thread survives:
    // the pack retries on the SAME ranks, no replacement spawned.
    let svc = assert_faulted_run_matches(&rt, &jobs, p, Storage::Dense, "rank=1,step=0,kind=err");
    let packs = svc.packs();
    assert!(packs.iter().map(|s| s.retries).sum::<usize>() >= 1, "no pack recorded a retry");
    assert_eq!(
        packs.iter().map(|s| s.exec.restarts).sum::<u64>(),
        0,
        "a surviving worker must not be replaced"
    );
    assert!(svc.admission().pack_faults >= 1);
}
