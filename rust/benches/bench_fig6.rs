//! Fig. 6: learning speed. Trains on 20-node ER and BA graphs and tracks
//! the mean approximation ratio on 10 held-out test graphs of 20 nodes
//! (subfigures 1a/2a) and 250 nodes (1b/2b) — the generalization claim.
//!
//! Paper shapes to reproduce: ER-20 test ratio 1.5 -> ~1.1; BA-20
//! 1.32 -> ~1.17; 250-node test ratios also improve (generalization).
//!
//! Env: OGGM_FAST=1 for a short smoke run; OGGM_FIG6_STEPS overrides.

#[path = "common.rs"]
mod common;

use oggm::coordinator::infer::{solve_mvc, InferCfg};
use oggm::coordinator::metrics::{approx_ratio, write_curve_csv, CurvePoint, Table};
use oggm::coordinator::selection::SelectionPolicy;
use oggm::coordinator::train::{TrainCfg, Trainer};
use oggm::graph::{generators, Graph};
use oggm::model::Params;
use oggm::runtime::Runtime;
use oggm::util::rng::Pcg32;
use std::time::Duration;

struct TestSet {
    label: &'static str,
    bucket: usize,
    graphs: Vec<(Graph, usize)>,
}

fn make_tests(kind: &str, n: usize, bucket: usize, count: usize, rng: &mut Pcg32,
              label: &'static str) -> TestSet {
    let budget = Duration::from_secs(if n > 100 { 3 } else { 10 });
    let graphs = (0..count)
        .map(|_| {
            let g = match kind {
                "er" => generators::erdos_renyi(n, 0.15, rng),
                _ => generators::barabasi_albert(n, 4, rng),
            };
            let opt = oggm::solvers::exact_mvc(&g, budget).size;
            (g, opt)
        })
        .collect();
    TestSet { label, bucket, graphs }
}

fn eval(rt: &Runtime, params: &Params, ts: &TestSet) -> f64 {
    let mut cfg = InferCfg::new(1, 2);
    if ts.bucket > 100 {
        // Large test graphs use adaptive multi-select for evaluation speed;
        // Fig. 7 shows the quality impact is ~1.00x at these sizes.
        cfg.policy = SelectionPolicy::AdaptiveMulti;
    }
    ts.graphs
        .iter()
        .map(|(g, opt)| {
            let res = solve_mvc(rt, &cfg, params, g, ts.bucket).unwrap();
            approx_ratio(res.solution_size, *opt)
        })
        .sum::<f64>()
        / ts.graphs.len() as f64
}

fn run_family(rt: &Runtime, kind: &str, steps: usize, eval_every: usize) -> Vec<(String, Vec<CurvePoint>)> {
    let mut rng = Pcg32::seeded(0x6A + kind.len() as u64);
    let train_graphs: Vec<Graph> = (0..16)
        .map(|_| match kind {
            "er" => generators::erdos_renyi(20, 0.15, &mut rng),
            _ => generators::barabasi_albert(20, 4, &mut rng),
        })
        .collect();
    let n_tests = common::scaled(10, 4);
    let tests_small = make_tests(kind, 20, 24, n_tests, &mut rng, "test|V|=20");
    let tests_large = make_tests(kind, 250, 252, common::scaled(6, 2), &mut rng, "test|V|=250");

    let mut cfg = TrainCfg::new(1, 24);
    cfg.seed = 17;
    cfg.hyper.lr = 1e-3;
    cfg.hyper.grad_iters = 4;
    cfg.hyper.eps_decay_steps = steps / 2;
    let params0 = common::init_params(&mut rng);
    let mut trainer = Trainer::new(rt, cfg, train_graphs, params0).unwrap();

    let mut curves: Vec<(String, Vec<CurvePoint>)> = vec![
        (format!("{kind}-test20"), Vec::new()),
        (format!("{kind}-test250"), Vec::new()),
    ];
    let r0 = eval(rt, &trainer.params, &tests_small);
    let r1 = eval(rt, &trainer.params, &tests_large);
    curves[0].1.push(CurvePoint { step: 0, ratio: r0, loss: None });
    curves[1].1.push(CurvePoint { step: 0, ratio: r1, loss: None });
    println!("[{kind}] step 0: ratio20 {r0:.4} ratio250 {r1:.4}");

    while trainer.global_step < steps {
        let mut marks = Vec::new();
        trainer
            .run_episodes(1, |rec| {
                if rec.global_step % eval_every == 0 {
                    marks.push((rec.global_step, rec.loss));
                }
            })
            .unwrap();
        for (step, loss) in marks {
            let r0 = eval(rt, &trainer.params, &tests_small);
            let r1 = eval(rt, &trainer.params, &tests_large);
            println!(
                "[{kind}] step {step}: ratio20 {r0:.4} ratio250 {r1:.4} loss {}",
                loss.map(|l| format!("{l:.4}")).unwrap_or_else(|| "-".into())
            );
            curves[0].1.push(CurvePoint { step, ratio: r0, loss: loss.map(|l| l as f64) });
            curves[1].1.push(CurvePoint { step, ratio: r1, loss: loss.map(|l| l as f64) });
        }
    }
    curves
}

fn main() {
    let rt = common::runtime();
    let steps: usize = std::env::var("OGGM_FIG6_STEPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or_else(|| common::scaled(300, 40));
    let eval_every = common::scaled(25, 20);

    let mut table = Table::new(
        "Fig. 6: learning curves (mean approx ratio, first -> best)",
        &["first", "best", "last"],
    );
    for kind in ["er", "ba"] {
        let curves = run_family(&rt, kind, steps, eval_every);
        for (label, points) in curves {
            let first = points.first().map(|p| p.ratio).unwrap_or(f64::NAN);
            let best = points.iter().map(|p| p.ratio).fold(f64::INFINITY, f64::min);
            let last = points.last().map(|p| p.ratio).unwrap_or(f64::NAN);
            table.row(label.clone(), vec![first, best, last]);
            write_curve_csv(format!("bench_fig6_{label}.csv"), &points).unwrap();
        }
    }
    common::emit(&table);
    println!("fig6: curves written to bench_fig6_*.csv");
}
