//! bench_queue: solver-service throughput, cold vs warm (ISSUE 4).
//!
//! A service session's first drain pays θ upload + XLA compiles; every
//! later drain runs against the warm runtime (compiled executables, θ
//! device-resident under the service's ThetaCache). This bench submits the
//! same mixed-scenario job set through one `Service` twice and reports
//! jobs/sec plus h2d bytes for the cold and warm drains, and the
//! amortized warm throughput over several repeats. Emits BENCH_queue.json.
//!
//! Check mode: without artifacts (CI containers) the bench prints a skip
//! notice and exits 0, like the artifact-gated tests.

#[path = "common.rs"]
mod common;

use oggm::batch::{BatchCfg, Job};
use oggm::coordinator::metrics::Table;
use oggm::service::Service;
use oggm::util::json::Json;
use oggm::util::rng::Pcg32;
use std::time::Instant;

/// Submit + drain the job set once; returns (wall seconds, h2d bytes).
fn drain_once(svc: &mut Service<'_>, set: &[Job]) -> (f64, u64) {
    let snap = svc.runtime().stats();
    let t0 = Instant::now();
    for job in set {
        svc.submit(job.clone()).expect("admission failed");
    }
    let events = svc.drain();
    let wall = t0.elapsed().as_secs_f64();
    assert_eq!(events.len(), set.len());
    for ev in &events {
        assert!(ev.result.is_ok(), "job {} failed: {:?}", ev.id, ev.result);
    }
    (wall, svc.runtime().stats().since(&snap).h2d_bytes)
}

fn main() {
    if !oggm::runtime::manifest::default_dir().join("manifest.tsv").exists() {
        println!("bench_queue: artifacts not built, skipping (check mode OK)");
        return;
    }
    let rt = common::runtime();
    let mut rng = Pcg32::seeded(0xC1);
    let params = common::init_params(&mut rng);
    let count = common::scaled(12, 6);
    let set = common::mixed_jobs(count, 0xC0);
    let reps = common::scaled(3, 1);

    let p_list: Vec<usize> = if common::fast_mode() { vec![1] } else { vec![1, 2] };
    let mut table = Table::new(
        &format!("bench_queue: {count} mixed-scenario jobs |V|=20 through one Service"),
        &["cold_jps", "warm_jps", "speedup", "cold_h2d_B", "warm_h2d_B"],
    );
    let mut rows = Vec::new();
    for &p in &p_list {
        if rt.manifest.batch_sizes(24, 24 / p).last().copied().unwrap_or(0) < 4 {
            println!("P={p}: no compiled batch shapes at N=24, skipping");
            continue;
        }
        let mut svc = Service::with_cfg(&rt, params.clone(), BatchCfg::new(p, 2));
        let (cold_wall, cold_h2d) = drain_once(&mut svc, &set);
        // Warm: amortize over reps on the SAME session.
        let (mut warm_wall, mut warm_h2d) = (0.0f64, 0u64);
        for _ in 0..reps {
            let (w, h) = drain_once(&mut svc, &set);
            warm_wall += w;
            warm_h2d += h;
        }
        let warm_wall = warm_wall / reps as f64;
        let warm_h2d = warm_h2d / reps as u64;
        let cold_jps = count as f64 / cold_wall;
        let warm_jps = count as f64 / warm_wall;
        println!(
            "P={p}: cold {cold_jps:.2} jobs/s, warm {warm_jps:.2} jobs/s \
             ({:.2}x), h2d {cold_h2d} -> {warm_h2d} B/drain, resident {:.1} KiB",
            warm_jps / cold_jps,
            rt.keyed_bytes() as f64 / 1024.0
        );
        table.row(
            format!("P={p}"),
            vec![cold_jps, warm_jps, warm_jps / cold_jps, cold_h2d as f64, warm_h2d as f64],
        );
        rows.push(
            Json::obj()
                .set("p", p)
                .set("jobs", count)
                .set("cold_jobs_per_sec", cold_jps)
                .set("warm_jobs_per_sec", warm_jps)
                .set("speedup", warm_jps / cold_jps)
                .set("cold_h2d_bytes", cold_h2d)
                .set("warm_h2d_bytes", warm_h2d),
        );
    }
    common::emit(&table);
    let json = Json::obj().set("bench", "queue").set("rows", Json::Arr(rows));
    std::fs::write("BENCH_queue.json", json.render()).expect("write BENCH_queue.json");
    println!("bench_queue: wrote BENCH_queue.json; OK");
}
