//! Fig. 7: original (d=1) inference vs adaptive multiple-node selection
//! (§4.5.1) on unseen ER graphs. Paper shape: 2.5–3.7x faster with MVC
//! ratio |MVC_new| / |MVC_orig| within ~1.008.
//!
//! Paper sizes were 750/1500/3000; defaults here are 756/1500 with 3000
//! included when OGGM_FIG7_FULL=1 (CPU-time guard, DESIGN.md §3).

#[path = "common.rs"]
mod common;

use oggm::coordinator::infer::{solve_mvc, InferCfg};
use oggm::coordinator::metrics::Table;
use oggm::coordinator::selection::SelectionPolicy;
use oggm::graph::generators;
use oggm::util::rng::Pcg32;

fn main() {
    let rt = common::runtime();
    let mut rng = Pcg32::seeded(0x77);
    let params = common::quick_trained_params(&rt, common::scaled(12, 3), 0x77);

    let mut sizes: Vec<usize> = if common::fast_mode() { vec![252] } else { vec![756, 1500] };
    if std::env::var("OGGM_FIG7_FULL").map(|v| v == "1").unwrap_or(false) {
        sizes.push(3000);
    }

    let mut t = Table::new(
        "Fig. 7: d=1 vs adaptive multi-node selection",
        &["orig_s", "multi_s", "speedup", "evals_orig", "evals_multi", "mvc_ratio"],
    );
    for &n in &sizes {
        let g = generators::erdos_renyi(n, 0.15, &mut rng);
        let mut orig = InferCfg::new(1, 2);
        orig.policy = SelectionPolicy::Single;
        let mut multi = InferCfg::new(1, 2);
        multi.policy = SelectionPolicy::AdaptiveMulti;

        let ro = solve_mvc(&rt, &orig, &params, &g, n).unwrap();
        let rm = solve_mvc(&rt, &multi, &params, &g, n).unwrap();
        let t_o = ro.sim_time_per_eval * ro.evaluations as f64;
        let t_m = rm.sim_time_per_eval * rm.evaluations as f64;
        let ratio = rm.solution_size as f64 / ro.solution_size as f64;
        t.row(
            format!("N={n}"),
            vec![
                t_o,
                t_m,
                t_o / t_m,
                ro.evaluations as f64,
                rm.evaluations as f64,
                ratio,
            ],
        );
        println!(
            "N={n}: orig {:.2}s ({} evals) vs multi {:.2}s ({} evals) — {:.2}x, ratio {:.4}",
            t_o, ro.evaluations, t_m, rm.evaluations, t_o / t_m, ratio
        );
        assert!(ratio < 1.15, "multi-select degraded quality too much: {ratio}");
    }
    common::emit(&t);
    println!("fig7: OK");
}
