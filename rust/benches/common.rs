//! Shared helpers for the bench harness binaries (criterion is unavailable
//! offline; every bench is a plain binary printing the paper's table/figure
//! rows and appending JSON to bench_results.jsonl).

#![allow(dead_code)]

use oggm::model::Params;
use oggm::runtime::{manifest, Runtime};
use oggm::util::rng::Pcg32;

/// Fast mode trims iteration counts/sizes (set OGGM_FAST=1).
pub fn fast_mode() -> bool {
    std::env::var("OGGM_FAST").map(|v| v == "1").unwrap_or(false)
}

/// Scale an iteration count down in fast mode.
pub fn scaled(full: usize, fast: usize) -> usize {
    if fast_mode() { fast } else { full }
}

pub fn runtime() -> Runtime {
    Runtime::new(manifest::default_dir()).expect("run `make artifacts` first")
}

/// Reproducible parameters: the python-initialized set when present.
pub fn init_params(rng: &mut Pcg32) -> Params {
    let init = manifest::default_dir().join("params_init.oggm");
    if init.exists() {
        Params::load(init, 32).unwrap()
    } else {
        Params::init(32, rng)
    }
}

/// Append a table to the results log and print it.
pub fn emit(table: &oggm::coordinator::metrics::Table) {
    println!("{}", table.render());
    if let Err(e) = table.append_jsonl("bench_results.jsonl") {
        eprintln!("warn: could not append bench_results.jsonl: {e}");
    }
}

/// Mixed-scenario job set: alternating ER/BA |V|=20 graphs cycling through
/// every scenario in `Scenario::ALL` order. Shared by `bench_queue` and
/// `rust/tests/service.rs` (via `#[path]`) so the bench measures exactly
/// the job mix the service equivalence tests pin.
pub fn mixed_jobs(count: usize, seed: u64) -> Vec<oggm::batch::Job> {
    use oggm::env::Scenario;
    use oggm::graph::generators;
    let mut rng = Pcg32::seeded(seed);
    (0..count)
        .map(|i| {
            let g = if i % 2 == 0 {
                generators::erdos_renyi(20, 0.2, &mut rng)
            } else {
                generators::barabasi_albert(20, 3, &mut rng)
            };
            oggm::batch::Job {
                id: format!("j{i}"),
                scenario: Scenario::ALL[i % Scenario::ALL.len()],
                graph: g,
            }
        })
        .collect()
}

/// Pre-trained parameters for inference benches: run a short training burst
/// so scores are meaningful (heavier training is train_mvc's job).
pub fn quick_trained_params(rt: &Runtime, episodes: usize, seed: u64) -> Params {
    use oggm::coordinator::train::{TrainCfg, Trainer};
    use oggm::graph::generators;
    let mut rng = Pcg32::new(seed, 3);
    let graphs: Vec<_> =
        (0..8).map(|_| generators::erdos_renyi(20, 0.15, &mut rng)).collect();
    let mut cfg = TrainCfg::new(1, 24);
    cfg.seed = seed;
    cfg.hyper.lr = 1e-3;
    cfg.hyper.grad_iters = 4;
    let params0 = init_params(&mut rng);
    let mut tr = Trainer::new(rt, cfg, graphs, params0).unwrap();
    tr.run_episodes(episodes, |_| {}).unwrap();
    tr.params
}
