//! §5 analysis: the Eq. 3–7 parallel-efficiency model and §5.2 memory model,
//! compared against the measured scaling of the distributed forward pass.
//! The model's sec_per_flop is calibrated from the measured P=1 point; the
//! check is whether the *shape* of time-vs-P matches.

#[path = "common.rs"]
mod common;

use oggm::analysis::{MemoryModel, ModelConfig};
use oggm::collective::CostModel;
use oggm::coordinator::engine::EngineCfg;
use oggm::coordinator::fwd::forward;
use oggm::coordinator::metrics::Table;
use oggm::coordinator::shard::shards_for_graph;
use oggm::env::{GraphEnv, MvcEnv};
use oggm::graph::{generators, Partition};
use oggm::util::rng::Pcg32;

fn main() {
    let rt = common::runtime();
    let mut rng = Pcg32::seeded(0xBB);
    let params = common::init_params(&mut rng);
    // Fast mode uses the 252 bucket, whose artifacts cover P ∈ {1,2,3}.
    let (n, p_list): (usize, Vec<usize>) = if common::fast_mode() {
        (252, vec![1, 2, 3])
    } else {
        (1488, vec![1, 2, 3, 4, 6])
    };
    let rho = 0.15;
    let g = generators::erdos_renyi(n, rho, &mut rng);
    let env = MvcEnv::new(g.clone());
    let cand: Vec<bool> = (0..g.n).map(|v| env.is_candidate(v)).collect();

    // Measure simulated step time per P.
    let mut measured = Vec::new();
    for &p in &p_list {
        let part = Partition::new(n, p);
        let shards = shards_for_graph(part, &g, env.removed_mask(), env.solution_mask(), &cand);
        let cfg = EngineCfg::new(p, 2);
        forward(&rt, &cfg, &params, &shards, false, true).unwrap();
        let out = forward(&rt, &cfg, &params, &shards, false, true).unwrap();
        measured.push(out.timing.simulated());
    }

    // Calibrate the model at P=1.
    let mut model = ModelConfig {
        b: 1,
        n,
        rho,
        k: 32,
        l: 2,
        sec_per_flop: 1e-10,
        net: CostModel::default(),
    };
    let base = model.t_policy_eval(1);
    model.sec_per_flop *= measured[0] / base;

    let mut t = Table::new(
        "Sec. 5.1 model vs measured (policy evaluation, seconds)",
        &["measured", "model", "model_eff_embed", "model_eff_action"],
    );
    for (i, &p) in p_list.iter().enumerate() {
        t.row(
            format!("P={p}"),
            vec![
                measured[i],
                model.t_policy_eval(p),
                model.efficiency_embed(p),
                model.efficiency_action(p),
            ],
        );
    }
    common::emit(&t);

    // Shape check: model and measurement agree on speedup@6 within 2.5x.
    let sp_meas = measured[0] / *measured.last().unwrap();
    let sp_model = model.t_policy_eval(1) / model.t_policy_eval(*p_list.last().unwrap());
    println!("speedup@max-P: measured {sp_meas:.2}x, model {sp_model:.2}x");
    assert!(sp_meas / sp_model < 2.5 && sp_model / sp_meas < 2.5,
            "model and measurement diverge on scaling shape");

    // §5.2 memory model at the paper's full scale.
    let mem = MemoryModel { b: 1, n: 21000, rho: 0.15, replay_tuples: 50_000 };
    let mut mt = Table::new(
        "Sec. 5.2 memory model at paper scale (MiB per device, N=21000)",
        &["P=1", "P=2", "P=6"],
    );
    let mib = 1024.0 * 1024.0;
    mt.row("A (sparse COO, paper)", vec![
        mem.adjacency_coo_bytes(1) / mib,
        mem.adjacency_coo_bytes(2) / mib,
        mem.adjacency_coo_bytes(6) / mib,
    ]);
    mt.row("A (dense f32, this repo)", vec![
        mem.adjacency_dense_bytes(1) / mib,
        mem.adjacency_dense_bytes(2) / mib,
        mem.adjacency_dense_bytes(6) / mib,
    ]);
    mt.row("replay (compressed)", vec![
        mem.replay_bytes(1) / mib,
        mem.replay_bytes(2) / mib,
        mem.replay_bytes(6) / mib,
    ]);
    mt.row("replay (dense ablation)", vec![
        mem.replay_bytes_uncompressed(1) / mib,
        mem.replay_bytes_uncompressed(2) / mib,
        mem.replay_bytes_uncompressed(6) / mib,
    ]);
    common::emit(&mt);
    println!("analysis: OK");
}
