//! Ablations of the design choices DESIGN.md calls out:
//!   1. skip-zero-layer forward optimization (exactness + speed),
//!   2. replay-buffer compression (paper §4.4) vs dense tuples,
//!   3. collective microbenchmarks (real threaded Communicator),
//!   4. solver baselines quality/runtime on ER graphs,
//!   5. fixed-d selection sweep (context for the adaptive schedule).

#[path = "common.rs"]
mod common;

use oggm::collective::Communicator;
use oggm::coordinator::engine::EngineCfg;
use oggm::coordinator::fwd::forward;
use oggm::coordinator::infer::{solve_mvc, InferCfg};
use oggm::coordinator::metrics::Table;
use oggm::coordinator::selection::SelectionPolicy;
use oggm::coordinator::shard::shards_for_graph;
use oggm::env::{GraphEnv, MvcEnv};
use oggm::graph::{generators, Partition};
use oggm::util::rng::Pcg32;
use oggm::util::timer;
use std::time::Duration;

fn ablate_skip_zero_layer(rt: &oggm::runtime::Runtime) {
    let mut rng = Pcg32::seeded(1);
    let params = common::init_params(&mut rng);
    let n = if common::fast_mode() { 252 } else { 756 };
    let g = generators::erdos_renyi(n, 0.15, &mut rng);
    let env = MvcEnv::new(g.clone());
    let cand: Vec<bool> = (0..g.n).map(|v| env.is_candidate(v)).collect();
    let part = Partition::new(n, 1);
    let shards = shards_for_graph(part, &g, env.removed_mask(), env.solution_mask(), &cand);
    let cfg = EngineCfg::new(1, 2);

    forward(rt, &cfg, &params, &shards, false, false).unwrap(); // warm
    let a = forward(rt, &cfg, &params, &shards, false, false).unwrap();
    let b = forward(rt, &cfg, &params, &shards, false, true).unwrap();
    let diff = oggm::util::max_abs_diff(&a.scores, &b.scores);
    let mut t = Table::new("ablation: skip-zero-layer fwd", &["sim_s", "max_abs_diff"]);
    t.row("full", vec![a.timing.simulated(), 0.0]);
    t.row("skip-layer0-msg", vec![b.timing.simulated(), diff as f64]);
    common::emit(&t);
    assert!(diff < 1e-4);
}

fn ablate_replay_memory() {
    use oggm::coordinator::replay::{BitSet, ReplayBuffer, Tuple};
    let mut t = Table::new(
        "ablation: replay compression (bytes per 10k tuples)",
        &["compressed_MiB", "dense_MiB", "factor"],
    );
    for n in [252usize, 1488, 2496] {
        let mut rb = ReplayBuffer::new(10_000);
        for i in 0..10_000u32 {
            rb.push(Tuple {
                graph_id: i % 16,
                solution: BitSet::from_bools(&vec![false; n]),
                action: 0,
                target: 0.0,
            });
        }
        let c = rb.bytes() as f64 / (1024.0 * 1024.0);
        let d = rb.bytes_uncompressed(n) as f64 / (1024.0 * 1024.0);
        t.row(format!("N={n}"), vec![c, d, d / c]);
    }
    common::emit(&t);
}

fn bench_collectives() {
    let mut t = Table::new(
        "microbench: threaded Communicator (ms per op, 1 MiB payload)",
        &["all_reduce", "all_gather", "barrier"],
    );
    for p in [2usize, 4, 6] {
        let elems = 256 * 1024; // 1 MiB of f32
        let run = |op: &'static str| -> f64 {
            let comms = Communicator::create(p);
            let iters = common::scaled(20, 5);
            let handles: Vec<_> = comms
                .into_iter()
                .map(|c| {
                    std::thread::spawn(move || {
                        let mut buf = vec![1.0f32; elems];
                        let st = timer::Stopwatch::start();
                        for _ in 0..iters {
                            match op {
                                "all_reduce" => c.all_reduce_sum(&mut buf).unwrap(),
                                "all_gather" => {
                                    let _ = c.all_gather(&buf[..elems / c.p()]).unwrap();
                                }
                                _ => c.barrier().unwrap(),
                            }
                        }
                        st.elapsed_s() / iters as f64
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).fold(0.0, f64::max)
        };
        t.row(
            format!("P={p}"),
            vec![run("all_reduce") * 1e3, run("all_gather") * 1e3, run("barrier") * 1e3],
        );
    }
    common::emit(&t);
}

fn bench_solvers() {
    let mut rng = Pcg32::seeded(3);
    let mut t = Table::new(
        "baseline solvers on ER(n, 0.15): cover sizes + exact runtime",
        &["exact", "greedy", "approx2", "exact_s", "optimal"],
    );
    for n in [20usize, 60, 120] {
        let g = generators::erdos_renyi(n, 0.15, &mut rng);
        let st = timer::Stopwatch::start();
        let ex = oggm::solvers::exact_mvc(&g, Duration::from_secs(20));
        let exact_s = st.elapsed_s();
        let gr = oggm::solvers::greedy_mvc(&g).iter().filter(|&&b| b).count();
        let ap = oggm::solvers::two_approx_mvc(&g).iter().filter(|&&b| b).count();
        t.row(
            format!("n={n}"),
            vec![ex.size as f64, gr as f64, ap as f64, exact_s, ex.optimal as u8 as f64],
        );
    }
    common::emit(&t);
}

fn ablate_fixed_d(rt: &oggm::runtime::Runtime) {
    let mut rng = Pcg32::seeded(4);
    let params = common::quick_trained_params(rt, common::scaled(10, 3), 4);
    let n = 252;
    let g = generators::erdos_renyi(n, 0.15, &mut rng);
    let exact = oggm::solvers::exact_mvc(&g, Duration::from_secs(5)).size;
    let mut t = Table::new(
        "ablation: fixed-d selection sweep (ER 252)",
        &["cover", "ratio_vs_exact", "evals", "total_sim_s"],
    );
    let policies: Vec<(String, SelectionPolicy)> = vec![
        ("d=1".into(), SelectionPolicy::Single),
        ("d=2".into(), SelectionPolicy::FixedMulti(2)),
        ("d=4".into(), SelectionPolicy::FixedMulti(4)),
        ("d=8".into(), SelectionPolicy::FixedMulti(8)),
        ("d=16".into(), SelectionPolicy::FixedMulti(16)),
        ("adaptive".into(), SelectionPolicy::AdaptiveMulti),
    ];
    for (label, policy) in policies {
        let mut cfg = InferCfg::new(1, 2);
        cfg.policy = policy;
        let res = solve_mvc(rt, &cfg, &params, &g, n).unwrap();
        t.row(
            label,
            vec![
                res.solution_size as f64,
                res.solution_size as f64 / exact as f64,
                res.evaluations as f64,
                res.sim_time_per_eval * res.evaluations as f64,
            ],
        );
    }
    common::emit(&t);
}

fn main() {
    let rt = common::runtime();
    ablate_skip_zero_layer(&rt);
    ablate_replay_memory();
    bench_collectives();
    bench_solvers();
    ablate_fixed_d(&rt);
    println!("ablation: OK");
}
