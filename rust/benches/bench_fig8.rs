//! Fig. 8: effect of the number of gradient-descent iterations τ (§4.5.2).
//! Trains with τ ∈ {1,2,4,8,16} and reports the steps needed to first reach
//! a target mean approximation ratio, plus curve oscillation (std of the
//! ratio over the last third). Paper shape: τ=2..8 converge in fewer steps
//! than τ=1; τ=16 oscillates.
//!
//! Paper used 250-node graphs; default here is 20-node training with
//! 20-node tests (OGGM_FIG8_N=250 for the paper's size).

#[path = "common.rs"]
mod common;

use oggm::coordinator::infer::{solve_mvc, InferCfg};
use oggm::coordinator::metrics::{approx_ratio, Table};
use oggm::coordinator::train::{TrainCfg, Trainer};
use oggm::graph::{generators, Graph, Partition};
use oggm::util::rng::Pcg32;
use std::time::Duration;

fn main() {
    let rt = common::runtime();
    let n: usize = std::env::var("OGGM_FIG8_N").ok().and_then(|v| v.parse().ok()).unwrap_or(20);
    let bucket = Partition::pad_to_bucket(n, 12);
    let steps: usize = std::env::var("OGGM_FIG8_STEPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or_else(|| common::scaled(240, 40));
    let eval_every = common::scaled(20, 20);
    let taus: Vec<usize> =
        if common::fast_mode() { vec![1, 8] } else { vec![1, 2, 4, 8, 16] };

    // Shared test set.
    let mut rng = Pcg32::seeded(0x88);
    let tests: Vec<(Graph, usize)> = (0..common::scaled(8, 3))
        .map(|_| {
            let g = generators::erdos_renyi(n, 0.15, &mut rng);
            let opt = oggm::solvers::exact_mvc(&g, Duration::from_secs(5)).size;
            (g, opt)
        })
        .collect();
    let eval = |params: &oggm::model::Params| -> f64 {
        let cfg = InferCfg::new(1, 2);
        tests
            .iter()
            .map(|(g, opt)| {
                approx_ratio(solve_mvc(&rt, &cfg, params, g, bucket).unwrap().solution_size, *opt)
            })
            .sum::<f64>()
            / tests.len() as f64
    };

    let mut t = Table::new(
        "Fig. 8: gradient-descent iterations tau",
        &["steps_to_best", "best_ratio", "final_ratio", "osc_std"],
    );
    for &tau in &taus {
        let mut rng = Pcg32::seeded(0x89);
        let train_graphs: Vec<Graph> =
            (0..12).map(|_| generators::erdos_renyi(n, 0.15, &mut rng)).collect();
        let mut cfg = TrainCfg::new(1, bucket);
        cfg.seed = 33;
        cfg.hyper.lr = 1e-3;
        cfg.hyper.grad_iters = tau;
        cfg.hyper.eps_decay_steps = steps / 2;
        let params0 = common::init_params(&mut rng);
        let mut trainer = Trainer::new(&rt, cfg, train_graphs, params0).unwrap();

        let mut curve: Vec<(usize, f64)> = vec![(0, eval(&trainer.params))];
        while trainer.global_step < steps {
            let mut marks = Vec::new();
            trainer
                .run_episodes(1, |rec| {
                    if rec.global_step % eval_every == 0 {
                        marks.push(rec.global_step);
                    }
                })
                .unwrap();
            for step in marks {
                curve.push((step, eval(&trainer.params)));
            }
        }
        let best = curve.iter().map(|&(_, r)| r).fold(f64::INFINITY, f64::min);
        let steps_to_best =
            curve.iter().find(|&&(_, r)| r <= best + 1e-9).map(|&(s, _)| s).unwrap_or(0);
        let final_r = curve.last().unwrap().1;
        let tail = &curve[curve.len() - curve.len() / 3..];
        let mean = tail.iter().map(|&(_, r)| r).sum::<f64>() / tail.len() as f64;
        let osc = (tail.iter().map(|&(_, r)| (r - mean) * (r - mean)).sum::<f64>()
            / tail.len() as f64)
            .sqrt();
        println!("tau={tau}: best {best:.4} at step {steps_to_best}, final {final_r:.4}, osc {osc:.4}");
        t.row(format!("tau={tau}"), vec![steps_to_best as f64, best, final_r, osc]);
    }
    common::emit(&t);
    println!("fig8: OK");
}
