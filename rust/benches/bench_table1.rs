//! Table 1: real-world graph statistics. The paper lists three Facebook
//! university networks (Vanderbilt/Georgetown/Mississippi); this repo uses
//! quarter-scale Holme–Kim stand-ins with matched edge probability
//! (DESIGN.md §3). The table prints the stand-ins' measured stats next to
//! the paper's reported values.

#[path = "common.rs"]
mod common;

use oggm::coordinator::metrics::Table;
use oggm::graph::{generators, stats};
use oggm::util::rng::Pcg32;

fn main() {
    let mut rng = Pcg32::seeded(20210661);
    let datasets = generators::social_standins(&mut rng);

    // Paper's Table 1 values: (|V|, |E|, rho).
    let paper = [
        ("Vanderbilt", 8100.0, 427_800.0, 0.0131),
        ("Georgetown", 9400.0, 425_600.0, 0.0096),
        ("Mississippi", 10500.0, 610_900.0, 0.0110),
    ];

    let mut t = Table::new(
        "Table 1: social-graph stand-ins (quarter-scale Holme-Kim) vs paper",
        &["V", "E", "rho", "paper_V", "paper_E", "paper_rho", "clustering"],
    );
    for ((name, g), (_, pv, pe, prho)) in datasets.iter().zip(paper.iter()) {
        let s = stats::dataset_stats(name, g);
        let cc = stats::clustering_coefficient(g, 400, &mut rng);
        t.row(
            name.to_string(),
            vec![s.nodes as f64, s.edges as f64, s.rho, *pv, *pe, *prho, cc],
        );
    }
    common::emit(&t);

    // Sanity: stand-in rho within 2x of the paper's (quarter scale keeps
    // rho comparable because both V and E scale together).
    for ((name, g), (_, _, _, prho)) in datasets.iter().zip(paper.iter()) {
        let rho = g.edge_probability();
        assert!(
            rho / prho < 5.0 && prho / rho < 5.0,
            "{name}: stand-in rho {rho} too far from paper {prho}"
        );
    }
    println!("table1: OK");
}
