//! Fig. 10: time per parallel RL inference step on real-world (social)
//! graphs, P ∈ {1,2,3,4,6}. Paper shape: ~4.1x speedup at 6 GPUs — lower
//! than the ER graphs of Fig. 9 because social graphs have far fewer edges.
//! Stand-ins are quarter-scale Holme–Kim graphs (Table 1 / DESIGN.md §3).

#[path = "common.rs"]
mod common;

use oggm::coordinator::engine::EngineCfg;
use oggm::coordinator::fwd::forward;
use oggm::coordinator::metrics::Table;
use oggm::coordinator::shard::shards_for_graph;
use oggm::env::{GraphEnv, MvcEnv};
use oggm::graph::{generators, Partition};
use oggm::util::rng::Pcg32;

fn main() {
    let rt = common::runtime();
    let mut rng = Pcg32::seeded(20210661);
    let params = common::init_params(&mut rng);
    let datasets = generators::social_standins(&mut rng);
    let datasets = if common::fast_mode() { &datasets[..1] } else { &datasets[..] };
    let p_list = [1usize, 2, 3, 4, 6];
    let reps = common::scaled(3, 1);

    let mut t = Table::new(
        "Fig. 10: time per RL inference step, social graphs (simulated-parallel seconds)",
        &["P=1", "P=2", "P=3", "P=4", "P=6", "speedup@6"],
    );
    for (name, g) in datasets {
        let env = MvcEnv::new(g.clone());
        let cand: Vec<bool> = (0..g.n).map(|v| env.is_candidate(v)).collect();
        let mut row = Vec::new();
        for &p in &p_list {
            let part = Partition::new(g.n, p);
            let shards =
                shards_for_graph(part, g, env.removed_mask(), env.solution_mask(), &cand);
            let cfg = EngineCfg::new(p, 2);
            forward(&rt, &cfg, &params, &shards, false, true).unwrap();
            let mut sim = 0.0;
            for _ in 0..reps {
                let out = forward(&rt, &cfg, &params, &shards, false, true).unwrap();
                sim += out.timing.simulated();
            }
            let sim = sim / reps as f64;
            println!("  {name} (|V|={}, |E|={}) P={p}: {sim:.4}s/step", g.n, g.m);
            row.push(sim);
        }
        let speedup = row[0] / row[4];
        row.push(speedup);
        println!("  {name}: speedup at P=6: {speedup:.2}x");
        t.row(name.to_string(), row);
    }
    common::emit(&t);
    println!("fig10: OK");
}
