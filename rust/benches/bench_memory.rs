//! bench_memory: per-shard resident adjacency bytes, dense (B×NI×N) vs
//! sparse CSR tiles (O(E/P + NI)), across the in-repo bucket ladder — the
//! DESIGN.md §7 memory-model observable. Emits BENCH_memory.json.
//!
//! Two modes compose:
//!  - **Host accounting (always runs, no artifacts needed):** builds the
//!    sparse shard state for generated graphs at each bucket and compares
//!    its measured bytes against the dense formula 4·B·NI·N (validated
//!    against a materialized dense shard at the smallest bucket — the big
//!    buckets use the formula so the bench itself never allocates the
//!    dense wall it is measuring).
//!  - **Measured solve (artifacts + sparse shapes present):** drives one
//!    dense and one sparse MVC solve and records each pack's `state_bytes`
//!    and the runtime's `ExecStats` byte counters, tying the table to
//!    measured transfers.
//!
//! Check mode: without artifacts the bench still emits the host-side table
//! and JSON, prints a notice for the skipped solve section, and exits 0.

#[path = "common.rs"]
mod common;

use oggm::batch::{solve_pack, BatchCfg};
use oggm::coordinator::metrics::{exec_stats_json, Table};
use oggm::coordinator::shard::{sparse_shards_for_graph, ShardState, Storage};
use oggm::env::Scenario;
use oggm::graph::{generators, Graph, Partition};
use oggm::util::json::Json;
use oggm::util::rng::Pcg32;

/// Fallback sparse tiling config mirroring python/compile/configs.py
/// (SPARSE_CHUNKS / SPARSE_EDGE_CAPS) for artifact-less host accounting.
const FALLBACK_CHUNK: usize = 48;
const FALLBACK_CAPS: [usize; 2] = [96, 768];

struct Row {
    bucket: usize,
    p: usize,
    nodes: usize,
    edges: usize,
    dense_bytes: usize,
    sparse_bytes: usize,
}

fn host_rows() -> Vec<Row> {
    // BA(d=4) stand-ins: the large-sparse-graph regime the CSR path is
    // for. The ladder ends at the largest in-repo bucket (sparse-only
    // 9996); in fast mode the tail is trimmed.
    let mut specs: Vec<(usize, usize)> = vec![(250, 252), (1488, 1488), (2496, 2496)];
    if !common::fast_mode() {
        specs.push((4992, 4992));
        specs.push((9996, 9996));
    }
    let mut rows = Vec::new();
    let mut rng = Pcg32::seeded(0x3E);
    for (n, bucket) in specs {
        let g = generators::barabasi_albert(n, 4, &mut rng);
        for p in [1usize, 4] {
            let part = Partition::new(bucket, p);
            let removed = vec![false; g.n];
            let sol = vec![false; g.n];
            let cand: Vec<bool> = (0..g.n).map(|v| g.degree(v) > 0).collect();
            let sparse = sparse_shards_for_graph(
                part, &g, &removed, &sol, &cand, FALLBACK_CHUNK, &FALLBACK_CAPS,
            );
            let sparse_bytes: usize = sparse.iter().map(|s| s.adjacency_bytes()).sum();
            // Dense bytes by formula (4·B·NI·N per shard, B = 1, P shards);
            // materializing the big buckets would allocate the very wall
            // the sparse path removes.
            let dense_bytes = 4 * part.ni() * part.n * p;
            rows.push(Row { bucket, p, nodes: g.n, edges: g.m, dense_bytes, sparse_bytes });
        }
    }
    rows
}

/// Validate the dense formula against one materialized shard set.
fn check_dense_formula() {
    let mut rng = Pcg32::seeded(0x3F);
    let g = generators::barabasi_albert(250, 4, &mut rng);
    let part = Partition::new(252, 4);
    let removed = vec![false; g.n];
    let sol = vec![false; g.n];
    let cand: Vec<bool> = (0..g.n).map(|v| g.degree(v) > 0).collect();
    let measured: usize = (0..part.p)
        .map(|i| {
            ShardState::from_graphs(part, i, &[&g], &[&removed], &[&sol], &[&cand])
                .adjacency_bytes()
        })
        .sum();
    assert_eq!(measured, 4 * part.ni() * part.n * part.p, "dense formula drifted");
}

fn main() {
    check_dense_formula();
    let rows = host_rows();

    let mut t = Table::new(
        "bench_memory: resident adjacency bytes per pack (B=1), dense vs sparse CSR",
        &["P", "E", "dense_B", "sparse_B", "reduction"],
    );
    let mut json_rows: Vec<Json> = Vec::new();
    let mut worst_large = f64::INFINITY;
    let mut largest = 0usize;
    for r in &rows {
        let red = r.dense_bytes as f64 / r.sparse_bytes.max(1) as f64;
        t.row(
            format!("N={}", r.bucket),
            vec![r.p as f64, r.edges as f64, r.dense_bytes as f64, r.sparse_bytes as f64, red],
        );
        if r.bucket > largest {
            largest = r.bucket;
            worst_large = red;
        } else if r.bucket == largest {
            worst_large = worst_large.min(red);
        }
        json_rows.push(
            Json::obj()
                .set("bucket", r.bucket)
                .set("p", r.p)
                .set("nodes", r.nodes)
                .set("edges", r.edges)
                .set("dense_adjacency_bytes", r.dense_bytes)
                .set("sparse_adjacency_bytes", r.sparse_bytes)
                .set("reduction", red),
        );
    }
    common::emit(&t);
    println!(
        "bench_memory: largest bucket N={largest} adjacency reduction {worst_large:.1}x{}",
        if worst_large >= 5.0 { "" } else { " — BELOW the 5x target" }
    );

    let mut json = Json::obj()
        .set("bench", "memory")
        .set("chunk", FALLBACK_CHUNK)
        .set("rows", json_rows)
        .set("largest_bucket_reduction", worst_large);

    // Measured-solve section (needs artifacts + sparse shapes).
    if !oggm::runtime::manifest::default_dir().join("manifest.tsv").exists() {
        println!("bench_memory: artifacts not built, skipping measured solves (check mode OK)");
    } else {
        let rt = common::runtime();
        if rt.manifest.sparse_config(1, 252, 32).is_err() {
            println!("bench_memory: sparse shapes not compiled, skipping measured solves");
        } else {
            let mut rng = Pcg32::seeded(0x40);
            let params = common::init_params(&mut rng);
            let g: Graph = generators::barabasi_albert(250, 4, &mut rng);
            let dense_cfg = BatchCfg::new(1, 2);
            let mut sparse_cfg = dense_cfg;
            sparse_cfg.storage = Storage::Sparse;
            let d =
                solve_pack(&rt, &dense_cfg, &params, Scenario::Mvc, vec![g.clone()], 252).unwrap();
            let s = solve_pack(&rt, &sparse_cfg, &params, Scenario::Mvc, vec![g], 252).unwrap();
            assert_eq!(d.per_graph[0].solution, s.per_graph[0].solution, "solve diverged");
            println!(
                "bench_memory: measured 250-node MVC — dense state {} B, sparse state {} B \
                 ({:.1}x); h2d dense {} B vs sparse {} B",
                d.state_bytes,
                s.state_bytes,
                d.state_bytes as f64 / s.state_bytes.max(1) as f64,
                d.exec.h2d_bytes,
                s.exec.h2d_bytes
            );
            json = json.set(
                "measured",
                Json::obj()
                    .set("n", 250usize)
                    .set("bucket", 252usize)
                    .set("dense_state_bytes", d.state_bytes)
                    .set("sparse_state_bytes", s.state_bytes)
                    .set("pack_edges", s.pack_edges)
                    .set("dense_exec", exec_stats_json(&d.exec))
                    .set("sparse_exec", exec_stats_json(&s.exec)),
            );
        }
    }

    std::fs::write("BENCH_memory.json", json.render()).expect("write BENCH_memory.json");
    println!("bench_memory: wrote BENCH_memory.json; OK");
}
