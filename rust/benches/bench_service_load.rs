//! bench_service_load: networked serve front door under open-loop load
//! (ISSUE 6).
//!
//! Drives `oggm serve --listen` over a real TCP socket with open-loop
//! Poisson arrivals (exponential inter-arrival sleeps, independent of
//! completions — a slow server builds queue, it does not slow the client)
//! and reports client-observed p50/p99 round-trip latency against the
//! offered jobs/sec, at P in {1, 2} under both execution engines. Every
//! run stays below the per-tenant quota, so it also asserts the
//! no-rejects-below-quota contract. Emits BENCH_service_load.json.
//!
//! Check mode: without artifacts (CI containers) the bench prints a skip
//! notice and exits 0, like the artifact-gated tests.

#[path = "common.rs"]
mod common;

use oggm::coordinator::engine::Engine;
use oggm::coordinator::metrics::Table;
use oggm::net::serve;
use oggm::runtime::manifest;
use oggm::service::Options;
use oggm::util::json::Json;
use oggm::util::rng::Pcg32;
use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::thread;
use std::time::{Duration, Instant};

/// Client-observed result of one load run (plus the server's
/// fault-recovery books, all zero on a clean run).
struct LoadRun {
    latencies_ms: Vec<f64>,
    rejects: usize,
    wall_secs: f64,
    /// Packs that needed a retry after a (scripted) fault.
    retried_packs: u64,
    /// Replacement ranks spawned by the pool supervisor.
    restarts: u64,
    /// Total recovery time (respawn + collective reset + θ republish).
    recovery_ms: f64,
}

/// Sorted-sample percentile (nearest-rank on the sorted slice).
fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx]
}

/// One open-loop run: `jobs` Poisson arrivals at `rate` jobs/sec through a
/// fresh single-connection server session.
fn run_load(opts: &Options, jobs: usize, rate: f64, seed: u64) -> LoadRun {
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind loopback");
    let addr = listener.local_addr().unwrap();
    let opts = opts.clone();
    let params = common::init_params(&mut Pcg32::seeded(0xD1));
    let server = thread::spawn(move || {
        serve(listener, manifest::default_dir(), params, &opts).expect("serve failed")
    });

    let stream = TcpStream::connect(addr).expect("connect");
    let reader = BufReader::new(stream.try_clone().expect("clone stream"));
    // The reader thread timestamps every response line as it arrives so
    // queueing delay on the socket is part of the measured latency.
    let collector = thread::spawn(move || {
        let mut seen: Vec<(String, Instant, bool)> = Vec::new();
        for line in reader.lines() {
            let line = match line {
                Ok(l) => l,
                Err(_) => break,
            };
            let at = Instant::now();
            let v = Json::parse(&line).expect("response is not JSON");
            let id = v.get("id").and_then(|j| j.as_str()).unwrap_or_default().to_string();
            let rejected = v.get("rejected").and_then(|j| j.as_bool()).unwrap_or(false);
            seen.push((id, at, rejected));
        }
        seen
    });

    let mut rng = Pcg32::seeded(seed);
    let mut sent: HashMap<String, Instant> = HashMap::new();
    let mut w = stream.try_clone().expect("clone stream");
    let t0 = Instant::now();
    for i in 0..jobs {
        // Exponential inter-arrival gap: -ln(U)/rate, U in (0, 1].
        let u = (1.0 - rng.next_f64()).max(1e-12);
        thread::sleep(Duration::from_secs_f64(-u.ln() / rate));
        let line = format!("gen er n=20 rho=0.2 seed={} id=l{i} mvc\n", 40 + i);
        sent.insert(format!("l{i}"), Instant::now());
        w.write_all(line.as_bytes()).expect("send job line");
    }
    // Half-close: EOF flushes the tenant's open packs and, with
    // --max-conns 1, shuts the server down once everything drains.
    stream.shutdown(Shutdown::Write).expect("half-close");
    let seen = collector.join().expect("reader thread");
    let summary = server.join().expect("server thread");
    let wall_secs = t0.elapsed().as_secs_f64();

    let mut latencies_ms = Vec::with_capacity(seen.len());
    let mut rejects = 0usize;
    for (id, at, rejected) in seen {
        if rejected {
            rejects += 1;
            continue;
        }
        let from = sent.get(&id).unwrap_or_else(|| panic!("unknown response id '{id}'"));
        latencies_ms.push(at.saturating_duration_since(*from).as_secs_f64() * 1e3);
    }
    assert_eq!(
        latencies_ms.len() + rejects,
        jobs,
        "response stream lost jobs (summary: {} jobs, {} failed)",
        summary.jobs,
        summary.failed
    );
    assert_eq!(summary.failed, 0, "jobs failed under load");
    // Open-loop in-flight is bounded by the job count, which every config
    // keeps below the quota — any reject is a backpressure bug.
    assert_eq!(rejects, 0, "rejected below quota ({rejects} rejects)");
    assert_eq!(summary.snapshot.rejected, 0);
    latencies_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let restarts: u64 = summary.packs.iter().map(|s| s.exec.restarts).sum();
    let recovery_ms: f64 =
        summary.packs.iter().map(|s| s.exec.recovery_time.as_secs_f64()).sum::<f64>() * 1e3;
    LoadRun {
        latencies_ms,
        rejects,
        wall_secs,
        retried_packs: summary.snapshot.retried_packs,
        restarts,
        recovery_ms,
    }
}

fn main() {
    if !manifest::default_dir().join("manifest.tsv").exists() {
        println!("bench_service_load: artifacts not built, skipping (check mode OK)");
        return;
    }
    let rt = common::runtime();
    let jobs = common::scaled(24, 8);
    let rates: Vec<f64> = if common::fast_mode() { vec![32.0] } else { vec![8.0, 32.0] };
    let p_list: Vec<usize> = if common::fast_mode() { vec![1] } else { vec![1, 2] };

    let mut table = Table::new(
        &format!("bench_service_load: {jobs} open-loop Poisson jobs over TCP"),
        &["offered_jps", "achieved_jps", "p50_ms", "p99_ms", "rejects"],
    );
    let mut rows = Vec::new();
    for &p in &p_list {
        if rt.manifest.batch_sizes(24, 24 / p).last().copied().unwrap_or(0) < 4 {
            println!("P={p}: no compiled batch shapes at N=24, skipping");
            continue;
        }
        for engine in [Engine::Lockstep, Engine::RankParallel] {
            for &rate in &rates {
                // Quota far above the job count (the no-reject contract);
                // a short max-wait bounds partial-pack tail latency.
                let opts = Options::new()
                    .p(p)
                    .engine(engine)
                    .max_wait(0.05)
                    .quota(jobs * 4)
                    .max_conns(1);
                let run = run_load(&opts, jobs, rate, 0xE0 ^ (p as u64) ^ rate as u64);
                let achieved = jobs as f64 / run.wall_secs;
                let p50 = percentile(&run.latencies_ms, 0.50);
                let p99 = percentile(&run.latencies_ms, 0.99);
                println!(
                    "P={p} {:>13}: offered {rate:>5.1} j/s, achieved {achieved:>6.2} j/s, \
                     p50 {p50:>8.2} ms, p99 {p99:>8.2} ms, rejects {}",
                    engine.name(),
                    run.rejects
                );
                table.row(
                    format!("P={p} {} @{rate}", engine.name()),
                    vec![rate, achieved, p50, p99, run.rejects as f64],
                );
                rows.push(
                    Json::obj()
                        .set("p", p)
                        .set("engine", engine.name())
                        .set("offered_jobs_per_sec", rate)
                        .set("achieved_jobs_per_sec", achieved)
                        .set("jobs", jobs)
                        .set("p50_ms", p50)
                        .set("p99_ms", p99)
                        .set("rejects", run.rejects),
                );
            }
        }
    }
    // Faulted variant (ISSUE 7): the same open-loop load through the
    // rank-parallel engine with ONE scripted rank panic mid-run — the pool
    // replaces the rank, the hit pack retries, no job is lost. Reported
    // against a clean baseline at the same rate so the p99 impact and the
    // recovery cost are visible side by side in BENCH_service_load.json.
    let p = 2usize;
    if rt.manifest.batch_sizes(24, 24 / p).last().copied().unwrap_or(0) >= 4 {
        let rate = rates.last().copied().unwrap_or(32.0);
        let base = Options::new()
            .p(p)
            .engine(Engine::RankParallel)
            .max_wait(0.05)
            .quota(jobs * 4)
            .max_conns(1);
        let clean = run_load(&base, jobs, rate, 0xF1);
        let faulted_opts = base
            .retries(2)
            .max_rank_restarts(2)
            .fault_plan("rank=1,step=2,kind=panic");
        let faulted = run_load(&faulted_opts, jobs, rate, 0xF1);
        assert!(faulted.restarts >= 1, "the scripted rank panic spawned no replacement");
        assert!(faulted.retried_packs >= 1, "no pack retried after the scripted fault");
        let p99_clean = percentile(&clean.latencies_ms, 0.99);
        let p99_faulted = percentile(&faulted.latencies_ms, 0.99);
        println!(
            "P={p} rank-par FAULTED: p99 {p99_faulted:>8.2} ms (clean {p99_clean:>8.2} ms), \
             {} restarts, recovery {:.2} ms, {} retried packs",
            faulted.restarts, faulted.recovery_ms, faulted.retried_packs
        );
        table.row(
            format!("P={p} rank-par faulted @{rate}"),
            vec![
                rate,
                jobs as f64 / faulted.wall_secs,
                percentile(&faulted.latencies_ms, 0.50),
                p99_faulted,
                faulted.rejects as f64,
            ],
        );
        rows.push(
            Json::obj()
                .set("p", p)
                .set("engine", "rank-parallel")
                .set("fault", "rank=1,step=2,kind=panic")
                .set("offered_jobs_per_sec", rate)
                .set("jobs", jobs)
                .set("p50_ms", percentile(&faulted.latencies_ms, 0.50))
                .set("p99_ms", p99_faulted)
                .set("p99_clean_ms", p99_clean)
                .set("restarts", faulted.restarts)
                .set("recovery_ms", faulted.recovery_ms)
                .set("retried_packs", faulted.retried_packs),
        );
    } else {
        println!("P={p}: no compiled batch shapes at N=24, skipping the faulted variant");
    }
    common::emit(&table);
    let json = Json::obj().set("bench", "service_load").set("rows", Json::Arr(rows));
    std::fs::write("BENCH_service_load.json", json.render())
        .expect("write BENCH_service_load.json");
    println!("bench_service_load: wrote BENCH_service_load.json; OK");
}
