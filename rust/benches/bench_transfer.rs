//! bench_transfer: per-step h2d/d2h transfer accounting, device-resident
//! (cached) vs fresh-upload (uncached) paths, on a 200-node MVC solve.
//!
//! The device-residency claim (DESIGN.md §6): after step 1 pays for the
//! θ/A uploads, each further step moves only the selection deltas (two
//! small masks) plus S/C — so steady-state h2d bytes/step drop >= 10x vs
//! the fresh-upload path, which re-uploads the full B×NI×N adjacency and
//! all seven θ tensors every evaluation. Emits BENCH_transfer.json.
//!
//! Check mode: without artifacts (CI containers) the bench prints a skip
//! notice and exits 0, like the artifact-gated tests.

#[path = "common.rs"]
mod common;

use oggm::coordinator::engine::EngineCfg;
use oggm::coordinator::fwd::{forward_dev, DeviceState};
use oggm::coordinator::infer::{solve_mvc, InferCfg};
use oggm::coordinator::metrics::{exec_stats_json, Table};
use oggm::coordinator::shard::{mirror_selection, shards_for_graph, ShardState};
use oggm::env::{GraphEnv, Scenario};
use oggm::graph::Partition;
use oggm::model::Params;
use oggm::runtime::Runtime;
use oggm::util::json::Json;
use oggm::util::rng::Pcg32;
use std::time::Instant;

/// Drive the cached solve manually so per-step byte deltas are observable;
/// returns (per-step h2d bytes, per-step d2h bytes, per-step wall seconds).
fn cached_steps(
    rt: &Runtime,
    params: &Params,
    g: &oggm::graph::Graph,
    bucket: usize,
    max_steps: usize,
) -> (Vec<u64>, Vec<u64>, Vec<f64>) {
    let part = Partition::new(bucket, 1);
    let cfg = EngineCfg::new(1, 2);
    let mut env = Scenario::Mvc.make_env(g.clone());
    let candidates: Vec<bool> = (0..g.n).map(|v| env.is_candidate(v)).collect();
    let mut shards: Vec<ShardState> =
        shards_for_graph(part, g, env.removed_mask(), env.solution_mask(), &candidates);
    let mut removed_prev: Vec<bool> = env.removed_mask().to_vec();
    let (mut h2d, mut d2h, mut wall) = (Vec::new(), Vec::new(), Vec::new());
    let mut snap = rt.stats();
    let mut dev = DeviceState::new(rt, params, &mut shards).unwrap();
    while !env.done() && h2d.len() < max_steps {
        // Time the FULL step (sync + forward + selection + state mirror),
        // so the wall column is like-for-like with the uncached path's
        // whole-solve-per-evaluation number.
        let t0 = Instant::now();
        dev.sync(&mut shards).unwrap();
        let out = forward_dev(rt, &cfg, params, &shards, false, true, Some(&dev)).unwrap();
        let delta = rt.stats().since(&snap);
        h2d.push(delta.h2d_bytes);
        d2h.push(delta.d2h_bytes);
        snap = rt.stats();
        let v = (0..g.n)
            .filter(|&v| env.is_candidate(v))
            .max_by(|&a, &b| out.scores[a].partial_cmp(&out.scores[b]).unwrap())
            .unwrap();
        env.step(v);
        mirror_selection(&mut shards, 0, v, &*env, &mut removed_prev);
        for sh in shards.iter_mut() {
            sh.refresh_candidates(0, |v| env.is_candidate(v));
        }
        wall.push(t0.elapsed().as_secs_f64());
    }
    (h2d, d2h, wall)
}

fn mean_u64(v: &[u64]) -> f64 {
    if v.is_empty() {
        0.0
    } else {
        v.iter().sum::<u64>() as f64 / v.len() as f64
    }
}

fn main() {
    if !oggm::runtime::manifest::default_dir().join("manifest.tsv").exists() {
        println!("bench_transfer: artifacts not built, skipping (check mode OK)");
        return;
    }
    let rt = common::runtime();
    let mut rng = Pcg32::seeded(0x7F);
    let params = common::init_params(&mut rng);
    let n = 200usize;
    let bucket = match rt.manifest.bucket_for(n, 1, 1) {
        Ok(b) => b,
        Err(e) => {
            println!("bench_transfer: {e:#}, skipping");
            return;
        }
    };
    let g = oggm::graph::generators::erdos_renyi(n, 0.15, &mut rng);
    let steps = common::scaled(20, 6);

    // Warm compiles off the clock for both paths.
    let mut warm_cfg = InferCfg::new(1, 2);
    solve_mvc(&rt, &warm_cfg, &params, &g, bucket).unwrap();
    warm_cfg.device_resident = false;
    solve_mvc(&rt, &warm_cfg, &params, &g, bucket).unwrap();

    // Uncached: whole solve, averaged per evaluation.
    let mut fresh_cfg = InferCfg::new(1, 2);
    fresh_cfg.device_resident = false;
    let before = rt.stats();
    let t0 = Instant::now();
    let res = solve_mvc(&rt, &fresh_cfg, &params, &g, bucket).unwrap();
    let fresh_wall = t0.elapsed().as_secs_f64();
    let fresh = rt.stats().since(&before);
    let evals = res.evaluations as f64;
    let (f_h2d, f_d2h, f_wall) =
        (fresh.h2d_bytes as f64 / evals, fresh.d2h_bytes as f64 / evals, fresh_wall / evals);

    // Cached: per-step series; steady state = steps 2+.
    let (h2d, d2h, wall) = cached_steps(&rt, &params, &g, bucket, steps);
    assert!(h2d.len() >= 3, "solve ended before steady state: {h2d:?}");
    let (c_h2d_1, c_h2d) = (h2d[0] as f64, mean_u64(&h2d[1..]));
    let c_d2h = mean_u64(&d2h[1..]);
    let c_wall = wall[1..].iter().sum::<f64>() / (wall.len() - 1) as f64;
    let reduction = f_h2d / c_h2d.max(1.0);

    let mut t = Table::new(
        &format!("bench_transfer: {n}-node MVC (bucket {bucket}, P=1), per step"),
        &["h2d_B", "d2h_B", "wall_s"],
    );
    t.row("uncached", vec![f_h2d, f_d2h, f_wall]);
    t.row("cached_step1", vec![c_h2d_1, d2h[0] as f64, wall[0]]);
    t.row("cached_steady", vec![c_h2d, c_d2h, c_wall]);
    common::emit(&t);
    println!(
        "bench_transfer: steady-state h2d {c_h2d:.0} B/step vs uncached {f_h2d:.0} B/step \
         ({reduction:.1}x reduction{})",
        if reduction >= 10.0 { "" } else { " — BELOW the 10x target" }
    );

    let json = Json::obj()
        .set("bench", "transfer")
        .set("n", n)
        .set("bucket", bucket)
        .set("p", 1usize)
        .set("evaluations", res.evaluations)
        .set(
            "uncached",
            Json::obj()
                .set("h2d_bytes_per_step", f_h2d)
                .set("d2h_bytes_per_step", f_d2h)
                .set("wall_per_step", f_wall),
        )
        .set(
            "cached",
            Json::obj()
                .set("step1_h2d_bytes", c_h2d_1)
                .set("steady_h2d_bytes_per_step", c_h2d)
                .set("steady_d2h_bytes_per_step", c_d2h)
                .set("steady_wall_per_step", c_wall),
        )
        .set("h2d_reduction", reduction)
        .set("solve_exec_stats", exec_stats_json(&fresh));
    std::fs::write("BENCH_transfer.json", json.render()).expect("write BENCH_transfer.json");
    println!("bench_transfer: wrote BENCH_transfer.json; OK");
}
