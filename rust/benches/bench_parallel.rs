//! bench_parallel: lockstep vs rank-parallel solve-step wall time (ISSUE 5).
//!
//! Solves the same pack (B=4 graphs, dense and sparse) under both engines
//! at P∈{1,2,4} and reports the wall-clock seconds per shared solve step,
//! plus the per-rank compute/transfer/collective breakdown — the
//! reproduction of the paper's spatial-parallelism wall-clock scaling on
//! the production hot path. The rank engine runs on a warm pool (second
//! pack of the session), so θ uploads and thread spawns are off the
//! measured path. Emits BENCH_parallel.json.
//!
//! Caveat (EXPERIMENTS.md §Perf): on a single host the PJRT CPU ranks
//! share cores, so speedups reflect host parallelism, not P devices.
//!
//! Check mode: without artifacts (CI containers) the bench prints a skip
//! notice and exits 0, like the artifact-gated tests.

#[path = "common.rs"]
mod common;

use oggm::batch::{solve_pack_session, BatchCfg, BatchResult, SessionState};
use oggm::coordinator::engine::Engine;
use oggm::coordinator::metrics::Table;
use oggm::coordinator::shard::Storage;
use oggm::env::Scenario;
use oggm::graph::{generators, Graph};
use oggm::model::Params;
use oggm::parallel::RankPool;
use oggm::runtime::Runtime;
use oggm::util::json::Json;
use oggm::util::rng::Pcg32;

fn pack_graphs(count: usize, seed: u64) -> Vec<Graph> {
    let mut rng = Pcg32::seeded(seed);
    (0..count).map(|_| generators::erdos_renyi(20, 0.25, &mut rng)).collect()
}

/// One pack solve (cold run warms compiles/θ; the second, warm call with
/// the same arguments is the measurement).
fn solve_once(
    rt: &Runtime,
    cfg: &BatchCfg,
    params: &Params,
    session: SessionState<'_>,
    seed: u64,
) -> BatchResult {
    solve_pack_session(rt, cfg, params, Scenario::Mvc, pack_graphs(4, seed), 24, session)
        .expect("pack solve failed")
}

fn main() {
    if !oggm::runtime::manifest::default_dir().join("manifest.tsv").exists() {
        println!("bench_parallel: artifacts not built, skipping (check mode OK)");
        return;
    }
    let rt = common::runtime();
    let mut rng = Pcg32::seeded(0xD1);
    let params = common::init_params(&mut rng);
    let p_list: Vec<usize> = if common::fast_mode() { vec![1, 2] } else { vec![1, 2, 4] };

    let mut table = Table::new(
        "bench_parallel: ms per shared solve step, B=4 pack of |V|=20 MVC",
        &["lockstep_ms", "ranks_ms", "speedup", "ranks_comm_ms", "ranks_h2d_ms"],
    );
    let mut rows = Vec::new();
    for storage in [Storage::Dense, Storage::Sparse] {
        for &p in &p_list {
            if rt.manifest.batch_sizes(24, 24 / p).last().copied().unwrap_or(0) < 4 {
                println!("{storage:?} P={p}: no compiled batch shapes at N=24, skipping");
                continue;
            }
            if storage == Storage::Sparse && rt.manifest.sparse_config(4, 24 / p, 32).is_err() {
                println!("sparse P={p}: sparse artifacts not compiled, skipping");
                continue;
            }
            let mut cfg = BatchCfg::new(p, 2);
            cfg.storage = storage;
            let seed = 0xD2 + p as u64;

            // Lockstep reference (one-thread simulation engine): cold solve
            // warms the compile caches, the second solve is measured.
            let _ = solve_once(&rt, &cfg, &params, SessionState::default(), seed);
            let lockstep = solve_once(&rt, &cfg, &params, SessionState::default(), seed);
            let ls_step = lockstep.wall_total / lockstep.rounds.max(1) as f64;

            // Rank-parallel on a warm pool; per-rank h2d bytes snapshot
            // between the cold and warm solves, so the published figure is
            // the WARM solve's transfer volume only.
            let pool = match RankPool::new("artifacts", p) {
                Ok(pool) => pool,
                Err(e) => {
                    println!("P={p}: rank pool unavailable ({e:#}), skipping");
                    continue;
                }
            };
            cfg.engine.mode = Engine::RankParallel;
            let session = SessionState { theta: None, pool: Some(&pool) };
            let _ = solve_once(&rt, &cfg, &params, session, seed);
            let stats0 = pool.rank_stats().expect("rank stats");
            let ranks = solve_once(&rt, &cfg, &params, session, seed);
            let stats1 = pool.rank_stats().expect("rank stats");
            let rk_step = ranks.wall_total / ranks.rounds.max(1) as f64;

            // Parity guard: the bench only means something if both engines
            // solved the pack identically.
            for (a, b) in lockstep.per_graph.iter().zip(&ranks.per_graph) {
                assert_eq!(a.solution, b.solution, "engines diverged; bench invalid");
            }

            let per_rank_h2d: Vec<f64> = stats1
                .iter()
                .zip(&stats0)
                .map(|(s1, s0)| s1.since(s0).h2d_bytes as f64)
                .collect();
            let rounds = ranks.rounds.max(1) as f64;
            println!(
                "{storage:?} P={p}: lockstep {:.2} ms/step, rank-parallel {:.2} ms/step \
                 ({:.2}x), comm {:.2} ms/step, h2d {:.2} ms/step over {} rounds",
                ls_step * 1e3,
                rk_step * 1e3,
                ls_step / rk_step,
                ranks.timing.comm / rounds * 1e3,
                ranks.timing.h2d / rounds * 1e3,
                ranks.rounds
            );
            table.row(
                format!("{storage:?} P={p}"),
                vec![
                    ls_step * 1e3,
                    rk_step * 1e3,
                    ls_step / rk_step,
                    ranks.timing.comm / rounds * 1e3,
                    ranks.timing.h2d / rounds * 1e3,
                ],
            );
            // All *_s fields are per solve step (divided by rounds), so the
            // JSON compares directly against lockstep_step_s like the table.
            let compute_per_step: Vec<f64> =
                ranks.timing.compute.iter().map(|c| c / rounds).collect();
            rows.push(
                Json::obj()
                    .set("storage", format!("{storage:?}").to_lowercase())
                    .set("p", p)
                    .set("rounds", ranks.rounds)
                    .set("lockstep_step_s", ls_step)
                    .set("rank_parallel_step_s", rk_step)
                    .set("speedup", ls_step / rk_step)
                    .set("rank_compute_step_s", compute_per_step)
                    .set("rank_comm_step_s", ranks.timing.comm / rounds)
                    .set("rank_h2d_step_s", ranks.timing.h2d / rounds)
                    .set("rank_h2d_bytes", per_rank_h2d)
                    .set("comm_bytes", ranks.timing.comm_bytes)
                    .set("collectives", ranks.timing.collectives),
            );
        }
    }
    common::emit(&table);
    let json = Json::obj().set("bench", "parallel").set("rows", Json::Arr(rows));
    std::fs::write("BENCH_parallel.json", json.render()).expect("write BENCH_parallel.json");
    println!("bench_parallel: wrote BENCH_parallel.json; OK");
}
