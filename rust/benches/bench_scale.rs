//! bench_scale: the paper-scale graph pipeline end to end — RMAT
//! generation, streaming SNAP/MatrixMarket I/O round-trips, shard-by-shard
//! streaming partitioning with DESIGN.md §7 resident-byte accounting, and
//! classical solution quality at scale.
//!
//! Full mode builds a ~30M-edge RMAT graph (the paper's §6 large-instance
//! regime). Fast/check mode (`OGGM_FAST=1` or `--check`) builds a
//! ~1M-edge smoke whose MatrixMarket file (`scale_smoke.mtx`) is kept in
//! the working directory as the CI eval-smoke input. The streaming-memory
//! assertions run in both modes: partition views must stay O(E/P + NI)
//! resident — never the dense 4·B·NI·N wall. Emits BENCH_scale.json
//! (field reference in README.md).
//!
//! The engine section (dense-vs-sparse storage × lockstep-vs-rank-parallel
//! execution on small packed graphs) needs compiled artifacts; without
//! them it prints a notice and the bench still exits 0 (check mode OK).

#[path = "common.rs"]
mod common;

use oggm::batch::{run_queue, BatchCfg, Job};
use oggm::coordinator::engine::Engine;
use oggm::coordinator::shard::Storage;
use oggm::env::Scenario;
use oggm::graph::{generators, io as gio, Graph, Partition};
use oggm::solvers::{self, verify};
use oggm::util::json::Json;
use oggm::util::rng::Pcg32;
use std::path::PathBuf;
use std::time::Instant;

/// CSR resident bytes of the loaded graph: row_ptr + col_idx.
fn csr_bytes(g: &Graph) -> usize {
    (g.n + 1) * std::mem::size_of::<usize>() + 2 * g.m * std::mem::size_of::<u32>()
}

fn main() {
    let fast = common::fast_mode() || std::env::args().any(|a| a == "--check");
    // Fast: 2^17 nodes, ~1M target edges. Full: 2^21 nodes, ~34M.
    let (scale, ef) = if fast { (17u32, 8usize) } else { (21u32, 16usize) };
    let mut rng = Pcg32::seeded(0x5CA1E);
    let t = Instant::now();
    let g = generators::rmat(scale, ef, &mut rng);
    let gen_s = t.elapsed().as_secs_f64();
    println!(
        "bench_scale[{}]: rmat(scale={scale}, ef={ef}) -> |V|={} |E|={} in {gen_s:.2}s \
         ({} B resident CSR)",
        if fast { "fast" } else { "full" },
        g.n,
        g.m,
        csr_bytes(&g)
    );

    let mut json = Json::obj()
        .set("bench", "scale")
        .set("mode", if fast { "fast" } else { "full" })
        .set("scale", scale as usize)
        .set("edge_factor", ef)
        .set("nodes", g.n)
        .set("edges", g.m)
        .set("gen_s", gen_s)
        .set("csr_bytes", csr_bytes(&g));

    // --- Streaming I/O round-trips (SNAP edge list + MatrixMarket). ---
    // The fast-mode .mtx stays in the working directory: CI's eval smoke
    // reads it back through `oggm eval --graph scale_smoke.mtx`.
    let mtx_path = if fast {
        PathBuf::from("scale_smoke.mtx")
    } else {
        std::env::temp_dir().join("oggm_scale.mtx")
    };
    let t = Instant::now();
    gio::write_mtx(&mtx_path, &g).expect("write mtx");
    let mtx_write_s = t.elapsed().as_secs_f64();
    let t = Instant::now();
    let g2 = gio::read_mtx(&mtx_path).expect("read mtx");
    let mtx_read_s = t.elapsed().as_secs_f64();
    assert_eq!(g2, g, "MatrixMarket round-trip must be exact");
    if !fast {
        let _ = std::fs::remove_file(&mtx_path);
    }

    let el_path = std::env::temp_dir().join("oggm_scale.edges");
    let t = Instant::now();
    gio::write_edge_list(&el_path, &g).expect("write edge list");
    let el_write_s = t.elapsed().as_secs_f64();
    let t = Instant::now();
    let g3 = gio::read_edge_list(&el_path).expect("read edge list");
    let el_read_s = t.elapsed().as_secs_f64();
    // Edge lists carry no isolated nodes and renumber by first appearance:
    // the edge count survives exactly, the node count only shrinks.
    assert_eq!(g3.m, g.m, "edge-list round-trip lost edges");
    assert!(g3.n <= g.n);
    let _ = std::fs::remove_file(&el_path);
    println!(
        "bench_scale: io mtx w {mtx_write_s:.2}s r {mtx_read_s:.2}s | \
         edges w {el_write_s:.2}s r {el_read_s:.2}s"
    );
    json = json.set(
        "io",
        Json::obj()
            .set("mtx_path", mtx_path.to_string_lossy().as_ref())
            .set("mtx_write_s", mtx_write_s)
            .set("mtx_read_s", mtx_read_s)
            .set("edges_write_s", el_write_s)
            .set("edges_read_s", el_read_s),
    );

    // --- Streaming partitioning: resident bytes per DESIGN.md §7. ---
    // Shard views must account to exactly the CSR the loader built (plus
    // one row_ptr sentinel per shard) — partitioning a 30M-edge graph
    // never materializes a dense NI×N wall or a per-shard edge copy.
    let mut part_rows: Vec<Json> = Vec::new();
    for p in [1usize, 2, 4] {
        let part = Partition::new(g.n, p);
        let t = Instant::now();
        let (mut rows, mut entries, mut resident) = (0usize, 0usize, 0usize);
        for sv in part.shard_views(&g) {
            rows += sv.rows;
            entries += sv.entries();
            resident += sv.resident_bytes();
        }
        let stream_s = t.elapsed().as_secs_f64();
        assert_eq!(rows, g.n);
        assert_eq!(entries, 2 * g.m);
        assert_eq!(
            resident,
            csr_bytes(&g) + (p - 1) * std::mem::size_of::<usize>(),
            "shard views must stay O(E/P + NI) resident"
        );
        let dense = 4 * part.ni() * part.n * p;
        let reduction = dense as f64 / resident as f64;
        assert!(
            reduction > 100.0,
            "streaming partition should beat dense storage by >100x at scale \
             (got {reduction:.1}x)"
        );
        println!(
            "bench_scale: P={p} streamed {entries} entries in {stream_s:.3}s, \
             resident {resident} B vs dense {dense} B ({reduction:.0}x)"
        );
        part_rows.push(
            Json::obj()
                .set("p", p)
                .set("resident_bytes", resident)
                .set("dense_bytes", dense)
                .set("reduction", reduction)
                .set("stream_s", stream_s),
        );
    }
    json = json.set("partition", Json::Arr(part_rows));

    // --- Classical solution quality at scale (exact is out of reach; the
    // maximal-matching half of the 2-approx is a true lower bound). ---
    let t = Instant::now();
    let greedy = solvers::greedy_mvc(&g);
    let greedy_s = t.elapsed().as_secs_f64();
    assert!(verify::is_vertex_cover(&g, &greedy), "greedy cover infeasible");
    let greedy_size = greedy.iter().filter(|&&b| b).count();

    let t = Instant::now();
    let approx = solvers::two_approx_mvc(&g);
    let approx_s = t.elapsed().as_secs_f64();
    assert!(verify::is_vertex_cover(&g, &approx), "2-approx cover infeasible");
    let approx_size = approx.iter().filter(|&&b| b).count();

    let t = Instant::now();
    let mis = solvers::greedy_mis(&g);
    let mis_s = t.elapsed().as_secs_f64();
    assert!(verify::is_independent_set(&g, &mis), "greedy MIS not independent");
    let mis_size = mis.iter().filter(|&&b| b).count();

    // |matching| = |2-approx|/2 ≤ OPT, so this bounds greedy's true ratio.
    let lb = (approx_size / 2).max(1);
    let greedy_ratio_ub = greedy_size as f64 / lb as f64;
    assert!(
        greedy_ratio_ub <= 3.0,
        "greedy MVC ratio bound {greedy_ratio_ub:.2} blew past 3.0"
    );
    println!(
        "bench_scale: greedy MVC {greedy_size} ({greedy_s:.2}s, ratio <= {greedy_ratio_ub:.2}), \
         2-approx {approx_size} ({approx_s:.2}s), greedy MIS {mis_size} ({mis_s:.2}s)"
    );
    json = json.set(
        "quality",
        Json::obj()
            .set("greedy_mvc", greedy_size)
            .set("greedy_mvc_s", greedy_s)
            .set("approx2_mvc", approx_size)
            .set("approx2_mvc_s", approx_s)
            .set("greedy_mis", mis_size)
            .set("greedy_mis_s", mis_s)
            .set("matching_lower_bound", lb)
            .set("greedy_ratio_upper_bound", greedy_ratio_ub),
    );

    // --- Engine matrix on packed small graphs (artifact-gated): the same
    // solutions must come out of dense/sparse storage under both engines.
    let mut engine_rows: Vec<Json> = Vec::new();
    if !oggm::runtime::manifest::default_dir().join("manifest.tsv").exists() {
        println!("bench_scale: artifacts not built, skipping engine matrix (check mode OK)");
    } else {
        let rt = common::runtime();
        let mut prng = Pcg32::seeded(0x5CA2E);
        let params = common::init_params(&mut prng);
        let jobs: Vec<Job> = (0..4)
            .map(|i| Job {
                id: format!("scale{i}"),
                scenario: Scenario::Mvc,
                graph: generators::erdos_renyi(20, 0.2, &mut prng),
            })
            .collect();
        let mut reference: Option<Vec<Vec<usize>>> = None;
        for (mode, storage) in [
            (Engine::Lockstep, Storage::Dense),
            (Engine::Lockstep, Storage::Sparse),
            (Engine::RankParallel, Storage::Dense),
            (Engine::RankParallel, Storage::Sparse),
        ] {
            let label = format!("{}/{:?}", mode.name(), storage);
            let mut cfg = BatchCfg::new(1, 2);
            cfg.engine.mode = mode;
            cfg.storage = storage;
            let t = Instant::now();
            let report = match run_queue(&rt, &cfg, &params, &jobs) {
                Ok(r) => r,
                Err(e) => {
                    println!("bench_scale: engine {label} skipped: {e:#}");
                    continue;
                }
            };
            let wall_s = t.elapsed().as_secs_f64();
            let sols: Vec<Vec<usize>> =
                report.outcomes.iter().map(|o| o.solution.clone()).collect();
            for o in &report.outcomes {
                assert!(o.valid, "engine {label}: job {} invalid", o.id);
            }
            match &reference {
                None => reference = Some(sols),
                Some(r) => assert_eq!(r, &sols, "engine {label} diverged"),
            }
            let rounds: usize = report.packs.iter().map(|p| p.rounds).sum();
            let per_step_ms =
                if rounds > 0 { report.wall_total * 1000.0 / rounds as f64 } else { 0.0 };
            println!(
                "bench_scale: engine {label}: {} jobs, wall {wall_s:.2}s, \
                 per-step {per_step_ms:.2}ms",
                report.outcomes.len()
            );
            engine_rows.push(
                Json::obj()
                    .set("engine", mode.name())
                    .set("storage", format!("{storage:?}").to_lowercase())
                    .set("wall_s", wall_s)
                    .set("per_step_ms", per_step_ms),
            );
        }
    }
    json = json.set("engines", Json::Arr(engine_rows));

    std::fs::write("BENCH_scale.json", json.render()).expect("write BENCH_scale.json");
    println!("bench_scale: wrote BENCH_scale.json; OK");
}
