//! Fig. 9: execution time of a single parallel RL inference step over large
//! ER graphs, P ∈ {1,2,3,4,6}. Paper shape: near-linear drop (21000-node:
//! 23.8s → 3.4s ≈ 7x at 6 GPUs). This repo quarter-scales the graphs
//! (1488/2496, ρ=0.15; DESIGN.md §3) and reports *simulated-parallel* step
//! time = max-shard compute + α–β comm (what a multi-GPU node would see).

#[path = "common.rs"]
mod common;

use oggm::coordinator::engine::EngineCfg;
use oggm::coordinator::fwd::forward;
use oggm::coordinator::metrics::Table;
use oggm::coordinator::shard::shards_for_graph;
use oggm::env::{GraphEnv, MvcEnv};
use oggm::graph::{generators, Partition};
use oggm::util::rng::Pcg32;

fn main() {
    let rt = common::runtime();
    let mut rng = Pcg32::seeded(0x99);
    let params = common::init_params(&mut rng);
    let sizes: Vec<usize> = if common::fast_mode() { vec![1488] } else { vec![1488, 2496] };
    let p_list = [1usize, 2, 3, 4, 6];
    let reps = common::scaled(3, 1);

    let mut t = Table::new(
        "Fig. 9: time per RL inference step, large ER graphs (simulated-parallel seconds)",
        &["P=1", "P=2", "P=3", "P=4", "P=6", "speedup@6"],
    );
    for &n in &sizes {
        println!("generating ER({n}, 0.15)...");
        let g = generators::erdos_renyi(n, 0.15, &mut rng);
        println!("|V|={} |E|={}", g.n, g.m);
        let env = MvcEnv::new(g.clone());
        let cand: Vec<bool> = (0..g.n).map(|v| env.is_candidate(v)).collect();
        let mut row = Vec::new();
        for &p in &p_list {
            let part = Partition::new(n, p);
            let shards =
                shards_for_graph(part, &g, env.removed_mask(), env.solution_mask(), &cand);
            let cfg = EngineCfg::new(p, 2);
            // Warm the executable cache, then measure.
            forward(&rt, &cfg, &params, &shards, false, true).unwrap();
            let mut sim = 0.0;
            for _ in 0..reps {
                let out = forward(&rt, &cfg, &params, &shards, false, true).unwrap();
                sim += out.timing.simulated();
            }
            let sim = sim / reps as f64;
            println!("  N={n} P={p}: {sim:.4}s/step (sim)");
            row.push(sim);
        }
        let speedup = row[0] / row[4];
        row.push(speedup);
        println!("  N={n}: speedup at P=6: {speedup:.2}x");
        t.row(format!("N={n}"), row);
    }
    common::emit(&t);
    println!("fig9: OK");
}
