//! bench_batch: graph-level batched inference vs sequential inference.
//!
//! The paper's graph-level batched processing claim: packing B small/medium
//! graphs into one forward pass per step keeps the device busy, so the
//! *per-graph* step cost drops well below B sequential single-graph runs.
//! This bench solves the same 8 graphs (a) sequentially via `solve_mvc` and
//! (b) packed via `solve_pack`, and reports wall-clock and simulated time
//! per graph-evaluation, plus the speedup. Run with compaction on and off
//! to see the eviction effect.

#[path = "common.rs"]
mod common;

use oggm::batch::{solve_pack, BatchCfg};
use oggm::coordinator::infer::{solve_mvc, InferCfg};
use oggm::coordinator::metrics::Table;
use oggm::env::Scenario;
use oggm::graph::{generators, Graph};
use oggm::util::rng::Pcg32;

fn main() {
    let rt = common::runtime();
    let mut rng = Pcg32::seeded(0xBA);
    let params = common::init_params(&mut rng);
    let b = 8usize;
    let n = 20usize;
    let bucket = 24usize;
    let p_list: Vec<usize> = if common::fast_mode() { vec![1, 2] } else { vec![1, 2, 3, 4] };
    let reps = common::scaled(3, 1);

    let graphs: Vec<Graph> = (0..b)
        .map(|i| {
            if i % 2 == 0 {
                generators::erdos_renyi(n, 0.2, &mut rng)
            } else {
                generators::barabasi_albert(n, 3, &mut rng)
            }
        })
        .collect();

    let mut t = Table::new(
        &format!("bench_batch: {b} graphs |V|={n}, per graph-eval seconds (wall)"),
        &["seq", "batched", "speedup", "seq_sim", "bat_sim", "repacks"],
    );
    for &p in &p_list {
        let caps = rt.manifest.batch_sizes(bucket, bucket / p);
        if caps.last().copied().unwrap_or(0) < b {
            println!("P={p}: no compiled batch-{b} shapes at N={bucket}, skipping \
                      (add batch shapes in configs.py and re-run make artifacts)");
            continue;
        }
        let icfg = InferCfg::new(p, 2);
        let bcfg = BatchCfg::new(p, 2);
        // Warm both artifact sets so compiles stay off the clock.
        for g in &graphs[..1] {
            solve_mvc(&rt, &icfg, &params, g, bucket).unwrap();
        }
        solve_pack(&rt, &bcfg, &params, Scenario::Mvc, graphs.clone(), bucket).unwrap();

        let (mut seq_wall, mut seq_sim, mut seq_evals) = (0.0f64, 0.0f64, 0usize);
        for _ in 0..reps {
            for g in &graphs {
                let r = solve_mvc(&rt, &icfg, &params, g, bucket).unwrap();
                seq_wall += r.wall_total;
                seq_sim += r.sim_time_per_eval * r.evaluations as f64;
                seq_evals += r.evaluations;
            }
        }
        let (mut bat_wall, mut bat_sim, mut bat_evals, mut repacks) =
            (0.0f64, 0.0f64, 0usize, 0usize);
        for _ in 0..reps {
            let r = solve_pack(&rt, &bcfg, &params, Scenario::Mvc, graphs.clone(), bucket).unwrap();
            bat_wall += r.wall_total;
            bat_sim += r.sim_total;
            bat_evals += r.per_graph.iter().map(|g| g.evaluations).sum::<usize>();
            repacks += r.repacks;
        }
        let seq_per = seq_wall / seq_evals as f64;
        let bat_per = bat_wall / bat_evals as f64;
        let speedup = seq_per / bat_per;
        println!(
            "P={p}: sequential {seq_per:.5}s/graph-eval, batched {bat_per:.5}s/graph-eval \
             ({speedup:.2}x, {} repacks/run)",
            repacks / reps
        );
        t.row(
            format!("P={p}"),
            vec![
                seq_per,
                bat_per,
                speedup,
                seq_sim / seq_evals as f64,
                bat_sim / bat_evals as f64,
                (repacks / reps) as f64,
            ],
        );
    }
    common::emit(&t);
    println!("bench_batch: OK");
}
