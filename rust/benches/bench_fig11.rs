//! Fig. 11: execution time of a single parallel RL *training* step over
//! large ER graphs, P ∈ {1,2,3,4,6}. Paper shape: 15000-node 161.4s → 29.1s
//! (5.5x) and 21000-node 316.4s → 54.4s (5.8x) at 6 GPUs. Quarter-scaled
//! sizes (1488/2496) with training minibatch B=4 (DESIGN.md §2).
//!
//! A training step = policy evaluation (B=1) + state update + τ·(fwd+bwd of
//! the reconstructed minibatch) + optimizer, exactly Alg. 5's loop body.

#[path = "common.rs"]
mod common;

use oggm::coordinator::metrics::Table;
use oggm::coordinator::train::{TrainCfg, Trainer};
use oggm::graph::generators;
use oggm::util::rng::Pcg32;

fn main() {
    let rt = common::runtime();
    let sizes: Vec<usize> = if common::fast_mode() { vec![1488] } else { vec![1488, 2496] };
    let p_list = [1usize, 2, 3, 4, 6];
    let measure_steps = common::scaled(3, 1);

    let mut t = Table::new(
        "Fig. 11: time per RL training step, large ER graphs (simulated-parallel seconds)",
        &["P=1", "P=2", "P=3", "P=4", "P=6", "speedup@6"],
    );
    for &n in &sizes {
        let mut row = Vec::new();
        for &p in &p_list {
            // Fresh trainer per P: same seed => same episode/action sequence.
            let mut rng = Pcg32::seeded(0xAA);
            let graphs =
                vec![generators::erdos_renyi(n, 0.15, &mut rng)];
            let mut cfg = TrainCfg::new(p, n);
            cfg.seed = 5;
            cfg.hyper.batch_size = 4; // matches the AOT training shapes
            cfg.hyper.lr = 1e-4;
            let params0 = common::init_params(&mut rng);
            let mut tr = Trainer::new(&rt, cfg, graphs, params0).unwrap();

            // One bounded run (run_steps stops mid-episode — a big-graph
            // episode is thousands of steps): `batch_size` replay-prefill
            // steps, one compile-warmup training step, then the measured
            // training steps.
            let total = 4 + 1 + measure_steps;
            let mut sims: Vec<f64> = Vec::new();
            let mut full_steps = 0usize;
            tr.run_steps(total, |rec| {
                if rec.loss.is_some() {
                    full_steps += 1;
                    if full_steps > 1 {
                        sims.push(rec.sim_step_time); // skip compile warmup
                    }
                }
            })
            .unwrap();
            assert!(!sims.is_empty(), "no full training steps measured");
            let sim = sims.iter().sum::<f64>() / sims.len() as f64;
            println!("  N={n} P={p}: {sim:.4}s/training-step (sim)");
            row.push(sim);
        }
        let speedup = row[0] / row[4];
        row.push(speedup);
        println!("  N={n}: speedup at P=6: {speedup:.2}x");
        t.row(format!("N={n}"), row);
    }
    common::emit(&t);
    println!("fig11: OK");
}
