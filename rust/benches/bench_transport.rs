//! bench_transport: per-frame overhead of the two rank transports
//! (DESIGN.md §12) — the in-process channel hop vs TCP-loopback socket
//! framing — across payload sizes, plus (with artifacts) the per-step
//! cost of a real rank-parallel forward over each. Emits
//! BENCH_transport.json.
//!
//! Three sections compose:
//!  - **Echo ladder (always runs, no artifacts needed):** one echo peer
//!    per transport bounces frames back; the ladder walks payload sizes
//!    from control-message (64 B) to θ-broadcast scale (1 MiB), timing
//!    round-trips. The in-proc peer moves the payload over channels
//!    without serializing (what `InProcLink` does); the TCP peer runs
//!    the real `transport::frame` codec over a loopback socket.
//!  - **Measured forward (artifacts present):** a P=2 pool over each
//!    transport drives the same policy evaluation; per-step wall time
//!    and the pool's tx/rx byte counters land in the JSON.
//!  - **Faulted recovery (artifacts present):** a scripted worker death
//!    mid-solve plus a `--reconnect` redial, timing death detection and
//!    the rejoin-and-retry path (DESIGN.md §12 liveness/rejoin).
//!
//! Check mode: without artifacts the bench still emits the echo table
//! and JSON, prints a notice for the skipped section, and exits 0.

#[path = "common.rs"]
mod common;

use oggm::coordinator::metrics::Table;
use oggm::transport::frame::{read_frame, write_frame, HEADER_LEN};
use oggm::util::json::Json;
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc;
use std::time::Instant;

/// (payload bytes, round-trips) ladder; trimmed in fast mode.
const SIZES: &[(usize, usize)] = &[(64, 4096), (4 << 10, 1024), (64 << 10, 256), (1 << 20, 32)];

struct Row {
    transport: &'static str,
    payload: usize,
    iters: usize,
    us_per_rt: f64,
    mb_s: f64,
}

fn ladder() -> Vec<(usize, usize)> {
    if common::fast_mode() {
        SIZES.iter().take(2).map(|&(s, it)| (s, it / 8)).collect()
    } else {
        SIZES.to_vec()
    }
}

/// Echo over an in-process channel pair: the payload crosses two mpsc
/// hops per round-trip and is never serialized, mirroring `InProcLink`.
fn inproc_echo() -> Vec<Row> {
    let (tx, peer_rx) = mpsc::channel::<Vec<u8>>();
    let (peer_tx, rx) = mpsc::channel::<Vec<u8>>();
    let peer = std::thread::spawn(move || {
        while let Ok(v) = peer_rx.recv() {
            if peer_tx.send(v).is_err() {
                break;
            }
        }
    });
    let mut rows = Vec::new();
    for (payload, iters) in ladder() {
        let msg = vec![7u8; payload];
        for _ in 0..8 {
            tx.send(msg.clone()).unwrap();
            rx.recv().unwrap();
        }
        let t = Instant::now();
        for _ in 0..iters {
            tx.send(msg.clone()).unwrap();
            let back = rx.recv().unwrap();
            assert_eq!(back.len(), payload);
        }
        let dt = t.elapsed().as_secs_f64();
        rows.push(Row {
            transport: "inproc",
            payload,
            iters,
            us_per_rt: dt * 1e6 / iters as f64,
            mb_s: (2 * payload * iters) as f64 / dt / 1e6,
        });
    }
    drop(tx);
    peer.join().unwrap();
    rows
}

/// Echo over a loopback TCP socket with the real frame codec on both
/// sides: each round-trip serializes, frames, and parses twice.
fn tcp_echo() -> Vec<Row> {
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind loopback");
    let addr = listener.local_addr().unwrap();
    let peer = std::thread::spawn(move || {
        let (mut s, _) = listener.accept().expect("accept echo client");
        s.set_nodelay(true).ok();
        while let Ok(f) = read_frame(&mut s) {
            if write_frame(&mut s, f.kind, f.rank, &f.payload).is_err() {
                break;
            }
        }
    });
    let mut stream = TcpStream::connect(addr).expect("connect echo peer");
    stream.set_nodelay(true).ok();
    let mut rows = Vec::new();
    for (payload, iters) in ladder() {
        let msg = vec![7u8; payload];
        for _ in 0..8 {
            write_frame(&mut stream, 1, 0, &msg).unwrap();
            read_frame(&mut stream).unwrap();
        }
        let t = Instant::now();
        for _ in 0..iters {
            write_frame(&mut stream, 1, 0, &msg).unwrap();
            let back = read_frame(&mut stream).unwrap();
            assert_eq!(back.payload.len(), payload);
        }
        let dt = t.elapsed().as_secs_f64();
        rows.push(Row {
            transport: "tcp",
            payload,
            iters,
            us_per_rt: dt * 1e6 / iters as f64,
            mb_s: (2 * (payload + HEADER_LEN) * iters) as f64 / dt / 1e6,
        });
    }
    drop(stream);
    peer.join().unwrap();
    rows
}

/// Measured forward per transport (artifact-gated): returns JSON or a
/// notice string for the skip path.
fn measured_forward() -> Result<Json, String> {
    use oggm::coordinator::engine::EngineCfg;
    use oggm::coordinator::shard::{shards_for_graph, ShardSet};
    use oggm::graph::{generators, Partition};
    use oggm::parallel::{remote_worker, RankPool};
    use oggm::util::rng::Pcg32;

    std::env::set_var("OGGM_RANK_WAIT_SECS", "4");
    let p = 2usize;
    let mut rng = Pcg32::seeded(0x7721);
    let g = generators::erdos_renyi(20, 0.25, &mut rng);
    let params = common::init_params(&mut rng);
    let part = Partition::new(24, p);
    let cfg = EngineCfg::new(p, 2);
    let steps = common::scaled(40, 5);
    let fresh = || {
        let removed = vec![false; g.n];
        let sol = vec![false; g.n];
        let cand: Vec<bool> = (0..g.n).map(|v| g.degree(v) > 0).collect();
        ShardSet::Dense(shards_for_graph(part, &g, &removed, &sol, &cand))
    };
    let dir = oggm::runtime::manifest::default_dir();

    let run = |pool: &RankPool| -> Result<(f64, Vec<f32>, u64, u64), String> {
        let mut set = fresh();
        pool.install(0, &params, &mut set, true).map_err(|e| format!("{e:#}"))?;
        let mut scores = Vec::new();
        let t = Instant::now();
        for _ in 0..steps {
            scores = pool.forward(0, &cfg, &set, false, true).map_err(|e| format!("{e:#}"))?.scores;
        }
        let ms = t.elapsed().as_secs_f64() * 1e3 / steps as f64;
        let st = pool.stats().map_err(|e| format!("{e:#}"))?;
        Ok((ms, scores, st.tx_bytes, st.rx_bytes))
    };

    let inproc = RankPool::new(&dir, p).map_err(|e| format!("rank pool unavailable: {e:#}"))?;
    let (in_ms, in_scores, in_tx, in_rx) = run(&inproc)?;
    drop(inproc);

    let l = TcpListener::bind("127.0.0.1:0").map_err(|e| e.to_string())?;
    let addr = l.local_addr().unwrap().to_string();
    drop(l);
    let workers: Vec<_> = (0..p)
        .map(|rank| {
            let addr = addr.clone();
            let dir = dir.clone();
            std::thread::spawn(move || {
                if let Err(e) = remote_worker(dir, &addr, rank, Some(p), None) {
                    eprintln!("bench_transport: worker {rank} exited with: {e:#}");
                }
            })
        })
        .collect();
    let tcp = RankPool::new_tcp(&dir, p, 2, None, &format!("tcp:{addr}"))
        .map_err(|e| format!("TCP rank group unavailable: {e:#}"))?;
    let (tcp_ms, tcp_scores, tcp_tx, tcp_rx) = run(&tcp)?;
    drop(tcp);
    for w in workers {
        let _ = w.join();
    }
    assert_eq!(tcp_scores, in_scores, "transports diverged — equivalence is broken");

    println!(
        "bench_transport: measured P={p} forward — inproc {in_ms:.3} ms/step, \
         tcp {tcp_ms:.3} ms/step ({:.2}x); tcp traffic {tcp_tx} B out / {tcp_rx} B in",
        tcp_ms / in_ms.max(1e-9)
    );
    Ok(Json::obj()
        .set("p", p)
        .set("steps", steps)
        .set("inproc_ms_per_step", in_ms)
        .set("tcp_ms_per_step", tcp_ms)
        .set("inproc_tx_bytes", in_tx)
        .set("inproc_rx_bytes", in_rx)
        .set("tcp_tx_bytes", tcp_tx)
        .set("tcp_rx_bytes", tcp_rx))
}

/// Faulted-recovery drill (artifact-gated): a scripted worker death
/// mid-solve (`kind=disconnect`, the kill -9 analogue), a `--reconnect`
/// redial, and the recovered re-solve — recording how fast the liveness
/// layer detected the death (detect_ms) and how long the rejoin-and-retry
/// path took end to end (recovery_ms). Lands in BENCH_transport.json as
/// the "faulted" object.
fn faulted_recovery() -> Result<Json, String> {
    use oggm::collective::fault::FaultPlan;
    use oggm::coordinator::engine::EngineCfg;
    use oggm::coordinator::shard::{shards_for_graph, ShardSet};
    use oggm::graph::{generators, Partition};
    use oggm::parallel::{remote_worker_with, RankPool};
    use oggm::transport::TcpCfg;
    use oggm::util::rng::Pcg32;
    use std::sync::Arc;
    use std::time::Duration;

    std::env::set_var("OGGM_RANK_WAIT_SECS", "4");
    let p = 2usize;
    let mut rng = Pcg32::seeded(0x7722);
    let g = generators::erdos_renyi(20, 0.25, &mut rng);
    let params = common::init_params(&mut rng);
    let part = Partition::new(24, p);
    let cfg = EngineCfg::new(p, 2);
    let fresh = || {
        let removed = vec![false; g.n];
        let sol = vec![false; g.n];
        let cand: Vec<bool> = (0..g.n).map(|v| g.degree(v) > 0).collect();
        ShardSet::Dense(shards_for_graph(part, &g, &removed, &sol, &cand))
    };
    let dir = oggm::runtime::manifest::default_dir();

    let l = TcpListener::bind("127.0.0.1:0").map_err(|e| e.to_string())?;
    let addr = l.local_addr().unwrap().to_string();
    drop(l);
    let workers: Vec<_> = (0..p)
        .map(|rank| {
            let addr = addr.clone();
            let dir = dir.clone();
            let fault = (rank == 1)
                .then(|| Arc::new(FaultPlan::parse("rank=1,kind=disconnect,frame=2").unwrap()));
            std::thread::spawn(move || {
                if let Err(e) = remote_worker_with(dir, &addr, rank, Some(p), fault, "", 2) {
                    eprintln!("bench_transport: faulted worker {rank} exited with: {e:#}");
                }
            })
        })
        .collect();
    let tcp_cfg = TcpCfg {
        timeout: Duration::from_secs(2),
        rejoin_window: Duration::from_secs(10),
        token: String::new(),
    };
    let pool = RankPool::new_tcp_with(&dir, p, 2, None, &format!("tcp:{addr}"), tcp_cfg)
        .map_err(|e| format!("TCP rank group unavailable: {e:#}"))?;

    // Drive into the scripted death, timing its detection.
    let mut set = fresh();
    let t = Instant::now();
    let died = pool
        .install(0, &params, &mut set, true)
        .and_then(|_| pool.forward(0, &cfg, &set, false, true).map(|_| ()));
    if died.is_ok() {
        return Err("scripted worker death never fired".into());
    }
    let detect_ms = t.elapsed().as_secs_f64() * 1e3;

    // Recovery: the next install holds the rejoin window open for the
    // redialing worker, then the forward must land.
    let t = Instant::now();
    let mut set2 = fresh();
    pool.install(0, &params, &mut set2, true)
        .map_err(|e| format!("post-rejoin install failed: {e:#}"))?;
    pool.forward(0, &cfg, &set2, false, true)
        .map_err(|e| format!("post-rejoin forward failed: {e:#}"))?;
    let recovery_ms = t.elapsed().as_secs_f64() * 1e3;
    let st = pool.stats().map_err(|e| format!("{e:#}"))?;
    drop(pool);
    for w in workers {
        let _ = w.join();
    }

    println!(
        "bench_transport: faulted P={p} — death detected in {detect_ms:.1} ms, \
         rejoin + re-solve in {recovery_ms:.1} ms ({} remote restart(s))",
        st.remote_restarts
    );
    Ok(Json::obj()
        .set("p", p)
        .set("detect_ms", detect_ms)
        .set("recovery_ms", recovery_ms)
        .set("remote_restarts", st.remote_restarts)
        .set("heartbeats_missed", st.heartbeats_missed)
        .set("rejoin_ms", st.rejoin_time.as_secs_f64() * 1e3))
}

fn main() {
    let mut rows = inproc_echo();
    rows.extend(tcp_echo());

    let mut t = Table::new(
        "bench_transport: echo round-trip per transport (frame codec on TCP, zero-copy in-proc)",
        &["payload_B", "iters", "us_per_rt", "MB_s"],
    );
    let mut json_rows: Vec<Json> = Vec::new();
    for r in &rows {
        t.row(
            format!("{}/{}", r.transport, r.payload),
            vec![r.payload as f64, r.iters as f64, r.us_per_rt, r.mb_s],
        );
        json_rows.push(
            Json::obj()
                .set("transport", r.transport)
                .set("payload_bytes", r.payload)
                .set("iters", r.iters)
                .set("us_per_round_trip", r.us_per_rt)
                .set("mb_per_s", r.mb_s),
        );
    }
    common::emit(&t);
    let small_in = rows.iter().find(|r| r.transport == "inproc").unwrap().us_per_rt;
    let small_tcp = rows.iter().find(|r| r.transport == "tcp").unwrap().us_per_rt;
    println!(
        "bench_transport: 64 B round-trip — inproc {small_in:.1} us, tcp {small_tcp:.1} us \
         ({:.1}x framing overhead)",
        small_tcp / small_in.max(1e-9)
    );

    let mut json = Json::obj().set("bench", "transport").set("echo", json_rows);
    if !oggm::runtime::manifest::default_dir().join("manifest.tsv").exists() {
        println!("bench_transport: artifacts not built, skipping measured forward (check mode OK)");
    } else {
        match measured_forward() {
            Ok(m) => json = json.set("measured", m),
            Err(why) => println!("bench_transport: skipping measured forward: {why}"),
        }
        match faulted_recovery() {
            Ok(f) => json = json.set("faulted", f),
            Err(why) => println!("bench_transport: skipping faulted recovery: {why}"),
        }
    }

    std::fs::write("BENCH_transport.json", json.render()).expect("write BENCH_transport.json");
    println!("bench_transport: wrote BENCH_transport.json; OK");
}
