//! Distributed backward pass: hand-rolled VJP-stage orchestration with
//! collective adjoints, mirroring python/tests/dist_sim.py `dist_backward`.
//!
//! Collective adjoints (DESIGN.md §2): the layer message all-reduce-sum +
//! local slice reverses to an all-gather of cotangent slices followed by a
//! broadcast into every shard's msg_bwd; the q_sum all-reduce reverses to an
//! all-reduce of d_sum_all plus a column broadcast. θ-gradients are summed
//! across shards (≡ the paper's gradient all-reduce of 4K²+4K floats).

use super::engine::{EngineCfg, StepTiming};
use super::fwd::{Activations, AnyDeviceState, DeviceState, SparseDeviceState, ThetaViews};
use super::shard::{ShardSet, ShardState, SparseShard};
use crate::model::Params;
use crate::runtime::{artifact_name, sparse_msg_name, sparse_pre_name, HostTensor, Input, Runtime};
use crate::util::add_assign;
use anyhow::Result;
use std::time::Instant;

/// Backward output: scalar loss, flat parameter gradient, timing.
#[derive(Debug)]
pub struct GradOutput {
    /// Minibatch DQN regression loss.
    pub loss: f32,
    /// Flat gradient in Params layout (already summed over shards).
    pub grads: Vec<f32>,
    /// Accumulated lockstep timing of the backward pass.
    pub timing: StepTiming,
}

/// DQN regression loss over the distributed scores + full backward pass.
///
/// `onehot` is B*N (one action per batch element), `targets` is B.
pub fn backward(
    rt: &Runtime,
    cfg: &EngineCfg,
    params: &Params,
    shards: &[ShardState],
    acts: &Activations,
    onehot: &[f32],
    targets: &[f32],
) -> Result<GradOutput> {
    backward_dev(rt, cfg, params, shards, acts, onehot, targets, None)
}

/// `backward` with optional device residency: a [`DeviceState`] shares the
/// already-uploaded θ and adjacency buffers with the forward pass, so the
/// τ repeated gradient iterations of §4.5.2 re-upload nothing but the
/// (small) activations.
#[allow(clippy::too_many_arguments)]
pub fn backward_dev(
    rt: &Runtime,
    cfg: &EngineCfg,
    params: &Params,
    shards: &[ShardState],
    acts: &Activations,
    onehot: &[f32],
    targets: &[f32],
    dev: Option<&DeviceState>,
) -> Result<GradOutput> {
    let wall = Instant::now();
    let p = shards.len();
    let (b, n, ni, k) = (shards[0].b, shards[0].n(), shards[0].ni(), params.k);
    assert_eq!(onehot.len(), b * n);
    assert_eq!(targets.len(), b);
    if let Some(d) = dev {
        // Same guards as forward_dev: a stale or re-shaped device adjacency
        // would silently produce wrong gradients.
        d.assert_in_sync(shards);
    }
    let mut timing = StepTiming::new(p);
    let mut grads = vec![0.0f32; params.flat.len()];
    let th = ThetaViews::new(params, dev.map(|d| d.theta_bufs()));

    let d_s = [b, ni];
    let d_a = [b, ni, n];
    let d_e = [b, k, ni];
    let d_m = [b, k, n];
    let d_sum = [b, k];

    let exec = |shard: usize, name: &str, inputs: &[Input], timing: &mut StepTiming| {
        let t0 = Instant::now();
        let out = rt.execute_in(name, inputs);
        timing.compute[shard] += t0.elapsed().as_secs_f64();
        out
    };

    // §Perf: the adjacency comes from the DeviceState when one is active
    // (zero upload) or is uploaded once and shared by pre_bwd and msg_bwd
    // (same fresh-upload accounting as the forward pass).
    let a_owned: Vec<xla::PjRtBuffer> = if dev.is_none() {
        super::fwd::upload_a_fresh(rt, shards, &d_a, &mut timing)?
    } else {
        Vec::new()
    };
    let a_bufs: Vec<&xla::PjRtBuffer> = match dev {
        Some(d) => (0..p).map(|i| d.a_buf(i)).collect(),
        None => a_owned.iter().collect(),
    };

    // ---- loss adjoint (host): q_sa = Σ_shards Σ_j scores_i·onehot_i  ----
    let t_host = Instant::now();
    let mut onehot_i: Vec<Vec<f32>> = Vec::with_capacity(p);
    for sh in shards.iter() {
        let row0 = sh.part.row0(sh.shard);
        let mut local = vec![0.0f32; b * ni];
        for g in 0..b {
            local[g * ni..(g + 1) * ni]
                .copy_from_slice(&onehot[g * n + row0..g * n + row0 + ni]);
        }
        onehot_i.push(local);
    }
    let mut q_sa = vec![0.0f32; b];
    for i in 0..p {
        for g in 0..b {
            for r in 0..ni {
                q_sa[g] += acts.scores_i[i][g * ni + r] * onehot_i[i][g * ni + r];
            }
        }
    }
    // (partial q_sa all-reduce — B floats)
    timing.add_comm(cfg.cost.all_reduce(p, 4 * b), 4 * b);
    let mut loss = 0.0f32;
    let mut d_qsa = vec![0.0f32; b];
    for g in 0..b {
        let diff = q_sa[g] - targets[g];
        loss += diff * diff / b as f32;
        d_qsa[g] = 2.0 * diff / b as f32;
    }
    let d_scores: Vec<Vec<f32>> = (0..p)
        .map(|i| {
            (0..b * ni)
                .map(|idx| d_qsa[idx / ni] * onehot_i[i][idx])
                .collect()
        })
        .collect();
    timing.host += t_host.elapsed().as_secs_f64();

    // ---- stage 5 adjoint ----
    let name_qbwd = artifact_name("q_scores_bwd", b, n, ni, k);
    let mut d_embed: Vec<Vec<f32>> = Vec::with_capacity(p);
    let mut d_sum_all = vec![0.0f32; b * k];
    for (i, sh) in shards.iter().enumerate() {
        let out = exec(
            i,
            &name_qbwd,
            &[
                th.t(4),
                th.t(5),
                th.t(6),
                Input::Host(HostTensor::new(&d_e, &acts.embed_final[i])),
                Input::Host(HostTensor::new(&d_s, &sh.c)),
                Input::Host(HostTensor::new(&d_sum, &acts.sum_all)),
                Input::Host(HostTensor::new(&d_s, &d_scores[i])),
            ],
            &mut timing,
        )?;
        let mut it = out.into_iter();
        let (d5, d6, d7, d_e_i, d_sa) = (
            it.next().unwrap(),
            it.next().unwrap(),
            it.next().unwrap(),
            it.next().unwrap(),
            it.next().unwrap(),
        );
        let t_host = Instant::now();
        accumulate(&mut grads, params.offset(4), &d5);
        accumulate(&mut grads, params.offset(5), &d6);
        accumulate(&mut grads, params.offset(6), &d7);
        add_assign(&mut d_sum_all, &d_sa);
        d_embed.push(d_e_i);
        timing.host += t_host.elapsed().as_secs_f64();
    }
    // q_sum collective adjoint: all-reduce d_sum_all, broadcast into columns.
    timing.add_comm(cfg.cost.all_reduce(p, 4 * b * k), 4 * b * k);
    let t_host = Instant::now();
    for d_e_i in d_embed.iter_mut() {
        for g in 0..b {
            for kk in 0..k {
                let base = g * k * ni + kk * ni;
                let add = d_sum_all[g * k + kk];
                for r in 0..ni {
                    d_e_i[base + r] += add;
                }
            }
        }
    }
    timing.host += t_host.elapsed().as_secs_f64();

    // ---- layer loop, reversed ----
    let name_cbwd = artifact_name("embed_combine_bwd", b, n, ni, k);
    let name_mbwd = artifact_name("embed_msg_bwd", b, n, ni, k);
    let mut d_pre_acc: Vec<Vec<f32>> = (0..p).map(|_| vec![0.0f32; b * k * ni]).collect();
    for layer in (0..cfg.l).rev() {
        let mut d_nbr: Vec<Vec<f32>> = Vec::with_capacity(p);
        for i in 0..p {
            let out = exec(
                i,
                &name_cbwd,
                &[
                    th.t(3),
                    Input::Host(HostTensor::new(&d_e, &acts.pre[i])),
                    Input::Host(HostTensor::new(&d_e, &acts.nbr_slice[layer][i])),
                    Input::Host(HostTensor::new(&d_e, &d_embed[i])),
                ],
                &mut timing,
            )?;
            let mut it = out.into_iter();
            let (d4, d_pre, d_nb) =
                (it.next().unwrap(), it.next().unwrap(), it.next().unwrap());
            let t_host = Instant::now();
            accumulate(&mut grads, params.offset(3), &d4);
            add_assign(&mut d_pre_acc[i], &d_pre);
            d_nbr.push(d_nb);
            timing.host += t_host.elapsed().as_secs_f64();
        }
        if layer == 0 {
            // Layer 0's message input is the zeros constant: its cotangent
            // is discarded, so the all-gather + msg_bwd are elided.
            break;
        }
        // Collective adjoint: ALL-GATHER cotangent slices into B*K*N.
        let t_host = Instant::now();
        let mut d_partial = vec![0.0f32; b * k * n];
        for (i, sh) in shards.iter().enumerate() {
            let row0 = sh.part.row0(sh.shard);
            for g in 0..b {
                for kk in 0..k {
                    let dst = g * k * n + kk * n + row0;
                    let src = g * k * ni + kk * ni;
                    d_partial[dst..dst + ni].copy_from_slice(&d_nbr[i][src..src + ni]);
                }
            }
        }
        timing.host += t_host.elapsed().as_secs_f64();
        timing.add_comm(cfg.cost.all_gather(p, 4 * b * k * ni), 4 * b * k * ni * p);
        for i in 0..p {
            let out = exec(
                i,
                &name_mbwd,
                &[Input::Dev(a_bufs[i]), Input::Host(HostTensor::new(&d_m, &d_partial))],
                &mut timing,
            )?;
            d_embed[i] = out.into_iter().next().unwrap();
        }
    }

    // ---- stage 1 adjoint ----
    let name_pbwd = artifact_name("embed_pre_bwd", b, n, ni, k);
    for (i, sh) in shards.iter().enumerate() {
        let out = exec(
            i,
            &name_pbwd,
            &[
                th.t(0),
                th.t(1),
                th.t(2),
                Input::Host(HostTensor::new(&d_s, &sh.s)),
                Input::Dev(a_bufs[i]),
                Input::Host(HostTensor::new(&d_e, &d_pre_acc[i])),
            ],
            &mut timing,
        )?;
        let mut it = out.into_iter();
        let (d1, d2, d3) = (it.next().unwrap(), it.next().unwrap(), it.next().unwrap());
        let t_host = Instant::now();
        accumulate(&mut grads, params.offset(0), &d1);
        accumulate(&mut grads, params.offset(1), &d2);
        accumulate(&mut grads, params.offset(2), &d3);
        timing.host += t_host.elapsed().as_secs_f64();
    }

    // Gradient all-reduce (θ1-θ7 = 4K²+4K floats, §5.1(3)).
    timing.add_comm(cfg.cost.all_reduce(p, 4 * grads.len()), 4 * grads.len());

    timing.wall = wall.elapsed().as_secs_f64();
    Ok(GradOutput { loss, grads, timing })
}

fn accumulate(grads: &mut [f32], offset: usize, part: &[f32]) {
    add_assign(&mut grads[offset..offset + part.len()], part);
}

/// DQN loss + full backward pass on the sparse CSR path (DESIGN.md §7):
/// the layer-message adjoint runs `embed_msg_sp_bwd` per edge tile (the
/// reversed gather/segment-sum), and stage 1's adjoint is
/// `embed_pre_sp_bwd` over the degree vector — no stage ever touches an
/// N-wide adjacency. python/tests/dist_sim.py `dist_backward_sparse` is
/// the executable specification. A [`SparseDeviceState`] shares the θ and
/// edge-tile buffers already uploaded by the forward pass.
#[allow(clippy::too_many_arguments)]
pub fn backward_sparse(
    rt: &Runtime,
    cfg: &EngineCfg,
    params: &Params,
    shards: &[SparseShard],
    acts: &Activations,
    onehot: &[f32],
    targets: &[f32],
    dev: Option<&SparseDeviceState>,
) -> Result<GradOutput> {
    let wall = Instant::now();
    let p = shards.len();
    let (b, n, ni, k) = (shards[0].b, shards[0].n(), shards[0].ni(), params.k);
    let chunk = shards[0].chunk;
    assert_eq!(onehot.len(), b * n);
    assert_eq!(targets.len(), b);
    if let Some(d) = dev {
        d.assert_in_sync(shards);
    }
    let mut timing = StepTiming::new(p);
    let mut grads = vec![0.0f32; params.flat.len()];
    let th = ThetaViews::new(params, dev.map(|d| d.theta_bufs()));

    let d_s = [b, ni];
    let d_e = [b, k, ni];
    let d_ec = [b, k, chunk];
    let d_sum = [b, k];

    let exec = |shard: usize, name: &str, inputs: &[Input], timing: &mut StepTiming| {
        let t0 = Instant::now();
        let out = rt.execute_in(name, inputs);
        timing.compute[shard] += t0.elapsed().as_secs_f64();
        out
    };

    // §Perf: edge tiles come from the SparseDeviceState when one is active
    // (zero upload) or are uploaded once and shared by every layer's tile
    // sweep (same fresh-upload accounting as the forward pass and the
    // dense path's A upload).
    let tile_owned: Vec<Vec<(xla::PjRtBuffer, xla::PjRtBuffer, xla::PjRtBuffer)>> =
        if dev.is_none() {
            super::fwd::upload_tiles_fresh(rt, shards, &mut timing)?
        } else {
            Vec::new()
        };

    // ---- loss adjoint (host) — identical to the dense path ----
    let t_host = Instant::now();
    let mut onehot_i: Vec<Vec<f32>> = Vec::with_capacity(p);
    for sh in shards.iter() {
        let row0 = sh.part.row0(sh.shard);
        let mut local = vec![0.0f32; b * ni];
        for g in 0..b {
            local[g * ni..(g + 1) * ni]
                .copy_from_slice(&onehot[g * n + row0..g * n + row0 + ni]);
        }
        onehot_i.push(local);
    }
    let mut q_sa = vec![0.0f32; b];
    for i in 0..p {
        for g in 0..b {
            for r in 0..ni {
                q_sa[g] += acts.scores_i[i][g * ni + r] * onehot_i[i][g * ni + r];
            }
        }
    }
    timing.add_comm(cfg.cost.all_reduce(p, 4 * b), 4 * b);
    let mut loss = 0.0f32;
    let mut d_qsa = vec![0.0f32; b];
    for g in 0..b {
        let diff = q_sa[g] - targets[g];
        loss += diff * diff / b as f32;
        d_qsa[g] = 2.0 * diff / b as f32;
    }
    let d_scores: Vec<Vec<f32>> = (0..p)
        .map(|i| (0..b * ni).map(|idx| d_qsa[idx / ni] * onehot_i[i][idx]).collect())
        .collect();
    timing.host += t_host.elapsed().as_secs_f64();

    // ---- stage 5 adjoint (shared N-free stage) ----
    let name_qbwd = artifact_name("q_scores_bwd", b, n, ni, k);
    let mut d_embed: Vec<Vec<f32>> = Vec::with_capacity(p);
    let mut d_sum_all = vec![0.0f32; b * k];
    for (i, sh) in shards.iter().enumerate() {
        let out = exec(
            i,
            &name_qbwd,
            &[
                th.t(4),
                th.t(5),
                th.t(6),
                Input::Host(HostTensor::new(&d_e, &acts.embed_final[i])),
                Input::Host(HostTensor::new(&d_s, &sh.c)),
                Input::Host(HostTensor::new(&d_sum, &acts.sum_all)),
                Input::Host(HostTensor::new(&d_s, &d_scores[i])),
            ],
            &mut timing,
        )?;
        let mut it = out.into_iter();
        let (d5, d6, d7, d_e_i, d_sa) = (
            it.next().unwrap(),
            it.next().unwrap(),
            it.next().unwrap(),
            it.next().unwrap(),
            it.next().unwrap(),
        );
        let t_host = Instant::now();
        accumulate(&mut grads, params.offset(4), &d5);
        accumulate(&mut grads, params.offset(5), &d6);
        accumulate(&mut grads, params.offset(6), &d7);
        add_assign(&mut d_sum_all, &d_sa);
        d_embed.push(d_e_i);
        timing.host += t_host.elapsed().as_secs_f64();
    }
    timing.add_comm(cfg.cost.all_reduce(p, 4 * b * k), 4 * b * k);
    let t_host = Instant::now();
    for d_e_i in d_embed.iter_mut() {
        for g in 0..b {
            for kk in 0..k {
                let base = g * k * ni + kk * ni;
                let add = d_sum_all[g * k + kk];
                for r in 0..ni {
                    d_e_i[base + r] += add;
                }
            }
        }
    }
    timing.host += t_host.elapsed().as_secs_f64();

    // ---- layer loop, reversed ----
    let name_cbwd = artifact_name("embed_combine_bwd", b, n, ni, k);
    let mut d_pre_acc: Vec<Vec<f32>> = (0..p).map(|_| vec![0.0f32; b * k * ni]).collect();
    let mut dchunk = vec![0.0f32; b * k * chunk];
    for layer in (0..cfg.l).rev() {
        let mut d_nbr: Vec<Vec<f32>> = Vec::with_capacity(p);
        for i in 0..p {
            let out = exec(
                i,
                &name_cbwd,
                &[
                    th.t(3),
                    Input::Host(HostTensor::new(&d_e, &acts.pre[i])),
                    Input::Host(HostTensor::new(&d_e, &acts.nbr_slice[layer][i])),
                    Input::Host(HostTensor::new(&d_e, &d_embed[i])),
                ],
                &mut timing,
            )?;
            let mut it = out.into_iter();
            let (d4, d_pre, d_nb) = (it.next().unwrap(), it.next().unwrap(), it.next().unwrap());
            let t_host = Instant::now();
            accumulate(&mut grads, params.offset(3), &d4);
            add_assign(&mut d_pre_acc[i], &d_pre);
            d_nbr.push(d_nb);
            timing.host += t_host.elapsed().as_secs_f64();
        }
        if layer == 0 {
            // Layer 0's message input is the zeros constant: its cotangent
            // is discarded, so the all-gather + tile sweep are elided.
            break;
        }
        // Collective adjoint: ALL-GATHER cotangent slices into B*K*N.
        let t_host = Instant::now();
        let mut d_partial = vec![0.0f32; b * k * n];
        for (i, sh) in shards.iter().enumerate() {
            let row0 = sh.part.row0(sh.shard);
            for g in 0..b {
                for kk in 0..k {
                    let dst = g * k * n + kk * n + row0;
                    let src = g * k * ni + kk * ni;
                    d_partial[dst..dst + ni].copy_from_slice(&d_nbr[i][src..src + ni]);
                }
            }
        }
        timing.host += t_host.elapsed().as_secs_f64();
        timing.add_comm(cfg.cost.all_gather(p, 4 * b * k * ni), 4 * b * k * ni * p);
        // Tile sweep: d_embed[b,k,j] = Σ_e [src_e == j] d_partial[dst_e]·w_e,
        // one embed_msg_sp_bwd per tile, destination-chunk sliced in and
        // source-chunk accumulated out (the transpose of the forward sweep).
        for (i, sh) in shards.iter().enumerate() {
            let mut d_emb = vec![0.0f32; b * k * ni];
            let tiles = &sh.tiles;
            let mut ti = 0usize;
            while ti < tiles.len() {
                let dc = tiles[ti].dc;
                // The forward groups by sc; chained (sc, dc) runs still
                // share dc, so slicing per run stays correct either way —
                // slice d_partial's destination chunk for this run.
                let t_host = Instant::now();
                let dlo = dc * chunk;
                let dhi = (dlo + chunk).min(n);
                dchunk.fill(0.0);
                for g in 0..b {
                    for kk in 0..k {
                        let so = g * k * n + kk * n + dlo;
                        let eo = g * k * chunk + kk * chunk;
                        dchunk[eo..eo + (dhi - dlo)]
                            .copy_from_slice(&d_partial[so..so + (dhi - dlo)]);
                    }
                }
                timing.host += t_host.elapsed().as_secs_f64();
                while ti < tiles.len() && tiles[ti].dc == dc {
                    let tile = &tiles[ti];
                    let name = sparse_msg_name("embed_msg_sp_bwd", b, tile.cap, chunk, k);
                    let (src_in, dst_in, w_in) = match dev {
                        Some(d) => (
                            Input::Dev(&d.src[i][ti]),
                            Input::Dev(&d.dst[i][ti]),
                            Input::Dev(&d.w[i][ti]),
                        ),
                        None => {
                            let (sb, db, wb) = &tile_owned[i][ti];
                            (Input::Dev(sb), Input::Dev(db), Input::Dev(wb))
                        }
                    };
                    let inputs =
                        [Input::Host(HostTensor::new(&d_ec, &dchunk)), src_in, dst_in, w_in];
                    let part = exec(i, &name, &inputs, &mut timing)?.into_iter().next().unwrap();
                    let t_host = Instant::now();
                    let slo = tile.sc * chunk;
                    let shi = (slo + chunk).min(ni);
                    for g in 0..b {
                        for kk in 0..k {
                            let no = g * k * ni + kk * ni + slo;
                            let po = g * k * chunk + kk * chunk;
                            let len = shi - slo;
                            add_assign(&mut d_emb[no..no + len], &part[po..po + len]);
                        }
                    }
                    timing.host += t_host.elapsed().as_secs_f64();
                    ti += 1;
                }
            }
            d_embed[i] = d_emb;
        }
    }

    // ---- stage 1 adjoint (degree-vector variant) ----
    let name_pbwd = sparse_pre_name("embed_pre_sp_bwd", b, ni, k);
    for (i, sh) in shards.iter().enumerate() {
        let out = exec(
            i,
            &name_pbwd,
            &[
                th.t(0),
                th.t(1),
                th.t(2),
                Input::Host(HostTensor::new(&d_s, &sh.s)),
                Input::Host(HostTensor::new(&d_s, &sh.deg)),
                Input::Host(HostTensor::new(&d_e, &d_pre_acc[i])),
            ],
            &mut timing,
        )?;
        let mut it = out.into_iter();
        let (d1, d2, d3) = (it.next().unwrap(), it.next().unwrap(), it.next().unwrap());
        let t_host = Instant::now();
        accumulate(&mut grads, params.offset(0), &d1);
        accumulate(&mut grads, params.offset(1), &d2);
        accumulate(&mut grads, params.offset(2), &d3);
        timing.host += t_host.elapsed().as_secs_f64();
    }

    // Gradient all-reduce (θ1-θ7 = 4K²+4K floats, §5.1(3)).
    timing.add_comm(cfg.cost.all_reduce(p, 4 * grads.len()), 4 * grads.len());

    timing.wall = wall.elapsed().as_secs_f64();
    Ok(GradOutput { loss, grads, timing })
}

/// Storage-generic backward: dispatch a [`ShardSet`] to [`backward_dev`]
/// (dense) or [`backward_sparse`] with the matching device state.
#[allow(clippy::too_many_arguments)]
pub fn backward_set(
    rt: &Runtime,
    cfg: &EngineCfg,
    params: &Params,
    set: &ShardSet,
    acts: &Activations,
    onehot: &[f32],
    targets: &[f32],
    dev: Option<&AnyDeviceState>,
) -> Result<GradOutput> {
    match set {
        ShardSet::Dense(sh) => {
            let d = match dev {
                Some(AnyDeviceState::Dense(d)) => Some(d),
                None => None,
                Some(AnyDeviceState::Sparse(_)) => panic!("sparse device state on dense set"),
            };
            backward_dev(rt, cfg, params, sh, acts, onehot, targets, d)
        }
        ShardSet::Sparse(sh) => {
            let d = match dev {
                Some(AnyDeviceState::Sparse(d)) => Some(d),
                None => None,
                Some(AnyDeviceState::Dense(_)) => panic!("dense device state on sparse set"),
            };
            backward_sparse(rt, cfg, params, sh, acts, onehot, targets, d)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::fwd::forward;
    use crate::coordinator::shard::ShardState;
    use crate::graph::{generators, Partition};
    use crate::util::rng::Pcg32;

    fn runtime() -> Option<Runtime> {
        if !std::path::Path::new("artifacts/manifest.tsv").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        Some(Runtime::new("artifacts").unwrap())
    }

    /// Build a B=8 training batch of random 20-node states.
    fn batch_shards(part: Partition, b: usize, seed: u64) -> Vec<ShardState> {
        let mut rng = Pcg32::seeded(seed);
        let graphs: Vec<_> =
            (0..b).map(|_| generators::erdos_renyi(20, 0.25, &mut rng)).collect();
        let grefs: Vec<&crate::graph::Graph> = graphs.iter().collect();
        let removed: Vec<Vec<bool>> = graphs.iter().map(|g| vec![false; g.n]).collect();
        let sol = removed.clone();
        let cand: Vec<Vec<bool>> = graphs
            .iter()
            .map(|g| (0..g.n).map(|v| g.degree(v) > 0).collect())
            .collect();
        (0..part.p)
            .map(|i| {
                ShardState::from_graphs(
                    part,
                    i,
                    &grefs,
                    &removed.iter().map(|r| r.as_slice()).collect::<Vec<_>>(),
                    &sol.iter().map(|r| r.as_slice()).collect::<Vec<_>>(),
                    &cand.iter().map(|r| r.as_slice()).collect::<Vec<_>>(),
                )
            })
            .collect()
    }

    fn make_targets(b: usize, n: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Pcg32::seeded(seed);
        let mut onehot = vec![0.0f32; b * n];
        for g in 0..b {
            onehot[g * n + rng.gen_range(20)] = 1.0; // actions among real nodes
        }
        let targets: Vec<f32> = (0..b).map(|_| rng.next_normal()).collect();
        (onehot, targets)
    }

    #[test]
    fn grad_p_parity() {
        // Gradients must agree across P — the distributed-backprop invariant.
        let Some(rt) = runtime() else { return };
        let params = Params::init(32, &mut Pcg32::seeded(21));
        let (onehot, targets) = make_targets(8, 24, 22);
        let mut reference: Option<(f32, Vec<f32>)> = None;
        for p in [1usize, 2, 3] {
            let part = Partition::new(24, p);
            let shards = batch_shards(part, 8, 20);
            let cfg = EngineCfg::new(p, 2);
            let fwd = forward(&rt, &cfg, &params, &shards, true, false).unwrap();
            let out = backward(&rt, &cfg, &params, &shards, fwd.acts.as_ref().unwrap(),
                               &onehot, &targets).unwrap();
            match &reference {
                None => reference = Some((out.loss, out.grads)),
                Some((l0, g0)) => {
                    assert!((out.loss - l0).abs() < 1e-4, "loss P={p}: {} vs {l0}", out.loss);
                    let d = crate::util::max_abs_diff(&out.grads, g0);
                    assert!(d < 1e-3, "grads P={p} diverge by {d}");
                }
            }
        }
    }

    #[test]
    fn backward_dev_matches_fresh() {
        // The device-resident backward (shared θ/A buffers) must reproduce
        // the fresh-upload gradients bit-exactly.
        let Some(rt) = runtime() else { return };
        let params = Params::init(32, &mut Pcg32::seeded(51));
        let (onehot, targets) = make_targets(8, 24, 52);
        for p in [1usize, 2] {
            let part = Partition::new(24, p);
            let mut shards = batch_shards(part, 8, 50);
            let cfg = EngineCfg::new(p, 2);
            let fwd = forward(&rt, &cfg, &params, &shards, true, false).unwrap();
            let acts = fwd.acts.as_ref().unwrap();
            let fresh = backward(&rt, &cfg, &params, &shards, acts, &onehot, &targets).unwrap();
            let dev = crate::coordinator::fwd::DeviceState::new(&rt, &params, &mut shards).unwrap();
            let res = super::backward_dev(
                &rt, &cfg, &params, &shards, acts, &onehot, &targets, Some(&dev),
            )
            .unwrap();
            assert_eq!(res.loss, fresh.loss, "P={p} loss diverges");
            assert_eq!(res.grads, fresh.grads, "P={p} grads diverge");
        }
    }

    /// Sparse twin of `batch_shards` (same seed → same graphs/states).
    fn batch_sparse_shards(
        rt: &Runtime,
        part: Partition,
        b: usize,
        seed: u64,
    ) -> Option<Vec<SparseShard>> {
        let Ok((chunk, caps)) = rt.manifest.sparse_config(b, part.ni(), 32) else {
            eprintln!("skipping: sparse train artifacts not compiled");
            return None;
        };
        let mut rng = Pcg32::seeded(seed);
        let graphs: Vec<_> = (0..b).map(|_| generators::erdos_renyi(20, 0.25, &mut rng)).collect();
        let grefs: Vec<&crate::graph::Graph> = graphs.iter().collect();
        let removed: Vec<Vec<bool>> = graphs.iter().map(|g| vec![false; g.n]).collect();
        let sol = removed.clone();
        let cand: Vec<Vec<bool>> = graphs
            .iter()
            .map(|g| (0..g.n).map(|v| g.degree(v) > 0).collect())
            .collect();
        Some(
            (0..part.p)
                .map(|i| {
                    SparseShard::from_graphs(
                        part,
                        i,
                        &grefs,
                        &removed.iter().map(|r| r.as_slice()).collect::<Vec<_>>(),
                        &sol.iter().map(|r| r.as_slice()).collect::<Vec<_>>(),
                        &cand.iter().map(|r| r.as_slice()).collect::<Vec<_>>(),
                        chunk,
                        &caps,
                    )
                })
                .collect(),
        )
    }

    #[test]
    fn sparse_backward_matches_dense() {
        // Sparse-path gradients must match the dense oracle to fp tolerance
        // (the tile scatter's summation order differs from the matmul's),
        // and the device-resident sparse backward must be bit-exact vs the
        // fresh sparse backward.
        let Some(rt) = runtime() else { return };
        let params = Params::init(32, &mut Pcg32::seeded(61));
        let (onehot, targets) = make_targets(8, 24, 62);
        for p in [1usize, 2] {
            let part = Partition::new(24, p);
            let dense = batch_shards(part, 8, 60);
            let Some(mut sparse) = batch_sparse_shards(&rt, part, 8, 60) else { return };
            let cfg = EngineCfg::new(p, 2);
            let fwd_d = forward(&rt, &cfg, &params, &dense, true, false).unwrap();
            let want = backward(&rt, &cfg, &params, &dense, fwd_d.acts.as_ref().unwrap(),
                                &onehot, &targets).unwrap();
            let fwd_s = crate::coordinator::fwd::forward_sparse(
                &rt, &cfg, &params, &sparse, true, false, None,
            )
            .unwrap();
            let acts_s = fwd_s.acts.as_ref().unwrap();
            let got =
                backward_sparse(&rt, &cfg, &params, &sparse, acts_s, &onehot, &targets, None)
                    .unwrap();
            assert!((got.loss - want.loss).abs() < 1e-4, "P={p} loss diverges");
            let d = crate::util::max_abs_diff(&got.grads, &want.grads);
            assert!(d < 1e-3, "P={p} sparse grads diverge from dense by {d}");

            let dev = SparseDeviceState::new(&rt, &params, &mut sparse).unwrap();
            let res = backward_sparse(
                &rt, &cfg, &params, &sparse, acts_s, &onehot, &targets, Some(&dev),
            )
            .unwrap();
            assert_eq!(res.loss, got.loss, "P={p} resident sparse loss diverges");
            assert_eq!(res.grads, got.grads, "P={p} resident sparse grads diverge");
        }
    }

    #[test]
    fn finite_difference_check() {
        // Directional finite-difference on a few random coordinates.
        let Some(rt) = runtime() else { return };
        let mut params = Params::init(32, &mut Pcg32::seeded(31));
        let part = Partition::new(24, 2);
        let shards = batch_shards(part, 8, 30);
        let cfg = EngineCfg::new(2, 2);
        let (onehot, targets) = make_targets(8, 24, 32);

        let loss_of = |params: &Params| -> f32 {
            let fwd = forward(&rt, &cfg, params, &shards, true, false).unwrap();
            let out = backward(&rt, &cfg, params, &shards, fwd.acts.as_ref().unwrap(),
                               &onehot, &targets).unwrap();
            out.loss
        };
        let fwd = forward(&rt, &cfg, &params, &shards, true, false).unwrap();
        let out = backward(&rt, &cfg, &params, &shards, fwd.acts.as_ref().unwrap(),
                           &onehot, &targets).unwrap();

        let mut rng = Pcg32::seeded(33);
        let eps = 1e-3f32;
        for _ in 0..6 {
            let idx = rng.gen_range(params.flat.len());
            let orig = params.flat[idx];
            params.flat[idx] = orig + eps;
            let lp = loss_of(&params);
            params.flat[idx] = orig - eps;
            let lm = loss_of(&params);
            params.flat[idx] = orig;
            let fd = (lp - lm) / (2.0 * eps);
            let an = out.grads[idx];
            let denom = fd.abs().max(an.abs()).max(1e-3);
            assert!(
                (fd - an).abs() / denom < 0.08,
                "idx {idx}: fd {fd} vs analytic {an}"
            );
        }
    }

    #[test]
    fn training_step_reduces_loss() {
        let Some(rt) = runtime() else { return };
        let mut params = Params::init(32, &mut Pcg32::seeded(41));
        let part = Partition::new(24, 1);
        let shards = batch_shards(part, 8, 40);
        let cfg = EngineCfg::new(1, 2);
        let (onehot, targets) = make_targets(8, 24, 42);
        let mut adam = crate::model::Adam::new(1e-2, params.flat.len());
        let mut losses = Vec::new();
        for _ in 0..20 {
            let fwd = forward(&rt, &cfg, &params, &shards, true, false).unwrap();
            let out = backward(&rt, &cfg, &params, &shards, fwd.acts.as_ref().unwrap(),
                               &onehot, &targets).unwrap();
            losses.push(out.loss);
            adam.step(&mut params.flat, &out.grads);
        }
        assert!(
            losses[19] < losses[0] * 0.5,
            "loss did not halve: {:?} -> {:?}",
            losses[0],
            losses[19]
        );
    }
}
