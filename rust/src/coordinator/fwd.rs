//! Distributed forward pass: Alg. 2 (embedding) + Alg. 3 (action scores)
//! orchestrated over P shards, with Rust-side collectives between the AOT
//! stage programs. Mirrors python/tests/dist_sim.py `dist_forward` exactly.
//!
//! Two execution modes share the math (DESIGN.md §6):
//!
//! - **Fresh-upload** (`forward`, no `DeviceState`): every stage input is
//!   uploaded from host per evaluation — stateless and simple; the
//!   golden/parity tests use it as the reference path.
//! - **Device-resident** (`forward_dev` with a [`DeviceState`]): θ and each
//!   shard's adjacency live on device across steps (uploaded once per
//!   solve, then patched on device by the `a_mask` stage from `ShardState`
//!   dirty deltas), `pre` stays on device across all L layers, and the
//!   embedding chains stage-to-stage on device. Host round-trips remain
//!   only at the collectives (all-reduce / all-gather) and the final score
//!   fetch — and at P = 1 even those are elided, because the all-reduce of
//!   one shard's partial is the identity and its column slice is the whole
//!   tensor. Scores are bit-identical to the fresh-upload path (asserted
//!   by rust/tests/device_state.rs).

use super::engine::{EngineCfg, StepTiming};
use super::shard::{ShardSet, ShardState, SparseShard};
use crate::model::Params;
use crate::runtime::{artifact_name, sparse_msg_name, sparse_pre_name, HostTensor, Input, Runtime};
use crate::util::add_assign;
use anyhow::Result;
use std::cell::RefCell;
use std::rc::Rc;
use std::time::Instant;

/// Saved activations for the backward pass (per shard / per layer).
#[derive(Debug, Clone)]
pub struct Activations {
    /// Stage-1 output pre^i, per shard, each B*K*NI.
    pub pre: Vec<Vec<f32>>,
    /// Embedding input per layer per shard (embed_{l-1}), B*K*NI.
    pub embed_in: Vec<Vec<Vec<f32>>>,
    /// Local slice of the all-reduced message per layer per shard, B*K*NI.
    pub nbr_slice: Vec<Vec<Vec<f32>>>,
    /// Final embedding per shard, B*K*NI.
    pub embed_final: Vec<Vec<f32>>,
    /// All-reduced embedding sum, B*K.
    pub sum_all: Vec<f32>,
    /// Per-shard local scores, B*NI.
    pub scores_i: Vec<Vec<f32>>,
}

/// Forward output: gathered scores plus timing (and activations if saved).
#[derive(Debug)]
pub struct FwdOutput {
    /// Gathered scores, B*N (node-major within each graph).
    pub scores: Vec<f32>,
    /// Saved activations (present when `save` was set).
    pub acts: Option<Activations>,
    /// Accumulated lockstep timing of this evaluation.
    pub timing: StepTiming,
}

/// A θ-residency handle that OUTLIVES any one `DeviceState`: a stable
/// keyed-cache namespace plus a content generation for the current
/// parameters. Successive device states built with `new_in(.., Some(cache))`
/// upload θ through this namespace, so the runtime serves the buffers from
/// cache (no transfer) for every solve after the first — the warm-service
/// optimization (`service::Service` holds one per session; DESIGN.md §8).
/// The owner must call [`ThetaCache::evict`] when done (device-state drops
/// deliberately leave the shared namespace resident).
#[derive(Debug, Clone)]
pub struct ThetaCache {
    /// Keyed-cache prefix (`tc<id>/`), disjoint from every `ds<id>/` /
    /// `sds<id>/` device-state namespace.
    prefix: String,
    /// Content generation of the host parameters last published here.
    generation: u64,
}

impl ThetaCache {
    /// Allocate a fresh θ namespace on `rt`. Nothing is uploaded yet; the
    /// first `DeviceState`/`SparseDeviceState` built against the cache pays
    /// the upload, later ones hit the keyed cache.
    pub fn new(rt: &Runtime) -> ThetaCache {
        ThetaCache { prefix: format!("tc{}/", rt.alloc_state_id()), generation: 0 }
    }

    /// Current content generation.
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// Keyed-cache key of θ tensor `i` in this namespace. The rank-parallel
    /// workers pre-publish θ through these keys when parameters change, so
    /// device states built against the cache hit without a transfer.
    pub(crate) fn theta_key(&self, i: usize) -> String {
        format!("{}theta{i}", self.prefix)
    }

    /// Invalidate after the host parameters change: the next device state
    /// built against the cache re-uploads θ instead of hitting stale
    /// buffers.
    pub fn bump(&mut self) {
        self.generation += 1;
    }

    /// Drop the cached θ buffers from the runtime (owner teardown).
    pub fn evict(&self, rt: &Runtime) {
        rt.evict_keyed(&self.prefix);
    }
}

/// Persistent device residency for one solve: θ and the per-shard
/// adjacency uploaded once, then kept in sync with the host `ShardState`s
/// by delta patching (see `sync`). Buffers are registered in the runtime's
/// keyed, generation-tracked cache under an exclusive `ds<id>/` namespace
/// and evicted on drop — except θ built against a shared [`ThetaCache`],
/// which stays resident for the cache's owner.
pub struct DeviceState<'r> {
    rt: &'r Runtime,
    id: u64,
    /// Content generation of the A buffers: bumped on every re-upload or
    /// on-device patch so the keyed cache never serves a stale copy.
    gen_a: u64,
    /// θ key prefix: the private `ds<id>/` namespace, or a shared
    /// [`ThetaCache`] namespace that outlives this state.
    theta_prefix: String,
    theta_gen: u64,
    /// θ lives in the private namespace (shared-cache states must not
    /// `refresh_theta`; the cache owner bumps the generation instead).
    theta_private: bool,
    /// Batch size B of the resident shards.
    pub b: usize,
    /// Padded global node count N.
    pub n: usize,
    /// Shard height NI.
    pub ni: usize,
    k: usize,
    theta: Vec<Rc<xla::PjRtBuffer>>,
    a: Vec<Rc<xla::PjRtBuffer>>,
    /// Zeros block [B,K,NI]: layer-0 embedding input / elided-message slice.
    zero_e: Rc<xla::PjRtBuffer>,
    /// `a_mask` artifact for this shape when compiled; dirty shards fall
    /// back to a full A re-upload without it.
    mask_name: Option<String>,
    /// Simulated transfer seconds of the most recent upload operation
    /// (`new`/`rebuild`/`sync`/`refresh_theta`), max-aggregated across
    /// shards where per-device transfers overlap in the lockstep model —
    /// the same rule the fresh path applies to its per-shard A uploads.
    xfer_secs: f64,
    /// Reused B*K*N host scratch for the layer-message all-reduce (one
    /// allocation per solve instead of one per layer per step).
    scratch: RefCell<Vec<f32>>,
}

impl<'r> DeviceState<'r> {
    /// Upload θ and every shard's adjacency. `shards` must share one
    /// partition/batch shape (as built by `shards_for_graph`/`_pack`);
    /// any pending dirty deltas are cleared, since the upload captures the
    /// current host state.
    pub fn new(
        rt: &'r Runtime,
        params: &Params,
        shards: &mut [ShardState],
    ) -> Result<DeviceState<'r>> {
        DeviceState::new_in(rt, params, shards, None)
    }

    /// Like [`DeviceState::new`], but θ goes through `theta` when given: a
    /// shared, service-owned namespace the keyed cache serves without a
    /// transfer once warm (the cold/warm h2d delta `rust/tests/service.rs`
    /// asserts).
    pub fn new_in(
        rt: &'r Runtime,
        params: &Params,
        shards: &mut [ShardState],
        theta_cache: Option<&ThetaCache>,
    ) -> Result<DeviceState<'r>> {
        assert!(!shards.is_empty(), "DeviceState needs at least one shard");
        let (b, n, ni, k) = (shards[0].b, shards[0].n(), shards[0].ni(), params.k);
        let id = rt.alloc_state_id();
        let (theta_prefix, theta_gen, theta_private) = match theta_cache {
            Some(c) => (c.prefix.clone(), c.generation, false),
            None => (format!("ds{id}/"), 0, true),
        };
        let t_theta = Instant::now();
        let mut theta = Vec::with_capacity(7);
        for i in 0..7 {
            theta.push(rt.upload_keyed(
                &format!("{theta_prefix}theta{i}"),
                theta_gen,
                &params.theta_dims(i),
                params.theta(i),
            )?);
        }
        let theta_secs = t_theta.elapsed().as_secs_f64();
        let (a, zero_e, mask_name, state_secs) =
            upload_shard_state(rt, id, 0, b, n, ni, k, shards)?;
        Ok(DeviceState {
            rt,
            id,
            gen_a: 0,
            theta_prefix,
            theta_gen,
            theta_private,
            b,
            n,
            ni,
            k,
            theta,
            a,
            zero_e,
            mask_name,
            xfer_secs: theta_secs + state_secs,
            scratch: RefCell::new(Vec::new()),
        })
    }

    /// Simulated transfer seconds of the most recent upload operation
    /// (`new`/`rebuild`/`sync`/`refresh_theta`) — what the solve loops book
    /// into `StepTiming::h2d` and their simulated totals.
    pub fn last_transfer_secs(&self) -> f64 {
        self.xfer_secs
    }

    /// The `forward_dev`/`backward_dev` precondition: the device buffers
    /// match these shards' shape and carry no un-synced deltas (a stale
    /// device adjacency would silently produce wrong scores/gradients).
    /// θ staleness is a caller contract instead — call `refresh_theta`
    /// after every optimizer step (train.rs tracks this with its
    /// `theta_stale` flag); verifying θ content here would hash ~4K²
    /// floats on every evaluation.
    pub fn assert_in_sync(&self, shards: &[ShardState]) {
        assert_eq!(shards.len(), self.a.len(), "shard count mismatch");
        let want = (shards[0].b, shards[0].n(), shards[0].ni());
        let got = (self.b, self.n, self.ni);
        assert_eq!(got, want, "DeviceState shape mismatch (rebuild after repack)");
        for sh in shards {
            assert!(!sh.is_dirty(), "un-synced shard deltas; call DeviceState::sync first");
        }
    }

    /// Re-upload θ after an optimizer step (the device copy must track the
    /// host parameters; A is untouched — minibatch state does not change
    /// across the τ repeated gradient iterations). Only valid on a private
    /// θ namespace: states built against a shared [`ThetaCache`] never
    /// change parameters (the cache owner bumps the generation instead),
    /// and a local bump here would silently desync the owner's tracking.
    pub fn refresh_theta(&mut self, params: &Params) -> Result<()> {
        assert_eq!(params.k, self.k, "embedding dim changed");
        assert!(
            self.theta_private,
            "refresh_theta on a shared ThetaCache namespace; bump the cache and rebuild instead"
        );
        let t0 = Instant::now();
        self.theta_gen += 1;
        for i in 0..7 {
            self.theta[i] = self.rt.upload_keyed(
                &format!("{}theta{i}", self.theta_prefix),
                self.theta_gen,
                &params.theta_dims(i),
                params.theta(i),
            )?;
        }
        self.xfer_secs = t0.elapsed().as_secs_f64();
        Ok(())
    }

    /// Explicit invalidation + rebuild from freshly built shards — what a
    /// compaction repack must do: the batch capacity (and with it every
    /// buffer shape) may have changed, so all per-shard buffers are
    /// re-uploaded at a new generation. θ is kept (repacks do not change
    /// parameters), which the keyed cache serves without an upload.
    pub fn rebuild(&mut self, shards: &mut [ShardState]) -> Result<()> {
        assert_eq!(shards.len(), self.a.len(), "shard count (P) cannot change");
        self.gen_a += 1;
        self.b = shards[0].b;
        self.n = shards[0].n();
        self.ni = shards[0].ni();
        let (a, zero_e, mask_name, state_secs) = upload_shard_state(
            self.rt, self.id, self.gen_a, self.b, self.n, self.ni, self.k, shards,
        )?;
        self.a = a;
        self.zero_e = zero_e;
        self.mask_name = mask_name;
        self.xfer_secs = state_secs;
        self.scratch.borrow_mut().clear();
        Ok(())
    }

    /// Push recorded host-side A deltas to the device copies. Dirty shards
    /// are patched *on device* by the `a_mask` stage — the upload is two
    /// small mask vectors (B·NI + B·N floats) instead of the full B·NI·N
    /// adjacency; masking is exact because removal only ever zeroes rows
    /// and columns. Without a compiled `a_mask` for this shape the shard
    /// falls back to a full re-upload. Call after applying selections and
    /// before the next `forward_dev`.
    pub fn sync(&mut self, shards: &mut [ShardState]) -> Result<()> {
        assert_eq!(shards.len(), self.a.len(), "shard count changed; rebuild instead");
        let (b, n, ni) = (self.b, self.n, self.ni);
        let mut slowest = 0.0f64;
        for (i, sh) in shards.iter_mut().enumerate() {
            assert_eq!((sh.b, sh.n(), sh.ni()), (b, n, ni), "shape changed; rebuild instead");
            if !sh.is_dirty() {
                continue;
            }
            let t_shard = Instant::now();
            let (rows, cols) = sh.take_dirty();
            let key = format!("ds{}/a{i}", self.id);
            self.gen_a += 1;
            if let Some(name) = &self.mask_name {
                let mut row_mask = vec![1.0f32; b * ni];
                for (g, r) in rows {
                    row_mask[g as usize * ni + r as usize] = 0.0;
                }
                let mut col_mask = vec![1.0f32; b * n];
                for (g, v) in cols {
                    col_mask[g as usize * n + v as usize] = 0.0;
                }
                let out = self.rt.execute_d(
                    name,
                    &[
                        Input::Dev(&self.a[i]),
                        Input::Host(HostTensor::new(&[b, ni], &row_mask)),
                        Input::Host(HostTensor::new(&[b, n], &col_mask)),
                    ],
                )?;
                let buf = out.into_iter().next().unwrap();
                self.a[i] = self.rt.put_keyed(&key, self.gen_a, &[b, ni, n], buf);
            } else {
                self.a[i] = self.rt.upload_keyed(&key, self.gen_a, &[b, ni, n], &sh.a)?;
            }
            // Per-device patches overlap in the simulated-parallel model:
            // the step pays the slowest shard's patch, not the sum.
            slowest = slowest.max(t_shard.elapsed().as_secs_f64());
        }
        self.xfer_secs = slowest;
        Ok(())
    }
}

/// Fresh-path adjacency upload: one owned device buffer per shard, the
/// slowest shard's upload booked as the step's transfer time (per-device
/// uploads overlap in the simulated-parallel model). Shared by the forward
/// and backward orchestrators so their accounting cannot diverge.
pub(crate) fn upload_a_fresh(
    rt: &Runtime,
    shards: &[ShardState],
    d_a: &[usize],
    timing: &mut StepTiming,
) -> Result<Vec<xla::PjRtBuffer>> {
    let mut owned = Vec::with_capacity(shards.len());
    let mut slowest = 0.0f64;
    for sh in shards.iter() {
        let t0 = Instant::now();
        owned.push(rt.upload(d_a, &sh.a)?);
        slowest = slowest.max(t0.elapsed().as_secs_f64());
    }
    timing.h2d += slowest;
    Ok(owned)
}

impl DeviceState<'_> {
    /// Device adjacency buffer of shard `i` (shared with the backward pass).
    pub(crate) fn a_buf(&self, i: usize) -> &xla::PjRtBuffer {
        &self.a[i]
    }

    /// The 7 resident θ buffers (feeds [`ThetaViews`]).
    pub(crate) fn theta_bufs(&self) -> &[Rc<xla::PjRtBuffer>] {
        &self.theta
    }

    /// The resident zeros block [B,K,NI] (layer-0 embedding input — shared
    /// with the rank-parallel worker forward).
    pub(crate) fn zero_buf(&self) -> &xla::PjRtBuffer {
        &self.zero_e
    }
}

impl Drop for DeviceState<'_> {
    fn drop(&mut self) {
        self.rt.evict_keyed(&format!("ds{}/", self.id));
    }
}

/// Upload A per shard plus the shared zeros block; resolve the `a_mask`
/// artifact for this shape. The returned seconds are the simulated
/// parallel transfer time: per-device A uploads overlap, so it is the
/// slowest shard's upload plus the (replicated) zeros block.
#[allow(clippy::too_many_arguments, clippy::type_complexity)]
fn upload_shard_state(
    rt: &Runtime,
    id: u64,
    generation: u64,
    b: usize,
    n: usize,
    ni: usize,
    k: usize,
    shards: &mut [ShardState],
) -> Result<(Vec<Rc<xla::PjRtBuffer>>, Rc<xla::PjRtBuffer>, Option<String>, f64)> {
    let mut a = Vec::with_capacity(shards.len());
    let mut slowest = 0.0f64;
    for (i, sh) in shards.iter_mut().enumerate() {
        assert_eq!((sh.b, sh.n(), sh.ni()), (b, n, ni), "mixed shard shapes");
        let t0 = Instant::now();
        a.push(rt.upload_keyed(&format!("ds{id}/a{i}"), generation, &[b, ni, n], &sh.a)?);
        slowest = slowest.max(t0.elapsed().as_secs_f64());
        // The upload captures the current host A; pending deltas are stale.
        sh.clear_dirty();
    }
    let t_zero = Instant::now();
    let zeros = vec![0.0f32; b * k * ni];
    let zero_e = rt.upload_keyed(&format!("ds{id}/zero"), generation, &[b, k, ni], &zeros)?;
    let secs = slowest + t_zero.elapsed().as_secs_f64();
    let mask = artifact_name("a_mask", b, n, ni, k);
    let mask_name = rt.manifest.has(&mask).then_some(mask);
    Ok((a, zero_e, mask_name, secs))
}

/// θ stage inputs: device-resident buffers when a device state (dense
/// [`DeviceState`] or sparse [`SparseDeviceState`]) is active, per-call
/// host tensors otherwise. Shared by the forward and backward
/// orchestrators of both storage modes.
pub(crate) struct ThetaViews<'p> {
    params: &'p Params,
    dims: Vec<Vec<usize>>,
    dev: Option<&'p [Rc<xla::PjRtBuffer>]>,
}

impl<'p> ThetaViews<'p> {
    pub(crate) fn new(
        params: &'p Params,
        dev: Option<&'p [Rc<xla::PjRtBuffer>]>,
    ) -> ThetaViews<'p> {
        ThetaViews { params, dims: (0..7).map(|i| params.theta_dims(i)).collect(), dev }
    }
    pub(crate) fn t(&self, idx: usize) -> Input<'_> {
        match self.dev {
            Some(theta) => Input::Dev(&theta[idx]),
            None => Input::Host(HostTensor::new(&self.dims[idx], self.params.theta(idx))),
        }
    }
}

/// Run the distributed policy evaluation on the fresh-upload path. `save`
/// keeps activations for the backward pass. When `skip_zero_layer` is set,
/// layer 0's message stage is elided (its input embedding is the zeros
/// constant of Alg. 2 line 3), a perf optimization logged in
/// EXPERIMENTS.md §Perf.
pub fn forward(
    rt: &Runtime,
    cfg: &EngineCfg,
    params: &Params,
    shards: &[ShardState],
    save: bool,
    skip_zero_layer: bool,
) -> Result<FwdOutput> {
    forward_dev(rt, cfg, params, shards, save, skip_zero_layer, None)
}

/// `forward` with optional device residency: pass a [`DeviceState`] (kept
/// in sync via `DeviceState::sync`) to skip the per-evaluation θ/A uploads
/// and keep intermediate activations on device.
pub fn forward_dev(
    rt: &Runtime,
    cfg: &EngineCfg,
    params: &Params,
    shards: &[ShardState],
    save: bool,
    skip_zero_layer: bool,
    dev: Option<&DeviceState>,
) -> Result<FwdOutput> {
    let wall = Instant::now();
    let p = shards.len();
    assert_eq!(p, cfg.p, "shard count != cfg.p");
    let (b, n, ni, k) = (shards[0].b, shards[0].n(), shards[0].ni(), params.k);
    let resident = dev.is_some();
    if let Some(d) = dev {
        d.assert_in_sync(shards);
    }
    let mut timing = StepTiming::new(p);
    let th = ThetaViews::new(params, dev.map(|d| d.theta_bufs()));

    let d_s = [b, ni];
    let d_a = [b, ni, n];
    let d_e = [b, k, ni];
    let d_sum = [b, k];

    let exec = |shard: usize, name: &str, inputs: &[Input], timing: &mut StepTiming| {
        let t0 = Instant::now();
        let out = rt.execute_in(name, inputs);
        timing.compute[shard] += t0.elapsed().as_secs_f64();
        out
    };
    let exec_d = |shard: usize, name: &str, inputs: &[Input], timing: &mut StepTiming| {
        let t0 = Instant::now();
        let out = rt.execute_d(name, inputs);
        timing.compute[shard] += t0.elapsed().as_secs_f64();
        out
    };
    let fetch = |shard: usize, buf: &xla::PjRtBuffer, timing: &mut StepTiming| {
        let t0 = Instant::now();
        let out = rt.fetch(buf);
        timing.compute[shard] += t0.elapsed().as_secs_f64();
        out
    };

    // §Perf: the adjacency either lives on device across steps
    // (DeviceState) or is uploaded once per evaluation and shared by every
    // stage that reads it; the upload is booked as transfer time, not
    // compute, so bench JSON can separate the two.
    let a_owned: Vec<xla::PjRtBuffer> = if dev.is_none() {
        upload_a_fresh(rt, shards, &d_a, &mut timing)?
    } else {
        Vec::new()
    };
    let a_refs: Vec<&xla::PjRtBuffer> = match dev {
        Some(d) => d.a.iter().map(|buf| &**buf).collect(),
        None => a_owned.iter().collect(),
    };

    // Stage 1: pre^i (layer-independent terms). Device-resident across all
    // L layers on the resident path; host vectors on the fresh path (and
    // when activations are saved for the backward pass).
    let name_pre = artifact_name("embed_pre", b, n, ni, k);
    let mut pre_d: Vec<xla::PjRtBuffer> = Vec::new();
    let mut pre_h: Vec<Vec<f32>> = Vec::new();
    for (i, sh) in shards.iter().enumerate() {
        let inputs = [
            th.t(0),
            th.t(1),
            th.t(2),
            Input::Host(HostTensor::new(&d_s, &sh.s)),
            Input::Dev(a_refs[i]),
        ];
        if resident {
            let buf = exec_d(i, &name_pre, &inputs, &mut timing)?.into_iter().next().unwrap();
            if save {
                pre_h.push(fetch(i, &buf, &mut timing)?);
            }
            pre_d.push(buf);
        } else {
            pre_h.push(exec(i, &name_pre, &inputs, &mut timing)?.into_iter().next().unwrap());
        }
    }

    // Embedding layers (Alg. 2 lines 9-15). At P = 1 on the resident path
    // (inference only — the backward pass needs host activations) the
    // collective is an identity, so the message chains straight into the
    // combine stage without leaving the device.
    let chain = resident && !save && p == 1;
    let mut embed_d: Vec<Option<xla::PjRtBuffer>> = (0..p).map(|_| None).collect();
    let mut embed_h: Vec<Vec<f32>> = if resident && !save {
        Vec::new()
    } else {
        (0..p).map(|_| vec![0.0f32; b * k * ni]).collect()
    };
    let mut embed_in: Vec<Vec<Vec<f32>>> = Vec::new();
    let mut nbr_slice_acts: Vec<Vec<Vec<f32>>> = Vec::new();
    let name_msg = artifact_name("embed_msg", b, n, ni, k);
    let name_cmb = artifact_name("embed_combine", b, n, ni, k);

    // One B*K*N all-reduce scratch per solve (DeviceState) or per call.
    let mut local_scratch: Vec<f32> = Vec::new();
    let mut dev_scratch;
    let nbr_full: &mut Vec<f32> = match dev {
        Some(d) => {
            dev_scratch = d.scratch.borrow_mut();
            &mut dev_scratch
        }
        None => &mut local_scratch,
    };
    if !chain {
        nbr_full.resize(b * k * n, 0.0);
    }

    for layer in 0..cfg.l {
        if save {
            embed_in.push(embed_h.clone());
        }
        let zero_input = layer == 0; // embed is the zeros constant
        let skip_msg = zero_input && skip_zero_layer;
        let mut msg_d: Option<xla::PjRtBuffer> = None;
        if !chain && !(skip_msg && resident) {
            nbr_full.fill(0.0);
        }
        if !skip_msg {
            // Stage 2 per shard + ALL-REDUCE (line 12).
            for i in 0..p {
                let embed_input = if resident {
                    if zero_input {
                        Input::Dev(&dev.unwrap().zero_e)
                    } else {
                        Input::Dev(embed_d[i].as_ref().unwrap())
                    }
                } else {
                    Input::Host(HostTensor::new(&d_e, &embed_h[i]))
                };
                let inputs = [embed_input, Input::Dev(a_refs[i])];
                if chain {
                    msg_d = Some(exec_d(i, &name_msg, &inputs, &mut timing)?
                        .into_iter()
                        .next()
                        .unwrap());
                } else {
                    let part = if resident {
                        let buf =
                            exec_d(i, &name_msg, &inputs, &mut timing)?.into_iter().next().unwrap();
                        fetch(i, &buf, &mut timing)?
                    } else {
                        exec(i, &name_msg, &inputs, &mut timing)?.into_iter().next().unwrap()
                    };
                    let t_host = Instant::now();
                    add_assign(nbr_full, &part);
                    timing.host += t_host.elapsed().as_secs_f64();
                }
            }
            timing.add_comm(cfg.cost.all_reduce(p, 4 * b * k * n), 4 * b * k * n);
        }
        // Local column slice + Stage 3 per shard. An elided layer-0 message
        // on the resident path uses the device zeros block directly — no
        // host slicing/uploading of all-zero tensors (bit-exact: the slice
        // would be zeros); the host copies survive only for saved acts.
        let zero_nbr = resident && skip_msg;
        let mut nbr_slices: Vec<Vec<f32>> = Vec::new();
        if zero_nbr {
            if save {
                nbr_slices = (0..p).map(|_| vec![0.0f32; b * k * ni]).collect();
            }
        } else if !chain {
            let t_host = Instant::now();
            for sh in shards.iter() {
                let row0 = sh.part.row0(sh.shard);
                let mut sl = vec![0.0f32; b * k * ni];
                for g in 0..b {
                    for kk in 0..k {
                        let src = g * k * n + kk * n + row0;
                        let dst = g * k * ni + kk * ni;
                        sl[dst..dst + ni].copy_from_slice(&nbr_full[src..src + ni]);
                    }
                }
                nbr_slices.push(sl);
            }
            timing.host += t_host.elapsed().as_secs_f64();
        }
        for i in 0..p {
            let nbr_input = if zero_nbr {
                Input::Dev(&dev.unwrap().zero_e)
            } else if chain {
                match &msg_d {
                    Some(m) => Input::Dev(m),
                    // Elided layer-0 message: the slice is all zeros (and
                    // at P = 1, [B,K,N] == [B,K,NI]).
                    None => Input::Dev(&dev.unwrap().zero_e),
                }
            } else {
                Input::Host(HostTensor::new(&d_e, &nbr_slices[i]))
            };
            let pre_input = if resident {
                Input::Dev(&pre_d[i])
            } else {
                Input::Host(HostTensor::new(&d_e, &pre_h[i]))
            };
            let inputs = [th.t(3), pre_input, nbr_input];
            if resident {
                let buf = exec_d(i, &name_cmb, &inputs, &mut timing)?.into_iter().next().unwrap();
                if save {
                    embed_h[i] = fetch(i, &buf, &mut timing)?;
                }
                embed_d[i] = Some(buf);
            } else {
                embed_h[i] = exec(i, &name_cmb, &inputs, &mut timing)?.into_iter().next().unwrap();
            }
        }
        if save {
            nbr_slice_acts.push(nbr_slices);
        }
    }

    // Final-embedding inputs shared by stages 4 and 5 (the resident path's
    // zeros-block fallback covers the L = 0 degenerate case).
    let e_inputs: Vec<Input> = (0..p)
        .map(|i| {
            if resident {
                match &embed_d[i] {
                    Some(buf) => Input::Dev(buf),
                    None => Input::Dev(&dev.unwrap().zero_e),
                }
            } else {
                Input::Host(HostTensor::new(&d_e, &embed_h[i]))
            }
        })
        .collect();

    // Stage 4 + ALL-REDUCE (Alg. 3 lines 4-5).
    let name_qsum = artifact_name("q_sum", b, n, ni, k);
    let mut sum_all = vec![0.0f32; b * k];
    let mut sum_d: Option<xla::PjRtBuffer> = None;
    for i in 0..p {
        let inputs = [e_inputs[i]];
        if chain {
            sum_d = Some(exec_d(i, &name_qsum, &inputs, &mut timing)?
                .into_iter()
                .next()
                .unwrap());
        } else {
            let part = if resident {
                let buf = exec_d(i, &name_qsum, &inputs, &mut timing)?.into_iter().next().unwrap();
                fetch(i, &buf, &mut timing)?
            } else {
                exec(i, &name_qsum, &inputs, &mut timing)?.into_iter().next().unwrap()
            };
            let t_host = Instant::now();
            add_assign(&mut sum_all, &part);
            timing.host += t_host.elapsed().as_secs_f64();
        }
    }
    timing.add_comm(cfg.cost.all_reduce(p, 4 * b * k), 4 * b * k);

    // Stage 5 + ALL-GATHER of scores (Alg. 4 line 6).
    let name_q = artifact_name("q_scores", b, n, ni, k);
    let mut scores = vec![0.0f32; b * n];
    let mut scores_i: Vec<Vec<f32>> = Vec::with_capacity(p);
    for (i, sh) in shards.iter().enumerate() {
        let sum_input = match &sum_d {
            Some(sd) => Input::Dev(sd),
            None => Input::Host(HostTensor::new(&d_sum, &sum_all)),
        };
        let inputs = [
            th.t(4),
            th.t(5),
            th.t(6),
            e_inputs[i],
            Input::Host(HostTensor::new(&d_s, &sh.c)),
            sum_input,
        ];
        let local = if resident {
            let buf = exec_d(i, &name_q, &inputs, &mut timing)?.into_iter().next().unwrap();
            fetch(i, &buf, &mut timing)?
        } else {
            exec(i, &name_q, &inputs, &mut timing)?.into_iter().next().unwrap()
        };
        let t_host = Instant::now();
        let row0 = sh.part.row0(sh.shard);
        for g in 0..b {
            scores[g * n + row0..g * n + row0 + ni].copy_from_slice(&local[g * ni..(g + 1) * ni]);
        }
        timing.host += t_host.elapsed().as_secs_f64();
        scores_i.push(local);
    }
    timing.add_comm(cfg.cost.all_gather(p, 4 * b * ni), 4 * b * ni * p);
    drop(e_inputs); // releases the embed_h borrow before it moves into acts

    timing.wall = wall.elapsed().as_secs_f64();
    let acts = if save {
        Some(Activations {
            pre: pre_h,
            embed_in,
            nbr_slice: nbr_slice_acts,
            embed_final: embed_h,
            sum_all,
            scores_i,
        })
    } else {
        None
    };
    Ok(FwdOutput { scores, acts, timing })
}

/// Persistent device residency for one solve on the sparse path (DESIGN.md
/// §7): θ plus every shard's edge-tile tensors (src/dst indices uploaded
/// once — topology never changes within a pack — and the per-tile live
/// masks w re-uploaded only for tiles a removal actually touched). The
/// per-tile B×EC mask upload is the sparse analog of the dense path's
/// `a_mask` patch: a removal moves O(degree) small tensors instead of a
/// B×NI×N adjacency.
pub struct SparseDeviceState<'r> {
    rt: &'r Runtime,
    id: u64,
    /// Content generation of the tile buffers: bumped on every re-upload so
    /// the keyed cache never serves a stale mask.
    gen_w: u64,
    /// θ key prefix: the private `sds<id>/` namespace, or a shared
    /// [`ThetaCache`] namespace that outlives this state. Dense and sparse
    /// states built against the same cache share the same buffers — θ does
    /// not depend on the storage mode.
    theta_prefix: String,
    theta_gen: u64,
    theta_private: bool,
    /// Batch size B of the resident shards.
    pub b: usize,
    /// Padded global node count N.
    pub n: usize,
    /// Shard height NI.
    pub ni: usize,
    k: usize,
    chunk: usize,
    theta: Vec<Rc<xla::PjRtBuffer>>,
    /// Per shard, per tile: chunk-local source indices [EC] (shared with
    /// the backward orchestrator, hence crate-visible).
    pub(crate) src: Vec<Vec<Rc<xla::PjRtBuffer>>>,
    /// Per shard, per tile: chunk-local destination indices [EC].
    pub(crate) dst: Vec<Vec<Rc<xla::PjRtBuffer>>>,
    /// Per shard, per tile: live-edge mask [B,EC].
    pub(crate) w: Vec<Vec<Rc<xla::PjRtBuffer>>>,
    /// Simulated transfer seconds of the most recent upload operation
    /// (same max-across-shards rule as the dense `DeviceState`).
    xfer_secs: f64,
}

/// Upload every tile tensor of every shard under `sds<id>/t/` keys at
/// `generation`; returns (src, dst, w buffers, slowest-shard seconds).
/// Pending dirty deltas are cleared — the upload captures current state.
#[allow(clippy::type_complexity)]
fn upload_tile_state(
    rt: &Runtime,
    id: u64,
    generation: u64,
    shards: &mut [SparseShard],
) -> Result<(
    Vec<Vec<Rc<xla::PjRtBuffer>>>,
    Vec<Vec<Rc<xla::PjRtBuffer>>>,
    Vec<Vec<Rc<xla::PjRtBuffer>>>,
    f64,
)> {
    let b = shards[0].b;
    let mut src = Vec::with_capacity(shards.len());
    let mut dst = Vec::with_capacity(shards.len());
    let mut w = Vec::with_capacity(shards.len());
    let mut slowest = 0.0f64;
    for (i, sh) in shards.iter_mut().enumerate() {
        let t0 = Instant::now();
        let mut src_i = Vec::with_capacity(sh.tiles.len());
        let mut dst_i = Vec::with_capacity(sh.tiles.len());
        let mut w_i = Vec::with_capacity(sh.tiles.len());
        for (t, tile) in sh.tiles.iter().enumerate() {
            let cap = [tile.cap];
            let bcap = [b, tile.cap];
            src_i.push(rt.upload_keyed(&format!("sds{id}/t/{i}/{t}/src"), generation, &cap,
                                       &tile.src)?);
            dst_i.push(rt.upload_keyed(&format!("sds{id}/t/{i}/{t}/dst"), generation, &cap,
                                       &tile.dst)?);
            w_i.push(rt.upload_keyed(&format!("sds{id}/t/{i}/{t}/w"), generation, &bcap,
                                     &tile.w)?);
        }
        sh.clear_dirty();
        src.push(src_i);
        dst.push(dst_i);
        w.push(w_i);
        slowest = slowest.max(t0.elapsed().as_secs_f64());
    }
    Ok((src, dst, w, slowest))
}

impl<'r> SparseDeviceState<'r> {
    /// Upload θ and every shard's edge tiles. `shards` must share one
    /// partition/batch/chunk shape (as built by `sparse_shards_for_graph`/
    /// `_pack`).
    pub fn new(
        rt: &'r Runtime,
        params: &Params,
        shards: &mut [SparseShard],
    ) -> Result<SparseDeviceState<'r>> {
        SparseDeviceState::new_in(rt, params, shards, None)
    }

    /// Like [`SparseDeviceState::new`], but θ goes through a shared
    /// [`ThetaCache`] when given (see [`DeviceState::new_in`]).
    pub fn new_in(
        rt: &'r Runtime,
        params: &Params,
        shards: &mut [SparseShard],
        theta_cache: Option<&ThetaCache>,
    ) -> Result<SparseDeviceState<'r>> {
        assert!(!shards.is_empty(), "SparseDeviceState needs at least one shard");
        let (b, n, ni, k, chunk) =
            (shards[0].b, shards[0].n(), shards[0].ni(), params.k, shards[0].chunk);
        let id = rt.alloc_state_id();
        let (theta_prefix, theta_gen, theta_private) = match theta_cache {
            Some(c) => (c.prefix.clone(), c.generation, false),
            None => (format!("sds{id}/"), 0, true),
        };
        let t_theta = Instant::now();
        let mut theta = Vec::with_capacity(7);
        for i in 0..7 {
            theta.push(rt.upload_keyed(
                &format!("{theta_prefix}theta{i}"),
                theta_gen,
                &params.theta_dims(i),
                params.theta(i),
            )?);
        }
        let theta_secs = t_theta.elapsed().as_secs_f64();
        let (src, dst, w, tile_secs) = upload_tile_state(rt, id, 0, shards)?;
        Ok(SparseDeviceState {
            rt,
            id,
            gen_w: 0,
            theta_prefix,
            theta_gen,
            theta_private,
            b,
            n,
            ni,
            k,
            chunk,
            theta,
            src,
            dst,
            w,
            xfer_secs: theta_secs + tile_secs,
        })
    }

    /// The 7 resident θ buffers (feeds [`ThetaViews`]).
    pub(crate) fn theta_bufs(&self) -> &[Rc<xla::PjRtBuffer>] {
        &self.theta
    }

    /// Simulated transfer seconds of the most recent upload operation
    /// (`new`/`rebuild`/`sync`/`refresh_theta`).
    pub fn last_transfer_secs(&self) -> f64 {
        self.xfer_secs
    }

    /// The `forward_sparse` precondition: resident buffers match these
    /// shards' shape and tile counts, with no un-synced live-mask deltas.
    pub fn assert_in_sync(&self, shards: &[SparseShard]) {
        assert_eq!(shards.len(), self.w.len(), "shard count mismatch");
        let want = (shards[0].b, shards[0].n(), shards[0].ni(), shards[0].chunk);
        let got = (self.b, self.n, self.ni, self.chunk);
        assert_eq!(got, want, "SparseDeviceState shape mismatch (rebuild after repack)");
        for (i, sh) in shards.iter().enumerate() {
            assert_eq!(sh.tiles.len(), self.w[i].len(), "tile count changed; rebuild");
            assert!(!sh.is_dirty(), "un-synced live-mask deltas; call sync first");
        }
    }

    /// Re-upload θ after an optimizer step (tiles untouched). Only valid
    /// on a private θ namespace — see [`DeviceState::refresh_theta`].
    pub fn refresh_theta(&mut self, params: &Params) -> Result<()> {
        assert_eq!(params.k, self.k, "embedding dim changed");
        assert!(
            self.theta_private,
            "refresh_theta on a shared ThetaCache namespace; bump the cache and rebuild instead"
        );
        let t0 = Instant::now();
        self.theta_gen += 1;
        for i in 0..7 {
            self.theta[i] = self.rt.upload_keyed(
                &format!("{}theta{i}", self.theta_prefix),
                self.theta_gen,
                &params.theta_dims(i),
                params.theta(i),
            )?;
        }
        self.xfer_secs = t0.elapsed().as_secs_f64();
        Ok(())
    }

    /// Explicit invalidation + rebuild from freshly built shards (a
    /// compaction repack changes the batch capacity, edge set, and tile
    /// layout). θ is kept — repacks do not change parameters.
    pub fn rebuild(&mut self, shards: &mut [SparseShard]) -> Result<()> {
        assert_eq!(shards.len(), self.w.len(), "shard count (P) cannot change");
        self.rt.evict_keyed(&format!("sds{}/t/", self.id));
        self.gen_w += 1;
        self.b = shards[0].b;
        self.n = shards[0].n();
        self.ni = shards[0].ni();
        self.chunk = shards[0].chunk;
        let (src, dst, w, secs) = upload_tile_state(self.rt, self.id, self.gen_w, shards)?;
        self.src = src;
        self.dst = dst;
        self.w = w;
        self.xfer_secs = secs;
        Ok(())
    }

    /// Push recorded live-mask deltas to the device: re-upload w ([B,EC])
    /// for exactly the tiles a removal touched. Call after applying
    /// selections and before the next `forward_sparse`.
    pub fn sync(&mut self, shards: &mut [SparseShard]) -> Result<()> {
        assert_eq!(shards.len(), self.w.len(), "shard count changed; rebuild instead");
        let (b, n, ni) = (self.b, self.n, self.ni);
        let mut slowest = 0.0f64;
        for (i, sh) in shards.iter_mut().enumerate() {
            assert_eq!((sh.b, sh.n(), sh.ni()), (b, n, ni), "shape changed; rebuild instead");
            if !sh.is_dirty() {
                continue;
            }
            let t_shard = Instant::now();
            self.gen_w += 1;
            for t in sh.take_dirty_tiles() {
                let tile = &sh.tiles[t as usize];
                self.w[i][t as usize] = self.rt.upload_keyed(
                    &format!("sds{}/t/{i}/{t}/w", self.id),
                    self.gen_w,
                    &[b, tile.cap],
                    &tile.w,
                )?;
            }
            slowest = slowest.max(t_shard.elapsed().as_secs_f64());
        }
        self.xfer_secs = slowest;
        Ok(())
    }
}

impl Drop for SparseDeviceState<'_> {
    fn drop(&mut self) {
        self.rt.evict_keyed(&format!("sds{}/", self.id));
    }
}

/// Fresh-path edge-tile upload: one owned (src, dst, w) buffer triple per
/// tile per shard, uploaded once per evaluation (shared across all L
/// layers) with the slowest shard's upload booked as the step's transfer
/// time — the sparse twin of [`upload_a_fresh`], so dense-vs-sparse and
/// resident-vs-fresh `StepTiming::h2d` comparisons stay like-for-like.
/// Shared by the forward and backward orchestrators.
#[allow(clippy::type_complexity)]
pub(crate) fn upload_tiles_fresh(
    rt: &Runtime,
    shards: &[SparseShard],
    timing: &mut StepTiming,
) -> Result<Vec<Vec<(xla::PjRtBuffer, xla::PjRtBuffer, xla::PjRtBuffer)>>> {
    let mut owned = Vec::with_capacity(shards.len());
    let mut slowest = 0.0f64;
    for sh in shards.iter() {
        let t0 = Instant::now();
        let mut per = Vec::with_capacity(sh.tiles.len());
        for tile in &sh.tiles {
            per.push((
                rt.upload(&[tile.cap], &tile.src)?,
                rt.upload(&[tile.cap], &tile.dst)?,
                rt.upload(&[sh.b, tile.cap], &tile.w)?,
            ));
        }
        slowest = slowest.max(t0.elapsed().as_secs_f64());
        owned.push(per);
    }
    timing.h2d += slowest;
    Ok(owned)
}

/// Run the distributed policy evaluation on the sparse CSR path (DESIGN.md
/// §7): `embed_pre_sp` consumes the live-degree vector, each layer's
/// message is a sweep of `embed_msg_sp` gather/segment-sum tiles
/// accumulated into the B×K×N all-reduce scratch, and the N-free
/// combine/q_sum/q_scores stages are shared with the dense path.
/// python/tests/dist_sim.py `dist_forward_sparse` is the executable
/// specification. Pass a [`SparseDeviceState`] (kept in sync via its
/// `sync`) to keep θ and the edge tensors device-resident across steps.
pub fn forward_sparse(
    rt: &Runtime,
    cfg: &EngineCfg,
    params: &Params,
    shards: &[SparseShard],
    save: bool,
    skip_zero_layer: bool,
    dev: Option<&SparseDeviceState>,
) -> Result<FwdOutput> {
    let wall = Instant::now();
    let p = shards.len();
    assert_eq!(p, cfg.p, "shard count != cfg.p");
    let (b, n, ni, k) = (shards[0].b, shards[0].n(), shards[0].ni(), params.k);
    let chunk = shards[0].chunk;
    for sh in shards {
        assert_eq!((sh.b, sh.n(), sh.ni(), sh.chunk), (b, n, ni, chunk), "mixed shard shapes");
    }
    if let Some(d) = dev {
        d.assert_in_sync(shards);
    }
    let mut timing = StepTiming::new(p);
    let th = ThetaViews::new(params, dev.map(|d| d.theta_bufs()));

    let d_s = [b, ni];
    let d_e = [b, k, ni];
    let d_ec = [b, k, chunk];
    let d_sum = [b, k];

    let exec = |shard: usize, name: &str, inputs: &[Input], timing: &mut StepTiming| {
        let t0 = Instant::now();
        let out = rt.execute_in(name, inputs);
        timing.compute[shard] += t0.elapsed().as_secs_f64();
        out
    };

    // §Perf: the edge tiles either live on device across steps
    // (SparseDeviceState) or are uploaded once per evaluation, shared by
    // every layer's tile sweep, and booked as transfer time — mirroring
    // the dense path's per-evaluation A upload accounting.
    let tile_owned: Vec<Vec<(xla::PjRtBuffer, xla::PjRtBuffer, xla::PjRtBuffer)>> =
        if dev.is_none() { upload_tiles_fresh(rt, shards, &mut timing)? } else { Vec::new() };

    // Stage 1: embed_pre_sp(θ1..θ3, S, deg) — the degree vector replaces
    // the dense adjacency row-sum (bit-identical: 0/1 row sums are small
    // integers).
    let name_pre = sparse_pre_name("embed_pre_sp", b, ni, k);
    let mut pre_h: Vec<Vec<f32>> = Vec::with_capacity(p);
    for (i, sh) in shards.iter().enumerate() {
        let inputs = [
            th.t(0),
            th.t(1),
            th.t(2),
            Input::Host(HostTensor::new(&d_s, &sh.s)),
            Input::Host(HostTensor::new(&d_s, &sh.deg)),
        ];
        pre_h.push(exec(i, &name_pre, &inputs, &mut timing)?.into_iter().next().unwrap());
    }

    // Embedding layers: per shard, sweep the edge tiles grouped by source
    // chunk (tiles are (sc, dc)-sorted by construction), slice the source
    // embedding once per group, and accumulate each tile's [B,K,NC] partial
    // into the B×K×N all-reduce scratch at its destination-chunk columns.
    let mut embed_h: Vec<Vec<f32>> = (0..p).map(|_| vec![0.0f32; b * k * ni]).collect();
    let mut embed_in: Vec<Vec<Vec<f32>>> = Vec::new();
    let mut nbr_slice_acts: Vec<Vec<Vec<f32>>> = Vec::new();
    let name_cmb = artifact_name("embed_combine", b, n, ni, k);
    let mut nbr_full = vec![0.0f32; b * k * n];
    let mut echunk = vec![0.0f32; b * k * chunk];

    for layer in 0..cfg.l {
        if save {
            embed_in.push(embed_h.clone());
        }
        let skip_msg = layer == 0 && skip_zero_layer;
        nbr_full.fill(0.0);
        if !skip_msg {
            for (i, sh) in shards.iter().enumerate() {
                let tiles = &sh.tiles;
                let mut ti = 0usize;
                while ti < tiles.len() {
                    let sc = tiles[ti].sc;
                    // Source-chunk slice of the local embedding, zero-padded
                    // past NI (padding rows are never referenced by live
                    // edges).
                    let t_host = Instant::now();
                    let lo = sc * chunk;
                    let hi = (lo + chunk).min(ni);
                    echunk.fill(0.0);
                    if lo < ni {
                        for g in 0..b {
                            for kk in 0..k {
                                let so = g * k * ni + kk * ni + lo;
                                let eo = g * k * chunk + kk * chunk;
                                echunk[eo..eo + (hi - lo)]
                                    .copy_from_slice(&embed_h[i][so..so + (hi - lo)]);
                            }
                        }
                    }
                    timing.host += t_host.elapsed().as_secs_f64();
                    while ti < tiles.len() && tiles[ti].sc == sc {
                        let tile = &tiles[ti];
                        let name = sparse_msg_name("embed_msg_sp", b, tile.cap, chunk, k);
                        let (src_in, dst_in, w_in) = match dev {
                            Some(d) => (
                                Input::Dev(&d.src[i][ti]),
                                Input::Dev(&d.dst[i][ti]),
                                Input::Dev(&d.w[i][ti]),
                            ),
                            None => {
                                let (sb, db, wb) = &tile_owned[i][ti];
                                (Input::Dev(sb), Input::Dev(db), Input::Dev(wb))
                            }
                        };
                        let inputs =
                            [Input::Host(HostTensor::new(&d_ec, &echunk)), src_in, dst_in, w_in];
                        let part =
                            exec(i, &name, &inputs, &mut timing)?.into_iter().next().unwrap();
                        let t_host = Instant::now();
                        let dlo = tile.dc * chunk;
                        let dhi = (dlo + chunk).min(n);
                        for g in 0..b {
                            for kk in 0..k {
                                let no = g * k * n + kk * n + dlo;
                                let po = g * k * chunk + kk * chunk;
                                add_assign(
                                    &mut nbr_full[no..no + (dhi - dlo)],
                                    &part[po..po + (dhi - dlo)],
                                );
                            }
                        }
                        timing.host += t_host.elapsed().as_secs_f64();
                        ti += 1;
                    }
                }
            }
            timing.add_comm(cfg.cost.all_reduce(p, 4 * b * k * n), 4 * b * k * n);
        }
        // Local column slice + combine (shared N-free stage).
        let t_host = Instant::now();
        let mut nbr_slices: Vec<Vec<f32>> = Vec::with_capacity(p);
        for sh in shards.iter() {
            let row0 = sh.part.row0(sh.shard);
            let mut sl = vec![0.0f32; b * k * ni];
            for g in 0..b {
                for kk in 0..k {
                    let src = g * k * n + kk * n + row0;
                    let dst = g * k * ni + kk * ni;
                    sl[dst..dst + ni].copy_from_slice(&nbr_full[src..src + ni]);
                }
            }
            nbr_slices.push(sl);
        }
        timing.host += t_host.elapsed().as_secs_f64();
        for i in 0..p {
            let inputs = [
                th.t(3),
                Input::Host(HostTensor::new(&d_e, &pre_h[i])),
                Input::Host(HostTensor::new(&d_e, &nbr_slices[i])),
            ];
            embed_h[i] = exec(i, &name_cmb, &inputs, &mut timing)?.into_iter().next().unwrap();
        }
        if save {
            nbr_slice_acts.push(nbr_slices);
        }
    }

    // Stage 4 + ALL-REDUCE (shared N-free stage).
    let name_qsum = artifact_name("q_sum", b, n, ni, k);
    let mut sum_all = vec![0.0f32; b * k];
    for i in 0..p {
        let part = exec(i, &name_qsum, &[Input::Host(HostTensor::new(&d_e, &embed_h[i]))],
                        &mut timing)?
            .into_iter()
            .next()
            .unwrap();
        let t_host = Instant::now();
        add_assign(&mut sum_all, &part);
        timing.host += t_host.elapsed().as_secs_f64();
    }
    timing.add_comm(cfg.cost.all_reduce(p, 4 * b * k), 4 * b * k);

    // Stage 5 + ALL-GATHER of scores (shared N-free stage).
    let name_q = artifact_name("q_scores", b, n, ni, k);
    let mut scores = vec![0.0f32; b * n];
    let mut scores_i: Vec<Vec<f32>> = Vec::with_capacity(p);
    for (i, sh) in shards.iter().enumerate() {
        let inputs = [
            th.t(4),
            th.t(5),
            th.t(6),
            Input::Host(HostTensor::new(&d_e, &embed_h[i])),
            Input::Host(HostTensor::new(&d_s, &sh.c)),
            Input::Host(HostTensor::new(&d_sum, &sum_all)),
        ];
        let local = exec(i, &name_q, &inputs, &mut timing)?.into_iter().next().unwrap();
        let t_host = Instant::now();
        let row0 = sh.part.row0(sh.shard);
        for g in 0..b {
            scores[g * n + row0..g * n + row0 + ni].copy_from_slice(&local[g * ni..(g + 1) * ni]);
        }
        timing.host += t_host.elapsed().as_secs_f64();
        scores_i.push(local);
    }
    timing.add_comm(cfg.cost.all_gather(p, 4 * b * ni), 4 * b * ni * p);

    timing.wall = wall.elapsed().as_secs_f64();
    let acts = if save {
        Some(Activations {
            pre: pre_h,
            embed_in,
            nbr_slice: nbr_slice_acts,
            embed_final: embed_h,
            sum_all,
            scores_i,
        })
    } else {
        None
    };
    Ok(FwdOutput { scores, acts, timing })
}

/// A device state for either storage mode — what the storage-generic solve
/// loops hold alongside a [`ShardSet`].
pub enum AnyDeviceState<'r> {
    /// Dense θ+A residency ([`DeviceState`]).
    Dense(DeviceState<'r>),
    /// Sparse θ+edge-tile residency ([`SparseDeviceState`]).
    Sparse(SparseDeviceState<'r>),
}

impl<'r> AnyDeviceState<'r> {
    /// Upload device state matching the set's storage mode.
    pub fn new(rt: &'r Runtime, params: &Params, set: &mut ShardSet) -> Result<AnyDeviceState<'r>> {
        AnyDeviceState::new_in(rt, params, set, None)
    }

    /// Like [`AnyDeviceState::new`], but θ goes through a shared
    /// [`ThetaCache`] when given (see [`DeviceState::new_in`]).
    pub fn new_in(
        rt: &'r Runtime,
        params: &Params,
        set: &mut ShardSet,
        theta_cache: Option<&ThetaCache>,
    ) -> Result<AnyDeviceState<'r>> {
        match set {
            ShardSet::Dense(sh) => {
                Ok(AnyDeviceState::Dense(DeviceState::new_in(rt, params, sh, theta_cache)?))
            }
            ShardSet::Sparse(sh) => {
                Ok(AnyDeviceState::Sparse(SparseDeviceState::new_in(rt, params, sh, theta_cache)?))
            }
        }
    }

    /// Push recorded host-side deltas to the device copies (see the
    /// per-mode `sync` docs).
    pub fn sync(&mut self, set: &mut ShardSet) -> Result<()> {
        match (self, set) {
            (AnyDeviceState::Dense(d), ShardSet::Dense(sh)) => d.sync(sh),
            (AnyDeviceState::Sparse(d), ShardSet::Sparse(sh)) => d.sync(sh),
            _ => panic!("device-state storage mode does not match the shard set"),
        }
    }

    /// Invalidate + re-upload after a repack (see the per-mode docs).
    pub fn rebuild(&mut self, set: &mut ShardSet) -> Result<()> {
        match (self, set) {
            (AnyDeviceState::Dense(d), ShardSet::Dense(sh)) => d.rebuild(sh),
            (AnyDeviceState::Sparse(d), ShardSet::Sparse(sh)) => d.rebuild(sh),
            _ => panic!("device-state storage mode does not match the shard set"),
        }
    }

    /// Re-upload θ after an optimizer step.
    pub fn refresh_theta(&mut self, params: &Params) -> Result<()> {
        match self {
            AnyDeviceState::Dense(d) => d.refresh_theta(params),
            AnyDeviceState::Sparse(d) => d.refresh_theta(params),
        }
    }

    /// Simulated transfer seconds of the most recent upload operation.
    pub fn last_transfer_secs(&self) -> f64 {
        match self {
            AnyDeviceState::Dense(d) => d.last_transfer_secs(),
            AnyDeviceState::Sparse(d) => d.last_transfer_secs(),
        }
    }
}

/// Storage-generic policy evaluation: dispatch a [`ShardSet`] to
/// [`forward_dev`] (dense) or [`forward_sparse`] with the matching device
/// state. Panics if a device state of the other mode is passed — the solve
/// loops construct both from the same set, so a mismatch is a logic bug.
pub fn forward_set(
    rt: &Runtime,
    cfg: &EngineCfg,
    params: &Params,
    set: &ShardSet,
    save: bool,
    skip_zero_layer: bool,
    dev: Option<&AnyDeviceState>,
) -> Result<FwdOutput> {
    match set {
        ShardSet::Dense(sh) => {
            let d = match dev {
                Some(AnyDeviceState::Dense(d)) => Some(d),
                None => None,
                Some(AnyDeviceState::Sparse(_)) => panic!("sparse device state on dense set"),
            };
            forward_dev(rt, cfg, params, sh, save, skip_zero_layer, d)
        }
        ShardSet::Sparse(sh) => {
            let d = match dev {
                Some(AnyDeviceState::Sparse(d)) => Some(d),
                None => None,
                Some(AnyDeviceState::Dense(_)) => panic!("dense device state on sparse set"),
            };
            forward_sparse(rt, cfg, params, sh, save, skip_zero_layer, d)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::shard::shards_for_graph;
    use crate::graph::{generators, Partition};
    use crate::util::rng::Pcg32;

    fn runtime() -> Option<Runtime> {
        if !std::path::Path::new("artifacts/manifest.tsv").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        Some(Runtime::new("artifacts").unwrap())
    }

    fn fresh_shards(part: Partition, g: &crate::graph::Graph) -> Vec<ShardState> {
        let removed = vec![false; g.n];
        let sol = vec![false; g.n];
        let cand: Vec<bool> = (0..g.n).map(|v| g.degree(v) > 0).collect();
        shards_for_graph(part, g, &removed, &sol, &cand)
    }

    #[test]
    fn forward_p_parity() {
        // Scores must be identical (within fp) for every device count — the
        // core spatial-parallelism invariant.
        let Some(rt) = runtime() else { return };
        let g = generators::erdos_renyi(20, 0.25, &mut Pcg32::seeded(3));
        let mut params = Params::zeros(32);
        let mut rng = Pcg32::seeded(11);
        params = Params::init(params.k, &mut rng);

        let mut reference: Option<Vec<f32>> = None;
        for p in [1usize, 2, 3, 4, 6] {
            let part = Partition::new(24, p);
            let shards = fresh_shards(part, &g);
            let cfg = EngineCfg::new(p, 2);
            let out = forward(&rt, &cfg, &params, &shards, false, false).unwrap();
            assert_eq!(out.scores.len(), 24);
            match &reference {
                None => reference = Some(out.scores),
                Some(want) => {
                    let d = crate::util::max_abs_diff(&out.scores, want);
                    assert!(d < 1e-3, "P={p} diverges by {d}");
                }
            }
        }
    }

    #[test]
    fn device_state_forward_matches_fresh() {
        // The resident path must reproduce the fresh-upload path bit-exactly
        // (same stage programs, same input bits — only the transport
        // differs). Covers both the P=1 full-chain and the P>1 collective
        // paths, with and without saved activations.
        let Some(rt) = runtime() else { return };
        let g = generators::erdos_renyi(20, 0.25, &mut Pcg32::seeded(7));
        let params = Params::init(32, &mut Pcg32::seeded(15));
        for p in [1usize, 2, 4] {
            let part = Partition::new(24, p);
            let mut shards = fresh_shards(part, &g);
            let cfg = EngineCfg::new(p, 2);
            let fresh = forward(&rt, &cfg, &params, &shards, false, true).unwrap();
            let dev = DeviceState::new(&rt, &params, &mut shards).unwrap();
            let res = forward_dev(&rt, &cfg, &params, &shards, false, true, Some(&dev)).unwrap();
            assert_eq!(res.scores, fresh.scores, "P={p} resident scores diverge");
            // save=true (training forward) with device-resident θ/A.
            let fresh_s = forward(&rt, &cfg, &params, &shards, true, false).unwrap();
            let res_s = forward_dev(&rt, &cfg, &params, &shards, true, false, Some(&dev)).unwrap();
            assert_eq!(res_s.scores, fresh_s.scores, "P={p} save-path scores diverge");
            let (fa, ra) = (fresh_s.acts.unwrap(), res_s.acts.unwrap());
            assert_eq!(ra.pre, fa.pre, "P={p} pre acts diverge");
            assert_eq!(ra.embed_final, fa.embed_final, "P={p} embed acts diverge");
            assert_eq!(ra.sum_all, fa.sum_all, "P={p} sum acts diverge");
        }
    }

    #[test]
    fn device_state_sync_tracks_removals() {
        // After removals, a synced DeviceState must give the same scores as
        // a fresh forward over the mutated host shards — whether the patch
        // went through the a_mask stage or the re-upload fallback.
        let Some(rt) = runtime() else { return };
        let g = generators::erdos_renyi(20, 0.25, &mut Pcg32::seeded(8));
        let params = Params::init(32, &mut Pcg32::seeded(16));
        for p in [1usize, 2] {
            let part = Partition::new(24, p);
            let mut shards = fresh_shards(part, &g);
            let cfg = EngineCfg::new(p, 2);
            let mut dev = DeviceState::new(&rt, &params, &mut shards).unwrap();
            let _ = forward_dev(&rt, &cfg, &params, &shards, false, true, Some(&dev)).unwrap();
            for sh in shards.iter_mut() {
                sh.apply_select(0, 3);
                sh.apply_select(0, 11);
            }
            dev.sync(&mut shards).unwrap();
            let res = forward_dev(&rt, &cfg, &params, &shards, false, true, Some(&dev)).unwrap();
            let fresh = forward(&rt, &cfg, &params, &shards, false, true).unwrap();
            assert_eq!(res.scores, fresh.scores, "P={p} synced scores diverge");
        }
    }

    fn fresh_sparse_shards(
        rt: &Runtime,
        part: Partition,
        g: &crate::graph::Graph,
    ) -> Option<Vec<SparseShard>> {
        let Ok((chunk, caps)) = rt.manifest.sparse_config(1, part.ni(), 32) else {
            eprintln!("skipping: sparse artifacts not compiled");
            return None;
        };
        let removed = vec![false; g.n];
        let sol = vec![false; g.n];
        let cand: Vec<bool> = (0..g.n).map(|v| g.degree(v) > 0).collect();
        Some(crate::coordinator::shard::sparse_shards_for_graph(
            part, g, &removed, &sol, &cand, chunk, &caps,
        ))
    }

    #[test]
    fn sparse_forward_matches_dense_oracle() {
        // The CSR path must reproduce the dense path's scores to fp
        // tolerance at every device count (the scatter's summation order
        // differs from the matmul's, so parity is fp-tolerant like the
        // batch engine's b=1-vs-b>=2 note, DESIGN.md §4 Numerics).
        let Some(rt) = runtime() else { return };
        let g = generators::erdos_renyi(20, 0.25, &mut Pcg32::seeded(9));
        let params = Params::init(32, &mut Pcg32::seeded(17));
        for p in [1usize, 2, 4] {
            let part = Partition::new(24, p);
            let dense = fresh_shards(part, &g);
            let Some(sparse) = fresh_sparse_shards(&rt, part, &g) else { return };
            let cfg = EngineCfg::new(p, 2);
            let want = forward(&rt, &cfg, &params, &dense, false, true).unwrap();
            let got = forward_sparse(&rt, &cfg, &params, &sparse, false, true, None).unwrap();
            let d = crate::util::max_abs_diff(&got.scores, &want.scores);
            assert!(d < 1e-4, "P={p} sparse diverges from dense by {d}");
            // Transfer/collective accounting matches the dense shape.
            assert_eq!(got.timing.collectives, want.timing.collectives);
        }
    }

    #[test]
    fn sparse_device_state_is_bit_exact_and_tracks_removals() {
        // Resident vs fresh on the SPARSE path is bit-exact (same stage
        // programs, same input bits — only the transport differs), and a
        // synced SparseDeviceState must track live-mask deltas after
        // removals.
        let Some(rt) = runtime() else { return };
        let g = generators::erdos_renyi(20, 0.25, &mut Pcg32::seeded(10));
        let params = Params::init(32, &mut Pcg32::seeded(18));
        for p in [1usize, 2] {
            let part = Partition::new(24, p);
            let Some(mut sparse) = fresh_sparse_shards(&rt, part, &g) else { return };
            let cfg = EngineCfg::new(p, 2);
            let mut dev = SparseDeviceState::new(&rt, &params, &mut sparse).unwrap();
            let res =
                forward_sparse(&rt, &cfg, &params, &sparse, false, true, Some(&dev)).unwrap();
            let fresh = forward_sparse(&rt, &cfg, &params, &sparse, false, true, None).unwrap();
            assert_eq!(res.scores, fresh.scores, "P={p} resident sparse scores diverge");
            for sh in sparse.iter_mut() {
                sh.apply_select(0, 3);
                sh.apply_select(0, 11);
            }
            dev.sync(&mut sparse).unwrap();
            let res2 =
                forward_sparse(&rt, &cfg, &params, &sparse, false, true, Some(&dev)).unwrap();
            let fresh2 = forward_sparse(&rt, &cfg, &params, &sparse, false, true, None).unwrap();
            assert_eq!(res2.scores, fresh2.scores, "P={p} synced sparse scores diverge");
            assert_ne!(res2.scores, res.scores, "removals did not change scores");
        }
    }

    #[test]
    fn forward_set_dispatches_storage_modes() {
        let Some(rt) = runtime() else { return };
        let g = generators::erdos_renyi(20, 0.25, &mut Pcg32::seeded(11));
        let params = Params::init(32, &mut Pcg32::seeded(19));
        let part = Partition::new(24, 2);
        let cfg = EngineCfg::new(2, 2);
        let dense_set = ShardSet::Dense(fresh_shards(part, &g));
        let Some(sp) = fresh_sparse_shards(&rt, part, &g) else { return };
        let sparse_set = ShardSet::Sparse(sp);
        let a = forward_set(&rt, &cfg, &params, &dense_set, false, true, None).unwrap();
        let b = forward_set(&rt, &cfg, &params, &sparse_set, false, true, None).unwrap();
        let d = crate::util::max_abs_diff(&a.scores, &b.scores);
        assert!(d < 1e-4, "set dispatch paths diverge by {d}");
    }

    #[test]
    fn skip_zero_layer_is_exact() {
        let Some(rt) = runtime() else { return };
        let g = generators::erdos_renyi(20, 0.25, &mut Pcg32::seeded(4));
        let params = Params::init(32, &mut Pcg32::seeded(12));
        let part = Partition::new(24, 2);
        let shards = fresh_shards(part, &g);
        let cfg = EngineCfg::new(2, 2);
        let a = forward(&rt, &cfg, &params, &shards, false, false).unwrap();
        let b = forward(&rt, &cfg, &params, &shards, false, true).unwrap();
        let d = crate::util::max_abs_diff(&a.scores, &b.scores);
        assert!(d < 1e-4, "skip-zero-layer changed scores by {d}");
    }

    #[test]
    fn timing_is_populated() {
        let Some(rt) = runtime() else { return };
        let g = generators::erdos_renyi(20, 0.25, &mut Pcg32::seeded(5));
        let params = Params::init(32, &mut Pcg32::seeded(13));
        let part = Partition::new(24, 3);
        let shards = fresh_shards(part, &g);
        let cfg = EngineCfg::new(3, 2);
        let out = forward(&rt, &cfg, &params, &shards, false, false).unwrap();
        assert!(out.timing.compute.iter().all(|&t| t > 0.0));
        // The A upload is booked as transfer, separable from compute.
        assert!(out.timing.h2d > 0.0);
        // L all-reduces + q_sum all-reduce + score all-gather.
        assert_eq!(out.timing.collectives, 2 + 2);
        assert!(out.timing.comm > 0.0);
        assert!(out.timing.wall >= out.timing.compute.iter().sum::<f64>() * 0.5);
    }

    #[test]
    fn activations_saved_when_requested() {
        let Some(rt) = runtime() else { return };
        let g = generators::erdos_renyi(20, 0.25, &mut Pcg32::seeded(6));
        let params = Params::init(32, &mut Pcg32::seeded(14));
        let part = Partition::new(24, 2);
        let shards = fresh_shards(part, &g);
        let cfg = EngineCfg::new(2, 2);
        let out = forward(&rt, &cfg, &params, &shards, true, false).unwrap();
        let acts = out.acts.unwrap();
        assert_eq!(acts.pre.len(), 2);
        assert_eq!(acts.embed_in.len(), 2); // L layers
        assert_eq!(acts.nbr_slice.len(), 2);
        assert_eq!(acts.embed_final.len(), 2);
        assert_eq!(acts.sum_all.len(), 32);
        assert_eq!(acts.scores_i[0].len(), 12);
    }
}
