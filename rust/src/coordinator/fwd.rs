//! Distributed forward pass: Alg. 2 (embedding) + Alg. 3 (action scores)
//! orchestrated over P shards, with Rust-side collectives between the AOT
//! stage programs. Mirrors python/tests/dist_sim.py `dist_forward` exactly.

use super::engine::{EngineCfg, StepTiming};
use super::shard::ShardState;
use crate::model::Params;
use crate::runtime::{artifact_name, HostTensor, Input, Runtime};
use anyhow::Result;
use std::time::Instant;

/// Saved activations for the backward pass (per shard / per layer).
#[derive(Debug, Clone)]
pub struct Activations {
    /// Stage-1 output pre^i, per shard, each B*K*NI.
    pub pre: Vec<Vec<f32>>,
    /// Embedding input per layer per shard (embed_{l-1}), B*K*NI.
    pub embed_in: Vec<Vec<Vec<f32>>>,
    /// Local slice of the all-reduced message per layer per shard, B*K*NI.
    pub nbr_slice: Vec<Vec<Vec<f32>>>,
    /// Final embedding per shard, B*K*NI.
    pub embed_final: Vec<Vec<f32>>,
    /// All-reduced embedding sum, B*K.
    pub sum_all: Vec<f32>,
    /// Per-shard local scores, B*NI.
    pub scores_i: Vec<Vec<f32>>,
}

/// Forward output: gathered scores plus timing (and activations if saved).
#[derive(Debug)]
pub struct FwdOutput {
    /// Gathered scores, B*N (node-major within each graph).
    pub scores: Vec<f32>,
    pub acts: Option<Activations>,
    pub timing: StepTiming,
}

struct ThetaViews<'p> {
    params: &'p Params,
    dims: Vec<Vec<usize>>,
}

impl<'p> ThetaViews<'p> {
    fn new(params: &'p Params) -> ThetaViews<'p> {
        ThetaViews { params, dims: (0..7).map(|i| params.theta_dims(i)).collect() }
    }
    fn t(&self, idx: usize) -> Input<'_> {
        Input::Host(HostTensor::new(&self.dims[idx], self.params.theta(idx)))
    }
}

/// Run the distributed policy evaluation. `save` keeps activations for the
/// backward pass. When `skip_zero_layer` is set, layer 0's message stage is
/// elided (its input embedding is the zeros constant of Alg. 2 line 3), a
/// perf optimization logged in EXPERIMENTS.md §Perf.
pub fn forward(
    rt: &Runtime,
    cfg: &EngineCfg,
    params: &Params,
    shards: &[ShardState],
    save: bool,
    skip_zero_layer: bool,
) -> Result<FwdOutput> {
    let wall = Instant::now();
    let p = shards.len();
    assert_eq!(p, cfg.p, "shard count != cfg.p");
    let (b, n, ni, k) = (shards[0].b, shards[0].n(), shards[0].ni(), params.k);
    let mut timing = StepTiming::new(p);
    let th = ThetaViews::new(params);

    let d_s = [b, ni];
    let d_a = [b, ni, n];
    let d_e = [b, k, ni];
    let d_sum = [b, k];

    let exec = |shard: usize, name: &str, inputs: &[Input], timing: &mut StepTiming| {
        let t0 = Instant::now();
        let out = rt.execute_in(name, inputs);
        timing.compute[shard] += t0.elapsed().as_secs_f64();
        out
    };

    // §Perf: upload each shard's A once per evaluation; every stage that
    // reads the adjacency shares the device buffer (h2d dominated the step
    // before this — see EXPERIMENTS.md §Perf).
    let mut a_bufs = Vec::with_capacity(p);
    for (i, sh) in shards.iter().enumerate() {
        let t0 = Instant::now();
        a_bufs.push(rt.upload(&d_a, &sh.a)?);
        timing.compute[i] += t0.elapsed().as_secs_f64();
    }

    // Stage 1: pre^i (layer-independent terms).
    let name_pre = artifact_name("embed_pre", b, n, ni, k);
    let mut pre: Vec<Vec<f32>> = Vec::with_capacity(p);
    for (i, sh) in shards.iter().enumerate() {
        let out = exec(
            i,
            &name_pre,
            &[th.t(0), th.t(1), th.t(2),
              Input::Host(HostTensor::new(&d_s, &sh.s)), Input::Dev(&a_bufs[i])],
            &mut timing,
        )?;
        pre.push(out.into_iter().next().unwrap());
    }

    // Embedding layers (Alg. 2 lines 9-15).
    let mut embed: Vec<Vec<f32>> = (0..p).map(|_| vec![0.0f32; b * k * ni]).collect();
    let mut acts = Activations {
        pre: if save { pre.clone() } else { Vec::new() },
        embed_in: Vec::new(),
        nbr_slice: Vec::new(),
        embed_final: Vec::new(),
        sum_all: Vec::new(),
        scores_i: Vec::new(),
    };
    let name_msg = artifact_name("embed_msg", b, n, ni, k);
    let name_cmb = artifact_name("embed_combine", b, n, ni, k);
    for layer in 0..cfg.l {
        if save {
            acts.embed_in.push(embed.clone());
        }
        let zero_input = layer == 0; // embed is the zeros constant
        let mut nbr_full = vec![0.0f32; b * k * n];
        if !(zero_input && skip_zero_layer) {
            // Stage 2 per shard + ALL-REDUCE (line 12).
            for i in 0..p {
                let out = exec(
                    i,
                    &name_msg,
                    &[Input::Host(HostTensor::new(&d_e, &embed[i])), Input::Dev(&a_bufs[i])],
                    &mut timing,
                )?;
                let t_host = Instant::now();
                for (acc, x) in nbr_full.iter_mut().zip(out[0].iter()) {
                    *acc += x;
                }
                timing.host += t_host.elapsed().as_secs_f64();
            }
            timing.add_comm(cfg.cost.all_reduce(p, 4 * b * k * n), 4 * b * k * n);
        }
        // Local column slice + Stage 3 per shard.
        let t_host = Instant::now();
        let mut nbr_slices: Vec<Vec<f32>> = Vec::with_capacity(p);
        for sh in shards.iter() {
            let row0 = sh.part.row0(sh.shard);
            let mut sl = vec![0.0f32; b * k * ni];
            for g in 0..b {
                for kk in 0..k {
                    let src = g * k * n + kk * n + row0;
                    let dst = g * k * ni + kk * ni;
                    sl[dst..dst + ni].copy_from_slice(&nbr_full[src..src + ni]);
                }
            }
            nbr_slices.push(sl);
        }
        timing.host += t_host.elapsed().as_secs_f64();
        for i in 0..p {
            let out = exec(
                i,
                &name_cmb,
                &[
                    th.t(3),
                    Input::Host(HostTensor::new(&d_e, &pre[i])),
                    Input::Host(HostTensor::new(&d_e, &nbr_slices[i])),
                ],
                &mut timing,
            )?;
            embed[i] = out.into_iter().next().unwrap();
        }
        if save {
            acts.nbr_slice.push(nbr_slices);
        }
    }

    // Stage 4 + ALL-REDUCE (Alg. 3 lines 4-5).
    let name_qsum = artifact_name("q_sum", b, n, ni, k);
    let mut sum_all = vec![0.0f32; b * k];
    for i in 0..p {
        let out =
            exec(i, &name_qsum, &[Input::Host(HostTensor::new(&d_e, &embed[i]))], &mut timing)?;
        let t_host = Instant::now();
        for (acc, x) in sum_all.iter_mut().zip(out[0].iter()) {
            *acc += x;
        }
        timing.host += t_host.elapsed().as_secs_f64();
    }
    timing.add_comm(cfg.cost.all_reduce(p, 4 * b * k), 4 * b * k);

    // Stage 5 + ALL-GATHER of scores (Alg. 4 line 6).
    let name_q = artifact_name("q_scores", b, n, ni, k);
    let mut scores = vec![0.0f32; b * n];
    let mut scores_i: Vec<Vec<f32>> = Vec::with_capacity(p);
    for (i, sh) in shards.iter().enumerate() {
        let out = exec(
            i,
            &name_q,
            &[
                th.t(4),
                th.t(5),
                th.t(6),
                Input::Host(HostTensor::new(&d_e, &embed[i])),
                Input::Host(HostTensor::new(&d_s, &sh.c)),
                Input::Host(HostTensor::new(&d_sum, &sum_all)),
            ],
            &mut timing,
        )?;
        let local = out.into_iter().next().unwrap();
        let t_host = Instant::now();
        let row0 = sh.part.row0(sh.shard);
        for g in 0..b {
            scores[g * n + row0..g * n + row0 + ni].copy_from_slice(&local[g * ni..(g + 1) * ni]);
        }
        timing.host += t_host.elapsed().as_secs_f64();
        scores_i.push(local);
    }
    timing.add_comm(cfg.cost.all_gather(p, 4 * b * ni), 4 * b * ni * p);

    timing.wall = wall.elapsed().as_secs_f64();
    let acts = if save {
        acts.embed_final = embed;
        acts.sum_all = sum_all;
        acts.scores_i = scores_i;
        Some(acts)
    } else {
        None
    };
    Ok(FwdOutput { scores, acts, timing })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::shard::shards_for_graph;
    use crate::graph::{generators, Partition};
    use crate::util::rng::Pcg32;

    fn runtime() -> Option<Runtime> {
        if !std::path::Path::new("artifacts/manifest.tsv").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        Some(Runtime::new("artifacts").unwrap())
    }

    fn fresh_shards(part: Partition, g: &crate::graph::Graph) -> Vec<ShardState> {
        let removed = vec![false; g.n];
        let sol = vec![false; g.n];
        let cand: Vec<bool> = (0..g.n).map(|v| g.degree(v) > 0).collect();
        shards_for_graph(part, g, &removed, &sol, &cand)
    }

    #[test]
    fn forward_p_parity() {
        // Scores must be identical (within fp) for every device count — the
        // core spatial-parallelism invariant.
        let Some(rt) = runtime() else { return };
        let g = generators::erdos_renyi(20, 0.25, &mut Pcg32::seeded(3));
        let mut params = Params::zeros(32);
        let mut rng = Pcg32::seeded(11);
        params = Params::init(params.k, &mut rng);

        let mut reference: Option<Vec<f32>> = None;
        for p in [1usize, 2, 3, 4, 6] {
            let part = Partition::new(24, p);
            let shards = fresh_shards(part, &g);
            let cfg = EngineCfg::new(p, 2);
            let out = forward(&rt, &cfg, &params, &shards, false, false).unwrap();
            assert_eq!(out.scores.len(), 24);
            match &reference {
                None => reference = Some(out.scores),
                Some(want) => {
                    let d = crate::util::max_abs_diff(&out.scores, want);
                    assert!(d < 1e-3, "P={p} diverges by {d}");
                }
            }
        }
    }

    #[test]
    fn skip_zero_layer_is_exact() {
        let Some(rt) = runtime() else { return };
        let g = generators::erdos_renyi(20, 0.25, &mut Pcg32::seeded(4));
        let params = Params::init(32, &mut Pcg32::seeded(12));
        let part = Partition::new(24, 2);
        let shards = fresh_shards(part, &g);
        let cfg = EngineCfg::new(2, 2);
        let a = forward(&rt, &cfg, &params, &shards, false, false).unwrap();
        let b = forward(&rt, &cfg, &params, &shards, false, true).unwrap();
        let d = crate::util::max_abs_diff(&a.scores, &b.scores);
        assert!(d < 1e-4, "skip-zero-layer changed scores by {d}");
    }

    #[test]
    fn timing_is_populated() {
        let Some(rt) = runtime() else { return };
        let g = generators::erdos_renyi(20, 0.25, &mut Pcg32::seeded(5));
        let params = Params::init(32, &mut Pcg32::seeded(13));
        let part = Partition::new(24, 3);
        let shards = fresh_shards(part, &g);
        let cfg = EngineCfg::new(3, 2);
        let out = forward(&rt, &cfg, &params, &shards, false, false).unwrap();
        assert!(out.timing.compute.iter().all(|&t| t > 0.0));
        // L all-reduces + q_sum all-reduce + score all-gather.
        assert_eq!(out.timing.collectives, 2 + 2);
        assert!(out.timing.comm > 0.0);
        assert!(out.timing.wall >= out.timing.compute.iter().sum::<f64>() * 0.5);
    }

    #[test]
    fn activations_saved_when_requested() {
        let Some(rt) = runtime() else { return };
        let g = generators::erdos_renyi(20, 0.25, &mut Pcg32::seeded(6));
        let params = Params::init(32, &mut Pcg32::seeded(14));
        let part = Partition::new(24, 2);
        let shards = fresh_shards(part, &g);
        let cfg = EngineCfg::new(2, 2);
        let out = forward(&rt, &cfg, &params, &shards, true, false).unwrap();
        let acts = out.acts.unwrap();
        assert_eq!(acts.pre.len(), 2);
        assert_eq!(acts.embed_in.len(), 2); // L layers
        assert_eq!(acts.nbr_slice.len(), 2);
        assert_eq!(acts.embed_final.len(), 2);
        assert_eq!(acts.sum_all.len(), 32);
        assert_eq!(acts.scores_i[0].len(), 12);
    }
}
