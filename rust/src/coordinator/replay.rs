//! Experience replay buffer with the paper's memory optimization (§4.4):
//! tuples store only (graph index, partial-solution snapshot, action,
//! target); `Tuples2Graphs` reconstructs the dense minibatch state from the
//! original CSR graphs at training time.

use super::shard::{ShardSet, ShardState, SparseShard, Storage};
use crate::graph::{Graph, Partition};
use crate::util::rng::Pcg32;

/// One compressed experience tuple.
#[derive(Debug, Clone)]
pub struct Tuple {
    /// Index into the training-graph dataset.
    pub graph_id: u32,
    /// Partial solution *before* the action, as a packed bitset.
    pub solution: BitSet,
    /// The selected node v_t.
    pub action: u32,
    /// Bellman target value.
    pub target: f32,
}

/// Packed bitset over node ids.
#[derive(Debug, Clone, PartialEq)]
pub struct BitSet {
    words: Vec<u64>,
    /// Number of bits (nodes) the set covers.
    pub len: usize,
}

impl BitSet {
    /// Pack a bool mask.
    pub fn from_bools(mask: &[bool]) -> BitSet {
        let mut words = vec![0u64; mask.len().div_ceil(64)];
        for (i, &b) in mask.iter().enumerate() {
            if b {
                words[i / 64] |= 1 << (i % 64);
            }
        }
        BitSet { words, len: mask.len() }
    }

    /// Bit i.
    pub fn get(&self, i: usize) -> bool {
        self.words[i / 64] & (1 << (i % 64)) != 0
    }

    /// Unpack to a bool mask.
    pub fn to_bools(&self) -> Vec<bool> {
        (0..self.len).map(|i| self.get(i)).collect()
    }

    /// Bytes held by the packed words.
    pub fn bytes(&self) -> usize {
        8 * self.words.len()
    }
}

/// Bounded FIFO replay buffer.
#[derive(Debug)]
pub struct ReplayBuffer {
    capacity: usize,
    tuples: std::collections::VecDeque<Tuple>,
}

impl ReplayBuffer {
    /// Empty buffer holding at most `capacity` tuples.
    pub fn new(capacity: usize) -> ReplayBuffer {
        ReplayBuffer { capacity, tuples: std::collections::VecDeque::new() }
    }

    /// Append a tuple, evicting the oldest at capacity.
    pub fn push(&mut self, t: Tuple) {
        if self.tuples.len() == self.capacity {
            self.tuples.pop_front();
        }
        self.tuples.push_back(t);
    }

    /// Number of buffered tuples.
    pub fn len(&self) -> usize {
        self.tuples.len()
    }

    /// Whether the buffer holds no tuples.
    pub fn is_empty(&self) -> bool {
        self.tuples.is_empty()
    }

    /// Sample `b` tuples with replacement (paper samples with the shared
    /// seed so every process draws the same minibatch).
    pub fn sample(&self, b: usize, rng: &mut Pcg32) -> Vec<&Tuple> {
        assert!(!self.is_empty(), "sampling from empty replay buffer");
        (0..b).map(|_| &self.tuples[rng.gen_range(self.tuples.len())]).collect()
    }

    /// Actual bytes held (compressed representation).
    pub fn bytes(&self) -> usize {
        self.tuples.iter().map(|t| 4 + 4 + 4 + t.solution.bytes()).sum()
    }

    /// Bytes a dense-state representation would need (ablation: stores the
    /// B×N×N f32 adjacency per tuple instead of the snapshot).
    pub fn bytes_uncompressed(&self, n: usize) -> usize {
        self.tuples.len() * (4 * n * n + 4 * n + 8)
    }
}

/// Reconstructed minibatch state (Tuples2Graphs, before sharding).
struct MiniState<'g> {
    grefs: Vec<&'g Graph>,
    removed: Vec<Vec<bool>>,
    solution: Vec<Vec<bool>>,
    candidates: Vec<Vec<bool>>,
    onehot: Vec<f32>,
    targets: Vec<f32>,
}

/// Tuples2Graphs (Alg. 5 line 21-24): rebuild the per-graph minibatch masks
/// for `tuples` over the training dataset `graphs`. For MVC the residual
/// graph removes solution nodes; candidates are the non-solution nodes with
/// uncovered incident edges — reconstructed from the CSR graph + snapshot,
/// exactly like the paper regenerates subgraphs from (index, S).
fn reconstruct<'g>(part: Partition, graphs: &'g [Graph], tuples: &[&Tuple]) -> MiniState<'g> {
    let b = tuples.len();
    let n = part.n;
    let mut st = MiniState {
        grefs: Vec::with_capacity(b),
        removed: Vec::with_capacity(b),
        solution: Vec::with_capacity(b),
        candidates: Vec::with_capacity(b),
        onehot: vec![0.0f32; b * n],
        targets: vec![0.0f32; b],
    };
    for (bi, t) in tuples.iter().enumerate() {
        let g = &graphs[t.graph_id as usize];
        let sol = t.solution.to_bools();
        assert_eq!(sol.len(), g.n);
        // Candidate = not in solution && has an uncovered incident edge.
        let cand: Vec<bool> = (0..g.n)
            .map(|v| {
                !sol[v]
                    && g.neighbors(v).iter().any(|&u| !sol[u as usize])
            })
            .collect();
        st.grefs.push(g);
        st.removed.push(sol.clone());
        st.solution.push(sol);
        st.candidates.push(cand);
        st.onehot[bi * n + t.action as usize] = 1.0;
        st.targets[bi] = t.target;
    }
    st
}

/// Rebuild the per-shard *dense* minibatch tensors for `tuples` (the
/// original Tuples2Graphs entry; see [`tuples_to_shard_set`] for the
/// storage-generic variant).
pub fn tuples_to_shards(
    part: Partition,
    graphs: &[Graph],
    tuples: &[&Tuple],
) -> (Vec<ShardState>, Vec<f32>, Vec<f32>) {
    let st = reconstruct(part, graphs, tuples);
    let shards = (0..part.p)
        .map(|i| {
            ShardState::from_graphs(
                part,
                i,
                &st.grefs,
                &st.removed.iter().map(|r| r.as_slice()).collect::<Vec<_>>(),
                &st.solution.iter().map(|r| r.as_slice()).collect::<Vec<_>>(),
                &st.candidates.iter().map(|r| r.as_slice()).collect::<Vec<_>>(),
            )
        })
        .collect();
    (shards, st.onehot, st.targets)
}

/// Storage-generic Tuples2Graphs: rebuild the minibatch as a [`ShardSet`]
/// in the requested storage mode. `sparse_cfg` is the (chunk, edge caps)
/// pair from `Manifest::sparse_config`, required iff `storage` is sparse.
pub fn tuples_to_shard_set(
    part: Partition,
    graphs: &[Graph],
    tuples: &[&Tuple],
    storage: Storage,
    sparse_cfg: Option<(usize, &[usize])>,
) -> (ShardSet, Vec<f32>, Vec<f32>) {
    match storage {
        Storage::Dense => {
            let (shards, onehot, targets) = tuples_to_shards(part, graphs, tuples);
            (ShardSet::Dense(shards), onehot, targets)
        }
        Storage::Sparse => {
            let (chunk, caps) = sparse_cfg.expect("sparse storage needs a sparse_cfg");
            let st = reconstruct(part, graphs, tuples);
            let shards = (0..part.p)
                .map(|i| {
                    SparseShard::from_graphs(
                        part,
                        i,
                        &st.grefs,
                        &st.removed.iter().map(|r| r.as_slice()).collect::<Vec<_>>(),
                        &st.solution.iter().map(|r| r.as_slice()).collect::<Vec<_>>(),
                        &st.candidates.iter().map(|r| r.as_slice()).collect::<Vec<_>>(),
                        chunk,
                        caps,
                    )
                })
                .collect();
            (ShardSet::Sparse(shards), st.onehot, st.targets)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::util::prop;

    #[test]
    fn bitset_roundtrip() {
        let mask: Vec<bool> = (0..130).map(|i| i % 3 == 0).collect();
        let bs = BitSet::from_bools(&mask);
        assert_eq!(bs.to_bools(), mask);
        assert_eq!(bs.bytes(), 8 * 3);
    }

    #[test]
    fn fifo_eviction() {
        let mut rb = ReplayBuffer::new(2);
        for i in 0..3u32 {
            rb.push(Tuple {
                graph_id: i,
                solution: BitSet::from_bools(&[false]),
                action: 0,
                target: 0.0,
            });
        }
        assert_eq!(rb.len(), 2);
        let mut rng = Pcg32::seeded(1);
        let ids: std::collections::HashSet<u32> =
            rb.sample(50, &mut rng).iter().map(|t| t.graph_id).collect();
        assert!(!ids.contains(&0), "evicted tuple sampled");
    }

    #[test]
    fn sampling_is_seed_deterministic() {
        let mut rb = ReplayBuffer::new(100);
        for i in 0..50u32 {
            rb.push(Tuple {
                graph_id: i,
                solution: BitSet::from_bools(&[false; 10]),
                action: i % 10,
                target: i as f32,
            });
        }
        let s1: Vec<u32> =
            rb.sample(8, &mut Pcg32::seeded(7)).iter().map(|t| t.graph_id).collect();
        let s2: Vec<u32> =
            rb.sample(8, &mut Pcg32::seeded(7)).iter().map(|t| t.graph_id).collect();
        assert_eq!(s1, s2);
    }

    #[test]
    fn compression_factor_matches_paper_claim() {
        // §5.2: compressed replay must be orders of magnitude below dense.
        let mut rb = ReplayBuffer::new(1000);
        let n = 252;
        for i in 0..1000u32 {
            rb.push(Tuple {
                graph_id: i % 4,
                solution: BitSet::from_bools(&vec![false; n]),
                action: 0,
                target: 0.0,
            });
        }
        let ratio = rb.bytes_uncompressed(n) as f64 / rb.bytes() as f64;
        assert!(ratio > 1000.0, "compression ratio only {ratio}");
    }

    #[test]
    fn tuples_to_shards_reconstructs_state() {
        let mut rng = Pcg32::seeded(3);
        let graphs = vec![
            generators::erdos_renyi(20, 0.25, &mut rng),
            generators::erdos_renyi(20, 0.25, &mut rng),
        ];
        let mut sol = vec![false; 20];
        sol[3] = true;
        let t = Tuple {
            graph_id: 1,
            solution: BitSet::from_bools(&sol),
            action: 5,
            target: -2.0,
        };
        let part = Partition::new(24, 2);
        let (shards, onehot, targets) = tuples_to_shards(part, &graphs, &[&t]);
        assert_eq!(shards.len(), 2);
        assert_eq!(shards[0].b, 1);
        assert_eq!(targets, vec![-2.0]);
        assert_eq!(onehot[5], 1.0);
        assert_eq!(onehot.iter().sum::<f32>(), 1.0);
        // Node 3 is in solution: S=1 on its shard, row zeroed.
        let owner = part.owner(3);
        let local = part.local(3);
        assert_eq!(shards[owner].s[local], 1.0);
        let ni = part.ni();
        let n = part.n;
        assert!(shards[owner].a[local * n..(local + 1) * n].iter().all(|&x| x == 0.0));
        // Column 3 zero on every shard.
        for sh in &shards {
            for r in 0..ni {
                assert_eq!(sh.a[r * n + 3], 0.0);
            }
        }
    }

    #[test]
    fn prop_candidate_reconstruction_matches_env() {
        // Tuples2Graphs' candidate rule must equal the environment's.
        prop::check_msg(
            "tuples2graphs-candidates",
            15,
            |r| {
                let g = generators::erdos_renyi(15 + r.gen_range(10), 0.2, r);
                let seed = r.next_u64();
                (g, seed)
            },
            |(g, seed)| {
                use crate::env::{GraphEnv, MvcEnv};
                let mut rng = Pcg32::seeded(*seed);
                let mut env = MvcEnv::new(g.clone());
                // Take a few random steps.
                for _ in 0..3 {
                    if env.done() {
                        break;
                    }
                    let cands: Vec<usize> =
                        (0..g.n).filter(|&v| env.is_candidate(v)).collect();
                    env.step(cands[rng.gen_range(cands.len())]);
                }
                let sol = env.solution_mask().to_vec();
                for v in 0..g.n {
                    let recon = !sol[v] && g.neighbors(v).iter().any(|&u| !sol[u as usize]);
                    if recon != env.is_candidate(v) {
                        return Err(format!("candidate mismatch at node {v}"));
                    }
                }
                Ok(())
            },
        );
    }
}
