//! Node selection policies over gathered scores.
//!
//! Implements the paper's argmax selection (Alg. 4 line 7) and the §4.5.1
//! adaptive multiple-node selection: take the top-d candidates per policy
//! evaluation with d scheduled 8 → 4 → 2 → 1 as the candidate set shrinks.

/// Selection policy for the inference loop.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SelectionPolicy {
    /// One node per evaluation (the original Alg. 4).
    Single,
    /// Adaptive top-d schedule (§4.5.1).
    AdaptiveMulti,
    /// Fixed d per evaluation (ablation).
    FixedMulti(usize),
}

/// The §4.5.1 schedule: d as a function of |C| and the LIVE node count of
/// the residual graph.
///
/// `n` must be the *current* number of unremoved nodes, not the original
/// graph size: multi-node removals shrink the graph (MVC/MIS), and a
/// schedule pinned to the original N compares |C| against thresholds that
/// no longer describe the remainder — e.g. 80 candidates in a 100-node
/// residue of an originally 1000-node graph is a dense (d=8) state, not a
/// nearly-finished (d=1) one. The solve loops derive `n` from the
/// environment's removed mask each evaluation (regression:
/// `schedule_uses_live_graph_size`).
pub fn adaptive_d(num_candidates: usize, n: usize) -> usize {
    if num_candidates > n / 2 {
        8
    } else if num_candidates > n / 4 {
        4
    } else if num_candidates > n / 8 {
        2
    } else {
        1
    }
}

/// Number of nodes to select this evaluation under `policy`. `n` is the
/// live (unremoved) node count — see [`adaptive_d`].
pub fn select_count(policy: SelectionPolicy, num_candidates: usize, n: usize) -> usize {
    let d = match policy {
        SelectionPolicy::Single => 1,
        SelectionPolicy::AdaptiveMulti => adaptive_d(num_candidates, n),
        SelectionPolicy::FixedMulti(d) => d.max(1),
    };
    d.min(num_candidates.max(1))
}

/// Top-d candidate nodes by score. `candidate(v)` gates eligibility;
/// returns global node indices, highest score first.
pub fn top_d(scores: &[f32], candidate: impl Fn(usize) -> bool, d: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..scores.len()).filter(|&v| candidate(v)).collect();
    idx.sort_by(|&a, &b| {
        scores[b].partial_cmp(&scores[a]).unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b)) // deterministic tie-break
    });
    idx.truncate(d);
    idx
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn schedule_matches_paper() {
        let n = 1000;
        assert_eq!(adaptive_d(501, n), 8);
        assert_eq!(adaptive_d(500, n), 4);
        assert_eq!(adaptive_d(251, n), 4);
        assert_eq!(adaptive_d(250, n), 2);
        assert_eq!(adaptive_d(126, n), 2);
        assert_eq!(adaptive_d(125, n), 1);
        assert_eq!(adaptive_d(1, n), 1);
    }

    #[test]
    fn schedule_is_monotone_in_candidates() {
        let n = 1024;
        let mut last = usize::MAX;
        for c in (1..=n).rev() {
            let d = adaptive_d(c, n);
            assert!(d <= last, "d grew as |C| shrank");
            last = d;
        }
    }

    #[test]
    fn schedule_uses_live_graph_size() {
        // Regression (ISSUE 3 bugfix): the thresholds must be evaluated
        // against the live residual-graph size the solve loops now pass,
        // not the original N. 80 candidates in a 100-node remainder of an
        // originally-1000-node graph is a dense (d=8) state — the pinned-N
        // schedule would have collapsed it to d=1 after the removals that
        // accompany a compaction repack.
        assert_eq!(adaptive_d(80, 1000), 1); // what pinning N would yield
        assert_eq!(adaptive_d(80, 100), 8); // live-count schedule
        assert_eq!(select_count(SelectionPolicy::AdaptiveMulti, 80, 100), 8);
    }

    #[test]
    fn select_count_caps_at_candidates() {
        assert_eq!(select_count(SelectionPolicy::AdaptiveMulti, 3, 4), 3);
        assert_eq!(select_count(SelectionPolicy::Single, 100, 100), 1);
        assert_eq!(select_count(SelectionPolicy::FixedMulti(5), 100, 100), 5);
        assert_eq!(select_count(SelectionPolicy::FixedMulti(0), 100, 100), 1);
    }

    #[test]
    fn top_d_orders_and_filters() {
        let scores = [0.1, 5.0, 3.0, 4.0, -1.0];
        let picked = top_d(&scores, |v| v != 1, 2);
        assert_eq!(picked, vec![3, 2]);
        let all = top_d(&scores, |_| true, 10);
        assert_eq!(all, vec![1, 3, 2, 0, 4]);
    }

    #[test]
    fn top_d_tie_break_is_deterministic() {
        let scores = [1.0, 1.0, 1.0];
        assert_eq!(top_d(&scores, |_| true, 2), vec![0, 1]);
    }

    #[test]
    fn prop_top_d_returns_candidates_sorted() {
        prop::check(
            "top-d-sorted",
            40,
            |r| {
                let n = 5 + r.gen_range(50);
                let scores: Vec<f32> = (0..n).map(|_| r.next_normal()).collect();
                let mask: Vec<bool> = (0..n).map(|_| r.next_f32() < 0.6).collect();
                let d = 1 + r.gen_range(8);
                (scores, mask, d)
            },
            |(scores, mask, d)| {
                let picked = top_d(scores, |v| mask[v], *d);
                picked.len() <= *d
                    && picked.iter().all(|&v| mask[v])
                    && picked.windows(2).all(|w| scores[w[0]] >= scores[w[1]])
            },
        );
    }
}
