//! Per-shard distributed state in two storage modes (DESIGN.md §7):
//!
//! - [`ShardState`] — the dense compute-path mirror of the paper's three
//!   distributed data structures (§4.1, Fig. 2): sub-adjacency A^i
//!   (B×NI×N f32), candidate set C^i (B×NI) and partial solution S^i
//!   (B×NI). O(B·NI·N) memory per shard; the golden oracle.
//! - [`SparseShard`] — the paper's distributed *sparse* storage (§4.1):
//!   the same S/C vectors plus the shard's directed edges as padded
//!   (source-chunk × destination-chunk) tiles with a per-batch-element
//!   live-edge mask, and the live out-degree vector. O(B·NI + E_i·(2+B))
//!   memory, where E_i is the shard's directed edge count — the adjacency
//!   term scales with edges, never NI·N.
//!
//! The coordinator keeps either mode in lockstep with the host-side
//! environment: node selection zeroes the node's local row and its column
//! on every shard (Fig. 4) — realized densely as row/column zeroing and
//! sparsely as live-mask clearing of every incident edge — sets S, and
//! clears C. [`mirror_selection`] is generic over the two so the solve
//! loops cannot drift between them.

use crate::env::GraphEnv;
use crate::graph::{Graph, Partition};
use std::collections::BTreeMap;

/// One shard's tensor state for a batch of B graph instances.
#[derive(Debug, Clone)]
pub struct ShardState {
    /// The row partition this shard belongs to.
    pub part: Partition,
    /// This shard's index (0..P).
    pub shard: usize,
    /// Batch size B.
    pub b: usize,
    /// Dense sub-adjacency, B × NI × N row-major.
    pub a: Vec<f32>,
    /// Partial solution, B × NI.
    pub s: Vec<f32>,
    /// Candidate set, B × NI.
    pub c: Vec<f32>,
    /// Locally-owned A-rows zeroed since the last `take_dirty`, as
    /// (batch element, local row). Fuels the device-residency delta path:
    /// instead of re-uploading the full B×NI×N adjacency, the coordinator
    /// patches the device copy with these deltas (fwd.rs `DeviceState`).
    dirty_rows: Vec<(u32, u32)>,
    /// A-columns zeroed since the last `take_dirty`, as (batch element,
    /// global column).
    dirty_cols: Vec<(u32, u32)>,
}

impl ShardState {
    /// Build shard `shard` of the partition for a batch of graphs, given
    /// per-graph removed masks (residual graph) and solution masks. The
    /// candidate mask is provided per graph as well (environment-defined).
    pub fn from_graphs(
        part: Partition,
        shard: usize,
        graphs: &[&Graph],
        removed: &[&[bool]],
        solution: &[&[bool]],
        candidates: &[&[bool]],
    ) -> ShardState {
        let b = graphs.len();
        assert!(b > 0 && removed.len() == b && solution.len() == b && candidates.len() == b);
        let (n, ni) = (part.n, part.ni());
        let row0 = part.row0(shard);
        let mut a = vec![0.0f32; b * ni * n];
        let mut s = vec![0.0f32; b * ni];
        let mut c = vec![0.0f32; b * ni];
        for (g_idx, g) in graphs.iter().enumerate() {
            assert!(g.n <= n, "graph larger than bucket");
            g.densify_rows(row0, ni, n, removed[g_idx], &mut a[g_idx * ni * n..(g_idx + 1) * ni * n]);
            for r in 0..ni {
                let v = row0 + r;
                if v < g.n {
                    s[g_idx * ni + r] = solution[g_idx][v] as u32 as f32;
                    c[g_idx * ni + r] = candidates[g_idx][v] as u32 as f32;
                }
            }
        }
        ShardState { part, shard, b, a, s, c, dirty_rows: Vec::new(), dirty_cols: Vec::new() }
    }

    /// Build a shard directly from dense full-graph tensors (B×N×N
    /// adjacency, B×N solution/candidate vectors). Used by the golden-vector
    /// integration tests where the state comes from the python build step.
    pub fn from_dense(
        part: Partition,
        shard: usize,
        b: usize,
        a_full: &[f32],
        s_full: &[f32],
        c_full: &[f32],
    ) -> ShardState {
        let (n, ni) = (part.n, part.ni());
        assert_eq!(a_full.len(), b * n * n);
        assert_eq!(s_full.len(), b * n);
        assert_eq!(c_full.len(), b * n);
        let row0 = part.row0(shard);
        let mut a = vec![0.0f32; b * ni * n];
        let mut s = vec![0.0f32; b * ni];
        let mut c = vec![0.0f32; b * ni];
        for g in 0..b {
            for r in 0..ni {
                let v = row0 + r;
                a[g * ni * n + r * n..g * ni * n + (r + 1) * n]
                    .copy_from_slice(&a_full[g * n * n + v * n..g * n * n + (v + 1) * n]);
                s[g * ni + r] = s_full[g * n + v];
                c[g * ni + r] = c_full[g * n + v];
            }
        }
        ShardState { part, shard, b, a, s, c, dirty_rows: Vec::new(), dirty_cols: Vec::new() }
    }

    /// Reassemble a shard from its wire-decoded tensors (the rank
    /// transport ships exactly these fields). Dirty tracking starts
    /// clean: deltas are applied coordinator-side and shipped as
    /// explicit `Sync` requests, never re-derived on the worker.
    pub(crate) fn from_wire(
        part: Partition,
        shard: usize,
        b: usize,
        a: Vec<f32>,
        s: Vec<f32>,
        c: Vec<f32>,
    ) -> ShardState {
        ShardState { part, shard, b, a, s, c, dirty_rows: Vec::new(), dirty_cols: Vec::new() }
    }

    /// Shard height NI = N / P.
    pub fn ni(&self) -> usize {
        self.part.ni()
    }

    /// Padded global node count N.
    pub fn n(&self) -> usize {
        self.part.n
    }

    /// Whether global node v lives on this shard.
    pub fn owns(&self, v: usize) -> bool {
        self.part.owner(v) == self.shard
    }

    /// Apply "select node v into the solution" for batch element g_idx
    /// (Fig. 4): zero v's row (if local) and v's column (always), set S,
    /// clear C for v. This fuses `set_solution` + `apply_remove` — the MVC
    /// semantics where selection and residual-removal coincide.
    pub fn apply_select(&mut self, g_idx: usize, v: usize) {
        self.set_solution(g_idx, v);
        self.apply_remove(g_idx, v);
    }

    /// Mark node v as part of batch element g_idx's solution (S only; the
    /// residual graph is updated separately via `apply_remove`, since
    /// scenarios differ in what selection removes — MVC drops the node,
    /// MIS drops its closed neighborhood, MaxCut drops nothing).
    pub fn set_solution(&mut self, g_idx: usize, v: usize) {
        let ni = self.ni();
        assert!(g_idx < self.b && v < self.n());
        if self.owns(v) {
            let r = self.part.local(v);
            self.s[g_idx * ni + r] = 1.0;
        }
    }

    /// Remove node v from batch element g_idx's residual graph (Fig. 4):
    /// zero v's row (if local) and v's column (always), clear C for v.
    pub fn apply_remove(&mut self, g_idx: usize, v: usize) {
        let (n, ni) = (self.n(), self.ni());
        assert!(g_idx < self.b && v < n);
        let base_a = g_idx * ni * n;
        if self.owns(v) {
            let r = self.part.local(v);
            self.a[base_a + r * n..base_a + (r + 1) * n].fill(0.0);
            self.c[g_idx * ni + r] = 0.0;
            self.dirty_rows.push((g_idx as u32, r as u32));
        }
        // Zero column v across all local rows.
        for r in 0..ni {
            self.a[base_a + r * n + v] = 0.0;
        }
        self.dirty_cols.push((g_idx as u32, v as u32));
    }

    /// Whether A has been mutated since the last `take_dirty`.
    pub fn is_dirty(&self) -> bool {
        !self.dirty_rows.is_empty() || !self.dirty_cols.is_empty()
    }

    /// Consume the recorded A-deltas: (zeroed local rows, zeroed columns),
    /// each as (batch element, index) pairs. Resets the dirty sets.
    pub fn take_dirty(&mut self) -> (Vec<(u32, u32)>, Vec<(u32, u32)>) {
        (std::mem::take(&mut self.dirty_rows), std::mem::take(&mut self.dirty_cols))
    }

    /// Forget recorded deltas (after a fresh full upload of A).
    pub fn clear_dirty(&mut self) {
        self.dirty_rows.clear();
        self.dirty_cols.clear();
    }

    /// Replay removal deltas recorded on another replica of this shard —
    /// the rank-parallel engine ships `(rows, cols)` pairs instead of the
    /// full adjacency (DESIGN.md §9). Zeroes exactly those rows/columns
    /// and records them as dirty, so a following device `sync` patches
    /// exactly what the originating replica's removals touched.
    pub fn apply_removed_deltas(&mut self, rows: &[(u32, u32)], cols: &[(u32, u32)]) {
        let (n, ni) = (self.n(), self.ni());
        for &(g, r) in rows {
            assert!((g as usize) < self.b && (r as usize) < ni, "row delta out of range");
            let base = g as usize * ni * n + r as usize * n;
            self.a[base..base + n].fill(0.0);
            self.dirty_rows.push((g, r));
        }
        for &(g, v) in cols {
            assert!((g as usize) < self.b && (v as usize) < n, "col delta out of range");
            let base = g as usize * ni * n;
            for r in 0..ni {
                self.a[base + r * n + v as usize] = 0.0;
            }
            self.dirty_cols.push((g, v));
        }
    }

    /// Refresh the candidate mask for batch element g_idx from the
    /// environment's candidate predicate (the host owns candidate logic).
    pub fn refresh_candidates(&mut self, g_idx: usize, is_candidate: impl Fn(usize) -> bool) {
        refresh_candidate_row(self.part, self.shard, &mut self.c, g_idx, is_candidate);
    }

    /// Bytes held by this shard's tensors (memory accounting, §5.2).
    pub fn bytes(&self) -> usize {
        4 * (self.a.len() + self.s.len() + self.c.len())
    }

    /// Bytes of the adjacency representation alone (the B·NI·N·4 term the
    /// sparse path eliminates; compared by `bench_memory`).
    pub fn adjacency_bytes(&self) -> usize {
        4 * self.a.len()
    }
}

/// Which per-shard storage a solve/train loop should use (DESIGN.md §7).
///
/// `Dense` materializes the B×NI×N sub-adjacency (the golden oracle path);
/// `Sparse` stores CSR-derived edge tiles and scales with the edge count.
/// The chunk size and edge-capacity ladder of the sparse path come from
/// the artifact manifest at solve time (`Manifest::sparse_config`), so the
/// knob itself stays `Copy`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Storage {
    /// Dense B×NI×N sub-adjacency per shard (the reference path).
    #[default]
    Dense,
    /// CSR-backed edge tiles + live-edge masks per shard (O(E/P + NI)).
    Sparse,
}

impl Storage {
    /// Parse a CLI value (`dense` | `sparse`).
    pub fn parse(s: &str) -> anyhow::Result<Storage> {
        match s.to_ascii_lowercase().as_str() {
            "dense" => Ok(Storage::Dense),
            "sparse" | "csr" => Ok(Storage::Sparse),
            other => anyhow::bail!("unknown storage '{other}' (dense|sparse)"),
        }
    }
}

/// One padded edge tile of a [`SparseShard`]: the live directed edges from
/// source rows [sc·NC, (sc+1)·NC) of the shard into global destination
/// columns [dc·NC, (dc+1)·NC), padded to a compiled edge capacity.
#[derive(Debug, Clone)]
pub struct EdgeTile {
    /// Source chunk index within the shard's NI rows.
    pub sc: usize,
    /// Destination chunk index within the global N columns.
    pub dc: usize,
    /// Compiled edge capacity EC this tile is padded to (its artifact
    /// bucket); `src`/`dst` have this length, `w` is B×EC.
    pub cap: usize,
    /// Number of real (non-padding) edges in the tile.
    pub len: usize,
    /// Chunk-local source row index per edge slot, as f32 (the runtime's
    /// upload path is f32-only; indices < 2^24 are exact).
    pub src: Vec<f32>,
    /// Chunk-local destination column index per edge slot, as f32.
    pub dst: Vec<f32>,
    /// Live-edge mask, B×EC row-major: w[g·EC+e] is 1.0 iff edge slot e
    /// carries a live edge of batch element g (0.0 for padding, removed
    /// edges, and edges belonging to other graphs of the pack).
    pub w: Vec<f32>,
}

/// One shard's sparse tensor state for a batch of B graph instances
/// (DESIGN.md §7): S/C vectors as in [`ShardState`], plus edge tiles with
/// live masks and the live out-degree vector that replaces the dense
/// adjacency row sum in `embed_pre_sp`.
#[derive(Debug, Clone)]
pub struct SparseShard {
    /// The row partition this shard belongs to.
    pub part: Partition,
    /// This shard's index (0..P).
    pub shard: usize,
    /// Batch size B.
    pub b: usize,
    /// Node chunk NC (source rows and destination columns are tiled in
    /// chunks of this many nodes; the compiled `embed_msg_sp` shape).
    pub chunk: usize,
    /// Edge tiles, ordered by (sc, dc) with overflow chained in place.
    pub tiles: Vec<EdgeTile>,
    /// Partial solution, B × NI.
    pub s: Vec<f32>,
    /// Candidate set, B × NI.
    pub c: Vec<f32>,
    /// Live out-degree per local row, B × NI (consumed by `embed_pre_sp`;
    /// integers, so bit-identical to the dense on-device row sum).
    pub deg: Vec<f32>,
    /// (batch element · N + global node) → every (tile, slot) the node is
    /// an endpoint of. Host-only index that makes removal O(degree).
    incidence: Vec<Vec<(u32, u32)>>,
    /// Tiles whose live mask changed since the last `take_dirty_tiles`
    /// (may contain duplicates until taken).
    dirty_tiles: Vec<u32>,
}

impl SparseShard {
    /// Build shard `shard` of the partition for a batch of graphs, given
    /// per-graph removed/solution/candidate masks — the sparse analog of
    /// [`ShardState::from_graphs`]. `edge_caps` is the compiled capacity
    /// ladder (ascending after internal sort); each (source-chunk,
    /// destination-chunk) bucket is split into tiles of the smallest
    /// capacity that fits the remainder, chaining overflow through tiles of
    /// the largest capacity (python/tests/dist_sim.py `build_tiles` is the
    /// executable specification of this layout).
    #[allow(clippy::too_many_arguments)]
    pub fn from_graphs(
        part: Partition,
        shard: usize,
        graphs: &[&Graph],
        removed: &[&[bool]],
        solution: &[&[bool]],
        candidates: &[&[bool]],
        chunk: usize,
        edge_caps: &[usize],
    ) -> SparseShard {
        let b = graphs.len();
        assert!(b > 0 && removed.len() == b && solution.len() == b && candidates.len() == b);
        assert!(chunk > 0, "chunk must be positive");
        let mut caps: Vec<usize> = edge_caps.to_vec();
        caps.sort_unstable();
        caps.dedup();
        assert!(!caps.is_empty(), "need at least one edge capacity");
        let (n, ni) = (part.n, part.ni());
        let row0 = part.row0(shard);

        let mut s = vec![0.0f32; b * ni];
        let mut c = vec![0.0f32; b * ni];
        let mut deg = vec![0.0f32; b * ni];
        // (sc, dc) → (batch element, chunk-local src, chunk-local dst),
        // enumerated batch-element-major then row-major (the canonical
        // tile order shared with the python spec).
        let mut buckets: BTreeMap<(usize, usize), Vec<(u32, u32, u32)>> = BTreeMap::new();
        for (g_idx, g) in graphs.iter().enumerate() {
            assert!(g.n <= n, "graph larger than bucket");
            for (r, u) in g.shard_edges(row0, ni, removed[g_idx]) {
                let (r, u) = (r as usize, u as usize);
                deg[g_idx * ni + r] += 1.0;
                buckets
                    .entry((r / chunk, u / chunk))
                    .or_default()
                    .push((g_idx as u32, (r % chunk) as u32, (u % chunk) as u32));
            }
            for r in 0..ni {
                let v = row0 + r;
                if v < g.n {
                    s[g_idx * ni + r] = solution[g_idx][v] as u32 as f32;
                    c[g_idx * ni + r] = candidates[g_idx][v] as u32 as f32;
                }
            }
        }

        let mut tiles: Vec<EdgeTile> = Vec::new();
        let mut incidence: Vec<Vec<(u32, u32)>> = vec![Vec::new(); b * n];
        for ((sc, dc), edges) in buckets {
            let mut rest = edges.as_slice();
            while !rest.is_empty() {
                let cap = caps
                    .iter()
                    .copied()
                    .find(|&cp| cp >= rest.len())
                    .unwrap_or(*caps.last().unwrap());
                let take = rest.len().min(cap);
                let (head, tail) = rest.split_at(take);
                rest = tail;
                let mut tile = EdgeTile {
                    sc,
                    dc,
                    cap,
                    len: take,
                    src: vec![0.0f32; cap],
                    dst: vec![0.0f32; cap],
                    w: vec![0.0f32; b * cap],
                };
                let t_idx = tiles.len() as u32;
                for (pos, &(g, rl, ul)) in head.iter().enumerate() {
                    tile.src[pos] = rl as f32;
                    tile.dst[pos] = ul as f32;
                    tile.w[g as usize * cap + pos] = 1.0;
                    let gsrc = row0 + sc * chunk + rl as usize;
                    let gdst = dc * chunk + ul as usize;
                    incidence[g as usize * n + gsrc].push((t_idx, pos as u32));
                    incidence[g as usize * n + gdst].push((t_idx, pos as u32));
                }
                tiles.push(tile);
            }
        }

        SparseShard {
            part,
            shard,
            b,
            chunk,
            tiles,
            s,
            c,
            deg,
            incidence,
            dirty_tiles: Vec::new(),
        }
    }

    /// Reassemble a shard from its wire-decoded tensors (the rank
    /// transport ships exactly these fields). The incidence index is
    /// left empty: it only accelerates coordinator-side `apply_remove`,
    /// which workers never call — their live masks are updated through
    /// explicit `Sync` deltas instead.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn from_wire(
        part: Partition,
        shard: usize,
        b: usize,
        chunk: usize,
        tiles: Vec<EdgeTile>,
        s: Vec<f32>,
        c: Vec<f32>,
        deg: Vec<f32>,
    ) -> SparseShard {
        SparseShard {
            part,
            shard,
            b,
            chunk,
            tiles,
            s,
            c,
            deg,
            incidence: Vec::new(),
            dirty_tiles: Vec::new(),
        }
    }

    /// Shard height NI = N / P.
    pub fn ni(&self) -> usize {
        self.part.ni()
    }

    /// Padded global node count N.
    pub fn n(&self) -> usize {
        self.part.n
    }

    /// Whether global node v lives on this shard.
    pub fn owns(&self, v: usize) -> bool {
        self.part.owner(v) == self.shard
    }

    /// Apply "select node v into the solution" for batch element `g_idx`
    /// (Fig. 4): the fused [`SparseShard::set_solution`] +
    /// [`SparseShard::apply_remove`], mirroring the dense path.
    pub fn apply_select(&mut self, g_idx: usize, v: usize) {
        self.set_solution(g_idx, v);
        self.apply_remove(g_idx, v);
    }

    /// Mark node v as part of batch element `g_idx`'s solution (S only).
    pub fn set_solution(&mut self, g_idx: usize, v: usize) {
        let ni = self.ni();
        assert!(g_idx < self.b && v < self.n());
        if self.owns(v) {
            let r = self.part.local(v);
            self.s[g_idx * ni + r] = 1.0;
        }
    }

    /// Remove node v from batch element `g_idx`'s residual graph: clear the
    /// live mask of every incident edge (the sparse realization of Fig. 4's
    /// row+column zeroing), decrement the surviving endpoints' degrees, and
    /// clear C for v if local. O(degree of v) via the incidence index.
    pub fn apply_remove(&mut self, g_idx: usize, v: usize) {
        let (n, ni, chunk) = (self.n(), self.ni(), self.chunk);
        assert!(g_idx < self.b && v < n);
        if self.owns(v) {
            let r = self.part.local(v);
            self.c[g_idx * ni + r] = 0.0;
        }
        for &(t, pos) in &self.incidence[g_idx * n + v] {
            let tile = &mut self.tiles[t as usize];
            let wi = g_idx * tile.cap + pos as usize;
            if tile.w[wi] == 0.0 {
                continue; // already dead (other endpoint removed earlier)
            }
            tile.w[wi] = 0.0;
            let src_row = tile.sc * chunk + tile.src[pos as usize] as usize;
            self.deg[g_idx * ni + src_row] -= 1.0;
            self.dirty_tiles.push(t);
        }
    }

    /// Whether any tile's live mask changed since the last
    /// `take_dirty_tiles`.
    pub fn is_dirty(&self) -> bool {
        !self.dirty_tiles.is_empty()
    }

    /// Consume the recorded live-mask deltas: the (deduplicated, sorted)
    /// tile indices whose `w` changed. The device-resident path re-uploads
    /// exactly these B×EC masks — the sparse analog of the dense `a_mask`
    /// patch (DESIGN.md §7).
    pub fn take_dirty_tiles(&mut self) -> Vec<u32> {
        let mut v = std::mem::take(&mut self.dirty_tiles);
        v.sort_unstable();
        v.dedup();
        v
    }

    /// Forget recorded deltas (after a fresh full upload of every tile).
    pub fn clear_dirty(&mut self) {
        self.dirty_tiles.clear();
    }

    /// Overwrite tile `t`'s live-edge mask with another replica's copy and
    /// mark it dirty — the sparse delta the rank-parallel engine ships per
    /// removal-touched tile (DESIGN.md §9). The replica's `deg`/`c`
    /// vectors are shipped per forward instead, so only `w` is replayed.
    pub fn overwrite_tile_mask(&mut self, t: usize, w: Vec<f32>) {
        let tile = &mut self.tiles[t];
        assert_eq!(w.len(), self.b * tile.cap, "tile {t} mask length mismatch");
        tile.w = w;
        self.dirty_tiles.push(t as u32);
    }

    /// Refresh the candidate mask for batch element `g_idx` from the
    /// environment's candidate predicate (the host owns candidate logic).
    pub fn refresh_candidates(&mut self, g_idx: usize, is_candidate: impl Fn(usize) -> bool) {
        refresh_candidate_row(self.part, self.shard, &mut self.c, g_idx, is_candidate);
    }

    /// Bytes of the f32 tensors a device would hold for this shard
    /// (S + C + deg + every tile's src/dst/w) — the sparse counterpart of
    /// [`ShardState::bytes`].
    pub fn bytes(&self) -> usize {
        4 * (self.s.len() + self.c.len() + self.deg.len())
            + self.tiles.iter().map(|t| 4 * (t.src.len() + t.dst.len() + t.w.len())).sum::<usize>()
    }

    /// Bytes of the adjacency representation alone (edge tiles; excludes
    /// S/C/deg) — what `bench_memory` compares against the dense
    /// B×NI×N·4 figure.
    pub fn adjacency_bytes(&self) -> usize {
        self.tiles.iter().map(|t| 4 * (t.src.len() + t.dst.len() + t.w.len())).sum()
    }

    /// Host-only bytes of the incidence index (removal acceleration; never
    /// uploaded).
    pub fn index_bytes(&self) -> usize {
        self.incidence.iter().map(|v| 8 * v.len()).sum()
    }

    /// Total live directed edges of batch element `g_idx` (test/stat hook).
    pub fn live_edges(&self, g_idx: usize) -> usize {
        self.tiles
            .iter()
            .map(|t| {
                (0..t.len).filter(|&e| t.w[g_idx * t.cap + e] != 0.0).count()
            })
            .sum()
    }

    /// Reconstruct batch element `g_idx`'s dense NI×N sub-adjacency from
    /// the live tiles — the oracle hook the dense/sparse parity tests
    /// compare against [`ShardState::a`].
    pub fn densify(&self, g_idx: usize) -> Vec<f32> {
        let (n, ni, chunk) = (self.n(), self.ni(), self.chunk);
        let mut a = vec![0.0f32; ni * n];
        for t in &self.tiles {
            for e in 0..t.len {
                if t.w[g_idx * t.cap + e] != 0.0 {
                    let row = t.sc * chunk + t.src[e] as usize;
                    let col = t.dc * chunk + t.dst[e] as usize;
                    a[row * n + col] = 1.0;
                }
            }
        }
        a
    }
}

/// Shared candidate-mask refresh over one shard's C row (both storage
/// modes store C as a B×NI f32 vector): rows past the real graph and
/// non-candidates go to 0.0. One body, so the dense and sparse candidate
/// masks cannot drift.
fn refresh_candidate_row(
    part: Partition,
    shard: usize,
    c: &mut [f32],
    g_idx: usize,
    is_candidate: impl Fn(usize) -> bool,
) {
    let ni = part.ni();
    let row0 = part.row0(shard);
    for r in 0..ni {
        let v = row0 + r;
        c[g_idx * ni + r] = if v < part.n && is_candidate(v) { 1.0 } else { 0.0 };
    }
}

/// The shard mutations a solve loop applies on every selection, shared by
/// the dense and sparse storage modes so [`mirror_selection`] (and with it
/// the sequential and batched loops) is storage-generic.
pub trait ShardStateOps {
    /// Mark node v as part of batch element `g_idx`'s solution.
    fn set_solution(&mut self, g_idx: usize, v: usize);
    /// Remove node v from batch element `g_idx`'s residual graph.
    fn apply_remove(&mut self, g_idx: usize, v: usize);
}

impl ShardStateOps for ShardState {
    fn set_solution(&mut self, g_idx: usize, v: usize) {
        ShardState::set_solution(self, g_idx, v);
    }
    fn apply_remove(&mut self, g_idx: usize, v: usize) {
        ShardState::apply_remove(self, g_idx, v);
    }
}

impl ShardStateOps for SparseShard {
    fn set_solution(&mut self, g_idx: usize, v: usize) {
        SparseShard::set_solution(self, g_idx, v);
    }
    fn apply_remove(&mut self, g_idx: usize, v: usize) {
        SparseShard::apply_remove(self, g_idx, v);
    }
}

/// Build all P shards for a single graph instance (inference entry).
pub fn shards_for_graph(
    part: Partition,
    g: &Graph,
    removed: &[bool],
    solution: &[bool],
    candidates: &[bool],
) -> Vec<ShardState> {
    (0..part.p)
        .map(|i| {
            ShardState::from_graphs(part, i, &[g], &[removed], &[solution], &[candidates])
        })
        .collect()
}

/// Mirror one environment selection onto the shard tensors (batch element
/// `g_idx`): set S for the picked node, then diff the environment's removed
/// mask against `removed_prev` and zero rows/cols of newly removed nodes.
/// The diff is what makes the mirroring scenario-generic — MVC removes the
/// node itself, MIS its closed neighborhood, MaxCut nothing — and it is
/// shared by the sequential (`infer::solve_env`) and batched
/// (`batch::solve_pack`) loops, and generic over the dense/sparse storage
/// modes ([`ShardStateOps`]), so the per-graph trajectories cannot drift
/// apart across any of those axes.
pub fn mirror_selection<S: ShardStateOps>(
    shards: &mut [S],
    g_idx: usize,
    v: usize,
    env: &dyn GraphEnv,
    removed_prev: &mut [bool],
) {
    for sh in shards.iter_mut() {
        sh.set_solution(g_idx, v);
    }
    let rm = env.removed_mask();
    for u in 0..env.num_nodes() {
        if rm[u] && !removed_prev[u] {
            removed_prev[u] = true;
            for sh in shards.iter_mut() {
                sh.apply_remove(g_idx, u);
            }
        }
    }
}

/// Build all P shards for a pack of graph instances (batched inference
/// entry): one block-diagonal batch element per graph.
pub fn shards_for_pack(
    part: Partition,
    graphs: &[&Graph],
    removed: &[&[bool]],
    solution: &[&[bool]],
    candidates: &[&[bool]],
) -> Vec<ShardState> {
    (0..part.p)
        .map(|i| ShardState::from_graphs(part, i, graphs, removed, solution, candidates))
        .collect()
}

/// Build all P sparse shards for a single graph instance (the [`Storage::Sparse`]
/// analog of [`shards_for_graph`]).
pub fn sparse_shards_for_graph(
    part: Partition,
    g: &Graph,
    removed: &[bool],
    solution: &[bool],
    candidates: &[bool],
    chunk: usize,
    edge_caps: &[usize],
) -> Vec<SparseShard> {
    (0..part.p)
        .map(|i| {
            SparseShard::from_graphs(
                part,
                i,
                &[g],
                &[removed],
                &[solution],
                &[candidates],
                chunk,
                edge_caps,
            )
        })
        .collect()
}

/// Build all P sparse shards for a pack of graph instances (the
/// [`Storage::Sparse`] analog of [`shards_for_pack`]).
#[allow(clippy::too_many_arguments)]
pub fn sparse_shards_for_pack(
    part: Partition,
    graphs: &[&Graph],
    removed: &[&[bool]],
    solution: &[&[bool]],
    candidates: &[&[bool]],
    chunk: usize,
    edge_caps: &[usize],
) -> Vec<SparseShard> {
    (0..part.p)
        .map(|i| {
            SparseShard::from_graphs(
                part, i, graphs, removed, solution, candidates, chunk, edge_caps,
            )
        })
        .collect()
}

/// A full shard set in either storage mode — what the solve/train loops
/// hold, so one loop body serves both paths (DESIGN.md §7).
#[derive(Debug, Clone)]
pub enum ShardSet {
    /// P dense shards (B×NI×N adjacency each).
    Dense(Vec<ShardState>),
    /// P sparse shards (edge tiles + live masks each).
    Sparse(Vec<SparseShard>),
}

impl ShardSet {
    /// Number of shards P.
    pub fn len(&self) -> usize {
        match self {
            ShardSet::Dense(v) => v.len(),
            ShardSet::Sparse(v) => v.len(),
        }
    }

    /// Whether the set holds no shards (empty pack).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Storage mode of this set.
    pub fn storage(&self) -> Storage {
        match self {
            ShardSet::Dense(_) => Storage::Dense,
            ShardSet::Sparse(_) => Storage::Sparse,
        }
    }

    /// Batch size B (shards agree by construction).
    pub fn b(&self) -> usize {
        match self {
            ShardSet::Dense(v) => v[0].b,
            ShardSet::Sparse(v) => v[0].b,
        }
    }

    /// Mirror one environment selection onto every shard (see
    /// [`mirror_selection`]).
    pub fn mirror_selection(
        &mut self,
        g_idx: usize,
        v: usize,
        env: &dyn GraphEnv,
        removed_prev: &mut [bool],
    ) {
        match self {
            ShardSet::Dense(sh) => mirror_selection(sh, g_idx, v, env, removed_prev),
            ShardSet::Sparse(sh) => mirror_selection(sh, g_idx, v, env, removed_prev),
        }
    }

    /// Apply "select v" (S + residual removal) on every shard — the
    /// training loop's MVC fused update.
    pub fn apply_select(&mut self, g_idx: usize, v: usize) {
        match self {
            ShardSet::Dense(sh) => sh.iter_mut().for_each(|s| s.apply_select(g_idx, v)),
            ShardSet::Sparse(sh) => sh.iter_mut().for_each(|s| s.apply_select(g_idx, v)),
        }
    }

    /// Refresh batch element `g_idx`'s candidate mask on every shard.
    pub fn refresh_candidates(&mut self, g_idx: usize, is_candidate: impl Fn(usize) -> bool) {
        match self {
            ShardSet::Dense(sh) => {
                sh.iter_mut().for_each(|s| s.refresh_candidates(g_idx, &is_candidate))
            }
            ShardSet::Sparse(sh) => {
                sh.iter_mut().for_each(|s| s.refresh_candidates(g_idx, &is_candidate))
            }
        }
    }

    /// Bytes held by all shards' f32 tensors (memory accounting, §5.2/§7).
    pub fn bytes(&self) -> usize {
        match self {
            ShardSet::Dense(sh) => sh.iter().map(|s| s.bytes()).sum(),
            ShardSet::Sparse(sh) => sh.iter().map(|s| s.bytes()).sum(),
        }
    }

    /// Forget recorded deltas on every shard — after a full re-upload (or
    /// after shipping replicas that captured the current state, as the
    /// rank-parallel install does).
    pub fn clear_dirty(&mut self) {
        match self {
            ShardSet::Dense(sh) => sh.iter_mut().for_each(|s| s.clear_dirty()),
            ShardSet::Sparse(sh) => sh.iter_mut().for_each(|s| s.clear_dirty()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Graph;

    fn square() -> Graph {
        // 0-1-2-3-0 cycle
        Graph::from_edges(4, &[(0, 1), (1, 2), (2, 3), (0, 3)]).unwrap()
    }

    fn fresh(part: Partition, g: &Graph) -> Vec<ShardState> {
        let removed = vec![false; g.n];
        let sol = vec![false; g.n];
        let cand: Vec<bool> = (0..g.n).map(|v| g.degree(v) > 0).collect();
        shards_for_graph(part, g, &removed, &sol, &cand)
    }

    #[test]
    fn densified_rows_match_graph() {
        let g = square();
        let part = Partition::new(4, 2);
        let shards = fresh(part, &g);
        // shard 0 holds rows 0,1: row0 = [0,1,0,1]; row1 = [1,0,1,0]
        assert_eq!(&shards[0].a[..4], &[0.0, 1.0, 0.0, 1.0]);
        assert_eq!(&shards[0].a[4..8], &[1.0, 0.0, 1.0, 0.0]);
        assert_eq!(shards[0].c, vec![1.0, 1.0]);
        assert_eq!(shards[1].s, vec![0.0, 0.0]);
    }

    #[test]
    fn apply_select_zeroes_row_and_col() {
        let g = square();
        let part = Partition::new(4, 2);
        let mut shards = fresh(part, &g);
        for sh in shards.iter_mut() {
            sh.apply_select(0, 1);
        }
        // Node 1 lives on shard 0 row 1: row zeroed, S set, C cleared.
        assert_eq!(&shards[0].a[4..8], &[0.0; 4]);
        assert_eq!(shards[0].s, vec![0.0, 1.0]);
        assert_eq!(shards[0].c, vec![1.0, 0.0]);
        // Column 1 zeroed everywhere.
        assert_eq!(shards[0].a[1], 0.0);
        assert_eq!(shards[1].a[1], 0.0);
        assert_eq!(shards[1].a[4 + 1], 0.0);
        // Untouched edge (2,3) survives on shard 1.
        assert_eq!(shards[1].a[3], 1.0); // row for node 2, col 3
    }

    #[test]
    fn padding_rows_stay_zero() {
        let g = Graph::from_edges(2, &[(0, 1)]).unwrap();
        let part = Partition::new(12, 3); // padded bucket
        let shards = fresh(part, &g);
        // shard 0 rows 0..4: nodes 0,1 real; 2,3 padding.
        assert_eq!(shards[0].c, vec![1.0, 1.0, 0.0, 0.0]);
        assert!(shards[1].a.iter().all(|&x| x == 0.0));
        assert!(shards[2].c.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn batch_layout_is_per_graph() {
        let g1 = square();
        let g2 = Graph::from_edges(4, &[(0, 2)]).unwrap();
        let part = Partition::new(4, 1);
        let removed = vec![false; 4];
        let sol = vec![false; 4];
        let cand = vec![true; 4];
        let sh = ShardState::from_graphs(
            part,
            0,
            &[&g1, &g2],
            &[&removed, &removed],
            &[&sol, &sol],
            &[&cand, &cand],
        );
        assert_eq!(sh.b, 2);
        assert_eq!(sh.a.len(), 2 * 4 * 4);
        // Graph 2's block has only edge (0,2).
        let block2 = &sh.a[16..32];
        assert_eq!(block2.iter().filter(|&&x| x == 1.0).count(), 2);
        assert_eq!(block2[2], 1.0);
        assert_eq!(block2[8], 1.0);
    }

    #[test]
    fn set_solution_without_removal_keeps_rows() {
        // MaxCut semantics: selection marks S but the node stays in the
        // residual graph (no row/col zeroing).
        let g = square();
        let part = Partition::new(4, 2);
        let mut shards = fresh(part, &g);
        for sh in shards.iter_mut() {
            sh.set_solution(0, 1);
        }
        assert_eq!(shards[0].s, vec![0.0, 1.0]);
        assert_eq!(&shards[0].a[4..8], &[1.0, 0.0, 1.0, 0.0]); // row intact
        assert_eq!(shards[1].a[1], 1.0); // column intact
    }

    #[test]
    fn apply_select_equals_solution_plus_remove() {
        let g = square();
        let part = Partition::new(4, 2);
        let mut a = fresh(part, &g);
        let mut b = fresh(part, &g);
        for sh in a.iter_mut() {
            sh.apply_select(0, 2);
        }
        for sh in b.iter_mut() {
            sh.set_solution(0, 2);
            sh.apply_remove(0, 2);
        }
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.a, y.a);
            assert_eq!(x.s, y.s);
            assert_eq!(x.c, y.c);
        }
    }

    #[test]
    fn dirty_tracking_records_removed_rows_and_cols() {
        let g = square();
        let part = Partition::new(4, 2);
        let mut shards = fresh(part, &g);
        assert!(!shards[0].is_dirty() && !shards[1].is_dirty());
        for sh in shards.iter_mut() {
            sh.apply_remove(0, 1);
        }
        // Node 1 lives on shard 0 (local row 1): row dirty there only; the
        // column is dirty on every shard.
        assert!(shards[0].is_dirty() && shards[1].is_dirty());
        let (rows0, cols0) = shards[0].take_dirty();
        assert_eq!(rows0, vec![(0, 1)]);
        assert_eq!(cols0, vec![(0, 1)]);
        let (rows1, cols1) = shards[1].take_dirty();
        assert!(rows1.is_empty());
        assert_eq!(cols1, vec![(0, 1)]);
        // take_dirty resets; clear_dirty drops pending deltas.
        assert!(!shards[0].is_dirty());
        shards[1].apply_remove(0, 3);
        assert!(shards[1].is_dirty());
        shards[1].clear_dirty();
        assert!(!shards[1].is_dirty());
    }

    #[test]
    fn apply_removed_deltas_replays_a_replica() {
        // The rank-parallel delta path: replaying (rows, cols) on a replica
        // must reproduce the originating shard's adjacency and dirty sets.
        let g = square();
        let part = Partition::new(4, 2);
        let mut origin = fresh(part, &g);
        let mut replica = fresh(part, &g);
        for sh in origin.iter_mut() {
            sh.apply_remove(0, 1);
            sh.apply_remove(0, 2);
        }
        for (o, r) in origin.iter_mut().zip(replica.iter_mut()) {
            let (rows, cols) = o.take_dirty();
            r.apply_removed_deltas(&rows, &cols);
            assert_eq!(r.a, o.a, "replica adjacency diverged");
            assert!(r.is_dirty(), "replica must record the replayed deltas");
            let (rr, rc) = r.take_dirty();
            assert_eq!(rr, rows);
            assert_eq!(rc, cols);
        }
    }

    #[test]
    fn overwrite_tile_mask_replays_a_replica() {
        let g = square();
        let part = Partition::new(4, 1);
        let mut origin = fresh_sparse(part, &g, 2, &[8]).remove(0);
        let mut replica = origin.clone();
        origin.apply_remove(0, 1);
        let dirty = origin.take_dirty_tiles();
        assert!(!dirty.is_empty());
        for &t in &dirty {
            replica.overwrite_tile_mask(t as usize, origin.tiles[t as usize].w.clone());
        }
        assert!(replica.is_dirty());
        assert_eq!(replica.take_dirty_tiles(), dirty);
        assert_eq!(replica.densify(0), origin.densify(0));
    }

    #[test]
    fn shard_set_clear_dirty_clears_every_shard() {
        let g = square();
        let part = Partition::new(4, 2);
        let mut set = ShardSet::Dense(fresh(part, &g));
        set.apply_select(0, 1);
        set.clear_dirty();
        if let ShardSet::Dense(sh) = &set {
            assert!(sh.iter().all(|s| !s.is_dirty()));
        }
    }

    fn fresh_sparse(part: Partition, g: &Graph, chunk: usize, caps: &[usize]) -> Vec<SparseShard> {
        let removed = vec![false; g.n];
        let sol = vec![false; g.n];
        let cand: Vec<bool> = (0..g.n).map(|v| g.degree(v) > 0).collect();
        sparse_shards_for_graph(part, g, &removed, &sol, &cand, chunk, caps)
    }

    #[test]
    fn sparse_densify_matches_dense_shard() {
        // The sparse tiles must reconstruct exactly the dense sub-adjacency,
        // and S/C/deg must agree with the dense shard's state — for every
        // shard, including a chunk that does not divide NI (NC=3 vs NI=2).
        let g = square();
        for (p, chunk) in [(1usize, 2usize), (2, 2), (2, 3), (4, 12)] {
            let part = Partition::new(4, p);
            let dense = fresh(part, &g);
            let sparse = fresh_sparse(part, &g, chunk, &[2, 8]);
            for (d, sp) in dense.iter().zip(&sparse) {
                assert_eq!(sp.densify(0), d.a, "P={p} chunk={chunk}");
                assert_eq!(sp.s, d.s);
                assert_eq!(sp.c, d.c);
                let ni = part.ni();
                for r in 0..ni {
                    let want: f32 = d.a[r * 4..(r + 1) * 4].iter().sum();
                    assert_eq!(sp.deg[r], want, "deg row {r}");
                }
            }
        }
    }

    #[test]
    fn sparse_remove_matches_dense_zeroing() {
        // apply_remove on both paths, then compare densified adjacency,
        // C, and deg — the Fig. 4 update equivalence.
        let g = square();
        let part = Partition::new(4, 2);
        let mut dense = fresh(part, &g);
        let mut sparse = fresh_sparse(part, &g, 2, &[8]);
        for v in [1usize, 3] {
            for sh in dense.iter_mut() {
                sh.apply_select(0, v);
            }
            for sh in sparse.iter_mut() {
                sh.apply_select(0, v);
            }
        }
        for (d, sp) in dense.iter().zip(&sparse) {
            assert_eq!(sp.densify(0), d.a);
            assert_eq!(sp.s, d.s);
            assert_eq!(sp.c, d.c);
            let ni = part.ni();
            for r in 0..ni {
                let want: f32 = d.a[r * 4..(r + 1) * 4].iter().sum();
                assert_eq!(sp.deg[r], want, "deg row {r} after removals");
            }
        }
        // Everything incident to nodes 1 and 3 is dead: square 0-1-2-3-0
        // loses all four edges.
        assert_eq!(sparse[0].live_edges(0) + sparse[1].live_edges(0), 0);
    }

    #[test]
    fn sparse_dirty_tiles_track_mask_changes() {
        let g = square();
        let part = Partition::new(4, 1);
        let mut sp = fresh_sparse(part, &g, 2, &[8]).remove(0);
        assert!(!sp.is_dirty());
        sp.apply_remove(0, 1);
        assert!(sp.is_dirty());
        let dirty = sp.take_dirty_tiles();
        assert!(!dirty.is_empty());
        assert!(dirty.windows(2).all(|w| w[0] < w[1]), "sorted + deduped");
        assert!(!sp.is_dirty());
        // Removing the opposite corner re-dirties; clear_dirty drops it.
        sp.apply_remove(0, 3);
        assert!(sp.is_dirty());
        sp.clear_dirty();
        assert!(!sp.is_dirty());
        // Double-removal of an already-dead neighborhood changes nothing
        // and records no dirty tiles.
        let before = sp.densify(0);
        sp.apply_remove(0, 1);
        assert!(!sp.is_dirty());
        assert_eq!(sp.densify(0), before);
    }

    #[test]
    fn sparse_tile_chaining_respects_caps() {
        // A capacity ladder smaller than a bucket's edge count must chain
        // tiles; all real edges survive and padding stays masked.
        let g = Graph::from_edges(
            6,
            &[(0, 1), (0, 2), (0, 3), (0, 4), (0, 5), (1, 2), (1, 3), (1, 4)],
        )
        .unwrap();
        let part = Partition::new(12, 1);
        let sp = fresh_sparse(part, &g, 12, &[2, 4]).remove(0);
        // 16 directed edges in one (0,0) bucket with max cap 4 → ≥ 4 tiles.
        assert!(sp.tiles.len() >= 4, "expected chained tiles, got {}", sp.tiles.len());
        for t in &sp.tiles {
            assert!(t.len <= t.cap);
            assert_eq!(t.src.len(), t.cap);
            assert_eq!(t.w.len(), t.cap); // b = 1
            for e in t.len..t.cap {
                assert_eq!(t.w[e], 0.0, "padding slot live");
            }
        }
        assert_eq!(sp.live_edges(0), 16);
        let mut dense = vec![0.0f32; 12 * 12];
        g.densify_rows(0, 12, 12, &[false; 6], &mut dense);
        assert_eq!(sp.densify(0), dense);
    }

    #[test]
    fn sparse_pack_blocks_are_per_graph() {
        // Batched sparse state: each batch element's live mask selects only
        // its own graph's edges (the block-diagonal invariant).
        let g1 = square();
        let g2 = Graph::from_edges(4, &[(0, 2)]).unwrap();
        let part = Partition::new(4, 1);
        let removed = vec![false; 4];
        let sol = vec![false; 4];
        let cand = vec![true; 4];
        let sp = SparseShard::from_graphs(
            part,
            0,
            &[&g1, &g2],
            &[&removed, &removed],
            &[&sol, &sol],
            &[&cand, &cand],
            2,
            &[8],
        );
        assert_eq!(sp.b, 2);
        assert_eq!(sp.live_edges(0), 8); // square: 4 undirected = 8 directed
        assert_eq!(sp.live_edges(1), 2);
        let dense0 = ShardState::from_graphs(
            part,
            0,
            &[&g1, &g2],
            &[&removed, &removed],
            &[&sol, &sol],
            &[&cand, &cand],
        );
        assert_eq!(sp.densify(0), &dense0.a[..16]);
        assert_eq!(sp.densify(1), &dense0.a[16..32]);
    }

    #[test]
    fn sparse_bytes_scale_with_edges_not_n() {
        // The §7 scaling claim at unit-test size: a near-empty 48-node
        // bucket costs the sparse path far less than the dense N² tensor.
        let g = Graph::from_edges(40, &[(0, 1), (2, 3)]).unwrap();
        let part = Partition::new(48, 1);
        let dense = fresh(part, &g).remove(0);
        let sparse = fresh_sparse(part, &g, 12, &[96]).remove(0);
        assert_eq!(dense.adjacency_bytes(), 4 * 48 * 48);
        assert!(
            sparse.adjacency_bytes() * 5 <= dense.adjacency_bytes(),
            "sparse {} vs dense {}",
            sparse.adjacency_bytes(),
            dense.adjacency_bytes()
        );
        assert!(sparse.index_bytes() > 0);
    }

    #[test]
    fn mirror_selection_is_storage_generic() {
        // Driving both storage modes through the shared mirror keeps them
        // in lockstep with the environment diff.
        use crate::env::{GraphEnv, MvcEnv};
        let g = square();
        let part = Partition::new(4, 2);
        let mut dense = fresh(part, &g);
        let mut sparse = fresh_sparse(part, &g, 2, &[8]);
        let mut env = MvcEnv::new(g.clone());
        let mut rp_d: Vec<bool> = env.removed_mask().to_vec();
        let mut rp_s = rp_d.clone();
        env.step(1);
        mirror_selection(&mut dense, 0, 1, &env, &mut rp_d);
        mirror_selection(&mut sparse, 0, 1, &env, &mut rp_s);
        for (d, sp) in dense.iter().zip(&sparse) {
            assert_eq!(sp.densify(0), d.a);
            assert_eq!(sp.s, d.s);
        }
    }

    #[test]
    fn shard_set_dispatches_both_modes() {
        use crate::env::{GraphEnv, MvcEnv};
        let g = square();
        let part = Partition::new(4, 2);
        let mut sets = [
            ShardSet::Dense(fresh(part, &g)),
            ShardSet::Sparse(fresh_sparse(part, &g, 2, &[8])),
        ];
        for set in sets.iter_mut() {
            assert_eq!(set.len(), 2);
            assert_eq!(set.b(), 1);
            assert!(!set.is_empty());
            assert!(set.bytes() > 0);
            let mut env = MvcEnv::new(g.clone());
            let mut rp: Vec<bool> = env.removed_mask().to_vec();
            env.step(2);
            set.mirror_selection(0, 2, &env, &mut rp);
            set.refresh_candidates(0, |v| env.is_candidate(v));
        }
        assert_eq!(sets[0].storage(), Storage::Dense);
        assert_eq!(sets[1].storage(), Storage::Sparse);
    }

    #[test]
    fn storage_parses() {
        assert_eq!(Storage::parse("dense").unwrap(), Storage::Dense);
        assert_eq!(Storage::parse("Sparse").unwrap(), Storage::Sparse);
        assert_eq!(Storage::parse("csr").unwrap(), Storage::Sparse);
        assert!(Storage::parse("coo").is_err());
        assert_eq!(Storage::default(), Storage::Dense);
    }

    #[test]
    fn refresh_candidates_applies_predicate() {
        let g = square();
        let part = Partition::new(4, 2);
        let mut shards = fresh(part, &g);
        shards[1].refresh_candidates(0, |v| v == 3);
        assert_eq!(shards[1].c, vec![0.0, 1.0]);
    }

    #[test]
    fn bytes_accounting() {
        let g = square();
        let part = Partition::new(4, 2);
        let shards = fresh(part, &g);
        assert_eq!(shards[0].bytes(), 4 * (8 + 2 + 2));
    }
}
