//! Per-shard distributed state: the dense compute-path mirror of the
//! paper's three distributed data structures (§4.1, Fig. 2): sub-adjacency
//! A^i (B×NI×N), candidate set C^i (B×NI) and partial solution S^i (B×NI).
//!
//! The coordinator keeps these in lockstep with the host-side environment:
//! node selection zeroes the node's local row and its column on every shard
//! (Fig. 4), sets S, and clears C.

use crate::env::GraphEnv;
use crate::graph::{Graph, Partition};

/// One shard's tensor state for a batch of B graph instances.
#[derive(Debug, Clone)]
pub struct ShardState {
    pub part: Partition,
    /// This shard's index (0..P).
    pub shard: usize,
    /// Batch size B.
    pub b: usize,
    /// Dense sub-adjacency, B × NI × N row-major.
    pub a: Vec<f32>,
    /// Partial solution, B × NI.
    pub s: Vec<f32>,
    /// Candidate set, B × NI.
    pub c: Vec<f32>,
    /// Locally-owned A-rows zeroed since the last `take_dirty`, as
    /// (batch element, local row). Fuels the device-residency delta path:
    /// instead of re-uploading the full B×NI×N adjacency, the coordinator
    /// patches the device copy with these deltas (fwd.rs `DeviceState`).
    dirty_rows: Vec<(u32, u32)>,
    /// A-columns zeroed since the last `take_dirty`, as (batch element,
    /// global column).
    dirty_cols: Vec<(u32, u32)>,
}

impl ShardState {
    /// Build shard `shard` of the partition for a batch of graphs, given
    /// per-graph removed masks (residual graph) and solution masks. The
    /// candidate mask is provided per graph as well (environment-defined).
    pub fn from_graphs(
        part: Partition,
        shard: usize,
        graphs: &[&Graph],
        removed: &[&[bool]],
        solution: &[&[bool]],
        candidates: &[&[bool]],
    ) -> ShardState {
        let b = graphs.len();
        assert!(b > 0 && removed.len() == b && solution.len() == b && candidates.len() == b);
        let (n, ni) = (part.n, part.ni());
        let row0 = part.row0(shard);
        let mut a = vec![0.0f32; b * ni * n];
        let mut s = vec![0.0f32; b * ni];
        let mut c = vec![0.0f32; b * ni];
        for (g_idx, g) in graphs.iter().enumerate() {
            assert!(g.n <= n, "graph larger than bucket");
            g.densify_rows(row0, ni, n, removed[g_idx], &mut a[g_idx * ni * n..(g_idx + 1) * ni * n]);
            for r in 0..ni {
                let v = row0 + r;
                if v < g.n {
                    s[g_idx * ni + r] = solution[g_idx][v] as u32 as f32;
                    c[g_idx * ni + r] = candidates[g_idx][v] as u32 as f32;
                }
            }
        }
        ShardState { part, shard, b, a, s, c, dirty_rows: Vec::new(), dirty_cols: Vec::new() }
    }

    /// Build a shard directly from dense full-graph tensors (B×N×N
    /// adjacency, B×N solution/candidate vectors). Used by the golden-vector
    /// integration tests where the state comes from the python build step.
    pub fn from_dense(
        part: Partition,
        shard: usize,
        b: usize,
        a_full: &[f32],
        s_full: &[f32],
        c_full: &[f32],
    ) -> ShardState {
        let (n, ni) = (part.n, part.ni());
        assert_eq!(a_full.len(), b * n * n);
        assert_eq!(s_full.len(), b * n);
        assert_eq!(c_full.len(), b * n);
        let row0 = part.row0(shard);
        let mut a = vec![0.0f32; b * ni * n];
        let mut s = vec![0.0f32; b * ni];
        let mut c = vec![0.0f32; b * ni];
        for g in 0..b {
            for r in 0..ni {
                let v = row0 + r;
                a[g * ni * n + r * n..g * ni * n + (r + 1) * n]
                    .copy_from_slice(&a_full[g * n * n + v * n..g * n * n + (v + 1) * n]);
                s[g * ni + r] = s_full[g * n + v];
                c[g * ni + r] = c_full[g * n + v];
            }
        }
        ShardState { part, shard, b, a, s, c, dirty_rows: Vec::new(), dirty_cols: Vec::new() }
    }

    pub fn ni(&self) -> usize {
        self.part.ni()
    }

    pub fn n(&self) -> usize {
        self.part.n
    }

    /// Whether global node v lives on this shard.
    pub fn owns(&self, v: usize) -> bool {
        self.part.owner(v) == self.shard
    }

    /// Apply "select node v into the solution" for batch element g_idx
    /// (Fig. 4): zero v's row (if local) and v's column (always), set S,
    /// clear C for v. This fuses `set_solution` + `apply_remove` — the MVC
    /// semantics where selection and residual-removal coincide.
    pub fn apply_select(&mut self, g_idx: usize, v: usize) {
        self.set_solution(g_idx, v);
        self.apply_remove(g_idx, v);
    }

    /// Mark node v as part of batch element g_idx's solution (S only; the
    /// residual graph is updated separately via `apply_remove`, since
    /// scenarios differ in what selection removes — MVC drops the node,
    /// MIS drops its closed neighborhood, MaxCut drops nothing).
    pub fn set_solution(&mut self, g_idx: usize, v: usize) {
        let ni = self.ni();
        assert!(g_idx < self.b && v < self.n());
        if self.owns(v) {
            let r = self.part.local(v);
            self.s[g_idx * ni + r] = 1.0;
        }
    }

    /// Remove node v from batch element g_idx's residual graph (Fig. 4):
    /// zero v's row (if local) and v's column (always), clear C for v.
    pub fn apply_remove(&mut self, g_idx: usize, v: usize) {
        let (n, ni) = (self.n(), self.ni());
        assert!(g_idx < self.b && v < n);
        let base_a = g_idx * ni * n;
        if self.owns(v) {
            let r = self.part.local(v);
            self.a[base_a + r * n..base_a + (r + 1) * n].fill(0.0);
            self.c[g_idx * ni + r] = 0.0;
            self.dirty_rows.push((g_idx as u32, r as u32));
        }
        // Zero column v across all local rows.
        for r in 0..ni {
            self.a[base_a + r * n + v] = 0.0;
        }
        self.dirty_cols.push((g_idx as u32, v as u32));
    }

    /// Whether A has been mutated since the last `take_dirty`.
    pub fn is_dirty(&self) -> bool {
        !self.dirty_rows.is_empty() || !self.dirty_cols.is_empty()
    }

    /// Consume the recorded A-deltas: (zeroed local rows, zeroed columns),
    /// each as (batch element, index) pairs. Resets the dirty sets.
    pub fn take_dirty(&mut self) -> (Vec<(u32, u32)>, Vec<(u32, u32)>) {
        (std::mem::take(&mut self.dirty_rows), std::mem::take(&mut self.dirty_cols))
    }

    /// Forget recorded deltas (after a fresh full upload of A).
    pub fn clear_dirty(&mut self) {
        self.dirty_rows.clear();
        self.dirty_cols.clear();
    }

    /// Refresh the candidate mask for batch element g_idx from the
    /// environment's candidate predicate (the host owns candidate logic).
    pub fn refresh_candidates(&mut self, g_idx: usize, is_candidate: impl Fn(usize) -> bool) {
        let ni = self.ni();
        let row0 = self.part.row0(self.shard);
        for r in 0..ni {
            let v = row0 + r;
            self.c[g_idx * ni + r] = if v < self.n() && is_candidate(v) { 1.0 } else { 0.0 };
        }
    }

    /// Bytes held by this shard's tensors (memory accounting, §5.2).
    pub fn bytes(&self) -> usize {
        4 * (self.a.len() + self.s.len() + self.c.len())
    }
}

/// Build all P shards for a single graph instance (inference entry).
pub fn shards_for_graph(
    part: Partition,
    g: &Graph,
    removed: &[bool],
    solution: &[bool],
    candidates: &[bool],
) -> Vec<ShardState> {
    (0..part.p)
        .map(|i| {
            ShardState::from_graphs(part, i, &[g], &[removed], &[solution], &[candidates])
        })
        .collect()
}

/// Mirror one environment selection onto the shard tensors (batch element
/// `g_idx`): set S for the picked node, then diff the environment's removed
/// mask against `removed_prev` and zero rows/cols of newly removed nodes.
/// The diff is what makes the mirroring scenario-generic — MVC removes the
/// node itself, MIS its closed neighborhood, MaxCut nothing — and it is
/// shared by the sequential (`infer::solve_env`) and batched
/// (`batch::solve_pack`) loops so their per-graph trajectories cannot
/// drift apart.
pub fn mirror_selection(
    shards: &mut [ShardState],
    g_idx: usize,
    v: usize,
    env: &dyn GraphEnv,
    removed_prev: &mut [bool],
) {
    for sh in shards.iter_mut() {
        sh.set_solution(g_idx, v);
    }
    let rm = env.removed_mask();
    for u in 0..env.num_nodes() {
        if rm[u] && !removed_prev[u] {
            removed_prev[u] = true;
            for sh in shards.iter_mut() {
                sh.apply_remove(g_idx, u);
            }
        }
    }
}

/// Build all P shards for a pack of graph instances (batched inference
/// entry): one block-diagonal batch element per graph.
pub fn shards_for_pack(
    part: Partition,
    graphs: &[&Graph],
    removed: &[&[bool]],
    solution: &[&[bool]],
    candidates: &[&[bool]],
) -> Vec<ShardState> {
    (0..part.p)
        .map(|i| ShardState::from_graphs(part, i, graphs, removed, solution, candidates))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Graph;

    fn square() -> Graph {
        // 0-1-2-3-0 cycle
        Graph::from_edges(4, &[(0, 1), (1, 2), (2, 3), (0, 3)]).unwrap()
    }

    fn fresh(part: Partition, g: &Graph) -> Vec<ShardState> {
        let removed = vec![false; g.n];
        let sol = vec![false; g.n];
        let cand: Vec<bool> = (0..g.n).map(|v| g.degree(v) > 0).collect();
        shards_for_graph(part, g, &removed, &sol, &cand)
    }

    #[test]
    fn densified_rows_match_graph() {
        let g = square();
        let part = Partition::new(4, 2);
        let shards = fresh(part, &g);
        // shard 0 holds rows 0,1: row0 = [0,1,0,1]; row1 = [1,0,1,0]
        assert_eq!(&shards[0].a[..4], &[0.0, 1.0, 0.0, 1.0]);
        assert_eq!(&shards[0].a[4..8], &[1.0, 0.0, 1.0, 0.0]);
        assert_eq!(shards[0].c, vec![1.0, 1.0]);
        assert_eq!(shards[1].s, vec![0.0, 0.0]);
    }

    #[test]
    fn apply_select_zeroes_row_and_col() {
        let g = square();
        let part = Partition::new(4, 2);
        let mut shards = fresh(part, &g);
        for sh in shards.iter_mut() {
            sh.apply_select(0, 1);
        }
        // Node 1 lives on shard 0 row 1: row zeroed, S set, C cleared.
        assert_eq!(&shards[0].a[4..8], &[0.0; 4]);
        assert_eq!(shards[0].s, vec![0.0, 1.0]);
        assert_eq!(shards[0].c, vec![1.0, 0.0]);
        // Column 1 zeroed everywhere.
        assert_eq!(shards[0].a[1], 0.0);
        assert_eq!(shards[1].a[1], 0.0);
        assert_eq!(shards[1].a[4 + 1], 0.0);
        // Untouched edge (2,3) survives on shard 1.
        assert_eq!(shards[1].a[3], 1.0); // row for node 2, col 3
    }

    #[test]
    fn padding_rows_stay_zero() {
        let g = Graph::from_edges(2, &[(0, 1)]).unwrap();
        let part = Partition::new(12, 3); // padded bucket
        let shards = fresh(part, &g);
        // shard 0 rows 0..4: nodes 0,1 real; 2,3 padding.
        assert_eq!(shards[0].c, vec![1.0, 1.0, 0.0, 0.0]);
        assert!(shards[1].a.iter().all(|&x| x == 0.0));
        assert!(shards[2].c.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn batch_layout_is_per_graph() {
        let g1 = square();
        let g2 = Graph::from_edges(4, &[(0, 2)]).unwrap();
        let part = Partition::new(4, 1);
        let removed = vec![false; 4];
        let sol = vec![false; 4];
        let cand = vec![true; 4];
        let sh = ShardState::from_graphs(
            part,
            0,
            &[&g1, &g2],
            &[&removed, &removed],
            &[&sol, &sol],
            &[&cand, &cand],
        );
        assert_eq!(sh.b, 2);
        assert_eq!(sh.a.len(), 2 * 4 * 4);
        // Graph 2's block has only edge (0,2).
        let block2 = &sh.a[16..32];
        assert_eq!(block2.iter().filter(|&&x| x == 1.0).count(), 2);
        assert_eq!(block2[2], 1.0);
        assert_eq!(block2[8], 1.0);
    }

    #[test]
    fn set_solution_without_removal_keeps_rows() {
        // MaxCut semantics: selection marks S but the node stays in the
        // residual graph (no row/col zeroing).
        let g = square();
        let part = Partition::new(4, 2);
        let mut shards = fresh(part, &g);
        for sh in shards.iter_mut() {
            sh.set_solution(0, 1);
        }
        assert_eq!(shards[0].s, vec![0.0, 1.0]);
        assert_eq!(&shards[0].a[4..8], &[1.0, 0.0, 1.0, 0.0]); // row intact
        assert_eq!(shards[1].a[1], 1.0); // column intact
    }

    #[test]
    fn apply_select_equals_solution_plus_remove() {
        let g = square();
        let part = Partition::new(4, 2);
        let mut a = fresh(part, &g);
        let mut b = fresh(part, &g);
        for sh in a.iter_mut() {
            sh.apply_select(0, 2);
        }
        for sh in b.iter_mut() {
            sh.set_solution(0, 2);
            sh.apply_remove(0, 2);
        }
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.a, y.a);
            assert_eq!(x.s, y.s);
            assert_eq!(x.c, y.c);
        }
    }

    #[test]
    fn dirty_tracking_records_removed_rows_and_cols() {
        let g = square();
        let part = Partition::new(4, 2);
        let mut shards = fresh(part, &g);
        assert!(!shards[0].is_dirty() && !shards[1].is_dirty());
        for sh in shards.iter_mut() {
            sh.apply_remove(0, 1);
        }
        // Node 1 lives on shard 0 (local row 1): row dirty there only; the
        // column is dirty on every shard.
        assert!(shards[0].is_dirty() && shards[1].is_dirty());
        let (rows0, cols0) = shards[0].take_dirty();
        assert_eq!(rows0, vec![(0, 1)]);
        assert_eq!(cols0, vec![(0, 1)]);
        let (rows1, cols1) = shards[1].take_dirty();
        assert!(rows1.is_empty());
        assert_eq!(cols1, vec![(0, 1)]);
        // take_dirty resets; clear_dirty drops pending deltas.
        assert!(!shards[0].is_dirty());
        shards[1].apply_remove(0, 3);
        assert!(shards[1].is_dirty());
        shards[1].clear_dirty();
        assert!(!shards[1].is_dirty());
    }

    #[test]
    fn refresh_candidates_applies_predicate() {
        let g = square();
        let part = Partition::new(4, 2);
        let mut shards = fresh(part, &g);
        shards[1].refresh_candidates(0, |v| v == 3);
        assert_eq!(shards[1].c, vec![0.0, 1.0]);
    }

    #[test]
    fn bytes_accounting() {
        let g = square();
        let part = Partition::new(4, 2);
        let shards = fresh(part, &g);
        assert_eq!(shards[0].bytes(), 4 * (8 + 2 + 2));
    }
}
