//! Parallel RL training (Alg. 5): seed-synchronized ε-greedy episodes over
//! the training dataset, compressed replay, Tuples2Graphs minibatch
//! reconstruction, distributed fwd/bwd, gradient all-reduce + replicated
//! Adam, and the §4.5.2 repeated-gradient-iterations optimization (τ).

use super::engine::{Engine, EngineCfg, StepTiming};
use super::replay::{tuples_to_shard_set, BitSet, ReplayBuffer, Tuple};
use super::selection::top_d;
use super::shard::{shards_for_graph, sparse_shards_for_graph, ShardSet, Storage};
use crate::env::{GraphEnv, MvcEnv};
use crate::graph::{Graph, Partition};
use crate::model::{Adam, Hyper, Params};
use crate::parallel::{ExecEngine, RankPool};
use crate::runtime::Runtime;
use anyhow::{ensure, Result};

/// Training configuration.
#[derive(Debug, Clone)]
pub struct TrainCfg {
    /// Shared engine parameters (P, L, comm cost model).
    pub engine: EngineCfg,
    /// RL/optimizer hyper-parameters (paper §6.1).
    pub hyper: Hyper,
    /// Padded bucket size (>= every training graph's |V|, divisible by 12).
    pub bucket_n: usize,
    /// Shared seed (Alg. 5 input SEED).
    pub seed: u64,
    /// Elide layer-0 message stage (exact; see fwd.rs).
    pub skip_zero_layer: bool,
    /// Resample the minibatch on every gradient iteration instead of
    /// reusing it (ablation; the paper iterates on one minibatch).
    pub resample_per_iter: bool,
    /// Hold the minibatch shard tensors on device across the τ repeated
    /// gradient iterations (§4.5.2) — only θ is re-uploaded after each
    /// optimizer step. Exact; off = the fresh-upload reference path.
    pub device_resident: bool,
    /// Per-shard storage mode (DESIGN.md §7) for both the episode policy
    /// evaluations and the training minibatches.
    pub storage: Storage,
}

impl TrainCfg {
    /// Default configuration for `p` shards at padded bucket `bucket_n`.
    pub fn new(p: usize, bucket_n: usize) -> TrainCfg {
        TrainCfg {
            engine: EngineCfg::new(p, 2),
            hyper: Hyper::default(),
            bucket_n,
            seed: 1,
            skip_zero_layer: true,
            resample_per_iter: false,
            device_resident: true,
            storage: Storage::Dense,
        }
    }
}

/// Per-step record for learning curves and Fig. 11 timing.
#[derive(Debug, Clone)]
pub struct StepRecord {
    /// Episode index the step belongs to.
    pub episode: usize,
    /// Global training-step counter.
    pub global_step: usize,
    /// Mean loss over the τ gradient iterations (None before the replay
    /// buffer can fill a minibatch).
    pub loss: Option<f32>,
    /// Simulated-parallel seconds for the full training step (policy eval +
    /// state update + τ·(fwd+bwd) + optimizer).
    pub sim_step_time: f64,
    /// Timing of the policy evaluation (Alg. 5 line 9).
    pub eval_timing: StepTiming,
    /// Timing of the τ gradient iterations (lines 17-26).
    pub train_timing: StepTiming,
}

/// The distributed trainer (one instance drives all P simulated devices).
pub struct Trainer<'r> {
    /// Stage runtime executing the AOT artifacts.
    pub rt: &'r Runtime,
    /// Training configuration.
    pub cfg: TrainCfg,
    /// Current policy parameters (updated in place by Adam).
    pub params: Params,
    /// Training dataset (graph index = replay `graph_id`).
    pub graphs: Vec<Graph>,
    adam: Adam,
    replay: ReplayBuffer,
    rng: crate::util::rng::Pcg32,
    /// Global training-step counter.
    pub global_step: usize,
    episode: usize,
    /// Persistent worker pool for the rank-parallel engine (None under
    /// lockstep). One pool serves the whole training run: episode shards
    /// live in slot 0, minibatches in slot 1, and θ re-publishes only when
    /// the optimizer actually changed it.
    pool: Option<RankPool>,
}

impl<'r> Trainer<'r> {
    /// Build a trainer; fails fast when required artifacts are missing.
    pub fn new(rt: &'r Runtime, cfg: TrainCfg, graphs: Vec<Graph>, params: Params) -> Result<Trainer<'r>> {
        ensure!(!graphs.is_empty(), "empty training dataset");
        let max_n = graphs.iter().map(|g| g.n).max().unwrap();
        ensure!(max_n <= cfg.bucket_n, "graph |V|={max_n} exceeds bucket {}", cfg.bucket_n);
        // Fail fast if artifacts for the training minibatch are missing.
        let part = Partition::new(cfg.bucket_n, cfg.engine.p);
        let name = crate::runtime::artifact_name(
            "q_scores_bwd",
            cfg.hyper.batch_size,
            cfg.bucket_n,
            part.ni(),
            params.k,
        );
        ensure!(
            rt.manifest.has(&name),
            "missing training artifact {name}; add the shape to configs.py"
        );
        if cfg.storage == Storage::Sparse {
            // Fail fast on the sparse stage set too: minibatch fwd/bwd and
            // the B=1 episode evaluations each need their own shapes.
            let (chunk, caps) =
                rt.manifest.sparse_config(cfg.hyper.batch_size, part.ni(), params.k)?;
            rt.manifest.sparse_config(1, part.ni(), params.k)?;
            let pbwd = crate::runtime::sparse_pre_name(
                "embed_pre_sp_bwd",
                cfg.hyper.batch_size,
                part.ni(),
                params.k,
            );
            ensure!(
                rt.manifest.has(&pbwd),
                "missing sparse training artifact {pbwd}; add the shape to \
                 python/compile/configs.py sparse_train_shapes()"
            );
            // The backward tile sweep runs embed_msg_sp_bwd at exactly the
            // capacities the forward ladder tiles with — every cap must be
            // compiled, or training would die mid-episode at the first
            // gradient iteration instead of here.
            for &cap in &caps {
                let mbwd = crate::runtime::sparse_msg_name(
                    "embed_msg_sp_bwd",
                    cfg.hyper.batch_size,
                    cap,
                    chunk,
                    params.k,
                );
                ensure!(
                    rt.manifest.has(&mbwd),
                    "missing sparse training artifact {mbwd}; add the shape to \
                     python/compile/configs.py sparse_train_shapes()"
                );
            }
        }
        let adam = Adam::new(cfg.hyper.lr, params.flat.len());
        let replay = ReplayBuffer::new(cfg.hyper.replay_capacity);
        let rng = crate::util::rng::Pcg32::seeded(cfg.seed);
        let pool = match cfg.engine.mode {
            Engine::Lockstep => None,
            Engine::RankParallel => {
                Some(RankPool::new(rt.manifest.dir.clone(), cfg.engine.p)?)
            }
        };
        Ok(Trainer {
            rt,
            cfg,
            params,
            graphs,
            adam,
            replay,
            rng,
            global_step: 0,
            episode: 0,
            pool,
        })
    }

    /// Capture a resumable checkpoint (params + optimizer + counters).
    pub fn checkpoint(&self) -> crate::model::checkpoint::Checkpoint {
        crate::model::checkpoint::Checkpoint::capture(
            &self.params,
            &self.adam,
            self.global_step,
            self.episode,
        )
    }

    /// Resume params/optimizer/counters from a checkpoint (the replay
    /// buffer is rebuilt by subsequent experience, as in the paper).
    pub fn resume_from(&mut self, ck: &crate::model::checkpoint::Checkpoint) {
        let (step, episode) = ck.restore(&mut self.params, &mut self.adam);
        self.global_step = step;
        self.episode = episode;
    }

    /// Experience tuples currently buffered.
    pub fn replay_len(&self) -> usize {
        self.replay.len()
    }

    /// Bytes held by the compressed replay buffer (§4.4).
    pub fn replay_bytes(&self) -> usize {
        self.replay.bytes()
    }

    /// Run `episodes` episodes, invoking `on_step` after every global step.
    pub fn run_episodes(
        &mut self,
        episodes: usize,
        mut on_step: impl FnMut(&StepRecord),
    ) -> Result<()> {
        for _ in 0..episodes {
            self.run_episode(None, &mut on_step)?;
        }
        Ok(())
    }

    /// Run exactly `steps` global training steps, crossing episode
    /// boundaries and stopping mid-episode if needed (used by the Fig. 11
    /// timing bench, where one big-graph episode is thousands of steps).
    pub fn run_steps(
        &mut self,
        steps: usize,
        mut on_step: impl FnMut(&StepRecord),
    ) -> Result<()> {
        let target = self.global_step + steps;
        while self.global_step < target {
            self.run_episode(Some(target), &mut on_step)?;
        }
        Ok(())
    }

    fn run_episode(
        &mut self,
        step_limit: Option<usize>,
        on_step: &mut impl FnMut(&StepRecord),
    ) -> Result<()> {
        let gamma = self.cfg.hyper.gamma;
        let b_train = self.cfg.hyper.batch_size;
        let part = Partition::new(self.cfg.bucket_n, self.cfg.engine.p);

        // Alg. 5 line 4: same seed => every process picks the same graph.
        let graph_id = self.rng.gen_range(self.graphs.len()) as u32;
        let g = self.graphs[graph_id as usize].clone();
        let mut env = MvcEnv::new(g.clone());
        let candidates: Vec<bool> = (0..g.n).map(|v| env.is_candidate(v)).collect();
        let mut set = match self.cfg.storage {
            Storage::Dense => ShardSet::Dense(shards_for_graph(
                part,
                &g,
                env.removed_mask(),
                env.solution_mask(),
                &candidates,
            )),
            Storage::Sparse => {
                let (chunk, caps) = self.rt.manifest.sparse_config(1, part.ni(), self.params.k)?;
                ShardSet::Sparse(sparse_shards_for_graph(
                    part,
                    &g,
                    env.removed_mask(),
                    env.solution_mask(),
                    &candidates,
                    chunk,
                    &caps,
                ))
            }
        };

        // Episode-long device residency for the policy-eval forward: the
        // episode graph's shards are uploaded once (coordinator runtime or
        // per rank, by engine), patched per step; θ is re-pushed only
        // after optimizer steps actually changed it. The one-time upload
        // cost is carried into the first step's transfer time so
        // resident-vs-fresh step times stay comparable.
        let pool = self.pool.as_ref();
        let mut eval_ctx = ExecEngine::install(
            self.rt,
            pool,
            &self.cfg.engine,
            &self.params,
            &mut set,
            self.cfg.device_resident,
            None,
            0,
        )?;
        let mut carry_h2d = eval_ctx.last_transfer_secs();
        let mut theta_stale = false;

        // Tuple awaiting its Bellman target (needs next state's max-Q).
        let mut pending: Option<(BitSet, u32, f32)> = None;

        while !env.done() {
            if step_limit.is_some_and(|lim| self.global_step >= lim) {
                // Bounded run: abandon the episode, keeping the pending
                // experience (reward-only target, like a terminal tuple).
                if let Some((sol, action, reward)) = pending.take() {
                    self.replay.push(Tuple { graph_id, solution: sol, action, target: reward });
                }
                return Ok(());
            }
            let mut sim_time = 0.0f64;

            // --- policy evaluation on the current state (B=1) ---
            let mut sync_t = std::mem::take(&mut carry_h2d);
            eval_ctx.sync(&mut set)?;
            sync_t += eval_ctx.last_transfer_secs();
            if theta_stale {
                // Lockstep: re-upload θ into the episode device state.
                // Rank-parallel: a no-op when the minibatch context already
                // published these parameters to the workers this step.
                eval_ctx.refresh_theta(&self.params)?;
                sync_t += eval_ctx.last_transfer_secs();
                theta_stale = false;
            }
            let mut eval = eval_ctx.forward(
                &self.cfg.engine,
                &self.params,
                &set,
                false,
                self.cfg.skip_zero_layer,
            )?;
            // Book the delta-sync/θ-refresh uploads as this step's transfer
            // time so resident-vs-fresh comparisons stay apples-to-apples.
            eval.timing.h2d += sync_t;
            sim_time += eval.timing.simulated();
            let max_q = (0..g.n)
                .filter(|&v| env.is_candidate(v))
                .map(|v| eval.scores[v])
                .fold(f32::NEG_INFINITY, f32::max);

            // Finalize the pending tuple: y = r + γ·max_a' Q(s', a').
            if let Some((sol, action, reward)) = pending.take() {
                self.replay.push(Tuple {
                    graph_id,
                    solution: sol,
                    action,
                    target: reward + gamma * max_q,
                });
            }

            // --- ε-greedy action (Alg. 5 line 10) ---
            let eps = self.cfg.hyper.epsilon(self.global_step);
            let cands: Vec<usize> = (0..g.n).filter(|&v| env.is_candidate(v)).collect();
            let v_t = if self.rng.next_f32() < eps {
                cands[self.rng.gen_range(cands.len())]
            } else {
                top_d(&eval.scores[..g.n], |v| env.is_candidate(v), 1)[0]
            };

            // --- apply action, update distributed state (lines 11-14) ---
            let snapshot = BitSet::from_bools(env.solution_mask());
            let (reward, done) = env.step(v_t);
            set.apply_select(0, v_t);
            set.refresh_candidates(0, |v| env.is_candidate(v));
            if done {
                // Terminal tuple: no successor state, y = r.
                self.replay.push(Tuple {
                    graph_id,
                    solution: snapshot,
                    action: v_t as u32,
                    target: reward,
                });
            } else {
                pending = Some((snapshot, v_t as u32, reward));
            }

            // --- distributed training step (lines 17-26) ---
            let mut loss = None;
            let mut train_timing = StepTiming::new(self.cfg.engine.p);
            if self.replay.len() >= b_train {
                let mut batch = self.replay.sample(b_train, &mut self.rng);
                let mut losses = 0.0f32;
                // §4.5.2: the τ repeated gradient iterations reuse one
                // minibatch — and, with device residency, ONE upload of its
                // shard tensors: only θ is re-pushed after each optimizer
                // step (previously every iteration re-built and re-uploaded
                // the full B×NI×N minibatch state for both fwd and bwd).
                // Sparse minibatches resolve their (chunk, caps) once per
                // training step (the manifest lookup is pure).
                let sparse_cfg = match self.cfg.storage {
                    Storage::Dense => None,
                    Storage::Sparse => {
                        Some(self.rt.manifest.sparse_config(b_train, part.ni(), self.params.k)?)
                    }
                };
                let scfg = sparse_cfg.as_ref().map(|(c, v)| (*c, v.as_slice()));
                let (mut bset, mut onehot, mut targets) =
                    tuples_to_shard_set(part, &self.graphs, &batch, self.cfg.storage, scfg);
                // Minibatch context in slot 1 — the episode state stays
                // resident in slot 0 on the rank-parallel engine.
                let mut mb_ctx = ExecEngine::install(
                    self.rt,
                    pool,
                    &self.cfg.engine,
                    &self.params,
                    &mut bset,
                    self.cfg.device_resident,
                    None,
                    1,
                )?;
                train_timing.h2d += mb_ctx.last_transfer_secs();
                for it in 0..self.cfg.hyper.grad_iters {
                    if it > 0 {
                        if self.cfg.resample_per_iter {
                            batch = self.replay.sample(b_train, &mut self.rng);
                            let scfg = sparse_cfg.as_ref().map(|(c, v)| (*c, v.as_slice()));
                            (bset, onehot, targets) = tuples_to_shard_set(
                                part,
                                &self.graphs,
                                &batch,
                                self.cfg.storage,
                                scfg,
                            );
                            mb_ctx.rebuild(&mut bset)?;
                            train_timing.h2d += mb_ctx.last_transfer_secs();
                        }
                        mb_ctx.refresh_theta(&self.params)?;
                        train_timing.h2d += mb_ctx.last_transfer_secs();
                    }
                    let fwd = mb_ctx.forward(
                        &self.cfg.engine,
                        &self.params,
                        &bset,
                        true,
                        self.cfg.skip_zero_layer,
                    )?;
                    let out = mb_ctx.backward(
                        &self.cfg.engine,
                        &self.params,
                        &bset,
                        fwd.acts.as_ref(),
                        &onehot,
                        &targets,
                    )?;
                    self.adam.step(&mut self.params.flat, &out.grads);
                    losses += out.loss;
                    train_timing.merge(&fwd.timing);
                    train_timing.merge(&out.timing);
                }
                sim_time += train_timing.simulated();
                loss = Some(losses / self.cfg.hyper.grad_iters as f32);
                theta_stale = true;
            }

            self.global_step += 1;
            on_step(&StepRecord {
                episode: self.episode,
                global_step: self.global_step,
                loss,
                sim_step_time: sim_time,
                eval_timing: eval.timing,
                train_timing,
            });
        }
        self.episode += 1;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::util::rng::Pcg32;

    fn runtime() -> Option<Runtime> {
        if !std::path::Path::new("artifacts/manifest.tsv").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        Some(Runtime::new("artifacts").unwrap())
    }

    fn dataset(count: usize, n: usize, seed: u64) -> Vec<Graph> {
        let mut rng = Pcg32::seeded(seed);
        (0..count).map(|_| generators::erdos_renyi(n, 0.15, &mut rng)).collect()
    }

    #[test]
    fn episodes_fill_replay_and_learn() {
        let Some(rt) = runtime() else { return };
        let graphs = dataset(4, 20, 1);
        let mut cfg = TrainCfg::new(1, 24);
        cfg.hyper.lr = 1e-3;
        let params = Params::init(32, &mut Pcg32::seeded(2));
        let mut tr = Trainer::new(&rt, cfg, graphs, params).unwrap();
        let mut steps = 0usize;
        let mut losses: Vec<f32> = Vec::new();
        tr.run_episodes(6, |rec| {
            steps += 1;
            if let Some(l) = rec.loss {
                losses.push(l);
            }
            assert!(rec.sim_step_time > 0.0);
        })
        .unwrap();
        assert!(steps >= 6, "too few steps: {steps}");
        assert!(tr.replay_len() > 0);
        assert!(!losses.is_empty(), "training never ran");
        assert!(losses.iter().all(|l| l.is_finite()));
    }

    #[test]
    fn sparse_storage_trains() {
        // The sparse path must drive full episodes end-to-end: policy
        // evaluations, replay fill, τ gradient iterations, optimizer steps.
        let Some(rt) = runtime() else { return };
        if rt.manifest.sparse_config(8, 24, 32).is_err() {
            eprintln!("skipping: sparse train artifacts not compiled");
            return;
        }
        let graphs = dataset(4, 20, 1);
        let mut cfg = TrainCfg::new(1, 24);
        cfg.hyper.lr = 1e-3;
        cfg.storage = Storage::Sparse;
        let params = Params::init(32, &mut Pcg32::seeded(2));
        let mut tr = Trainer::new(&rt, cfg, graphs, params).unwrap();
        let mut losses: Vec<f32> = Vec::new();
        tr.run_episodes(4, |rec| {
            if let Some(l) = rec.loss {
                losses.push(l);
            }
        })
        .unwrap();
        assert!(tr.replay_len() > 0);
        assert!(!losses.is_empty(), "sparse training never ran");
        assert!(losses.iter().all(|l| l.is_finite()));
    }

    #[test]
    fn training_is_seed_deterministic() {
        let Some(rt) = runtime() else { return };
        let run = |seed: u64| -> Vec<f32> {
            let graphs = dataset(3, 20, 7);
            let mut cfg = TrainCfg::new(1, 24);
            cfg.seed = seed;
            let params = Params::init(32, &mut Pcg32::seeded(9));
            let mut tr = Trainer::new(&rt, cfg, graphs, params).unwrap();
            tr.run_episodes(3, |_| {}).unwrap();
            tr.params.flat
        };
        let a = run(5);
        let b = run(5);
        let c = run(6);
        assert_eq!(a, b, "same seed diverged");
        assert_ne!(a, c, "different seeds identical");
    }

    #[test]
    fn trainer_p_parity() {
        // End-to-end training determinism across device counts: parameters
        // after a few episodes must match to fp tolerance.
        let Some(rt) = runtime() else { return };
        let run = |p: usize| -> Vec<f32> {
            let graphs = dataset(3, 20, 11);
            let mut cfg = TrainCfg::new(p, 24);
            cfg.seed = 3;
            let params = Params::init(32, &mut Pcg32::seeded(13));
            let mut tr = Trainer::new(&rt, cfg, graphs, params).unwrap();
            tr.run_episodes(2, |_| {}).unwrap();
            tr.params.flat
        };
        let p1 = run(1);
        let p2 = run(2);
        let d = crate::util::max_abs_diff(&p1, &p2);
        assert!(d < 5e-3, "P=1 vs P=2 params diverged by {d}");
    }

    #[test]
    fn rejects_missing_artifacts() {
        let Some(rt) = runtime() else { return };
        let graphs = dataset(1, 20, 1);
        let mut cfg = TrainCfg::new(1, 24);
        cfg.hyper.batch_size = 99; // no artifacts at B=99
        let params = Params::init(32, &mut Pcg32::seeded(2));
        assert!(Trainer::new(&rt, cfg, graphs, params).is_err());
    }

    #[test]
    fn rejects_oversized_graphs() {
        let Some(rt) = runtime() else { return };
        let graphs = dataset(1, 30, 1); // 30 > bucket 24
        let cfg = TrainCfg::new(1, 24);
        let params = Params::init(32, &mut Pcg32::seeded(2));
        assert!(Trainer::new(&rt, cfg, graphs, params).is_err());
    }
}
