//! Lockstep simulation engine primitives.
//!
//! The P "GPUs" are P shard contexts driven from one thread in lockstep
//! (DESIGN.md §3): each stage executes per shard with its compute time
//! measured individually, and each collective contributes α–β-modeled
//! communication time. The *simulated parallel* step time is
//!   max_i(compute_i per stage, summed over stages) + Σ comm costs
//! which is exactly what the paper's per-step measurements report.

use crate::collective::CostModel;

/// Which execution engine drives the P shards (DESIGN.md §3/§9).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Engine {
    /// Single-threaded lockstep simulation: the P shard contexts are driven
    /// from one thread with per-stage compute measured individually and
    /// communication α–β-modeled (DESIGN.md §3). The reference engine.
    #[default]
    Lockstep,
    /// Persistent rank-parallel pool (`crate::parallel`): P long-lived
    /// worker threads, each owning its own PJRT runtime and its rank's
    /// device-resident state, synchronizing through real shared-memory
    /// collectives (DESIGN.md §9). The true-concurrency hot path.
    RankParallel,
}

impl Engine {
    /// Parse a CLI value (`lockstep` | `rank-parallel`).
    pub fn parse(s: &str) -> anyhow::Result<Engine> {
        match s.to_ascii_lowercase().as_str() {
            "lockstep" => Ok(Engine::Lockstep),
            "rank-parallel" | "ranks" | "parallel" => Ok(Engine::RankParallel),
            other => anyhow::bail!("unknown engine '{other}' (lockstep|rank-parallel)"),
        }
    }

    /// Short CLI/JSON name (`lockstep` / `rank-parallel`).
    pub fn name(&self) -> &'static str {
        match self {
            Engine::Lockstep => "lockstep",
            Engine::RankParallel => "rank-parallel",
        }
    }
}

/// Timing of one distributed operation (a policy evaluation, a training
/// step, ...), accumulated across stages and collectives.
#[derive(Debug, Clone, Default)]
pub struct StepTiming {
    /// Per-shard accumulated compute seconds (index = shard).
    pub compute: Vec<f64>,
    /// Modeled communication seconds (α–β).
    pub comm: f64,
    /// Host-side coordinator seconds (state updates, reductions in Rust).
    pub host: f64,
    /// Host→device transfer seconds (explicit uploads on the coordinator's
    /// critical path — kept separate from `compute` so bench JSON can split
    /// compute/comm/transfer). Defined as "cost of getting device state
    /// current": explicit uploads, and on the device-resident path the
    /// on-device delta patches (`a_mask`) that replace them — so the fresh
    /// and resident paths' columns compare like-for-like.
    pub h2d: f64,
    /// Measured wall-clock of the whole lockstep pass.
    pub wall: f64,
    /// Bytes moved through collectives.
    pub comm_bytes: u64,
    /// Number of collectives.
    pub collectives: u64,
}

impl StepTiming {
    /// Zeroed timing for P shards.
    pub fn new(p: usize) -> StepTiming {
        StepTiming { compute: vec![0.0; p], ..Default::default() }
    }

    /// Simulated parallel time: slowest shard's compute + modeled comm +
    /// host time (the coordinator's serial work) + transfer time.
    pub fn simulated(&self) -> f64 {
        self.compute.iter().copied().fold(0.0, f64::max) + self.comm + self.host + self.h2d
    }

    /// Total compute across shards (what a single device would do).
    pub fn compute_total(&self) -> f64 {
        self.compute.iter().sum()
    }

    /// Record one collective: modeled seconds + payload bytes.
    pub fn add_comm(&mut self, cost: f64, bytes: usize) {
        self.comm += cost;
        self.comm_bytes += bytes as u64;
        self.collectives += 1;
    }

    /// Accumulate another timing into this one.
    pub fn merge(&mut self, other: &StepTiming) {
        if self.compute.len() < other.compute.len() {
            self.compute.resize(other.compute.len(), 0.0);
        }
        for (a, b) in self.compute.iter_mut().zip(&other.compute) {
            *a += b;
        }
        self.comm += other.comm;
        self.host += other.host;
        self.h2d += other.h2d;
        self.wall += other.wall;
        self.comm_bytes += other.comm_bytes;
        self.collectives += other.collectives;
    }
}

/// Engine configuration shared by forward/backward orchestrators.
#[derive(Debug, Clone, Copy)]
pub struct EngineCfg {
    /// Number of devices P (simulated shards or worker ranks).
    pub p: usize,
    /// Embedding layers L (runtime loop).
    pub l: usize,
    /// Communication cost model (lockstep comm attribution).
    pub cost: CostModel,
    /// Which execution engine drives the shards (DESIGN.md §9).
    pub mode: Engine,
}

impl EngineCfg {
    /// Default engine config for P shards and L layers (lockstep mode).
    pub fn new(p: usize, l: usize) -> EngineCfg {
        EngineCfg { p, l, cost: CostModel::default(), mode: Engine::Lockstep }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn engine_parses() {
        assert_eq!(Engine::parse("lockstep").unwrap(), Engine::Lockstep);
        assert_eq!(Engine::parse("rank-parallel").unwrap(), Engine::RankParallel);
        assert_eq!(Engine::parse("Ranks").unwrap(), Engine::RankParallel);
        assert!(Engine::parse("gpu").is_err());
        assert_eq!(Engine::default(), Engine::Lockstep);
        assert_eq!(EngineCfg::new(2, 2).mode, Engine::Lockstep);
        assert_eq!(Engine::RankParallel.name(), "rank-parallel");
    }

    #[test]
    fn simulated_takes_max_shard() {
        let mut t = StepTiming::new(3);
        t.compute = vec![1.0, 3.0, 2.0];
        t.comm = 0.5;
        t.host = 0.25;
        assert_eq!(t.simulated(), 3.75);
        assert_eq!(t.compute_total(), 6.0);
        // Transfer time is its own term, separable from compute.
        t.h2d = 0.25;
        assert_eq!(t.simulated(), 4.0);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = StepTiming::new(2);
        a.compute = vec![1.0, 2.0];
        a.add_comm(0.1, 100);
        let mut b = StepTiming::new(2);
        b.compute = vec![0.5, 0.5];
        b.add_comm(0.2, 200);
        b.h2d = 0.125;
        a.merge(&b);
        assert_eq!(a.compute, vec![1.5, 2.5]);
        assert_eq!(a.comm_bytes, 300);
        assert_eq!(a.collectives, 2);
        assert!((a.comm - 0.3).abs() < 1e-12);
        assert_eq!(a.h2d, 0.125);
    }
}
