//! CLI subcommand implementations for the `oggm` binary.
//!
//! Every subcommand parses its shared knobs through the one
//! `service::Options` front door and lowers to its loop config via `From`
//! — the commands themselves are thin shells around the library entry
//! points (`Trainer`, `solve_scenario`, `run_queue`, `Service`).

use super::infer::{solve_scenario, InferCfg};
use super::metrics;
use super::train::{TrainCfg, Trainer};
use crate::analysis::quality::{self, Baseline, EvalCfg, Instance};
use crate::batch::{self, BatchCfg, Job};
use crate::collective::fault::FaultPlan;
use crate::env::Scenario;
use crate::graph::{generators, io as gio, stats, Graph, Partition};
use crate::model::Params;
use crate::net;
use crate::runtime::{manifest, Runtime};
use crate::service::{Options, Service, SubmitMeta};
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::rng::Pcg32;
use anyhow::{bail, Context, Result};
use std::io::{BufRead, Write};
use std::net::TcpListener;
use std::sync::mpsc::RecvTimeoutError;
use std::sync::Arc;
use std::time::Duration;

fn load_runtime() -> Result<Runtime> {
    Runtime::new(manifest::default_dir())
}

/// Resolve a graph from CLI options: `--graph <file>` (SNAP edge list or
/// MatrixMarket `.mtx`, dispatched on extension) or a generator spec
/// `--gen er|ba|hk|rmat --n <nodes>` (`--scale`/`--ef` for rmat).
fn resolve_graph(args: &Args, rng: &mut Pcg32) -> Result<Graph> {
    if let Some(path) = args.get("graph") {
        return gio::read_graph(path);
    }
    gen_graph(args, &args.get_or("gen", "er"), rng)
}

/// One synthetic graph from the shared generator knobs.
fn gen_graph(args: &Args, kind: &str, rng: &mut Pcg32) -> Result<Graph> {
    let n = args.get_usize("n", 250);
    match kind {
        "er" => Ok(generators::erdos_renyi(n, args.get_f64("rho", generators::ER_RHO), rng)),
        "ba" => Ok(generators::barabasi_albert(n, args.get_usize("d", generators::BA_D), rng)),
        "hk" => Ok(generators::holme_kim(
            n,
            args.get_usize("d", generators::BA_D),
            args.get_f64("triad", 0.25),
            rng,
        )),
        "rmat" => {
            Ok(generators::rmat(args.get_usize("scale", 10) as u32, args.get_usize("ef", 8), rng))
        }
        other => bail!("unknown generator '{other}' (er|ba|hk|rmat)"),
    }
}

fn load_or_init_params(args: &Args, rng: &mut Pcg32) -> Result<Params> {
    match args.get("params") {
        Some(path) => Params::load(path, 32).context("loading --params"),
        None => {
            let init = manifest::default_dir().join("params_init.oggm");
            if init.exists() {
                Params::load(init, 32)
            } else {
                Ok(Params::init(32, rng))
            }
        }
    }
}

/// `oggm info`: manifest + platform summary.
pub fn cmd_info(_args: &Args) -> Result<()> {
    let rt = load_runtime()?;
    println!("platform: {}", rt.platform());
    println!("artifacts: {} entries (K={}, L={})", rt.manifest.entries.len(),
             rt.manifest.k, rt.manifest.l);
    let mut shapes = rt.manifest.available_fwd_shapes(1);
    shapes.dedup();
    println!("inference buckets (N, NI):");
    for (n, ni) in shapes {
        println!("  N={n:>6}  NI={ni:>6}  (P={})", n / ni);
    }
    Ok(())
}

/// `oggm train --n 20 --graphs 8 --episodes 20 --p 2 --tau 4 --out params.oggm`.
pub fn cmd_train(args: &Args) -> Result<()> {
    let rt = load_runtime()?;
    let opts = Options::from_args(args)?;
    let mut rng = Pcg32::new(opts.seed_or(1), 77);
    let n = args.get_usize("n", 20);
    let count = args.get_usize("graphs", 8);
    let graphs: Vec<Graph> = (0..count)
        .map(|_| generators::erdos_renyi(n, args.get_f64("rho", 0.15), &mut rng))
        .collect();
    let cfg = TrainCfg::from(&opts.clone().bucket(Partition::pad_to_bucket(n, 12)));
    let params = load_or_init_params(args, &mut rng)?;
    let mut trainer = Trainer::new(&rt, cfg, graphs, params)?;
    let episodes = args.get_usize("episodes", 20);
    let mut last_loss = None;
    trainer.run_episodes(episodes, |rec| {
        if rec.loss.is_some() {
            last_loss = rec.loss;
        }
        if rec.global_step % 10 == 0 {
            println!(
                "step {:>5}  episode {:>4}  loss {:>10}  sim {:.4}s",
                rec.global_step,
                rec.episode,
                rec.loss.map(|l| format!("{l:.5}")).unwrap_or_else(|| "-".into()),
                rec.sim_step_time
            );
        }
    })?;
    println!("trained {} steps; final loss {:?}", trainer.global_step, last_loss);
    if let Some(out) = args.get("out") {
        trainer.params.save(out)?;
        println!("saved params to {out}");
    }
    Ok(())
}

/// `oggm infer --n 250 --p 2 --multi --scenario mis --params trained.oggm`
/// — RL inference on one graph, any scenario (`--scenario` defaults to
/// mvc, preserving the historical MVC-only behavior).
pub fn cmd_infer(args: &Args) -> Result<()> {
    let rt = load_runtime()?;
    let opts = Options::from_args(args)?;
    let mut rng = Pcg32::new(opts.seed_or(2), 78);
    let g = resolve_graph(args, &mut rng)?;
    let params = load_or_init_params(args, &mut rng)?;
    let scenario = opts.scenario.unwrap_or(Scenario::Mvc);
    let bucket = rt.manifest.bucket_for(g.n, opts.p, 1)?;
    let cfg = InferCfg::from(&opts);
    let res = solve_scenario(&rt, &cfg, &params, &g, bucket, scenario)?;
    println!(
        "graph |V|={} |E|={}: {} solution size {} (objective {}) in {} evaluations \
         ({} selections)",
        g.n, g.m, scenario.name(), res.solution_size, res.objective, res.evaluations,
        res.selections
    );
    println!(
        "sim time/eval {:.4}s   wall total {:.2}s   comm {:.1} KiB over {} collectives",
        res.sim_time_per_eval,
        res.wall_total,
        res.timing.comm_bytes as f64 / 1024.0,
        res.timing.collectives
    );
    Ok(())
}

/// `oggm batch-solve --manifest jobs.txt --p 2 --multi --out results.json`
/// — the job-queue front-end over the graph-level batched solve engine.
/// `--demo <count>` synthesizes a mixed ER/BA manifest instead of reading
/// one (a zero-setup smoke path). `--scenario` overrides every job's
/// scenario; `--no-compact` disables early-exit pack compaction;
/// `--sparse` switches the packs to CSR storage (DESIGN.md §7);
/// `--engine rank-parallel` runs the packs on the persistent rank pool
/// (DESIGN.md §9); `--ranks tcp:<addr>,...` routes that pool over TCP
/// worker processes launched with `oggm rank` (DESIGN.md §12); `--check`
/// exits 0 with a notice when artifacts are not built (CI smoke mode,
/// both engines).
pub fn cmd_batch_solve(args: &Args) -> Result<()> {
    // Options are validated before the check-mode short-circuit (same
    // order as cmd_serve), so CI's artifact-less smoke still catches a
    // bad --engine/--scenario value.
    let opts = Options::from_args(args)?;
    if args.has_flag("check") && !manifest::default_dir().join("manifest.tsv").exists() {
        println!("batch-solve: artifacts not built, skipping (check mode OK)");
        return Ok(());
    }
    let rt = load_runtime()?;
    let mut rng = Pcg32::new(opts.seed_or(4), 80);
    let specs = match args.get("manifest") {
        Some(path) => batch::load_manifest(path)?,
        None => {
            let count = args.get_usize("demo", 0);
            if count == 0 {
                bail!("batch-solve needs --manifest <file> or --demo <count>");
            }
            batch::parse_manifest(&demo_manifest(args, &opts, count, false))?
        }
    };
    let mut jobs = Vec::with_capacity(specs.len());
    for spec in &specs {
        jobs.push(Job {
            id: spec.id.clone(),
            scenario: opts.scenario.unwrap_or(spec.scenario),
            graph: spec.materialize()?,
        });
    }
    println!("batch-solve: {} jobs", jobs.len());

    let cfg = BatchCfg::from(&opts);
    let params = load_or_init_params(args, &mut rng)?;
    let report = batch::run_queue_with(&rt, &cfg, &params, &jobs, opts.ranks.as_deref())?;

    for p in &report.packs {
        println!(
            "pack {:>3}: {:>6} N={:<5} jobs={:<3} capacity={:<3} rounds={:<4} repacks={} \
             sim {:.4}s  wall {:.2}s  h2d {:.1} KiB  d2h {:.1} KiB ({} execs)",
            p.pack, p.scenario.name(), p.bucket_n, p.jobs, p.capacity, p.rounds, p.repacks,
            p.sim_time, p.wall_time,
            p.exec.h2d_bytes as f64 / 1024.0,
            p.exec.d2h_bytes as f64 / 1024.0,
            p.exec.executions
        );
    }
    for o in &report.outcomes {
        println!(
            "job {:>12}: {:>6} |V|={:<5} |E|={:<6} solution={:<4} objective={:<8} \
             {} evals={} (pack {})",
            o.id, o.scenario.name(), o.nodes, o.edges, o.solution_size, o.objective,
            if o.valid { "valid" } else { "INVALID" }, o.evaluations, o.pack
        );
    }
    let invalid = report.outcomes.iter().filter(|o| !o.valid).count();
    println!(
        "batch-solve: {} jobs in {} packs, {:.2}s wall total ({} invalid)",
        report.outcomes.len(), report.packs.len(), report.wall_total, invalid
    );
    if let Some(out) = args.get("out") {
        std::fs::write(out, report.to_json().render())
            .with_context(|| format!("writing {out}"))?;
        println!("wrote {out}");
    }
    if invalid > 0 {
        bail!("{invalid} jobs produced invalid solutions");
    }
    Ok(())
}

/// Synthesize a demo job manifest: `count` mixed ER/BA jobs, deterministic
/// per `--seed`. With `mixed_scenarios` the jobs also cycle through every
/// scenario (the serve smoke path, so pack grouping is exercised);
/// batch-solve's historical demo keeps the default (mvc) scenario.
fn demo_manifest(args: &Args, opts: &Options, count: usize, mixed_scenarios: bool) -> String {
    let n = args.get_usize("n", 20);
    (0..count)
        .map(|i| {
            let model = if i % 2 == 0 { "er" } else { "ba" };
            let seed = opts.seed_or(4) + i as u64;
            let scenario = if mixed_scenarios {
                format!(" {}", Scenario::ALL[i % Scenario::ALL.len()].name())
            } else {
                String::new()
            };
            format!("gen {model} n={n} seed={seed} id=demo{i}{scenario}\n")
        })
        .collect()
}

/// Write one JSONL error object for a job that never reached the service
/// (parse / materialize / admission failure) — the stream stays one line
/// per input job either way, and the line counts toward the summary like
/// every other emitted line.
fn serve_error_line(out: &mut dyn Write, written: &mut usize, id: &str, err: &str) -> Result<()> {
    let line = Json::obj().set("id", id).set("error", err).render();
    writeln!(out, "{line}").context("writing JSONL output")?;
    // The failure is known now — stream it now (same contract as pack
    // outcomes; a tailing consumer must not wait for the next pack).
    out.flush().context("flushing JSONL output")?;
    *written += 1;
    Ok(())
}

/// Drain every ready service event to the JSONL sink (streaming: flushed
/// immediately so a tailing caller sees outcomes as packs finish).
fn serve_write_ready(
    svc: &mut Service<'_>,
    out: &mut dyn Write,
    written: &mut usize,
    failed: &mut usize,
) -> Result<()> {
    // Per-pack stats go to stderr as packs finish (and taking them keeps
    // the persistent session's stats buffer from growing without bound).
    let snap = svc.admission();
    for p in svc.take_packs() {
        eprintln!(
            "serve: pack {:>3}: {:>6} N={:<5} jobs={:<3} cause={:<8} capacity={:<3} \
             rounds={:<4} sim {:.4}s  h2d {:.1} KiB | depth={} open={}",
            p.pack,
            p.scenario.name(),
            p.bucket_n,
            p.jobs,
            p.cause.name(),
            p.capacity,
            p.rounds,
            p.sim_time,
            p.exec.h2d_bytes as f64 / 1024.0,
            snap.pending,
            snap.open_packs
        );
    }
    let mut any = false;
    while let Some(ev) = svc.poll() {
        if ev.result.is_err() {
            *failed += 1;
        }
        writeln!(out, "{}", ev.to_json().render()).context("writing JSONL output")?;
        *written += 1;
        any = true;
    }
    if any {
        out.flush().context("flushing JSONL output")?;
    }
    Ok(())
}

/// `oggm serve --jobs jobs.txt --out results.jsonl --p 2 --multi` — the
/// persistent solver service front door. Job lines (the batch-solve
/// manifest grammar, one job per line) stream in from `--jobs <file>` or
/// stdin; each is admitted into the warm [`Service`] as it arrives, and
/// one JSONL outcome line per job is appended to `--out` (default stdout)
/// as packs finish — results stream while later jobs are still being read.
/// `--demo <count>` synthesizes a mixed-scenario job stream instead of
/// reading input. `--scenario` overrides every job; `--max-wait <secs>`
/// and per-job `max_latency_ms=` launch partial packs on a real clock —
/// input lines arrive on a side thread and the loop sleeps exactly until
/// the earliest due pack, so an idle stream still launches on time;
/// `--engine rank-parallel` solves packs on a session-persistent rank pool
/// (DESIGN.md §9); `--ranks tcp:<addr>,...` routes that pool over `oggm
/// rank` worker processes (DESIGN.md §12); `--check` exits 0 with a notice
/// when artifacts are not built (CI smoke mode). Human-readable progress
/// goes to stderr so stdout stays pure JSONL.
///
/// `--listen ADDR` switches to the networked front door (DESIGN.md §10):
/// a TCP listener speaking the same line grammar (or its JSON form), one
/// connection per tenant, multiplexed into one warm session with
/// continuous batching, per-tenant quotas (`--quota`, default 64), a
/// bounded admission queue (`--queue-cap`), and `--max-conns N` for
/// deterministic drain-and-exit shutdown. A `{"op":"drain"}` request or
/// SIGTERM drains gracefully: stop accepting, flush open packs, stream
/// every remaining outcome, exit 0 (DESIGN.md §11).
///
/// Fault tolerance (DESIGN.md §11): `--retries N` re-solves a pack that
/// failed on a retryable fault (default 1), `--max-rank-restarts N`
/// budgets rank replacement per pack (default 2), and `--fault-plan
/// "rank=1,step=3,kind=panic"` injects deterministic faults for drills
/// (also via `OGGM_FAULT_PLAN`).
pub fn cmd_serve(args: &Args) -> Result<()> {
    let opts = Options::from_args(args)?;
    if args.has_flag("check") && !manifest::default_dir().join("manifest.tsv").exists() {
        println!("serve: artifacts not built, skipping (check mode OK)");
        return Ok(());
    }
    let mut rng = Pcg32::new(opts.seed_or(4), 80);
    let params = load_or_init_params(args, &mut rng)?;

    if let Some(addr) = &opts.listen {
        if args.get("jobs").is_some() || args.get_usize("demo", 0) > 0 {
            bail!("--listen serves sockets; --jobs/--demo are file-mode inputs");
        }
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding --listen {addr}"))?;
        eprintln!(
            "serve: listening on {} (quota {}, queue cap {}{})",
            listener.local_addr().context("reading the bound address")?,
            opts.quota.unwrap_or(net::server::DEFAULT_QUOTA),
            opts.queue_cap,
            match opts.max_conns {
                Some(n) => format!(", max {n} conns"),
                None => String::new(),
            }
        );
        let summary = net::serve(listener, manifest::default_dir(), params, &opts)?;
        eprintln!(
            "serve: {} conns, {} jobs in, {} JSONL lines out ({} failed), {} packs{}{}",
            summary.conns, summary.jobs, summary.lines_out, summary.failed,
            summary.snapshot.launched,
            if summary.slow_disconnects > 0 {
                format!(", {} slow consumers disconnected", summary.slow_disconnects)
            } else {
                String::new()
            },
            if summary.drained { " [drained]" } else { "" }
        );
        eprintln!(
            "serve: admission {}",
            metrics::admission_stats_json(&summary.snapshot).render()
        );
        return Ok(());
    }

    let rt = load_runtime()?;
    let mut svc = Service::new(&rt, params, &opts);

    if args.get("jobs").is_some() && args.get_usize("demo", 0) > 0 {
        bail!("--jobs and --demo are mutually exclusive (one real stream or one synthetic)");
    }
    let reader: Box<dyn BufRead + Send> = match args.get_usize("demo", 0) {
        0 => match args.get("jobs") {
            Some(path) => Box::new(std::io::BufReader::new(
                std::fs::File::open(path).with_context(|| format!("opening --jobs {path}"))?,
            )),
            None => Box::new(std::io::BufReader::new(std::io::stdin())),
        },
        count => Box::new(std::io::Cursor::new(demo_manifest(args, &opts, count, true))),
    };
    let mut out: Box<dyn Write> = match args.get("out") {
        Some(path) => Box::new(std::io::BufWriter::new(
            std::fs::File::create(path).with_context(|| format!("creating --out {path}"))?,
        )),
        None => Box::new(std::io::stdout()),
    };

    let (mut parsed, mut written, mut failed) = (0usize, 0usize, 0usize);
    let mut lineno = 0usize;
    // Input lines arrive over a channel so the loop can sleep exactly
    // until the earliest due pack — the same tick driver the TCP front
    // loop uses (one clock for both serve modes).
    let lines = net::driver::spawn_line_reader(reader);
    loop {
        let raw = match net::driver::recv_deadline(&lines, svc.next_due()) {
            Err(RecvTimeoutError::Timeout) => {
                // A pack came due (deadline or max-wait) while the input
                // stream was idle: launch it and stream the results now.
                svc.tick();
                serve_write_ready(&mut svc, &mut out, &mut written, &mut failed)?;
                continue;
            }
            Err(RecvTimeoutError::Disconnected) => break,
            Ok(line) => line.context("reading job input")?,
        };
        lineno += 1;
        // Every input line is also a chance to fire the clock policies and
        // stream whatever finished, even when the line itself admits
        // nothing (comments, blanks, malformed lines).
        svc.tick();
        serve_write_ready(&mut svc, &mut out, &mut written, &mut failed)?;
        let spec = match batch::parse_job_line(&raw, parsed) {
            Ok(None) => continue,
            Ok(Some(spec)) => spec,
            Err(e) => {
                // One bad line must not kill the session: emit an error
                // object for it and keep serving.
                let id = format!("line{lineno}");
                serve_error_line(&mut out, &mut written, &id, &format!("{e:#}"))?;
                failed += 1;
                continue;
            }
        };
        parsed += 1;
        let id = spec.id.clone();
        let meta = SubmitMeta {
            tenant: 0,
            max_latency: spec.max_latency_ms.map(Duration::from_millis),
        };
        let job = match spec.materialize() {
            Ok(graph) => {
                Job { id: id.clone(), scenario: opts.scenario.unwrap_or(spec.scenario), graph }
            }
            Err(e) => {
                serve_error_line(&mut out, &mut written, &id, &format!("{e:#}"))?;
                failed += 1;
                continue;
            }
        };
        if let Err(e) = svc.submit_with(job, meta) {
            serve_error_line(&mut out, &mut written, &id, &format!("{e:#}"))?;
            failed += 1;
        }
        // Stream whatever finished (a pack that filled launches inside
        // submit; clock launches happen in the service's tick).
        serve_write_ready(&mut svc, &mut out, &mut written, &mut failed)?;
    }
    // EOF: solve the partial packs and drain the tail.
    svc.flush();
    serve_write_ready(&mut svc, &mut out, &mut written, &mut failed)?;
    out.flush().context("flushing JSONL output")?;

    eprintln!(
        "serve: {} jobs in, {} JSONL lines out ({} failed), {} packs, \
         warm device state {:.1} KiB",
        parsed,
        written,
        failed,
        svc.launched(),
        rt.keyed_bytes() as f64 / 1024.0
    );
    eprintln!("serve: admission {}", metrics::admission_stats_json(&svc.admission()).render());
    Ok(())
}

/// `oggm rank --connect 127.0.0.1:7701 --rank 1 [--world 2]` — a
/// process-separated rank worker (DESIGN.md §12). Connects to a
/// coordinator started with `--engine rank-parallel --ranks tcp:<addr>,...`
/// (batch-solve or serve), handshakes rank id, world size, and the local
/// artifact-manifest fingerprint — mismatched processes are rejected
/// before any work — then serves the same request protocol the in-process
/// worker threads speak until the coordinator closes the session.
/// `--world` cross-checks the coordinator's P when given; `--fault-plan`
/// (or `OGGM_FAULT_PLAN`) injects deterministic faults for drills. The
/// connect retries for `OGGM_RANK_WAIT_SECS` (default 60), so workers may
/// be launched before the coordinator listens.
///
/// Recovery knobs (DESIGN.md §12): `--reconnect[=N]` redials a lost
/// coordinator link up to N times (bare flag = 5) with exponential
/// backoff, re-running the Hello/Welcome handshake to rejoin the same
/// rank slot; `--token <secret>` (or `OGGM_TOKEN`) is the shared secret
/// the coordinator's `--token` demands in that handshake.
pub fn cmd_rank(args: &Args) -> Result<()> {
    let addr = args.get("connect").context("oggm rank needs --connect <host:port>")?;
    let rank = args
        .get("rank")
        .context("oggm rank needs --rank <R> (which rank this worker serves)")?
        .parse::<usize>()
        .context("--rank must be a non-negative integer")?;
    let world = match args.get("world") {
        Some(w) => {
            Some(w.parse::<usize>().context("--world must be a positive integer")?)
        }
        None => None,
    };
    let fault = match args.get("fault-plan") {
        Some(spec) => Some(Arc::new(
            FaultPlan::parse(spec).context("parsing the --fault-plan spec")?,
        )),
        None => FaultPlan::from_env()?,
    };
    // `--reconnect 3` / `--reconnect=3` bounds the redial budget; the bare
    // flag gets a stock budget of 5 (backoff 250ms..5s, see
    // `reconnect_backoff`). Absent = exit on the first lost link.
    let reconnect = if args.get("reconnect").is_some() {
        args.get_usize("reconnect", 0)
    } else if args.has_flag("reconnect") {
        5
    } else {
        0
    };
    let token = match args.get("token") {
        Some(t) => t.to_string(),
        None => std::env::var("OGGM_TOKEN").unwrap_or_default(),
    };
    eprintln!("rank {rank}: connecting to coordinator at {addr}");
    crate::parallel::remote_worker_with(
        manifest::default_dir(),
        addr,
        rank,
        world,
        fault,
        &token,
        reconnect,
    )?;
    eprintln!("rank {rank}: session closed by the coordinator; exiting");
    Ok(())
}

/// `oggm solve --n 100` — classical baselines on one graph.
pub fn cmd_solve(args: &Args) -> Result<()> {
    let opts = Options::from_args(args)?;
    let mut rng = Pcg32::new(opts.seed_or(3), 79);
    let g = resolve_graph(args, &mut rng)?;
    let s = stats::dataset_stats("input", &g);
    println!("graph |V|={} |E|={} rho={:.4}", s.nodes, s.edges, s.rho);
    let greedy = crate::solvers::greedy_mvc(&g);
    println!("greedy cover:   {}", greedy.iter().filter(|&&b| b).count());
    let approx = crate::solvers::two_approx_mvc(&g);
    println!("2-approx cover: {}", approx.iter().filter(|&&b| b).count());
    let budget = std::time::Duration::from_secs_f64(args.get_f64("budget", 10.0));
    let exact = crate::solvers::exact_mvc(&g, budget);
    println!(
        "exact cover:    {} ({}, {} B&B nodes)",
        exact.size,
        if exact.optimal { "optimal" } else { "cutoff hit" },
        exact.nodes_explored
    );
    Ok(())
}

/// Instances for `oggm eval`: one real-format file (`--graph`, SNAP edge
/// list or `.mtx`) or `--count` synthetic graphs from the generator knobs.
fn eval_instances(args: &Args, rng: &mut Pcg32) -> Result<Vec<Instance>> {
    if let Some(path) = args.get("graph") {
        let name = std::path::Path::new(path)
            .file_stem()
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_else(|| path.to_string());
        return Ok(vec![Instance { name, graph: gio::read_graph(path)? }]);
    }
    let count = args.get_usize("count", 4);
    if count == 0 {
        bail!("eval needs --graph <file> or --count >= 1 synthetic instances");
    }
    let kind = args.get_or("gen", "er");
    let mut out = Vec::with_capacity(count);
    for i in 0..count {
        out.push(Instance { name: format!("{kind}{i}"), graph: gen_graph(args, &kind, rng)? });
    }
    Ok(out)
}

/// `oggm eval --graph web.mtx --scenario mvc --baselines exact,greedy,approx2
/// --budget 30 --out report.json` — the solution-quality harness
/// (EXPERIMENTS.md §Quality). Solves each instance with the RL engine
/// through the batched `Service` path (same knobs as batch-solve:
/// `--engine`, `--sparse`, `--p`, `--multi`, ...) and with the classical
/// baselines, re-validates every solution with `solvers::verify`, and
/// reports per-solver approximation ratios against the exact optimum when
/// proven (else the best feasible objective) plus wall and per-step time.
/// Instances: `--graph <file>` (SNAP edge list or MatrixMarket, dispatched
/// on extension) or `--gen er|ba|hk|rmat --n/--scale/--ef --count k`.
/// `--no-rl` scores baselines only; without artifacts RL is skipped with a
/// notice (so `--check` CI smokes run baselines-only and still exit 0).
/// `--budget` caps the exact solver's seconds, `--exact-cap` its node
/// count. Any infeasible solution is a hard error.
pub fn cmd_eval(args: &Args) -> Result<()> {
    let opts = Options::from_args(args)?;
    let scenario = opts.scenario.unwrap_or(Scenario::Mvc);
    let mut cfg = EvalCfg::new(scenario);
    cfg.baselines = Baseline::parse_list(&args.get_or("baselines", "default"), scenario)?;
    cfg.exact_budget = Duration::from_secs_f64(args.get_f64("budget", 10.0));
    cfg.exact_node_cap = args.get_usize("exact-cap", 2000);
    cfg.seed = opts.seed_or(3);
    cfg.ls_rounds = args.get_usize("ls-rounds", 200);

    let mut rng = Pcg32::new(opts.seed_or(3), 81);
    let instances = eval_instances(args, &mut rng)?;
    println!(
        "eval: {} {} instance(s), baselines [{}]",
        instances.len(),
        scenario.name(),
        cfg.baselines.iter().map(|b| b.name()).collect::<Vec<_>>().join(",")
    );

    let want_rl = !args.has_flag("no-rl");
    let have_artifacts = manifest::default_dir().join("manifest.tsv").exists();
    let report = if want_rl && have_artifacts {
        let rt = load_runtime()?;
        let params = load_or_init_params(args, &mut rng)?;
        quality::evaluate(Some(&rt), Some(&params), &opts, &cfg, &instances)?
    } else {
        if want_rl {
            println!("eval: artifacts not built; scoring classical baselines only");
        }
        quality::evaluate(None, None, &opts, &cfg, &instances)?
    };

    for inst in &report.instances {
        println!(
            "instance {}: |V|={} |E|={}  reference {}={}{}",
            inst.name,
            inst.nodes,
            inst.edges,
            inst.ref_solver,
            inst.ref_objective,
            if inst.ref_optimal { " (optimal)" } else { "" }
        );
        for s in &inst.scores {
            println!(
                "  {:<12} objective {:<10} ratio {:.4}  {}  wall {:.3}s{}",
                s.solver,
                s.objective,
                s.ratio,
                if s.feasible { "feasible" } else { "INFEASIBLE" },
                s.wall_s,
                match s.per_step_ms {
                    Some(ms) => format!("  per-step {ms:.2}ms"),
                    None => String::new(),
                }
            );
        }
    }
    println!(
        "eval: worst ratio {:.4} over {} instance(s), {} infeasible",
        report.worst_ratio(),
        report.instances.len(),
        report.infeasible_count()
    );
    if let Some(out) = args.get("out") {
        std::fs::write(out, report.to_json().render())
            .with_context(|| format!("writing {out}"))?;
        println!("wrote {out}");
    }
    if report.infeasible_count() > 0 {
        bail!("{} solver scores failed feasibility validation", report.infeasible_count());
    }
    Ok(())
}
