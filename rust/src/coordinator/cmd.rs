//! CLI subcommand implementations for the `oggm` binary.

use super::infer::{solve_mvc, InferCfg};
use super::selection::SelectionPolicy;
use super::train::{TrainCfg, Trainer};
use crate::batch::{self, BatchCfg, Job};
use crate::graph::{generators, io as gio, stats, Graph, Partition};
use crate::model::Params;
use crate::runtime::{manifest, Runtime};
use crate::util::cli::Args;
use crate::util::rng::Pcg32;
use anyhow::{bail, Context, Result};

fn load_runtime() -> Result<Runtime> {
    Runtime::new(manifest::default_dir())
}

/// Resolve a graph from CLI options: `--graph <file>` (edge list) or a
/// generator spec `--gen er|ba|hk --n <nodes>`.
fn resolve_graph(args: &Args, rng: &mut Pcg32) -> Result<Graph> {
    if let Some(path) = args.get("graph") {
        return gio::read_edge_list(path);
    }
    let n = args.get_usize("n", 250);
    match args.get_or("gen", "er").as_str() {
        "er" => Ok(generators::erdos_renyi(n, args.get_f64("rho", generators::ER_RHO), rng)),
        "ba" => Ok(generators::barabasi_albert(n, args.get_usize("d", generators::BA_D), rng)),
        "hk" => Ok(generators::holme_kim(n, args.get_usize("d", generators::BA_D),
                                         args.get_f64("triad", 0.25), rng)),
        other => bail!("unknown generator '{other}' (er|ba|hk)"),
    }
}

fn load_or_init_params(args: &Args, rng: &mut Pcg32) -> Result<Params> {
    match args.get("params") {
        Some(path) => Params::load(path, 32).context("loading --params"),
        None => {
            let init = manifest::default_dir().join("params_init.oggm");
            if init.exists() {
                Params::load(init, 32)
            } else {
                Ok(Params::init(32, rng))
            }
        }
    }
}

/// `oggm info`: manifest + platform summary.
pub fn cmd_info(_args: &Args) -> Result<()> {
    let rt = load_runtime()?;
    println!("platform: {}", rt.platform());
    println!("artifacts: {} entries (K={}, L={})", rt.manifest.entries.len(),
             rt.manifest.k, rt.manifest.l);
    let mut shapes = rt.manifest.available_fwd_shapes(1);
    shapes.dedup();
    println!("inference buckets (N, NI):");
    for (n, ni) in shapes {
        println!("  N={n:>6}  NI={ni:>6}  (P={})", n / ni);
    }
    Ok(())
}

/// `oggm train --n 20 --graphs 8 --episodes 20 --p 2 --tau 4 --out params.oggm`.
pub fn cmd_train(args: &Args) -> Result<()> {
    let rt = load_runtime()?;
    let seed = args.get_u64("seed", 1);
    let mut rng = Pcg32::new(seed, 77);
    let n = args.get_usize("n", 20);
    let count = args.get_usize("graphs", 8);
    let graphs: Vec<Graph> = (0..count)
        .map(|_| generators::erdos_renyi(n, args.get_f64("rho", 0.15), &mut rng))
        .collect();
    let bucket = Partition::pad_to_bucket(n, 12);
    let mut cfg = TrainCfg::new(args.get_usize("p", 1), bucket);
    cfg.seed = seed;
    cfg.hyper.lr = args.get_f64("lr", 1e-3) as f32;
    cfg.hyper.grad_iters = args.get_usize("tau", 1);
    cfg.hyper.batch_size = args.get_usize("batch", 8);
    if args.has_flag("sparse") {
        cfg.storage = super::shard::Storage::Sparse;
    }
    let params = load_or_init_params(args, &mut rng)?;
    let mut trainer = Trainer::new(&rt, cfg, graphs, params)?;
    let episodes = args.get_usize("episodes", 20);
    let mut last_loss = None;
    trainer.run_episodes(episodes, |rec| {
        if rec.loss.is_some() {
            last_loss = rec.loss;
        }
        if rec.global_step % 10 == 0 {
            println!(
                "step {:>5}  episode {:>4}  loss {:>10}  sim {:.4}s",
                rec.global_step,
                rec.episode,
                rec.loss.map(|l| format!("{l:.5}")).unwrap_or_else(|| "-".into()),
                rec.sim_step_time
            );
        }
    })?;
    println!("trained {} steps; final loss {:?}", trainer.global_step, last_loss);
    if let Some(out) = args.get("out") {
        trainer.params.save(out)?;
        println!("saved params to {out}");
    }
    Ok(())
}

/// `oggm infer --n 250 --p 2 --multi --params trained.oggm`.
pub fn cmd_infer(args: &Args) -> Result<()> {
    let rt = load_runtime()?;
    let mut rng = Pcg32::new(args.get_u64("seed", 2), 78);
    let g = resolve_graph(args, &mut rng)?;
    let params = load_or_init_params(args, &mut rng)?;
    let p = args.get_usize("p", 1);
    let bucket = rt.manifest.bucket_for(g.n, p, 1)?;
    let mut cfg = InferCfg::new(p, 2);
    if args.has_flag("multi") {
        cfg.policy = SelectionPolicy::AdaptiveMulti;
    }
    if args.has_flag("sparse") {
        cfg.storage = super::shard::Storage::Sparse;
    }
    let res = solve_mvc(&rt, &cfg, &params, &g, bucket)?;
    println!(
        "graph |V|={} |E|={}: cover size {} in {} evaluations ({} selections)",
        g.n, g.m, res.solution_size, res.evaluations, res.selections
    );
    println!(
        "sim time/eval {:.4}s   wall total {:.2}s   comm {:.1} KiB over {} collectives",
        res.sim_time_per_eval,
        res.wall_total,
        res.timing.comm_bytes as f64 / 1024.0,
        res.timing.collectives
    );
    Ok(())
}

/// `oggm batch-solve --manifest jobs.txt --p 2 --multi --out results.json`
/// — the job-queue front-end over the graph-level batched solve engine.
/// `--demo <count>` synthesizes a mixed ER/BA manifest instead of reading
/// one (a zero-setup smoke path). `--scenario` overrides every job's
/// scenario; `--no-compact` disables early-exit pack compaction;
/// `--sparse` switches the packs to CSR storage (DESIGN.md §7).
pub fn cmd_batch_solve(args: &Args) -> Result<()> {
    let rt = load_runtime()?;
    let mut rng = Pcg32::new(args.get_u64("seed", 4), 80);
    let specs = match args.get("manifest") {
        Some(path) => batch::load_manifest(path)?,
        None => {
            let count = args.get_usize("demo", 0);
            if count == 0 {
                bail!("batch-solve needs --manifest <file> or --demo <count>");
            }
            let n = args.get_usize("n", 20);
            // Mixed ER/BA jobs, deterministic per --seed.
            let text: String = (0..count)
                .map(|i| {
                    let model = if i % 2 == 0 { "er" } else { "ba" };
                    let seed = args.get_u64("seed", 4) + i as u64;
                    format!("gen {model} n={n} seed={seed} id=demo{i}\n")
                })
                .collect();
            batch::parse_manifest(&text)?
        }
    };
    let override_scenario = match args.get("scenario") {
        Some(s) => Some(crate::env::Scenario::parse(s)?),
        None => None,
    };
    let mut jobs = Vec::with_capacity(specs.len());
    for spec in &specs {
        jobs.push(Job {
            id: spec.id.clone(),
            scenario: override_scenario.unwrap_or(spec.scenario),
            graph: spec.materialize()?,
        });
    }
    println!("batch-solve: {} jobs", jobs.len());

    let mut cfg = BatchCfg::new(args.get_usize("p", 1), 2);
    if args.has_flag("multi") {
        cfg.policy = SelectionPolicy::AdaptiveMulti;
    }
    if args.has_flag("no-compact") {
        cfg.compact = false;
    }
    if args.has_flag("sparse") {
        cfg.storage = super::shard::Storage::Sparse;
    }
    let params = load_or_init_params(args, &mut rng)?;
    let report = batch::run_queue(&rt, &cfg, &params, &jobs)?;

    for p in &report.packs {
        println!(
            "pack {:>3}: {:>6} N={:<5} jobs={:<3} capacity={:<3} rounds={:<4} repacks={} \
             sim {:.4}s  wall {:.2}s  h2d {:.1} KiB  d2h {:.1} KiB ({} execs)",
            p.pack, p.scenario.name(), p.bucket_n, p.jobs, p.capacity, p.rounds, p.repacks,
            p.sim_time, p.wall_time,
            p.exec.h2d_bytes as f64 / 1024.0,
            p.exec.d2h_bytes as f64 / 1024.0,
            p.exec.executions
        );
    }
    for o in &report.outcomes {
        println!(
            "job {:>12}: {:>6} |V|={:<5} |E|={:<6} solution={:<4} objective={:<8} \
             {} evals={} (pack {})",
            o.id, o.scenario.name(), o.nodes, o.edges, o.solution_size, o.objective,
            if o.valid { "valid" } else { "INVALID" }, o.evaluations, o.pack
        );
    }
    let invalid = report.outcomes.iter().filter(|o| !o.valid).count();
    println!(
        "batch-solve: {} jobs in {} packs, {:.2}s wall total ({} invalid)",
        report.outcomes.len(), report.packs.len(), report.wall_total, invalid
    );
    if let Some(out) = args.get("out") {
        std::fs::write(out, report.to_json().render())
            .with_context(|| format!("writing {out}"))?;
        println!("wrote {out}");
    }
    if invalid > 0 {
        bail!("{invalid} jobs produced invalid solutions");
    }
    Ok(())
}

/// `oggm solve --n 100` — classical baselines on one graph.
pub fn cmd_solve(args: &Args) -> Result<()> {
    let mut rng = Pcg32::new(args.get_u64("seed", 3), 79);
    let g = resolve_graph(args, &mut rng)?;
    let s = stats::dataset_stats("input", &g);
    println!("graph |V|={} |E|={} rho={:.4}", s.nodes, s.edges, s.rho);
    let greedy = crate::solvers::greedy_mvc(&g);
    println!("greedy cover:   {}", greedy.iter().filter(|&&b| b).count());
    let approx = crate::solvers::two_approx_mvc(&g);
    println!("2-approx cover: {}", approx.iter().filter(|&&b| b).count());
    let budget = std::time::Duration::from_secs_f64(args.get_f64("budget", 10.0));
    let exact = crate::solvers::exact_mvc(&g, budget);
    println!(
        "exact cover:    {} ({}, {} B&B nodes)",
        exact.size,
        if exact.optimal { "optimal" } else { "cutoff hit" },
        exact.nodes_explored
    );
    Ok(())
}
