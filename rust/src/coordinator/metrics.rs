//! Metrics output: learning-curve records, bench rows, JSON/CSV writers.

use crate::runtime::ExecStats;
use crate::service::AdmissionSnapshot;
use crate::util::json::Json;
use anyhow::Result;
use std::io::Write;
use std::path::Path;

/// Runtime transfer/execution counters as a JSON object (the shared shape
/// for `oggm batch-solve` pack stats and the transfer bench).
pub fn exec_stats_json(st: &ExecStats) -> Json {
    Json::obj()
        .set("executions", st.executions)
        .set("h2d_bytes", st.h2d_bytes)
        .set("d2h_bytes", st.d2h_bytes)
        .set("cache_hits", st.cache_hits)
        .set("exec_time", st.exec_time.as_secs_f64())
        .set("h2d_time", st.h2d_time.as_secs_f64())
        .set("d2h_time", st.d2h_time.as_secs_f64())
        .set("compile_time", st.compile_time.as_secs_f64())
        .set("restarts", st.restarts)
        .set("recovery_time", st.recovery_time.as_secs_f64())
        .set("tx_bytes", st.tx_bytes)
        .set("rx_bytes", st.rx_bytes)
        .set("remote_restarts", st.remote_restarts)
        .set("heartbeats_missed", st.heartbeats_missed)
        .set("rejoin_time", st.rejoin_time.as_secs_f64())
}

/// Admission/backpressure counters as a JSON object — the shared shape for
/// `oggm serve` stderr stats, the net front door's `{"op":"stats"}`
/// response, and `BENCH_service_load.json` (DESIGN.md §10).
pub fn admission_stats_json(snap: &AdmissionSnapshot) -> Json {
    Json::obj()
        .set("submitted", snap.submitted)
        .set("rejected", snap.rejected)
        .set("pending", snap.pending)
        .set("in_flight", snap.in_flight)
        .set("open_packs", snap.open_packs)
        .set("peak_pending", snap.peak_pending)
        .set("tenants", snap.tenants)
        .set("max_tenant_load", snap.max_tenant_load)
        .set("launched", snap.launched)
        .set(
            "launch_causes",
            Json::obj()
                .set("fill", snap.fill_launches)
                .set("deadline", snap.deadline_launches)
                .set("max_wait", snap.max_wait_launches)
                .set("flush", snap.flush_launches),
        )
        .set("queue_full_rejects", snap.queue_full_rejects)
        .set("retried_packs", snap.retried_packs)
        .set("pack_faults", snap.pack_faults)
}

/// Approximation ratio |sol| / |opt| (the paper's quality metric, Fig. 6/8).
pub fn approx_ratio(solution_size: usize, optimal_size: usize) -> f64 {
    if optimal_size == 0 {
        return if solution_size == 0 { 1.0 } else { f64::INFINITY };
    }
    solution_size as f64 / optimal_size as f64
}

/// A learning-curve point (training step → mean test approx ratio).
#[derive(Debug, Clone)]
pub struct CurvePoint {
    /// Global training step of the measurement.
    pub step: usize,
    /// Mean test approximation ratio at that step.
    pub ratio: f64,
    /// Mean training loss at that step, if training ran.
    pub loss: Option<f64>,
}

/// Write curve points as CSV (step,ratio,loss).
pub fn write_curve_csv(path: impl AsRef<Path>, points: &[CurvePoint]) -> Result<()> {
    let mut w = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(w, "step,ratio,loss")?;
    for p in points {
        writeln!(
            w,
            "{},{:.6},{}",
            p.step,
            p.ratio,
            p.loss.map(|l| format!("{l:.6}")).unwrap_or_default()
        )?;
    }
    Ok(())
}

/// A generic bench row: label → named values; renders aligned tables and JSON.
#[derive(Debug, Clone)]
pub struct Table {
    /// Table caption (printed and logged).
    pub title: String,
    /// Column headers.
    pub columns: Vec<String>,
    /// (row label, values) pairs.
    pub rows: Vec<(String, Vec<f64>)>,
}

impl Table {
    /// Create an empty table with a caption and column headers.
    pub fn new(title: &str, columns: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append one row.
    pub fn row(&mut self, label: impl Into<String>, values: Vec<f64>) {
        let label = label.into();
        assert_eq!(values.len(), self.columns.len(), "row width mismatch in {}", self.title);
        self.rows.push((label, values));
    }

    /// Render as an aligned text table (what the bench binaries print).
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let label_w = self
            .rows
            .iter()
            .map(|(l, _)| l.len())
            .chain(std::iter::once(8))
            .max()
            .unwrap();
        out.push_str(&format!("{:label_w$}", ""));
        for c in &self.columns {
            out.push_str(&format!(" {c:>14}"));
        }
        out.push('\n');
        for (label, vals) in &self.rows {
            out.push_str(&format!("{label:label_w$}"));
            for v in vals {
                if v.abs() >= 1000.0 || (*v != 0.0 && v.abs() < 0.001) {
                    out.push_str(&format!(" {v:>14.4e}"));
                } else {
                    out.push_str(&format!(" {v:>14.4}"));
                }
            }
            out.push('\n');
        }
        out
    }

    /// Render as a JSON object (bench_results.jsonl rows).
    pub fn to_json(&self) -> Json {
        let rows: Vec<Json> = self
            .rows
            .iter()
            .map(|(label, vals)| {
                let mut o = Json::obj().set("label", label.as_str());
                for (c, v) in self.columns.iter().zip(vals) {
                    o = o.set(c, *v);
                }
                o
            })
            .collect();
        Json::obj().set("title", self.title.as_str()).set("rows", Json::Arr(rows))
    }

    /// Append the JSON form to a results file (one JSON object per line).
    pub fn append_jsonl(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut f = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
        writeln!(f, "{}", self.to_json().render())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ratio_edge_cases() {
        assert_eq!(approx_ratio(10, 8), 1.25);
        assert_eq!(approx_ratio(0, 0), 1.0);
        assert!(approx_ratio(1, 0).is_infinite());
    }

    #[test]
    fn exec_stats_render_as_json() {
        let mut st = ExecStats::default();
        st.executions = 12;
        st.h2d_bytes = 4096;
        st.d2h_bytes = 128;
        st.cache_hits = 3;
        st.restarts = 2;
        st.recovery_time = std::time::Duration::from_millis(250);
        st.tx_bytes = 777;
        st.rx_bytes = 333;
        st.remote_restarts = 1;
        st.heartbeats_missed = 4;
        st.rejoin_time = std::time::Duration::from_millis(500);
        let s = exec_stats_json(&st).render();
        assert!(s.contains("\"executions\":12"), "{s}");
        assert!(s.contains("\"h2d_bytes\":4096"), "{s}");
        assert!(s.contains("\"d2h_bytes\":128"), "{s}");
        assert!(s.contains("\"cache_hits\":3"), "{s}");
        assert!(s.contains("\"restarts\":2"), "{s}");
        assert!(s.contains("\"recovery_time\":0.25"), "{s}");
        assert!(s.contains("\"tx_bytes\":777"), "{s}");
        assert!(s.contains("\"rx_bytes\":333"), "{s}");
        assert!(s.contains("\"remote_restarts\":1"), "{s}");
        assert!(s.contains("\"heartbeats_missed\":4"), "{s}");
        assert!(s.contains("\"rejoin_time\":0.5"), "{s}");
    }

    #[test]
    fn admission_stats_render_as_json() {
        let snap = AdmissionSnapshot {
            submitted: 9,
            rejected: 2,
            pending: 3,
            in_flight: 4,
            open_packs: 1,
            peak_pending: 5,
            tenants: 2,
            max_tenant_load: 4,
            launched: 2,
            fill_launches: 1,
            deadline_launches: 1,
            queue_full_rejects: 1,
            retried_packs: 1,
            pack_faults: 2,
            ..Default::default()
        };
        let s = admission_stats_json(&snap).render();
        assert!(s.contains("\"submitted\":9"), "{s}");
        assert!(s.contains("\"rejected\":2"), "{s}");
        assert!(s.contains("\"in_flight\":4"), "{s}");
        assert!(s.contains("\"max_tenant_load\":4"), "{s}");
        assert!(s.contains("\"deadline\":1"), "{s}");
        assert!(s.contains("\"queue_full_rejects\":1"), "{s}");
        assert!(s.contains("\"retried_packs\":1"), "{s}");
        assert!(s.contains("\"pack_faults\":2"), "{s}");
    }

    #[test]
    fn table_renders_and_jsons() {
        let mut t = Table::new("fig9", &["p1", "p6"]);
        t.row("n=1488", vec![1.5, 0.3]);
        let s = t.render();
        assert!(s.contains("fig9") && s.contains("n=1488") && s.contains("0.3"));
        let j = t.to_json().render();
        assert!(j.contains("\"p6\":0.3"));
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn table_checks_width() {
        let mut t = Table::new("x", &["a"]);
        t.row("r", vec![1.0, 2.0]);
    }

    #[test]
    fn curve_csv_writes() {
        let dir = std::env::temp_dir().join(format!("oggm_metrics_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("curve.csv");
        write_curve_csv(
            &p,
            &[
                CurvePoint { step: 0, ratio: 1.5, loss: None },
                CurvePoint { step: 10, ratio: 1.2, loss: Some(0.5) },
            ],
        )
        .unwrap();
        let s = std::fs::read_to_string(&p).unwrap();
        assert!(s.starts_with("step,ratio,loss"));
        assert!(s.contains("10,1.200000,0.500000"));
        std::fs::remove_dir_all(&dir).ok();
    }
}
