//! Parallel RL inference (Alg. 4): distributed policy evaluation per step,
//! score all-gather, (multi-)node selection, distributed state update —
//! until the environment reports a complete solution.

use super::engine::{Engine, EngineCfg, StepTiming};
use super::selection::{select_count, top_d, SelectionPolicy};
use super::shard::{shards_for_graph, sparse_shards_for_graph, ShardSet, Storage};
use crate::env::{GraphEnv, Scenario};
use crate::graph::{Graph, Partition};
use crate::model::Params;
use crate::parallel::{ExecEngine, RankPool};
use crate::runtime::Runtime;
use anyhow::Result;
use std::time::Instant;

/// Inference configuration.
#[derive(Debug, Clone, Copy)]
pub struct InferCfg {
    /// Shared engine parameters (P, L, comm cost model).
    pub engine: EngineCfg,
    /// Node-selection policy (single / adaptive multi / fixed multi).
    pub policy: SelectionPolicy,
    /// Elide layer-0 message stage (exact; see fwd.rs).
    pub skip_zero_layer: bool,
    /// Hold θ + adjacency state on device across steps (exact; see fwd.rs
    /// `DeviceState`/`SparseDeviceState`). Off = the fresh-upload reference
    /// path.
    pub device_resident: bool,
    /// Per-shard storage mode (DESIGN.md §7): dense B×NI×N oracle or
    /// CSR-backed sparse tiles scaling O(E/P + NI).
    pub storage: Storage,
}

impl InferCfg {
    /// Default configuration for `p` shards and `l` embedding layers.
    pub fn new(p: usize, l: usize) -> InferCfg {
        InferCfg {
            engine: EngineCfg::new(p, l),
            policy: SelectionPolicy::Single,
            skip_zero_layer: true,
            device_resident: true,
            storage: Storage::Dense,
        }
    }
}

/// Result of solving one graph by RL inference.
#[derive(Debug)]
pub struct InferResult {
    /// Solution mask over the (unpadded) nodes.
    pub solution: Vec<bool>,
    /// Number of selected nodes |S|.
    pub solution_size: usize,
    /// Scenario objective of the final solution (|S| except MaxCut: cut weight).
    pub objective: f64,
    /// Policy-model evaluations performed (= steps of Alg. 4).
    pub evaluations: usize,
    /// Nodes selected in total (>= evaluations under multi-select).
    pub selections: usize,
    /// Per-evaluation timing, accumulated.
    pub timing: StepTiming,
    /// Simulated-parallel seconds per evaluation (mean).
    pub sim_time_per_eval: f64,
    /// Wall-clock total.
    pub wall_total: f64,
}

/// Solve one environment instance by RL inference (Alg. 4 generalized over
/// scenarios). `env` must be freshly constructed over `g`; the scenario's
/// residual-graph semantics are mirrored onto the shards by diffing the
/// environment's removed mask after each selection (MVC removes the node,
/// MIS its closed neighborhood, MaxCut nothing).
pub fn solve_env(
    rt: &Runtime,
    cfg: &InferCfg,
    params: &Params,
    g: &Graph,
    bucket_n: usize,
    env: &mut dyn GraphEnv,
) -> Result<InferResult> {
    // The rank-parallel engine amortizes its pool across every step of
    // this solve; persistent callers hold one across solves and pass it
    // through `solve_env_in` instead.
    let transient = match cfg.engine.mode {
        Engine::Lockstep => None,
        Engine::RankParallel => Some(RankPool::new(rt.manifest.dir.clone(), cfg.engine.p)?),
    };
    solve_env_in(rt, cfg, params, g, bucket_n, env, transient.as_ref())
}

/// [`solve_env`] with an optional caller-owned [`RankPool`] (required —
/// and used — only when `cfg.engine.mode` is [`Engine::RankParallel`];
/// a warm pool skips the per-solve θ upload and thread spawns).
pub fn solve_env_in(
    rt: &Runtime,
    cfg: &InferCfg,
    params: &Params,
    g: &Graph,
    bucket_n: usize,
    env: &mut dyn GraphEnv,
    pool: Option<&RankPool>,
) -> Result<InferResult> {
    let wall = Instant::now();
    let part = Partition::new(bucket_n, cfg.engine.p);
    let candidates: Vec<bool> = (0..g.n).map(|v| env.is_candidate(v)).collect();
    let mut set = match cfg.storage {
        Storage::Dense => ShardSet::Dense(shards_for_graph(
            part,
            g,
            env.removed_mask(),
            env.solution_mask(),
            &candidates,
        )),
        Storage::Sparse => {
            let (chunk, caps) = rt.manifest.sparse_config(1, part.ni(), params.k)?;
            ShardSet::Sparse(sparse_shards_for_graph(
                part,
                g,
                env.removed_mask(),
                env.solution_mask(),
                &candidates,
                chunk,
                &caps,
            ))
        }
    };
    let mut removed_prev: Vec<bool> = env.removed_mask().to_vec();

    let mut timing = StepTiming::new(cfg.engine.p);
    let mut evaluations = 0usize;
    let mut selections = 0usize;
    let mut sim_total = 0.0f64;

    // Execution context (DESIGN.md §6/§7/§9): device residency — θ and the
    // shard adjacency state (dense A, or the sparse edge tiles) uploaded
    // once here, on the coordinator runtime (lockstep) or per rank
    // (rank-parallel); each step pushes only the selection deltas. The
    // one-time upload is a real cost — book it like every other transfer
    // so resident-vs-fresh simulated times stay comparable.
    let mut ctx = ExecEngine::install(
        rt,
        pool,
        &cfg.engine,
        params,
        &mut set,
        cfg.device_resident,
        None,
        0,
    )?;
    let up_t = ctx.last_transfer_secs();
    timing.h2d += up_t;
    sim_total += up_t;

    while !env.done() {
        // Push state deltas from the previous step's selections to the
        // device (dense: row/col masks; sparse: dirty tile live-masks).
        ctx.sync(&mut set)?;
        let sync_t = ctx.last_transfer_secs();
        timing.h2d += sync_t;
        sim_total += sync_t;
        // Distributed policy evaluation (Alg. 4 lines 4-6).
        let skip0 = cfg.skip_zero_layer;
        let out = ctx.forward(&cfg.engine, params, &set, false, skip0)?;
        evaluations += 1;
        sim_total += out.timing.simulated();
        timing.merge(&out.timing);

        // Selection (line 7 / §4.5.1). The adaptive-d thresholds compare
        // |C| against the LIVE residual-graph size, not the original N —
        // multi-node removals shrink the graph, and a schedule pinned to
        // the original N under-selects on the shrunken remainder.
        let t_host = Instant::now();
        let rm = env.removed_mask();
        let num_cand = (0..g.n).filter(|&v| env.is_candidate(v)).count();
        let live = (0..g.n).filter(|&v| !rm[v]).count();
        let d = select_count(cfg.policy, num_cand, live);
        let picked = top_d(&out.scores[..g.n], |v| env.is_candidate(v), d);
        assert!(!picked.is_empty(), "no candidates but env not done");
        // Apply selections (lines 8-10) — candidates can be invalidated by
        // earlier picks in the same batch, so re-check before stepping.
        let mut host_t = t_host.elapsed().as_secs_f64();
        for v in picked {
            if !env.is_candidate(v) {
                continue;
            }
            let (_r, done) = env.step(v);
            selections += 1;
            let t_upd = Instant::now();
            set.mirror_selection(0, v, &*env, &mut removed_prev);
            host_t += t_upd.elapsed().as_secs_f64();
            if done {
                break;
            }
        }
        // Refresh candidate masks from the environment (covered-out nodes).
        let t_upd = Instant::now();
        set.refresh_candidates(0, |v| env.is_candidate(v));
        host_t += t_upd.elapsed().as_secs_f64();
        timing.host += host_t;
        sim_total += host_t;
    }

    Ok(InferResult {
        solution: env.solution_mask().to_vec(),
        solution_size: env.solution_size(),
        objective: env.objective(),
        evaluations,
        selections,
        sim_time_per_eval: if evaluations > 0 { sim_total / evaluations as f64 } else { 0.0 },
        timing,
        wall_total: wall.elapsed().as_secs_f64(),
    })
}

/// Solve `g` under `scenario` with a freshly constructed environment.
pub fn solve_scenario(
    rt: &Runtime,
    cfg: &InferCfg,
    params: &Params,
    g: &Graph,
    bucket_n: usize,
    scenario: Scenario,
) -> Result<InferResult> {
    let mut env = scenario.make_env(g.clone());
    let res = solve_env(rt, cfg, params, g, bucket_n, env.as_mut())?;
    assert!(
        scenario.validate(g, &res.solution),
        "{scenario} inference produced an invalid solution"
    );
    Ok(res)
}

/// Solve the MVC instance `g` with the pretrained `params` on `p` shards.
///
/// Deprecated in docs: a thin alias of [`solve_scenario`] with
/// [`Scenario::Mvc`], kept for the paper-era callers/tests. New code
/// (including `oggm infer`, which takes `--scenario`) should call
/// `solve_scenario` directly.
pub fn solve_mvc(
    rt: &Runtime,
    cfg: &InferCfg,
    params: &Params,
    g: &Graph,
    bucket_n: usize,
) -> Result<InferResult> {
    solve_scenario(rt, cfg, params, g, bucket_n, Scenario::Mvc)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::util::rng::Pcg32;

    fn runtime() -> Option<Runtime> {
        if !std::path::Path::new("artifacts/manifest.tsv").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        Some(Runtime::new("artifacts").unwrap())
    }

    #[test]
    fn solves_to_valid_cover_all_p() {
        let Some(rt) = runtime() else { return };
        let g = generators::erdos_renyi(20, 0.2, &mut Pcg32::seeded(1));
        let params = Params::init(32, &mut Pcg32::seeded(2));
        for p in [1usize, 2, 6] {
            let cfg = InferCfg::new(p, 2);
            let res = solve_mvc(&rt, &cfg, &params, &g, 24).unwrap();
            assert!(res.solution_size > 0);
            assert_eq!(res.selections, res.solution_size);
            assert!(res.evaluations <= g.n);
        }
    }

    #[test]
    fn multi_select_uses_fewer_evaluations() {
        let Some(rt) = runtime() else { return };
        let g = generators::erdos_renyi(250, 0.15, &mut Pcg32::seeded(3));
        let params = Params::init(32, &mut Pcg32::seeded(4));
        let mut single = InferCfg::new(1, 2);
        single.policy = SelectionPolicy::Single;
        let mut multi = InferCfg::new(1, 2);
        multi.policy = SelectionPolicy::AdaptiveMulti;
        let rs = solve_mvc(&rt, &single, &params, &g, 252).unwrap();
        let rm = solve_mvc(&rt, &multi, &params, &g, 252).unwrap();
        assert!(
            rm.evaluations * 2 <= rs.evaluations,
            "multi-select did not reduce evals: {} vs {}",
            rm.evaluations,
            rs.evaluations
        );
        // Quality should be close (paper: ratio ≈ 1.00x at these scales).
        let ratio = rm.solution_size as f64 / rs.solution_size as f64;
        assert!(ratio < 1.25, "multi-select ratio degraded: {ratio}");
    }

    #[test]
    fn sparse_storage_matches_dense_solutions() {
        // Same graph, same params: the CSR path must pick the same cover as
        // the dense oracle (argmax selection absorbs the fp-level scatter
        // vs matmul summation difference; DESIGN.md §7).
        let Some(rt) = runtime() else { return };
        let g = generators::erdos_renyi(20, 0.2, &mut Pcg32::seeded(21));
        let params = Params::init(32, &mut Pcg32::seeded(22));
        for p in [1usize, 2] {
            if rt.manifest.sparse_config(1, 24 / p, 32).is_err() {
                eprintln!("skipping: sparse artifacts not compiled");
                return;
            }
            let dense = solve_mvc(&rt, &InferCfg::new(p, 2), &params, &g, 24).unwrap();
            let mut scfg = InferCfg::new(p, 2);
            scfg.storage = crate::coordinator::shard::Storage::Sparse;
            let sparse = solve_mvc(&rt, &scfg, &params, &g, 24).unwrap();
            assert_eq!(sparse.solution, dense.solution, "P={p} sparse cover diverges");
            assert_eq!(sparse.evaluations, dense.evaluations);
        }
    }

    #[test]
    fn p_parity_of_solutions() {
        // Same params + graph must give the same cover for any P.
        let Some(rt) = runtime() else { return };
        let g = generators::erdos_renyi(20, 0.25, &mut Pcg32::seeded(5));
        let params = Params::init(32, &mut Pcg32::seeded(6));
        let base = solve_mvc(&rt, &InferCfg::new(1, 2), &params, &g, 24).unwrap();
        for p in [2usize, 3, 4] {
            let r = solve_mvc(&rt, &InferCfg::new(p, 2), &params, &g, 24).unwrap();
            assert_eq!(r.solution, base.solution, "P={p} picked a different cover");
        }
    }
}
