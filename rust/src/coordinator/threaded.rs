//! True-concurrency SPMD engine: P OS threads, one per simulated device,
//! each with its own PJRT runtime (client + executables are thread-local —
//! the xla crate's handles are not Send), synchronizing through
//! `collective::Communicator` exactly like ranks over NCCL.
//!
//! This is the liveness-mode counterpart of the lockstep engine (DESIGN.md
//! §3): the lockstep engine measures simulated-parallel time; this engine
//! demonstrates the same SPMD program running under real concurrency, and
//! the parity test pins both to identical scores.

use super::shard::ShardState;
use crate::collective::Communicator;
use crate::graph::{Graph, Partition};
use crate::model::Params;
use crate::runtime::{artifact_name, HostTensor, Input, Runtime};
use anyhow::{Context, Result};
use std::path::PathBuf;

/// Inputs each worker thread needs (everything is plain `Send` data).
#[derive(Clone)]
struct WorkerJob {
    dir: PathBuf,
    part: Partition,
    rank: usize,
    l: usize,
    params: Params,
    graph: Graph,
    removed: Vec<bool>,
    solution: Vec<bool>,
    candidates: Vec<bool>,
}

/// One SPMD policy evaluation (Alg. 2 + Alg. 3) executed by a worker rank.
fn worker_forward(job: &WorkerJob, comm: &Communicator) -> Result<Vec<f32>> {
    let rt = Runtime::new(&job.dir).context("worker runtime")?;
    let sh = ShardState::from_graphs(
        job.part,
        job.rank,
        &[&job.graph],
        &[&job.removed],
        &[&job.solution],
        &[&job.candidates],
    );
    let (b, n, ni, k) = (1usize, job.part.n, job.part.ni(), job.params.k);
    let _p = job.part.p;
    let params = &job.params;

    let d_s = [b, ni];
    let d_a = [b, ni, n];
    let d_e = [b, k, ni];
    let d_sum = [b, k];
    let d_k = [k];
    let d_kk = [k, k];
    let d_2k = [2 * k];

    let a_buf = rt.upload(&d_a, &sh.a)?;

    // Stage 1.
    let pre = rt
        .execute_in(
            &artifact_name("embed_pre", b, n, ni, k),
            &[
                Input::Host(HostTensor::new(&d_k, params.theta(0))),
                Input::Host(HostTensor::new(&d_k, params.theta(1))),
                Input::Host(HostTensor::new(&d_kk, params.theta(2))),
                Input::Host(HostTensor::new(&d_s, &sh.s)),
                Input::Dev(&a_buf),
            ],
        )?
        .remove(0);

    // Embedding layers with real all-reduce between ranks.
    let mut embed = vec![0.0f32; b * k * ni];
    let row0 = job.part.row0(job.rank);
    for layer in 0..job.l {
        let mut partial = if layer == 0 {
            vec![0.0f32; b * k * n] // zeros constant — skip the msg stage
        } else {
            rt.execute_in(
                &artifact_name("embed_msg", b, n, ni, k),
                &[Input::Host(HostTensor::new(&d_e, &embed)), Input::Dev(&a_buf)],
            )?
            .remove(0)
        };
        comm.all_reduce_sum(&mut partial); // Alg. 2 line 12
        let mut nbr = vec![0.0f32; b * k * ni];
        for kk in 0..k {
            nbr[kk * ni..(kk + 1) * ni]
                .copy_from_slice(&partial[kk * n + row0..kk * n + row0 + ni]);
        }
        embed = rt
            .execute_in(
                &artifact_name("embed_combine", b, n, ni, k),
                &[
                    Input::Host(HostTensor::new(&d_kk, params.theta(3))),
                    Input::Host(HostTensor::new(&d_e, &pre)),
                    Input::Host(HostTensor::new(&d_e, &nbr)),
                ],
            )?
            .remove(0);
    }

    // Alg. 3: q_sum all-reduce + scores all-gather.
    let mut sum_all = rt
        .execute_in(
            &artifact_name("q_sum", b, n, ni, k),
            &[Input::Host(HostTensor::new(&d_e, &embed))],
        )?
        .remove(0);
    comm.all_reduce_sum(&mut sum_all);
    let scores_local = rt
        .execute_in(
            &artifact_name("q_scores", b, n, ni, k),
            &[
                Input::Host(HostTensor::new(&d_kk, params.theta(4))),
                Input::Host(HostTensor::new(&d_kk, params.theta(5))),
                Input::Host(HostTensor::new(&d_2k, params.theta(6))),
                Input::Host(HostTensor::new(&d_e, &embed)),
                Input::Host(HostTensor::new(&d_s, &sh.c)),
                Input::Host(HostTensor::new(&d_sum, &sum_all)),
            ],
        )?
        .remove(0);
    Ok(comm.all_gather(&scores_local)) // Alg. 4 line 6
}

/// Evaluate the policy over `p` concurrent worker threads; returns the
/// gathered scores (identical on every rank; rank 0's copy is returned).
pub fn forward_threaded(
    dir: impl Into<PathBuf>,
    part: Partition,
    l: usize,
    params: &Params,
    graph: &Graph,
    removed: &[bool],
    solution: &[bool],
    candidates: &[bool],
) -> Result<Vec<f32>> {
    let dir = dir.into();
    let comms = Communicator::create(part.p);
    let mut handles = Vec::new();
    for (rank, comm) in comms.into_iter().enumerate() {
        let job = WorkerJob {
            dir: dir.clone(),
            part,
            rank,
            l,
            params: params.clone(),
            graph: graph.clone(),
            removed: removed.to_vec(),
            solution: solution.to_vec(),
            candidates: candidates.to_vec(),
        };
        handles.push(std::thread::spawn(move || worker_forward(&job, &comm)));
    }
    let mut out = None;
    for (rank, h) in handles.into_iter().enumerate() {
        let scores = h.join().expect("worker panicked")?;
        if rank == 0 {
            out = Some(scores);
        }
    }
    Ok(out.unwrap())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::EngineCfg;
    use crate::coordinator::fwd::forward;
    use crate::coordinator::shard::shards_for_graph;
    use crate::env::{GraphEnv, MvcEnv};
    use crate::graph::generators;
    use crate::util::rng::Pcg32;

    #[test]
    fn threaded_matches_lockstep() {
        if !std::path::Path::new("artifacts/manifest.tsv").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let g = generators::erdos_renyi(20, 0.25, &mut Pcg32::seeded(2));
        let params = Params::init(32, &mut Pcg32::seeded(3));
        let env = MvcEnv::new(g.clone());
        let cand: Vec<bool> = (0..g.n).map(|v| env.is_candidate(v)).collect();

        for p in [1usize, 2, 3] {
            let part = Partition::new(24, p);
            // Lockstep reference.
            let rt = Runtime::new("artifacts").unwrap();
            let shards =
                shards_for_graph(part, &g, env.removed_mask(), env.solution_mask(), &cand);
            let cfg = EngineCfg::new(p, 2);
            let want = forward(&rt, &cfg, &params, &shards, false, true).unwrap().scores;
            // Real threads.
            let got = forward_threaded(
                "artifacts",
                part,
                2,
                &params,
                &g,
                env.removed_mask(),
                env.solution_mask(),
                &cand,
            )
            .unwrap();
            let d = crate::util::max_abs_diff(&got, &want);
            assert!(d < 1e-4, "P={p}: threaded diverges from lockstep by {d}");
        }
    }
}
