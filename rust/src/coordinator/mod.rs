//! L3 coordinator: the paper's system contribution.
//!
//! Spatial-parallel shard management (§4.1), distributed policy evaluation
//! orchestration (§4.2), parallel RL inference (Alg. 4) and training
//! (Alg. 5), the replay-buffer memory optimization, and the adaptive
//! multiple-node selection + repeated-gradient-iteration optimizations
//! (§4.5).

pub mod cmd;
pub mod shard;
pub mod engine;
pub mod fwd;
pub mod bwd;
pub mod selection;
pub mod infer;
pub mod replay;
pub mod train;
pub mod metrics;
pub mod threaded;
