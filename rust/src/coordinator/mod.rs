//! L3 coordinator: the paper's system contribution.
//!
//! Spatial-parallel shard management (§4.1), distributed policy evaluation
//! orchestration (§4.2), parallel RL inference (Alg. 4) and training
//! (Alg. 5), the replay-buffer memory optimization, and the adaptive
//! multiple-node selection + repeated-gradient-iteration optimizations
//! (§4.5).

/// CLI subcommand implementations for the `oggm` binary.
pub mod cmd;
/// Per-shard distributed state, dense and sparse (DESIGN.md §7).
pub mod shard;
/// Lockstep simulation-engine primitives (timing, config).
pub mod engine;
/// Distributed forward pass + device-residency layers.
pub mod fwd;
/// Distributed backward pass (hand-rolled VJP orchestration).
pub mod bwd;
/// Node-selection policies (argmax / §4.5.1 adaptive multi).
pub mod selection;
/// Parallel RL inference (Alg. 4).
pub mod infer;
/// Compressed experience replay (§4.4) + Tuples2Graphs.
pub mod replay;
/// Parallel RL training (Alg. 5).
pub mod train;
/// Metrics output: curves, tables, JSON/CSV writers.
pub mod metrics;
