//! Adam optimizer (Kingma & Ba), replicated on every shard exactly as the
//! paper replicates PyTorch's `optim.Adam` per process: gradients are
//! all-reduced first, so each shard applies an identical deterministic
//! update and parameters stay bit-equal across shards.

/// Adam state over a flat parameter vector.
#[derive(Debug, Clone)]
pub struct Adam {
    /// Learning rate η.
    pub lr: f32,
    /// First-moment decay β1.
    pub beta1: f32,
    /// Second-moment decay β2.
    pub beta2: f32,
    /// Numerical-stability term ε.
    pub eps: f32,
    m: Vec<f32>,
    v: Vec<f32>,
    t: u64,
}

impl Adam {
    /// Fresh optimizer state over `n` parameters.
    pub fn new(lr: f32, n: usize) -> Adam {
        Adam { lr, beta1: 0.9, beta2: 0.999, eps: 1e-8, m: vec![0.0; n], v: vec![0.0; n], t: 0 }
    }

    /// Optimizer steps taken so far.
    pub fn step_count(&self) -> u64 {
        self.t
    }

    /// Snapshot optimizer state (for checkpointing): (m, v, t).
    pub fn state(&self) -> (&[f32], &[f32], u64) {
        (&self.m, &self.v, self.t)
    }

    /// Restore optimizer state from a checkpoint snapshot.
    pub fn restore(&mut self, m: &[f32], v: &[f32], t: u64) {
        assert_eq!(m.len(), self.m.len());
        assert_eq!(v.len(), self.v.len());
        self.m.copy_from_slice(m);
        self.v.copy_from_slice(v);
        self.t = t;
    }

    /// In-place parameter update with gradient `g`.
    pub fn step(&mut self, params: &mut [f32], g: &[f32]) {
        assert_eq!(params.len(), self.m.len());
        assert_eq!(g.len(), self.m.len());
        self.t += 1;
        let b1t = 1.0 - self.beta1.powi(self.t as i32);
        let b2t = 1.0 - self.beta2.powi(self.t as i32);
        for i in 0..params.len() {
            self.m[i] = self.beta1 * self.m[i] + (1.0 - self.beta1) * g[i];
            self.v[i] = self.beta2 * self.v[i] + (1.0 - self.beta2) * g[i] * g[i];
            let mhat = self.m[i] / b1t;
            let vhat = self.v[i] / b2t;
            params[i] -= self.lr * mhat / (vhat.sqrt() + self.eps);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minimizes_quadratic() {
        // f(x) = (x-3)^2; grad = 2(x-3).
        let mut x = vec![0.0f32];
        let mut opt = Adam::new(0.1, 1);
        for _ in 0..500 {
            let g = vec![2.0 * (x[0] - 3.0)];
            opt.step(&mut x, &g);
        }
        assert!((x[0] - 3.0).abs() < 1e-2, "x={}", x[0]);
    }

    #[test]
    fn first_step_is_lr_sized() {
        // Adam's debiased first step ≈ lr * sign(g).
        let mut x = vec![0.0f32, 0.0];
        let mut opt = Adam::new(0.01, 2);
        opt.step(&mut x, &[5.0, -0.3]);
        assert!((x[0] + 0.01).abs() < 1e-4);
        assert!((x[1] - 0.01).abs() < 1e-4);
    }

    #[test]
    fn deterministic_across_replicas() {
        let mut a = (vec![1.0f32; 8], Adam::new(0.05, 8));
        let mut b = (vec![1.0f32; 8], Adam::new(0.05, 8));
        for step in 0..50 {
            let g: Vec<f32> = (0..8).map(|i| ((i + step) as f32).sin()).collect();
            a.1.step(&mut a.0, &g);
            b.1.step(&mut b.0, &g);
        }
        assert_eq!(a.0, b.0);
    }
}
