//! Policy-model parameters (θ1..θ7 of Eq. 1/Eq. 2), optimizer, and
//! hyper-parameters. The flat layout mirrors python/compile/model.py's
//! `PARAM_ORDER`; artifacts consume the θ tensors as separate PJRT inputs
//! sliced from the flat vector.

/// Policy parameters θ1..θ7 (flat layout + accessors).
pub mod params;
/// Replicated Adam optimizer.
pub mod adam;
/// RL/optimizer hyper-parameters (paper §6.1).
pub mod hyper;
/// Training checkpoints (params + optimizer + counters).
pub mod checkpoint;

pub use adam::Adam;
pub use hyper::Hyper;
pub use params::Params;
