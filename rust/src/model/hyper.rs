//! Hyper-parameters (paper §6.1 defaults).

/// RL + model hyper-parameters.
#[derive(Debug, Clone)]
pub struct Hyper {
    /// Embedding dimension K (paper: 32). Must match the AOT artifacts.
    pub k: usize,
    /// Number of recurrent embedding layers L (paper: 2).
    pub l: usize,
    /// Learning rate η (paper: 1e-5; examples default higher for the
    /// short CPU-scale runs recorded in EXPERIMENTS.md).
    pub lr: f32,
    /// Discount factor γ (paper: 0.9).
    pub gamma: f32,
    /// ε-greedy start/end (paper: 0.9 → 0.1, linear decay).
    pub eps_start: f32,
    /// ε-greedy floor after decay.
    pub eps_end: f32,
    /// Steps over which ε decays.
    pub eps_decay_steps: usize,
    /// Replay buffer capacity R (paper: 50,000).
    pub replay_capacity: usize,
    /// Minibatch size B for experience tuples.
    pub batch_size: usize,
    /// Gradient-descent iterations τ per training step (§4.5.2; paper
    /// default 1, best 8).
    pub grad_iters: usize,
}

impl Default for Hyper {
    fn default() -> Hyper {
        Hyper {
            k: 32,
            l: 2,
            lr: 1e-3,
            gamma: 0.9,
            eps_start: 0.9,
            eps_end: 0.1,
            eps_decay_steps: 500,
            replay_capacity: 50_000,
            batch_size: 8,
            grad_iters: 1,
        }
    }
}

impl Hyper {
    /// ε at a given global training step (linear decay).
    pub fn epsilon(&self, step: usize) -> f32 {
        if step >= self.eps_decay_steps {
            return self.eps_end;
        }
        let frac = step as f32 / self.eps_decay_steps as f32;
        self.eps_start + (self.eps_end - self.eps_start) * frac
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn epsilon_decays_linearly() {
        let h = Hyper::default();
        assert_eq!(h.epsilon(0), 0.9);
        assert_eq!(h.epsilon(h.eps_decay_steps), 0.1);
        assert_eq!(h.epsilon(10 * h.eps_decay_steps), 0.1);
        let mid = h.epsilon(h.eps_decay_steps / 2);
        assert!((mid - 0.5).abs() < 1e-3);
    }
}
