//! Flat parameter vector with named views, matching
//! python/compile/model.py PARAM_ORDER exactly:
//!   theta1[K], theta2[K], theta3[K,K], theta4[K,K],
//!   theta5[K,K], theta6[K,K], theta7[2K]

use crate::util::binio::{self, Tensor};
use crate::util::rng::Pcg32;
use anyhow::{bail, Result};
use std::path::Path;

/// Names in flat order.
pub const PARAM_NAMES: [&str; 7] =
    ["theta1", "theta2", "theta3", "theta4", "theta5", "theta6", "theta7"];

/// The policy-model parameters (flat f32 vector + embedding dim K).
#[derive(Debug, Clone, PartialEq)]
pub struct Params {
    /// Embedding dimension K.
    pub k: usize,
    /// θ1..θ7 concatenated (4K² + 4K floats).
    pub flat: Vec<f32>,
}

impl Params {
    /// Flat length for embedding dim k (4k² + 4k).
    pub fn len_for_k(k: usize) -> usize {
        4 * k * k + 4 * k
    }

    /// Shapes in flat order for embedding dim k.
    pub fn shapes(k: usize) -> [(usize, Vec<usize>); 7] {
        [
            (k, vec![k]),
            (k, vec![k]),
            (k * k, vec![k, k]),
            (k * k, vec![k, k]),
            (k * k, vec![k, k]),
            (k * k, vec![k, k]),
            (2 * k, vec![2 * k]),
        ]
    }

    /// All-zero parameters (tests).
    pub fn zeros(k: usize) -> Params {
        Params { k, flat: vec![0.0; Self::len_for_k(k)] }
    }

    /// Gaussian init (scale 0.1, the reference model's init).
    pub fn init(k: usize, rng: &mut Pcg32) -> Params {
        let mut p = Params::zeros(k);
        for x in p.flat.iter_mut() {
            *x = 0.1 * rng.next_normal();
        }
        p
    }

    /// Byte offset (in f32 elements) of the i-th θ tensor.
    pub fn offset(&self, idx: usize) -> usize {
        Self::shapes(self.k)[..idx].iter().map(|(n, _)| n).sum()
    }

    /// Slice of the i-th θ tensor.
    pub fn theta(&self, idx: usize) -> &[f32] {
        let off = self.offset(idx);
        let len = Self::shapes(self.k)[idx].0;
        &self.flat[off..off + len]
    }

    /// Dims of the i-th θ tensor.
    pub fn theta_dims(&self, idx: usize) -> Vec<usize> {
        Self::shapes(self.k)[idx].1.clone()
    }

    /// Save to the binio tensor container format.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        binio::save(path, &[Tensor::new("params", vec![self.flat.len()], self.flat.clone())])
    }

    /// Load parameters saved by `save` (or the python build step).
    pub fn load(path: impl AsRef<Path>, k: usize) -> Result<Params> {
        let tensors = binio::load(path)?;
        let t = binio::find(&tensors, "params")?;
        if t.data.len() != Self::len_for_k(k) {
            bail!("param length {} != expected {} for K={k}", t.data.len(), Self::len_for_k(k));
        }
        Ok(Params { k, flat: t.data.clone() })
    }

    /// L2 norm (debug/metrics).
    pub fn norm(&self) -> f32 {
        self.flat.iter().map(|x| x * x).sum::<f32>().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout_matches_python() {
        let k = 32;
        let p = Params::zeros(k);
        assert_eq!(p.flat.len(), 4224);
        assert_eq!(p.offset(0), 0);
        assert_eq!(p.offset(1), 32);
        assert_eq!(p.offset(2), 64);
        assert_eq!(p.offset(3), 64 + 1024);
        assert_eq!(p.offset(6), 64 + 4 * 1024);
        assert_eq!(p.theta(6).len(), 64);
        assert_eq!(p.theta_dims(2), vec![32, 32]);
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir().join(format!("oggm_params_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("p.oggm");
        let mut rng = Pcg32::seeded(1);
        let p = Params::init(32, &mut rng);
        p.save(&path).unwrap();
        let q = Params::load(&path, 32).unwrap();
        assert_eq!(p, q);
        assert!(Params::load(&path, 16).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn init_is_scaled_gaussian() {
        let mut rng = Pcg32::seeded(2);
        let p = Params::init(32, &mut rng);
        let var = p.flat.iter().map(|x| x * x).sum::<f32>() / p.flat.len() as f32;
        assert!((var.sqrt() - 0.1).abs() < 0.01, "std {}", var.sqrt());
    }
}
