//! Training checkpoints: parameters + Adam state + step counters in one
//! OGGM container, so long training runs (paper-scale learning curves) can
//! be resumed bit-exactly.

use super::adam::Adam;
use super::params::Params;
use crate::util::binio::{self, Tensor};
use anyhow::{bail, Result};
use std::path::Path;

/// A full training checkpoint.
#[derive(Debug, Clone)]
pub struct Checkpoint {
    /// Policy parameters.
    pub params: Params,
    /// Adam first-moment state.
    pub adam_m: Vec<f32>,
    /// Adam second-moment state.
    pub adam_v: Vec<f32>,
    /// Adam step counter.
    pub adam_t: u64,
    /// Global training step at capture.
    pub global_step: u64,
    /// Episode counter at capture.
    pub episode: u64,
}

impl Checkpoint {
    /// Capture the full training state.
    pub fn capture(params: &Params, adam: &Adam, global_step: usize, episode: usize) -> Checkpoint {
        let (m, v, t) = adam.state();
        Checkpoint {
            params: params.clone(),
            adam_m: m.to_vec(),
            adam_v: v.to_vec(),
            adam_t: t,
            global_step: global_step as u64,
            episode: episode as u64,
        }
    }

    /// Restore into an (params, adam) pair; returns (global_step, episode).
    pub fn restore(&self, params: &mut Params, adam: &mut Adam) -> (usize, usize) {
        params.flat.copy_from_slice(&self.params.flat);
        adam.restore(&self.adam_m, &self.adam_v, self.adam_t);
        (self.global_step as usize, self.episode as usize)
    }

    /// Write to the binio tensor container format.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let meta = vec![self.adam_t as f32, self.global_step as f32, self.episode as f32,
                        self.params.k as f32];
        binio::save(
            path,
            &[
                Tensor::new("params", vec![self.params.flat.len()], self.params.flat.clone()),
                Tensor::new("adam_m", vec![self.adam_m.len()], self.adam_m.clone()),
                Tensor::new("adam_v", vec![self.adam_v.len()], self.adam_v.clone()),
                Tensor::new("meta", vec![4], meta),
            ],
        )
    }

    /// Load a checkpoint written by `save`.
    pub fn load(path: impl AsRef<Path>) -> Result<Checkpoint> {
        let ts = binio::load(path)?;
        let meta = binio::find(&ts, "meta")?.data.clone();
        if meta.len() != 4 {
            bail!("malformed checkpoint meta");
        }
        let k = meta[3] as usize;
        let flat = binio::find(&ts, "params")?.data.clone();
        if flat.len() != Params::len_for_k(k) {
            bail!("checkpoint param length mismatch for K={k}");
        }
        Ok(Checkpoint {
            params: Params { k, flat },
            adam_m: binio::find(&ts, "adam_m")?.data.clone(),
            adam_v: binio::find(&ts, "adam_v")?.data.clone(),
            adam_t: meta[0] as u64,
            global_step: meta[1] as u64,
            episode: meta[2] as u64,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn roundtrip_resumes_bit_exact() {
        let dir = std::env::temp_dir().join(format!("oggm_ckpt_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("c.oggm");

        let mut rng = Pcg32::seeded(1);
        let mut params = Params::init(32, &mut rng);
        let mut adam = Adam::new(1e-3, params.flat.len());
        // Take some optimizer steps so m/v/t are non-trivial.
        for s in 0..5 {
            let g: Vec<f32> = (0..params.flat.len()).map(|i| ((i + s) as f32).sin()).collect();
            adam.step(&mut params.flat, &g);
        }
        let ck = Checkpoint::capture(&params, &adam, 42, 7);
        ck.save(&path).unwrap();

        let loaded = Checkpoint::load(&path).unwrap();
        let mut params2 = Params::zeros(32);
        let mut adam2 = Adam::new(1e-3, params2.flat.len());
        let (step, ep) = loaded.restore(&mut params2, &mut adam2);
        assert_eq!((step, ep), (42, 7));
        assert_eq!(params2.flat, params.flat);

        // Continuing both optimizers must stay identical.
        let g = vec![0.25f32; params.flat.len()];
        adam.step(&mut params.flat, &g);
        adam2.step(&mut params2.flat, &g);
        assert_eq!(params.flat, params2.flat);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_corrupt() {
        let dir = std::env::temp_dir().join(format!("oggm_ckpt2_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.oggm");
        crate::util::binio::save(
            &path,
            &[crate::util::binio::Tensor::new("meta", vec![1], vec![1.0])],
        )
        .unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
