//! TCP rank transport: process-separated workers over loopback or LAN.
//!
//! The coordinator listens on one address per rank; each `oggm rank`
//! worker process dials in and handshakes (`Hello` → `Welcome` /
//! `Reject`) carrying its rank id, expected world size, and artifact
//! manifest fingerprint so mismatched processes fail fast with a
//! contextful message instead of diverging mid-solve.
//!
//! Collectives are *hub-folded*: workers deposit payloads as
//! [`msg::WireMsg::CollDeposit`] frames, and the coordinator-side
//! [`CollHub`] folds them in rank order (bitwise identical to the
//! in-process chunked fold, which is also a rank-order left fold) and
//! fans the result back as `CollResult`. An abort from any rank (or a
//! worker disconnect) is fanned to every peer as `CollAbort`, and is
//! *sticky*: every later collective on that group fails with the same
//! originating rank and reason until the pool resets the group.
//!
//! **Liveness and rejoin.** Every steady-state read is deadline-bounded
//! (`--rank-timeout`, carried to workers in `Welcome`): both sides send
//! [`msg::WireMsg::Heartbeat`] frames on otherwise-idle links at a
//! third of the timeout, so a peer that produces no frame for a full
//! timeout is declared dead with a contextful "unreachable for Xs"
//! reason and the hub aborts the group exactly like
//! `Communicator::abort`. The group's listeners stay open in a
//! [`TcpGroup`] after formation, so a replacement worker can re-run the
//! Hello/Welcome handshake for a vacated rank slot inside the pool's
//! `--rejoin-window` ([`TcpGroup::rejoin`]) — the last piece that makes
//! remote rank death retryable (DESIGN.md §12).

use std::collections::HashSet;
use std::io::Write;
use std::net::{Shutdown, TcpListener, TcpStream};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, Sender, TryRecvError};
use std::sync::{Arc, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::collective::fault::{FaultKind, FaultPlan};
use crate::parallel::{Req, Resp};

use super::frame::{read_frame, write_frame, FrameReader, HEADER_LEN};
use super::msg::{self, CollOp, WireMsg};

/// Liveness/rejoin/authentication knobs for one TCP rank group, lowered
/// from `--rank-timeout`, `--rejoin-window`, and `--token`/`OGGM_TOKEN`.
#[derive(Debug, Clone)]
pub struct TcpCfg {
    /// Liveness deadline per link: a peer that produces no frame (data
    /// or heartbeat) for this long is declared dead. `Duration::ZERO`
    /// disables deadlines and heartbeats (reads block forever, the
    /// pre-liveness behavior — useful only for debugging).
    pub timeout: Duration,
    /// How long `ensure_live` waits for replacement workers to
    /// re-handshake vacated rank slots before failing terminally.
    pub rejoin_window: Duration,
    /// Shared handshake secret; empty = no authentication. Compared in
    /// constant time against each worker's `Hello`.
    pub token: String,
}

impl Default for TcpCfg {
    fn default() -> TcpCfg {
        TcpCfg {
            timeout: Duration::from_secs(30),
            rejoin_window: Duration::from_secs(30),
            token: String::new(),
        }
    }
}

/// Heartbeat cadence for a given liveness deadline: a third of the
/// timeout, floored so ~3 beats fit in any enforceable window.
fn heartbeat_interval(timeout: Duration) -> Duration {
    (timeout / 3).max(Duration::from_millis(10))
}

/// Constant-time token equality: every byte of the longer input is
/// inspected regardless of where the first mismatch sits, so response
/// timing leaks nothing about the coordinator's secret.
fn token_matches(presented: &str, expected: &str) -> bool {
    let (a, b) = (presented.as_bytes(), expected.as_bytes());
    let mut diff = a.len() ^ b.len();
    for i in 0..a.len().max(b.len()) {
        let x = *a.get(i).unwrap_or(&0);
        let y = *b.get(i).unwrap_or(&0);
        diff |= (x ^ y) as usize;
    }
    diff == 0
}

/// Lock a mutex, tolerating poisoning: a panicking peer thread must not
/// cascade into every other rank's transport path.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// How long to wait for rank workers to connect (or for a worker to
/// reach its coordinator), in seconds. `OGGM_RANK_WAIT_SECS` overrides
/// the 60 s default — CI smokes shorten it so failures surface fast.
fn wait_secs() -> u64 {
    std::env::var("OGGM_RANK_WAIT_SECS").ok().and_then(|s| s.parse().ok()).unwrap_or(60)
}

/// Coordinator-side write half of one worker connection: a mutex-held
/// stream (the hub fans results from whichever reader thread completes
/// a collective) plus the shared tx counter.
#[derive(Clone)]
struct RankWriter {
    stream: Arc<Mutex<TcpStream>>,
    tx_bytes: Arc<AtomicU64>,
}

impl RankWriter {
    /// Encode and send one message addressed to `rank`.
    fn send(&self, rank: u32, msg: &WireMsg) -> Result<()> {
        let mut payload = Vec::new();
        msg.encode(&mut payload)?;
        let mut stream = lock(&self.stream);
        let n = write_frame(&mut *stream, msg.kind(), rank, &payload)?;
        self.tx_bytes.fetch_add(n, Ordering::Relaxed);
        Ok(())
    }
}

/// Mutable hub state: per-rank writers, deposit slots for the
/// collective in flight, and the sticky abort record.
struct HubInner {
    writers: Vec<Option<RankWriter>>,
    slots: Vec<Option<Vec<f32>>>,
    op: Option<CollOp>,
    arrived: usize,
    aborted: Option<(usize, String)>,
}

/// Coordinator-side collective folding point for the TCP transport.
///
/// Plays the role the shared deposit slots play in the in-process
/// [`crate::collective::Communicator`]: ranks deposit, the last arrival
/// folds in rank order, and everyone receives the same result bytes.
pub(crate) struct CollHub {
    p: usize,
    /// Liveness deadlines missed across the group's lifetime (survives
    /// `reset`; folded into `ExecStats::heartbeats_missed`).
    heartbeats_missed: AtomicU64,
    inner: Mutex<HubInner>,
}

impl CollHub {
    /// New hub for a `p`-rank group with no connections registered yet.
    pub(crate) fn new(p: usize) -> Arc<CollHub> {
        Arc::new(CollHub {
            p,
            heartbeats_missed: AtomicU64::new(0),
            inner: Mutex::new(HubInner {
                writers: (0..p).map(|_| None).collect(),
                slots: (0..p).map(|_| None).collect(),
                op: None,
                arrived: 0,
                aborted: None,
            }),
        })
    }

    /// Register the write half for `rank` (called once per admitted
    /// worker; a rejoining replacement overwrites the dead writer).
    fn register(&self, rank: usize, writer: RankWriter) {
        lock(&self.inner).writers[rank] = Some(writer);
    }

    /// Count one missed liveness deadline (a rank declared unreachable).
    fn note_missed_heartbeat(&self) {
        self.heartbeats_missed.fetch_add(1, Ordering::Relaxed);
    }

    /// Liveness deadlines missed over the group's lifetime.
    pub(crate) fn heartbeats_missed(&self) -> u64 {
        self.heartbeats_missed.load(Ordering::Relaxed)
    }

    /// Clear deposit state and the sticky abort: the group is fresh
    /// again. The pool calls this after replacing collectives
    /// (mirrors `Req::NewComm` on the in-process path).
    pub(crate) fn reset(&self) {
        let mut inner = lock(&self.inner);
        for s in inner.slots.iter_mut() {
            *s = None;
        }
        inner.op = None;
        inner.arrived = 0;
        inner.aborted = None;
    }

    /// Record a sticky abort (first abort wins) and fan `CollAbort` to
    /// every connected worker so in-flight deposits fail immediately.
    pub(crate) fn abort(&self, rank: usize, reason: &str) {
        let mut inner = lock(&self.inner);
        if inner.aborted.is_none() {
            inner.aborted = Some((rank, reason.to_string()));
        }
        let (ar, ref areason) = *inner.aborted.as_ref().unwrap();
        let msg = WireMsg::CollAbort { rank: ar as u32, reason: areason.clone() };
        for w in inner.writers.iter().flatten() {
            let _ = w.send(ar as u32, &msg);
        }
    }

    /// One rank's deposit. When the last rank arrives the hub folds in
    /// rank order and fans the result; protocol violations (op
    /// mismatch, duplicate deposit, length mismatch) abort the group.
    fn deposit(&self, rank: usize, op: CollOp, payload: Vec<f32>) {
        enum Outcome {
            Pending,
            Fanout(Vec<f32>),
            Abort(String),
            Rejected(usize, String),
        }
        let outcome = {
            let mut inner = lock(&self.inner);
            if let Some((ar, reason)) = inner.aborted.clone() {
                Outcome::Rejected(ar, reason)
            } else if rank >= self.p {
                Outcome::Abort(format!("collective deposit from unknown rank {rank}"))
            } else if inner.op.is_some() && inner.op != Some(op) {
                Outcome::Abort(format!(
                    "collective op mismatch: rank {rank} deposited {} during {}",
                    op.name(),
                    inner.op.unwrap().name()
                ))
            } else if inner.slots[rank].is_some() {
                Outcome::Abort(format!(
                    "duplicate collective deposit from rank {rank} ({})",
                    op.name()
                ))
            } else {
                inner.op = Some(op);
                inner.slots[rank] = Some(payload);
                inner.arrived += 1;
                if inner.arrived < self.p {
                    Outcome::Pending
                } else {
                    match fold(op, &mut inner.slots) {
                        Ok(result) => {
                            inner.op = None;
                            inner.arrived = 0;
                            for s in inner.slots.iter_mut() {
                                *s = None;
                            }
                            Outcome::Fanout(result)
                        }
                        Err(reason) => Outcome::Abort(reason),
                    }
                }
            }
        };
        match outcome {
            Outcome::Pending => {}
            Outcome::Fanout(result) => {
                let inner = lock(&self.inner);
                let msg = WireMsg::CollResult { payload: result };
                for (r, w) in inner.writers.iter().enumerate() {
                    if let Some(w) = w {
                        let _ = w.send(r as u32, &msg);
                    }
                }
            }
            Outcome::Abort(reason) => self.abort(rank, &reason),
            Outcome::Rejected(ar, reason) => {
                // Group already aborted: tell just this depositor.
                let inner = lock(&self.inner);
                if let Some(w) = inner.writers[rank.min(self.p - 1)].as_ref() {
                    let _ =
                        w.send(rank as u32, &WireMsg::CollAbort { rank: ar as u32, reason });
                }
            }
        }
    }
}

/// Fold all deposits for `op` in rank order. This must stay bitwise
/// identical to the in-process fold in `collective/comm.rs`, which
/// accumulates `rank 0 + rank 1 + …` per chunk — a whole-buffer
/// left fold over ranks produces the same f32 result.
fn fold(op: CollOp, slots: &mut [Option<Vec<f32>>]) -> std::result::Result<Vec<f32>, String> {
    match op {
        CollOp::Barrier => Ok(Vec::new()),
        CollOp::AllReduce => {
            let mut acc = slots[0].take().expect("rank 0 deposit present");
            for (r, s) in slots.iter().enumerate().skip(1) {
                let s = s.as_ref().expect("deposit present");
                if s.len() != acc.len() {
                    return Err(format!(
                        "all_reduce length mismatch across ranks ({} vs {} at rank {r})",
                        acc.len(),
                        s.len()
                    ));
                }
                for (a, b) in acc.iter_mut().zip(s) {
                    *a += *b;
                }
            }
            Ok(acc)
        }
        CollOp::AllGather => {
            let mut out = Vec::new();
            for s in slots.iter() {
                out.extend_from_slice(s.as_ref().expect("deposit present"));
            }
            Ok(out)
        }
        CollOp::Broadcast => Ok(slots[0].take().expect("rank 0 deposit present")),
    }
}

/// Coordinator-side endpoint of one TCP rank worker: the write half,
/// a channel fed by the connection's reader thread, and liveness state.
pub(crate) struct TcpLink {
    rank: usize,
    writer: RankWriter,
    resp_rx: Receiver<Resp>,
    dead: Arc<AtomicBool>,
    rx_bytes: Arc<AtomicU64>,
    /// Why the link died ("unreachable for Xs" / "disconnected"),
    /// recorded by the reader thread before it flips `dead`.
    reason: Arc<Mutex<Option<String>>>,
    reader: Option<JoinHandle<()>>,
}

impl TcpLink {
    /// The rank this link serves (rejoin hands back links keyed by the
    /// slot the replacement handshook for).
    pub(crate) fn rank(&self) -> usize {
        self.rank
    }

    /// Send one request; `Err(())` on a dead or unwritable connection.
    pub(crate) fn send(&self, req: Req) -> Result<(), ()> {
        if self.dead.load(Ordering::Acquire) {
            return Err(());
        }
        let msg = WireMsg::Req(req);
        if self.writer.send(self.rank as u32, &msg).is_err() {
            self.dead.store(true, Ordering::Release);
            return Err(());
        }
        Ok(())
    }

    /// Blocking receive of one response; `Err(())` once the reader
    /// thread has exited (connection closed or protocol error).
    pub(crate) fn recv(&self) -> Result<Resp, ()> {
        self.resp_rx.recv().map_err(|_| ())
    }

    /// Non-blocking receive used to drain stale responses.
    pub(crate) fn try_recv(&self) -> Option<Resp> {
        match self.resp_rx.try_recv() {
            Ok(resp) => Some(resp),
            Err(TryRecvError::Empty | TryRecvError::Disconnected) => None,
        }
    }

    /// Whether the connection is known dead (write failed or reader exited).
    pub(crate) fn is_dead(&self) -> bool {
        self.dead.load(Ordering::Acquire)
    }

    /// (tx_bytes, rx_bytes) actually moved over this connection.
    pub(crate) fn traffic(&self) -> (u64, u64) {
        (self.writer.tx_bytes.load(Ordering::Relaxed), self.rx_bytes.load(Ordering::Relaxed))
    }

    /// Why the link died, once the reader thread has recorded it
    /// ("rank R unreachable for Xs …" on a liveness miss, or the
    /// disconnect reason). `None` while the link is healthy or when the
    /// write side noticed first.
    pub(crate) fn death_reason(&self) -> Option<String> {
        lock(&self.reason).clone()
    }
}

impl Drop for TcpLink {
    fn drop(&mut self) {
        if let Ok(stream) = lock(&self.writer.stream).try_clone() {
            let _ = stream.shutdown(Shutdown::Both);
        }
        if let Some(h) = self.reader.take() {
            let _ = h.join();
        }
    }
}

/// Spawn the per-connection reader thread: routes `Resp` frames to the
/// pool's channel and collective frames to the hub, enforces the
/// liveness deadline (ticking on read timeouts, sending heartbeats so
/// the worker can prove the reverse direction), and marks the link dead
/// — recording *why* in `reason` — before aborting the group.
fn spawn_reader(
    rank: usize,
    stream: TcpStream,
    resp_tx: Sender<Resp>,
    dead: Arc<AtomicBool>,
    rx_bytes: Arc<AtomicU64>,
    reason: Arc<Mutex<Option<String>>>,
    hub: Arc<CollHub>,
    writer: RankWriter,
    timeout: Duration,
) -> Result<JoinHandle<()>> {
    let handle = std::thread::Builder::new()
        .name(format!("oggm-rank{rank}-rx"))
        .spawn(move || {
            let enforce = timeout > Duration::ZERO;
            let tick = heartbeat_interval(timeout);
            // A short read timeout turns the blocking read into a
            // liveness tick; FrameReader keeps partial bytes buffered
            // across ticks so a timeout mid-frame never desyncs.
            let _ = stream.set_read_timeout(if enforce { Some(tick) } else { None });
            let mut frames = FrameReader::new(stream);
            let mut last_in = Instant::now();
            let mut last_beat = Instant::now();
            let why: String = loop {
                match frames.poll() {
                    Ok(Some(frame)) => {
                        last_in = Instant::now();
                        rx_bytes.fetch_add(
                            (HEADER_LEN + frame.payload.len()) as u64,
                            Ordering::Relaxed,
                        );
                        match WireMsg::decode(frame.kind, &frame.payload) {
                            Ok(WireMsg::Heartbeat) => {} // liveness only
                            Ok(WireMsg::Resp(resp)) => {
                                if resp_tx.send(resp).is_err() {
                                    break format!("rank {rank} link closed by the pool");
                                }
                            }
                            Ok(WireMsg::CollDeposit { op, payload }) => {
                                hub.deposit(rank, op, payload)
                            }
                            Ok(WireMsg::CollAbort { rank: ar, reason }) => {
                                hub.abort(ar as usize, &reason)
                            }
                            Ok(_) => {} // stale handshake frames: ignore
                            Err(e) => {
                                break format!("rank {rank} sent an undecodable frame: {e:#}")
                            }
                        }
                    }
                    Ok(None) => {
                        // Read timeout tick: enforce the deadline.
                        let idle = last_in.elapsed();
                        if enforce && idle >= timeout {
                            hub.note_missed_heartbeat();
                            break format!(
                                "rank {rank} unreachable for {:.1}s (no frames or heartbeats \
                                 within the {:.1}s --rank-timeout)",
                                idle.as_secs_f64(),
                                timeout.as_secs_f64()
                            );
                        }
                    }
                    Err(e) => break format!("rank {rank} worker process disconnected ({e:#})"),
                }
                // Prove our own liveness on idle links: the worker runs
                // the mirror-image deadline against the coordinator.
                if enforce && last_beat.elapsed() >= tick {
                    let _ = writer.send(rank as u32, &WireMsg::Heartbeat);
                    last_beat = Instant::now();
                }
            };
            // Record the reason before flipping `dead` so anyone who
            // observes the flag finds the context already in place.
            *lock(&reason) = Some(why.clone());
            dead.store(true, Ordering::Release);
            hub.abort(rank, &why);
        })
        .with_context(|| format!("spawning reader thread for rank {rank}"))?;
    Ok(handle)
}

/// Validate one inbound connection's `Hello` against the shared token,
/// group shape, and artifact fingerprint; on success reply `Welcome`
/// (carrying the liveness deadline) and build the link, on failure
/// reply `Reject{reason}` best-effort and bail. The same path admits
/// formation-time workers and rejoining replacements.
fn admit(
    stream: TcpStream,
    p: usize,
    fingerprint: u64,
    taken: &HashSet<usize>,
    hub: &Arc<CollHub>,
    cfg: &TcpCfg,
) -> Result<TcpLink> {
    stream.set_nodelay(true).ok();
    stream
        .set_read_timeout(Some(Duration::from_secs(5)))
        .context("setting handshake read timeout")?;
    let mut reader = stream.try_clone().context("cloning rank stream")?;
    let reject = |stream: &TcpStream, reason: &str| {
        let mut payload = Vec::new();
        let msg = WireMsg::Reject { reason: reason.to_string() };
        if msg.encode(&mut payload).is_ok() {
            let _ = write_frame(&mut &*stream, msg.kind(), 0, &payload);
        }
    };
    let frame = read_frame(&mut reader).context("reading rank handshake")?;
    let (rank, world, fp, token) = match WireMsg::decode(frame.kind, &frame.payload) {
        Ok(WireMsg::Hello { rank, world, fingerprint, token }) => {
            (rank as usize, world as usize, fingerprint, token)
        }
        Ok(other) => {
            let why = format!("expected Hello, got message kind {}", other.kind());
            reject(&stream, &why);
            bail!("rank handshake: {why}");
        }
        Err(e) => return Err(e.context("decoding rank handshake")),
    };
    let fail = |why: String| -> Result<TcpLink> {
        reject(&stream, &why);
        bail!("rank handshake: {why}");
    };
    // Authentication first: an unauthenticated peer learns nothing
    // about the group shape, and neither reason leaks either token.
    if !token_matches(&token, &cfg.token) {
        return fail(
            "authentication token mismatch: pass the coordinator's --token \
             (or OGGM_TOKEN) to `oggm rank`"
                .to_string(),
        );
    }
    if rank >= p {
        return fail(format!("rank {rank} out of range for a P={p} group"));
    }
    if taken.contains(&rank) {
        return fail(format!("duplicate connection for rank {rank}"));
    }
    if world != 0 && world != p {
        return fail(format!(
            "world size mismatch: worker launched for P={world}, coordinator runs P={p}"
        ));
    }
    if fp != fingerprint {
        return fail(format!(
            "artifact manifest fingerprint mismatch (worker {fp:#018x}, coordinator \
             {fingerprint:#018x}): workers must share the coordinator's artifact set"
        ));
    }
    let writer = RankWriter {
        stream: Arc::new(Mutex::new(stream.try_clone().context("cloning rank stream")?)),
        tx_bytes: Arc::new(AtomicU64::new(0)),
    };
    let timeout_ms = cfg.timeout.as_millis().min(u32::MAX as u128) as u32;
    writer
        .send(rank as u32, &WireMsg::Welcome { p: p as u32, timeout_ms })
        .with_context(|| format!("welcoming rank {rank}"))?;
    if cfg.timeout > Duration::ZERO {
        // Deadline-bound the steady-state writes too: a peer that
        // stops draining its socket cannot park us in `send` forever.
        stream
            .set_write_timeout(Some(cfg.timeout))
            .context("setting rank write timeout")?;
    }
    let (resp_tx, resp_rx) = std::sync::mpsc::channel();
    let dead = Arc::new(AtomicBool::new(false));
    let rx_bytes = Arc::new(AtomicU64::new(0));
    let reason = Arc::new(Mutex::new(None));
    hub.register(rank, writer.clone());
    let reader = spawn_reader(
        rank,
        stream,
        resp_tx,
        Arc::clone(&dead),
        Arc::clone(&rx_bytes),
        Arc::clone(&reason),
        Arc::clone(hub),
        writer.clone(),
        cfg.timeout,
    )?;
    Ok(TcpLink { rank, writer, resp_rx, dead, rx_bytes, reason, reader: Some(reader) })
}

/// A formed TCP rank group's admission state: the live listeners (kept
/// open after formation so replacement workers can rejoin), the hub,
/// and everything needed to re-run the handshake for a vacated slot.
pub(crate) struct TcpGroup {
    listeners: Vec<TcpListener>,
    hub: Arc<CollHub>,
    p: usize,
    fingerprint: u64,
    cfg: TcpCfg,
}

impl TcpGroup {
    /// Listen on the given addresses and admit exactly `p` rank
    /// workers, returning the group (listeners stay open for rejoin)
    /// and the links indexed by rank. Bails with a contextful message
    /// if the full group does not form within the wait window; any
    /// handshake rejection during formation is fail-fast.
    pub(crate) fn form(
        addrs: &[String],
        p: usize,
        fingerprint: u64,
        hub: &Arc<CollHub>,
        cfg: TcpCfg,
    ) -> Result<(TcpGroup, Vec<TcpLink>)> {
        let mut unique: Vec<&str> = Vec::new();
        for a in addrs {
            let a = a.trim();
            if !a.is_empty() && !unique.contains(&a) {
                unique.push(a);
            }
        }
        if unique.is_empty() || unique.len() > p {
            bail!(
                "--ranks lists {} listen address(es); expected 1..={p} for a P={p} group",
                unique.len()
            );
        }
        let mut listeners = Vec::new();
        for a in &unique {
            let l =
                TcpListener::bind(a).with_context(|| format!("binding rank listener on {a}"))?;
            l.set_nonblocking(true).context("setting rank listener nonblocking")?;
            listeners.push(l);
        }
        let deadline = Instant::now() + Duration::from_secs(wait_secs());
        let mut links: Vec<Option<TcpLink>> = (0..p).map(|_| None).collect();
        let mut taken: HashSet<usize> = HashSet::new();
        while taken.len() < p {
            let mut accepted = false;
            for l in &listeners {
                match l.accept() {
                    Ok((stream, _)) => {
                        stream
                            .set_nonblocking(false)
                            .context("setting rank stream blocking")?;
                        let link = admit(stream, p, fingerprint, &taken, hub, &cfg)?;
                        taken.insert(link.rank);
                        links[link.rank] = Some(link);
                        accepted = true;
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {}
                    Err(e) => return Err(e).context("accepting rank connection"),
                }
            }
            if taken.len() == p {
                break;
            }
            if Instant::now() >= deadline {
                bail!(
                    "timed out waiting for rank workers: {} of {p} connected \
                     (launch `oggm rank --connect <addr> --rank R` workers)",
                    taken.len()
                );
            }
            if !accepted {
                std::thread::sleep(Duration::from_millis(25));
            }
        }
        let group = TcpGroup { listeners, hub: Arc::clone(hub), p, fingerprint, cfg };
        Ok((group, links.into_iter().map(|l| l.expect("all ranks admitted")).collect()))
    }

    /// The group's liveness/rejoin/auth configuration.
    pub(crate) fn cfg(&self) -> &TcpCfg {
        &self.cfg
    }

    /// The group's collective hub.
    pub(crate) fn hub(&self) -> &Arc<CollHub> {
        &self.hub
    }

    /// Hold the rejoin window open for replacement workers to re-run
    /// the handshake for the `vacant` rank slots. `live` are the ranks
    /// still healthy — a dial-in claiming one of those is rejected as a
    /// duplicate, exactly as at formation time. Unlike formation, a bad
    /// handshake here is logged and skipped (a stray dialer must not
    /// kill the recovery); only window expiry is terminal.
    pub(crate) fn rejoin(&self, vacant: &[usize], live: &HashSet<usize>) -> Result<Vec<TcpLink>> {
        let window = self.cfg.rejoin_window;
        let deadline = Instant::now() + window;
        let wanted: HashSet<usize> = vacant.iter().copied().collect();
        let mut filled: HashSet<usize> = HashSet::new();
        let mut links = Vec::new();
        while filled.len() < wanted.len() {
            let mut accepted = false;
            for l in &self.listeners {
                match l.accept() {
                    Ok((stream, _)) => {
                        let taken: HashSet<usize> =
                            live.union(&filled).copied().collect();
                        let admitted = stream
                            .set_nonblocking(false)
                            .context("setting rank stream blocking")
                            .and_then(|_| {
                                admit(stream, self.p, self.fingerprint, &taken, &self.hub, &self.cfg)
                            });
                        match admitted {
                            Ok(link) => {
                                filled.insert(link.rank);
                                links.push(link);
                                accepted = true;
                            }
                            Err(e) => {
                                eprintln!("rank rejoin: rejected a connection: {e:#}")
                            }
                        }
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {}
                    Err(e) => return Err(e).context("accepting rejoin connection"),
                }
            }
            if filled.len() == wanted.len() {
                break;
            }
            if Instant::now() >= deadline {
                let mut missing: Vec<usize> =
                    wanted.difference(&filled).copied().collect();
                missing.sort_unstable();
                let missing: Vec<String> = missing.iter().map(|r| r.to_string()).collect();
                bail!(
                    "rejoin window expired: rank(s) {} still vacant after {:.0}s \
                     (relaunch `oggm rank --connect <addr> --rank R --reconnect`, \
                     or raise --rejoin-window)",
                    missing.join(", "),
                    window.as_secs_f64()
                );
            }
            if !accepted {
                std::thread::sleep(Duration::from_millis(25));
            }
        }
        Ok(links)
    }
}

/// Worker-side connection state: the stream halves plus traffic
/// counters, the sticky abort record shared between the request loop
/// and the collective path, the liveness deadline carried in
/// `Welcome`, and the injected-fault hooks (`disconnect` / `stall`).
pub(crate) struct RemoteIo {
    rank: u32,
    reader: Mutex<FrameReader<TcpStream>>,
    writer: Mutex<TcpStream>,
    tx_bytes: AtomicU64,
    rx_bytes: AtomicU64,
    aborted: Mutex<Option<(usize, String)>>,
    /// Liveness deadline from `Welcome{timeout_ms}`; zero = disabled.
    timeout: Duration,
    /// `kind=stall` fired: outbound frames (responses, deposits,
    /// heartbeats) are silently swallowed while reads continue — the
    /// worker looks alive at the socket level but proves nothing.
    stalled: AtomicBool,
    /// `kind=disconnect` fired: the socket was shut down on purpose, so
    /// the exit must read as a fault, not a clean coordinator shutdown.
    fault_disconnect: AtomicBool,
    /// Worker-side fault plan for the liveness kinds; counted per
    /// received control request (`frame=` in the plan grammar).
    fault: Option<Arc<FaultPlan>>,
    reqs_seen: AtomicU64,
}

impl RemoteIo {
    /// Encode and send one message (frames carry this worker's rank).
    /// A stalled worker reports success without writing anything.
    pub(crate) fn send(&self, msg: &WireMsg) -> Result<()> {
        if self.stalled.load(Ordering::Acquire) {
            return Ok(());
        }
        let mut payload = Vec::new();
        msg.encode(&mut payload)?;
        let mut w = lock(&self.writer);
        let n = write_frame(&mut *w, msg.kind(), self.rank, &payload)?;
        self.tx_bytes.fetch_add(n, Ordering::Relaxed);
        Ok(())
    }

    /// Prove liveness to the coordinator (called by the worker's
    /// heartbeat thread so long device computations don't read as death).
    pub(crate) fn heartbeat(&self) -> Result<()> {
        self.send(&WireMsg::Heartbeat)
    }

    /// The liveness deadline the coordinator announced in `Welcome`
    /// (zero = deadlines disabled).
    pub(crate) fn timeout(&self) -> Duration {
        self.timeout
    }

    /// Whether an injected `disconnect` fault closed this connection.
    pub(crate) fn disconnected_by_fault(&self) -> bool {
        self.fault_disconnect.load(Ordering::Acquire)
    }

    /// Read and decode one message, counting rx bytes. Deadline-bounded:
    /// ticks on read timeouts and bails with a contextful "coordinator
    /// unreachable" once nothing (not even a heartbeat) has arrived for
    /// a full liveness window.
    fn recv_msg(&self) -> Result<WireMsg> {
        let mut r = lock(&self.reader);
        let start = Instant::now();
        loop {
            match r.poll()? {
                Some(frame) => {
                    self.rx_bytes.fetch_add(
                        (HEADER_LEN + frame.payload.len()) as u64,
                        Ordering::Relaxed,
                    );
                    return WireMsg::decode(frame.kind, &frame.payload);
                }
                None => {
                    let idle = start.elapsed();
                    if self.timeout > Duration::ZERO && idle >= self.timeout {
                        bail!(
                            "coordinator unreachable for {:.1}s (rank {} saw no frames or \
                             heartbeats within the {:.1}s --rank-timeout)",
                            idle.as_secs_f64(),
                            self.rank,
                            self.timeout.as_secs_f64()
                        );
                    }
                }
            }
        }
    }

    /// Blocking receive of the next control request. Collective aborts
    /// arriving between requests are recorded sticky; heartbeats and
    /// stale collective results are discarded. `None` means the
    /// coordinator is gone (or an injected `disconnect` fired).
    pub(crate) fn recv_req(&self) -> Option<Req> {
        loop {
            match self.recv_msg() {
                Ok(WireMsg::Req(req)) => {
                    let n = self.reqs_seen.fetch_add(1, Ordering::Relaxed);
                    match self.fault.as_ref().and_then(|f| f.fire_liveness(self.rank as usize, n))
                    {
                        Some(FaultKind::Disconnect) => {
                            // Scripted kill -9: drop the socket without
                            // a goodbye and report the link as gone.
                            self.fault_disconnect.store(true, Ordering::Release);
                            let _ = lock(&self.writer).shutdown(Shutdown::Both);
                            return None;
                        }
                        Some(FaultKind::Stall) => {
                            self.stalled.store(true, Ordering::Release);
                        }
                        _ => {}
                    }
                    return Some(req);
                }
                Ok(WireMsg::CollAbort { rank, reason }) => {
                    self.record_abort(rank as usize, &reason)
                }
                Ok(_) => {} // heartbeats / stale CollResult / handshake frames
                Err(_) => return None,
            }
        }
    }

    /// Send one response; `false` means the coordinator is unreachable.
    pub(crate) fn send_resp(&self, resp: Resp) -> bool {
        self.send(&WireMsg::Resp(resp)).is_ok()
    }

    /// Record a sticky abort (first abort wins).
    fn record_abort(&self, rank: usize, reason: &str) {
        let mut a = lock(&self.aborted);
        if a.is_none() {
            *a = Some((rank, reason.to_string()));
        }
    }

    /// The sticky abort record, if any.
    fn aborted(&self) -> Option<(usize, String)> {
        lock(&self.aborted).clone()
    }

    /// Clear the sticky abort (a fresh collective group was issued).
    fn clear_abort(&self) {
        *lock(&self.aborted) = None;
    }

    /// (tx_bytes, rx_bytes) moved over this worker's connection.
    pub(crate) fn traffic(&self) -> (u64, u64) {
        (self.tx_bytes.load(Ordering::Relaxed), self.rx_bytes.load(Ordering::Relaxed))
    }
}

/// Worker-side collective backend: deposits go to the coordinator hub
/// as frames, results come back on the same stream.
pub(crate) struct RemoteComm {
    io: Arc<RemoteIo>,
    rank: usize,
    p: usize,
    bytes: AtomicU64,
    ops: AtomicU64,
}

impl RemoteComm {
    /// New remote collective backend for `rank` in a `p`-rank group.
    pub(crate) fn new(io: Arc<RemoteIo>, rank: usize, p: usize) -> RemoteComm {
        RemoteComm { io, rank, p, bytes: AtomicU64::new(0), ops: AtomicU64::new(0) }
    }

    /// World size.
    pub(crate) fn p(&self) -> usize {
        self.p
    }

    /// (logical collective bytes, collective op count) — same
    /// accounting the in-process communicator reports.
    pub(crate) fn traffic(&self) -> (u64, u64) {
        (self.bytes.load(Ordering::Relaxed), self.ops.load(Ordering::Relaxed))
    }

    /// Add to the logical traffic counters.
    pub(crate) fn add_traffic(&self, bytes: u64, count_op: bool) {
        self.bytes.fetch_add(bytes, Ordering::Relaxed);
        if count_op {
            self.ops.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// The sticky abort record, if any.
    pub(crate) fn aborted(&self) -> Option<(usize, String)> {
        self.io.aborted()
    }

    /// Abort the group: record locally (first wins) and tell the hub
    /// best-effort so peers fail fast too.
    pub(crate) fn abort(&self, reason: &str) {
        self.io.record_abort(self.rank, reason);
        let _ = self.io.send(&WireMsg::CollAbort {
            rank: self.rank as u32,
            reason: reason.to_string(),
        });
    }

    /// A fresh collective group: clear the sticky abort and zero the
    /// counters (mirrors the in-process `NewComm` fresh-group state).
    pub(crate) fn reset(&self) {
        self.io.clear_abort();
        self.bytes.store(0, Ordering::Relaxed);
        self.ops.store(0, Ordering::Relaxed);
    }

    /// One deposit→result round trip through the hub. Returns the
    /// folded payload, or the originating `(rank, reason)` on abort.
    pub(crate) fn roundtrip(
        &self,
        op: CollOp,
        payload: Vec<f32>,
    ) -> std::result::Result<Vec<f32>, (usize, String)> {
        if let Some(a) = self.aborted() {
            return Err(a);
        }
        if let Err(e) = self.io.send(&WireMsg::CollDeposit { op, payload }) {
            let reason = format!("rank {} lost its coordinator connection: {e}", self.rank);
            self.io.record_abort(self.rank, &reason);
            return Err((self.rank, reason));
        }
        loop {
            match self.io.recv_msg() {
                Ok(WireMsg::CollResult { payload }) => return Ok(payload),
                Ok(WireMsg::CollAbort { rank, reason }) => {
                    self.io.record_abort(rank as usize, &reason);
                    return Err((rank as usize, reason));
                }
                Ok(WireMsg::Req(_)) => {
                    let reason = format!(
                        "protocol error: control request arrived mid-{} on rank {}",
                        op.name(),
                        self.rank
                    );
                    self.abort(&reason);
                    return Err((self.rank, reason));
                }
                Ok(_) => {}
                Err(e) => {
                    let reason =
                        format!("rank {} lost its coordinator connection: {e}", self.rank);
                    self.io.record_abort(self.rank, &reason);
                    return Err((self.rank, reason));
                }
            }
        }
    }
}

/// Dial the coordinator from a worker process and complete the
/// handshake (presenting `token` for authentication). Retries the
/// connect until the wait window closes (the coordinator may not be
/// listening yet), then bails. Returns the connection — already
/// running the liveness deadline the coordinator announced in
/// `Welcome` — and the coordinator's world size.
pub(crate) fn connect_worker(
    addr: &str,
    rank: usize,
    world: Option<usize>,
    dir: &Path,
    token: &str,
    fault: Option<Arc<FaultPlan>>,
) -> Result<(Arc<RemoteIo>, usize)> {
    let fingerprint = super::manifest_fingerprint(dir);
    let deadline = Instant::now() + Duration::from_secs(wait_secs());
    let stream = loop {
        match TcpStream::connect(addr) {
            Ok(s) => break s,
            Err(e) => {
                if Instant::now() >= deadline {
                    return Err(e).with_context(|| {
                        format!("connecting to coordinator at {addr} (rank {rank})")
                    });
                }
                std::thread::sleep(Duration::from_millis(250));
            }
        }
    };
    stream.set_nodelay(true).ok();
    let hello = WireMsg::Hello {
        rank: rank as u32,
        world: world.unwrap_or(0) as u32,
        fingerprint,
        token: token.to_string(),
    };
    let mut payload = Vec::new();
    hello.encode(&mut payload)?;
    let hello_bytes = write_frame(&mut &stream, hello.kind(), rank as u32, &payload)
        .context("sending rank handshake")?;
    stream
        .set_read_timeout(Some(Duration::from_secs(10)))
        .context("setting handshake read timeout")?;
    let frame = {
        let mut r = stream.try_clone().context("cloning stream")?;
        read_frame(&mut r).context("reading coordinator handshake reply")?
    };
    let reply_bytes = (HEADER_LEN + frame.payload.len()) as u64;
    let (p, timeout) = match WireMsg::decode(frame.kind, &frame.payload)
        .context("decoding coordinator handshake reply")?
    {
        WireMsg::Welcome { p, timeout_ms } => {
            (p as usize, Duration::from_millis(timeout_ms as u64))
        }
        WireMsg::Reject { reason } => bail!("coordinator rejected this worker: {reason}"),
        other => bail!("unexpected handshake reply (message kind {})", other.kind()),
    };
    if timeout > Duration::ZERO {
        // Steady state: short read timeouts are liveness ticks for the
        // worker's own deadline, and writes are deadline-bounded too.
        stream
            .set_read_timeout(Some(heartbeat_interval(timeout)))
            .context("setting liveness read timeout")?;
        stream.set_write_timeout(Some(timeout)).context("setting liveness write timeout")?;
    } else {
        stream.set_read_timeout(None).context("clearing handshake read timeout")?;
    }
    let io = RemoteIo {
        rank: rank as u32,
        reader: Mutex::new(FrameReader::new(stream.try_clone().context("cloning stream")?)),
        writer: Mutex::new(stream),
        tx_bytes: AtomicU64::new(hello_bytes),
        rx_bytes: AtomicU64::new(reply_bytes),
        aborted: Mutex::new(None),
        timeout,
        stalled: AtomicBool::new(false),
        fault_disconnect: AtomicBool::new(false),
        fault,
        reqs_seen: AtomicU64::new(0),
    };
    Ok((Arc::new(io), p))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hub_folds_all_reduce_in_rank_order() {
        let mut slots = vec![
            Some(vec![1.0f32, 2.0]),
            Some(vec![10.0, 20.0]),
            Some(vec![100.0, 200.0]),
        ];
        let out = fold(CollOp::AllReduce, &mut slots).unwrap();
        assert_eq!(out, vec![111.0, 222.0]);
    }

    #[test]
    fn hub_all_gather_concatenates_in_rank_order() {
        let mut slots = vec![Some(vec![1.0f32]), Some(vec![2.0, 3.0])];
        let out = fold(CollOp::AllGather, &mut slots).unwrap();
        assert_eq!(out, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn hub_broadcast_takes_rank_zero() {
        let mut slots = vec![Some(vec![7.0f32]), Some(Vec::new())];
        let out = fold(CollOp::Broadcast, &mut slots).unwrap();
        assert_eq!(out, vec![7.0]);
    }

    #[test]
    fn hub_length_mismatch_is_contextful() {
        let mut slots = vec![Some(vec![1.0f32, 2.0]), Some(vec![1.0])];
        let err = fold(CollOp::AllReduce, &mut slots).unwrap_err();
        assert!(err.contains("length mismatch"), "{err}");
    }

    #[test]
    fn hub_abort_is_sticky_and_first_wins() {
        let hub = CollHub::new(2);
        hub.abort(1, "first");
        hub.abort(0, "second");
        let inner = lock(&hub.inner);
        assert_eq!(inner.aborted.as_ref().unwrap(), &(1, "first".to_string()));
    }

    #[test]
    fn hub_reset_clears_the_sticky_abort() {
        let hub = CollHub::new(1);
        hub.abort(0, "boom");
        hub.reset();
        assert!(lock(&hub.inner).aborted.is_none());
    }

    #[test]
    fn hub_reset_keeps_the_missed_heartbeat_count() {
        let hub = CollHub::new(1);
        hub.note_missed_heartbeat();
        hub.note_missed_heartbeat();
        hub.reset();
        assert_eq!(hub.heartbeats_missed(), 2);
    }

    #[test]
    fn token_compare_covers_the_full_matrix() {
        assert!(token_matches("", ""));
        assert!(token_matches("sekrit", "sekrit"));
        assert!(!token_matches("sekrit", ""));
        assert!(!token_matches("", "sekrit"));
        assert!(!token_matches("sekrit", "sekrat"));
        assert!(!token_matches("sekrit", "sekrit2"));
    }

    #[test]
    fn heartbeat_interval_is_a_third_with_a_floor() {
        assert_eq!(heartbeat_interval(Duration::from_secs(30)), Duration::from_secs(10));
        assert_eq!(
            heartbeat_interval(Duration::from_millis(3)),
            Duration::from_millis(10),
            "tiny timeouts floor at 10ms so the tick loop cannot spin"
        );
    }
}
