//! TCP rank transport: process-separated workers over loopback or LAN.
//!
//! The coordinator listens on one address per rank; each `oggm rank`
//! worker process dials in and handshakes (`Hello` → `Welcome` /
//! `Reject`) carrying its rank id, expected world size, and artifact
//! manifest fingerprint so mismatched processes fail fast with a
//! contextful message instead of diverging mid-solve.
//!
//! Collectives are *hub-folded*: workers deposit payloads as
//! [`msg::WireMsg::CollDeposit`] frames, and the coordinator-side
//! [`CollHub`] folds them in rank order (bitwise identical to the
//! in-process chunked fold, which is also a rank-order left fold) and
//! fans the result back as `CollResult`. An abort from any rank (or a
//! worker disconnect) is fanned to every peer as `CollAbort`, and is
//! *sticky*: every later collective on that group fails with the same
//! originating rank and reason until the pool resets the group.

use std::collections::HashSet;
use std::io::{BufReader, Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, Sender, TryRecvError};
use std::sync::{Arc, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::parallel::{Req, Resp};

use super::frame::{read_frame, write_frame, HEADER_LEN};
use super::msg::{self, CollOp, WireMsg};

/// Lock a mutex, tolerating poisoning: a panicking peer thread must not
/// cascade into every other rank's transport path.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// How long to wait for rank workers to connect (or for a worker to
/// reach its coordinator), in seconds. `OGGM_RANK_WAIT_SECS` overrides
/// the 60 s default — CI smokes shorten it so failures surface fast.
fn wait_secs() -> u64 {
    std::env::var("OGGM_RANK_WAIT_SECS").ok().and_then(|s| s.parse().ok()).unwrap_or(60)
}

/// Coordinator-side write half of one worker connection: a mutex-held
/// stream (the hub fans results from whichever reader thread completes
/// a collective) plus the shared tx counter.
#[derive(Clone)]
struct RankWriter {
    stream: Arc<Mutex<TcpStream>>,
    tx_bytes: Arc<AtomicU64>,
}

impl RankWriter {
    /// Encode and send one message addressed to `rank`.
    fn send(&self, rank: u32, msg: &WireMsg) -> Result<()> {
        let mut payload = Vec::new();
        msg.encode(&mut payload)?;
        let mut stream = lock(&self.stream);
        let n = write_frame(&mut *stream, msg.kind(), rank, &payload)?;
        self.tx_bytes.fetch_add(n, Ordering::Relaxed);
        Ok(())
    }
}

/// Mutable hub state: per-rank writers, deposit slots for the
/// collective in flight, and the sticky abort record.
struct HubInner {
    writers: Vec<Option<RankWriter>>,
    slots: Vec<Option<Vec<f32>>>,
    op: Option<CollOp>,
    arrived: usize,
    aborted: Option<(usize, String)>,
}

/// Coordinator-side collective folding point for the TCP transport.
///
/// Plays the role the shared deposit slots play in the in-process
/// [`crate::collective::Communicator`]: ranks deposit, the last arrival
/// folds in rank order, and everyone receives the same result bytes.
pub(crate) struct CollHub {
    p: usize,
    inner: Mutex<HubInner>,
}

impl CollHub {
    /// New hub for a `p`-rank group with no connections registered yet.
    pub(crate) fn new(p: usize) -> Arc<CollHub> {
        Arc::new(CollHub {
            p,
            inner: Mutex::new(HubInner {
                writers: (0..p).map(|_| None).collect(),
                slots: (0..p).map(|_| None).collect(),
                op: None,
                arrived: 0,
                aborted: None,
            }),
        })
    }

    /// Register the write half for `rank` (called once per admitted worker).
    fn register(&self, rank: usize, writer: RankWriter) {
        lock(&self.inner).writers[rank] = Some(writer);
    }

    /// Clear deposit state and the sticky abort: the group is fresh
    /// again. The pool calls this after replacing collectives
    /// (mirrors `Req::NewComm` on the in-process path).
    pub(crate) fn reset(&self) {
        let mut inner = lock(&self.inner);
        for s in inner.slots.iter_mut() {
            *s = None;
        }
        inner.op = None;
        inner.arrived = 0;
        inner.aborted = None;
    }

    /// Record a sticky abort (first abort wins) and fan `CollAbort` to
    /// every connected worker so in-flight deposits fail immediately.
    pub(crate) fn abort(&self, rank: usize, reason: &str) {
        let mut inner = lock(&self.inner);
        if inner.aborted.is_none() {
            inner.aborted = Some((rank, reason.to_string()));
        }
        let (ar, ref areason) = *inner.aborted.as_ref().unwrap();
        let msg = WireMsg::CollAbort { rank: ar as u32, reason: areason.clone() };
        for w in inner.writers.iter().flatten() {
            let _ = w.send(ar as u32, &msg);
        }
    }

    /// One rank's deposit. When the last rank arrives the hub folds in
    /// rank order and fans the result; protocol violations (op
    /// mismatch, duplicate deposit, length mismatch) abort the group.
    fn deposit(&self, rank: usize, op: CollOp, payload: Vec<f32>) {
        enum Outcome {
            Pending,
            Fanout(Vec<f32>),
            Abort(String),
            Rejected(usize, String),
        }
        let outcome = {
            let mut inner = lock(&self.inner);
            if let Some((ar, reason)) = inner.aborted.clone() {
                Outcome::Rejected(ar, reason)
            } else if rank >= self.p {
                Outcome::Abort(format!("collective deposit from unknown rank {rank}"))
            } else if inner.op.is_some() && inner.op != Some(op) {
                Outcome::Abort(format!(
                    "collective op mismatch: rank {rank} deposited {} during {}",
                    op.name(),
                    inner.op.unwrap().name()
                ))
            } else if inner.slots[rank].is_some() {
                Outcome::Abort(format!(
                    "duplicate collective deposit from rank {rank} ({})",
                    op.name()
                ))
            } else {
                inner.op = Some(op);
                inner.slots[rank] = Some(payload);
                inner.arrived += 1;
                if inner.arrived < self.p {
                    Outcome::Pending
                } else {
                    match fold(op, &mut inner.slots) {
                        Ok(result) => {
                            inner.op = None;
                            inner.arrived = 0;
                            for s in inner.slots.iter_mut() {
                                *s = None;
                            }
                            Outcome::Fanout(result)
                        }
                        Err(reason) => Outcome::Abort(reason),
                    }
                }
            }
        };
        match outcome {
            Outcome::Pending => {}
            Outcome::Fanout(result) => {
                let inner = lock(&self.inner);
                let msg = WireMsg::CollResult { payload: result };
                for (r, w) in inner.writers.iter().enumerate() {
                    if let Some(w) = w {
                        let _ = w.send(r as u32, &msg);
                    }
                }
            }
            Outcome::Abort(reason) => self.abort(rank, &reason),
            Outcome::Rejected(ar, reason) => {
                // Group already aborted: tell just this depositor.
                let inner = lock(&self.inner);
                if let Some(w) = inner.writers[rank.min(self.p - 1)].as_ref() {
                    let _ =
                        w.send(rank as u32, &WireMsg::CollAbort { rank: ar as u32, reason });
                }
            }
        }
    }
}

/// Fold all deposits for `op` in rank order. This must stay bitwise
/// identical to the in-process fold in `collective/comm.rs`, which
/// accumulates `rank 0 + rank 1 + …` per chunk — a whole-buffer
/// left fold over ranks produces the same f32 result.
fn fold(op: CollOp, slots: &mut [Option<Vec<f32>>]) -> std::result::Result<Vec<f32>, String> {
    match op {
        CollOp::Barrier => Ok(Vec::new()),
        CollOp::AllReduce => {
            let mut acc = slots[0].take().expect("rank 0 deposit present");
            for (r, s) in slots.iter().enumerate().skip(1) {
                let s = s.as_ref().expect("deposit present");
                if s.len() != acc.len() {
                    return Err(format!(
                        "all_reduce length mismatch across ranks ({} vs {} at rank {r})",
                        acc.len(),
                        s.len()
                    ));
                }
                for (a, b) in acc.iter_mut().zip(s) {
                    *a += *b;
                }
            }
            Ok(acc)
        }
        CollOp::AllGather => {
            let mut out = Vec::new();
            for s in slots.iter() {
                out.extend_from_slice(s.as_ref().expect("deposit present"));
            }
            Ok(out)
        }
        CollOp::Broadcast => Ok(slots[0].take().expect("rank 0 deposit present")),
    }
}

/// Coordinator-side endpoint of one TCP rank worker: the write half,
/// a channel fed by the connection's reader thread, and liveness state.
pub(crate) struct TcpLink {
    rank: usize,
    writer: RankWriter,
    resp_rx: Receiver<Resp>,
    dead: Arc<AtomicBool>,
    rx_bytes: Arc<AtomicU64>,
    reader: Option<JoinHandle<()>>,
}

impl TcpLink {
    /// Send one request; `Err(())` on a dead or unwritable connection.
    pub(crate) fn send(&self, req: Req) -> Result<(), ()> {
        if self.dead.load(Ordering::Acquire) {
            return Err(());
        }
        let msg = WireMsg::Req(req);
        if self.writer.send(self.rank as u32, &msg).is_err() {
            self.dead.store(true, Ordering::Release);
            return Err(());
        }
        Ok(())
    }

    /// Blocking receive of one response; `Err(())` once the reader
    /// thread has exited (connection closed or protocol error).
    pub(crate) fn recv(&self) -> Result<Resp, ()> {
        self.resp_rx.recv().map_err(|_| ())
    }

    /// Non-blocking receive used to drain stale responses.
    pub(crate) fn try_recv(&self) -> Option<Resp> {
        match self.resp_rx.try_recv() {
            Ok(resp) => Some(resp),
            Err(TryRecvError::Empty | TryRecvError::Disconnected) => None,
        }
    }

    /// Whether the connection is known dead (write failed or reader exited).
    pub(crate) fn is_dead(&self) -> bool {
        self.dead.load(Ordering::Acquire)
    }

    /// (tx_bytes, rx_bytes) actually moved over this connection.
    pub(crate) fn traffic(&self) -> (u64, u64) {
        (self.writer.tx_bytes.load(Ordering::Relaxed), self.rx_bytes.load(Ordering::Relaxed))
    }
}

impl Drop for TcpLink {
    fn drop(&mut self) {
        if let Ok(stream) = lock(&self.writer.stream).try_clone() {
            let _ = stream.shutdown(Shutdown::Both);
        }
        if let Some(h) = self.reader.take() {
            let _ = h.join();
        }
    }
}

/// Spawn the per-connection reader thread: routes `Resp` frames to the
/// pool's channel and collective frames to the hub, and marks the link
/// dead (aborting the group) when the stream closes.
fn spawn_reader(
    rank: usize,
    stream: TcpStream,
    resp_tx: Sender<Resp>,
    dead: Arc<AtomicBool>,
    rx_bytes: Arc<AtomicU64>,
    hub: Arc<CollHub>,
) -> Result<JoinHandle<()>> {
    let handle = std::thread::Builder::new()
        .name(format!("oggm-rank{rank}-rx"))
        .spawn(move || {
            let mut r = BufReader::new(stream);
            loop {
                let frame = match read_frame(&mut r) {
                    Ok(f) => f,
                    Err(_) => break,
                };
                rx_bytes
                    .fetch_add((HEADER_LEN + frame.payload.len()) as u64, Ordering::Relaxed);
                match WireMsg::decode(frame.kind, &frame.payload) {
                    Ok(WireMsg::Resp(resp)) => {
                        if resp_tx.send(resp).is_err() {
                            break;
                        }
                    }
                    Ok(WireMsg::CollDeposit { op, payload }) => hub.deposit(rank, op, payload),
                    Ok(WireMsg::CollAbort { rank: ar, reason }) => {
                        hub.abort(ar as usize, &reason)
                    }
                    Ok(_) => {} // stale handshake frames: ignore
                    Err(_) => break,
                }
            }
            dead.store(true, Ordering::Release);
            hub.abort(rank, &format!("rank {rank} worker process disconnected"));
        })
        .with_context(|| format!("spawning reader thread for rank {rank}"))?;
    Ok(handle)
}

/// Validate one inbound connection's `Hello` against the group shape
/// and artifact fingerprint; on success reply `Welcome` and build the
/// link, on failure reply `Reject{reason}` best-effort and bail.
fn admit(
    stream: TcpStream,
    p: usize,
    fingerprint: u64,
    taken: &HashSet<usize>,
    hub: &Arc<CollHub>,
) -> Result<TcpLink> {
    stream.set_nodelay(true).ok();
    stream
        .set_read_timeout(Some(Duration::from_secs(5)))
        .context("setting handshake read timeout")?;
    let mut reader = stream.try_clone().context("cloning rank stream")?;
    let reject = |stream: &TcpStream, reason: &str| {
        let mut payload = Vec::new();
        let msg = WireMsg::Reject { reason: reason.to_string() };
        if msg.encode(&mut payload).is_ok() {
            let _ = write_frame(&mut &*stream, msg.kind(), 0, &payload);
        }
    };
    let frame = read_frame(&mut reader).context("reading rank handshake")?;
    let (rank, world, fp) = match WireMsg::decode(frame.kind, &frame.payload) {
        Ok(WireMsg::Hello { rank, world, fingerprint }) => {
            (rank as usize, world as usize, fingerprint)
        }
        Ok(other) => {
            let why = format!("expected Hello, got message kind {}", other.kind());
            reject(&stream, &why);
            bail!("rank handshake: {why}");
        }
        Err(e) => return Err(e.context("decoding rank handshake")),
    };
    let fail = |why: String| -> Result<TcpLink> {
        reject(&stream, &why);
        bail!("rank handshake: {why}");
    };
    if rank >= p {
        return fail(format!("rank {rank} out of range for a P={p} group"));
    }
    if taken.contains(&rank) {
        return fail(format!("duplicate connection for rank {rank}"));
    }
    if world != 0 && world != p {
        return fail(format!(
            "world size mismatch: worker launched for P={world}, coordinator runs P={p}"
        ));
    }
    if fp != fingerprint {
        return fail(format!(
            "artifact manifest fingerprint mismatch (worker {fp:#018x}, coordinator \
             {fingerprint:#018x}): workers must share the coordinator's artifact set"
        ));
    }
    let writer = RankWriter {
        stream: Arc::new(Mutex::new(stream.try_clone().context("cloning rank stream")?)),
        tx_bytes: Arc::new(AtomicU64::new(0)),
    };
    writer
        .send(rank as u32, &WireMsg::Welcome { p: p as u32 })
        .with_context(|| format!("welcoming rank {rank}"))?;
    stream.set_read_timeout(None).context("clearing handshake read timeout")?;
    let (resp_tx, resp_rx) = std::sync::mpsc::channel();
    let dead = Arc::new(AtomicBool::new(false));
    let rx_bytes = Arc::new(AtomicU64::new(0));
    hub.register(rank, writer.clone());
    let reader = spawn_reader(
        rank,
        stream,
        resp_tx,
        Arc::clone(&dead),
        Arc::clone(&rx_bytes),
        Arc::clone(hub),
    )?;
    Ok(TcpLink { rank, writer, resp_rx, dead, rx_bytes, reader: Some(reader) })
}

/// Listen on the given addresses and admit exactly `p` rank workers,
/// returning their links indexed by rank. Bails with a contextful
/// message if the full group does not form within the wait window.
pub(crate) fn accept_ranks(
    addrs: &[String],
    p: usize,
    fingerprint: u64,
    hub: &Arc<CollHub>,
) -> Result<Vec<TcpLink>> {
    let mut unique: Vec<&str> = Vec::new();
    for a in addrs {
        let a = a.trim();
        if !a.is_empty() && !unique.contains(&a) {
            unique.push(a);
        }
    }
    if unique.is_empty() || unique.len() > p {
        bail!(
            "--ranks lists {} listen address(es); expected 1..={p} for a P={p} group",
            unique.len()
        );
    }
    let mut listeners = Vec::new();
    for a in &unique {
        let l = TcpListener::bind(a).with_context(|| format!("binding rank listener on {a}"))?;
        l.set_nonblocking(true).context("setting rank listener nonblocking")?;
        listeners.push(l);
    }
    let deadline = Instant::now() + Duration::from_secs(wait_secs());
    let mut links: Vec<Option<TcpLink>> = (0..p).map(|_| None).collect();
    let mut taken: HashSet<usize> = HashSet::new();
    while taken.len() < p {
        let mut accepted = false;
        for l in &listeners {
            match l.accept() {
                Ok((stream, _)) => {
                    stream.set_nonblocking(false).context("setting rank stream blocking")?;
                    let link = admit(stream, p, fingerprint, &taken, hub)?;
                    taken.insert(link.rank);
                    links[link.rank] = Some(link);
                    accepted = true;
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {}
                Err(e) => return Err(e).context("accepting rank connection"),
            }
        }
        if taken.len() == p {
            break;
        }
        if Instant::now() >= deadline {
            bail!(
                "timed out waiting for rank workers: {} of {p} connected \
                 (launch `oggm rank --connect <addr> --rank R` workers)",
                taken.len()
            );
        }
        if !accepted {
            std::thread::sleep(Duration::from_millis(25));
        }
    }
    Ok(links.into_iter().map(|l| l.expect("all ranks admitted")).collect())
}

/// Worker-side connection state: the stream halves plus traffic
/// counters and the sticky abort record shared between the request
/// loop and the collective path.
pub(crate) struct RemoteIo {
    rank: u32,
    reader: Mutex<BufReader<TcpStream>>,
    writer: Mutex<TcpStream>,
    tx_bytes: AtomicU64,
    rx_bytes: AtomicU64,
    aborted: Mutex<Option<(usize, String)>>,
}

impl RemoteIo {
    /// Encode and send one message (frames carry this worker's rank).
    pub(crate) fn send(&self, msg: &WireMsg) -> Result<()> {
        let mut payload = Vec::new();
        msg.encode(&mut payload)?;
        let mut w = lock(&self.writer);
        let n = write_frame(&mut *w, msg.kind(), self.rank, &payload)?;
        self.tx_bytes.fetch_add(n, Ordering::Relaxed);
        Ok(())
    }

    /// Read and decode one message, counting rx bytes.
    fn recv_msg(&self) -> Result<WireMsg> {
        let mut r = lock(&self.reader);
        let frame = read_frame(&mut *r)?;
        self.rx_bytes
            .fetch_add((HEADER_LEN + frame.payload.len()) as u64, Ordering::Relaxed);
        WireMsg::decode(frame.kind, &frame.payload)
    }

    /// Blocking receive of the next control request. Collective aborts
    /// arriving between requests are recorded sticky; stale collective
    /// results are discarded. `None` means the coordinator is gone.
    pub(crate) fn recv_req(&self) -> Option<Req> {
        loop {
            match self.recv_msg() {
                Ok(WireMsg::Req(req)) => return Some(req),
                Ok(WireMsg::CollAbort { rank, reason }) => {
                    self.record_abort(rank as usize, &reason)
                }
                Ok(_) => {} // stale CollResult / handshake frames
                Err(_) => return None,
            }
        }
    }

    /// Send one response; `false` means the coordinator is unreachable.
    pub(crate) fn send_resp(&self, resp: Resp) -> bool {
        self.send(&WireMsg::Resp(resp)).is_ok()
    }

    /// Record a sticky abort (first abort wins).
    fn record_abort(&self, rank: usize, reason: &str) {
        let mut a = lock(&self.aborted);
        if a.is_none() {
            *a = Some((rank, reason.to_string()));
        }
    }

    /// The sticky abort record, if any.
    fn aborted(&self) -> Option<(usize, String)> {
        lock(&self.aborted).clone()
    }

    /// Clear the sticky abort (a fresh collective group was issued).
    fn clear_abort(&self) {
        *lock(&self.aborted) = None;
    }

    /// (tx_bytes, rx_bytes) moved over this worker's connection.
    pub(crate) fn traffic(&self) -> (u64, u64) {
        (self.tx_bytes.load(Ordering::Relaxed), self.rx_bytes.load(Ordering::Relaxed))
    }
}

/// Worker-side collective backend: deposits go to the coordinator hub
/// as frames, results come back on the same stream.
pub(crate) struct RemoteComm {
    io: Arc<RemoteIo>,
    rank: usize,
    p: usize,
    bytes: AtomicU64,
    ops: AtomicU64,
}

impl RemoteComm {
    /// New remote collective backend for `rank` in a `p`-rank group.
    pub(crate) fn new(io: Arc<RemoteIo>, rank: usize, p: usize) -> RemoteComm {
        RemoteComm { io, rank, p, bytes: AtomicU64::new(0), ops: AtomicU64::new(0) }
    }

    /// World size.
    pub(crate) fn p(&self) -> usize {
        self.p
    }

    /// (logical collective bytes, collective op count) — same
    /// accounting the in-process communicator reports.
    pub(crate) fn traffic(&self) -> (u64, u64) {
        (self.bytes.load(Ordering::Relaxed), self.ops.load(Ordering::Relaxed))
    }

    /// Add to the logical traffic counters.
    pub(crate) fn add_traffic(&self, bytes: u64, count_op: bool) {
        self.bytes.fetch_add(bytes, Ordering::Relaxed);
        if count_op {
            self.ops.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// The sticky abort record, if any.
    pub(crate) fn aborted(&self) -> Option<(usize, String)> {
        self.io.aborted()
    }

    /// Abort the group: record locally (first wins) and tell the hub
    /// best-effort so peers fail fast too.
    pub(crate) fn abort(&self, reason: &str) {
        self.io.record_abort(self.rank, reason);
        let _ = self.io.send(&WireMsg::CollAbort {
            rank: self.rank as u32,
            reason: reason.to_string(),
        });
    }

    /// A fresh collective group: clear the sticky abort and zero the
    /// counters (mirrors the in-process `NewComm` fresh-group state).
    pub(crate) fn reset(&self) {
        self.io.clear_abort();
        self.bytes.store(0, Ordering::Relaxed);
        self.ops.store(0, Ordering::Relaxed);
    }

    /// One deposit→result round trip through the hub. Returns the
    /// folded payload, or the originating `(rank, reason)` on abort.
    pub(crate) fn roundtrip(
        &self,
        op: CollOp,
        payload: Vec<f32>,
    ) -> std::result::Result<Vec<f32>, (usize, String)> {
        if let Some(a) = self.aborted() {
            return Err(a);
        }
        if let Err(e) = self.io.send(&WireMsg::CollDeposit { op, payload }) {
            let reason = format!("rank {} lost its coordinator connection: {e}", self.rank);
            self.io.record_abort(self.rank, &reason);
            return Err((self.rank, reason));
        }
        loop {
            match self.io.recv_msg() {
                Ok(WireMsg::CollResult { payload }) => return Ok(payload),
                Ok(WireMsg::CollAbort { rank, reason }) => {
                    self.io.record_abort(rank as usize, &reason);
                    return Err((rank as usize, reason));
                }
                Ok(WireMsg::Req(_)) => {
                    let reason = format!(
                        "protocol error: control request arrived mid-{} on rank {}",
                        op.name(),
                        self.rank
                    );
                    self.abort(&reason);
                    return Err((self.rank, reason));
                }
                Ok(_) => {}
                Err(e) => {
                    let reason =
                        format!("rank {} lost its coordinator connection: {e}", self.rank);
                    self.io.record_abort(self.rank, &reason);
                    return Err((self.rank, reason));
                }
            }
        }
    }
}

/// Dial the coordinator from a worker process and complete the
/// handshake. Retries the connect until the wait window closes (the
/// coordinator may not be listening yet), then bails. Returns the
/// connection and the coordinator's world size.
pub(crate) fn connect_worker(
    addr: &str,
    rank: usize,
    world: Option<usize>,
    dir: &Path,
) -> Result<(Arc<RemoteIo>, usize)> {
    let fingerprint = super::manifest_fingerprint(dir);
    let deadline = Instant::now() + Duration::from_secs(wait_secs());
    let stream = loop {
        match TcpStream::connect(addr) {
            Ok(s) => break s,
            Err(e) => {
                if Instant::now() >= deadline {
                    return Err(e).with_context(|| {
                        format!("connecting to coordinator at {addr} (rank {rank})")
                    });
                }
                std::thread::sleep(Duration::from_millis(250));
            }
        }
    };
    stream.set_nodelay(true).ok();
    let io = RemoteIo {
        rank: rank as u32,
        reader: Mutex::new(BufReader::new(stream.try_clone().context("cloning stream")?)),
        writer: Mutex::new(stream.try_clone().context("cloning stream")?),
        tx_bytes: AtomicU64::new(0),
        rx_bytes: AtomicU64::new(0),
        aborted: Mutex::new(None),
    };
    io.send(&WireMsg::Hello {
        rank: rank as u32,
        world: world.unwrap_or(0) as u32,
        fingerprint,
    })
    .context("sending rank handshake")?;
    stream
        .set_read_timeout(Some(Duration::from_secs(10)))
        .context("setting handshake read timeout")?;
    let reply = io.recv_msg().context("reading coordinator handshake reply")?;
    stream.set_read_timeout(None).context("clearing handshake read timeout")?;
    match reply {
        WireMsg::Welcome { p } => Ok((Arc::new(io), p as usize)),
        WireMsg::Reject { reason } => bail!("coordinator rejected this worker: {reason}"),
        other => bail!("unexpected handshake reply (message kind {})", other.kind()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hub_folds_all_reduce_in_rank_order() {
        let mut slots = vec![
            Some(vec![1.0f32, 2.0]),
            Some(vec![10.0, 20.0]),
            Some(vec![100.0, 200.0]),
        ];
        let out = fold(CollOp::AllReduce, &mut slots).unwrap();
        assert_eq!(out, vec![111.0, 222.0]);
    }

    #[test]
    fn hub_all_gather_concatenates_in_rank_order() {
        let mut slots = vec![Some(vec![1.0f32]), Some(vec![2.0, 3.0])];
        let out = fold(CollOp::AllGather, &mut slots).unwrap();
        assert_eq!(out, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn hub_broadcast_takes_rank_zero() {
        let mut slots = vec![Some(vec![7.0f32]), Some(Vec::new())];
        let out = fold(CollOp::Broadcast, &mut slots).unwrap();
        assert_eq!(out, vec![7.0]);
    }

    #[test]
    fn hub_length_mismatch_is_contextful() {
        let mut slots = vec![Some(vec![1.0f32, 2.0]), Some(vec![1.0])];
        let err = fold(CollOp::AllReduce, &mut slots).unwrap_err();
        assert!(err.contains("length mismatch"), "{err}");
    }

    #[test]
    fn hub_abort_is_sticky_and_first_wins() {
        let hub = CollHub::new(2);
        hub.abort(1, "first");
        hub.abort(0, "second");
        let inner = lock(&hub.inner);
        assert_eq!(inner.aborted.as_ref().unwrap(), &(1, "first".to_string()));
    }

    #[test]
    fn hub_reset_clears_the_sticky_abort() {
        let hub = CollHub::new(1);
        hub.abort(0, "boom");
        hub.reset();
        assert!(lock(&hub.inner).aborted.is_none());
    }
}
