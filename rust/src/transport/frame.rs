//! Wire framing for rank transport messages (DESIGN.md §12).
//!
//! Every message between the coordinator and a rank worker — control
//! requests, responses, and collective traffic — travels as one *frame*:
//! a fixed 16-byte little-endian header followed by an opaque payload.
//!
//! ```text
//! offset  size  field
//! 0       4     magic   b"OGTP"
//! 4       2     version protocol version (this build speaks VERSION)
//! 6       2     kind    message discriminant (transport::msg constants)
//! 8       4     rank    sending/addressed rank id
//! 12      4     len     payload length in bytes
//! ```
//!
//! The header is deliberately version-first after the magic so that a
//! peer speaking a different protocol revision is rejected with a
//! message naming both versions before any payload is trusted. Payloads
//! are capped at [`MAX_PAYLOAD`] so a corrupt length field cannot drive
//! an unbounded allocation.

use std::io::{Read, Write};

use anyhow::{bail, Context, Result};

/// Frame magic: the first four bytes of every frame.
pub const MAGIC: [u8; 4] = *b"OGTP";
/// Protocol version this build speaks. Bump on any wire-format change.
/// v2: heartbeat frames, authentication token in `Hello`, liveness
/// deadline in `Welcome`, recovery counters in the stats response.
pub const VERSION: u16 = 2;
/// Fixed header length in bytes (magic + version + kind + rank + len).
pub const HEADER_LEN: usize = 16;
/// Maximum accepted payload length (2 GiB): a sanity cap against
/// corrupt or malicious length fields, far above any real payload.
pub const MAX_PAYLOAD: u32 = 2 << 30;

/// One decoded frame: the header fields plus the raw payload bytes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Frame {
    /// Message discriminant (see `transport::msg` kind constants).
    pub kind: u16,
    /// Sending (worker→coordinator) or addressed (coordinator→worker) rank.
    pub rank: u32,
    /// Opaque payload bytes; interpretation depends on `kind`.
    pub payload: Vec<u8>,
}

/// Write one frame (header + payload) to `w`. Returns the total number
/// of bytes written (`HEADER_LEN + payload.len()`), for traffic
/// accounting.
pub fn write_frame<W: Write>(w: &mut W, kind: u16, rank: u32, payload: &[u8]) -> Result<u64> {
    if payload.len() as u64 > MAX_PAYLOAD as u64 {
        bail!("frame payload of {} bytes exceeds the {} byte cap", payload.len(), MAX_PAYLOAD);
    }
    let mut hdr = [0u8; HEADER_LEN];
    hdr[0..4].copy_from_slice(&MAGIC);
    hdr[4..6].copy_from_slice(&VERSION.to_le_bytes());
    hdr[6..8].copy_from_slice(&kind.to_le_bytes());
    hdr[8..12].copy_from_slice(&rank.to_le_bytes());
    hdr[12..16].copy_from_slice(&(payload.len() as u32).to_le_bytes());
    w.write_all(&hdr).context("writing frame header")?;
    w.write_all(payload).context("writing frame payload")?;
    Ok((HEADER_LEN + payload.len()) as u64)
}

/// Read one frame from `r`, validating magic, protocol version, and the
/// payload length cap. Errors are contextful: a mismatched version
/// names both the peer's version and this build's.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Frame> {
    let mut hdr = [0u8; HEADER_LEN];
    r.read_exact(&mut hdr).context("truncated frame header")?;
    if hdr[0..4] != MAGIC {
        bail!(
            "bad frame magic {:02x?} (expected \"OGTP\" — peer is not an oggm rank transport)",
            &hdr[0..4]
        );
    }
    let version = u16::from_le_bytes([hdr[4], hdr[5]]);
    if version != VERSION {
        bail!(
            "transport protocol version mismatch: peer speaks v{version}, \
             this build speaks v{VERSION}"
        );
    }
    let kind = u16::from_le_bytes([hdr[6], hdr[7]]);
    let rank = u32::from_le_bytes([hdr[8], hdr[9], hdr[10], hdr[11]]);
    let len = u32::from_le_bytes([hdr[12], hdr[13], hdr[14], hdr[15]]);
    if len > MAX_PAYLOAD {
        bail!("frame payload length {len} exceeds the {MAX_PAYLOAD} byte cap");
    }
    let mut payload = vec![0u8; len as usize];
    r.read_exact(&mut payload)
        .with_context(|| format!("truncated frame payload (wanted {len} bytes)"))?;
    Ok(Frame { kind, rank, payload })
}

/// Decode one frame from the head of `buf` without consuming the source
/// stream: returns `Ok(Some((frame, consumed)))` when `buf` holds a
/// complete frame, `Ok(None)` when more bytes are needed, and an error
/// on bad magic / version mismatch / oversize — the same validations as
/// [`read_frame`]. This is the incremental half of the codec: a socket
/// read timeout may land mid-frame, so deadline-bounded readers
/// accumulate bytes and decode from the buffer instead of `read_exact`
/// (which would lose the partial header on a timeout tick).
pub fn decode_frame(buf: &[u8]) -> Result<Option<(Frame, usize)>> {
    if buf.len() < HEADER_LEN {
        return Ok(None);
    }
    if buf[0..4] != MAGIC {
        bail!(
            "bad frame magic {:02x?} (expected \"OGTP\" — peer is not an oggm rank transport)",
            &buf[0..4]
        );
    }
    let version = u16::from_le_bytes([buf[4], buf[5]]);
    if version != VERSION {
        bail!(
            "transport protocol version mismatch: peer speaks v{version}, \
             this build speaks v{VERSION}"
        );
    }
    let kind = u16::from_le_bytes([buf[6], buf[7]]);
    let rank = u32::from_le_bytes([buf[8], buf[9], buf[10], buf[11]]);
    let len = u32::from_le_bytes([buf[12], buf[13], buf[14], buf[15]]);
    if len > MAX_PAYLOAD {
        bail!("frame payload length {len} exceeds the {MAX_PAYLOAD} byte cap");
    }
    let total = HEADER_LEN + len as usize;
    if buf.len() < total {
        return Ok(None);
    }
    let payload = buf[HEADER_LEN..total].to_vec();
    Ok(Some((Frame { kind, rank, payload }, total)))
}

/// An incremental frame reader over a byte stream with read timeouts.
///
/// `poll` returns `Ok(None)` when the underlying read times out (a
/// liveness tick — the partial frame, if any, stays buffered), a frame
/// when one completes, and an error on EOF or a malformed header. This
/// is what lets every steady-state I/O site be deadline-bounded
/// (DESIGN.md §12) without ever desyncing the length-prefixed stream.
pub struct FrameReader<R: Read> {
    src: R,
    buf: Vec<u8>,
    chunk: Box<[u8]>,
}

impl<R: Read> FrameReader<R> {
    /// Wrap a readable stream (typically a `TcpStream` with a read
    /// timeout installed).
    pub fn new(src: R) -> FrameReader<R> {
        FrameReader { src, buf: Vec::new(), chunk: vec![0u8; 64 * 1024].into_boxed_slice() }
    }

    /// Immutable access to the wrapped stream (e.g. to adjust timeouts).
    pub fn get_ref(&self) -> &R {
        &self.src
    }

    /// Try to produce the next frame. `Ok(None)` means the read timed
    /// out before a frame completed — call again after the liveness
    /// check. EOF is an error ("connection closed by peer"): with
    /// heartbeats on every idle link, a silent close is indistinguishable
    /// from death and is reported as such.
    pub fn poll(&mut self) -> Result<Option<Frame>> {
        loop {
            if let Some((frame, consumed)) = decode_frame(&self.buf)? {
                self.buf.drain(..consumed);
                return Ok(Some(frame));
            }
            match self.src.read(&mut self.chunk) {
                Ok(0) => bail!("connection closed by peer"),
                Ok(n) => self.buf.extend_from_slice(&self.chunk[..n]),
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut =>
                {
                    return Ok(None);
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e).context("reading frame bytes"),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn frame_round_trips() {
        let mut buf = Vec::new();
        let n = write_frame(&mut buf, 7, 3, &[1, 2, 3, 4, 5]).unwrap();
        assert_eq!(n, (HEADER_LEN + 5) as u64);
        let f = read_frame(&mut Cursor::new(&buf)).unwrap();
        assert_eq!(f, Frame { kind: 7, rank: 3, payload: vec![1, 2, 3, 4, 5] });
    }

    #[test]
    fn empty_payload_round_trips() {
        let mut buf = Vec::new();
        write_frame(&mut buf, 1, 0, &[]).unwrap();
        let f = read_frame(&mut Cursor::new(&buf)).unwrap();
        assert_eq!((f.kind, f.rank, f.payload.len()), (1, 0, 0));
    }

    #[test]
    fn bad_magic_is_rejected() {
        let mut buf = Vec::new();
        write_frame(&mut buf, 1, 0, &[9]).unwrap();
        buf[0] = b'X';
        let err = read_frame(&mut Cursor::new(&buf)).unwrap_err().to_string();
        assert!(err.contains("bad frame magic"), "{err}");
    }

    #[test]
    fn version_mismatch_names_both_versions() {
        let mut buf = Vec::new();
        write_frame(&mut buf, 1, 0, &[]).unwrap();
        buf[4..6].copy_from_slice(&(VERSION + 1).to_le_bytes());
        let err = read_frame(&mut Cursor::new(&buf)).unwrap_err().to_string();
        assert!(err.contains(&format!("v{}", VERSION + 1)), "{err}");
        assert!(err.contains(&format!("v{VERSION}")), "{err}");
    }

    #[test]
    fn truncated_header_and_payload_are_contextful() {
        let mut buf = Vec::new();
        write_frame(&mut buf, 2, 1, &[1, 2, 3, 4]).unwrap();
        let hdr_err =
            read_frame(&mut Cursor::new(&buf[..HEADER_LEN - 3])).unwrap_err().to_string();
        assert!(hdr_err.contains("truncated frame header"), "{hdr_err}");
        let pay_err = read_frame(&mut Cursor::new(&buf[..HEADER_LEN + 2])).unwrap_err();
        assert!(format!("{pay_err:#}").contains("truncated frame payload"), "{pay_err:#}");
    }

    #[test]
    fn oversized_length_field_is_rejected() {
        let mut buf = Vec::new();
        write_frame(&mut buf, 2, 1, &[]).unwrap();
        buf[12..16].copy_from_slice(&u32::MAX.to_le_bytes());
        let err = read_frame(&mut Cursor::new(&buf)).unwrap_err().to_string();
        assert!(err.contains("exceeds"), "{err}");
    }

    #[test]
    fn decode_frame_is_incremental() {
        let mut wire = Vec::new();
        write_frame(&mut wire, 5, 2, &[7, 8, 9]).unwrap();
        write_frame(&mut wire, 6, 0, &[]).unwrap();
        // Feeding any strict prefix of the first frame yields None; the
        // full prefix yields the frame plus its exact byte count.
        for cut in 0..HEADER_LEN + 3 {
            assert!(decode_frame(&wire[..cut]).unwrap().is_none(), "cut={cut}");
        }
        let (f, used) = decode_frame(&wire).unwrap().unwrap();
        assert_eq!(f, Frame { kind: 5, rank: 2, payload: vec![7, 8, 9] });
        assert_eq!(used, HEADER_LEN + 3);
        let (f2, used2) = decode_frame(&wire[used..]).unwrap().unwrap();
        assert_eq!((f2.kind, f2.rank, f2.payload.len(), used2), (6, 0, 0, HEADER_LEN));
        // The buffered decoder validates the same header invariants.
        let mut bad = wire.clone();
        bad[0] = b'X';
        assert!(decode_frame(&bad).unwrap_err().to_string().contains("bad frame magic"));
    }

    /// A reader that yields its script in dribs, with a timeout between.
    struct Dribble {
        data: Vec<u8>,
        at: usize,
        step: usize,
        ticks: usize,
    }

    impl Read for Dribble {
        fn read(&mut self, out: &mut [u8]) -> std::io::Result<usize> {
            if self.at >= self.data.len() {
                return Ok(0);
            }
            // Alternate: timeout, then a few bytes.
            self.ticks += 1;
            if self.ticks % 2 == 1 {
                return Err(std::io::ErrorKind::WouldBlock.into());
            }
            let n = self.step.min(self.data.len() - self.at).min(out.len());
            out[..n].copy_from_slice(&self.data[self.at..self.at + n]);
            self.at += n;
            Ok(n)
        }
    }

    #[test]
    fn frame_reader_survives_timeouts_mid_frame() {
        let mut wire = Vec::new();
        write_frame(&mut wire, 4, 1, &[1, 2, 3, 4, 5, 6, 7]).unwrap();
        let total = wire.len();
        let mut fr = FrameReader::new(Dribble { data: wire, at: 0, step: 3, ticks: 0 });
        let mut frames = Vec::new();
        let mut polls = 0;
        while frames.is_empty() {
            polls += 1;
            assert!(polls < 64, "reader never completed the frame");
            match fr.poll() {
                Ok(Some(f)) => frames.push(f),
                Ok(None) => {} // timeout tick: partial bytes stay buffered
                Err(e) => panic!("unexpected error: {e:#}"),
            }
        }
        assert_eq!(frames[0].payload, vec![1, 2, 3, 4, 5, 6, 7]);
        assert!(polls > total / 3, "expected many timeout ticks, got {polls}");
        // EOF after the frame is an error, not a hang.
        let err = loop {
            match fr.poll() {
                Ok(Some(f)) => panic!("phantom frame {f:?}"),
                Ok(None) => {}
                Err(e) => break format!("{e:#}"),
            }
        };
        assert!(err.contains("closed"), "{err}");
    }
}
