//! Wire framing for rank transport messages (DESIGN.md §12).
//!
//! Every message between the coordinator and a rank worker — control
//! requests, responses, and collective traffic — travels as one *frame*:
//! a fixed 16-byte little-endian header followed by an opaque payload.
//!
//! ```text
//! offset  size  field
//! 0       4     magic   b"OGTP"
//! 4       2     version protocol version (this build speaks VERSION)
//! 6       2     kind    message discriminant (transport::msg constants)
//! 8       4     rank    sending/addressed rank id
//! 12      4     len     payload length in bytes
//! ```
//!
//! The header is deliberately version-first after the magic so that a
//! peer speaking a different protocol revision is rejected with a
//! message naming both versions before any payload is trusted. Payloads
//! are capped at [`MAX_PAYLOAD`] so a corrupt length field cannot drive
//! an unbounded allocation.

use std::io::{Read, Write};

use anyhow::{bail, Context, Result};

/// Frame magic: the first four bytes of every frame.
pub const MAGIC: [u8; 4] = *b"OGTP";
/// Protocol version this build speaks. Bump on any wire-format change.
pub const VERSION: u16 = 1;
/// Fixed header length in bytes (magic + version + kind + rank + len).
pub const HEADER_LEN: usize = 16;
/// Maximum accepted payload length (2 GiB): a sanity cap against
/// corrupt or malicious length fields, far above any real payload.
pub const MAX_PAYLOAD: u32 = 2 << 30;

/// One decoded frame: the header fields plus the raw payload bytes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Frame {
    /// Message discriminant (see `transport::msg` kind constants).
    pub kind: u16,
    /// Sending (worker→coordinator) or addressed (coordinator→worker) rank.
    pub rank: u32,
    /// Opaque payload bytes; interpretation depends on `kind`.
    pub payload: Vec<u8>,
}

/// Write one frame (header + payload) to `w`. Returns the total number
/// of bytes written (`HEADER_LEN + payload.len()`), for traffic
/// accounting.
pub fn write_frame<W: Write>(w: &mut W, kind: u16, rank: u32, payload: &[u8]) -> Result<u64> {
    if payload.len() as u64 > MAX_PAYLOAD as u64 {
        bail!("frame payload of {} bytes exceeds the {} byte cap", payload.len(), MAX_PAYLOAD);
    }
    let mut hdr = [0u8; HEADER_LEN];
    hdr[0..4].copy_from_slice(&MAGIC);
    hdr[4..6].copy_from_slice(&VERSION.to_le_bytes());
    hdr[6..8].copy_from_slice(&kind.to_le_bytes());
    hdr[8..12].copy_from_slice(&rank.to_le_bytes());
    hdr[12..16].copy_from_slice(&(payload.len() as u32).to_le_bytes());
    w.write_all(&hdr).context("writing frame header")?;
    w.write_all(payload).context("writing frame payload")?;
    Ok((HEADER_LEN + payload.len()) as u64)
}

/// Read one frame from `r`, validating magic, protocol version, and the
/// payload length cap. Errors are contextful: a mismatched version
/// names both the peer's version and this build's.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Frame> {
    let mut hdr = [0u8; HEADER_LEN];
    r.read_exact(&mut hdr).context("truncated frame header")?;
    if hdr[0..4] != MAGIC {
        bail!(
            "bad frame magic {:02x?} (expected \"OGTP\" — peer is not an oggm rank transport)",
            &hdr[0..4]
        );
    }
    let version = u16::from_le_bytes([hdr[4], hdr[5]]);
    if version != VERSION {
        bail!(
            "transport protocol version mismatch: peer speaks v{version}, \
             this build speaks v{VERSION}"
        );
    }
    let kind = u16::from_le_bytes([hdr[6], hdr[7]]);
    let rank = u32::from_le_bytes([hdr[8], hdr[9], hdr[10], hdr[11]]);
    let len = u32::from_le_bytes([hdr[12], hdr[13], hdr[14], hdr[15]]);
    if len > MAX_PAYLOAD {
        bail!("frame payload length {len} exceeds the {MAX_PAYLOAD} byte cap");
    }
    let mut payload = vec![0u8; len as usize];
    r.read_exact(&mut payload)
        .with_context(|| format!("truncated frame payload (wanted {len} bytes)"))?;
    Ok(Frame { kind, rank, payload })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn frame_round_trips() {
        let mut buf = Vec::new();
        let n = write_frame(&mut buf, 7, 3, &[1, 2, 3, 4, 5]).unwrap();
        assert_eq!(n, (HEADER_LEN + 5) as u64);
        let f = read_frame(&mut Cursor::new(&buf)).unwrap();
        assert_eq!(f, Frame { kind: 7, rank: 3, payload: vec![1, 2, 3, 4, 5] });
    }

    #[test]
    fn empty_payload_round_trips() {
        let mut buf = Vec::new();
        write_frame(&mut buf, 1, 0, &[]).unwrap();
        let f = read_frame(&mut Cursor::new(&buf)).unwrap();
        assert_eq!((f.kind, f.rank, f.payload.len()), (1, 0, 0));
    }

    #[test]
    fn bad_magic_is_rejected() {
        let mut buf = Vec::new();
        write_frame(&mut buf, 1, 0, &[9]).unwrap();
        buf[0] = b'X';
        let err = read_frame(&mut Cursor::new(&buf)).unwrap_err().to_string();
        assert!(err.contains("bad frame magic"), "{err}");
    }

    #[test]
    fn version_mismatch_names_both_versions() {
        let mut buf = Vec::new();
        write_frame(&mut buf, 1, 0, &[]).unwrap();
        buf[4..6].copy_from_slice(&(VERSION + 1).to_le_bytes());
        let err = read_frame(&mut Cursor::new(&buf)).unwrap_err().to_string();
        assert!(err.contains(&format!("v{}", VERSION + 1)), "{err}");
        assert!(err.contains(&format!("v{VERSION}")), "{err}");
    }

    #[test]
    fn truncated_header_and_payload_are_contextful() {
        let mut buf = Vec::new();
        write_frame(&mut buf, 2, 1, &[1, 2, 3, 4]).unwrap();
        let hdr_err =
            read_frame(&mut Cursor::new(&buf[..HEADER_LEN - 3])).unwrap_err().to_string();
        assert!(hdr_err.contains("truncated frame header"), "{hdr_err}");
        let pay_err = read_frame(&mut Cursor::new(&buf[..HEADER_LEN + 2])).unwrap_err();
        assert!(format!("{pay_err:#}").contains("truncated frame payload"), "{pay_err:#}");
    }

    #[test]
    fn oversized_length_field_is_rejected() {
        let mut buf = Vec::new();
        write_frame(&mut buf, 2, 1, &[]).unwrap();
        buf[12..16].copy_from_slice(&u32::MAX.to_le_bytes());
        let err = read_frame(&mut Cursor::new(&buf)).unwrap_err().to_string();
        assert!(err.contains("exceeds"), "{err}");
    }
}
