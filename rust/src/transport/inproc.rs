//! In-process rank transport: the original threaded-pool channels,
//! wrapped behind the transport seam.
//!
//! Messages stay as Rust values end to end — `Arc`-shared buffers
//! (θ, one-hot targets) cross the "wire" zero-copy. To keep the
//! per-rank traffic counters comparable with the TCP transport, each
//! send/recv is *priced* via the canonical encoders
//! ([`msg::req_wire_len`]/[`msg::resp_wire_len`]) without serializing:
//! the counters report what the message *would* cost on a real wire.

use std::cell::Cell;
use std::sync::mpsc::{Receiver, Sender, TryRecvError};

use crate::parallel::{Req, Resp};

use super::msg;

/// Coordinator-side endpoint of one in-process rank: the request
/// sender and response receiver of the worker thread's channel pair,
/// plus logical traffic counters.
pub(crate) struct InProcLink {
    tx: Sender<Req>,
    rx: Receiver<Resp>,
    tx_bytes: Cell<u64>,
    rx_bytes: Cell<u64>,
}

impl InProcLink {
    /// Wrap a freshly spawned worker's channel endpoints.
    pub(crate) fn new(tx: Sender<Req>, rx: Receiver<Resp>) -> InProcLink {
        InProcLink { tx, rx, tx_bytes: Cell::new(0), rx_bytes: Cell::new(0) }
    }

    /// Send one request. `Err(())` means the worker's receiving end is
    /// gone (thread exited); callers map this to their own contextful
    /// message so wording stays owned by the pool.
    pub(crate) fn send(&self, req: Req) -> Result<(), ()> {
        self.tx_bytes.set(self.tx_bytes.get() + msg::req_wire_len(&req));
        self.tx.send(req).map_err(|_| ())
    }

    /// Blocking receive of one response; `Err(())` on a dead worker.
    pub(crate) fn recv(&self) -> Result<Resp, ()> {
        let resp = self.rx.recv().map_err(|_| ())?;
        self.rx_bytes.set(self.rx_bytes.get() + msg::resp_wire_len(&resp));
        Ok(resp)
    }

    /// Non-blocking receive used to drain stale responses.
    pub(crate) fn try_recv(&self) -> Option<Resp> {
        match self.rx.try_recv() {
            Ok(resp) => {
                self.rx_bytes.set(self.rx_bytes.get() + msg::resp_wire_len(&resp));
                Some(resp)
            }
            Err(TryRecvError::Empty | TryRecvError::Disconnected) => None,
        }
    }

    /// (tx_bytes, rx_bytes) priced at canonical wire size.
    pub(crate) fn traffic(&self) -> (u64, u64) {
        (self.tx_bytes.get(), self.rx_bytes.get())
    }
}
