//! Pluggable rank transport: process-separated distributed execution
//! behind one seam (DESIGN.md §12).
//!
//! The rank-parallel engine talks to its P workers through a *link*
//! abstraction with two implementations carrying the same framed,
//! versioned payloads:
//!
//! * [`inproc`] — the original threaded pool: Rust channels, messages
//!   cross as values (zero-copy for `Arc`-shared buffers), counters
//!   priced at canonical wire size so they stay comparable.
//! * [`tcp`] — separate OS processes over sockets: length-prefixed
//!   frames ([`frame`]), a handshake carrying rank id, world size, and
//!   the artifact manifest fingerprint so mismatched processes fail
//!   fast, and hub-folded collectives that are bitwise identical to
//!   the in-process rank-order fold.
//!
//! Both serialize via [`msg`], so a solve over TCP workers produces
//! bit-identical solutions and collective counts to the in-process
//! engine — `rust/tests/transport_equivalence.rs` pins this.

pub mod frame;
pub(crate) mod inproc;
pub(crate) mod msg;
pub(crate) mod tcp;

pub use tcp::TcpCfg;

use std::path::Path;
use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;

use crate::parallel::{Req, Resp};

/// FNV-1a 64-bit fingerprint of the artifact manifest (`manifest.tsv`)
/// under `dir`. Workers and the coordinator exchange this during the
/// TCP handshake: a mismatch means the processes were pointed at
/// different artifact sets and would silently diverge, so the
/// handshake rejects them up front. A missing manifest hashes as the
/// empty byte string (both sides degraded still match).
pub fn manifest_fingerprint(dir: &Path) -> u64 {
    let bytes = std::fs::read(dir.join("manifest.tsv")).unwrap_or_default();
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Coordinator-side endpoint of one rank, over either transport. The
/// pool holds one per rank and never cares which kind it is beyond the
/// wording of its failure messages.
pub(crate) enum RankLink {
    /// In-process worker thread (channel pair).
    InProc(inproc::InProcLink),
    /// Separate worker process (TCP connection).
    Tcp(tcp::TcpLink),
}

impl RankLink {
    /// Send one request; `Err(())` on a dead worker.
    pub(crate) fn send(&self, req: Req) -> Result<(), ()> {
        match self {
            RankLink::InProc(l) => l.send(req),
            RankLink::Tcp(l) => l.send(req),
        }
    }

    /// Blocking receive of one response; `Err(())` on a dead worker.
    pub(crate) fn recv(&self) -> Result<Resp, ()> {
        match self {
            RankLink::InProc(l) => l.recv(),
            RankLink::Tcp(l) => l.recv(),
        }
    }

    /// Non-blocking receive used to drain stale responses.
    pub(crate) fn try_recv(&self) -> Option<Resp> {
        match self {
            RankLink::InProc(l) => l.try_recv(),
            RankLink::Tcp(l) => l.try_recv(),
        }
    }

    /// (tx_bytes, rx_bytes) for this rank's control+collective traffic.
    pub(crate) fn traffic(&self) -> (u64, u64) {
        match self {
            RankLink::InProc(l) => l.traffic(),
            RankLink::Tcp(l) => l.traffic(),
        }
    }

    /// Failure wording for a send that found the worker gone. Both
    /// phrasings are retryable in the Executor: the in-process thread
    /// can be respawned, and since the rejoin window a dead worker
    /// *process* can be replaced by a reconnecting one. A TCP link that
    /// recorded a death reason (liveness miss) reports that instead of
    /// the generic wording.
    pub(crate) fn gone_msg(&self, rank: usize) -> String {
        match self {
            RankLink::InProc(_) => format!("rank {rank} worker is gone"),
            RankLink::Tcp(l) => l.death_reason().unwrap_or_else(|| {
                format!("rank {rank} worker process unreachable (connection closed)")
            }),
        }
    }

    /// Failure wording for a receive that found the worker dead; same
    /// retryable split as [`RankLink::gone_msg`].
    pub(crate) fn death_msg(&self, rank: usize) -> String {
        match self {
            RankLink::InProc(_) => format!("rank {rank}: worker thread died"),
            RankLink::Tcp(l) => l
                .death_reason()
                .unwrap_or_else(|| format!("rank {rank}: worker process disconnected")),
        }
    }
}

/// Worker-side endpoint: where `worker_main` receives requests and
/// sends responses, over either transport.
pub(crate) enum WorkerLink {
    /// In-process: the worker thread's end of the channel pair.
    Chan {
        /// Request receiver (coordinator → worker).
        rx: Receiver<Req>,
        /// Response sender (worker → coordinator).
        tx: Sender<Resp>,
    },
    /// Separate process: the worker's TCP connection.
    Remote(Arc<tcp::RemoteIo>),
}

impl WorkerLink {
    /// Blocking receive of the next request; `None` when the
    /// coordinator is gone and the worker should exit.
    pub(crate) fn recv(&self) -> Option<Req> {
        match self {
            WorkerLink::Chan { rx, .. } => rx.recv().ok(),
            WorkerLink::Remote(io) => io.recv_req(),
        }
    }

    /// Send one response; `false` when the coordinator is unreachable.
    pub(crate) fn send(&self, resp: Resp) -> bool {
        match self {
            WorkerLink::Chan { tx, .. } => tx.send(resp).is_ok(),
            WorkerLink::Remote(io) => io.send_resp(resp),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fingerprint_is_stable_and_content_sensitive() {
        let dir = std::env::temp_dir().join(format!("oggm_fp_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let missing = manifest_fingerprint(&dir);
        assert_eq!(missing, manifest_fingerprint(&dir), "stable on missing manifest");
        std::fs::write(dir.join("manifest.tsv"), b"# oggm artifact manifest\tk=32\n").unwrap();
        let a = manifest_fingerprint(&dir);
        assert_ne!(a, missing);
        std::fs::write(dir.join("manifest.tsv"), b"# oggm artifact manifest\tk=64\n").unwrap();
        assert_ne!(a, manifest_fingerprint(&dir));
        std::fs::remove_dir_all(&dir).ok();
    }
}
