//! Payload (de)serialization for rank-transport frames.
//!
//! Every control-plane [`Req`]/[`Resp`] the in-process pool passes over
//! channels has a canonical byte encoding here, so the TCP transport
//! carries *exactly the same payloads* and results stay bitwise
//! identical across transports. Scalars are little-endian; f32 buffers
//! are written as a u32 element count followed by raw LE bytes (the
//! `util::binio` idiom). The same encoders back the `InProc` logical
//! byte counters via [`CountWriter`], so `tx_bytes`/`rx_bytes` are
//! comparable between transports even though the in-process path never
//! actually serializes.

use std::io::Write;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Result};

use crate::coordinator::shard::{EdgeTile, ShardState, SparseShard};
use crate::graph::partition::Partition;
use crate::model::params::Params;
use crate::parallel::{FwdReq, RankShard, RankTiming, Req, Resp, SyncDelta};
use crate::runtime::exec::ExecStats;

use super::frame::HEADER_LEN;

/// Frame kind: worker→coordinator handshake greeting.
pub(crate) const KIND_HELLO: u16 = 1;
/// Frame kind: coordinator→worker handshake acceptance.
pub(crate) const KIND_WELCOME: u16 = 2;
/// Frame kind: coordinator→worker handshake rejection (then close).
pub(crate) const KIND_REJECT: u16 = 3;
/// Frame kind: coordinator→worker control request ([`Req`]).
pub(crate) const KIND_REQ: u16 = 4;
/// Frame kind: worker→coordinator control response ([`Resp`]).
pub(crate) const KIND_RESP: u16 = 5;
/// Frame kind: worker→coordinator collective deposit.
pub(crate) const KIND_COLL_DEPOSIT: u16 = 6;
/// Frame kind: coordinator→worker collective result fan-out.
pub(crate) const KIND_COLL_RESULT: u16 = 7;
/// Frame kind: collective abort notice (either direction).
pub(crate) const KIND_COLL_ABORT: u16 = 8;
/// Frame kind: liveness heartbeat (either direction, empty payload).
/// Sent on otherwise-idle links so a peer that stops responding is
/// distinguishable from a peer with nothing to say (DESIGN.md §12).
pub(crate) const KIND_HEARTBEAT: u16 = 9;

/// Collective operation discriminant carried in a deposit frame; the
/// hub validates that all ranks of a generation deposit the same op.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum CollOp {
    /// No payload; pure synchronization.
    Barrier,
    /// Elementwise sum; all ranks deposit equal-length buffers.
    AllReduce,
    /// Concatenation in rank order.
    AllGather,
    /// Rank 0's buffer copied to everyone.
    Broadcast,
}

impl CollOp {
    fn to_u8(self) -> u8 {
        match self {
            CollOp::Barrier => 0,
            CollOp::AllReduce => 1,
            CollOp::AllGather => 2,
            CollOp::Broadcast => 3,
        }
    }

    fn from_u8(v: u8) -> Result<CollOp> {
        Ok(match v {
            0 => CollOp::Barrier,
            1 => CollOp::AllReduce,
            2 => CollOp::AllGather,
            3 => CollOp::Broadcast,
            other => bail!("unknown collective op tag {other}"),
        })
    }

    /// Human-readable name, used in abort/mismatch messages.
    pub(crate) fn name(self) -> &'static str {
        match self {
            CollOp::Barrier => "barrier",
            CollOp::AllReduce => "all_reduce",
            CollOp::AllGather => "all_gather",
            CollOp::Broadcast => "broadcast",
        }
    }
}

/// One decoded transport message: the union of everything that can
/// travel in a frame after the header.
#[derive(Debug)]
pub(crate) enum WireMsg {
    /// Worker greeting: its rank, expected world size (0 = any), the
    /// FNV-1a fingerprint of its artifact manifest, and the shared
    /// authentication token (empty = none presented).
    Hello {
        /// The connecting worker's rank id.
        rank: u32,
        /// World size the worker was launched for (0 = accept any).
        world: u32,
        /// `manifest_fingerprint` of the worker's artifact dir.
        fingerprint: u64,
        /// Shared secret (`--token`/`OGGM_TOKEN`); compared in constant
        /// time against the coordinator's. Empty when unauthenticated.
        token: String,
    },
    /// Coordinator acceptance carrying the authoritative world size.
    Welcome {
        /// World size P of the group the worker just joined.
        p: u32,
        /// The coordinator's `--rank-timeout` in milliseconds: the
        /// liveness deadline both sides enforce (0 disables deadlines).
        timeout_ms: u32,
    },
    /// Coordinator rejection; the connection closes after this.
    Reject {
        /// Why the worker was turned away (version, rank, fingerprint…).
        reason: String,
    },
    /// A control-plane request (coordinator→worker).
    Req(Req),
    /// A control-plane response (worker→coordinator).
    Resp(Resp),
    /// A collective deposit (worker→coordinator hub).
    CollDeposit {
        /// Which collective this deposit belongs to.
        op: CollOp,
        /// The rank's contribution (possibly empty, e.g. barrier).
        payload: Vec<f32>,
    },
    /// The folded collective result fanned out to every rank.
    CollResult {
        /// The reduced/gathered/broadcast buffer (empty for barrier).
        payload: Vec<f32>,
    },
    /// A collective abort notice; sticky until the next fresh group.
    CollAbort {
        /// The rank that aborted (or was observed dead).
        rank: u32,
        /// Contextful reason, preserved verbatim across the wire.
        reason: String,
    },
    /// A liveness heartbeat: no payload, refreshes the receiver's
    /// last-inbound clock and is otherwise discarded.
    Heartbeat,
}

impl WireMsg {
    /// The frame kind this message travels under.
    pub(crate) fn kind(&self) -> u16 {
        match self {
            WireMsg::Hello { .. } => KIND_HELLO,
            WireMsg::Welcome { .. } => KIND_WELCOME,
            WireMsg::Reject { .. } => KIND_REJECT,
            WireMsg::Req(_) => KIND_REQ,
            WireMsg::Resp(_) => KIND_RESP,
            WireMsg::CollDeposit { .. } => KIND_COLL_DEPOSIT,
            WireMsg::CollResult { .. } => KIND_COLL_RESULT,
            WireMsg::CollAbort { .. } => KIND_COLL_ABORT,
            WireMsg::Heartbeat => KIND_HEARTBEAT,
        }
    }

    /// Encode this message's payload (header excluded) into `w`.
    pub(crate) fn encode<W: Write>(&self, w: &mut W) -> Result<()> {
        match self {
            WireMsg::Hello { rank, world, fingerprint, token } => {
                put_u32(w, *rank)?;
                put_u32(w, *world)?;
                put_u64(w, *fingerprint)?;
                put_str(w, token)?;
            }
            WireMsg::Welcome { p, timeout_ms } => {
                put_u32(w, *p)?;
                put_u32(w, *timeout_ms)?;
            }
            WireMsg::Reject { reason } => put_str(w, reason)?,
            WireMsg::Req(r) => encode_req(r, w)?,
            WireMsg::Resp(r) => encode_resp(r, w)?,
            WireMsg::CollDeposit { op, payload } => {
                put_u8(w, op.to_u8())?;
                put_f32s(w, payload)?;
            }
            WireMsg::CollResult { payload } => put_f32s(w, payload)?,
            WireMsg::CollAbort { rank, reason } => {
                put_u32(w, *rank)?;
                put_str(w, reason)?;
            }
            WireMsg::Heartbeat => {}
        }
        Ok(())
    }

    /// Decode a frame payload given its kind.
    pub(crate) fn decode(kind: u16, payload: &[u8]) -> Result<WireMsg> {
        let mut r = Reader::new(payload);
        let msg = match kind {
            KIND_HELLO => WireMsg::Hello {
                rank: r.u32()?,
                world: r.u32()?,
                fingerprint: r.u64()?,
                token: r.str()?,
            },
            KIND_WELCOME => WireMsg::Welcome { p: r.u32()?, timeout_ms: r.u32()? },
            KIND_REJECT => WireMsg::Reject { reason: r.str()? },
            KIND_REQ => return Ok(WireMsg::Req(decode_req(payload)?)),
            KIND_RESP => return Ok(WireMsg::Resp(decode_resp(payload)?)),
            KIND_COLL_DEPOSIT => {
                let op = CollOp::from_u8(r.u8()?)?;
                WireMsg::CollDeposit { op, payload: r.f32s()? }
            }
            KIND_COLL_RESULT => WireMsg::CollResult { payload: r.f32s()? },
            KIND_COLL_ABORT => WireMsg::CollAbort { rank: r.u32()?, reason: r.str()? },
            KIND_HEARTBEAT => WireMsg::Heartbeat,
            other => bail!("unknown transport frame kind {other}"),
        };
        r.finish()?;
        Ok(msg)
    }
}

// ---------------------------------------------------------------- Req

/// Encode a [`Req`] payload. [`Req::NewComm`] is encoded as the
/// transport-neutral "reset collectives" tag — a remote worker cannot
/// receive an in-process communicator, so both `NewComm` and
/// `ResetComm` decode to [`Req::ResetComm`].
pub(crate) fn encode_req<W: Write>(req: &Req, w: &mut W) -> Result<()> {
    match req {
        Req::SetParams(p) => {
            put_u8(w, 0)?;
            put_u32(w, p.k as u32)?;
            put_f32s(w, &p.flat)?;
        }
        Req::NewComm(_) | Req::ResetComm => put_u8(w, 1)?,
        Req::Install { slot, shard, resident } => {
            put_u8(w, 2)?;
            put_u32(w, *slot as u32)?;
            put_u8(w, u8::from(*resident))?;
            encode_shard(shard, w)?;
        }
        Req::Sync { slot, delta } => {
            put_u8(w, 3)?;
            put_u32(w, *slot as u32)?;
            encode_delta(delta, w)?;
        }
        Req::Rebuild { slot, shard } => {
            put_u8(w, 4)?;
            put_u32(w, *slot as u32)?;
            encode_shard(shard, w)?;
        }
        Req::Forward { slot, f } => {
            put_u8(w, 5)?;
            put_u32(w, *slot as u32)?;
            put_u32(w, f.l as u32)?;
            put_u8(w, u8::from(f.save))?;
            put_u8(w, u8::from(f.skip_zero))?;
            put_f32s(w, &f.s)?;
            put_f32s(w, &f.c)?;
            put_opt_f32s(w, f.deg.as_deref())?;
        }
        Req::Backward { slot, l, onehot, targets } => {
            put_u8(w, 6)?;
            put_u32(w, *slot as u32)?;
            put_u32(w, *l as u32)?;
            put_f32s(w, onehot)?;
            put_f32s(w, targets)?;
        }
        Req::Uninstall { slot } => {
            put_u8(w, 7)?;
            put_u32(w, *slot as u32)?;
        }
        Req::Stats => put_u8(w, 8)?,
        Req::InjectFailure => put_u8(w, 9)?,
        Req::Shutdown => put_u8(w, 10)?,
    }
    Ok(())
}

/// Decode a [`Req`] payload (inverse of [`encode_req`]).
pub(crate) fn decode_req(payload: &[u8]) -> Result<Req> {
    let mut r = Reader::new(payload);
    let req = match r.u8()? {
        0 => {
            let k = r.u32()? as usize;
            let flat = r.f32s()?;
            Req::SetParams(Arc::new(Params { k, flat }))
        }
        1 => Req::ResetComm,
        2 => {
            let slot = r.u32()? as usize;
            let resident = r.u8()? != 0;
            let shard = decode_shard(&mut r)?;
            Req::Install { slot, shard, resident }
        }
        3 => {
            let slot = r.u32()? as usize;
            let delta = decode_delta(&mut r)?;
            Req::Sync { slot, delta }
        }
        4 => {
            let slot = r.u32()? as usize;
            let shard = decode_shard(&mut r)?;
            Req::Rebuild { slot, shard }
        }
        5 => {
            let slot = r.u32()? as usize;
            let l = r.u32()? as usize;
            let save = r.u8()? != 0;
            let skip_zero = r.u8()? != 0;
            let s = r.f32s()?;
            let c = r.f32s()?;
            let deg = r.opt_f32s()?;
            Req::Forward { slot, f: FwdReq { l, save, skip_zero, s, c, deg } }
        }
        6 => {
            let slot = r.u32()? as usize;
            let l = r.u32()? as usize;
            let onehot = Arc::new(r.f32s()?);
            let targets = Arc::new(r.f32s()?);
            Req::Backward { slot, l, onehot, targets }
        }
        7 => Req::Uninstall { slot: r.u32()? as usize },
        8 => Req::Stats,
        9 => Req::InjectFailure,
        10 => Req::Shutdown,
        other => bail!("unknown request tag {other}"),
    };
    r.finish()?;
    Ok(req)
}

fn encode_shard<W: Write>(shard: &RankShard, w: &mut W) -> Result<()> {
    match shard {
        RankShard::Dense(s) => {
            put_u8(w, 0)?;
            encode_part(s.part, w)?;
            put_u32(w, s.shard as u32)?;
            put_u32(w, s.b as u32)?;
            put_f32s(w, &s.a)?;
            put_f32s(w, &s.s)?;
            put_f32s(w, &s.c)?;
        }
        RankShard::Sparse(s) => {
            put_u8(w, 1)?;
            encode_part(s.part, w)?;
            put_u32(w, s.shard as u32)?;
            put_u32(w, s.b as u32)?;
            put_u32(w, s.chunk as u32)?;
            put_u32(w, s.tiles.len() as u32)?;
            for t in &s.tiles {
                put_u32(w, t.sc as u32)?;
                put_u32(w, t.dc as u32)?;
                put_u32(w, t.cap as u32)?;
                put_u32(w, t.len as u32)?;
                put_f32s(w, &t.src)?;
                put_f32s(w, &t.dst)?;
                put_f32s(w, &t.w)?;
            }
            put_f32s(w, &s.s)?;
            put_f32s(w, &s.c)?;
            put_f32s(w, &s.deg)?;
        }
    }
    Ok(())
}

fn decode_shard(r: &mut Reader<'_>) -> Result<RankShard> {
    Ok(match r.u8()? {
        0 => {
            let part = decode_part(r)?;
            let shard = r.u32()? as usize;
            let b = r.u32()? as usize;
            let a = r.f32s()?;
            let s = r.f32s()?;
            let c = r.f32s()?;
            RankShard::Dense(ShardState::from_wire(part, shard, b, a, s, c))
        }
        1 => {
            let part = decode_part(r)?;
            let shard = r.u32()? as usize;
            let b = r.u32()? as usize;
            let chunk = r.u32()? as usize;
            let n_tiles = r.u32()? as usize;
            let mut tiles = Vec::with_capacity(n_tiles);
            for _ in 0..n_tiles {
                let (sc, dc) = (r.u32()? as usize, r.u32()? as usize);
                let (cap, len) = (r.u32()? as usize, r.u32()? as usize);
                let src = r.f32s()?;
                let dst = r.f32s()?;
                let w = r.f32s()?;
                tiles.push(EdgeTile { sc, dc, cap, len, src, dst, w });
            }
            let s = r.f32s()?;
            let c = r.f32s()?;
            let deg = r.f32s()?;
            RankShard::Sparse(SparseShard::from_wire(part, shard, b, chunk, tiles, s, c, deg))
        }
        other => bail!("unknown shard tag {other}"),
    })
}

fn encode_part<W: Write>(part: Partition, w: &mut W) -> Result<()> {
    put_u32(w, part.n as u32)?;
    put_u32(w, part.p as u32)
}

fn decode_part(r: &mut Reader<'_>) -> Result<Partition> {
    let n = r.u32()? as usize;
    let p = r.u32()? as usize;
    if p < 1 || n % p != 0 {
        bail!("invalid partition on the wire: P={p} must divide padded N={n}");
    }
    Ok(Partition::new(n, p))
}

fn encode_delta<W: Write>(delta: &SyncDelta, w: &mut W) -> Result<()> {
    match delta {
        SyncDelta::Dense { rows, cols } => {
            put_u8(w, 0)?;
            put_u32_pairs(w, rows)?;
            put_u32_pairs(w, cols)?;
        }
        SyncDelta::Sparse { tiles } => {
            put_u8(w, 1)?;
            put_u32(w, tiles.len() as u32)?;
            for (idx, mask) in tiles {
                put_u32(w, *idx)?;
                put_f32s(w, mask)?;
            }
        }
    }
    Ok(())
}

fn decode_delta(r: &mut Reader<'_>) -> Result<SyncDelta> {
    Ok(match r.u8()? {
        0 => SyncDelta::Dense { rows: r.u32_pairs()?, cols: r.u32_pairs()? },
        1 => {
            let n = r.u32()? as usize;
            let mut tiles = Vec::with_capacity(n);
            for _ in 0..n {
                let idx = r.u32()?;
                tiles.push((idx, r.f32s()?));
            }
            SyncDelta::Sparse { tiles }
        }
        other => bail!("unknown sync delta tag {other}"),
    })
}

// --------------------------------------------------------------- Resp

/// Encode a [`Resp`] payload.
pub(crate) fn encode_resp<W: Write>(resp: &Resp, w: &mut W) -> Result<()> {
    match resp {
        Resp::Unit { xfer } => {
            put_u8(w, 0)?;
            put_f64(w, *xfer)?;
        }
        Resp::Fwd { scores, timing } => {
            put_u8(w, 1)?;
            put_opt_f32s(w, scores.as_deref())?;
            encode_timing(timing, w)?;
        }
        Resp::Bwd { loss, grads, timing } => {
            put_u8(w, 2)?;
            put_f32(w, *loss)?;
            put_opt_f32s(w, grads.as_deref())?;
            encode_timing(timing, w)?;
        }
        Resp::Stats(s) => {
            put_u8(w, 3)?;
            put_u64(w, s.executions)?;
            put_u64(w, s.compile_time.as_nanos() as u64)?;
            put_u64(w, s.exec_time.as_nanos() as u64)?;
            put_u64(w, s.h2d_time.as_nanos() as u64)?;
            put_u64(w, s.d2h_time.as_nanos() as u64)?;
            put_u64(w, s.h2d_bytes)?;
            put_u64(w, s.d2h_bytes)?;
            put_u64(w, s.cache_hits)?;
            put_u64(w, s.restarts)?;
            put_u64(w, s.recovery_time.as_nanos() as u64)?;
            put_u64(w, s.tx_bytes)?;
            put_u64(w, s.rx_bytes)?;
            put_u64(w, s.remote_restarts)?;
            put_u64(w, s.heartbeats_missed)?;
            put_u64(w, s.rejoin_time.as_nanos() as u64)?;
        }
        Resp::Err(msg) => {
            put_u8(w, 4)?;
            put_str(w, msg)?;
        }
    }
    Ok(())
}

/// Decode a [`Resp`] payload (inverse of [`encode_resp`]).
pub(crate) fn decode_resp(payload: &[u8]) -> Result<Resp> {
    let mut r = Reader::new(payload);
    let resp = match r.u8()? {
        0 => Resp::Unit { xfer: r.f64()? },
        1 => Resp::Fwd { scores: r.opt_f32s()?, timing: decode_timing(&mut r)? },
        2 => Resp::Bwd {
            loss: r.f32()?,
            grads: r.opt_f32s()?,
            timing: decode_timing(&mut r)?,
        },
        3 => Resp::Stats(ExecStats {
            executions: r.u64()?,
            compile_time: Duration::from_nanos(r.u64()?),
            exec_time: Duration::from_nanos(r.u64()?),
            h2d_time: Duration::from_nanos(r.u64()?),
            d2h_time: Duration::from_nanos(r.u64()?),
            h2d_bytes: r.u64()?,
            d2h_bytes: r.u64()?,
            cache_hits: r.u64()?,
            restarts: r.u64()?,
            recovery_time: Duration::from_nanos(r.u64()?),
            tx_bytes: r.u64()?,
            rx_bytes: r.u64()?,
            remote_restarts: r.u64()?,
            heartbeats_missed: r.u64()?,
            rejoin_time: Duration::from_nanos(r.u64()?),
        }),
        4 => Resp::Err(r.str()?),
        other => bail!("unknown response tag {other}"),
    };
    r.finish()?;
    Ok(resp)
}

fn encode_timing<W: Write>(t: &RankTiming, w: &mut W) -> Result<()> {
    put_f64(w, t.compute)?;
    put_f64(w, t.host)?;
    put_f64(w, t.comm)?;
    put_f64(w, t.h2d)?;
    put_u64(w, t.comm_bytes)?;
    put_u64(w, t.collectives)
}

fn decode_timing(r: &mut Reader<'_>) -> Result<RankTiming> {
    Ok(RankTiming {
        compute: r.f64()?,
        host: r.f64()?,
        comm: r.f64()?,
        h2d: r.f64()?,
        comm_bytes: r.u64()?,
        collectives: r.u64()?,
    })
}

// ------------------------------------------------- wire-length probes

/// An `io::Write` that counts bytes and discards them — used to price
/// a message's wire size without serializing it (the `InProc` logical
/// traffic counters).
struct CountWriter(u64);

impl Write for CountWriter {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        self.0 += buf.len() as u64;
        Ok(buf.len())
    }
    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

/// The total frame size (header included) `req` would occupy on the
/// wire. O(1) per buffer — only lengths are accumulated.
pub(crate) fn req_wire_len(req: &Req) -> u64 {
    let mut c = CountWriter(0);
    // Counting cannot fail: CountWriter's Write impl is infallible.
    let _ = encode_req(req, &mut c);
    c.0 + HEADER_LEN as u64
}

/// The total frame size (header included) `resp` would occupy.
pub(crate) fn resp_wire_len(resp: &Resp) -> u64 {
    let mut c = CountWriter(0);
    let _ = encode_resp(resp, &mut c);
    c.0 + HEADER_LEN as u64
}

// ------------------------------------------------------- primitives

fn put_u8<W: Write>(w: &mut W, v: u8) -> Result<()> {
    w.write_all(&[v])?;
    Ok(())
}

fn put_u32<W: Write>(w: &mut W, v: u32) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

fn put_u64<W: Write>(w: &mut W, v: u64) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

fn put_f32<W: Write>(w: &mut W, v: f32) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

fn put_f64<W: Write>(w: &mut W, v: f64) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

fn put_str<W: Write>(w: &mut W, s: &str) -> Result<()> {
    put_u32(w, s.len() as u32)?;
    w.write_all(s.as_bytes())?;
    Ok(())
}

/// Length-prefixed f32 buffer as raw little-endian bytes (one bulk
/// write; f32 has no invalid bit patterns so this is lossless).
fn put_f32s<W: Write>(w: &mut W, v: &[f32]) -> Result<()> {
    put_u32(w, v.len() as u32)?;
    // SAFETY: f32 is 4 bytes with no padding; the slice's backing
    // memory is valid for len*4 bytes for the duration of the call.
    let bytes =
        unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) };
    w.write_all(bytes)?;
    Ok(())
}

fn put_opt_f32s<W: Write>(w: &mut W, v: Option<&[f32]>) -> Result<()> {
    match v {
        None => put_u8(w, 0),
        Some(v) => {
            put_u8(w, 1)?;
            put_f32s(w, v)
        }
    }
}

fn put_u32_pairs<W: Write>(w: &mut W, v: &[(u32, u32)]) -> Result<()> {
    put_u32(w, v.len() as u32)?;
    for &(a, b) in v {
        put_u32(w, a)?;
        put_u32(w, b)?;
    }
    Ok(())
}

/// A bounds-checked slice reader for decoding payloads.
struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.buf.len() - self.pos < n {
            bail!(
                "truncated payload: wanted {n} bytes at offset {}, have {}",
                self.pos,
                self.buf.len() - self.pos
            );
        }
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes(b.try_into().expect("8 bytes")))
    }

    fn f32(&mut self) -> Result<f32> {
        let b = self.take(4)?;
        Ok(f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn f64(&mut self) -> Result<f64> {
        let b = self.take(8)?;
        Ok(f64::from_le_bytes(b.try_into().expect("8 bytes")))
    }

    fn str(&mut self) -> Result<String> {
        let len = self.u32()? as usize;
        let bytes = self.take(len)?;
        Ok(String::from_utf8_lossy(bytes).into_owned())
    }

    fn f32s(&mut self) -> Result<Vec<f32>> {
        let len = self.u32()? as usize;
        let bytes = self.take(len.checked_mul(4).unwrap_or(usize::MAX))?;
        let mut out = vec![0f32; len];
        // SAFETY: `out` owns len*4 writable bytes; `bytes` is exactly
        // len*4 bytes; copy through u8 pointers sidesteps alignment.
        unsafe {
            std::ptr::copy_nonoverlapping(
                bytes.as_ptr(),
                out.as_mut_ptr() as *mut u8,
                len * 4,
            );
        }
        Ok(out)
    }

    fn opt_f32s(&mut self) -> Result<Option<Vec<f32>>> {
        Ok(match self.u8()? {
            0 => None,
            _ => Some(self.f32s()?),
        })
    }

    fn u32_pairs(&mut self) -> Result<Vec<(u32, u32)>> {
        let len = self.u32()? as usize;
        let mut out = Vec::with_capacity(len.min(1 << 20));
        for _ in 0..len {
            out.push((self.u32()?, self.u32()?));
        }
        Ok(out)
    }

    fn finish(&self) -> Result<()> {
        if self.pos != self.buf.len() {
            bail!("trailing bytes in payload: {} of {} consumed", self.pos, self.buf.len());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn round_trip_req(req: &Req) -> Req {
        let mut buf = Vec::new();
        encode_req(req, &mut buf).unwrap();
        assert_eq!(buf.len() as u64 + HEADER_LEN as u64, req_wire_len(req));
        decode_req(&buf).unwrap()
    }

    fn round_trip_resp(resp: &Resp) -> Resp {
        let mut buf = Vec::new();
        encode_resp(resp, &mut buf).unwrap();
        assert_eq!(buf.len() as u64 + HEADER_LEN as u64, resp_wire_len(resp));
        decode_resp(&buf).unwrap()
    }

    #[test]
    fn set_params_round_trips_bitwise() {
        let p = Params { k: 4, flat: vec![0.5, -1.25, f32::MIN_POSITIVE, 3.75] };
        match round_trip_req(&Req::SetParams(Arc::new(p.clone()))) {
            Req::SetParams(got) => {
                assert_eq!(got.k, p.k);
                let a: Vec<u32> = got.flat.iter().map(|v| v.to_bits()).collect();
                let b: Vec<u32> = p.flat.iter().map(|v| v.to_bits()).collect();
                assert_eq!(a, b);
            }
            other => panic!("wrong variant: {other:?}"),
        }
    }

    #[test]
    fn forward_round_trips() {
        let req = Req::Forward {
            slot: 2,
            f: FwdReq {
                l: 3,
                save: true,
                skip_zero: false,
                s: vec![1.0, 0.0],
                c: vec![0.0, 1.0],
                deg: Some(vec![2.0, 0.0]),
            },
        };
        match round_trip_req(&req) {
            Req::Forward { slot, f } => {
                assert_eq!(slot, 2);
                assert_eq!((f.l, f.save, f.skip_zero), (3, true, false));
                assert_eq!(f.s, vec![1.0, 0.0]);
                assert_eq!(f.deg, Some(vec![2.0, 0.0]));
            }
            other => panic!("wrong variant: {other:?}"),
        }
    }

    #[test]
    fn sync_deltas_round_trip() {
        let dense = Req::Sync {
            slot: 0,
            delta: SyncDelta::Dense { rows: vec![(0, 3), (1, 7)], cols: vec![(0, 12)] },
        };
        match round_trip_req(&dense) {
            Req::Sync { delta: SyncDelta::Dense { rows, cols }, .. } => {
                assert_eq!(rows, vec![(0, 3), (1, 7)]);
                assert_eq!(cols, vec![(0, 12)]);
            }
            other => panic!("wrong variant: {other:?}"),
        }
        let sparse = Req::Sync {
            slot: 1,
            delta: SyncDelta::Sparse { tiles: vec![(4, vec![1.0, 0.0, 1.0])] },
        };
        match round_trip_req(&sparse) {
            Req::Sync { delta: SyncDelta::Sparse { tiles }, .. } => {
                assert_eq!(tiles.len(), 1);
                assert_eq!(tiles[0].0, 4);
                assert_eq!(tiles[0].1, vec![1.0, 0.0, 1.0]);
            }
            other => panic!("wrong variant: {other:?}"),
        }
    }

    #[test]
    fn new_comm_decodes_as_reset() {
        // NewComm carries an in-process handle that cannot cross the
        // wire; the canonical encoding is the reset tag.
        match round_trip_req(&Req::ResetComm) {
            Req::ResetComm => {}
            other => panic!("wrong variant: {other:?}"),
        }
    }

    #[test]
    fn responses_round_trip() {
        match round_trip_resp(&Resp::Unit { xfer: 1.5 }) {
            Resp::Unit { xfer } => assert_eq!(xfer, 1.5),
            other => panic!("wrong variant: {other:?}"),
        }
        let timing = RankTiming {
            compute: 0.25,
            host: 0.5,
            comm: 0.125,
            h2d: 0.0,
            comm_bytes: 640,
            collectives: 7,
        };
        match round_trip_resp(&Resp::Fwd { scores: Some(vec![0.5, -0.5]), timing }) {
            Resp::Fwd { scores, timing: t } => {
                assert_eq!(scores, Some(vec![0.5, -0.5]));
                assert_eq!((t.comm_bytes, t.collectives), (640, 7));
            }
            other => panic!("wrong variant: {other:?}"),
        }
        match round_trip_resp(&Resp::Err("boom".into())) {
            Resp::Err(m) => assert_eq!(m, "boom"),
            other => panic!("wrong variant: {other:?}"),
        }
    }

    #[test]
    fn stats_round_trip_includes_traffic_counters() {
        let mut s = ExecStats::default();
        s.executions = 9;
        s.exec_time = Duration::from_millis(12);
        s.tx_bytes = 1024;
        s.rx_bytes = 2048;
        s.remote_restarts = 3;
        s.heartbeats_missed = 2;
        s.rejoin_time = Duration::from_millis(75);
        match round_trip_resp(&Resp::Stats(s)) {
            Resp::Stats(got) => {
                assert_eq!(got.executions, 9);
                assert_eq!(got.exec_time, Duration::from_millis(12));
                assert_eq!((got.tx_bytes, got.rx_bytes), (1024, 2048));
                assert_eq!((got.remote_restarts, got.heartbeats_missed), (3, 2));
                assert_eq!(got.rejoin_time, Duration::from_millis(75));
            }
            other => panic!("wrong variant: {other:?}"),
        }
    }

    #[test]
    fn handshake_messages_round_trip() {
        let msgs = [
            WireMsg::Hello {
                rank: 1,
                world: 2,
                fingerprint: 0xdead_beef,
                token: "sekrit".into(),
            },
            WireMsg::Hello { rank: 0, world: 0, fingerprint: 7, token: String::new() },
            WireMsg::Welcome { p: 4, timeout_ms: 30_000 },
            WireMsg::Reject { reason: "fingerprint mismatch".into() },
            WireMsg::CollDeposit { op: CollOp::AllReduce, payload: vec![1.0, 2.0] },
            WireMsg::CollResult { payload: vec![3.0] },
            WireMsg::CollAbort { rank: 2, reason: "injected".into() },
            WireMsg::Heartbeat,
        ];
        for msg in msgs {
            let mut buf = Vec::new();
            msg.encode(&mut buf).unwrap();
            let got = WireMsg::decode(msg.kind(), &buf).unwrap();
            assert_eq!(format!("{msg:?}"), format!("{got:?}"));
        }
    }

    #[test]
    fn truncated_and_trailing_payloads_are_rejected() {
        let mut buf = Vec::new();
        encode_req(&Req::Uninstall { slot: 3 }, &mut buf).unwrap();
        let err = decode_req(&buf[..buf.len() - 1]).unwrap_err().to_string();
        assert!(err.contains("truncated payload"), "{err}");
        buf.push(0);
        let err = decode_req(&buf).unwrap_err().to_string();
        assert!(err.contains("trailing bytes"), "{err}");
        let err = decode_req(&[250]).unwrap_err().to_string();
        assert!(err.contains("unknown request tag"), "{err}");
    }
}
