//! Mini property-testing helper (proptest is unavailable offline).
//!
//! `check` runs a property over `cases` randomly generated inputs; on
//! failure it reports the failing case index and seed so the case can be
//! replayed deterministically (no shrinking — cases are kept small instead).

use super::rng::Pcg32;

/// Run `prop` over `cases` inputs produced by `gen`. Panics with the seed of
/// the first failing case.
pub fn check<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    mut gen: impl FnMut(&mut Pcg32) -> T,
    mut prop: impl FnMut(&T) -> bool,
) {
    let base = 0x0661_u64;
    for case in 0..cases {
        let seed = base.wrapping_add((case as u64).wrapping_mul(0x9E3779B97F4A7C15));
        let mut rng = Pcg32::seeded(seed);
        let input = gen(&mut rng);
        if !prop(&input) {
            panic!("property '{name}' failed at case {case} (seed {seed:#x}): {input:?}");
        }
    }
}

/// Like `check` but the property returns Result with a message.
pub fn check_msg<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    mut gen: impl FnMut(&mut Pcg32) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let base = 0x0662_u64;
    for case in 0..cases {
        let seed = base.wrapping_add((case as u64).wrapping_mul(0x9E3779B97F4A7C15));
        let mut rng = Pcg32::seeded(seed);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!("property '{name}' failed at case {case} (seed {seed:#x}): {msg}\ninput: {input:?}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_true_property() {
        check("sum-commutes", 50, |r| (r.gen_range(100), r.gen_range(100)), |&(a, b)| {
            a + b == b + a
        });
    }

    #[test]
    #[should_panic(expected = "always-false")]
    fn fails_false_property() {
        check("always-false", 5, |r| r.gen_range(10), |_| false);
    }
}
