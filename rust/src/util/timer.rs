//! Timing helpers for the bench harness (criterion is unavailable offline;
//! benches are plain binaries that print paper-table rows).

use std::time::{Duration, Instant};

/// A simple scoped stopwatch.
pub struct Stopwatch(Instant);

impl Stopwatch {
    /// Start timing now.
    pub fn start() -> Self {
        Stopwatch(Instant::now())
    }
    /// Elapsed time since start/restart.
    pub fn elapsed(&self) -> Duration {
        self.0.elapsed()
    }
    /// Elapsed seconds since start/restart.
    pub fn elapsed_s(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
    /// Return the elapsed time and restart the clock.
    pub fn restart(&mut self) -> Duration {
        let e = self.0.elapsed();
        self.0 = Instant::now();
        e
    }
}

/// Summary statistics over repeated measurements.
#[derive(Debug, Clone, Default)]
pub struct Stats {
    /// Raw samples in insertion order (seconds).
    pub samples: Vec<f64>,
}

impl Stats {
    /// Record one measurement.
    pub fn push(&mut self, secs: f64) {
        self.samples.push(secs);
    }
    /// Number of samples.
    pub fn n(&self) -> usize {
        self.samples.len()
    }
    /// Arithmetic mean (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }
    /// Minimum sample.
    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }
    /// Maximum sample.
    pub fn max(&self) -> f64 {
        self.samples.iter().copied().fold(0.0, f64::max)
    }
    /// Sample standard deviation (0 below two samples).
    pub fn stddev(&self) -> f64 {
        if self.samples.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        let v = self.samples.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
            / (self.samples.len() - 1) as f64;
        v.sqrt()
    }
    /// Median sample (0 when empty).
    pub fn median(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mid = s.len() / 2;
        if s.len() % 2 == 0 { (s[mid - 1] + s[mid]) / 2.0 } else { s[mid] }
    }
}

/// Time a closure `iters` times after `warmup` runs; returns per-iter stats.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut stats = Stats::default();
    for _ in 0..iters {
        let t = Instant::now();
        f();
        stats.push(t.elapsed().as_secs_f64());
    }
    stats
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basics() {
        let mut s = Stats::default();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.push(x);
        }
        assert_eq!(s.mean(), 2.5);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
        assert_eq!(s.median(), 2.5);
        assert!((s.stddev() - 1.2909944).abs() < 1e-5);
    }

    #[test]
    fn bench_counts_iters() {
        let mut n = 0;
        let st = bench(2, 5, || n += 1);
        assert_eq!(n, 7);
        assert_eq!(st.n(), 5);
    }
}
