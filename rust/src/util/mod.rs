//! Small self-contained utilities (the environment is fully offline, so
//! rand/serde/criterion equivalents are hand-rolled here; see DESIGN.md §3).

/// PCG32 random number generator (rand stand-in).
pub mod rng;
/// Stopwatches and summary statistics for the benches.
pub mod timer;
/// Binary f32-tensor container (`.oggm` files).
pub mod binio;
/// Minimal JSON writer (serde stand-in).
pub mod json;
/// Tiny property-test harness.
pub mod prop;
/// Hand-rolled CLI argument parsing (clap stand-in).
pub mod cli;

/// Element-wise `acc += src` over f32 slices, processed in fixed-width
/// chunks so the compiler autovectorizes (the scalar `iter_mut().zip()`
/// form defeated SIMD on the B·K·N all-reduce accumulation hot path).
pub fn add_assign(acc: &mut [f32], src: &[f32]) {
    assert_eq!(acc.len(), src.len(), "add_assign length mismatch");
    const W: usize = 8;
    let mut a = acc.chunks_exact_mut(W);
    let mut s = src.chunks_exact(W);
    for (ca, cs) in (&mut a).zip(&mut s) {
        for i in 0..W {
            ca[i] += cs[i];
        }
    }
    for (x, y) in a.into_remainder().iter_mut().zip(s.remainder()) {
        *x += y;
    }
}

/// Maximum absolute difference between two slices (for fp parity checks).
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len(), "length mismatch: {} vs {}", a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

/// Relative L2 error ||a-b|| / max(||b||, eps).
pub fn rel_l2(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    let num: f32 = a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum();
    let den: f32 = b.iter().map(|y| y * y).sum();
    (num.sqrt()) / den.sqrt().max(1e-12)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn diff_helpers() {
        assert_eq!(max_abs_diff(&[1.0, 2.0], &[1.0, 2.5]), 0.5);
        assert!(rel_l2(&[1.0, 0.0], &[1.0, 0.0]) < 1e-9);
    }

    #[test]
    fn add_assign_matches_scalar_at_all_remainders() {
        // Cover lengths around the chunk width, including 0 and non-multiples.
        for len in [0usize, 1, 7, 8, 9, 16, 23, 64] {
            let mut acc: Vec<f32> = (0..len).map(|i| i as f32 * 0.5).collect();
            let src: Vec<f32> = (0..len).map(|i| (i * i) as f32 * 0.25).collect();
            let want: Vec<f32> = acc.iter().zip(&src).map(|(a, s)| a + s).collect();
            add_assign(&mut acc, &src);
            assert_eq!(acc, want, "len={len}");
        }
    }
}
