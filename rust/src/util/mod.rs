//! Small self-contained utilities (the environment is fully offline, so
//! rand/serde/criterion equivalents are hand-rolled here; see DESIGN.md §3).

pub mod rng;
pub mod timer;
pub mod binio;
pub mod json;
pub mod prop;
pub mod cli;

/// Maximum absolute difference between two slices (for fp parity checks).
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len(), "length mismatch: {} vs {}", a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

/// Relative L2 error ||a-b|| / max(||b||, eps).
pub fn rel_l2(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    let num: f32 = a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum();
    let den: f32 = b.iter().map(|y| y * y).sum();
    (num.sqrt()) / den.sqrt().max(1e-12)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn diff_helpers() {
        assert_eq!(max_abs_diff(&[1.0, 2.0], &[1.0, 2.5]), 0.5);
        assert!(rel_l2(&[1.0, 0.0], &[1.0, 0.0]) < 1e-9);
    }
}
