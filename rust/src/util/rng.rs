//! PCG32 pseudo-random generator (O'Neill 2014, pcg32_xsh_rr variant).
//!
//! The paper synchronizes exploration across processes by sharing one seed
//! (Alg. 5 input `SEED`); every stochastic choice in this repo (graph
//! generation, ε-greedy exploration, replay sampling) goes through this
//! deterministic generator so the lockstep engine is bit-reproducible.

/// A 32-bit output permuted-congruential generator.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    /// Create a generator from a seed and a stream id (distinct streams are
    /// statistically independent).
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Seed-only constructor (stream 0).
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// Next 32 random bits (the PCG-XSH-RR output function).
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next 64 random bits.
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform f32 in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform f64 in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, bound) with Lemire rejection (unbiased).
    pub fn gen_range(&mut self, bound: usize) -> usize {
        assert!(bound > 0, "gen_range bound must be positive");
        let bound = bound as u32;
        let threshold = bound.wrapping_neg() % bound;
        loop {
            let r = self.next_u32();
            let m = (r as u64) * (bound as u64);
            if (m as u32) >= threshold {
                return (m >> 32) as usize;
            }
        }
    }

    /// Standard normal via Box-Muller (used for parameter init).
    pub fn next_normal(&mut self) -> f32 {
        let u1 = self.next_f64().max(1e-12);
        let u2 = self.next_f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (Floyd's algorithm).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.gen_range(j + 1);
            let pick = if chosen.contains(&t) { j } else { t };
            chosen.insert(pick);
            out.push(pick);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg32::seeded(42);
        let mut b = Pcg32::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg32::new(42, 1);
        let mut b = Pcg32::new(42, 2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Pcg32::seeded(7);
        for _ in 0..1000 {
            let x = r.next_f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn gen_range_bounds_and_coverage() {
        let mut r = Pcg32::seeded(9);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.gen_range(10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Pcg32::seeded(3);
        let s = r.sample_indices(50, 20);
        let set: std::collections::HashSet<_> = s.iter().collect();
        assert_eq!(set.len(), 20);
        assert!(s.iter().all(|&i| i < 50));
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::seeded(11);
        let n = 20000;
        let xs: Vec<f32> = (0..n).map(|_| r.next_normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.08, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::seeded(5);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
