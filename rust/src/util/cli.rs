//! Hand-rolled `--key value` / `--flag` argument parser (clap is unavailable
//! offline). Used by the `oggm` binary, the examples, and the benches.

use std::collections::HashMap;

/// Parsed command-line arguments: positionals plus `--key value` options.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: HashMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an explicit iterator (tests) or `std::env::args` (main).
    pub fn parse<I: IntoIterator<Item = String>>(it: I) -> Args {
        let mut args = Args::default();
        let mut iter = it.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(key) = a.strip_prefix("--") {
                // `--key=value`, `--key value`, or bare `--flag`.
                if let Some((k, v)) = key.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if iter.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = iter.next().unwrap();
                    args.options.insert(key.to_string(), v);
                } else {
                    args.flags.push(key.to_string());
                }
            } else {
                args.positional.push(a);
            }
        }
        args
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).map(|v| v.parse().expect("integer option")).unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).map(|v| v.parse().expect("integer option")).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).map(|v| v.parse().expect("float option")).unwrap_or(default)
    }

    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    /// Parse a comma-separated list of usize (e.g. `--p 1,2,3,4,6`).
    pub fn get_usize_list(&self, key: &str, default: &[usize]) -> Vec<usize> {
        match self.get(key) {
            Some(v) => v.split(',').map(|s| s.trim().parse().expect("int list")).collect(),
            None => default.to_vec(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn parses_mixed() {
        let a = parse("train --n 24 --p=3 --verbose --seed 7");
        assert_eq!(a.positional, vec!["train"]);
        assert_eq!(a.get_usize("n", 0), 24);
        assert_eq!(a.get_usize("p", 0), 3);
        assert_eq!(a.get_u64("seed", 0), 7);
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn lists_and_defaults() {
        let a = parse("--p 1,2,6");
        assert_eq!(a.get_usize_list("p", &[1]), vec![1, 2, 6]);
        assert_eq!(a.get_usize_list("q", &[4]), vec![4]);
        assert_eq!(a.get_or("mode", "sim"), "sim");
    }
}
