//! Hand-rolled `--key value` / `--flag` argument parser (clap is unavailable
//! offline). Used by the `oggm` binary, the examples, and the benches.

use std::collections::HashMap;

/// Parsed command-line arguments: positionals plus `--key value` options.
#[derive(Debug, Default, Clone)]
pub struct Args {
    /// Positional arguments in order.
    pub positional: Vec<String>,
    /// `--key value` options.
    pub options: HashMap<String, String>,
    /// Bare `--flag` switches.
    pub flags: Vec<String>,
}

/// Whether a token introduces an option (`--key` / `--flag`), as opposed to
/// being a value or positional. Single-dash numerics (`-3.5`, `-1,-2`) are
/// ordinary values; a double-dash token continuing with a digit, dot, or
/// further dash (`--3.5`, `---`) is never treated as an option *name* — it
/// passes through verbatim as a value/positional (callers parsing it
/// numerically will still reject the literal dashes).
fn is_option_token(tok: &str) -> bool {
    match tok.strip_prefix("--") {
        Some(rest) => {
            !matches!(rest.chars().next(), Some(c) if c.is_ascii_digit() || c == '.' || c == '-')
        }
        None => false,
    }
}

impl Args {
    /// Parse from an explicit iterator (tests) or `std::env::args` (main).
    pub fn parse<I: IntoIterator<Item = String>>(it: I) -> Args {
        let mut args = Args::default();
        let mut iter = it.into_iter().peekable();
        while let Some(a) = iter.next() {
            if is_option_token(&a) {
                let key = a.strip_prefix("--").unwrap();
                // `--key=value`, `--key value`, or bare `--flag`.
                if let Some((k, v)) = key.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if iter.peek().map(|n| !is_option_token(n)).unwrap_or(false) {
                    let v = iter.next().unwrap();
                    args.options.insert(key.to_string(), v);
                } else {
                    args.flags.push(key.to_string());
                }
            } else {
                args.positional.push(a);
            }
        }
        args
    }

    /// Parse the process arguments (skipping argv[0]).
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// Option value for `--key`, if given.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    /// Option value with a default.
    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Integer option with a default.
    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).map(|v| v.parse().expect("integer option")).unwrap_or(default)
    }

    /// u64 option with a default.
    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).map(|v| v.parse().expect("integer option")).unwrap_or(default)
    }

    /// Float option with a default.
    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).map(|v| v.parse().expect("float option")).unwrap_or(default)
    }

    /// Whether the bare flag `--key` was passed.
    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    /// Parse a comma-separated list of usize (e.g. `--p 1,2,3,4,6`).
    pub fn get_usize_list(&self, key: &str, default: &[usize]) -> Vec<usize> {
        match self.get(key) {
            Some(v) => v.split(',').map(|s| s.trim().parse().expect("int list")).collect(),
            None => default.to_vec(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn parses_mixed() {
        let a = parse("train --n 24 --p=3 --verbose --seed 7");
        assert_eq!(a.positional, vec!["train"]);
        assert_eq!(a.get_usize("n", 0), 24);
        assert_eq!(a.get_usize("p", 0), 3);
        assert_eq!(a.get_u64("seed", 0), 7);
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn lists_and_defaults() {
        let a = parse("--p 1,2,6");
        assert_eq!(a.get_usize_list("p", &[1]), vec![1, 2, 6]);
        assert_eq!(a.get_usize_list("q", &[4]), vec![4]);
        assert_eq!(a.get_or("mode", "sim"), "sim");
    }

    #[test]
    fn negative_number_values() {
        // `--key value` must accept negative numbers in both spellings.
        let a = parse("--offset -3.5 --bias=-2 --temps -1,-2,3 --lr 1e-3");
        assert_eq!(a.get_f64("offset", 0.0), -3.5);
        assert_eq!(a.get_f64("bias", 0.0), -2.0);
        assert_eq!(a.get("temps"), Some("-1,-2,3"));
        assert_eq!(a.get_f64("lr", 0.0), 1e-3);
        assert!(a.flags.is_empty(), "negative values misread as flags: {:?}", a.flags);
    }

    #[test]
    fn negative_value_after_flag_and_option_boundaries() {
        // A flag followed by an option stays a flag; a flag followed by a
        // negative number swallows it as the value (grammar is untyped).
        let a = parse("solve --multi --budget -0.5 --verbose");
        assert_eq!(a.positional, vec!["solve"]);
        assert!(a.has_flag("multi"));
        assert!(a.has_flag("verbose"));
        assert_eq!(a.get_f64("budget", 0.0), -0.5);
    }

    #[test]
    fn option_token_classification() {
        assert!(is_option_token("--key"));
        assert!(is_option_token("--k"));
        assert!(!is_option_token("-3.5"));
        // Double-dash numerics never become option *names*; as values they
        // pass through verbatim (numeric parsing rejects them downstream).
        assert!(!is_option_token("--3.5"));
        assert!(!is_option_token("--.5"));
        assert!(!is_option_token("---"));
        assert!(!is_option_token("positional"));
        assert!(!is_option_token("-x"));
        let a = parse("--offset --3.5");
        assert_eq!(a.get("offset"), Some("--3.5"));
    }

    #[test]
    fn trailing_option_with_negative_value() {
        let a = parse("--delta -1");
        assert_eq!(a.get_f64("delta", 0.0), -1.0);
        let b = parse("--delta");
        assert!(b.has_flag("delta"));
    }
}
