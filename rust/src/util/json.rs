//! Minimal JSON writer *and reader* (serde is unavailable offline). The
//! writer covers metrics/bench output; the reader ([`Json::parse`]) covers
//! the net front door's JSONL request lines (`rust/src/net/proto.rs`) —
//! full JSON (nested objects/arrays, escapes, numbers), recursion-capped.

use anyhow::{bail, Result};
use std::fmt::Write as _;

/// A JSON value builder.
#[derive(Debug, Clone)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Number (integral values print without a decimal point).
    Num(f64),
    /// Escaped string.
    Str(String),
    /// Array of values.
    Arr(Vec<Json>),
    /// Object as insertion-ordered key/value pairs.
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Empty JSON object builder.
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    /// Append a key/value pair (panics on non-objects).
    pub fn set(mut self, key: &str, val: impl Into<Json>) -> Json {
        if let Json::Obj(ref mut kvs) = self {
            kvs.push((key.to_string(), val.into()));
        } else {
            panic!("set() on non-object");
        }
        self
    }

    /// Serialize to a compact JSON string.
    pub fn render(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    // Integral values print without decimal point.
                    if x.fract() == 0.0 && x.abs() < 1e15 {
                        let _ = write!(out, "{}", *x as i64);
                    } else {
                        let _ = write!(out, "{x}");
                    }
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(kvs) => {
                out.push('{');
                for (i, (k, v)) in kvs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl Json {
    /// Parse one JSON value from `text` (the whole string must be the
    /// value, modulo surrounding whitespace). Errors carry a byte offset.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value(0)?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing characters at byte {}", p.pos);
        }
        Ok(v)
    }

    /// Object field lookup (None for non-objects or missing keys; the
    /// first occurrence wins, matching how `set` appends).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kvs) => kvs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// String payload of a `Json::Str`.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Numeric payload of a `Json::Num`.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Non-negative integral payload of a `Json::Num` (rejects fractions,
    /// negatives, and magnitudes past exact f64 integer range).
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(x) if x.fract() == 0.0 && *x >= 0.0 && *x < 9.0e15 => Some(*x as u64),
            _ => None,
        }
    }

    /// Boolean payload of a `Json::Bool`.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The keys of an object, in document order (empty for non-objects).
    pub fn keys(&self) -> Vec<&str> {
        match self {
            Json::Obj(kvs) => kvs.iter().map(|(k, _)| k.as_str()).collect(),
            _ => Vec::new(),
        }
    }
}

/// Nesting bound for the reader: request lines are flat-ish; anything
/// deeper than this is hostile or garbage, not a job.
const MAX_DEPTH: usize = 32;

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if matches!(b, b' ' | b'\t' | b'\n' | b'\r') {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn eat(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            bail!("expected '{}' at byte {}", b as char, self.pos)
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn value(&mut self, depth: usize) -> Result<Json> {
        if depth > MAX_DEPTH {
            bail!("nesting deeper than {MAX_DEPTH} at byte {}", self.pos);
        }
        match self.peek() {
            Some(b'{') => self.object(depth),
            Some(b'[') => self.array(depth),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(b'-' | b'0'..=b'9') => self.number(),
            Some(c) => bail!("unexpected '{}' at byte {}", c as char, self.pos),
            None => bail!("unexpected end of input"),
        }
    }

    fn object(&mut self, depth: usize) -> Result<Json> {
        self.eat(b'{')?;
        let mut kvs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(kvs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value(depth + 1)?;
            kvs.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(kvs));
                }
                _ => bail!("expected ',' or '}}' at byte {}", self.pos),
            }
        }
    }

    fn array(&mut self, depth: usize) -> Result<Json> {
        self.eat(b'[')?;
        let mut xs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(xs));
        }
        loop {
            self.skip_ws();
            xs.push(self.value(depth + 1)?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(xs));
                }
                _ => bail!("expected ',' or ']' at byte {}", self.pos),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or_else(|| anyhow::anyhow!("short \\u escape"))?;
                            let code = u32::from_str_radix(std::str::from_utf8(hex)?, 16)?;
                            // Surrogates are not paired — request ids are
                            // ASCII-ish; map them to the replacement char.
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => bail!("bad escape at byte {}", self.pos),
                    }
                    self.pos += 1;
                }
                Some(b) if b < 0x20 => bail!("control character in string at byte {}", self.pos),
                Some(_) => {
                    // Multi-byte UTF-8 passes through verbatim.
                    let start = self.pos;
                    self.pos += 1;
                    while self.pos < self.bytes.len() && self.bytes[self.pos] & 0xC0 == 0x80 {
                        self.pos += 1;
                    }
                    s.push_str(std::str::from_utf8(&self.bytes[start..self.pos])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        let x: f64 = text.parse().map_err(|_| anyhow::anyhow!("bad number '{text}'"))?;
        Ok(Json::Num(x))
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<f32> for Json {
    fn from(x: f32) -> Json {
        Json::Num(x as f64)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl<T: Into<Json> + Clone> From<&[T]> for Json {
    fn from(xs: &[T]) -> Json {
        Json::Arr(xs.iter().map(|x| x.clone().into()).collect())
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(xs: Vec<T>) -> Json {
        Json::Arr(xs.into_iter().map(|x| x.into()).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_nested() {
        let j = Json::obj()
            .set("name", "fig9")
            .set("p", 6usize)
            .set("times", vec![1.5f64, 2.0])
            .set("ok", true);
        assert_eq!(j.render(), r#"{"name":"fig9","p":6,"times":[1.5,2],"ok":true}"#);
    }

    #[test]
    fn escapes_strings() {
        let j = Json::Str("a\"b\n\\".into());
        assert_eq!(j.render(), r#""a\"b\n\\""#);
    }

    #[test]
    fn parse_roundtrips_writer_output() {
        let j = Json::obj()
            .set("name", "fig9")
            .set("p", 6usize)
            .set("times", vec![1.5f64, 2.0, -3.25e2])
            .set("ok", true)
            .set("none", Json::Null)
            .set("weird", "a\"b\n\\ü");
        let back = Json::parse(&j.render()).unwrap();
        assert_eq!(back.render(), j.render());
        assert_eq!(back.get("name").unwrap().as_str(), Some("fig9"));
        assert_eq!(back.get("p").unwrap().as_u64(), Some(6));
        assert_eq!(back.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(back.get("weird").unwrap().as_str(), Some("a\"b\n\\ü"));
        assert_eq!(back.keys(), vec!["name", "p", "times", "ok", "none", "weird"]);
    }

    #[test]
    fn parse_accepts_request_shapes() {
        let j = Json::parse(
            r#" {"id":"a1","scenario":"mvc","gen":"er","n":20,"seed":7,"max_latency_ms":250} "#,
        )
        .unwrap();
        assert_eq!(j.get("n").unwrap().as_u64(), Some(20));
        assert_eq!(j.get("max_latency_ms").unwrap().as_u64(), Some(250));
        assert!(j.get("missing").is_none());
        // \u escapes and nested containers.
        let j = Json::parse(r#"{"a":[{"b":"A"}],"c":{}}"#).unwrap();
        assert_eq!(j.get("a").unwrap().render(), r#"[{"b":"A"}]"#);
    }

    #[test]
    fn parse_rejects_garbage() {
        for bad in [
            "", "{", "[1,", "{\"a\":}", "{'a':1}", "{\"a\":1} x", "nulll", "--1", "1.2.3",
            "\"unterminated", "{\"a\" 1}",
        ] {
            assert!(Json::parse(bad).is_err(), "accepted {bad:?}");
        }
        // Fractional / negative / huge numbers are not u64s.
        assert_eq!(Json::parse("1.5").unwrap().as_u64(), None);
        assert_eq!(Json::parse("-3").unwrap().as_u64(), None);
        assert_eq!(Json::parse("7").unwrap().as_u64(), Some(7));
        // Depth cap trips instead of blowing the stack.
        let deep = format!("{}1{}", "[".repeat(200), "]".repeat(200));
        assert!(Json::parse(&deep).is_err());
    }
}
