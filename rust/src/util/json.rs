//! Minimal JSON *writer* for metrics/bench output (serde is unavailable
//! offline). Only what the harness needs: objects, arrays, strings, numbers.

use std::fmt::Write as _;

/// A JSON value builder.
#[derive(Debug, Clone)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Number (integral values print without a decimal point).
    Num(f64),
    /// Escaped string.
    Str(String),
    /// Array of values.
    Arr(Vec<Json>),
    /// Object as insertion-ordered key/value pairs.
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Empty JSON object builder.
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    /// Append a key/value pair (panics on non-objects).
    pub fn set(mut self, key: &str, val: impl Into<Json>) -> Json {
        if let Json::Obj(ref mut kvs) = self {
            kvs.push((key.to_string(), val.into()));
        } else {
            panic!("set() on non-object");
        }
        self
    }

    /// Serialize to a compact JSON string.
    pub fn render(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    // Integral values print without decimal point.
                    if x.fract() == 0.0 && x.abs() < 1e15 {
                        let _ = write!(out, "{}", *x as i64);
                    } else {
                        let _ = write!(out, "{x}");
                    }
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(kvs) => {
                out.push('{');
                for (i, (k, v)) in kvs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<f32> for Json {
    fn from(x: f32) -> Json {
        Json::Num(x as f64)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl<T: Into<Json> + Clone> From<&[T]> for Json {
    fn from(xs: &[T]) -> Json {
        Json::Arr(xs.iter().map(|x| x.clone().into()).collect())
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(xs: Vec<T>) -> Json {
        Json::Arr(xs.into_iter().map(|x| x.into()).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_nested() {
        let j = Json::obj()
            .set("name", "fig9")
            .set("p", 6usize)
            .set("times", vec![1.5f64, 2.0])
            .set("ok", true);
        assert_eq!(j.render(), r#"{"name":"fig9","p":6,"times":[1.5,2],"ok":true}"#);
    }

    #[test]
    fn escapes_strings() {
        let j = Json::Str("a\"b\n\\".into());
        assert_eq!(j.render(), r#""a\"b\n\\""#);
    }
}
