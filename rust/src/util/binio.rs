//! Tiny binary tensor container ("OGGM" format) used for model checkpoints
//! and for golden test vectors exchanged with the python build step.
//!
//! Layout (little endian):
//!   magic  b"OGGM"            4 bytes
//!   version u32               (currently 1)
//!   count  u32                number of named tensors
//!   per tensor:
//!     name_len u32, name bytes (utf-8)
//!     ndim u32, dims u32 × ndim
//!     f32 data (prod(dims) elements)

use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"OGGM";
const VERSION: u32 = 1;

/// A named f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    /// Tensor name (lookup key).
    pub name: String,
    /// Row-major shape.
    pub dims: Vec<usize>,
    /// Row-major f32 data.
    pub data: Vec<f32>,
}

impl Tensor {
    /// Build a named tensor; dims must match the data length.
    pub fn new(name: impl Into<String>, dims: Vec<usize>, data: Vec<f32>) -> Self {
        let t = Tensor { name: name.into(), dims, data };
        assert_eq!(t.dims.iter().product::<usize>(), t.data.len(), "dims/data mismatch");
        t
    }
}

fn write_u32<W: Write>(w: &mut W, x: u32) -> Result<()> {
    w.write_all(&x.to_le_bytes())?;
    Ok(())
}

fn read_u32<R: Read>(r: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

/// Write tensors to `path`.
pub fn save(path: impl AsRef<Path>, tensors: &[Tensor]) -> Result<()> {
    let mut w = std::io::BufWriter::new(
        std::fs::File::create(path.as_ref())
            .with_context(|| format!("create {}", path.as_ref().display()))?,
    );
    w.write_all(MAGIC)?;
    write_u32(&mut w, VERSION)?;
    write_u32(&mut w, tensors.len() as u32)?;
    for t in tensors {
        write_u32(&mut w, t.name.len() as u32)?;
        w.write_all(t.name.as_bytes())?;
        write_u32(&mut w, t.dims.len() as u32)?;
        for &d in &t.dims {
            write_u32(&mut w, d as u32)?;
        }
        // Bulk-write the f32 payload.
        let bytes: &[u8] = unsafe {
            std::slice::from_raw_parts(t.data.as_ptr() as *const u8, t.data.len() * 4)
        };
        w.write_all(bytes)?;
    }
    Ok(())
}

/// Read all tensors from `path`.
pub fn load(path: impl AsRef<Path>) -> Result<Vec<Tensor>> {
    let mut r = std::io::BufReader::new(
        std::fs::File::open(path.as_ref())
            .with_context(|| format!("open {}", path.as_ref().display()))?,
    );
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("bad magic in {}", path.as_ref().display());
    }
    let version = read_u32(&mut r)?;
    if version != VERSION {
        bail!("unsupported OGGM version {version}");
    }
    let count = read_u32(&mut r)? as usize;
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        let name_len = read_u32(&mut r)? as usize;
        let mut name = vec![0u8; name_len];
        r.read_exact(&mut name)?;
        let ndim = read_u32(&mut r)? as usize;
        let mut dims = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            dims.push(read_u32(&mut r)? as usize);
        }
        let n: usize = dims.iter().product();
        let mut bytes = vec![0u8; n * 4];
        r.read_exact(&mut bytes)?;
        let data: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        out.push(Tensor { name: String::from_utf8(name)?, dims, data });
    }
    Ok(out)
}

/// Find a tensor by name.
pub fn find<'a>(tensors: &'a [Tensor], name: &str) -> Result<&'a Tensor> {
    tensors
        .iter()
        .find(|t| t.name == name)
        .with_context(|| format!("tensor '{name}' not found"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join(format!("oggm_binio_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.oggm");
        let ts = vec![
            Tensor::new("a", vec![2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]),
            Tensor::new("b", vec![1], vec![-7.5]),
            Tensor::new("empty", vec![0], vec![]),
        ];
        save(&p, &ts).unwrap();
        let back = load(&p).unwrap();
        assert_eq!(ts, back);
        assert_eq!(find(&back, "b").unwrap().data, vec![-7.5]);
        assert!(find(&back, "zzz").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join(format!("oggm_badmagic_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.oggm");
        std::fs::write(&p, b"NOPE....").unwrap();
        assert!(load(&p).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
