//! The persistent rank pool: P long-lived worker threads driven over
//! message channels by the (single-threaded) coordinator.
//!
//! Lifecycle (DESIGN.md §9): a pool is created once per session (Service /
//! Trainer) or per solve (one-shot CLI paths); each worker constructs its
//! own [`Runtime`] at spawn and keeps a per-rank θ cache warm across
//! packs. Per pack, the coordinator *installs* each rank's shard replica
//! (slot-addressed, so a trainer can keep the episode state and the
//! current minibatch resident simultaneously), then per step ships only
//! compact deltas (dirty rows/cols or dirty tile masks) and the small S/C
//! masks. Shared immutable inputs — parameters, loss targets — cross the
//! channel as `Arc`s, so publishing them is O(1) per rank, not O(N+E)
//! (the fix for the old per-call engine's full-graph clones).
//!
//! Failure semantics (DESIGN.md §11): a worker that errors aborts the
//! collective group (waking sibling ranks mid-collective), the pool
//! surfaces one contextful error naming the originating rank, and the next
//! `install` transparently resets the collective group so the pool stays
//! usable — a failed rank becomes a per-job error at the service boundary,
//! never a wedged process.
//!
//! A worker that *panics* additionally exits its thread (rank death). The
//! pool's supervisor notices at the next `install` via
//! `JoinHandle::is_finished` and spawns a **replacement rank**: fresh
//! thread-local `Runtime`, fresh channels, a new collective group for the
//! whole pool, and θ re-published to the replacement from the Arc-shared
//! parameters — shard state re-ships with the install itself. Restart
//! rounds are budgeted per pack (`max_restarts`, the `--max-rank-restarts`
//! flag, default 2) with exponential backoff, and the pool's
//! [`ExecStats`] report restart counts and total recovery time.
//!
//! Remote (TCP) rank death gets the same treatment via **rejoin**
//! (DESIGN.md §12): the group's listeners stay open, so when a worker
//! process dies (detected by the `--rank-timeout` liveness deadline or a
//! closed socket) the supervisor holds the `--rejoin-window` open for a
//! relaunched `oggm rank --reconnect` worker to re-handshake into the
//! vacated slot, then resets the group and re-publishes θ exactly as for
//! a thread replacement — same budget, same backoff, and the retried
//! pack's solutions stay bit-identical.
//!
//! Deterministic fault injection: `RankPool::new` reads `OGGM_FAULT_PLAN`
//! (see [`crate::collective::fault`]) and `new_with` accepts an explicit
//! plan, threading it into every worker (forward-step faults) and every
//! communicator handle (collective-phase faults).

use super::worker;
use crate::collective::fault::{FaultKind, FaultPlan};
use crate::collective::Communicator;
use crate::coordinator::bwd::GradOutput;
use crate::coordinator::engine::{EngineCfg, StepTiming};
use crate::coordinator::fwd::FwdOutput;
use crate::coordinator::shard::ShardSet;
use crate::model::Params;
use crate::runtime::ExecStats;
use crate::transport::inproc::InProcLink;
use crate::transport::tcp::{TcpCfg, TcpGroup};
use crate::transport::{RankLink, WorkerLink};
use anyhow::{anyhow, bail, ensure, Context, Result};
use std::cell::RefCell;
use std::collections::HashSet;
use std::path::PathBuf;
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// One rank's shard replica shipped at install/rebuild.
pub(crate) enum RankShard {
    Dense(crate::coordinator::shard::ShardState),
    Sparse(crate::coordinator::shard::SparseShard),
}

/// Per-rank state delta shipped at sync (the rank-parallel twin of the
/// lockstep `DeviceState::sync` inputs).
pub(crate) enum SyncDelta {
    Dense { rows: Vec<(u32, u32)>, cols: Vec<(u32, u32)> },
    Sparse { tiles: Vec<(u32, Vec<f32>)> },
}

/// Per-rank forward request: the per-step masks plus loop knobs.
pub(crate) struct FwdReq {
    pub l: usize,
    pub save: bool,
    pub skip_zero: bool,
    pub s: Vec<f32>,
    pub c: Vec<f32>,
    pub deg: Option<Vec<f32>>,
}

/// Coordinator → worker requests. Every request except `Shutdown` gets
/// exactly one [`Resp`].
pub(crate) enum Req {
    SetParams(Arc<Params>),
    NewComm(Communicator),
    /// Make the worker's existing collective handle fresh again (the
    /// remote-transport twin of `NewComm`: a communicator holding live
    /// socket state can't be rebuilt coordinator-side, so it is reset
    /// in place instead).
    ResetComm,
    Install { slot: usize, shard: RankShard, resident: bool },
    Sync { slot: usize, delta: SyncDelta },
    Rebuild { slot: usize, shard: RankShard },
    Forward { slot: usize, f: FwdReq },
    Backward { slot: usize, l: usize, onehot: Arc<Vec<f32>>, targets: Arc<Vec<f32>> },
    Uninstall { slot: usize },
    Stats,
    InjectFailure,
    Shutdown,
}

/// Measured per-rank attribution of one forward/backward, aggregated by
/// the pool into a [`StepTiming`] so rank-parallel and lockstep metrics
/// stay column-compatible (compute per rank; host/comm/h2d max-aggregated
/// where per-rank work overlaps in real time).
#[derive(Debug, Clone, Copy, Default)]
pub(crate) struct RankTiming {
    pub compute: f64,
    pub host: f64,
    /// Seconds this rank spent blocked inside collectives.
    pub comm: f64,
    pub h2d: f64,
    pub comm_bytes: u64,
    pub collectives: u64,
}

/// Worker → coordinator responses.
pub(crate) enum Resp {
    /// Generic acknowledgment; `xfer` is the simulated transfer seconds of
    /// the acknowledged upload operation (0 when nothing moved).
    Unit { xfer: f64 },
    Fwd { scores: Option<Vec<f32>>, timing: RankTiming },
    Bwd { loss: f32, grads: Option<Vec<f32>>, timing: RankTiming },
    Stats(ExecStats),
    Err(String),
}

struct WorkerHandle {
    /// The coordinator's endpoint of this rank, over either transport.
    link: RankLink,
    /// In-process worker thread handle (None for remote processes).
    join: Option<JoinHandle<()>>,
}

impl WorkerHandle {
    /// Whether this rank can no longer serve requests: an in-process
    /// worker whose thread exited, or a TCP worker whose connection
    /// closed.
    fn is_dead(&self) -> bool {
        match &self.link {
            RankLink::InProc(_) => self.join.as_ref().map_or(true, |j| j.is_finished()),
            RankLink::Tcp(l) => l.is_dead(),
        }
    }
}

/// The pool's handle on the collective group, per transport. A failed
/// local group is replaced wholesale (fresh [`Communicator`]s shipped
/// via `NewComm`); a failed TCP group is reset in place (the hub clears
/// its sticky abort, each worker clears its own via `ResetComm`). The
/// TCP arm keeps the whole [`TcpGroup`] — listeners included — so
/// vacated rank slots can be re-admitted during recovery.
enum GroupCtl {
    Local(Vec<Communicator>),
    Tcp(TcpGroup),
}

/// Why a coordinator→worker send failed.
enum SendFail {
    /// The worker is gone (channel closed / connection dead).
    Gone,
    /// An injected transport fault discarded the frame; the group was
    /// aborted and the pool poisoned. Carries the contextful message.
    Dropped(String),
}

struct PoolCtl {
    /// Flat copy of the last published parameters (change detection: a
    /// warm pool re-publishes θ only when the content actually changed —
    /// the zero-θ-bytes warm-pack property).
    last_params: Option<Vec<f32>>,
    /// The last published parameters as shipped — re-published to a
    /// replacement rank, whose fresh runtime starts with no θ.
    published: Option<Arc<Params>>,
    /// Set after any failed operation; the next install resets the
    /// collective group before proceeding.
    poisoned: bool,
    /// Consecutive recovery rounds that replaced dead ranks without an
    /// intervening successful install — the budget `max_restarts` caps.
    streak: usize,
    /// Total rank replacements over the pool's lifetime.
    restarts_total: u64,
    /// Replacements that were remote rejoins (a reconnecting worker
    /// process re-admitted into its old TCP rank slot) — a subset of
    /// `restarts_total`.
    remote_restarts: u64,
    /// Total wall time spent in recovery (respawn + collective reset + θ
    /// republish).
    recovery: Duration,
    /// Wall time spent holding the rejoin window open for replacement
    /// workers — a subset of `recovery`.
    rejoin: Duration,
}

/// A persistent pool of P rank workers (DESIGN.md §9). Single-threaded
/// coordinator side; the workers own the concurrency.
pub struct RankPool {
    p: usize,
    dir: PathBuf,
    /// Scripted fault plan threaded into workers and communicator handles.
    fault: Option<Arc<FaultPlan>>,
    /// Max consecutive rank-replacement rounds per pack (DESIGN.md §11).
    max_restarts: usize,
    /// Interior mutability: the supervisor replaces dead handles in place
    /// while the coordinator drives the pool through `&self`.
    workers: RefCell<Vec<WorkerHandle>>,
    /// The current collective group (see [`GroupCtl`]); the supervisor
    /// swaps/resets it during recovery.
    group: RefCell<GroupCtl>,
    /// Per-rank count of frames sent on each coordinator→worker link —
    /// the `frame=` coordinate transport fault specs address.
    frames: RefCell<Vec<u64>>,
    ctl: RefCell<PoolCtl>,
}

/// Default per-pack rank-replacement budget (`--max-rank-restarts`).
pub const DEFAULT_MAX_RANK_RESTARTS: usize = 2;

impl RankPool {
    /// Spawn P persistent rank workers over the artifact directory. Each
    /// worker constructs its own PJRT runtime; failure on any rank (e.g.
    /// the offline xla stub) fails construction with that rank's error.
    /// Reads a fault-injection script from `OGGM_FAULT_PLAN` when set.
    pub fn new(dir: impl Into<PathBuf>, p: usize) -> Result<RankPool> {
        RankPool::new_with(dir, p, DEFAULT_MAX_RANK_RESTARTS, FaultPlan::from_env()?)
    }

    /// `new` with an explicit restart budget and fault plan (the service
    /// threads `--max-rank-restarts` / `--fault-plan` through here).
    pub fn new_with(
        dir: impl Into<PathBuf>,
        p: usize,
        max_restarts: usize,
        fault: Option<Arc<FaultPlan>>,
    ) -> Result<RankPool> {
        ensure!(p >= 1, "rank pool needs at least one rank");
        let dir = dir.into();
        // Runtime::new sets TF_CPP_MIN_LOG_LEVEL when unset; do that once
        // here, before any worker exists, so P concurrent runtime startups
        // never race the (non-thread-safe) env mutation.
        if std::env::var_os("TF_CPP_MIN_LOG_LEVEL").is_none() {
            std::env::set_var("TF_CPP_MIN_LOG_LEVEL", "1");
        }
        let comms = Communicator::create_with_faults(p, fault.clone());
        let group = GroupCtl::Local(comms.clone());
        let mut workers = Vec::with_capacity(p);
        for (rank, comm) in comms.into_iter().enumerate() {
            workers.push(spawn_worker(&dir, rank, comm, fault.clone())?);
        }
        let pool = RankPool {
            p,
            dir,
            fault,
            max_restarts,
            workers: RefCell::new(workers),
            group: RefCell::new(group),
            frames: RefCell::new(vec![0; p]),
            ctl: RefCell::new(PoolCtl {
                last_params: None,
                published: None,
                poisoned: false,
                streak: 0,
                restarts_total: 0,
                remote_restarts: 0,
                recovery: Duration::ZERO,
                rejoin: Duration::ZERO,
            }),
        };
        // Startup handshake: every worker acknowledges its runtime.
        pool.collect_unit("start rank runtimes")?;
        Ok(pool)
    }

    /// Build a pool whose P ranks are **separate OS processes** reached
    /// over TCP (DESIGN.md §12) with default liveness/rejoin knobs
    /// ([`TcpCfg::default`]: 30 s timeout and rejoin window, no token).
    pub fn new_tcp(
        dir: impl Into<PathBuf>,
        p: usize,
        max_restarts: usize,
        fault: Option<Arc<FaultPlan>>,
        spec: &str,
    ) -> Result<RankPool> {
        RankPool::new_tcp_with(dir, p, max_restarts, fault, spec, TcpCfg::default())
    }

    /// [`RankPool::new_tcp`] with explicit liveness/rejoin/auth knobs:
    /// listen on the `--ranks` addresses, admit exactly P `oggm rank`
    /// workers (handshake-validated against this pool's world size,
    /// artifact fingerprint, and shared token), and wait for each
    /// worker's runtime-start acknowledgment — the same startup
    /// handshake the threaded pool performs. The listeners stay open so
    /// replacement workers can rejoin vacated rank slots during
    /// recovery (DESIGN.md §12).
    pub fn new_tcp_with(
        dir: impl Into<PathBuf>,
        p: usize,
        max_restarts: usize,
        fault: Option<Arc<FaultPlan>>,
        spec: &str,
        cfg: TcpCfg,
    ) -> Result<RankPool> {
        ensure!(p >= 1, "rank pool needs at least one rank");
        let dir = dir.into();
        let addrs = parse_rank_spec(spec, p)?;
        let hub = crate::transport::tcp::CollHub::new(p);
        let fingerprint = crate::transport::manifest_fingerprint(&dir);
        let (group, links) = TcpGroup::form(&addrs, p, fingerprint, &hub, cfg)
            .context("forming the TCP rank group")?;
        let workers = links
            .into_iter()
            .map(|l| WorkerHandle { link: RankLink::Tcp(l), join: None })
            .collect();
        let pool = RankPool {
            p,
            dir,
            fault,
            max_restarts,
            workers: RefCell::new(workers),
            group: RefCell::new(GroupCtl::Tcp(group)),
            frames: RefCell::new(vec![0; p]),
            ctl: RefCell::new(PoolCtl {
                last_params: None,
                published: None,
                poisoned: false,
                streak: 0,
                restarts_total: 0,
                remote_restarts: 0,
                recovery: Duration::ZERO,
                rejoin: Duration::ZERO,
            }),
        };
        pool.collect_unit("start rank runtimes")?;
        Ok(pool)
    }

    /// Number of worker ranks P.
    pub fn p(&self) -> usize {
        self.p
    }

    /// (total rank replacements, total recovery wall time) so far.
    pub fn restart_stats(&self) -> (u64, Duration) {
        let ctl = self.ctl.borrow();
        (ctl.restarts_total, ctl.recovery)
    }

    /// Abort the current collective group with `rank` as the origin.
    fn abort_group(&self, rank: usize, msg: &str) {
        match &*self.group.borrow() {
            GroupCtl::Local(comms) => {
                if let Some(c) = comms.get(rank) {
                    c.abort(msg);
                }
            }
            GroupCtl::Tcp(g) => g.hub().abort(rank, msg),
        }
    }

    /// Send one request to rank `i`, running the transport fault script
    /// at this link's frame counter first. An injected `drop` aborts the
    /// group (so ranks already holding the request fail fast instead of
    /// deadlocking on the missing peer), poisons the pool, and discards
    /// the frame.
    fn send_req(&self, i: usize, req: Req) -> Result<(), SendFail> {
        if let Some(plan) = &self.fault {
            let frame = {
                let mut frames = self.frames.borrow_mut();
                let f = frames[i];
                frames[i] += 1;
                f
            };
            match plan.fire_transport(i, frame) {
                None => {}
                Some(FaultKind::Delay(d)) => std::thread::sleep(d),
                Some(FaultKind::Drop) => {
                    let msg =
                        format!("injected fault: transport frame {frame} to rank {i} dropped");
                    self.abort_group(i, &msg);
                    self.ctl.borrow_mut().poisoned = true;
                    return Err(SendFail::Dropped(msg));
                }
                // fire_transport only yields transport kinds.
                Some(_) => unreachable!(),
            }
        }
        if self.workers.borrow()[i].link.send(req).is_err() {
            return Err(SendFail::Gone);
        }
        Ok(())
    }

    /// After a dropped frame at rank `sent`, ranks `0..sent` already
    /// hold the request and owe exactly one response each — the group
    /// abort guarantees none blocks forever waiting for the missing
    /// peers. Consume those responses so recovery starts from quiet
    /// channels.
    fn drain_owed(&self, sent: usize) {
        let ws = self.workers.borrow();
        for w in ws.iter().take(sent) {
            let _ = w.link.recv();
        }
    }

    fn send_all<F: FnMut(usize) -> Req>(&self, mut f: F) -> Result<()> {
        for i in 0..self.p {
            match self.send_req(i, f(i)) {
                Ok(()) => {}
                Err(SendFail::Gone) => {
                    self.ctl.borrow_mut().poisoned = true;
                    bail!("{}", self.workers.borrow()[i].link.gone_msg(i));
                }
                Err(SendFail::Dropped(msg)) => {
                    self.drain_owed(i);
                    bail!("{msg}");
                }
            }
        }
        Ok(())
    }

    /// Collect one response per worker, in rank order. Any error response
    /// (or dead worker) poisons the pool and surfaces as one contextful
    /// error preferring the originating failure over abort echoes.
    fn recv_all(&self, what: &str) -> Result<Vec<Resp>> {
        let mut out = Vec::with_capacity(self.p);
        let mut errs: Vec<(usize, String)> = Vec::new();
        for (i, w) in self.workers.borrow().iter().enumerate() {
            match w.link.recv() {
                Ok(Resp::Err(e)) => errs.push((i, e)),
                Ok(r) => out.push(r),
                Err(()) => errs.push((i, w.link.death_msg(i))),
            }
        }
        if !errs.is_empty() {
            self.ctl.borrow_mut().poisoned = true;
            let primary = errs
                .iter()
                .find(|(_, e)| !e.contains("aborted by rank"))
                .unwrap_or(&errs[0]);
            let extra = if errs.len() > 1 {
                format!(" ({} of {} ranks affected)", errs.len(), self.p)
            } else {
                String::new()
            };
            bail!("{what} failed: {}{extra}", primary.1);
        }
        Ok(out)
    }

    /// Collect unit acknowledgments; returns the slowest rank's transfer
    /// seconds (per-rank uploads overlap in real time).
    fn collect_unit(&self, what: &str) -> Result<f64> {
        let resps = self.recv_all(what)?;
        let mut xfer = 0.0f64;
        for (i, r) in resps.into_iter().enumerate() {
            match r {
                Resp::Unit { xfer: x } => xfer = xfer.max(x),
                _ => bail!("rank {i}: unexpected response during {what}"),
            }
        }
        Ok(xfer)
    }

    /// Recover from an earlier failed operation: drain stale responses,
    /// **replace dead ranks** (a panicked worker exits its thread; the
    /// replacement gets a fresh runtime and θ re-published from the last
    /// Arc-shared parameters), and hand every worker a fresh collective
    /// group (an aborted group is permanently failed by design).
    /// Replacement rounds are budgeted by `max_restarts` per pack with
    /// exponential backoff; shard state re-ships with the install that
    /// triggered this recovery.
    fn ensure_live(&self) -> Result<()> {
        if !self.ctl.borrow().poisoned {
            return Ok(());
        }
        let t0 = Instant::now();
        // Drain stale responses left by the failed operation.
        for w in self.workers.borrow().iter() {
            while w.link.try_recv().is_some() {}
        }
        // Detect dead ranks: a panicked worker has exited its thread (or
        // a remote worker's connection has closed).
        let dead: Vec<usize> = self
            .workers
            .borrow()
            .iter()
            .enumerate()
            .filter(|(_, w)| w.is_dead())
            .map(|(i, _)| i)
            .collect();
        if matches!(&*self.group.borrow(), GroupCtl::Tcp(_)) {
            let mut rejoin_elapsed = Duration::ZERO;
            if !dead.is_empty() {
                // A dead worker *process* cannot be respawned from here
                // — its runtime, θ cache, and socket live in another OS
                // process — but the group's listeners are still open:
                // hold the rejoin window and let a relaunched
                // (`--reconnect`) worker re-handshake into the vacated
                // slot, under the same per-pack budget and backoff the
                // threaded supervisor uses.
                let streak = self.ctl.borrow().streak;
                if streak >= self.max_restarts {
                    self.ctl.borrow_mut().streak = 0;
                    bail!(
                        "{} dead remote rank(s) after {streak} replacement round(s): \
                         per-pack restart budget exhausted (max {}; raise \
                         --max-rank-restarts)",
                        dead.len(),
                        self.max_restarts
                    );
                }
                std::thread::sleep(Duration::from_millis(5u64 << streak.min(4)));
                let reasons: Vec<String> = {
                    let ws = self.workers.borrow();
                    dead.iter().map(|&i| ws[i].link.death_msg(i)).collect()
                };
                eprintln!(
                    "rank pool: lost remote rank(s) [{}]: {}; holding the rejoin window \
                     open for replacements",
                    dead.iter().map(|r| r.to_string()).collect::<Vec<_>>().join(", "),
                    reasons.join("; ")
                );
                let t_rejoin = Instant::now();
                let live: HashSet<usize> =
                    (0..self.p).filter(|i| !dead.contains(i)).collect();
                let links = {
                    let group = self.group.borrow();
                    let GroupCtl::Tcp(g) = &*group else { unreachable!() };
                    // Window expiry is terminal and passes straight
                    // through ("rejoin window expired: …").
                    g.rejoin(&dead, &live)?
                };
                rejoin_elapsed = t_rejoin.elapsed();
                {
                    let mut ws = self.workers.borrow_mut();
                    for link in links {
                        let r = link.rank();
                        // Dropping the old handle shuts the dead socket
                        // and joins its reader thread.
                        ws[r] = WorkerHandle { link: RankLink::Tcp(link), join: None };
                    }
                }
                // Each rejoined worker acknowledges its runtime start
                // (the same startup handshake formation performs).
                {
                    let ws = self.workers.borrow();
                    for &i in &dead {
                        match ws[i].link.recv() {
                            Ok(Resp::Unit { .. }) => {}
                            Ok(Resp::Err(e)) => {
                                bail!("replacement rank {i} failed to start: {e}")
                            }
                            _ => bail!("rank {i}: unexpected response during rejoin startup"),
                        }
                    }
                }
            }
            // Make the group fresh in place — hub first (so no stale
            // abort races the acks), then each worker clears its sticky
            // abort and acknowledges.
            if let GroupCtl::Tcp(g) = &*self.group.borrow() {
                g.hub().reset();
            }
            self.send_all(|_| Req::ResetComm)?;
            self.collect_unit("reset collectives")?;
            if !dead.is_empty() {
                // Rejoined workers restarted with an empty θ cache:
                // re-publish the last parameters to them (Arc-shared,
                // O(1) coordinator-side; shard state re-ships with the
                // install that triggered this recovery).
                if let Some(arc) = self.ctl.borrow().published.clone() {
                    let ws = self.workers.borrow();
                    for &i in &dead {
                        if ws[i].link.send(Req::SetParams(arc.clone())).is_err() {
                            bail!("{}", ws[i].link.gone_msg(i));
                        }
                    }
                    for &i in &dead {
                        match ws[i].link.recv() {
                            Ok(Resp::Unit { .. }) => {}
                            Ok(Resp::Err(e)) => {
                                bail!("republish θ to replacement rank failed: {e}")
                            }
                            _ => bail!("rank {i}: unexpected response to θ republish"),
                        }
                    }
                }
                let mut ctl = self.ctl.borrow_mut();
                ctl.streak += 1;
                ctl.restarts_total += dead.len() as u64;
                ctl.remote_restarts += dead.len() as u64;
                ctl.rejoin += rejoin_elapsed;
            }
            let mut ctl = self.ctl.borrow_mut();
            ctl.recovery += t0.elapsed();
            ctl.poisoned = false;
            return Ok(());
        }
        if !dead.is_empty() {
            let streak = self.ctl.borrow().streak;
            if streak >= self.max_restarts {
                // Surface the exhaustion (the current pack fails), but
                // grant the next pack a fresh budget instead of wedging
                // the pool permanently.
                self.ctl.borrow_mut().streak = 0;
                bail!(
                    "{} dead rank(s) after {streak} replacement round(s): per-pack restart \
                     budget exhausted (max {}; raise --max-rank-restarts)",
                    dead.len(),
                    self.max_restarts
                );
            }
            // Exponential backoff before touching the runtime again: a
            // persistent environment fault should not spin the supervisor.
            std::thread::sleep(Duration::from_millis(5u64 << streak.min(4)));
        }
        // Fresh collective group for the whole pool. Replacements receive
        // their handle at spawn; survivors get theirs via NewComm — each
        // rank acknowledges exactly once (spawn ack or NewComm ack).
        let fresh = Communicator::create_with_faults(self.p, self.fault.clone());
        *self.group.borrow_mut() = GroupCtl::Local(fresh.clone());
        let mut comms: Vec<Option<Communicator>> = fresh.into_iter().map(Some).collect();
        {
            let mut ws = self.workers.borrow_mut();
            for &i in &dead {
                if let Some(j) = ws[i].join.take() {
                    let _ = j.join(); // reap the dead thread
                }
                let comm = comms[i].take().expect("each rank's comm is taken once");
                ws[i] = spawn_worker(&self.dir, i, comm, self.fault.clone())
                    .context("respawning a replacement rank")?;
            }
        }
        for (i, w) in self.workers.borrow().iter().enumerate() {
            if let Some(c) = comms[i].take() {
                if w.link.send(Req::NewComm(c)).is_err() {
                    bail!("rank {i} worker is gone");
                }
            }
        }
        self.collect_unit("reset collectives")?;
        // Replacements restarted with an empty θ cache: re-publish the
        // last parameters to them (O(1) per rank — they're Arc-shared).
        if !dead.is_empty() {
            if let Some(arc) = self.ctl.borrow().published.clone() {
                let ws = self.workers.borrow();
                for &i in &dead {
                    if ws[i].link.send(Req::SetParams(arc.clone())).is_err() {
                        bail!("rank {i} worker is gone");
                    }
                }
                for &i in &dead {
                    match ws[i].link.recv() {
                        Ok(Resp::Unit { .. }) => {}
                        Ok(Resp::Err(e)) => bail!("republish θ to replacement rank failed: {e}"),
                        _ => bail!("rank {i}: unexpected response to θ republish"),
                    }
                }
            }
            let mut ctl = self.ctl.borrow_mut();
            ctl.streak += 1;
            ctl.restarts_total += dead.len() as u64;
        }
        let mut ctl = self.ctl.borrow_mut();
        ctl.recovery += t0.elapsed();
        ctl.poisoned = false;
        Ok(())
    }

    /// Publish parameters to every rank if they changed since the last
    /// publish: each worker re-uploads θ through its per-rank cache once.
    /// Returns the slowest rank's upload seconds (0.0 on a warm no-op).
    pub fn ensure_params(&self, params: &Params) -> Result<f64> {
        if self.ctl.borrow().last_params.as_deref() == Some(params.flat.as_slice()) {
            return Ok(0.0);
        }
        let arc = Arc::new(params.clone());
        self.send_all(|_| Req::SetParams(arc.clone()))?;
        let xfer = self.collect_unit("publish parameters")?;
        let mut ctl = self.ctl.borrow_mut();
        ctl.last_params = Some(params.flat.clone());
        ctl.published = Some(arc);
        Ok(xfer)
    }

    /// Install a pack into `slot`: publish parameters (if changed), ship
    /// each rank its shard replica, and build per-rank device residency
    /// when `resident`. Clears the coordinator shards' dirty deltas — the
    /// replicas capture the current state. Returns transfer seconds.
    pub fn install(
        &self,
        slot: usize,
        params: &Params,
        set: &mut ShardSet,
        resident: bool,
    ) -> Result<f64> {
        self.ensure_live()?;
        let mut xfer = self.ensure_params(params)?;
        set.clear_dirty();
        self.send_shards(|shard| Req::Install { slot, shard, resident }, set)?;
        xfer += self.collect_unit("install pack")?;
        // A successful install opens a new pack: the per-pack restart
        // budget starts fresh.
        self.ctl.borrow_mut().streak = 0;
        Ok(xfer)
    }

    /// Ship fresh shard replicas after a repack (capacity/shape change);
    /// per-rank device state is rebuilt, θ is kept. Returns transfer secs.
    pub fn rebuild(&self, slot: usize, set: &mut ShardSet) -> Result<f64> {
        set.clear_dirty();
        self.send_shards(|shard| Req::Rebuild { slot, shard }, set)?;
        self.collect_unit("rebuild pack")
    }

    fn send_shards<F: Fn(RankShard) -> Req>(&self, f: F, set: &ShardSet) -> Result<()> {
        match set {
            ShardSet::Dense(shards) => {
                ensure!(
                    shards.len() == self.p,
                    "pack has {} shards but the pool has {} ranks",
                    shards.len(),
                    self.p
                );
                self.send_all(|i| f(RankShard::Dense(shards[i].clone())))
            }
            ShardSet::Sparse(shards) => {
                ensure!(
                    shards.len() == self.p,
                    "pack has {} shards but the pool has {} ranks",
                    shards.len(),
                    self.p
                );
                self.send_all(|i| f(RankShard::Sparse(shards[i].clone())))
            }
        }
    }

    /// Consume the coordinator shards' dirty deltas and ship them to the
    /// ranks (dense: zeroed rows/cols; sparse: dirty tile masks), which
    /// patch their replicas and device copies. A fully-clean set (e.g.
    /// the first round after install, or MaxCut solves that never remove
    /// nodes) skips the channel round-trip entirely. Returns transfer
    /// seconds.
    pub fn sync(&self, slot: usize, set: &mut ShardSet) -> Result<f64> {
        let clean = match set {
            ShardSet::Dense(shards) => shards.iter().all(|sh| !sh.is_dirty()),
            ShardSet::Sparse(shards) => shards.iter().all(|sh| !sh.is_dirty()),
        };
        if clean {
            return Ok(0.0);
        }
        let deltas: Vec<SyncDelta> = match set {
            ShardSet::Dense(shards) => shards
                .iter_mut()
                .map(|sh| {
                    let (rows, cols) = sh.take_dirty();
                    SyncDelta::Dense { rows, cols }
                })
                .collect(),
            ShardSet::Sparse(shards) => shards
                .iter_mut()
                .map(|sh| {
                    let tiles = sh
                        .take_dirty_tiles()
                        .into_iter()
                        .map(|t| (t, sh.tiles[t as usize].w.clone()))
                        .collect();
                    SyncDelta::Sparse { tiles }
                })
                .collect(),
        };
        let mut it = deltas.into_iter();
        self.send_all(|_| Req::Sync { slot, delta: it.next().unwrap() })?;
        self.collect_unit("sync pack deltas")
    }

    /// One rank-concurrent distributed policy evaluation of the installed
    /// pack. `set` supplies each rank's current S/C (and sparse degree)
    /// masks; activations saved under `save` stay rank-local for the
    /// following [`RankPool::backward`].
    pub fn forward(
        &self,
        slot: usize,
        cfg: &EngineCfg,
        set: &ShardSet,
        save: bool,
        skip_zero: bool,
    ) -> Result<FwdOutput> {
        let wall = Instant::now();
        match set {
            ShardSet::Dense(shards) => self.send_all(|i| Req::Forward {
                slot,
                f: FwdReq {
                    l: cfg.l,
                    save,
                    skip_zero,
                    s: shards[i].s.clone(),
                    c: shards[i].c.clone(),
                    deg: None,
                },
            })?,
            ShardSet::Sparse(shards) => self.send_all(|i| Req::Forward {
                slot,
                f: FwdReq {
                    l: cfg.l,
                    save,
                    skip_zero,
                    s: shards[i].s.clone(),
                    c: shards[i].c.clone(),
                    deg: Some(shards[i].deg.clone()),
                },
            })?,
        }
        let resps = self.recv_all("rank-parallel forward")?;
        let (scores, timing) = self.fold_fwd(resps, wall)?;
        Ok(FwdOutput { scores, acts: None, timing })
    }

    fn fold_fwd(&self, resps: Vec<Resp>, wall: Instant) -> Result<(Vec<f32>, StepTiming)> {
        let mut timing = StepTiming::new(self.p);
        let mut scores = None;
        for (i, r) in resps.into_iter().enumerate() {
            let Resp::Fwd { scores: sc, timing: t } = r else {
                bail!("rank {i}: unexpected response to forward");
            };
            fold_rank_timing(&mut timing, i, &t);
            if sc.is_some() {
                scores = sc;
            }
        }
        timing.wall = wall.elapsed().as_secs_f64();
        Ok((scores.context("rank 0 returned no scores")?, timing))
    }

    /// One rank-concurrent distributed backward over the activations the
    /// last `save` forward left on the ranks. The gradient all-reduce runs
    /// inside the workers; rank 0 returns the (replicated) result.
    pub fn backward(
        &self,
        slot: usize,
        cfg: &EngineCfg,
        onehot: &[f32],
        targets: &[f32],
    ) -> Result<GradOutput> {
        let wall = Instant::now();
        let onehot = Arc::new(onehot.to_vec());
        let targets = Arc::new(targets.to_vec());
        self.send_all(|_| Req::Backward {
            slot,
            l: cfg.l,
            onehot: onehot.clone(),
            targets: targets.clone(),
        })?;
        let resps = self.recv_all("rank-parallel backward")?;
        let mut timing = StepTiming::new(self.p);
        let (mut loss, mut grads) = (0.0f32, None);
        for (i, r) in resps.into_iter().enumerate() {
            let Resp::Bwd { loss: lo, grads: g, timing: t } = r else {
                bail!("rank {i}: unexpected response to backward");
            };
            fold_rank_timing(&mut timing, i, &t);
            if i == 0 {
                loss = lo;
            }
            if g.is_some() {
                grads = g;
            }
        }
        timing.wall = wall.elapsed().as_secs_f64();
        Ok(GradOutput { loss, grads: grads.context("rank 0 returned no gradients")?, timing })
    }

    /// Drop the pack installed in `slot` on every rank (device buffers are
    /// evicted; θ and compiled executables stay warm).
    pub fn uninstall(&self, slot: usize) -> Result<()> {
        self.send_all(|_| Req::Uninstall { slot })?;
        self.collect_unit("uninstall pack")?;
        Ok(())
    }

    /// Per-rank runtime counter snapshots, in rank order (each rank's h2d
    /// bytes, executions, cache hits — the warm-pool observables), with
    /// that rank's transport link traffic folded into
    /// `tx_bytes`/`rx_bytes` (coordinator-side perspective: tx =
    /// requests shipped to the rank, rx = responses received from it).
    pub fn rank_stats(&self) -> Result<Vec<ExecStats>> {
        self.send_all(|_| Req::Stats)?;
        let resps = self.recv_all("rank stats")?;
        let mut out = Vec::with_capacity(self.p);
        let ws = self.workers.borrow();
        for (i, r) in resps.into_iter().enumerate() {
            let Resp::Stats(mut s) = r else {
                bail!("rank {i}: unexpected response to stats");
            };
            let (tx, rx) = ws[i].link.traffic();
            s.tx_bytes += tx;
            s.rx_bytes += rx;
            out.push(s);
        }
        Ok(out)
    }

    /// Summed runtime counters across all ranks (the pool-level
    /// [`ExecStats`] the pack/queue metrics book), plus the supervisor's
    /// restart count and recovery time.
    pub fn stats(&self) -> Result<ExecStats> {
        let mut total = ExecStats::default();
        for s in self.rank_stats()? {
            total.add(&s);
        }
        let ctl = self.ctl.borrow();
        total.restarts = ctl.restarts_total;
        total.recovery_time = ctl.recovery;
        total.remote_restarts = ctl.remote_restarts;
        total.rejoin_time = ctl.rejoin;
        if let GroupCtl::Tcp(g) = &*self.group.borrow() {
            total.heartbeats_missed = g.hub().heartbeats_missed();
        }
        Ok(total)
    }

    /// Test hook: make `rank`'s worker fail its next forward (exercises
    /// the abort-instead-of-deadlock path end to end).
    #[doc(hidden)]
    pub fn inject_failure(&self, rank: usize) -> Result<()> {
        let ws = self.workers.borrow();
        let w = ws.get(rank).ok_or_else(|| anyhow!("no rank {rank}"))?;
        w.link.send(Req::InjectFailure).map_err(|_| anyhow!("{}", w.link.gone_msg(rank)))?;
        match w.link.recv() {
            Ok(Resp::Unit { .. }) => Ok(()),
            _ => bail!("rank {rank}: unexpected response to inject_failure"),
        }
    }
}

/// Parse the `--ranks` coordinator spec: comma-separated listen
/// addresses, each optionally prefixed `tcp:` (e.g.
/// `tcp:127.0.0.1:7650,tcp:127.0.0.1:7651`). Fewer addresses than P is
/// fine — multiple workers may dial the same listener.
fn parse_rank_spec(spec: &str, p: usize) -> Result<Vec<String>> {
    let mut addrs = Vec::new();
    for part in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
        let addr = part.strip_prefix("tcp:").unwrap_or(part);
        ensure!(addr.contains(':'), "rank listen address '{addr}' is not host:port");
        addrs.push(addr.to_string());
    }
    ensure!(
        !addrs.is_empty() && addrs.len() <= p,
        "--ranks lists {} address(es); expected 1..={p} for a P={p} group",
        addrs.len()
    );
    Ok(addrs)
}

/// Spawn one rank worker thread with fresh channels. Used at pool startup
/// and by the supervisor when replacing a dead rank.
fn spawn_worker(
    dir: &PathBuf,
    rank: usize,
    comm: Communicator,
    fault: Option<Arc<FaultPlan>>,
) -> Result<WorkerHandle> {
    let (tx, worker_rx) = channel::<Req>();
    let (worker_tx, rx) = channel::<Resp>();
    let d = dir.clone();
    let join = std::thread::Builder::new()
        .name(format!("oggm-rank{rank}"))
        .spawn(move || {
            let link = WorkerLink::Chan { rx: worker_rx, tx: worker_tx };
            let _ = worker::worker_main(d, rank, comm, fault, link);
        })
        .context("spawning rank worker")?;
    Ok(WorkerHandle { link: RankLink::InProc(InProcLink::new(tx, rx)), join: Some(join) })
}

/// Merge one rank's measured attribution into the pool-level timing.
fn fold_rank_timing(timing: &mut StepTiming, rank: usize, t: &RankTiming) {
    timing.compute[rank] = t.compute;
    timing.host = timing.host.max(t.host);
    timing.comm = timing.comm.max(t.comm);
    timing.h2d = timing.h2d.max(t.h2d);
    if rank == 0 {
        timing.comm_bytes = t.comm_bytes;
        timing.collectives = t.collectives;
    }
}

impl Drop for RankPool {
    fn drop(&mut self) {
        let ws = self.workers.get_mut();
        for w in ws.iter() {
            let _ = w.link.send(Req::Shutdown);
        }
        for w in ws.iter_mut() {
            if let Some(j) = w.join.take() {
                let _ = j.join();
            }
        }
    }
}
