//! The persistent rank pool: P long-lived worker threads driven over
//! message channels by the (single-threaded) coordinator.
//!
//! Lifecycle (DESIGN.md §9): a pool is created once per session (Service /
//! Trainer) or per solve (one-shot CLI paths); each worker constructs its
//! own [`Runtime`] at spawn and keeps a per-rank θ cache warm across
//! packs. Per pack, the coordinator *installs* each rank's shard replica
//! (slot-addressed, so a trainer can keep the episode state and the
//! current minibatch resident simultaneously), then per step ships only
//! compact deltas (dirty rows/cols or dirty tile masks) and the small S/C
//! masks. Shared immutable inputs — parameters, loss targets — cross the
//! channel as `Arc`s, so publishing them is O(1) per rank, not O(N+E)
//! (the fix for the old per-call engine's full-graph clones).
//!
//! Failure semantics: a worker that errors aborts the collective group
//! (waking sibling ranks mid-collective), the pool surfaces one contextful
//! error naming the originating rank, and the next `install` transparently
//! resets the collective group so the pool stays usable — a failed rank
//! becomes a per-job error at the service boundary, never a wedged
//! process.

use super::worker;
use crate::collective::Communicator;
use crate::coordinator::bwd::GradOutput;
use crate::coordinator::engine::{EngineCfg, StepTiming};
use crate::coordinator::fwd::FwdOutput;
use crate::coordinator::shard::ShardSet;
use crate::model::Params;
use crate::runtime::ExecStats;
use anyhow::{anyhow, bail, ensure, Context, Result};
use std::cell::RefCell;
use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

/// One rank's shard replica shipped at install/rebuild.
pub(crate) enum RankShard {
    Dense(crate::coordinator::shard::ShardState),
    Sparse(crate::coordinator::shard::SparseShard),
}

/// Per-rank state delta shipped at sync (the rank-parallel twin of the
/// lockstep `DeviceState::sync` inputs).
pub(crate) enum SyncDelta {
    Dense { rows: Vec<(u32, u32)>, cols: Vec<(u32, u32)> },
    Sparse { tiles: Vec<(u32, Vec<f32>)> },
}

/// Per-rank forward request: the per-step masks plus loop knobs.
pub(crate) struct FwdReq {
    pub l: usize,
    pub save: bool,
    pub skip_zero: bool,
    pub s: Vec<f32>,
    pub c: Vec<f32>,
    pub deg: Option<Vec<f32>>,
}

/// Coordinator → worker requests. Every request except `Shutdown` gets
/// exactly one [`Resp`].
pub(crate) enum Req {
    SetParams(Arc<Params>),
    NewComm(Communicator),
    Install { slot: usize, shard: RankShard, resident: bool },
    Sync { slot: usize, delta: SyncDelta },
    Rebuild { slot: usize, shard: RankShard },
    Forward { slot: usize, f: FwdReq },
    Backward { slot: usize, l: usize, onehot: Arc<Vec<f32>>, targets: Arc<Vec<f32>> },
    Uninstall { slot: usize },
    Stats,
    InjectFailure,
    Shutdown,
}

/// Measured per-rank attribution of one forward/backward, aggregated by
/// the pool into a [`StepTiming`] so rank-parallel and lockstep metrics
/// stay column-compatible (compute per rank; host/comm/h2d max-aggregated
/// where per-rank work overlaps in real time).
#[derive(Debug, Clone, Copy, Default)]
pub(crate) struct RankTiming {
    pub compute: f64,
    pub host: f64,
    /// Seconds this rank spent blocked inside collectives.
    pub comm: f64,
    pub h2d: f64,
    pub comm_bytes: u64,
    pub collectives: u64,
}

/// Worker → coordinator responses.
pub(crate) enum Resp {
    /// Generic acknowledgment; `xfer` is the simulated transfer seconds of
    /// the acknowledged upload operation (0 when nothing moved).
    Unit { xfer: f64 },
    Fwd { scores: Option<Vec<f32>>, timing: RankTiming },
    Bwd { loss: f32, grads: Option<Vec<f32>>, timing: RankTiming },
    Stats(ExecStats),
    Err(String),
}

struct WorkerHandle {
    tx: Sender<Req>,
    rx: Receiver<Resp>,
    join: Option<JoinHandle<()>>,
}

struct PoolCtl {
    /// Flat copy of the last published parameters (change detection: a
    /// warm pool re-publishes θ only when the content actually changed —
    /// the zero-θ-bytes warm-pack property).
    last_params: Option<Vec<f32>>,
    /// Set after any failed operation; the next install resets the
    /// collective group before proceeding.
    poisoned: bool,
}

/// A persistent pool of P rank workers (DESIGN.md §9). Single-threaded
/// coordinator side; the workers own the concurrency.
pub struct RankPool {
    p: usize,
    workers: Vec<WorkerHandle>,
    ctl: RefCell<PoolCtl>,
}

impl RankPool {
    /// Spawn P persistent rank workers over the artifact directory. Each
    /// worker constructs its own PJRT runtime; failure on any rank (e.g.
    /// the offline xla stub) fails construction with that rank's error.
    pub fn new(dir: impl Into<PathBuf>, p: usize) -> Result<RankPool> {
        ensure!(p >= 1, "rank pool needs at least one rank");
        let dir = dir.into();
        // Runtime::new sets TF_CPP_MIN_LOG_LEVEL when unset; do that once
        // here, before any worker exists, so P concurrent runtime startups
        // never race the (non-thread-safe) env mutation.
        if std::env::var_os("TF_CPP_MIN_LOG_LEVEL").is_none() {
            std::env::set_var("TF_CPP_MIN_LOG_LEVEL", "1");
        }
        let comms = Communicator::create(p);
        let mut workers = Vec::with_capacity(p);
        for (rank, comm) in comms.into_iter().enumerate() {
            let (tx, worker_rx) = channel::<Req>();
            let (worker_tx, rx) = channel::<Resp>();
            let d = dir.clone();
            let join = std::thread::Builder::new()
                .name(format!("oggm-rank{rank}"))
                .spawn(move || worker::worker_main(d, rank, comm, worker_rx, worker_tx))
                .context("spawning rank worker")?;
            workers.push(WorkerHandle { tx, rx, join: Some(join) });
        }
        let pool = RankPool {
            p,
            workers,
            ctl: RefCell::new(PoolCtl { last_params: None, poisoned: false }),
        };
        // Startup handshake: every worker acknowledges its runtime.
        pool.collect_unit("start rank runtimes")?;
        Ok(pool)
    }

    /// Number of worker ranks P.
    pub fn p(&self) -> usize {
        self.p
    }

    fn send_all<F: FnMut(usize) -> Req>(&self, mut f: F) -> Result<()> {
        for (i, w) in self.workers.iter().enumerate() {
            if w.tx.send(f(i)).is_err() {
                self.ctl.borrow_mut().poisoned = true;
                bail!("rank {i} worker is gone");
            }
        }
        Ok(())
    }

    /// Collect one response per worker, in rank order. Any error response
    /// (or dead worker) poisons the pool and surfaces as one contextful
    /// error preferring the originating failure over abort echoes.
    fn recv_all(&self, what: &str) -> Result<Vec<Resp>> {
        let mut out = Vec::with_capacity(self.p);
        let mut errs: Vec<(usize, String)> = Vec::new();
        for (i, w) in self.workers.iter().enumerate() {
            match w.rx.recv() {
                Ok(Resp::Err(e)) => errs.push((i, e)),
                Ok(r) => out.push(r),
                Err(_) => errs.push((i, format!("rank {i}: worker thread died"))),
            }
        }
        if !errs.is_empty() {
            self.ctl.borrow_mut().poisoned = true;
            let primary = errs
                .iter()
                .find(|(_, e)| !e.contains("aborted by rank"))
                .unwrap_or(&errs[0]);
            let extra = if errs.len() > 1 {
                format!(" ({} of {} ranks affected)", errs.len(), self.p)
            } else {
                String::new()
            };
            bail!("{what} failed: {}{extra}", primary.1);
        }
        Ok(out)
    }

    /// Collect unit acknowledgments; returns the slowest rank's transfer
    /// seconds (per-rank uploads overlap in real time).
    fn collect_unit(&self, what: &str) -> Result<f64> {
        let resps = self.recv_all(what)?;
        let mut xfer = 0.0f64;
        for (i, r) in resps.into_iter().enumerate() {
            match r {
                Resp::Unit { xfer: x } => xfer = xfer.max(x),
                _ => bail!("rank {i}: unexpected response during {what}"),
            }
        }
        Ok(xfer)
    }

    /// Recover from an earlier failed operation: drain stale responses and
    /// hand every worker a fresh collective group (an aborted group is
    /// permanently failed by design).
    fn ensure_live(&self) -> Result<()> {
        if !self.ctl.borrow().poisoned {
            return Ok(());
        }
        for w in &self.workers {
            while w.rx.try_recv().is_ok() {}
        }
        let comms = Communicator::create(self.p);
        self.send_all(|i| Req::NewComm(comms[i].clone()))?;
        self.collect_unit("reset collectives")?;
        self.ctl.borrow_mut().poisoned = false;
        Ok(())
    }

    /// Publish parameters to every rank if they changed since the last
    /// publish: each worker re-uploads θ through its per-rank cache once.
    /// Returns the slowest rank's upload seconds (0.0 on a warm no-op).
    pub fn ensure_params(&self, params: &Params) -> Result<f64> {
        if self.ctl.borrow().last_params.as_deref() == Some(params.flat.as_slice()) {
            return Ok(0.0);
        }
        let arc = Arc::new(params.clone());
        self.send_all(|_| Req::SetParams(arc.clone()))?;
        let xfer = self.collect_unit("publish parameters")?;
        self.ctl.borrow_mut().last_params = Some(params.flat.clone());
        Ok(xfer)
    }

    /// Install a pack into `slot`: publish parameters (if changed), ship
    /// each rank its shard replica, and build per-rank device residency
    /// when `resident`. Clears the coordinator shards' dirty deltas — the
    /// replicas capture the current state. Returns transfer seconds.
    pub fn install(
        &self,
        slot: usize,
        params: &Params,
        set: &mut ShardSet,
        resident: bool,
    ) -> Result<f64> {
        self.ensure_live()?;
        let mut xfer = self.ensure_params(params)?;
        set.clear_dirty();
        self.send_shards(|shard| Req::Install { slot, shard, resident }, set)?;
        xfer += self.collect_unit("install pack")?;
        Ok(xfer)
    }

    /// Ship fresh shard replicas after a repack (capacity/shape change);
    /// per-rank device state is rebuilt, θ is kept. Returns transfer secs.
    pub fn rebuild(&self, slot: usize, set: &mut ShardSet) -> Result<f64> {
        set.clear_dirty();
        self.send_shards(|shard| Req::Rebuild { slot, shard }, set)?;
        self.collect_unit("rebuild pack")
    }

    fn send_shards<F: Fn(RankShard) -> Req>(&self, f: F, set: &ShardSet) -> Result<()> {
        match set {
            ShardSet::Dense(shards) => {
                ensure!(
                    shards.len() == self.p,
                    "pack has {} shards but the pool has {} ranks",
                    shards.len(),
                    self.p
                );
                self.send_all(|i| f(RankShard::Dense(shards[i].clone())))
            }
            ShardSet::Sparse(shards) => {
                ensure!(
                    shards.len() == self.p,
                    "pack has {} shards but the pool has {} ranks",
                    shards.len(),
                    self.p
                );
                self.send_all(|i| f(RankShard::Sparse(shards[i].clone())))
            }
        }
    }

    /// Consume the coordinator shards' dirty deltas and ship them to the
    /// ranks (dense: zeroed rows/cols; sparse: dirty tile masks), which
    /// patch their replicas and device copies. A fully-clean set (e.g.
    /// the first round after install, or MaxCut solves that never remove
    /// nodes) skips the channel round-trip entirely. Returns transfer
    /// seconds.
    pub fn sync(&self, slot: usize, set: &mut ShardSet) -> Result<f64> {
        let clean = match set {
            ShardSet::Dense(shards) => shards.iter().all(|sh| !sh.is_dirty()),
            ShardSet::Sparse(shards) => shards.iter().all(|sh| !sh.is_dirty()),
        };
        if clean {
            return Ok(0.0);
        }
        let deltas: Vec<SyncDelta> = match set {
            ShardSet::Dense(shards) => shards
                .iter_mut()
                .map(|sh| {
                    let (rows, cols) = sh.take_dirty();
                    SyncDelta::Dense { rows, cols }
                })
                .collect(),
            ShardSet::Sparse(shards) => shards
                .iter_mut()
                .map(|sh| {
                    let tiles = sh
                        .take_dirty_tiles()
                        .into_iter()
                        .map(|t| (t, sh.tiles[t as usize].w.clone()))
                        .collect();
                    SyncDelta::Sparse { tiles }
                })
                .collect(),
        };
        let mut it = deltas.into_iter();
        self.send_all(|_| Req::Sync { slot, delta: it.next().unwrap() })?;
        self.collect_unit("sync pack deltas")
    }

    /// One rank-concurrent distributed policy evaluation of the installed
    /// pack. `set` supplies each rank's current S/C (and sparse degree)
    /// masks; activations saved under `save` stay rank-local for the
    /// following [`RankPool::backward`].
    pub fn forward(
        &self,
        slot: usize,
        cfg: &EngineCfg,
        set: &ShardSet,
        save: bool,
        skip_zero: bool,
    ) -> Result<FwdOutput> {
        let wall = Instant::now();
        match set {
            ShardSet::Dense(shards) => self.send_all(|i| Req::Forward {
                slot,
                f: FwdReq {
                    l: cfg.l,
                    save,
                    skip_zero,
                    s: shards[i].s.clone(),
                    c: shards[i].c.clone(),
                    deg: None,
                },
            })?,
            ShardSet::Sparse(shards) => self.send_all(|i| Req::Forward {
                slot,
                f: FwdReq {
                    l: cfg.l,
                    save,
                    skip_zero,
                    s: shards[i].s.clone(),
                    c: shards[i].c.clone(),
                    deg: Some(shards[i].deg.clone()),
                },
            })?,
        }
        let resps = self.recv_all("rank-parallel forward")?;
        let (scores, timing) = self.fold_fwd(resps, wall)?;
        Ok(FwdOutput { scores, acts: None, timing })
    }

    fn fold_fwd(&self, resps: Vec<Resp>, wall: Instant) -> Result<(Vec<f32>, StepTiming)> {
        let mut timing = StepTiming::new(self.p);
        let mut scores = None;
        for (i, r) in resps.into_iter().enumerate() {
            let Resp::Fwd { scores: sc, timing: t } = r else {
                bail!("rank {i}: unexpected response to forward");
            };
            fold_rank_timing(&mut timing, i, &t);
            if sc.is_some() {
                scores = sc;
            }
        }
        timing.wall = wall.elapsed().as_secs_f64();
        Ok((scores.context("rank 0 returned no scores")?, timing))
    }

    /// One rank-concurrent distributed backward over the activations the
    /// last `save` forward left on the ranks. The gradient all-reduce runs
    /// inside the workers; rank 0 returns the (replicated) result.
    pub fn backward(
        &self,
        slot: usize,
        cfg: &EngineCfg,
        onehot: &[f32],
        targets: &[f32],
    ) -> Result<GradOutput> {
        let wall = Instant::now();
        let onehot = Arc::new(onehot.to_vec());
        let targets = Arc::new(targets.to_vec());
        self.send_all(|_| Req::Backward {
            slot,
            l: cfg.l,
            onehot: onehot.clone(),
            targets: targets.clone(),
        })?;
        let resps = self.recv_all("rank-parallel backward")?;
        let mut timing = StepTiming::new(self.p);
        let (mut loss, mut grads) = (0.0f32, None);
        for (i, r) in resps.into_iter().enumerate() {
            let Resp::Bwd { loss: lo, grads: g, timing: t } = r else {
                bail!("rank {i}: unexpected response to backward");
            };
            fold_rank_timing(&mut timing, i, &t);
            if i == 0 {
                loss = lo;
            }
            if g.is_some() {
                grads = g;
            }
        }
        timing.wall = wall.elapsed().as_secs_f64();
        Ok(GradOutput { loss, grads: grads.context("rank 0 returned no gradients")?, timing })
    }

    /// Drop the pack installed in `slot` on every rank (device buffers are
    /// evicted; θ and compiled executables stay warm).
    pub fn uninstall(&self, slot: usize) -> Result<()> {
        self.send_all(|_| Req::Uninstall { slot })?;
        self.collect_unit("uninstall pack")?;
        Ok(())
    }

    /// Per-rank runtime counter snapshots, in rank order (each rank's h2d
    /// bytes, executions, cache hits — the warm-pool observables).
    pub fn rank_stats(&self) -> Result<Vec<ExecStats>> {
        self.send_all(|_| Req::Stats)?;
        let resps = self.recv_all("rank stats")?;
        let mut out = Vec::with_capacity(self.p);
        for (i, r) in resps.into_iter().enumerate() {
            let Resp::Stats(s) = r else {
                bail!("rank {i}: unexpected response to stats");
            };
            out.push(s);
        }
        Ok(out)
    }

    /// Summed runtime counters across all ranks (the pool-level
    /// [`ExecStats`] the pack/queue metrics book).
    pub fn stats(&self) -> Result<ExecStats> {
        let mut total = ExecStats::default();
        for s in self.rank_stats()? {
            total.add(&s);
        }
        Ok(total)
    }

    /// Test hook: make `rank`'s worker fail its next forward (exercises
    /// the abort-instead-of-deadlock path end to end).
    #[doc(hidden)]
    pub fn inject_failure(&self, rank: usize) -> Result<()> {
        let w = self.workers.get(rank).ok_or_else(|| anyhow!("no rank {rank}"))?;
        w.tx.send(Req::InjectFailure).map_err(|_| anyhow!("rank {rank} worker is gone"))?;
        match w.rx.recv() {
            Ok(Resp::Unit { .. }) => Ok(()),
            _ => bail!("rank {rank}: unexpected response to inject_failure"),
        }
    }
}

/// Merge one rank's measured attribution into the pool-level timing.
fn fold_rank_timing(timing: &mut StepTiming, rank: usize, t: &RankTiming) {
    timing.compute[rank] = t.compute;
    timing.host = timing.host.max(t.host);
    timing.comm = timing.comm.max(t.comm);
    timing.h2d = timing.h2d.max(t.h2d);
    if rank == 0 {
        timing.comm_bytes = t.comm_bytes;
        timing.collectives = t.collectives;
    }
}

impl Drop for RankPool {
    fn drop(&mut self) {
        for w in &self.workers {
            let _ = w.tx.send(Req::Shutdown);
        }
        for w in &mut self.workers {
            if let Some(j) = w.join.take() {
                let _ = j.join();
            }
        }
    }
}
