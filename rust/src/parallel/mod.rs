//! Rank-parallel execution engine (DESIGN.md §9).
//!
//! Promotes true concurrency to the solve/train hot path: a persistent
//! [`RankPool`] of P worker threads — each owning a thread-local PJRT
//! [`Runtime`](crate::runtime::Runtime), its rank's device-resident state,
//! and a per-rank θ cache that survives packs — synchronizing through the
//! chunked, rank-order-deterministic collectives of `crate::collective`.
//! This is the production reproduction of the paper's parallel training
//! and inference algorithms (Alg. 2-5): the same SPMD per-rank programs
//! the lockstep engine simulates, executed by real concurrent ranks.
//!
//! [`ExecEngine`] is the abstraction the solve/train loops drive: one
//! install/sync/rebuild/forward/backward surface dispatching to either the
//! single-threaded lockstep engine (DESIGN.md §3, the measurement
//! reference) or the rank pool, selected by
//! [`EngineCfg::mode`](crate::coordinator::engine::EngineCfg) /
//! `--engine`. Solutions and scores are pinned identical across the two
//! (rust/tests/parallel_equivalence.rs).

mod pool;
mod worker;

pub use crate::coordinator::engine::Engine;
pub use pool::{RankPool, DEFAULT_MAX_RANK_RESTARTS};
pub(crate) use pool::{FwdReq, RankShard, RankTiming, Req, Resp, SyncDelta};
pub use worker::{reconnect_backoff, remote_worker, remote_worker_with};

use crate::coordinator::bwd::{backward_set, GradOutput};
use crate::coordinator::engine::EngineCfg;
use crate::coordinator::fwd::{forward_set, Activations, AnyDeviceState, FwdOutput, ThetaCache};
use crate::coordinator::shard::ShardSet;
use crate::model::Params;
use crate::runtime::Runtime;
use anyhow::{ensure, Context, Result};

/// One solve's execution context: device residency plus the forward /
/// backward entry points, behind one surface for both engines. The
/// lockstep arm wraps the classic `&Runtime` + [`AnyDeviceState`] pair;
/// the rank-parallel arm drives a [`RankPool`] slot (uninstalled when the
/// context drops — θ and compiled executables stay warm on the pool).
pub enum ExecEngine<'a> {
    /// Single-threaded lockstep simulation (DESIGN.md §3).
    Lockstep {
        /// The coordinator's runtime.
        rt: &'a Runtime,
        /// Device residency for this solve (None = fresh-upload path).
        dev: Option<AnyDeviceState<'a>>,
    },
    /// Persistent rank pool (DESIGN.md §9).
    Ranks {
        /// The session- or solve-owned pool.
        pool: &'a RankPool,
        /// Pack slot this context installed (trainers use slot 0 for the
        /// episode state and slot 1 for the minibatch).
        slot: usize,
        /// Slowest rank's transfer seconds of the most recent upload op.
        xfer: f64,
    },
}

impl<'a> ExecEngine<'a> {
    /// Build the execution context for one solve: uploads device state
    /// (when `resident`) on the lockstep engine, or installs the pack into
    /// `slot` on the rank pool — which must be `Some` and sized P when
    /// `cfg.mode` is [`Engine::RankParallel`]. The lockstep θ upload goes
    /// through `theta` when given (the service's shared cache); the rank
    /// engine's per-rank θ caches make that parameter moot there.
    #[allow(clippy::too_many_arguments)]
    pub fn install(
        rt: &'a Runtime,
        pool: Option<&'a RankPool>,
        cfg: &EngineCfg,
        params: &Params,
        set: &mut ShardSet,
        resident: bool,
        theta: Option<&ThetaCache>,
        slot: usize,
    ) -> Result<ExecEngine<'a>> {
        match cfg.mode {
            Engine::Lockstep => {
                let dev = if resident {
                    Some(AnyDeviceState::new_in(rt, params, set, theta)?)
                } else {
                    None
                };
                Ok(ExecEngine::Lockstep { rt, dev })
            }
            Engine::RankParallel => {
                let pool = pool.context(
                    "rank-parallel engine selected but no RankPool was provided",
                )?;
                ensure!(
                    pool.p() == cfg.p,
                    "rank pool has {} ranks but the engine config wants P={}",
                    pool.p(),
                    cfg.p
                );
                let xfer = pool.install(slot, params, set, resident)?;
                Ok(ExecEngine::Ranks { pool, slot, xfer })
            }
        }
    }

    /// Simulated transfer seconds of the most recent upload operation
    /// (install / sync / rebuild / refresh_theta) — what the solve loops
    /// book into `StepTiming::h2d`.
    pub fn last_transfer_secs(&self) -> f64 {
        match self {
            ExecEngine::Lockstep { dev, .. } => {
                dev.as_ref().map_or(0.0, |d| d.last_transfer_secs())
            }
            ExecEngine::Ranks { xfer, .. } => *xfer,
        }
    }

    /// Push the shards' recorded dirty deltas to the device copies (dense:
    /// row/col masks; sparse: dirty tile live-masks). A lockstep fresh
    /// context is a no-op (deltas ride in the next full upload); the rank
    /// engine always ships them — its workers' replicas must track the
    /// coordinator's state.
    pub fn sync(&mut self, set: &mut ShardSet) -> Result<()> {
        match self {
            ExecEngine::Lockstep { dev, .. } => {
                if let Some(d) = dev.as_mut() {
                    d.sync(set)?;
                }
                Ok(())
            }
            ExecEngine::Ranks { pool, slot, xfer } => {
                *xfer = pool.sync(*slot, set)?;
                Ok(())
            }
        }
    }

    /// Invalidate + re-upload after a compaction repack (the batch
    /// capacity, and with it every buffer shape, may have changed).
    pub fn rebuild(&mut self, set: &mut ShardSet) -> Result<()> {
        match self {
            ExecEngine::Lockstep { dev, .. } => {
                if let Some(d) = dev.as_mut() {
                    d.rebuild(set)?;
                }
                Ok(())
            }
            ExecEngine::Ranks { pool, slot, xfer } => {
                *xfer = pool.rebuild(*slot, set)?;
                Ok(())
            }
        }
    }

    /// Re-publish θ after an optimizer step. The rank engine publishes to
    /// every rank at most once per parameter content (a no-op when another
    /// context already pushed the same parameters this step).
    pub fn refresh_theta(&mut self, params: &Params) -> Result<()> {
        match self {
            ExecEngine::Lockstep { dev, .. } => {
                if let Some(d) = dev.as_mut() {
                    d.refresh_theta(params)?;
                }
                Ok(())
            }
            ExecEngine::Ranks { pool, xfer, .. } => {
                *xfer = pool.ensure_params(params)?;
                Ok(())
            }
        }
    }

    /// One distributed policy evaluation of the installed pack. On the
    /// rank engine, `save`d activations stay rank-local (the returned
    /// `acts` is `None`) and are consumed by the following
    /// [`ExecEngine::backward`].
    pub fn forward(
        &mut self,
        cfg: &EngineCfg,
        params: &Params,
        set: &ShardSet,
        save: bool,
        skip_zero: bool,
    ) -> Result<FwdOutput> {
        match self {
            ExecEngine::Lockstep { rt, dev } => {
                forward_set(*rt, cfg, params, set, save, skip_zero, dev.as_ref())
            }
            ExecEngine::Ranks { pool, slot, .. } => {
                pool.forward(*slot, cfg, set, save, skip_zero)
            }
        }
    }

    /// One distributed backward pass. The lockstep arm consumes the
    /// activations returned by its forward (`acts` must be `Some`); the
    /// rank arm uses the activations its workers kept from the last
    /// `save` forward.
    pub fn backward(
        &mut self,
        cfg: &EngineCfg,
        params: &Params,
        set: &ShardSet,
        acts: Option<&Activations>,
        onehot: &[f32],
        targets: &[f32],
    ) -> Result<GradOutput> {
        match self {
            ExecEngine::Lockstep { rt, dev } => {
                let acts =
                    acts.context("lockstep backward needs the forward's saved activations")?;
                backward_set(*rt, cfg, params, set, acts, onehot, targets, dev.as_ref())
            }
            ExecEngine::Ranks { pool, slot, .. } => {
                pool.backward(*slot, cfg, onehot, targets)
            }
        }
    }

}

impl Drop for ExecEngine<'_> {
    fn drop(&mut self) {
        if let ExecEngine::Ranks { pool, slot, .. } = self {
            // Free the pack's device buffers; θ and executables stay warm.
            let _ = pool.uninstall(*slot);
        }
    }
}
