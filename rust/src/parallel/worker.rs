//! The rank worker: one long-lived thread per rank, owning a thread-local
//! [`Runtime`] (PJRT handles are not `Send`), its rank's host shard mirror,
//! its per-rank device residency, and a per-rank θ cache that persists
//! across packs — the engine's warm-pool optimization (DESIGN.md §9).
//!
//! The worker executes the same SPMD per-rank programs as the lockstep
//! engine's per-shard loops (Alg. 2-5), with the α–β-modeled collectives
//! replaced by real [`Communicator`] operations. Because the communicator's
//! all-reduce is rank-order deterministic (collective/comm.rs), scores and
//! gradients match the lockstep engine's sequential host reductions.
//!
//! Failure discipline: any error or panic while handling a request aborts
//! the collective group before the error response is sent, so sibling
//! ranks blocked mid-collective wake with a contextful error instead of
//! deadlocking (the hang-on-failure fix of ISSUE 5). An ordinary `Err` is
//! recoverable — the worker stays alive and serves the next request — but
//! a *panic* is treated as rank death: the thread sends its last error
//! response and exits, and the pool's supervisor spawns a replacement rank
//! (DESIGN.md §11).
//!
//! A [`FaultPlan`] (DESIGN.md §11) can be scripted into the worker: faults
//! with no `op=` fire at this rank's 0-based forward-step counter
//! (`kind=panic` kills the thread, `kind=err` fails the request,
//! `kind=slow` stalls), and the same plan rides on the [`Communicator`]
//! for collective-phase faults.

use super::pool::{FwdReq, RankShard, RankTiming, Req, Resp, SyncDelta};
use crate::collective::fault::{FaultKind, FaultPlan};
use crate::collective::Communicator;
use crate::coordinator::engine::StepTiming;
use crate::coordinator::fwd::{
    upload_tiles_fresh, AnyDeviceState, DeviceState, SparseDeviceState, ThetaCache, ThetaViews,
};
use crate::coordinator::shard::{ShardSet, ShardState, SparseShard};
use crate::model::Params;
use crate::runtime::{artifact_name, sparse_msg_name, sparse_pre_name, HostTensor, Input, Runtime};
use crate::transport::tcp::connect_worker;
use crate::transport::WorkerLink;
use crate::util::add_assign;
use anyhow::{anyhow, bail, ensure, Context, Result};
use std::path::{Path, PathBuf};
use std::rc::Rc;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Saved activations of this rank's last `save` forward (consumed by the
/// following backward; the per-rank twin of `fwd::Activations` — they never
/// leave the worker, which is what makes training minibatches rank-local).
struct RankActs {
    pre: Vec<f32>,
    /// Per layer: this rank's local slice of the all-reduced message.
    nbr_slice: Vec<Vec<f32>>,
    embed_final: Vec<f32>,
    sum_all: Vec<f32>,
    scores_i: Vec<f32>,
}

/// One installed pack slot: the host mirror of this rank's shard plus its
/// device residency. Multiple slots let a trainer keep the episode state
/// and the current minibatch resident at once.
struct Pack<'r> {
    mirror: ShardSet,
    dev: Option<AnyDeviceState<'r>>,
    acts: Option<RankActs>,
}

/// Worker-persistent state that outlives packs.
struct WorkerState {
    rank: usize,
    comm: Communicator,
    /// Per-rank θ namespace; survives packs, so θ re-uploads only when the
    /// parameters actually change (the warm-pool zero-θ-bytes property).
    theta: ThetaCache,
    /// The θ buffers published at the cache's current generation.
    theta_bufs: Vec<Rc<xla::PjRtBuffer>>,
    params: Option<Arc<Params>>,
    fail_next: bool,
    /// Scripted fault plan shared with the communicator handles; checked
    /// at the forward-step injection site (DESIGN.md §11).
    fault: Option<Arc<FaultPlan>>,
    /// 0-based count of forward requests served — the `step` coordinate a
    /// fault spec without `op=` addresses.
    fwd_steps: usize,
}

fn pack_mut<'a, 'r>(
    packs: &'a mut Vec<Option<Pack<'r>>>,
    slot: usize,
) -> Result<&'a mut Pack<'r>> {
    packs
        .get_mut(slot)
        .and_then(|p| p.as_mut())
        .ok_or_else(|| anyhow!("no pack installed in slot {slot}"))
}

/// How a worker request loop ended — the signal `--reconnect` keys off:
/// only a lost link is worth redialing for.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum WorkerExit {
    /// Clean `Shutdown` request from the coordinator.
    Shutdown,
    /// The link died: coordinator gone, socket closed, or an injected
    /// `disconnect` fault. A `--reconnect` worker redials after this.
    LinkLost,
    /// Fatal local failure (runtime start failed, or a panic left the
    /// worker's state suspect): reconnecting would not help.
    Fatal,
}

/// Worker thread entry: construct the thread-local runtime, acknowledge
/// startup, then serve requests until shutdown. Every request gets exactly
/// one response; failures abort the collective group first. An ordinary
/// error keeps the worker alive; a panic sends its error response and then
/// exits the thread — rank death the pool supervisor detects and repairs
/// by spawning a replacement rank (DESIGN.md §11).
pub(crate) fn worker_main(
    dir: PathBuf,
    rank: usize,
    comm: Communicator,
    fault: Option<Arc<FaultPlan>>,
    link: WorkerLink,
) -> WorkerExit {
    let rt = match Runtime::new(&dir) {
        Ok(rt) => {
            let _ = link.send(Resp::Unit { xfer: 0.0 });
            rt
        }
        Err(e) => {
            let _ = link.send(Resp::Err(format!("rank {rank}: runtime start failed: {e:#}")));
            return WorkerExit::Fatal;
        }
    };
    let mut st = WorkerState {
        rank,
        comm,
        theta: ThetaCache::new(&rt),
        theta_bufs: Vec::new(),
        params: None,
        fail_next: false,
        fault,
        fwd_steps: 0,
    };
    let mut packs: Vec<Option<Pack>> = Vec::new();
    while let Some(req) = link.recv() {
        if matches!(req, Req::Shutdown) {
            return WorkerExit::Shutdown;
        }
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            handle(&rt, &mut st, &mut packs, req)
        }));
        let (resp, fatal) = match caught {
            Ok(Ok(r)) => (r, false),
            Ok(Err(e)) => {
                let msg = format!("rank {rank}: {e:#}");
                // Wake sibling ranks blocked in a collective before the
                // coordinator even sees this error — no deadlock window.
                st.comm.abort(msg.clone());
                (Resp::Err(msg), false)
            }
            Err(payload) => {
                // Preserve the panic message (e.g. a length-mismatch
                // assert) so the surfaced error stays contextful, not just
                // "panicked".
                let msg = payload
                    .downcast_ref::<&str>()
                    .map(|s| s.to_string())
                    .or_else(|| payload.downcast_ref::<String>().cloned())
                    .unwrap_or_else(|| "unknown panic payload".into());
                let msg = format!("rank {rank}: worker panicked: {msg}");
                st.comm.abort(msg.clone());
                (Resp::Err(msg), true)
            }
        };
        let sent = link.send(resp);
        if !sent {
            return WorkerExit::LinkLost;
        }
        if fatal {
            // A panicked worker's runtime state is suspect: exit the
            // thread so `join.is_finished()` reads true and the pool's
            // supervisor replaces this rank with a fresh runtime.
            return WorkerExit::Fatal;
        }
    }
    WorkerExit::LinkLost
}

/// Backoff schedule for `--reconnect` redials: 250 ms doubling per
/// attempt, capped at 5 s. Pure so the schedule is unit-testable.
pub fn reconnect_backoff(attempt: usize) -> Duration {
    Duration::from_millis((250u64 << attempt.min(5)).min(5_000))
}

/// Run this process as one rank of a TCP-transport pool (the `oggm rank`
/// subcommand, DESIGN.md §12): dial the coordinator at `addr`, handshake
/// as `rank` (validated against the coordinator's world size and artifact
/// manifest fingerprint — mismatched processes are rejected before any
/// work), then serve the same request loop an in-process worker thread
/// runs. Same payloads, same rank-order collective folds — results are
/// bit-identical to the threaded engine. Returns when the coordinator
/// shuts the pool down or the connection closes; a handshake rejection
/// surfaces as a contextful error. The handshake token comes from
/// `OGGM_TOKEN` and the session is single-shot — `oggm rank` passes
/// explicit credentials and a `--reconnect` budget via
/// [`remote_worker_with`].
pub fn remote_worker(
    dir: impl Into<PathBuf>,
    addr: &str,
    rank: usize,
    world: Option<usize>,
    fault: Option<Arc<FaultPlan>>,
) -> Result<()> {
    let token = std::env::var("OGGM_TOKEN").unwrap_or_default();
    remote_worker_with(dir, addr, rank, world, fault, &token, 0)
}

/// [`remote_worker`] with explicit credentials and a redial budget.
///
/// `reconnect` is the number of *extra* sessions allowed after the link
/// is lost: on a lost coordinator connection (crash, liveness abort,
/// injected `disconnect`) the worker sleeps [`reconnect_backoff`] and
/// redials, re-running the Hello/Welcome handshake so the coordinator's
/// rejoin window can re-admit it into its old rank slot. A clean
/// `Shutdown` from the coordinator, a handshake rejection, or a fatal
/// local failure (runtime start, panic) ends the process instead —
/// redialing could not help, and looping on a rejection would spam the
/// coordinator forever.
pub fn remote_worker_with(
    dir: impl Into<PathBuf>,
    addr: &str,
    rank: usize,
    world: Option<usize>,
    fault: Option<Arc<FaultPlan>>,
    token: &str,
    reconnect: usize,
) -> Result<()> {
    let dir = dir.into();
    let mut attempt = 0usize;
    loop {
        match serve_session(&dir, addr, rank, world, fault.clone(), token) {
            Ok(WorkerExit::Shutdown) => return Ok(()),
            Ok(WorkerExit::Fatal) => {
                bail!(
                    "rank {rank}: worker exited after a fatal local failure \
                     (see the error response sent to the coordinator)"
                )
            }
            Ok(WorkerExit::LinkLost) => {
                if attempt >= reconnect {
                    bail!(
                        "rank {rank}: lost the coordinator connection \
                         (pass --reconnect to redial automatically)"
                    );
                }
            }
            Err(e) => {
                // A rejection means credentials or group shape are
                // wrong; redialing would just repeat it.
                if attempt >= reconnect
                    || format!("{e:#}").contains("coordinator rejected this worker")
                {
                    return Err(e);
                }
            }
        }
        let wait = reconnect_backoff(attempt);
        attempt += 1;
        eprintln!(
            "rank {rank}: coordinator connection lost; reconnect attempt \
             {attempt}/{reconnect} in {}ms",
            wait.as_millis()
        );
        std::thread::sleep(wait);
    }
}

/// One dial→handshake→serve session. `Err` is a connect/handshake
/// failure (terminal: rejections mean credentials or shape are wrong);
/// `Ok(exit)` reports how an established session ended.
fn serve_session(
    dir: &Path,
    addr: &str,
    rank: usize,
    world: Option<usize>,
    fault: Option<Arc<FaultPlan>>,
    token: &str,
) -> Result<WorkerExit> {
    let (io, p) = connect_worker(addr, rank, world, dir, token, fault.clone())?;
    // Prove liveness while the request loop is deep in device compute:
    // a dedicated thread beats the coordinator's deadline even when a
    // single step legitimately outlasts `--rank-timeout`.
    let stop = Arc::new(AtomicBool::new(false));
    let beats = if io.timeout() > Duration::ZERO {
        let io = Arc::clone(&io);
        let stop = Arc::clone(&stop);
        let tick = (io.timeout() / 3).max(Duration::from_millis(10));
        Some(std::thread::spawn(move || {
            let mut last = Instant::now();
            while !stop.load(Ordering::Acquire) {
                if last.elapsed() >= tick {
                    if io.heartbeat().is_err() {
                        break;
                    }
                    last = Instant::now();
                }
                std::thread::sleep(tick.min(Duration::from_millis(50)));
            }
        }))
    } else {
        None
    };
    let comm = Communicator::remote(rank, p, io.clone(), fault.clone());
    let exit = worker_main(dir.to_path_buf(), rank, comm, fault, WorkerLink::Remote(io.clone()));
    stop.store(true, Ordering::Release);
    if let Some(h) = beats {
        let _ = h.join();
    }
    if exit == WorkerExit::LinkLost && io.disconnected_by_fault() {
        eprintln!("rank {rank}: injected fault: worker socket disconnected");
    }
    Ok(exit)
}

fn handle<'r>(
    rt: &'r Runtime,
    st: &mut WorkerState,
    packs: &mut Vec<Option<Pack<'r>>>,
    req: Req,
) -> Result<Resp> {
    match req {
        Req::SetParams(p) => {
            // Publish θ through the per-rank cache namespace: later device
            // states built against the cache hit without a transfer, and a
            // mid-pack refresh (optimizer step) re-points `theta_bufs`
            // without rebuilding any pack state.
            st.theta.bump();
            let t0 = Instant::now();
            st.theta_bufs.clear();
            for i in 0..7 {
                st.theta_bufs.push(rt.upload_keyed(
                    &st.theta.theta_key(i),
                    st.theta.generation(),
                    &p.theta_dims(i),
                    p.theta(i),
                )?);
            }
            st.params = Some(p);
            Ok(Resp::Unit { xfer: t0.elapsed().as_secs_f64() })
        }
        Req::NewComm(c) => {
            st.comm = c;
            Ok(Resp::Unit { xfer: 0.0 })
        }
        Req::ResetComm => {
            st.comm.reset();
            Ok(Resp::Unit { xfer: 0.0 })
        }
        Req::Install { slot, shard, resident } => {
            let params =
                st.params.clone().context("install before parameters were published")?;
            let mut mirror = match shard {
                RankShard::Dense(sh) => ShardSet::Dense(vec![sh]),
                RankShard::Sparse(sh) => ShardSet::Sparse(vec![sh]),
            };
            let (dev, xfer) = if resident {
                let d = AnyDeviceState::new_in(rt, &params, &mut mirror, Some(&st.theta))?;
                let x = d.last_transfer_secs();
                (Some(d), x)
            } else {
                (None, 0.0)
            };
            if packs.len() <= slot {
                packs.resize_with(slot + 1, || None);
            }
            packs[slot] = Some(Pack { mirror, dev, acts: None });
            Ok(Resp::Unit { xfer })
        }
        Req::Rebuild { slot, shard } => {
            let pack = pack_mut(packs, slot)?;
            pack.mirror = match shard {
                RankShard::Dense(sh) => ShardSet::Dense(vec![sh]),
                RankShard::Sparse(sh) => ShardSet::Sparse(vec![sh]),
            };
            pack.acts = None;
            let xfer = match pack.dev.as_mut() {
                Some(d) => {
                    d.rebuild(&mut pack.mirror)?;
                    d.last_transfer_secs()
                }
                None => 0.0,
            };
            Ok(Resp::Unit { xfer })
        }
        Req::Sync { slot, delta } => {
            let pack = pack_mut(packs, slot)?;
            match (&mut pack.mirror, delta) {
                (ShardSet::Dense(shards), SyncDelta::Dense { rows, cols }) => {
                    shards[0].apply_removed_deltas(&rows, &cols);
                }
                (ShardSet::Sparse(shards), SyncDelta::Sparse { tiles }) => {
                    for (t, w) in tiles {
                        shards[0].overwrite_tile_mask(t as usize, w);
                    }
                }
                _ => bail!("sync delta storage mode does not match the installed pack"),
            }
            let xfer = match pack.dev.as_mut() {
                Some(d) => {
                    d.sync(&mut pack.mirror)?;
                    d.last_transfer_secs()
                }
                None => {
                    // Fresh mode re-uploads from the (now updated) mirror
                    // per evaluation; the deltas are already applied.
                    pack.mirror.clear_dirty();
                    0.0
                }
            };
            Ok(Resp::Unit { xfer })
        }
        Req::Forward { slot, f } => {
            if st.fail_next {
                st.fail_next = false;
                bail!("injected failure (test hook)");
            }
            let step = st.fwd_steps;
            st.fwd_steps += 1;
            if let Some(plan) = &st.fault {
                match plan.fire(st.rank, step, None) {
                    None => {}
                    Some(FaultKind::Slow(d)) => std::thread::sleep(d),
                    Some(FaultKind::Err) => {
                        bail!("injected fault (rank {}, forward step {step})", st.rank)
                    }
                    Some(FaultKind::Panic) => {
                        panic!("injected fault (rank {}, forward step {step})", st.rank)
                    }
                    // Transport kinds fire at the frame send site, never
                    // at the forward-step site.
                    Some(FaultKind::Drop | FaultKind::Delay(_)) => unreachable!(),
                }
            }
            let params =
                st.params.clone().context("forward before parameters were published")?;
            let pack = pack_mut(packs, slot)?;
            run_forward(rt, st, &params, pack, f)
        }
        Req::Backward { slot, l, onehot, targets } => {
            let params =
                st.params.clone().context("backward before parameters were published")?;
            let pack = pack_mut(packs, slot)?;
            run_backward(rt, st, &params, pack, l, &onehot, &targets)
        }
        Req::Uninstall { slot } => {
            if let Some(p) = packs.get_mut(slot) {
                *p = None;
            }
            Ok(Resp::Unit { xfer: 0.0 })
        }
        Req::Stats => Ok(Resp::Stats(rt.stats())),
        Req::InjectFailure => {
            st.fail_next = true;
            Ok(Resp::Unit { xfer: 0.0 })
        }
        Req::Shutdown => unreachable!("shutdown handled by the worker loop"),
    }
}

fn run_forward<'r>(
    rt: &'r Runtime,
    st: &WorkerState,
    params: &Params,
    pack: &mut Pack<'r>,
    f: FwdReq,
) -> Result<Resp> {
    let FwdReq { l, save, skip_zero, s, c, deg } = f;
    // Refresh the per-step masks shipped with the request: S/C (and the
    // sparse live-degree vector) are owned by the coordinator's candidate
    // logic, so they arrive fresh instead of being replayed as deltas.
    match &mut pack.mirror {
        ShardSet::Dense(shards) => {
            let sh = &mut shards[0];
            ensure!(
                s.len() == sh.s.len() && c.len() == sh.c.len(),
                "forward mask shape mismatch (repack without rebuild?)"
            );
            sh.s = s;
            sh.c = c;
        }
        ShardSet::Sparse(shards) => {
            let sh = &mut shards[0];
            let deg = deg.context("sparse forward request without a degree vector")?;
            ensure!(
                s.len() == sh.s.len() && c.len() == sh.c.len() && deg.len() == sh.deg.len(),
                "forward mask shape mismatch (repack without rebuild?)"
            );
            sh.s = s;
            sh.c = c;
            sh.deg = deg;
        }
    }
    if pack.dev.is_some() {
        ensure!(st.theta_bufs.len() == 7, "device-resident forward without published θ");
    }
    match (&pack.mirror, &pack.dev) {
        (ShardSet::Dense(shards), dev) => {
            let d = match dev {
                Some(AnyDeviceState::Dense(d)) => Some(d),
                None => None,
                Some(AnyDeviceState::Sparse(_)) => bail!("sparse device state on dense pack"),
            };
            forward_dense(rt, st, params, &shards[0], d, l, save, skip_zero, &mut pack.acts)
        }
        (ShardSet::Sparse(shards), dev) => {
            let d = match dev {
                Some(AnyDeviceState::Sparse(d)) => Some(d),
                None => None,
                Some(AnyDeviceState::Dense(_)) => bail!("dense device state on sparse pack"),
            };
            forward_sparse_rank(rt, st, params, &shards[0], d, l, save, skip_zero, &mut pack.acts)
        }
    }
}

/// Re-interleave an all-gather of per-rank [B, NI] parts into the global
/// [B, N] layout (ranks own contiguous row blocks, but batch elements
/// interleave them).
fn scatter_gathered(gathered: &[f32], p: usize, b: usize, n: usize, ni: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; b * n];
    for r in 0..p {
        let r0 = r * ni;
        for g in 0..b {
            let src = r * b * ni + g * ni;
            out[g * n + r0..g * n + r0 + ni].copy_from_slice(&gathered[src..src + ni]);
        }
    }
    out
}

/// This rank's slice of an all-reduced [B, K, N] message.
fn slice_rows(full: &[f32], b: usize, k: usize, n: usize, ni: usize, row0: usize) -> Vec<f32> {
    let mut sl = vec![0.0f32; b * k * ni];
    for g in 0..b {
        for kk in 0..k {
            let src = g * k * n + kk * n + row0;
            let dst = g * k * ni + kk * ni;
            sl[dst..dst + ni].copy_from_slice(&full[src..src + ni]);
        }
    }
    sl
}

/// One SPMD policy evaluation on this rank's dense shard (Alg. 2 + Alg. 3):
/// the per-shard body of the lockstep `forward_dev`, with real collectives.
#[allow(clippy::too_many_arguments)]
fn forward_dense(
    rt: &Runtime,
    st: &WorkerState,
    params: &Params,
    sh: &ShardState,
    dev: Option<&DeviceState>,
    l: usize,
    save: bool,
    skip_zero: bool,
    acts_out: &mut Option<RankActs>,
) -> Result<Resp> {
    let (b, n, ni, k) = (sh.b, sh.n(), sh.ni(), params.k);
    let row0 = sh.part.row0(sh.shard);
    let p = st.comm.p();
    let resident = dev.is_some();
    let mut t = RankTiming::default();
    let th = ThetaViews::new(params, resident.then(|| st.theta_bufs.as_slice()));

    let d_s = [b, ni];
    let d_a = [b, ni, n];
    let d_e = [b, k, ni];
    let d_sum = [b, k];

    // A: device-resident across steps, or uploaded once per evaluation
    // (booked as transfer, matching the lockstep fresh path's accounting).
    let a_owned;
    let a_ref: &xla::PjRtBuffer = match dev {
        Some(d) => d.a_buf(0),
        None => {
            let t0 = Instant::now();
            a_owned = rt.upload(&d_a, &sh.a)?;
            t.h2d += t0.elapsed().as_secs_f64();
            &a_owned
        }
    };

    // Stage 1: pre (device-resident across all L layers when resident).
    let name_pre = artifact_name("embed_pre", b, n, ni, k);
    let pre_inputs =
        [th.t(0), th.t(1), th.t(2), Input::Host(HostTensor::new(&d_s, &sh.s)), Input::Dev(a_ref)];
    let mut pre_d: Option<xla::PjRtBuffer> = None;
    let mut pre_h: Vec<f32> = Vec::new();
    {
        let t0 = Instant::now();
        if resident {
            let buf = rt.execute_d(&name_pre, &pre_inputs)?.into_iter().next().unwrap();
            if save {
                pre_h = rt.fetch(&buf)?;
            }
            pre_d = Some(buf);
        } else {
            pre_h = rt.execute_in(&name_pre, &pre_inputs)?.into_iter().next().unwrap();
        }
        t.compute += t0.elapsed().as_secs_f64();
    }

    // Embedding layers with REAL all-reduce between ranks (Alg. 2 line 12).
    let name_msg = artifact_name("embed_msg", b, n, ni, k);
    let name_cmb = artifact_name("embed_combine", b, n, ni, k);
    let mut embed_d: Option<xla::PjRtBuffer> = None;
    let mut embed_h: Vec<f32> = vec![0.0f32; b * k * ni];
    let mut nbr_acts: Vec<Vec<f32>> = Vec::new();
    for layer in 0..l {
        let skip_msg = layer == 0 && skip_zero;
        let nbr_slice: Vec<f32> = if skip_msg {
            // Elided layer-0 message: the slice is exactly zeros (fwd.rs).
            vec![0.0f32; b * k * ni]
        } else {
            let mut partial: Vec<f32>;
            {
                let t0 = Instant::now();
                if resident {
                    let embed_input = if layer == 0 {
                        Input::Dev(dev.unwrap().zero_buf())
                    } else {
                        Input::Dev(embed_d.as_ref().unwrap())
                    };
                    let buf = rt
                        .execute_d(&name_msg, &[embed_input, Input::Dev(a_ref)])?
                        .into_iter()
                        .next()
                        .unwrap();
                    partial = rt.fetch(&buf)?;
                } else {
                    partial = rt
                        .execute_in(
                            &name_msg,
                            &[Input::Host(HostTensor::new(&d_e, &embed_h)), Input::Dev(a_ref)],
                        )?
                        .into_iter()
                        .next()
                        .unwrap();
                }
                t.compute += t0.elapsed().as_secs_f64();
            }
            let tc = Instant::now();
            st.comm.all_reduce_sum(&mut partial)?;
            t.comm += tc.elapsed().as_secs_f64();
            t.comm_bytes += 4 * (b * k * n) as u64;
            t.collectives += 1;
            let t0 = Instant::now();
            let sl = slice_rows(&partial, b, k, n, ni, row0);
            t.host += t0.elapsed().as_secs_f64();
            sl
        };
        if save {
            nbr_acts.push(nbr_slice.clone());
        }
        // Stage 3: combine.
        let pre_input = if resident {
            Input::Dev(pre_d.as_ref().unwrap())
        } else {
            Input::Host(HostTensor::new(&d_e, &pre_h))
        };
        let cmb_inputs = [th.t(3), pre_input, Input::Host(HostTensor::new(&d_e, &nbr_slice))];
        let t0 = Instant::now();
        if resident {
            let buf = rt.execute_d(&name_cmb, &cmb_inputs)?.into_iter().next().unwrap();
            if save {
                embed_h = rt.fetch(&buf)?;
            }
            embed_d = Some(buf);
        } else {
            embed_h = rt.execute_in(&name_cmb, &cmb_inputs)?.into_iter().next().unwrap();
        }
        t.compute += t0.elapsed().as_secs_f64();
    }

    // Final-embedding input shared by stages 4 and 5 (zeros block covers
    // the L = 0 degenerate case on the resident path).
    let e_input = if resident {
        match &embed_d {
            Some(buf) => Input::Dev(buf),
            None => Input::Dev(dev.unwrap().zero_buf()),
        }
    } else {
        Input::Host(HostTensor::new(&d_e, &embed_h))
    };

    // Stage 4 + ALL-REDUCE (Alg. 3 lines 4-5).
    let name_qsum = artifact_name("q_sum", b, n, ni, k);
    let mut sum_all: Vec<f32>;
    {
        let t0 = Instant::now();
        if resident {
            let buf = rt.execute_d(&name_qsum, &[e_input])?.into_iter().next().unwrap();
            sum_all = rt.fetch(&buf)?;
        } else {
            sum_all = rt.execute_in(&name_qsum, &[e_input])?.into_iter().next().unwrap();
        }
        t.compute += t0.elapsed().as_secs_f64();
    }
    let tc = Instant::now();
    st.comm.all_reduce_sum(&mut sum_all)?;
    t.comm += tc.elapsed().as_secs_f64();
    t.comm_bytes += 4 * (b * k) as u64;
    t.collectives += 1;

    // Stage 5 + ALL-GATHER of scores (Alg. 4 line 6).
    let name_q = artifact_name("q_scores", b, n, ni, k);
    let q_inputs = [
        th.t(4),
        th.t(5),
        th.t(6),
        e_input,
        Input::Host(HostTensor::new(&d_s, &sh.c)),
        Input::Host(HostTensor::new(&d_sum, &sum_all)),
    ];
    let local: Vec<f32>;
    {
        let t0 = Instant::now();
        if resident {
            let buf = rt.execute_d(&name_q, &q_inputs)?.into_iter().next().unwrap();
            local = rt.fetch(&buf)?;
        } else {
            local = rt.execute_in(&name_q, &q_inputs)?.into_iter().next().unwrap();
        }
        t.compute += t0.elapsed().as_secs_f64();
    }
    let tc = Instant::now();
    let gathered = st.comm.all_gather(&local)?;
    t.comm += tc.elapsed().as_secs_f64();
    t.comm_bytes += 4 * (b * ni * p) as u64;
    t.collectives += 1;
    // Only rank 0 returns the gathered scores; skipping the B×N re-
    // interleave on the other ranks keeps their host column honest.
    let t0 = Instant::now();
    let scores = (st.rank == 0).then(|| scatter_gathered(&gathered, p, b, n, ni));
    t.host += t0.elapsed().as_secs_f64();

    *acts_out = save.then(|| RankActs {
        pre: pre_h,
        nbr_slice: nbr_acts,
        embed_final: embed_h,
        sum_all,
        scores_i: local,
    });
    Ok(Resp::Fwd { scores, timing: t })
}

/// One SPMD policy evaluation on this rank's sparse shard (DESIGN.md §7):
/// the per-shard body of the lockstep `forward_sparse` with real
/// collectives — tile sweep into the local B×K×N scratch, all-reduce,
/// slice, N-free combine/q stages.
#[allow(clippy::too_many_arguments)]
fn forward_sparse_rank(
    rt: &Runtime,
    st: &WorkerState,
    params: &Params,
    sh: &SparseShard,
    dev: Option<&SparseDeviceState>,
    l: usize,
    save: bool,
    skip_zero: bool,
    acts_out: &mut Option<RankActs>,
) -> Result<Resp> {
    let (b, n, ni, k, chunk) = (sh.b, sh.n(), sh.ni(), params.k, sh.chunk);
    let row0 = sh.part.row0(sh.shard);
    let p = st.comm.p();
    let resident = dev.is_some();
    let mut t = RankTiming::default();
    let th = ThetaViews::new(params, resident.then(|| st.theta_bufs.as_slice()));

    let d_s = [b, ni];
    let d_e = [b, k, ni];
    let d_ec = [b, k, chunk];
    let d_sum = [b, k];

    // Edge tiles: device-resident, or uploaded once per evaluation.
    let tile_owned: Vec<Vec<(xla::PjRtBuffer, xla::PjRtBuffer, xla::PjRtBuffer)>> = if resident {
        Vec::new()
    } else {
        let mut tmp = StepTiming::new(1);
        let owned = upload_tiles_fresh(rt, std::slice::from_ref(sh), &mut tmp)?;
        t.h2d += tmp.h2d;
        owned
    };

    // Stage 1: degree-vector pre.
    let name_pre = sparse_pre_name("embed_pre_sp", b, ni, k);
    let pre_h: Vec<f32>;
    {
        let t0 = Instant::now();
        pre_h = rt
            .execute_in(
                &name_pre,
                &[
                    th.t(0),
                    th.t(1),
                    th.t(2),
                    Input::Host(HostTensor::new(&d_s, &sh.s)),
                    Input::Host(HostTensor::new(&d_s, &sh.deg)),
                ],
            )?
            .into_iter()
            .next()
            .unwrap();
        t.compute += t0.elapsed().as_secs_f64();
    }

    let name_cmb = artifact_name("embed_combine", b, n, ni, k);
    let mut embed_h = vec![0.0f32; b * k * ni];
    let mut nbr_acts: Vec<Vec<f32>> = Vec::new();
    let mut nbr_full = vec![0.0f32; b * k * n];
    let mut echunk = vec![0.0f32; b * k * chunk];
    for layer in 0..l {
        let skip_msg = layer == 0 && skip_zero;
        let nbr_slice: Vec<f32> = if skip_msg {
            vec![0.0f32; b * k * ni]
        } else {
            nbr_full.fill(0.0);
            let tiles = &sh.tiles;
            let mut ti = 0usize;
            while ti < tiles.len() {
                let sc = tiles[ti].sc;
                // Source-chunk slice of the local embedding, zero-padded
                // past NI (padding rows are never referenced by live edges).
                let t0 = Instant::now();
                let lo = sc * chunk;
                let hi = (lo + chunk).min(ni);
                echunk.fill(0.0);
                if lo < ni {
                    for g in 0..b {
                        for kk in 0..k {
                            let so = g * k * ni + kk * ni + lo;
                            let eo = g * k * chunk + kk * chunk;
                            echunk[eo..eo + (hi - lo)]
                                .copy_from_slice(&embed_h[so..so + (hi - lo)]);
                        }
                    }
                }
                t.host += t0.elapsed().as_secs_f64();
                while ti < tiles.len() && tiles[ti].sc == sc {
                    let tile = &tiles[ti];
                    let name = sparse_msg_name("embed_msg_sp", b, tile.cap, chunk, k);
                    let (src_in, dst_in, w_in) = match dev {
                        Some(d) => (
                            Input::Dev(&d.src[0][ti]),
                            Input::Dev(&d.dst[0][ti]),
                            Input::Dev(&d.w[0][ti]),
                        ),
                        None => {
                            let (sb, db, wb) = &tile_owned[0][ti];
                            (Input::Dev(sb), Input::Dev(db), Input::Dev(wb))
                        }
                    };
                    let inputs =
                        [Input::Host(HostTensor::new(&d_ec, &echunk)), src_in, dst_in, w_in];
                    let t0 = Instant::now();
                    let part = rt.execute_in(&name, &inputs)?.into_iter().next().unwrap();
                    t.compute += t0.elapsed().as_secs_f64();
                    let t0 = Instant::now();
                    let dlo = tile.dc * chunk;
                    let dhi = (dlo + chunk).min(n);
                    for g in 0..b {
                        for kk in 0..k {
                            let no = g * k * n + kk * n + dlo;
                            let po = g * k * chunk + kk * chunk;
                            add_assign(
                                &mut nbr_full[no..no + (dhi - dlo)],
                                &part[po..po + (dhi - dlo)],
                            );
                        }
                    }
                    t.host += t0.elapsed().as_secs_f64();
                    ti += 1;
                }
            }
            let tc = Instant::now();
            st.comm.all_reduce_sum(&mut nbr_full)?;
            t.comm += tc.elapsed().as_secs_f64();
            t.comm_bytes += 4 * (b * k * n) as u64;
            t.collectives += 1;
            let t0 = Instant::now();
            let sl = slice_rows(&nbr_full, b, k, n, ni, row0);
            t.host += t0.elapsed().as_secs_f64();
            sl
        };
        if save {
            nbr_acts.push(nbr_slice.clone());
        }
        let t0 = Instant::now();
        embed_h = rt
            .execute_in(
                &name_cmb,
                &[
                    th.t(3),
                    Input::Host(HostTensor::new(&d_e, &pre_h)),
                    Input::Host(HostTensor::new(&d_e, &nbr_slice)),
                ],
            )?
            .into_iter()
            .next()
            .unwrap();
        t.compute += t0.elapsed().as_secs_f64();
    }

    // Stage 4 + ALL-REDUCE (shared N-free stage).
    let name_qsum = artifact_name("q_sum", b, n, ni, k);
    let mut sum_all: Vec<f32>;
    {
        let t0 = Instant::now();
        sum_all = rt
            .execute_in(&name_qsum, &[Input::Host(HostTensor::new(&d_e, &embed_h))])?
            .into_iter()
            .next()
            .unwrap();
        t.compute += t0.elapsed().as_secs_f64();
    }
    let tc = Instant::now();
    st.comm.all_reduce_sum(&mut sum_all)?;
    t.comm += tc.elapsed().as_secs_f64();
    t.comm_bytes += 4 * (b * k) as u64;
    t.collectives += 1;

    // Stage 5 + ALL-GATHER of scores.
    let name_q = artifact_name("q_scores", b, n, ni, k);
    let local: Vec<f32>;
    {
        let t0 = Instant::now();
        local = rt
            .execute_in(
                &name_q,
                &[
                    th.t(4),
                    th.t(5),
                    th.t(6),
                    Input::Host(HostTensor::new(&d_e, &embed_h)),
                    Input::Host(HostTensor::new(&d_s, &sh.c)),
                    Input::Host(HostTensor::new(&d_sum, &sum_all)),
                ],
            )?
            .into_iter()
            .next()
            .unwrap();
        t.compute += t0.elapsed().as_secs_f64();
    }
    let tc = Instant::now();
    let gathered = st.comm.all_gather(&local)?;
    t.comm += tc.elapsed().as_secs_f64();
    t.comm_bytes += 4 * (b * ni * p) as u64;
    t.collectives += 1;
    // Only rank 0 returns the gathered scores; skipping the B×N re-
    // interleave on the other ranks keeps their host column honest.
    let t0 = Instant::now();
    let scores = (st.rank == 0).then(|| scatter_gathered(&gathered, p, b, n, ni));
    t.host += t0.elapsed().as_secs_f64();

    *acts_out = save.then(|| RankActs {
        pre: pre_h,
        nbr_slice: nbr_acts,
        embed_final: embed_h,
        sum_all,
        scores_i: local,
    });
    Ok(Resp::Fwd { scores, timing: t })
}

fn run_backward<'r>(
    rt: &'r Runtime,
    st: &WorkerState,
    params: &Params,
    pack: &mut Pack<'r>,
    l: usize,
    onehot: &[f32],
    targets: &[f32],
) -> Result<Resp> {
    let Pack { mirror, dev, acts } = pack;
    let acts = acts.as_ref().context("rank backward before a saved forward")?;
    match (&*mirror, &*dev) {
        (ShardSet::Dense(shards), dev) => {
            let d = match dev {
                Some(AnyDeviceState::Dense(d)) => Some(d),
                None => None,
                Some(AnyDeviceState::Sparse(_)) => bail!("sparse device state on dense pack"),
            };
            backward_dense(rt, st, params, &shards[0], d, acts, l, onehot, targets)
        }
        (ShardSet::Sparse(shards), dev) => {
            let d = match dev {
                Some(AnyDeviceState::Sparse(d)) => Some(d),
                None => None,
                Some(AnyDeviceState::Dense(_)) => bail!("dense device state on sparse pack"),
            };
            backward_sparse_rank(rt, st, params, &shards[0], d, acts, l, onehot, targets)
        }
    }
}

/// Shared loss adjoint: local q_sa partial, REAL all-reduce (B floats),
/// replicated loss + this rank's d_scores. Returns (loss, d_scores).
#[allow(clippy::too_many_arguments)]
fn loss_adjoint(
    st: &WorkerState,
    t: &mut RankTiming,
    scores_i: &[f32],
    onehot: &[f32],
    targets: &[f32],
    b: usize,
    n: usize,
    ni: usize,
    row0: usize,
) -> Result<(f32, Vec<f32>)> {
    let t0 = Instant::now();
    let mut onehot_i = vec![0.0f32; b * ni];
    for g in 0..b {
        onehot_i[g * ni..(g + 1) * ni].copy_from_slice(&onehot[g * n + row0..g * n + row0 + ni]);
    }
    let mut q_sa = vec![0.0f32; b];
    for g in 0..b {
        for r in 0..ni {
            q_sa[g] += scores_i[g * ni + r] * onehot_i[g * ni + r];
        }
    }
    t.host += t0.elapsed().as_secs_f64();
    let tc = Instant::now();
    st.comm.all_reduce_sum(&mut q_sa)?;
    t.comm += tc.elapsed().as_secs_f64();
    t.comm_bytes += 4 * b as u64;
    t.collectives += 1;
    let t0 = Instant::now();
    let mut loss = 0.0f32;
    let mut d_qsa = vec![0.0f32; b];
    for g in 0..b {
        let diff = q_sa[g] - targets[g];
        loss += diff * diff / b as f32;
        d_qsa[g] = 2.0 * diff / b as f32;
    }
    let d_scores: Vec<f32> =
        (0..b * ni).map(|idx| d_qsa[idx / ni] * onehot_i[idx]).collect();
    t.host += t0.elapsed().as_secs_f64();
    Ok((loss, d_scores))
}

fn accumulate(grads: &mut [f32], offset: usize, part: &[f32]) {
    add_assign(&mut grads[offset..offset + part.len()], part);
}

/// Column-broadcast the all-reduced d_sum into the embedding cotangent
/// (the q_sum collective's adjoint), in place.
fn add_sum_columns(d_embed: &mut [f32], d_sum_all: &[f32], b: usize, k: usize, ni: usize) {
    for g in 0..b {
        for kk in 0..k {
            let base = g * k * ni + kk * ni;
            let add = d_sum_all[g * k + kk];
            for r in 0..ni {
                d_embed[base + r] += add;
            }
        }
    }
}

/// The all-gather collective adjoint: gather this rank's cotangent slice
/// and re-interleave into the global [B, K, N] cotangent.
fn gather_cotangent(
    st: &WorkerState,
    t: &mut RankTiming,
    d_nbr: &[f32],
    b: usize,
    k: usize,
    n: usize,
    ni: usize,
) -> Result<Vec<f32>> {
    let p = st.comm.p();
    let tc = Instant::now();
    let gathered = st.comm.all_gather(d_nbr)?;
    t.comm += tc.elapsed().as_secs_f64();
    t.comm_bytes += 4 * (b * k * ni * p) as u64;
    t.collectives += 1;
    let t0 = Instant::now();
    let mut d_partial = vec![0.0f32; b * k * n];
    for r in 0..p {
        let r0 = r * ni;
        for g in 0..b {
            for kk in 0..k {
                let dst = g * k * n + kk * n + r0;
                let src = r * b * k * ni + g * k * ni + kk * ni;
                d_partial[dst..dst + ni].copy_from_slice(&gathered[src..src + ni]);
            }
        }
    }
    t.host += t0.elapsed().as_secs_f64();
    Ok(d_partial)
}

/// Shared stage-5 adjoint + the q_sum collective's adjoint (an N-free
/// stage, identical on the dense and sparse paths): run `q_scores_bwd`,
/// accumulate the θ5..θ7 gradients, all-reduce the sum cotangent, and
/// return the embedding cotangent with the column broadcast applied.
#[allow(clippy::too_many_arguments)]
fn stage5_adjoint(
    rt: &Runtime,
    st: &WorkerState,
    t: &mut RankTiming,
    th: &ThetaViews,
    params: &Params,
    acts: &RankActs,
    c: &[f32],
    d_scores: &[f32],
    grads: &mut [f32],
    b: usize,
    n: usize,
    ni: usize,
    k: usize,
) -> Result<Vec<f32>> {
    let (d_s, d_e, d_sum) = ([b, ni], [b, k, ni], [b, k]);
    let name = artifact_name("q_scores_bwd", b, n, ni, k);
    let out = {
        let t0 = Instant::now();
        let out = rt.execute_in(
            &name,
            &[
                th.t(4),
                th.t(5),
                th.t(6),
                Input::Host(HostTensor::new(&d_e, &acts.embed_final)),
                Input::Host(HostTensor::new(&d_s, c)),
                Input::Host(HostTensor::new(&d_sum, &acts.sum_all)),
                Input::Host(HostTensor::new(&d_s, d_scores)),
            ],
        )?;
        t.compute += t0.elapsed().as_secs_f64();
        out
    };
    let mut it = out.into_iter();
    let (d5, d6, d7, d_e_i, d_sa) = (
        it.next().unwrap(),
        it.next().unwrap(),
        it.next().unwrap(),
        it.next().unwrap(),
        it.next().unwrap(),
    );
    let t0 = Instant::now();
    accumulate(grads, params.offset(4), &d5);
    accumulate(grads, params.offset(5), &d6);
    accumulate(grads, params.offset(6), &d7);
    t.host += t0.elapsed().as_secs_f64();
    // q_sum collective adjoint: all-reduce d_sum, broadcast into columns.
    let mut d_sum_all = d_sa;
    let tc = Instant::now();
    st.comm.all_reduce_sum(&mut d_sum_all)?;
    t.comm += tc.elapsed().as_secs_f64();
    t.comm_bytes += 4 * (b * k) as u64;
    t.collectives += 1;
    let mut d_embed = d_e_i;
    let t0 = Instant::now();
    add_sum_columns(&mut d_embed, &d_sum_all, b, k, ni);
    t.host += t0.elapsed().as_secs_f64();
    Ok(d_embed)
}

/// Shared per-layer combine adjoint (another N-free stage): run
/// `embed_combine_bwd`, accumulate θ4 and the pre cotangent, and return
/// the layer-message cotangent slice.
#[allow(clippy::too_many_arguments)]
fn combine_bwd_step(
    rt: &Runtime,
    t: &mut RankTiming,
    th: &ThetaViews,
    params: &Params,
    acts: &RankActs,
    layer: usize,
    d_embed: &[f32],
    grads: &mut [f32],
    d_pre_acc: &mut [f32],
    b: usize,
    n: usize,
    ni: usize,
    k: usize,
) -> Result<Vec<f32>> {
    let d_e = [b, k, ni];
    let name = artifact_name("embed_combine_bwd", b, n, ni, k);
    let out = {
        let t0 = Instant::now();
        let out = rt.execute_in(
            &name,
            &[
                th.t(3),
                Input::Host(HostTensor::new(&d_e, &acts.pre)),
                Input::Host(HostTensor::new(&d_e, &acts.nbr_slice[layer])),
                Input::Host(HostTensor::new(&d_e, d_embed)),
            ],
        )?;
        t.compute += t0.elapsed().as_secs_f64();
        out
    };
    let mut it = out.into_iter();
    let (d4, d_pre, d_nbr) = (it.next().unwrap(), it.next().unwrap(), it.next().unwrap());
    let t0 = Instant::now();
    accumulate(grads, params.offset(3), &d4);
    add_assign(d_pre_acc, &d_pre);
    t.host += t0.elapsed().as_secs_f64();
    Ok(d_nbr)
}

/// Accumulate a stage-1 adjoint's θ1..θ3 outputs and run the final REAL
/// gradient all-reduce (θ1-θ7 = 4K²+4K floats, §5.1(3)).
fn finish_grads(
    st: &WorkerState,
    t: &mut RankTiming,
    params: &Params,
    grads: &mut Vec<f32>,
    d123: Vec<Vec<f32>>,
) -> Result<()> {
    let t0 = Instant::now();
    for (i, d) in d123.into_iter().enumerate() {
        accumulate(grads, params.offset(i), &d);
    }
    t.host += t0.elapsed().as_secs_f64();
    let tc = Instant::now();
    st.comm.all_reduce_sum(grads)?;
    t.comm += tc.elapsed().as_secs_f64();
    t.comm_bytes += 4 * grads.len() as u64;
    t.collectives += 1;
    Ok(())
}

/// This rank's distributed backward on the dense path: the per-shard body
/// of the lockstep `backward_dev`, with the collective adjoints realized
/// as real all-reduce / all-gather operations (DESIGN.md §2/§9).
#[allow(clippy::too_many_arguments)]
fn backward_dense(
    rt: &Runtime,
    st: &WorkerState,
    params: &Params,
    sh: &ShardState,
    dev: Option<&DeviceState>,
    acts: &RankActs,
    l: usize,
    onehot: &[f32],
    targets: &[f32],
) -> Result<Resp> {
    let (b, n, ni, k) = (sh.b, sh.n(), sh.ni(), params.k);
    ensure!(onehot.len() == b * n && targets.len() == b, "loss target shape mismatch");
    let row0 = sh.part.row0(sh.shard);
    let resident = dev.is_some();
    let mut t = RankTiming::default();
    let mut grads = vec![0.0f32; params.flat.len()];
    let th = ThetaViews::new(params, resident.then(|| st.theta_bufs.as_slice()));

    let d_s = [b, ni];
    let d_a = [b, ni, n];
    let d_e = [b, k, ni];
    let d_m = [b, k, n];

    let a_owned;
    let a_ref: &xla::PjRtBuffer = match dev {
        Some(d) => d.a_buf(0),
        None => {
            let t0 = Instant::now();
            a_owned = rt.upload(&d_a, &sh.a)?;
            t.h2d += t0.elapsed().as_secs_f64();
            &a_owned
        }
    };

    let (loss, d_scores) =
        loss_adjoint(st, &mut t, &acts.scores_i, onehot, targets, b, n, ni, row0)?;

    // ---- stage 5 adjoint + q_sum collective adjoint (shared helper) ----
    let mut d_embed =
        stage5_adjoint(rt, st, &mut t, &th, params, acts, &sh.c, &d_scores, &mut grads, b, n,
                       ni, k)?;

    // ---- layer loop, reversed ----
    let name_mbwd = artifact_name("embed_msg_bwd", b, n, ni, k);
    let mut d_pre_acc = vec![0.0f32; b * k * ni];
    for layer in (0..l).rev() {
        let d_nbr = combine_bwd_step(
            rt, &mut t, &th, params, acts, layer, &d_embed, &mut grads, &mut d_pre_acc, b, n,
            ni, k,
        )?;
        if layer == 0 {
            // Layer 0's message input is the zeros constant: its cotangent
            // is discarded, so the all-gather + msg_bwd are elided.
            break;
        }
        let d_partial = gather_cotangent(st, &mut t, &d_nbr, b, k, n, ni)?;
        let t0 = Instant::now();
        d_embed = rt
            .execute_in(
                &name_mbwd,
                &[Input::Dev(a_ref), Input::Host(HostTensor::new(&d_m, &d_partial))],
            )?
            .into_iter()
            .next()
            .unwrap();
        t.compute += t0.elapsed().as_secs_f64();
    }

    // ---- stage 1 adjoint ----
    let name_pbwd = artifact_name("embed_pre_bwd", b, n, ni, k);
    let out = {
        let t0 = Instant::now();
        let out = rt.execute_in(
            &name_pbwd,
            &[
                th.t(0),
                th.t(1),
                th.t(2),
                Input::Host(HostTensor::new(&d_s, &sh.s)),
                Input::Dev(a_ref),
                Input::Host(HostTensor::new(&d_e, &d_pre_acc)),
            ],
        )?;
        t.compute += t0.elapsed().as_secs_f64();
        out
    };
    finish_grads(st, &mut t, params, &mut grads, out)?;

    Ok(Resp::Bwd { loss, grads: (st.rank == 0).then_some(grads), timing: t })
}

/// This rank's distributed backward on the sparse CSR path: the per-shard
/// body of the lockstep `backward_sparse` with real collective adjoints —
/// reversed tile sweep (`embed_msg_sp_bwd` per tile) and the degree-vector
/// stage-1 adjoint (DESIGN.md §7/§9).
#[allow(clippy::too_many_arguments)]
fn backward_sparse_rank(
    rt: &Runtime,
    st: &WorkerState,
    params: &Params,
    sh: &SparseShard,
    dev: Option<&SparseDeviceState>,
    acts: &RankActs,
    l: usize,
    onehot: &[f32],
    targets: &[f32],
) -> Result<Resp> {
    let (b, n, ni, k, chunk) = (sh.b, sh.n(), sh.ni(), params.k, sh.chunk);
    ensure!(onehot.len() == b * n && targets.len() == b, "loss target shape mismatch");
    let row0 = sh.part.row0(sh.shard);
    let resident = dev.is_some();
    let mut t = RankTiming::default();
    let mut grads = vec![0.0f32; params.flat.len()];
    let th = ThetaViews::new(params, resident.then(|| st.theta_bufs.as_slice()));

    let d_s = [b, ni];
    let d_e = [b, k, ni];
    let d_ec = [b, k, chunk];

    let tile_owned: Vec<Vec<(xla::PjRtBuffer, xla::PjRtBuffer, xla::PjRtBuffer)>> = if resident {
        Vec::new()
    } else {
        let mut tmp = StepTiming::new(1);
        let owned = upload_tiles_fresh(rt, std::slice::from_ref(sh), &mut tmp)?;
        t.h2d += tmp.h2d;
        owned
    };

    let (loss, d_scores) =
        loss_adjoint(st, &mut t, &acts.scores_i, onehot, targets, b, n, ni, row0)?;

    // ---- stage 5 adjoint + q_sum collective adjoint (shared helper) ----
    let mut d_embed =
        stage5_adjoint(rt, st, &mut t, &th, params, acts, &sh.c, &d_scores, &mut grads, b, n,
                       ni, k)?;

    // ---- layer loop, reversed ----
    let mut d_pre_acc = vec![0.0f32; b * k * ni];
    let mut dchunk = vec![0.0f32; b * k * chunk];
    for layer in (0..l).rev() {
        let d_nbr = combine_bwd_step(
            rt, &mut t, &th, params, acts, layer, &d_embed, &mut grads, &mut d_pre_acc, b, n,
            ni, k,
        )?;
        if layer == 0 {
            break;
        }
        let d_partial = gather_cotangent(st, &mut t, &d_nbr, b, k, n, ni)?;
        // Reversed tile sweep: destination-chunk sliced in, source-chunk
        // accumulated out (the transpose of the forward sweep).
        let mut d_emb = vec![0.0f32; b * k * ni];
        let tiles = &sh.tiles;
        let mut ti = 0usize;
        while ti < tiles.len() {
            let dc = tiles[ti].dc;
            let t0 = Instant::now();
            let dlo = dc * chunk;
            let dhi = (dlo + chunk).min(n);
            dchunk.fill(0.0);
            for g in 0..b {
                for kk in 0..k {
                    let so = g * k * n + kk * n + dlo;
                    let eo = g * k * chunk + kk * chunk;
                    dchunk[eo..eo + (dhi - dlo)]
                        .copy_from_slice(&d_partial[so..so + (dhi - dlo)]);
                }
            }
            t.host += t0.elapsed().as_secs_f64();
            while ti < tiles.len() && tiles[ti].dc == dc {
                let tile = &tiles[ti];
                let name = sparse_msg_name("embed_msg_sp_bwd", b, tile.cap, chunk, k);
                let (src_in, dst_in, w_in) = match dev {
                    Some(d) => (
                        Input::Dev(&d.src[0][ti]),
                        Input::Dev(&d.dst[0][ti]),
                        Input::Dev(&d.w[0][ti]),
                    ),
                    None => {
                        let (sb, db, wb) = &tile_owned[0][ti];
                        (Input::Dev(sb), Input::Dev(db), Input::Dev(wb))
                    }
                };
                let inputs = [Input::Host(HostTensor::new(&d_ec, &dchunk)), src_in, dst_in, w_in];
                let t0 = Instant::now();
                let part = rt.execute_in(&name, &inputs)?.into_iter().next().unwrap();
                t.compute += t0.elapsed().as_secs_f64();
                let t0 = Instant::now();
                let slo = tile.sc * chunk;
                let shi = (slo + chunk).min(ni);
                for g in 0..b {
                    for kk in 0..k {
                        let no = g * k * ni + kk * ni + slo;
                        let po = g * k * chunk + kk * chunk;
                        let len = shi - slo;
                        add_assign(&mut d_emb[no..no + len], &part[po..po + len]);
                    }
                }
                t.host += t0.elapsed().as_secs_f64();
                ti += 1;
            }
        }
        d_embed = d_emb;
    }

    // ---- stage 1 adjoint (degree-vector variant) ----
    let name_pbwd = sparse_pre_name("embed_pre_sp_bwd", b, ni, k);
    let out = {
        let t0 = Instant::now();
        let out = rt.execute_in(
            &name_pbwd,
            &[
                th.t(0),
                th.t(1),
                th.t(2),
                Input::Host(HostTensor::new(&d_s, &sh.s)),
                Input::Host(HostTensor::new(&d_s, &sh.deg)),
                Input::Host(HostTensor::new(&d_e, &d_pre_acc)),
            ],
        )?;
        t.compute += t0.elapsed().as_secs_f64();
        out
    };
    finish_grads(st, &mut t, params, &mut grads, out)?;

    Ok(Resp::Bwd { loss, grads: (st.rank == 0).then_some(grads), timing: t })
}
