//! `oggm` — OpenGraphGym-MG command-line entry point.
//!
//! Subcommands:
//!   info                         print artifact/manifest + device info
//!   train  [--opts]              distributed RL training (Alg. 5)
//!   infer  [--opts]              distributed RL inference (Alg. 4, --scenario)
//!   solve  [--opts]              classical baselines (exact / greedy / 2-approx)
//!   batch-solve [--opts]         batched inference over a job manifest (§Batch)
//!   eval   [--opts]              solution-quality harness: RL vs classical
//!                                baselines, JSON report (--out, --check)
//!   serve  [--opts]              persistent solver service: job lines in,
//!                                JSONL outcomes streamed out (DESIGN.md §8);
//!                                --listen ADDR serves the same protocol over
//!                                TCP with continuous batching, per-tenant
//!                                quotas (--quota), a bounded admission queue
//!                                (--queue-cap), and --max-conns for
//!                                deterministic shutdown (DESIGN.md §10);
//!                                {"op":"drain"} or SIGTERM drains gracefully,
//!                                --retries / --max-rank-restarts /
//!                                --fault-plan tune fault tolerance
//!                                (DESIGN.md §11)
//!   rank   --connect ADDR --rank R [--world P]
//!                                process-separated rank worker: joins a
//!                                coordinator running --engine rank-parallel
//!                                --ranks tcp:<addr>,... (DESIGN.md §12)

use oggm::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let code = match cmd {
        "info" => oggm::coordinator::cmd::cmd_info(&args),
        "train" => oggm::coordinator::cmd::cmd_train(&args),
        "infer" => oggm::coordinator::cmd::cmd_infer(&args),
        "solve" => oggm::coordinator::cmd::cmd_solve(&args),
        "batch-solve" => oggm::coordinator::cmd::cmd_batch_solve(&args),
        "eval" => oggm::coordinator::cmd::cmd_eval(&args),
        "serve" => oggm::coordinator::cmd::cmd_serve(&args),
        "rank" => oggm::coordinator::cmd::cmd_rank(&args),
        _ => {
            eprintln!(
                "usage: oggm <info|train|infer|solve|batch-solve|eval|serve|rank> \
                 [--key value ...]\n\
                 see README.md for options"
            );
            Ok(())
        }
    }
    .map(|_| 0)
    .unwrap_or_else(|e| {
        eprintln!("error: {e:#}");
        1
    });
    std::process::exit(code);
}
