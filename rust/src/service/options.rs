//! The unified options layer: ONE builder-style configuration type that
//! every front door (CLI subcommands, [`crate::service::Service`], library
//! callers) fills once and lowers into the per-loop configs (`InferCfg`,
//! `TrainCfg`, `BatchCfg`) via `From` conversions — so p/l/storage/policy/
//! compaction/seed plumbing cannot drift between entry points.
//!
//! `Options::from_args` is the single CLI parser: `--p`, `--l`, `--multi`,
//! `--sparse`, `--engine`, `--no-compact`, `--fresh`, `--seed`,
//! `--scenario`, `--lr`, `--tau`, `--batch`, `--max-wait`, and the serve
//! networking knobs `--listen`, `--quota`, `--queue-cap`, `--max-conns`.
//! Seed is kept as `Option<u64>` so each subcommand can preserve its
//! historical default stream (`seed_or`).

use crate::batch::BatchCfg;
use crate::coordinator::engine::{Engine, EngineCfg};
use crate::coordinator::infer::InferCfg;
use crate::coordinator::selection::SelectionPolicy;
use crate::coordinator::shard::Storage;
use crate::coordinator::train::TrainCfg;
use crate::env::Scenario;
use crate::util::cli::Args;
use anyhow::Result;

/// When the service launches a non-full open pack (full packs always
/// launch immediately under [`LaunchPolicy::OnFill`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum LaunchPolicy {
    /// Launch a pack the moment it fills to the largest compiled batch
    /// capacity; partial packs wait for `flush()` / the max-wait policy.
    /// The incremental service default: callers see outcomes stream in
    /// while later jobs are still being admitted.
    #[default]
    OnFill,
    /// Never launch before `flush()`; open packs may exceed the compiled
    /// capacity and are chunked at flush time, in deterministic
    /// (scenario, bucket) key order. This reproduces the one-shot
    /// `batch::run_queue` grouping (and its pack numbering) exactly, which
    /// is how the compatibility wrapper pins the redesign bit-exact.
    OnFlush,
}

/// Unified solver options (see module docs). Build with the fluent setters,
/// then lower with `InferCfg::from(&opts)` / `BatchCfg::from(&opts)` /
/// `TrainCfg::from(&opts)`, or hand the whole thing to `Service::new`.
#[derive(Debug, Clone)]
pub struct Options {
    /// Simulated device count P.
    pub p: usize,
    /// Embedding layers L.
    pub l: usize,
    /// Node-selection policy (single / adaptive multi).
    pub policy: SelectionPolicy,
    /// Per-shard storage mode (dense oracle or CSR tiles, DESIGN.md §7).
    pub storage: Storage,
    /// Execution engine: single-threaded lockstep simulation, or the
    /// persistent rank-parallel worker pool (DESIGN.md §9).
    pub engine: Engine,
    /// Early-exit pack compaction (batched paths only).
    pub compact: bool,
    /// Hold θ + adjacency state on device across steps (DESIGN.md §6).
    pub device_resident: bool,
    /// Elide the exact layer-0 message stage.
    pub skip_zero_layer: bool,
    /// Seed, when given explicitly (`seed_or` supplies the per-subcommand
    /// historical default so RNG streams stay decorrelated).
    pub seed: Option<u64>,
    /// Scenario override: forces every job/solve to this scenario
    /// (`oggm infer --scenario`, `oggm serve --scenario`).
    pub scenario: Option<Scenario>,
    /// Padded training bucket N (None = the lowering default, 24 — callers
    /// that know their graphs set it, e.g. `cmd_train` from `--n`).
    pub bucket_n: Option<usize>,
    /// Learning rate (training).
    pub lr: f32,
    /// Repeated gradient iterations τ (§4.5.2).
    pub tau: usize,
    /// Replay minibatch size B (training).
    pub batch: usize,
    /// Service pack-launch policy.
    pub launch: LaunchPolicy,
    /// Service max-wait seconds: an open pack older than this launches on
    /// the next `submit`/`tick` even if not full (None = wait for fill or
    /// flush).
    pub max_wait: Option<f64>,
    /// TCP listen address for `oggm serve --listen` (None = read job lines
    /// from a file / stdin, the PR 4 single-tenant mode).
    pub listen: Option<String>,
    /// Per-tenant load quota: max jobs a tenant may have queued or in
    /// flight before admission rejects with backpressure (None = no
    /// quota). The TCP front door defaults this to 64.
    pub quota: Option<usize>,
    /// Bound on the network front channel (parsed jobs waiting for
    /// admission across all connections); arrivals beyond it are rejected
    /// with backpressure instead of buffered without limit.
    pub queue_cap: usize,
    /// Stop accepting after this many connections, then exit once they
    /// drain (None = serve until killed — or until a `{"op":"drain"}`
    /// request / SIGTERM triggers the graceful drain, DESIGN.md §11).
    /// Smoke tests and benches use it for deterministic shutdown.
    pub max_conns: Option<usize>,
    /// Full pack re-solve attempts after a retryable fault before per-job
    /// errors are emitted (`--retries`, DESIGN.md §11).
    pub retries: usize,
    /// Per-pack rank-replacement budget for the rank-parallel pool
    /// (`--max-rank-restarts`, DESIGN.md §11).
    pub max_rank_restarts: usize,
    /// Deterministic fault-injection script (`--fault-plan`, DESIGN.md
    /// §11), e.g. `rank=1,step=3,kind=panic`; None = also honor the
    /// `OGGM_FAULT_PLAN` environment variable where pools are created.
    pub fault_plan: Option<String>,
    /// Rank transport spec for the rank-parallel engine (`--ranks`,
    /// DESIGN.md §12): a comma-separated list of `tcp:<host:port>` listen
    /// addresses the coordinator accepts `oggm rank` worker processes on.
    /// None = the in-process threaded pool.
    pub ranks: Option<String>,
    /// Remote-rank liveness deadline in seconds (`--rank-timeout`,
    /// DESIGN.md §12): a TCP rank silent for this long — no frames and no
    /// heartbeats — is declared dead. 0 disables enforcement.
    pub rank_timeout: f64,
    /// Seconds a vacated TCP rank slot stays open for a replacement worker
    /// to rejoin (`--rejoin-window`, DESIGN.md §12) before the loss is
    /// terminal.
    pub rejoin_window: f64,
    /// Shared secret required in the rank Hello handshake (`--token`,
    /// DESIGN.md §12); None = also honor the `OGGM_TOKEN` environment
    /// variable where TCP pools are created (empty = auth disabled).
    pub token: Option<String>,
}

impl Default for Options {
    fn default() -> Options {
        Options {
            p: 1,
            l: 2,
            policy: SelectionPolicy::Single,
            storage: Storage::Dense,
            engine: Engine::Lockstep,
            compact: true,
            device_resident: true,
            skip_zero_layer: true,
            seed: None,
            scenario: None,
            bucket_n: None,
            lr: 1e-3,
            tau: 1,
            batch: 8,
            launch: LaunchPolicy::OnFill,
            max_wait: None,
            listen: None,
            quota: None,
            queue_cap: 256,
            max_conns: None,
            retries: 1,
            max_rank_restarts: crate::parallel::DEFAULT_MAX_RANK_RESTARTS,
            fault_plan: None,
            ranks: None,
            rank_timeout: 30.0,
            rejoin_window: 30.0,
            token: None,
        }
    }
}

impl Options {
    /// Start from the defaults (P=1, L=2, single-select, dense, compaction
    /// and device residency on).
    pub fn new() -> Options {
        Options::default()
    }

    /// Parse every shared CLI option off `args` — the one front-door
    /// parser all `oggm` subcommands use. Unknown scenario names error;
    /// options not on the command line keep their defaults.
    pub fn from_args(args: &Args) -> Result<Options> {
        let mut o = Options::new();
        o.p = args.get_usize("p", o.p);
        o.l = args.get_usize("l", o.l);
        if args.has_flag("multi") {
            o.policy = SelectionPolicy::AdaptiveMulti;
        }
        if args.has_flag("sparse") {
            o.storage = Storage::Sparse;
        }
        if let Some(s) = args.get("engine") {
            o.engine = Engine::parse(s)?;
        }
        if args.has_flag("no-compact") {
            o.compact = false;
        }
        if args.has_flag("fresh") {
            o.device_resident = false;
        }
        o.seed = args.get("seed").map(|_| args.get_u64("seed", 0));
        o.scenario = match args.get("scenario") {
            Some(s) => Some(Scenario::parse(s)?),
            None => None,
        };
        o.lr = args.get_f64("lr", o.lr as f64) as f32;
        o.tau = args.get_usize("tau", o.tau);
        o.batch = args.get_usize("batch", o.batch);
        o.max_wait = args.get("max-wait").map(|_| args.get_f64("max-wait", 0.0));
        o.listen = args.get("listen").map(|s| s.to_string());
        o.quota = args.get("quota").map(|_| args.get_usize("quota", 64));
        o.queue_cap = args.get_usize("queue-cap", o.queue_cap);
        o.max_conns = args.get("max-conns").map(|_| args.get_usize("max-conns", 1));
        o.retries = args.get_usize("retries", o.retries);
        o.max_rank_restarts = args.get_usize("max-rank-restarts", o.max_rank_restarts);
        o.fault_plan = args.get("fault-plan").map(|s| s.to_string());
        o.ranks = args.get("ranks").map(|s| s.to_string());
        o.rank_timeout = args.get_f64("rank-timeout", o.rank_timeout);
        o.rejoin_window = args.get_f64("rejoin-window", o.rejoin_window);
        o.token = args.get("token").map(|s| s.to_string());
        Ok(o)
    }

    /// Set the device count P.
    pub fn p(mut self, p: usize) -> Options {
        self.p = p;
        self
    }

    /// Set the embedding layer count L.
    pub fn l(mut self, l: usize) -> Options {
        self.l = l;
        self
    }

    /// Set the selection policy.
    pub fn policy(mut self, policy: SelectionPolicy) -> Options {
        self.policy = policy;
        self
    }

    /// Set the storage mode.
    pub fn storage(mut self, storage: Storage) -> Options {
        self.storage = storage;
        self
    }

    /// Set the execution engine.
    pub fn engine(mut self, engine: Engine) -> Options {
        self.engine = engine;
        self
    }

    /// Enable/disable early-exit compaction.
    pub fn compact(mut self, on: bool) -> Options {
        self.compact = on;
        self
    }

    /// Set an explicit seed.
    pub fn seed(mut self, seed: u64) -> Options {
        self.seed = Some(seed);
        self
    }

    /// Force every job to one scenario.
    pub fn scenario(mut self, scenario: Scenario) -> Options {
        self.scenario = Some(scenario);
        self
    }

    /// Set the padded training bucket N.
    pub fn bucket(mut self, bucket_n: usize) -> Options {
        self.bucket_n = Some(bucket_n);
        self
    }

    /// Set the service pack-launch policy.
    pub fn launch(mut self, launch: LaunchPolicy) -> Options {
        self.launch = launch;
        self
    }

    /// Set the service max-wait seconds.
    pub fn max_wait(mut self, secs: f64) -> Options {
        self.max_wait = Some(secs);
        self
    }

    /// Set the TCP listen address (switches `serve` to network mode).
    pub fn listen(mut self, addr: impl Into<String>) -> Options {
        self.listen = Some(addr.into());
        self
    }

    /// Set the per-tenant load quota.
    pub fn quota(mut self, quota: usize) -> Options {
        self.quota = Some(quota);
        self
    }

    /// Set the bounded admission-queue capacity.
    pub fn queue_cap(mut self, cap: usize) -> Options {
        self.queue_cap = cap;
        self
    }

    /// Stop accepting after `n` connections (deterministic shutdown).
    pub fn max_conns(mut self, n: usize) -> Options {
        self.max_conns = Some(n);
        self
    }

    /// Set the pack retry budget (re-solve attempts after retryable
    /// faults).
    pub fn retries(mut self, n: usize) -> Options {
        self.retries = n;
        self
    }

    /// Set the per-pack rank-replacement budget.
    pub fn max_rank_restarts(mut self, n: usize) -> Options {
        self.max_rank_restarts = n;
        self
    }

    /// Set a deterministic fault-injection script (see
    /// [`crate::collective::fault`] for the grammar).
    pub fn fault_plan(mut self, plan: impl Into<String>) -> Options {
        self.fault_plan = Some(plan.into());
        self
    }

    /// Set the rank transport spec (TCP listen addresses for
    /// process-separated rank workers, DESIGN.md §12).
    pub fn ranks(mut self, spec: impl Into<String>) -> Options {
        self.ranks = Some(spec.into());
        self
    }

    /// Set the remote-rank liveness deadline in seconds (0 disables).
    pub fn rank_timeout(mut self, secs: f64) -> Options {
        self.rank_timeout = secs;
        self
    }

    /// Set the rejoin window a vacated TCP rank slot stays open, in
    /// seconds.
    pub fn rejoin_window(mut self, secs: f64) -> Options {
        self.rejoin_window = secs;
        self
    }

    /// Set the shared secret rank workers must present in their Hello
    /// handshake.
    pub fn token(mut self, token: impl Into<String>) -> Options {
        self.token = Some(token.into());
        self
    }

    /// The seed, or the calling subcommand's historical default (train 1,
    /// infer 2, solve 3, batch/serve 4 — distinct so their RNG streams
    /// never alias).
    pub fn seed_or(&self, default: u64) -> u64 {
        self.seed.unwrap_or(default)
    }
}

impl From<&Options> for EngineCfg {
    fn from(o: &Options) -> EngineCfg {
        let mut cfg = EngineCfg::new(o.p, o.l);
        cfg.mode = o.engine;
        cfg
    }
}

impl From<&Options> for InferCfg {
    fn from(o: &Options) -> InferCfg {
        InferCfg {
            engine: EngineCfg::from(o),
            policy: o.policy,
            skip_zero_layer: o.skip_zero_layer,
            device_resident: o.device_resident,
            storage: o.storage,
        }
    }
}

impl From<&Options> for BatchCfg {
    fn from(o: &Options) -> BatchCfg {
        BatchCfg {
            engine: EngineCfg::from(o),
            policy: o.policy,
            skip_zero_layer: o.skip_zero_layer,
            compact: o.compact,
            device_resident: o.device_resident,
            storage: o.storage,
            retries: o.retries,
            max_rank_restarts: o.max_rank_restarts,
            rank_timeout: o.rank_timeout,
            rejoin_window: o.rejoin_window,
        }
    }
}

impl From<&Options> for TrainCfg {
    fn from(o: &Options) -> TrainCfg {
        let mut cfg = TrainCfg::new(o.p, o.bucket_n.unwrap_or(24));
        cfg.engine = EngineCfg::from(o);
        cfg.seed = o.seed_or(1);
        cfg.hyper.lr = o.lr;
        cfg.hyper.grad_iters = o.tau;
        cfg.hyper.batch_size = o.batch;
        cfg.skip_zero_layer = o.skip_zero_layer;
        cfg.device_resident = o.device_resident;
        cfg.storage = o.storage;
        cfg
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn from_args_covers_the_shared_surface() {
        let a = parse("--p 2 --l 3 --multi --sparse --engine rank-parallel --no-compact \
                       --seed 9 --scenario mis --lr 0.01 --tau 4 --batch 16 --max-wait 0.5");
        let o = Options::from_args(&a).unwrap();
        assert_eq!(o.p, 2);
        assert_eq!(o.l, 3);
        assert_eq!(o.policy, SelectionPolicy::AdaptiveMulti);
        assert_eq!(o.storage, Storage::Sparse);
        assert_eq!(o.engine, Engine::RankParallel);
        assert_eq!(InferCfg::from(&o).engine.mode, Engine::RankParallel);
        assert_eq!(BatchCfg::from(&o).engine.mode, Engine::RankParallel);
        assert_eq!(TrainCfg::from(&o).engine.mode, Engine::RankParallel);
        assert!(!o.compact);
        assert_eq!(o.seed, Some(9));
        assert_eq!(o.seed_or(4), 9);
        assert_eq!(o.scenario, Some(Scenario::Mis));
        assert_eq!(o.lr, 0.01);
        assert_eq!(o.tau, 4);
        assert_eq!(o.batch, 16);
        assert_eq!(o.max_wait, Some(0.5));
    }

    #[test]
    fn defaults_match_the_historical_cfgs() {
        let o = Options::from_args(&parse("")).unwrap();
        assert_eq!(o.seed, None);
        assert_eq!(o.seed_or(2), 2);
        // Lowerings agree with the per-loop constructors the subcommands
        // used to call directly.
        let i = InferCfg::from(&o);
        let d = InferCfg::new(1, 2);
        assert_eq!(i.engine.p, d.engine.p);
        assert_eq!(i.engine.l, d.engine.l);
        assert_eq!(i.policy, d.policy);
        assert_eq!(i.storage, d.storage);
        assert_eq!(i.device_resident, d.device_resident);
        assert_eq!(i.skip_zero_layer, d.skip_zero_layer);
        let b = BatchCfg::from(&o);
        let db = BatchCfg::new(1, 2);
        assert_eq!(b.compact, db.compact);
        assert_eq!(b.policy, db.policy);
        let t = TrainCfg::from(&o.clone().bucket(36).seed(7));
        assert_eq!(t.bucket_n, 36);
        assert_eq!(t.seed, 7);
        assert_eq!(t.hyper.lr, 1e-3);
        assert_eq!(t.hyper.grad_iters, 1);
        assert_eq!(t.hyper.batch_size, 8);
    }

    #[test]
    fn serve_networking_knobs_parse() {
        let o = Options::from_args(&parse(
            "--listen 127.0.0.1:7001 --quota 8 --queue-cap 32 --max-conns 2",
        ))
        .unwrap();
        assert_eq!(o.listen.as_deref(), Some("127.0.0.1:7001"));
        assert_eq!(o.quota, Some(8));
        assert_eq!(o.queue_cap, 32);
        assert_eq!(o.max_conns, Some(2));
        // And the defaults: file mode, no quota, bounded queue.
        let o = Options::from_args(&parse("")).unwrap();
        assert!(o.listen.is_none());
        assert_eq!(o.quota, None);
        assert_eq!(o.queue_cap, 256);
        assert_eq!(o.max_conns, None);
    }

    #[test]
    fn fault_tolerance_knobs_parse_and_lower() {
        let o = Options::from_args(&parse(
            "--retries 3 --max-rank-restarts 5 --fault-plan rank=1,step=0,kind=panic",
        ))
        .unwrap();
        assert_eq!(o.retries, 3);
        assert_eq!(o.max_rank_restarts, 5);
        assert_eq!(o.fault_plan.as_deref(), Some("rank=1,step=0,kind=panic"));
        let b = BatchCfg::from(&o);
        assert_eq!(b.retries, 3);
        assert_eq!(b.max_rank_restarts, 5);
        // Defaults: one retry, the pool's stock restart budget, no plan.
        let o = Options::from_args(&parse("")).unwrap();
        assert_eq!(o.retries, 1);
        assert_eq!(o.max_rank_restarts, crate::parallel::DEFAULT_MAX_RANK_RESTARTS);
        assert!(o.fault_plan.is_none());
        assert_eq!(BatchCfg::from(&o).retries, 1);
    }

    #[test]
    fn rank_transport_spec_parses() {
        let o = Options::from_args(&parse("--ranks tcp:127.0.0.1:7701,tcp:127.0.0.1:7702"))
            .unwrap();
        assert_eq!(o.ranks.as_deref(), Some("tcp:127.0.0.1:7701,tcp:127.0.0.1:7702"));
        let o = Options::from_args(&parse("")).unwrap();
        assert!(o.ranks.is_none());
    }

    #[test]
    fn liveness_knobs_parse_and_lower() {
        let o = Options::from_args(&parse(
            "--rank-timeout 2.5 --rejoin-window 7 --token hunter2",
        ))
        .unwrap();
        assert_eq!(o.rank_timeout, 2.5);
        assert_eq!(o.rejoin_window, 7.0);
        assert_eq!(o.token.as_deref(), Some("hunter2"));
        let b = BatchCfg::from(&o);
        assert_eq!(b.rank_timeout, 2.5);
        assert_eq!(b.rejoin_window, 7.0);
        // Defaults: 30s liveness deadline and rejoin window, no token.
        let o = Options::from_args(&parse("")).unwrap();
        assert_eq!(o.rank_timeout, 30.0);
        assert_eq!(o.rejoin_window, 30.0);
        assert!(o.token.is_none());
        assert_eq!(BatchCfg::from(&o).rank_timeout, 30.0);
        assert_eq!(BatchCfg::new(1, 2).rejoin_window, 30.0);
    }

    #[test]
    fn fresh_flag_disables_residency_everywhere() {
        let o = Options::from_args(&parse("--fresh")).unwrap();
        assert!(!InferCfg::from(&o).device_resident);
        assert!(!BatchCfg::from(&o).device_resident);
        assert!(!TrainCfg::from(&o).device_resident);
    }

    #[test]
    fn bad_scenario_errors() {
        assert!(Options::from_args(&parse("--scenario tsp")).is_err());
    }

    #[test]
    fn engine_defaults_to_lockstep_and_rejects_unknown() {
        let o = Options::from_args(&parse("")).unwrap();
        assert_eq!(o.engine, Engine::Lockstep);
        assert_eq!(BatchCfg::from(&o).engine.mode, Engine::Lockstep);
        assert!(Options::from_args(&parse("--engine gpu")).is_err());
    }
}
