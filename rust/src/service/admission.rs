//! Admission control: the runtime-free half of the solver service.
//!
//! The [`Admitter`] owns everything about a session that does NOT need a
//! device: open packs keyed by (scenario, compiled bucket), the launch
//! policy (fill / flush / max-wait / per-job deadline), per-tenant load
//! quotas, and the backpressure counters. It never solves anything —
//! `submit`/`tick`/`flush` return [`PackRun`]s, and whoever owns the
//! compute (the synchronous [`Service`](crate::service::Service), or the
//! TCP front door's solver thread, DESIGN.md §10) executes them.
//!
//! That split is what makes **continuous batching** possible: the network
//! front thread keeps admitting jobs into open packs through this type
//! while earlier [`PackRun`]s are still in flight on the solver thread.
//! It is also what makes launch policy *testable without artifacts* — the
//! unit tests below drive deadlines and quotas against a synthetic
//! manifest, no compiled stage anywhere.
//!
//! Launch policy, in precedence order (evaluated per open pack):
//! 1. **Fill** — under [`LaunchPolicy::OnFill`] a pack launches inside
//!    `submit` the moment it reaches the largest compiled batch capacity.
//! 2. **Deadline** — each job may carry a `max_latency` budget; the pack's
//!    due time is the earliest member deadline. `max_latency` bounds time
//!    spent *queued in an open pack* (solve time is excluded — there is no
//!    solve-time estimator; DESIGN.md §10 discusses the contract).
//! 3. **Max-wait** — the session-wide cap on how long any open pack may
//!    wait, measured from the pack's first admission.
//! A pack's due time is the *earlier* of (2) and (3); when both are due,
//! the deadline wins the cause bookkeeping (ties go to [`LaunchCause::Deadline`]).
//! Under [`LaunchPolicy::OnFlush`] nothing launches before `flush()` —
//! deadlines and max-wait are deliberately inert so the one-shot
//! `batch::run_queue` wrapper keeps its bit-exact historical grouping.

use crate::batch::queue::Job;
use crate::env::Scenario;
use crate::graph::Graph;
use crate::runtime::Manifest;
use crate::service::options::LaunchPolicy;
use crate::service::JobId;
use anyhow::{anyhow, Context, Result};
use std::collections::btree_map::Entry;
use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// Per-submission metadata the wire protocol attaches to a [`Job`].
#[derive(Debug, Clone, Copy, Default)]
pub struct SubmitMeta {
    /// Tenant (connection) the job belongs to; quota accounting is per
    /// tenant. Library callers that don't multiplex use the default 0.
    pub tenant: u64,
    /// Launch-deadline budget: the job's pack becomes due this long after
    /// admission (None = no per-job deadline).
    pub max_latency: Option<Duration>,
}

/// Why `submit` refused a job.
#[derive(Debug)]
pub enum AdmitError {
    /// Backpressure: the tenant is at its load quota. Retryable once some
    /// of the tenant's jobs finish; carries queue-depth context for the
    /// reject event.
    Busy {
        /// Human-readable reject reason (tenant load, quota).
        reason: String,
        /// Jobs currently waiting in open packs (session-wide).
        depth: usize,
        /// The rejecting tenant's current load (queued + in flight).
        load: usize,
    },
    /// The job can never be admitted (no compiled bucket fits, manifest
    /// inconsistency). Not retryable.
    Invalid(anyhow::Error),
}

impl AdmitError {
    /// Render the error message (both variants are contextful).
    pub fn message(&self) -> String {
        match self {
            AdmitError::Busy { reason, .. } => reason.clone(),
            AdmitError::Invalid(e) => format!("{e:#}"),
        }
    }
}

impl From<AdmitError> for anyhow::Error {
    fn from(e: AdmitError) -> anyhow::Error {
        match e {
            AdmitError::Busy { reason, .. } => anyhow!(reason),
            AdmitError::Invalid(err) => err,
        }
    }
}

/// What fired a pack launch (bookkept per pack and surfaced in
/// [`PackStat`](crate::batch::queue::PackStat) / the admission snapshot).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LaunchCause {
    /// The pack filled to the largest compiled batch capacity.
    Fill,
    /// A member job's `max_latency` deadline came due.
    Deadline,
    /// The session max-wait elapsed since the pack opened.
    MaxWait,
    /// An explicit `flush()` (or end-of-stream for a tenant).
    Flush,
}

impl LaunchCause {
    /// Lowercase name (JSON/stat rendering).
    pub fn name(self) -> &'static str {
        match self {
            LaunchCause::Fill => "fill",
            LaunchCause::Deadline => "deadline",
            LaunchCause::MaxWait => "max_wait",
            LaunchCause::Flush => "flush",
        }
    }
}

/// A job riding in an open pack (admission accepted, not yet launched).
#[derive(Debug)]
pub struct Pending {
    /// Service-assigned handle.
    pub job: JobId,
    /// Caller-facing id.
    pub id: String,
    /// The instance to solve.
    pub graph: Graph,
    /// Owning tenant (quota accounting + event routing).
    pub tenant: u64,
    /// Admission time (queue-wait accounting).
    pub submitted: Instant,
    /// Launch deadline, if the job carried a `max_latency` budget.
    pub due: Option<Instant>,
}

/// One launched pack, ready for an executor: the admission-ordered member
/// jobs plus the pack's identity and launch cause. Produced by
/// [`Admitter::submit`]/[`Admitter::tick`]/[`Admitter::flush`]; consumed by
/// [`Executor::run`](crate::service::Executor::run) (inline or on a solver
/// thread).
#[derive(Debug)]
pub struct PackRun {
    /// Monotonic pack index (launch order, successful or not).
    pub pack: usize,
    /// Scenario shared by every member.
    pub scenario: Scenario,
    /// Padded bucket size N of the pack.
    pub bucket: usize,
    /// What fired the launch.
    pub cause: LaunchCause,
    /// Member jobs, in admission order.
    pub members: Vec<Pending>,
}

/// Backpressure counters at a point in time (rendered by
/// [`metrics::admission_stats_json`](crate::coordinator::metrics::admission_stats_json)).
#[derive(Debug, Clone, Copy, Default)]
pub struct AdmissionSnapshot {
    /// Jobs admitted over the session.
    pub submitted: u64,
    /// Jobs refused for backpressure (quota / bounded queue).
    pub rejected: u64,
    /// Jobs waiting in open packs right now.
    pub pending: usize,
    /// Jobs launched but whose outcome event has not been emitted yet.
    pub in_flight: usize,
    /// Open (not yet launched) packs right now.
    pub open_packs: usize,
    /// High-water mark of `pending` over the session.
    pub peak_pending: usize,
    /// Tenants with non-zero load right now.
    pub tenants: usize,
    /// Largest single-tenant load (queued + in flight) right now.
    pub max_tenant_load: usize,
    /// Packs launched so far.
    pub launched: usize,
    /// Launches fired by pack fill.
    pub fill_launches: u64,
    /// Launches fired by a per-job deadline.
    pub deadline_launches: u64,
    /// Launches fired by the session max-wait.
    pub max_wait_launches: u64,
    /// Launches fired by an explicit flush / end-of-stream.
    pub flush_launches: u64,
    /// Jobs dropped reader-side because the bounded inbound queue was full
    /// (TCP front door only; a subset of `rejected`).
    pub queue_full_rejects: u64,
    /// Packs that needed at least one full re-solve after a retryable
    /// fault before succeeding or giving up (DESIGN.md §11).
    pub retried_packs: u64,
    /// Retryable solve faults absorbed across all packs (rank failures,
    /// injected faults, collective aborts).
    pub pack_faults: u64,
}

/// An open pack: jobs of one (scenario, bucket) waiting to launch.
#[derive(Debug)]
struct OpenPack {
    members: Vec<Pending>,
    opened: Instant,
    /// Largest compiled batch capacity for the key's (bucket, P) — the
    /// fill threshold and the chunk size at launch.
    max_cap: usize,
}

impl OpenPack {
    /// When this pack becomes due, and why: the earlier of the earliest
    /// member deadline and `opened + max_wait`. Deadline wins ties.
    fn due(&self, max_wait: Option<f64>) -> Option<(Instant, LaunchCause)> {
        let deadline = self.members.iter().filter_map(|m| m.due).min();
        // Clamp: from_secs_f64 panics on negative/huge CLI values.
        let waited =
            max_wait.map(|w| self.opened + Duration::from_secs_f64(w.clamp(0.0, 1e9)));
        match (deadline, waited) {
            (Some(d), Some(w)) if d <= w => Some((d, LaunchCause::Deadline)),
            (Some(_) | None, Some(w)) => Some((w, LaunchCause::MaxWait)),
            (Some(d), None) => Some((d, LaunchCause::Deadline)),
            (None, None) => None,
        }
    }
}

/// Admission control for one service session (see module docs). Built
/// from the artifact [`Manifest`] — no runtime, no device, `Send`.
#[derive(Debug)]
pub struct Admitter {
    manifest: Manifest,
    p: usize,
    launch: LaunchPolicy,
    max_wait: Option<f64>,
    /// Max load (queued + in flight) per tenant; None = unlimited.
    quota: Option<usize>,
    open: BTreeMap<(Scenario, usize), OpenPack>,
    /// Load per tenant: jobs admitted whose outcome event has not been
    /// emitted yet (queued in an open pack OR launched and in flight).
    load: BTreeMap<u64, usize>,
    next_job: u64,
    launched: usize,
    in_flight: usize,
    rejected: u64,
    peak_pending: usize,
    fill_launches: u64,
    deadline_launches: u64,
    max_wait_launches: u64,
    flush_launches: u64,
    queue_full_rejects: u64,
    retried_packs: u64,
    pack_faults: u64,
}

impl Admitter {
    /// New session over `manifest` with `p` shards per pack.
    pub fn new(manifest: Manifest, p: usize) -> Admitter {
        Admitter {
            manifest,
            p,
            launch: LaunchPolicy::OnFill,
            max_wait: None,
            quota: None,
            open: BTreeMap::new(),
            load: BTreeMap::new(),
            next_job: 0,
            launched: 0,
            in_flight: 0,
            rejected: 0,
            peak_pending: 0,
            fill_launches: 0,
            deadline_launches: 0,
            max_wait_launches: 0,
            flush_launches: 0,
            queue_full_rejects: 0,
            retried_packs: 0,
            pack_faults: 0,
        }
    }

    /// Set the launch policy (builder style).
    pub fn launch_policy(mut self, launch: LaunchPolicy) -> Admitter {
        self.set_launch(launch);
        self
    }

    /// Set the session max-wait seconds (builder style).
    pub fn max_wait(mut self, secs: Option<f64>) -> Admitter {
        self.set_max_wait(secs);
        self
    }

    /// Set the per-tenant load quota (builder style; None = unlimited).
    pub fn quota(mut self, quota: Option<usize>) -> Admitter {
        self.set_quota(quota);
        self
    }

    /// Set the launch policy in place (for embedding types).
    pub fn set_launch(&mut self, launch: LaunchPolicy) {
        self.launch = launch;
    }

    /// Set the session max-wait seconds in place.
    pub fn set_max_wait(&mut self, secs: Option<f64>) {
        self.max_wait = secs;
    }

    /// Set the per-tenant load quota in place (None = unlimited).
    pub fn set_quota(&mut self, quota: Option<usize>) {
        self.quota = quota;
    }

    /// Admit one job. On success the job is in an open pack and any packs
    /// that launched as a consequence (fill under [`LaunchPolicy::OnFill`],
    /// or a zero/past deadline) are returned for execution.
    ///
    /// [`AdmitError::Busy`] is backpressure (tenant at quota; job NOT
    /// admitted, no job id consumed, retryable). [`AdmitError::Invalid`]
    /// means the job can never run here (no compiled bucket fits).
    pub fn submit(
        &mut self,
        job: Job,
        meta: SubmitMeta,
    ) -> std::result::Result<(JobId, Vec<PackRun>), AdmitError> {
        let bucket = self
            .manifest
            .bucket_for_any_batch(job.graph.n, self.p)
            .with_context(|| format!("job '{}' (|V|={}) not admitted", job.id, job.graph.n))
            .map_err(AdmitError::Invalid)?;
        if let Some(quota) = self.quota {
            let used = self.load.get(&meta.tenant).copied().unwrap_or(0);
            if used >= quota {
                self.rejected += 1;
                return Err(AdmitError::Busy {
                    reason: format!(
                        "job '{}' rejected: tenant {} at load quota ({used}/{quota} \
                         jobs queued or in flight)",
                        job.id, meta.tenant
                    ),
                    depth: self.pending(),
                    load: used,
                });
            }
        }
        let key = (job.scenario, bucket);
        let now = Instant::now();
        // The capacity lookup only matters when this key opens a new pack;
        // an existing open pack already carries it.
        let open = match self.open.entry(key) {
            Entry::Occupied(e) => e.into_mut(),
            Entry::Vacant(v) => {
                let max_cap = self
                    .manifest
                    .batch_sizes(bucket, bucket / self.p)
                    .last()
                    .copied()
                    .with_context(|| {
                        format!(
                            "job '{}': no compiled batch capacities at bucket N={bucket}, P={} \
                             (manifest inconsistent: the bucket lookup accepted it)",
                            job.id, self.p
                        )
                    })
                    .map_err(AdmitError::Invalid)?;
                v.insert(OpenPack { members: Vec::new(), opened: now, max_cap })
            }
        };
        let jid = JobId::new(self.next_job);
        self.next_job += 1;
        open.members.push(Pending {
            job: jid,
            id: job.id,
            graph: job.graph,
            tenant: meta.tenant,
            submitted: now,
            due: meta.max_latency.map(|d| now + d),
        });
        *self.load.entry(meta.tenant).or_insert(0) += 1;
        self.peak_pending = self.peak_pending.max(self.pending());
        let mut runs = Vec::new();
        if self.launch == LaunchPolicy::OnFill && open.members.len() >= open.max_cap {
            let pack = self.open.remove(&key).expect("open pack just inserted");
            self.launch_chunks(key, pack, LaunchCause::Fill, &mut runs);
        }
        // A zero (or past) deadline launches on the spot.
        runs.extend(self.tick(now));
        Ok((jid, runs))
    }

    /// Launch every open pack that is due at `now` (deadline or max-wait),
    /// in deterministic (scenario, bucket) key order. No-op under
    /// [`LaunchPolicy::OnFlush`] — that policy's contract is "nothing
    /// launches before `flush()`".
    pub fn tick(&mut self, now: Instant) -> Vec<PackRun> {
        let mut runs = Vec::new();
        if self.launch == LaunchPolicy::OnFlush {
            return runs;
        }
        let due: Vec<((Scenario, usize), LaunchCause)> = self
            .open
            .iter()
            .filter_map(|(&k, pack)| {
                pack.due(self.max_wait)
                    .filter(|&(at, _)| at <= now)
                    .map(|(_, cause)| (k, cause))
            })
            .collect();
        for (key, cause) in due {
            let pack = self.open.remove(&key).expect("due key read from the map");
            self.launch_chunks(key, pack, cause, &mut runs);
        }
        runs
    }

    /// Launch every open pack (cause [`LaunchCause::Flush`]), in
    /// deterministic key order, chunking oversize [`LaunchPolicy::OnFlush`]
    /// groups to the compiled capacity — exactly `run_queue`'s historical
    /// grouping.
    pub fn flush(&mut self) -> Vec<PackRun> {
        let open = std::mem::take(&mut self.open);
        let mut runs = Vec::new();
        for (key, pack) in open {
            self.launch_chunks(key, pack, LaunchCause::Flush, &mut runs);
        }
        runs
    }

    /// Launch every open pack containing at least one of `tenant`'s jobs
    /// (end-of-stream for that tenant: its jobs must not wait for traffic
    /// from other tenants). Whole packs launch — co-riding jobs of other
    /// tenants ride along, which only ever lowers their latency.
    pub fn flush_tenant(&mut self, tenant: u64) -> Vec<PackRun> {
        let keys: Vec<(Scenario, usize)> = self
            .open
            .iter()
            .filter(|(_, pack)| pack.members.iter().any(|m| m.tenant == tenant))
            .map(|(&k, _)| k)
            .collect();
        let mut runs = Vec::new();
        for key in keys {
            let pack = self.open.remove(&key).expect("key read from the map");
            self.launch_chunks(key, pack, LaunchCause::Flush, &mut runs);
        }
        runs
    }

    /// The earliest instant any open pack becomes due (the tick driver's
    /// sleep bound). None when nothing is waiting on a clock — no open
    /// packs, no deadline/max-wait policy, or [`LaunchPolicy::OnFlush`].
    pub fn next_due(&self) -> Option<Instant> {
        if self.launch == LaunchPolicy::OnFlush {
            return None;
        }
        self.open.values().filter_map(|p| p.due(self.max_wait)).map(|(at, _)| at).min()
    }

    /// Record that `count` outcome events for `tenant`'s launched jobs
    /// were emitted (frees quota and in-flight accounting).
    pub fn complete(&mut self, tenant: u64, count: usize) {
        self.in_flight = self.in_flight.saturating_sub(count);
        if let Some(load) = self.load.get_mut(&tenant) {
            *load = load.saturating_sub(count);
            if *load == 0 {
                self.load.remove(&tenant);
            }
        }
    }

    /// Record one job dropped because a bounded inbound queue was full
    /// (the TCP front door's reader-side reject, which never reaches
    /// `submit`). Counts toward `rejected` like any backpressure refusal.
    pub fn record_queue_full(&mut self) {
        self.queue_full_rejects += 1;
        self.rejected += 1;
    }

    /// Record one executed pack's fault-recovery tallies: `retries` full
    /// re-solve attempts and `faults` retryable faults absorbed
    /// (DESIGN.md §11). No-op for fault-free packs.
    pub fn record_retries(&mut self, retries: u64, faults: u64) {
        if retries > 0 {
            self.retried_packs += 1;
        }
        self.pack_faults += faults;
    }

    /// Jobs waiting in open packs right now.
    pub fn pending(&self) -> usize {
        self.open.values().map(|p| p.members.len()).sum()
    }

    /// Jobs admitted for `tenant` whose outcome event has not been
    /// emitted yet (queued + in flight).
    pub fn tenant_load(&self, tenant: u64) -> usize {
        self.load.get(&tenant).copied().unwrap_or(0)
    }

    /// Jobs admitted over the session so far.
    pub fn submitted(&self) -> u64 {
        self.next_job
    }

    /// Packs launched so far (successful or failed).
    pub fn launched(&self) -> usize {
        self.launched
    }

    /// Point-in-time backpressure counters.
    pub fn snapshot(&self) -> AdmissionSnapshot {
        AdmissionSnapshot {
            submitted: self.next_job,
            rejected: self.rejected,
            pending: self.pending(),
            in_flight: self.in_flight,
            open_packs: self.open.len(),
            peak_pending: self.peak_pending,
            tenants: self.load.len(),
            max_tenant_load: self.load.values().copied().max().unwrap_or(0),
            launched: self.launched,
            fill_launches: self.fill_launches,
            deadline_launches: self.deadline_launches,
            max_wait_launches: self.max_wait_launches,
            flush_launches: self.flush_launches,
            queue_full_rejects: self.queue_full_rejects,
            retried_packs: self.retried_packs,
            pack_faults: self.pack_faults,
        }
    }

    /// Chunk a closing pack to its compiled capacity and assign pack
    /// indices, preserving admission order.
    fn launch_chunks(
        &mut self,
        key: (Scenario, usize),
        pack: OpenPack,
        cause: LaunchCause,
        runs: &mut Vec<PackRun>,
    ) {
        let mut members = pack.members;
        while !members.is_empty() {
            let rest = if members.len() > pack.max_cap {
                members.split_off(pack.max_cap)
            } else {
                Vec::new()
            };
            let chunk = std::mem::replace(&mut members, rest);
            self.in_flight += chunk.len();
            match cause {
                LaunchCause::Fill => self.fill_launches += 1,
                LaunchCause::Deadline => self.deadline_launches += 1,
                LaunchCause::MaxWait => self.max_wait_launches += 1,
                LaunchCause::Flush => self.flush_launches += 1,
            }
            runs.push(PackRun {
                pack: self.launched,
                scenario: key.0,
                bucket: key.1,
                cause,
                members: chunk,
            });
            self.launched += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::util::rng::Pcg32;

    /// Synthetic manifest: one N=24 bucket with batch capacities 1/2/4 at
    /// P=1 — launch policy runs entirely host-side, no artifacts needed.
    fn manifest() -> Manifest {
        let dir = std::env::temp_dir().join(format!(
            "oggm_admit_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.tsv"),
            "# oggm artifact manifest\tk=32\tl=2\n\
             q_scores_b1_n24_ni24_k32\tq_scores\t1\t24\t24\t32\t1\tq1.hlo.txt\n\
             q_scores_b2_n24_ni24_k32\tq_scores\t2\t24\t24\t32\t1\tq2.hlo.txt\n\
             q_scores_b4_n24_ni24_k32\tq_scores\t4\t24\t24\t32\t1\tq4.hlo.txt\n",
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        std::fs::remove_dir_all(&dir).ok();
        m
    }

    fn job(i: usize) -> Job {
        Job {
            id: format!("j{i}"),
            scenario: Scenario::Mvc,
            graph: generators::erdos_renyi(20, 0.2, &mut Pcg32::seeded(7 + i as u64)),
        }
    }

    fn meta(tenant: u64, ms: Option<u64>) -> SubmitMeta {
        SubmitMeta { tenant, max_latency: ms.map(Duration::from_millis) }
    }

    #[test]
    fn fill_launch_chunks_and_numbers_packs() {
        let mut a = Admitter::new(manifest(), 1);
        let mut runs = Vec::new();
        for i in 0..5 {
            let (jid, r) = a.submit(job(i), SubmitMeta::default()).unwrap();
            assert_eq!(jid.index(), i);
            runs.extend(r);
        }
        // Capacity 4 filled once -> one fill launch; the 5th job rides on.
        assert_eq!(runs.len(), 1);
        assert_eq!(runs[0].pack, 0);
        assert_eq!(runs[0].cause, LaunchCause::Fill);
        assert_eq!(runs[0].members.len(), 4);
        assert_eq!(a.pending(), 1);
        assert_eq!(a.snapshot().in_flight, 4);
        let tail = a.flush();
        assert_eq!(tail.len(), 1);
        assert_eq!(tail[0].pack, 1);
        assert_eq!(tail[0].cause, LaunchCause::Flush);
        let snap = a.snapshot();
        assert_eq!((snap.fill_launches, snap.flush_launches), (1, 1));
        assert_eq!(snap.peak_pending, 4);
    }

    #[test]
    fn deadline_fires_before_fill() {
        let mut a = Admitter::new(manifest(), 1);
        // 2 of capacity 4, one with an immediate deadline: launches inside
        // submit's tick without ever filling.
        let (_, r) = a.submit(job(0), SubmitMeta::default()).unwrap();
        assert!(r.is_empty());
        let (_, r) = a.submit(job(1), meta(0, Some(0))).unwrap();
        assert_eq!(r.len(), 1, "zero deadline must launch on the spot");
        assert_eq!(r[0].cause, LaunchCause::Deadline);
        assert_eq!(r[0].members.len(), 2, "the co-riding job launches too");
        assert_eq!(a.snapshot().deadline_launches, 1);
        assert!(a.next_due().is_none());
    }

    #[test]
    fn deadline_vs_max_wait_precedence() {
        // Deadline earlier than max-wait: cause is Deadline.
        let mut a = Admitter::new(manifest(), 1).max_wait(Some(1e6));
        let (_, runs) = a.submit(job(0), meta(0, Some(0))).unwrap();
        assert_eq!(runs.len(), 1);
        assert_eq!(runs[0].cause, LaunchCause::Deadline);
        assert_eq!(a.snapshot().deadline_launches, 1);
        assert_eq!(a.snapshot().max_wait_launches, 0);

        // Max-wait earlier than every deadline: cause is MaxWait.
        let mut a = Admitter::new(manifest(), 1).max_wait(Some(0.0));
        let (_, runs) = a.submit(job(0), meta(0, Some(1_000_000))).unwrap();
        assert_eq!(runs.len(), 1);
        assert_eq!(runs[0].cause, LaunchCause::MaxWait);
        assert_eq!(a.snapshot().max_wait_launches, 1);

        // next_due reports the earlier bound (deadline here).
        let mut a = Admitter::new(manifest(), 1).max_wait(Some(1e6));
        a.submit(job(0), meta(0, Some(5_000))).unwrap();
        let due = a.next_due().expect("a deadline is pending");
        let lead = due.saturating_duration_since(Instant::now());
        assert!(lead <= Duration::from_millis(5_000), "due follows the deadline, got {lead:?}");
    }

    #[test]
    fn on_flush_ignores_clocks() {
        let mut a = Admitter::new(manifest(), 1)
            .launch_policy(LaunchPolicy::OnFlush)
            .max_wait(Some(0.0));
        let (_, runs) = a.submit(job(0), meta(0, Some(0))).unwrap();
        assert!(runs.is_empty(), "OnFlush launched before flush()");
        assert!(a.next_due().is_none());
        assert!(a.tick(Instant::now()).is_empty());
        // 5 jobs chunk to 4+1 at flush, key-ordered, pack-numbered.
        for i in 1..5 {
            a.submit(job(i), SubmitMeta::default()).unwrap();
        }
        let runs = a.flush();
        assert_eq!(runs.len(), 2);
        assert_eq!((runs[0].pack, runs[0].members.len()), (0, 4));
        assert_eq!((runs[1].pack, runs[1].members.len()), (1, 1));
    }

    #[test]
    fn quota_rejects_are_busy_and_retryable() {
        let mut a = Admitter::new(manifest(), 1).quota(Some(2));
        a.submit(job(0), meta(7, None)).unwrap();
        a.submit(job(1), meta(7, None)).unwrap();
        // Tenant 7 is at quota; tenant 8 is not.
        let err = a.submit(job(2), meta(7, None)).unwrap_err();
        match err {
            AdmitError::Busy { reason, depth, load } => {
                assert!(reason.contains("j2") && reason.contains("quota"), "{reason}");
                assert_eq!((depth, load), (2, 2));
            }
            other => panic!("expected Busy, got {other:?}"),
        }
        assert_eq!(a.submitted(), 2, "rejected job must not consume an id");
        assert_eq!(a.snapshot().rejected, 1);
        a.submit(job(3), meta(8, None)).unwrap();
        assert_eq!(a.snapshot().tenants, 2);
        assert_eq!(a.snapshot().max_tenant_load, 2);

        // Launch + complete frees the quota.
        let runs = a.flush();
        let t7: usize =
            runs.iter().flat_map(|r| &r.members).filter(|m| m.tenant == 7).count();
        assert_eq!(t7, 2);
        a.complete(7, t7);
        assert_eq!(a.tenant_load(7), 0);
        assert!(a.submit(job(4), meta(7, None)).is_ok());
    }

    #[test]
    fn flush_tenant_takes_whole_copacked_packs() {
        let mut a = Admitter::new(manifest(), 1);
        a.submit(job(0), meta(1, None)).unwrap();
        a.submit(job(1), meta(2, None)).unwrap();
        let mut b = job(2);
        b.scenario = Scenario::Mis;
        a.submit(b, meta(2, None)).unwrap();
        // Tenant 1's EOF launches the MVC pack (tenant 2's job co-rides)
        // but not tenant 2's MIS-only pack.
        let runs = a.flush_tenant(1);
        assert_eq!(runs.len(), 1);
        assert_eq!(runs[0].scenario, Scenario::Mvc);
        assert_eq!(runs[0].members.len(), 2);
        assert_eq!(a.pending(), 1);
        assert!(a.flush_tenant(99).is_empty());
    }

    #[test]
    fn fault_counters_accumulate_in_the_snapshot() {
        let mut a = Admitter::new(manifest(), 1);
        assert_eq!(a.snapshot().queue_full_rejects, 0);
        a.record_queue_full();
        a.record_queue_full();
        let snap = a.snapshot();
        assert_eq!(snap.queue_full_rejects, 2);
        assert_eq!(snap.rejected, 2, "queue-full drops are backpressure rejects");

        a.record_retries(0, 0); // fault-free pack: no-op
        a.record_retries(2, 2); // pack that recovered after two faults
        a.record_retries(1, 2); // pack that retried once, then failed again
        let snap = a.snapshot();
        assert_eq!(snap.retried_packs, 2);
        assert_eq!(snap.pack_faults, 4);
    }

    #[test]
    fn invalid_jobs_never_consume_ids() {
        let mut a = Admitter::new(manifest(), 1);
        let whale = Job {
            id: "whale".into(),
            scenario: Scenario::Mvc,
            graph: generators::barabasi_albert(500, 2, &mut Pcg32::seeded(3)),
        };
        match a.submit(whale, SubmitMeta::default()) {
            Err(AdmitError::Invalid(e)) => {
                assert!(format!("{e:#}").contains("whale"), "{e:#}");
            }
            other => panic!("expected Invalid, got {other:?}"),
        }
        assert_eq!(a.submitted(), 0);
        assert_eq!(a.snapshot().rejected, 0, "invalid is not backpressure");
    }
}
