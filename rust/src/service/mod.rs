//! The solver service: a persistent front door over the batched solve
//! engine (DESIGN.md §8).
//!
//! Where `batch::run_queue` is one-shot — every job up front, solve
//! everything, report at the end — a [`Service`] is a long-lived session
//! that owns the warm state heavy solve traffic needs. Since PR 6 it is a
//! thin composition of two halves that can also be used apart:
//!
//! * [`Admitter`] (`service/admission.rs`) — the runtime-free admission
//!   core: open packs keyed by (scenario, compiled bucket), launch policy
//!   (fill / flush / max-wait / per-job deadline), per-tenant quotas and
//!   backpressure counters. `Send`, testable without artifacts.
//! * [`Executor`] — the compute half: owns the session's warm
//!   [`ThetaCache`](crate::coordinator::fwd::ThetaCache) and lazy
//!   [`RankPool`], and turns each launched [`PackRun`] into per-job
//!   [`JobEvent`]s plus a [`PackStat`].
//!
//! The synchronous [`Service`] wires them back to back: `submit` admits
//! and solves any launched pack before returning. The TCP front door
//! (`net/`, DESIGN.md §10) runs the same two halves on different threads —
//! the [`Admitter`] on the connection-facing front thread, the
//! [`Executor`] on a solver thread with its own [`Runtime`] — which is
//! what makes continuous batching work: jobs keep packing while earlier
//! packs are in flight.
//!
//! Behavior notes carried over from PR 4/5 (pinned by tests):
//!
//! * Streaming — finished packs push one [`JobEvent`] per job into a ready
//!   queue drained by [`Service::poll`]; a pack-level solve failure becomes
//!   contextful per-job error events, never a panic.
//! * Fault tolerance (PR 7, DESIGN.md §11) — a pack that fails on a
//!   *retryable* fault (rank death, collective abort, injected fault) is
//!   re-solved whole, original ids and deadlines intact, up to `--retries`
//!   times before any error event is emitted; retried solves are
//!   bit-identical to fault-free runs because the engine is deterministic.
//! * Warm caches — θ is published once per session; every pack after the
//!   first skips the θ upload (`rust/tests/service.rs` pins it).
//! * `batch::run_queue` stays a thin compatibility wrapper
//!   ([`LaunchPolicy::OnFlush`] + fail-fast) with its historical grouping
//!   bit-exact.

/// The unified options layer (`Options`, `LaunchPolicy`).
pub mod options;

/// Runtime-free admission control (open packs, deadlines, quotas).
pub mod admission;

pub use admission::{
    AdmitError, Admitter, AdmissionSnapshot, LaunchCause, PackRun, Pending, SubmitMeta,
};
pub use options::{LaunchPolicy, Options};

use crate::batch::queue::{Job, JobOutcome, PackStat};
use crate::batch::solve::{solve_pack_session, SessionState};
use crate::collective::fault::FaultPlan;
use crate::coordinator::engine::Engine;
use crate::coordinator::fwd::ThetaCache;
use crate::env::Scenario;
use crate::model::Params;
use crate::parallel::RankPool;
use crate::runtime::Runtime;
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Instant;

/// Service-assigned job handle, monotonically numbered in admission order
/// (so it doubles as the submission index).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct JobId(u64);

impl JobId {
    /// Wrap an admission index (the [`Admitter`] is the only id source).
    pub(crate) fn new(i: u64) -> JobId {
        JobId(i)
    }

    /// The admission index (0 = first job submitted to this service).
    pub fn index(self) -> usize {
        self.0 as usize
    }
}

impl std::fmt::Display for JobId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "#{}", self.0)
    }
}

/// One streamed per-job result: the outcome, or a contextful error for
/// jobs whose pack failed to solve.
#[derive(Debug, Clone)]
pub struct JobEvent {
    /// Service-assigned handle (as returned by [`Service::submit`]).
    pub job: JobId,
    /// Caller-facing id (echoed from the submitted [`Job`]).
    pub id: String,
    /// Scenario the job ran under.
    pub scenario: Scenario,
    /// Tenant that submitted the job (0 for single-tenant sessions).
    pub tenant: u64,
    /// Milliseconds the job waited between admission and its pack starting
    /// to solve (queue wait; solve time is not included).
    pub wait_ms: f64,
    /// The outcome, or the pack's error with job/pack context.
    pub result: Result<JobOutcome, String>,
}

impl JobEvent {
    /// Render as one `oggm serve` JSONL line: the [`JobOutcome`] object
    /// plus the service `job` handle, tenant, and queue wait, or
    /// `{id, job, scenario, tenant, wait_ms, error}` for failures (schema
    /// in README §serve).
    pub fn to_json(&self) -> Json {
        let base = match &self.result {
            Ok(o) => o.to_json(),
            Err(e) => Json::obj()
                .set("id", self.id.as_str())
                .set("scenario", self.scenario.name())
                .set("error", e.as_str()),
        };
        base.set("job", self.job.0)
            .set("tenant", self.tenant)
            .set("wait_ms", (self.wait_ms * 1000.0).round() / 1000.0)
    }
}

/// Ascending node ids of a per-graph solution mask.
fn solution_ids(mask: &[bool]) -> Vec<usize> {
    mask.iter().enumerate().filter(|(_, &b)| b).map(|(v, _)| v).collect()
}

/// The result of executing one [`PackRun`]: per-job events (in admission
/// order) and, for successful packs, the pack's statistics row.
#[derive(Debug)]
pub struct PackDone {
    /// One event per member job, in admission order.
    pub events: Vec<JobEvent>,
    /// Statistics for a successfully solved pack (None on failure/skip).
    pub stat: Option<PackStat>,
    /// Full re-solve attempts this pack took after retryable faults
    /// (0 on the fault-free path; DESIGN.md §11).
    pub retries: usize,
    /// Retryable faults absorbed while executing this pack (a final
    /// retryable failure with no budget left still counts).
    pub faults: usize,
}

/// The compute half of a service session: a warm θ cache plus the lazy
/// rank pool, turning launched [`PackRun`]s into [`PackDone`]s. Owned
/// directly by [`Service`] for the synchronous path; the TCP front door
/// runs one on a dedicated solver thread with its own [`Runtime`] (a
/// `Runtime` is single-threaded, so the executor lives where the runtime
/// lives).
pub struct Executor<'r> {
    rt: &'r Runtime,
    params: Params,
    cfg: crate::batch::BatchCfg,
    /// Stop solving after the first pack-level error: later runs emit
    /// skipped-error events instead of solving (`run_queue`'s historical
    /// fail-fast).
    abort_on_error: bool,
    aborted: bool,
    theta: ThetaCache,
    /// Persistent rank pool for the rank-parallel engine, created lazily
    /// at the first run (so construction stays infallible) and kept warm
    /// across packs: each rank re-uploads θ only when the session
    /// parameters change — i.e. never, after the first pack (DESIGN.md §9).
    pool: Option<RankPool>,
    /// Unparsed `--fault-plan` spec for the session's rank pool; parsed
    /// lazily at pool creation (so construction stays infallible — a bad
    /// spec surfaces as per-job error events). `None` falls back to the
    /// `OGGM_FAULT_PLAN` environment variable.
    fault_spec: Option<String>,
    /// Unparsed `--ranks` transport spec (DESIGN.md §12): TCP listen
    /// addresses for process-separated rank workers. `None` = the
    /// in-process threaded pool.
    ranks_spec: Option<String>,
    /// Shared secret TCP rank workers must present in their Hello
    /// handshake (`--token`, DESIGN.md §12). `None` falls back to the
    /// `OGGM_TOKEN` environment variable; empty = auth disabled.
    token_spec: Option<String>,
}

impl<'r> Executor<'r> {
    /// New executor over a warm runtime.
    pub fn new(rt: &'r Runtime, params: Params, cfg: crate::batch::BatchCfg) -> Executor<'r> {
        Executor {
            rt,
            params,
            cfg,
            abort_on_error: false,
            aborted: false,
            theta: ThetaCache::new(rt),
            pool: None,
            fault_spec: None,
            ranks_spec: None,
            token_spec: None,
        }
    }

    /// Stop solving after the first pack-level error (builder style); see
    /// [`Service::fail_fast`].
    pub fn fail_fast(mut self, on: bool) -> Executor<'r> {
        self.abort_on_error = on;
        self
    }

    /// Set the fault-injection plan spec (builder style; the `--fault-plan`
    /// flag). `None` falls back to `OGGM_FAULT_PLAN`.
    pub fn fault_plan(mut self, spec: Option<String>) -> Executor<'r> {
        self.fault_spec = spec;
        self
    }

    /// Set the rank transport spec (builder style; the `--ranks` flag).
    /// `Some` runs the rank-parallel engine over TCP worker processes
    /// instead of in-process threads (DESIGN.md §12).
    pub fn rank_transport(mut self, spec: Option<String>) -> Executor<'r> {
        self.ranks_spec = spec;
        self
    }

    /// Set the shared rank-worker auth token (builder style; the `--token`
    /// flag). `None` falls back to `OGGM_TOKEN`; empty disables auth.
    pub fn rank_token(mut self, token: Option<String>) -> Executor<'r> {
        self.token_spec = token;
        self
    }

    /// The parameters this executor serves.
    pub fn params(&self) -> &Params {
        &self.params
    }

    /// Start the session's rank pool if the configured engine needs one
    /// (no-op under lockstep, or once it exists). A startup failure (e.g.
    /// the offline xla stub) surfaces through per-job error events, like
    /// any pack-level failure.
    fn ensure_pool(&mut self) -> Result<()> {
        if self.cfg.engine.mode != Engine::RankParallel || self.pool.is_some() {
            return Ok(());
        }
        let plan = match &self.fault_spec {
            Some(spec) => Some(Arc::new(
                FaultPlan::parse(spec).context("parsing the --fault-plan spec")?,
            )),
            None => FaultPlan::from_env()?,
        };
        let pool = match &self.ranks_spec {
            Some(spec) => {
                let tcp = crate::transport::TcpCfg {
                    timeout: std::time::Duration::from_secs_f64(
                        self.cfg.rank_timeout.max(0.0),
                    ),
                    rejoin_window: std::time::Duration::from_secs_f64(
                        self.cfg.rejoin_window.max(0.0),
                    ),
                    token: match &self.token_spec {
                        Some(t) => t.clone(),
                        None => std::env::var("OGGM_TOKEN").unwrap_or_default(),
                    },
                };
                RankPool::new_tcp_with(
                    self.rt.manifest.dir.clone(),
                    self.cfg.engine.p,
                    self.cfg.max_rank_restarts,
                    plan,
                    spec,
                    tcp,
                )
                .context("forming the TCP rank-parallel worker group")?
            }
            None => RankPool::new_with(
                self.rt.manifest.dir.clone(),
                self.cfg.engine.p,
                self.cfg.max_rank_restarts,
                plan,
            )
            .context("starting the rank-parallel worker pool")?,
        };
        self.pool = Some(pool);
        Ok(())
    }

    /// Solve one launched pack; emit one event per member. A pack-level
    /// failure becomes per-job error events with pack context (the service
    /// boundary never panics on a bad pack).
    pub fn run(&mut self, run: PackRun) -> PackDone {
        debug_assert!(!run.members.is_empty(), "run of an empty pack");
        let PackRun { pack: pack_idx, scenario, bucket, cause, members } = run;
        let started = Instant::now();
        let mut events = Vec::with_capacity(members.len());
        if self.aborted {
            // Fail-fast mode after an earlier pack error: skip the solve,
            // but still emit one event per job so nothing is lost.
            for m in members {
                events.push(JobEvent {
                    job: m.job,
                    id: m.id,
                    scenario,
                    tenant: m.tenant,
                    wait_ms: ms_since(m.submitted, started),
                    result: Err("skipped: an earlier pack failed (fail-fast)".into()),
                });
            }
            return PackDone { events, stat: None, retries: 0, faults: 0 };
        }
        let mut meta = Vec::with_capacity(members.len());
        let mut graphs = Vec::with_capacity(members.len());
        for m in members {
            meta.push((m.job, m.id, m.graph.n, m.graph.m, m.tenant, m.submitted));
            graphs.push(m.graph);
        }
        // Retry loop (DESIGN.md §11): a retryable fault — rank death, a
        // collective abort, an injected fault — re-solves the whole pack
        // with the original jobs, ids, and deadlines, up to `--retries`
        // times, before any per-job error event is emitted. The solve
        // engine is deterministic, so a retried solve is bit-identical to
        // a fault-free run. Non-retryable errors (admission / shape /
        // compile problems) fail on the first attempt.
        let mut retries = 0usize;
        let mut faults = 0usize;
        let res = match self.ensure_pool() {
            Err(e) => Err(e),
            Ok(()) => loop {
                // Clone the instances only while another attempt remains.
                let attempt_graphs = if retries < self.cfg.retries {
                    graphs.clone()
                } else {
                    std::mem::take(&mut graphs)
                };
                let attempt = solve_pack_session(
                    self.rt,
                    &self.cfg,
                    &self.params,
                    scenario,
                    attempt_graphs,
                    bucket,
                    SessionState { theta: Some(&self.theta), pool: self.pool.as_ref() },
                );
                match attempt {
                    Ok(r) => break Ok(r),
                    Err(e) => {
                        let retryable = retryable_fault(&format!("{e:#}"));
                        if retryable {
                            faults += 1;
                            if retries < self.cfg.retries {
                                retries += 1;
                                continue;
                            }
                        }
                        break Err(e);
                    }
                }
            },
        };
        match res {
            Ok(res) => {
                for (slot, (job, id, nodes, edges, tenant, submitted)) in
                    meta.into_iter().enumerate()
                {
                    let r = &res.per_graph[slot];
                    events.push(JobEvent {
                        job,
                        id: id.clone(),
                        scenario,
                        tenant,
                        wait_ms: ms_since(submitted, started),
                        result: Ok(JobOutcome {
                            id,
                            scenario,
                            nodes,
                            edges,
                            pack: pack_idx,
                            solution: solution_ids(&r.solution),
                            solution_size: r.solution_size,
                            objective: r.objective,
                            valid: r.valid,
                            evaluations: r.evaluations,
                            selections: r.selections,
                        }),
                    });
                }
                let stat = PackStat {
                    pack: pack_idx,
                    scenario,
                    bucket_n: bucket,
                    cause,
                    jobs: res.per_graph.len(),
                    capacity: res.initial_capacity,
                    rounds: res.rounds,
                    repacks: res.repacks,
                    sim_time: res.sim_total,
                    wall_time: res.wall_total,
                    comm_bytes: res.timing.comm_bytes,
                    retries,
                    exec: res.exec,
                };
                PackDone { events, stat: Some(stat), retries, faults }
            }
            Err(e) => {
                if self.abort_on_error {
                    self.aborted = true;
                }
                let msg = format!("pack {pack_idx} ({scenario}, N={bucket}): {e:#}");
                for (job, id, _, _, tenant, submitted) in meta {
                    events.push(JobEvent {
                        job,
                        id,
                        scenario,
                        tenant,
                        wait_ms: ms_since(submitted, started),
                        result: Err(msg.clone()),
                    });
                }
                PackDone { events, stat: None, retries, faults }
            }
        }
    }
}

/// Whether a pack-level solve error is worth a full re-solve: rank and
/// worker failures (thread or remote process — the pool replaces dead
/// threads and re-admits rejoining worker processes on the next
/// install), collective aborts, and injected faults are transient.
/// Admission, shape, and compilation errors are not (retrying them
/// would burn device time on a deterministic failure), and neither is
/// an expired rejoin window: the replacement never came, so another
/// attempt would just wait out the window again.
pub fn retryable_fault(msg: &str) -> bool {
    // Terminal markers first: an expired rejoin window's context chain
    // can also contain retryable phrasings (the liveness reason that
    // vacated the slot), and the terminal classification must win.
    if msg.contains("rejoin window expired") {
        return false;
    }
    const MARKERS: &[&str] = &[
        "injected fault",
        "injected panic",
        "aborted by rank",
        "panicked",
        "worker thread died",
        "worker is gone",
        "worker process disconnected",
        "worker process unreachable",
        "unreachable for",
        "restart budget exhausted",
        "replacement rank",
    ];
    MARKERS.iter().any(|m| msg.contains(m))
}

impl Drop for Executor<'_> {
    fn drop(&mut self) {
        self.theta.evict(self.rt);
    }
}

/// Milliseconds from `from` to `to` (0 if the clock went backwards).
fn ms_since(from: Instant, to: Instant) -> f64 {
    to.saturating_duration_since(from).as_secs_f64() * 1e3
}

/// A persistent solver service session. See the module docs for the
/// lifecycle; construction is [`Service::new`] from [`Options`] (CLI /
/// library callers) or [`Service::with_cfg`] from a raw
/// [`BatchCfg`](crate::batch::BatchCfg) (the `run_queue` compatibility
/// wrapper, which must preserve an exact cfg including its cost model).
pub struct Service<'r> {
    adm: Admitter,
    exec: Executor<'r>,
    ready: VecDeque<JobEvent>,
    packs: Vec<PackStat>,
}

impl<'r> Service<'r> {
    /// Open a service session over a warm runtime with the given options.
    pub fn new(rt: &'r Runtime, params: Params, opts: &Options) -> Service<'r> {
        let mut svc = Service::with_cfg(rt, params, crate::batch::BatchCfg::from(opts));
        svc.adm.set_launch(opts.launch);
        svc.adm.set_max_wait(opts.max_wait);
        svc.adm.set_quota(opts.quota);
        svc.exec.fault_spec = opts.fault_plan.clone();
        svc.exec.ranks_spec = opts.ranks.clone();
        svc.exec.token_spec = opts.token.clone();
        svc
    }

    /// Open a service session from an exact [`BatchCfg`](crate::batch::BatchCfg)
    /// (launch policy [`LaunchPolicy::OnFill`], no max-wait, no quota;
    /// override with [`Service::launch_policy`] / [`Service::quota`]).
    pub fn with_cfg(rt: &'r Runtime, params: Params, cfg: crate::batch::BatchCfg) -> Service<'r> {
        let adm = Admitter::new(rt.manifest.clone(), cfg.engine.p);
        Service {
            adm,
            exec: Executor::new(rt, params, cfg),
            ready: VecDeque::new(),
            packs: Vec::new(),
        }
    }

    /// Override the pack-launch policy (builder style).
    pub fn launch_policy(mut self, launch: LaunchPolicy) -> Service<'r> {
        self.adm.set_launch(launch);
        self
    }

    /// Set the per-tenant load quota (builder style; None = unlimited).
    pub fn quota(mut self, quota: Option<usize>) -> Service<'r> {
        self.adm.set_quota(quota);
        self
    }

    /// Stop solving after the first pack-level error (builder style):
    /// later launches emit "skipped" error events instead of running their
    /// packs. The one-shot `run_queue` wrapper sets this so an early pack
    /// failure does not burn device time solving packs whose outcomes the
    /// failed call will discard; a streaming service keeps the default
    /// (false) and serves every pack independently.
    pub fn fail_fast(mut self, on: bool) -> Service<'r> {
        self.exec.abort_on_error = on;
        self
    }

    /// Route the rank-parallel engine over TCP worker processes (builder
    /// style; see [`Executor::rank_transport`], DESIGN.md §12).
    pub fn rank_transport(mut self, spec: Option<String>) -> Service<'r> {
        self.exec.ranks_spec = spec;
        self
    }

    /// Set the shared rank-worker auth token (builder style; see
    /// [`Executor::rank_token`], DESIGN.md §12).
    pub fn rank_token(mut self, token: Option<String>) -> Service<'r> {
        self.exec.token_spec = token;
        self
    }

    /// Admit one job under the default tenant (0, no deadline). Errors (no
    /// compiled bucket fits the graph at this P, or the tenant is at
    /// quota) are returned here with the job id in the context — the job
    /// is not admitted and no event will be emitted for it. On success the
    /// job is in an open pack; under [`LaunchPolicy::OnFill`] a pack that
    /// just filled to compiled capacity launches (and solves) before
    /// `submit` returns, so its outcomes are already pollable.
    pub fn submit(&mut self, job: Job) -> Result<JobId> {
        self.submit_with(job, SubmitMeta::default())
    }

    /// Admit one job with explicit tenant / deadline metadata. See
    /// [`Service::submit`]; the typed [`AdmitError`] (backpressure vs
    /// invalid) is flattened into `anyhow` here — callers that need to
    /// distinguish (the TCP front door) drive the [`Admitter`] directly.
    pub fn submit_with(&mut self, job: Job, meta: SubmitMeta) -> Result<JobId> {
        let (jid, runs) = self.adm.submit(job, meta).map_err(anyhow::Error::from)?;
        self.run_packs(runs);
        Ok(jid)
    }

    /// Launch (and solve) every open pack that is due — a member job's
    /// deadline passed, or the session max-wait expired (no-op without
    /// either policy). Called by `submit`; long-lived callers with idle
    /// gaps (e.g. `oggm serve` between input lines) call it on a clock
    /// bounded by [`Service::next_due`]. Under [`LaunchPolicy::OnFlush`]
    /// this is a no-op — that policy's contract is "nothing launches
    /// before `flush()`", and the deterministic flush-time grouping the
    /// `run_queue` wrapper relies on must not be perturbed by a deadline.
    pub fn tick(&mut self) {
        let runs = self.adm.tick(Instant::now());
        self.run_packs(runs);
    }

    /// The earliest instant any open pack becomes due, for sleep bounds in
    /// tick-driving loops. None when no launch is waiting on a clock.
    pub fn next_due(&self) -> Option<Instant> {
        self.adm.next_due()
    }

    /// Launch (and solve) every open pack, in deterministic (scenario,
    /// bucket) key order, chunking oversize [`LaunchPolicy::OnFlush`]
    /// groups to the compiled capacity — exactly `run_queue`'s historical
    /// grouping.
    pub fn flush(&mut self) {
        let runs = self.adm.flush();
        self.run_packs(runs);
    }

    /// Pop the next streamed outcome, if any pack has finished since the
    /// last poll.
    pub fn poll(&mut self) -> Option<JobEvent> {
        self.ready.pop_front()
    }

    /// Flush open packs and take every ready event (the "solve whatever is
    /// left and give me everything" path).
    pub fn drain(&mut self) -> Vec<JobEvent> {
        self.flush();
        self.ready.drain(..).collect()
    }

    /// Jobs admitted but not yet solved (riding in open packs).
    pub fn pending(&self) -> usize {
        self.adm.pending()
    }

    /// Events ready to poll right now.
    pub fn ready_len(&self) -> usize {
        self.ready.len()
    }

    /// Jobs admitted over the session so far.
    pub fn submitted(&self) -> u64 {
        self.adm.submitted()
    }

    /// Per-pack statistics, in launch order (grows as packs finish;
    /// failed packs have no stats row, so this can be shorter than
    /// [`Service::launched`]).
    pub fn packs(&self) -> &[PackStat] {
        &self.packs
    }

    /// Packs launched so far, successful or failed.
    pub fn launched(&self) -> usize {
        self.adm.launched()
    }

    /// Take ownership of the per-pack statistics accumulated so far
    /// (the `run_queue` wrapper builds its report from these).
    pub fn take_packs(&mut self) -> Vec<PackStat> {
        std::mem::take(&mut self.packs)
    }

    /// Point-in-time admission/backpressure counters.
    pub fn admission(&self) -> AdmissionSnapshot {
        self.adm.snapshot()
    }

    /// The parameters this service serves.
    pub fn params(&self) -> &Params {
        self.exec.params()
    }

    /// The runtime this service runs on.
    pub fn runtime(&self) -> &'r Runtime {
        self.exec.rt
    }

    /// Solve launched packs inline: events stream to the ready queue,
    /// stats accumulate, and per-tenant load is released as events emit.
    fn run_packs(&mut self, runs: Vec<PackRun>) {
        for run in runs {
            let done = self.exec.run(run);
            self.adm.record_retries(done.retries as u64, done.faults as u64);
            for ev in &done.events {
                self.adm.complete(ev.tenant, 1);
            }
            self.ready.extend(done.events);
            self.packs.extend(done.stat);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn outcome() -> JobOutcome {
        JobOutcome {
            id: "a".into(),
            scenario: Scenario::Mis,
            nodes: 20,
            edges: 31,
            pack: 2,
            solution: vec![0, 5],
            solution_size: 2,
            objective: 2.0,
            valid: true,
            evaluations: 2,
            selections: 2,
        }
    }

    #[test]
    fn event_json_done_and_failed() {
        let ev = JobEvent {
            job: JobId(7),
            id: "a".into(),
            scenario: Scenario::Mis,
            tenant: 3,
            wait_ms: 1.5,
            result: Ok(outcome()),
        };
        let s = ev.to_json().render();
        assert!(s.contains("\"id\":\"a\""), "{s}");
        assert!(s.contains("\"job\":7"), "{s}");
        assert!(s.contains("\"tenant\":3"), "{s}");
        assert!(s.contains("\"wait_ms\":1.5"), "{s}");
        assert!(s.contains("\"solution\":[0,5]"), "{s}");
        assert!(s.contains("\"valid\":true"), "{s}");
        assert!(!s.contains("error"), "{s}");

        let ev = JobEvent {
            job: JobId(8),
            id: "b".into(),
            scenario: Scenario::Mvc,
            tenant: 0,
            wait_ms: 0.0,
            result: Err("pack 1 (mvc, N=24): boom".into()),
        };
        let s = ev.to_json().render();
        assert!(s.contains("\"error\":\"pack 1 (mvc, N=24): boom\""), "{s}");
        assert!(s.contains("\"job\":8"), "{s}");
        assert!(s.contains("\"tenant\":0"), "{s}");
        assert!(!s.contains("solution"), "{s}");
    }

    #[test]
    fn job_id_is_the_admission_index() {
        assert_eq!(JobId(3).index(), 3);
        assert_eq!(format!("{}", JobId(3)), "#3");
    }

    #[test]
    fn fault_classification_separates_transient_from_permanent() {
        for msg in [
            "rank-parallel forward failed: injected fault at all_reduce(deposit) (rank 1, phase 3)",
            "install pack failed: collective aborted by rank 1: boom",
            "rank 1: worker panicked: injected panic",
            "rank 0: worker thread died",
            "2 dead rank(s) after 2 replacement round(s): per-pack restart budget exhausted",
            "install pack failed: injected fault: transport frame 2 to rank 1 dropped",
            // TCP rank death is retryable since rejoin (DESIGN.md §12): a
            // replacement worker re-fills the slot inside the window.
            "rank 1 worker process unreachable (connection closed)",
            "install pack failed: rank 2 worker process disconnected (broken pipe)",
            "rank 1 unreachable for 3.2s (no frames or heartbeats within the 3.0s --rank-timeout)",
        ] {
            assert!(retryable_fault(msg), "should be retryable: {msg}");
        }
        for msg in [
            "job 'a' (|V|=500) not admitted: no compiled bucket fits",
            "loading stage q_scores_b4_n24: no such artifact",
            "pack has 2 shards but the pool has 4 ranks",
            // Window expiry is terminal — and stays terminal even when its
            // context chain carries a retryable "unreachable for" phrase
            // (the expiry check is ordered first).
            "rejoin window expired: rank(s) 1 still vacant after 30s",
            "rejoin window expired: rank 1 unreachable for 31.0s",
        ] {
            assert!(!retryable_fault(msg), "should not be retryable: {msg}");
        }
    }
}
