//! The solver service: a persistent front door over the batched solve
//! engine (DESIGN.md §8).
//!
//! Where `batch::run_queue` is one-shot — every job up front, solve
//! everything, report at the end — a [`Service`] is a long-lived session
//! that owns the warm state heavy solve traffic needs:
//!
//! * **Incremental admission** — [`Service::submit`] drops each job into an
//!   *open pack* keyed by (scenario, compiled bucket). A pack launches the
//!   moment it fills to the largest compiled batch capacity
//!   ([`LaunchPolicy::OnFill`]), when an optional max-wait expires, or at
//!   [`Service::flush`]. Admission errors (no compiled bucket fits the
//!   graph) surface per job at `submit`, with the job id in the message.
//! * **Streaming outcomes** — finished packs push one [`JobEvent`] per job
//!   into a ready queue that [`Service::poll`] drains, so callers see
//!   results while later jobs are still being admitted. A pack-level solve
//!   failure becomes a contextful per-job error event, never a panic.
//! * **Warm caches** — compiled executables live in the [`Runtime`], and θ
//!   is published once through a service-owned
//!   [`ThetaCache`](crate::coordinator::fwd::ThetaCache), so every pack
//!   after the first skips the θ upload entirely (`rust/tests/service.rs`
//!   asserts a warm drain moves strictly fewer h2d bytes than a cold one).
//!
//! Configuration comes from one builder-style [`Options`] shared with every
//! CLI subcommand; `batch::run_queue` is a thin compatibility wrapper over
//! this type (submit all → flush → drain, [`LaunchPolicy::OnFlush`]).

/// The unified options layer (`Options`, `LaunchPolicy`).
pub mod options;

pub use options::{LaunchPolicy, Options};

use crate::batch::queue::{Job, JobOutcome, PackStat};
use crate::batch::solve::{solve_pack_session, SessionState};
use crate::coordinator::engine::Engine;
use crate::coordinator::fwd::ThetaCache;
use crate::env::Scenario;
use crate::graph::Graph;
use crate::model::Params;
use crate::parallel::RankPool;
use crate::runtime::Runtime;
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::collections::btree_map::Entry;
use std::collections::{BTreeMap, VecDeque};
use std::time::Instant;

/// Service-assigned job handle, monotonically numbered in admission order
/// (so it doubles as the submission index).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct JobId(u64);

impl JobId {
    /// The admission index (0 = first job submitted to this service).
    pub fn index(self) -> usize {
        self.0 as usize
    }
}

impl std::fmt::Display for JobId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "#{}", self.0)
    }
}

/// One streamed per-job result: the outcome, or a contextful error for
/// jobs whose pack failed to solve.
#[derive(Debug, Clone)]
pub struct JobEvent {
    /// Service-assigned handle (as returned by [`Service::submit`]).
    pub job: JobId,
    /// Caller-facing id (echoed from the submitted [`Job`]).
    pub id: String,
    /// Scenario the job ran under.
    pub scenario: Scenario,
    /// The outcome, or the pack's error with job/pack context.
    pub result: Result<JobOutcome, String>,
}

impl JobEvent {
    /// Render as one `oggm serve` JSONL line: the [`JobOutcome`] object
    /// plus the service `job` handle, or `{id, job, scenario, error}` for
    /// failures (schema in README §serve).
    pub fn to_json(&self) -> Json {
        match &self.result {
            Ok(o) => o.to_json().set("job", self.job.0),
            Err(e) => Json::obj()
                .set("id", self.id.as_str())
                .set("job", self.job.0)
                .set("scenario", self.scenario.name())
                .set("error", e.as_str()),
        }
    }
}

/// Ascending node ids of a per-graph solution mask.
fn solution_ids(mask: &[bool]) -> Vec<usize> {
    mask.iter().enumerate().filter(|(_, &b)| b).map(|(v, _)| v).collect()
}

/// A not-yet-launched job riding in an open pack.
#[derive(Debug)]
struct Pending {
    job: JobId,
    id: String,
    graph: Graph,
}

/// An open pack: jobs of one (scenario, bucket) waiting to fill.
#[derive(Debug)]
struct OpenPack {
    members: Vec<Pending>,
    opened: Instant,
    /// Largest compiled batch capacity for the key's (bucket, P) — the
    /// fill threshold and the flush-time chunk size.
    max_cap: usize,
}

/// A persistent solver service session. See the module docs for the
/// lifecycle; construction is [`Service::new`] from [`Options`] (CLI /
/// library callers) or [`Service::with_cfg`] from a raw
/// [`BatchCfg`](crate::batch::BatchCfg) (the `run_queue` compatibility
/// wrapper, which must preserve an exact cfg including its cost model).
pub struct Service<'r> {
    rt: &'r Runtime,
    params: Params,
    cfg: crate::batch::BatchCfg,
    launch: LaunchPolicy,
    max_wait: Option<f64>,
    /// Stop solving after the first pack-level error: later launches emit
    /// skipped-error events instead of running (the `run_queue` wrapper's
    /// historical fail-fast).
    abort_on_error: bool,
    aborted: bool,
    theta: ThetaCache,
    /// Persistent rank pool for the rank-parallel engine, created lazily
    /// at the first launch (so construction stays infallible) and kept
    /// warm across packs: each rank re-uploads θ only when the session
    /// parameters change — i.e. never, after the first pack (DESIGN.md §9).
    pool: Option<RankPool>,
    next_job: u64,
    /// Packs launched so far (successful or failed) — the pack-index
    /// source. `packs` holds stats for successful packs only, so its
    /// length would reuse an index after a failure.
    launched: usize,
    open: BTreeMap<(Scenario, usize), OpenPack>,
    ready: VecDeque<JobEvent>,
    packs: Vec<PackStat>,
}

impl<'r> Service<'r> {
    /// Open a service session over a warm runtime with the given options.
    pub fn new(rt: &'r Runtime, params: Params, opts: &Options) -> Service<'r> {
        let mut svc = Service::with_cfg(rt, params, crate::batch::BatchCfg::from(opts));
        svc.launch = opts.launch;
        svc.max_wait = opts.max_wait;
        svc
    }

    /// Open a service session from an exact [`BatchCfg`](crate::batch::BatchCfg)
    /// (launch policy [`LaunchPolicy::OnFill`], no max-wait; override with
    /// [`Service::launch_policy`]).
    pub fn with_cfg(rt: &'r Runtime, params: Params, cfg: crate::batch::BatchCfg) -> Service<'r> {
        Service {
            rt,
            params,
            cfg,
            launch: LaunchPolicy::OnFill,
            max_wait: None,
            abort_on_error: false,
            aborted: false,
            theta: ThetaCache::new(rt),
            pool: None,
            next_job: 0,
            launched: 0,
            open: BTreeMap::new(),
            ready: VecDeque::new(),
            packs: Vec::new(),
        }
    }

    /// Override the pack-launch policy (builder style).
    pub fn launch_policy(mut self, launch: LaunchPolicy) -> Service<'r> {
        self.launch = launch;
        self
    }

    /// Stop solving after the first pack-level error (builder style):
    /// later launches emit "skipped" error events instead of running their
    /// packs. The one-shot `run_queue` wrapper sets this so an early pack
    /// failure does not burn device time solving packs whose outcomes the
    /// failed call will discard; a streaming service keeps the default
    /// (false) and serves every pack independently.
    pub fn fail_fast(mut self, on: bool) -> Service<'r> {
        self.abort_on_error = on;
        self
    }

    /// Admit one job. Errors (no compiled bucket fits the graph at this P)
    /// are returned here with the job id in the context — the job is not
    /// admitted and no event will be emitted for it. On success the job is
    /// in an open pack; under [`LaunchPolicy::OnFill`] a pack that just
    /// filled to compiled capacity launches before `submit` returns, so
    /// its outcomes are already pollable.
    pub fn submit(&mut self, job: Job) -> Result<JobId> {
        let p = self.cfg.engine.p;
        let bucket = self
            .rt
            .manifest
            .bucket_for_any_batch(job.graph.n, p)
            .with_context(|| format!("job '{}' (|V|={}) not admitted", job.id, job.graph.n))?;
        let key = (job.scenario, bucket);
        // The capacity lookup only matters when this key opens a new pack;
        // an existing open pack already carries it.
        let open = match self.open.entry(key) {
            Entry::Occupied(e) => e.into_mut(),
            Entry::Vacant(v) => {
                let max_cap = self
                    .rt
                    .manifest
                    .batch_sizes(bucket, bucket / p)
                    .last()
                    .copied()
                    .with_context(|| {
                        format!(
                            "job '{}': no compiled batch capacities at bucket N={bucket}, P={p} \
                             (manifest inconsistent: the bucket lookup accepted it)",
                            job.id
                        )
                    })?;
                v.insert(OpenPack { members: Vec::new(), opened: Instant::now(), max_cap })
            }
        };
        let jid = JobId(self.next_job);
        self.next_job += 1;
        open.members.push(Pending { job: jid, id: job.id, graph: job.graph });
        if self.launch == LaunchPolicy::OnFill && open.members.len() >= open.max_cap {
            let pack = self.open.remove(&key).expect("open pack just inserted");
            self.launch_chunks(key.0, key.1, pack);
        }
        self.tick();
        Ok(jid)
    }

    /// Launch every open pack whose max-wait expired (no-op without a
    /// max-wait policy). Called by `submit`; long-lived callers with idle
    /// gaps (e.g. `oggm serve` between input lines) call it directly.
    /// Under [`LaunchPolicy::OnFlush`] this is a no-op — that policy's
    /// contract is "nothing launches before `flush()`", and the
    /// deterministic flush-time grouping the `run_queue` wrapper relies on
    /// must not be perturbed by a deadline.
    pub fn tick(&mut self) {
        if self.launch == LaunchPolicy::OnFlush {
            return;
        }
        let Some(wait) = self.max_wait else { return };
        let due: Vec<(Scenario, usize)> = self
            .open
            .iter()
            .filter(|(_, pack)| pack.opened.elapsed().as_secs_f64() >= wait)
            .map(|(&k, _)| k)
            .collect();
        for key in due {
            let pack = self.open.remove(&key).expect("due key read from the map");
            self.launch_chunks(key.0, key.1, pack);
        }
    }

    /// Launch every open pack, in deterministic (scenario, bucket) key
    /// order, chunking oversize [`LaunchPolicy::OnFlush`] groups to the
    /// compiled capacity — exactly `run_queue`'s historical grouping.
    pub fn flush(&mut self) {
        let open = std::mem::take(&mut self.open);
        for ((scenario, bucket), pack) in open {
            self.launch_chunks(scenario, bucket, pack);
        }
    }

    /// Pop the next streamed outcome, if any pack has finished since the
    /// last poll.
    pub fn poll(&mut self) -> Option<JobEvent> {
        self.ready.pop_front()
    }

    /// Flush open packs and take every ready event (the "solve whatever is
    /// left and give me everything" path).
    pub fn drain(&mut self) -> Vec<JobEvent> {
        self.flush();
        self.ready.drain(..).collect()
    }

    /// Jobs admitted but not yet solved (riding in open packs).
    pub fn pending(&self) -> usize {
        self.open.values().map(|p| p.members.len()).sum()
    }

    /// Events ready to poll right now.
    pub fn ready_len(&self) -> usize {
        self.ready.len()
    }

    /// Jobs admitted over the session so far.
    pub fn submitted(&self) -> u64 {
        self.next_job
    }

    /// Per-pack statistics, in launch order (grows as packs finish;
    /// failed packs have no stats row, so this can be shorter than
    /// [`Service::launched`]).
    pub fn packs(&self) -> &[PackStat] {
        &self.packs
    }

    /// Packs launched so far, successful or failed.
    pub fn launched(&self) -> usize {
        self.launched
    }

    /// Take ownership of the per-pack statistics accumulated so far
    /// (the `run_queue` wrapper builds its report from these).
    pub fn take_packs(&mut self) -> Vec<PackStat> {
        std::mem::take(&mut self.packs)
    }

    /// The parameters this service serves.
    pub fn params(&self) -> &Params {
        &self.params
    }

    /// The runtime this service runs on.
    pub fn runtime(&self) -> &'r Runtime {
        self.rt
    }

    /// Start the session's rank pool if the configured engine needs one
    /// (no-op under lockstep, or once it exists). A startup failure (e.g.
    /// the offline xla stub) surfaces through the caller's per-job error
    /// events, like any pack-level failure.
    fn ensure_pool(&mut self) -> Result<()> {
        if self.cfg.engine.mode != Engine::RankParallel || self.pool.is_some() {
            return Ok(());
        }
        let pool = RankPool::new(self.rt.manifest.dir.clone(), self.cfg.engine.p)
            .context("starting the rank-parallel worker pool")?;
        self.pool = Some(pool);
        Ok(())
    }

    /// Launch `pack`'s members as one or more solve packs of at most
    /// `max_cap` jobs, preserving admission order.
    fn launch_chunks(&mut self, scenario: Scenario, bucket: usize, pack: OpenPack) {
        let mut members = pack.members;
        while !members.is_empty() {
            let rest = if members.len() > pack.max_cap {
                members.split_off(pack.max_cap)
            } else {
                Vec::new()
            };
            let chunk = std::mem::replace(&mut members, rest);
            self.launch(scenario, bucket, chunk);
        }
    }

    /// Solve one chunk as a pack; emit one event per member. A pack-level
    /// failure becomes a per-job error event with pack context (the
    /// service boundary never panics on a bad pack).
    fn launch(&mut self, scenario: Scenario, bucket: usize, chunk: Vec<Pending>) {
        debug_assert!(!chunk.is_empty(), "launch of an empty chunk");
        if self.aborted {
            // Fail-fast mode after an earlier pack error: skip the solve,
            // but still emit one event per job so nothing is lost.
            for m in chunk {
                self.ready.push_back(JobEvent {
                    job: m.job,
                    id: m.id,
                    scenario,
                    result: Err("skipped: an earlier pack failed (fail-fast)".into()),
                });
            }
            return;
        }
        let pack_idx = self.launched;
        self.launched += 1;
        let mut meta = Vec::with_capacity(chunk.len());
        let mut graphs = Vec::with_capacity(chunk.len());
        for m in chunk {
            meta.push((m.job, m.id, m.graph.n, m.graph.m));
            graphs.push(m.graph);
        }
        let res = match self.ensure_pool() {
            Err(e) => Err(e),
            Ok(()) => solve_pack_session(
                self.rt,
                &self.cfg,
                &self.params,
                scenario,
                graphs,
                bucket,
                SessionState { theta: Some(&self.theta), pool: self.pool.as_ref() },
            ),
        };
        match res {
            Ok(res) => {
                for (slot, (job, id, nodes, edges)) in meta.into_iter().enumerate() {
                    let r = &res.per_graph[slot];
                    self.ready.push_back(JobEvent {
                        job,
                        id: id.clone(),
                        scenario,
                        result: Ok(JobOutcome {
                            id,
                            scenario,
                            nodes,
                            edges,
                            pack: pack_idx,
                            solution: solution_ids(&r.solution),
                            solution_size: r.solution_size,
                            objective: r.objective,
                            valid: r.valid,
                            evaluations: r.evaluations,
                            selections: r.selections,
                        }),
                    });
                }
                self.packs.push(PackStat {
                    pack: pack_idx,
                    scenario,
                    bucket_n: bucket,
                    jobs: res.per_graph.len(),
                    capacity: res.initial_capacity,
                    rounds: res.rounds,
                    repacks: res.repacks,
                    sim_time: res.sim_total,
                    wall_time: res.wall_total,
                    comm_bytes: res.timing.comm_bytes,
                    exec: res.exec,
                });
            }
            Err(e) => {
                if self.abort_on_error {
                    self.aborted = true;
                }
                let msg = format!("pack {pack_idx} ({scenario}, N={bucket}): {e:#}");
                for (job, id, _, _) in meta {
                    self.ready.push_back(JobEvent {
                        job,
                        id,
                        scenario,
                        result: Err(msg.clone()),
                    });
                }
            }
        }
    }
}

impl Drop for Service<'_> {
    fn drop(&mut self) {
        self.theta.evict(self.rt);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn outcome() -> JobOutcome {
        JobOutcome {
            id: "a".into(),
            scenario: Scenario::Mis,
            nodes: 20,
            edges: 31,
            pack: 2,
            solution: vec![0, 5],
            solution_size: 2,
            objective: 2.0,
            valid: true,
            evaluations: 2,
            selections: 2,
        }
    }

    #[test]
    fn event_json_done_and_failed() {
        let ev = JobEvent {
            job: JobId(7),
            id: "a".into(),
            scenario: Scenario::Mis,
            result: Ok(outcome()),
        };
        let s = ev.to_json().render();
        assert!(s.contains("\"id\":\"a\""), "{s}");
        assert!(s.contains("\"job\":7"), "{s}");
        assert!(s.contains("\"solution\":[0,5]"), "{s}");
        assert!(s.contains("\"valid\":true"), "{s}");
        assert!(!s.contains("error"), "{s}");

        let ev = JobEvent {
            job: JobId(8),
            id: "b".into(),
            scenario: Scenario::Mvc,
            result: Err("pack 1 (mvc, N=24): boom".into()),
        };
        let s = ev.to_json().render();
        assert!(s.contains("\"error\":\"pack 1 (mvc, N=24): boom\""), "{s}");
        assert!(s.contains("\"job\":8"), "{s}");
        assert!(!s.contains("solution"), "{s}");
    }

    #[test]
    fn job_id_is_the_admission_index() {
        assert_eq!(JobId(3).index(), 3);
        assert_eq!(format!("{}", JobId(3)), "#3");
    }
}
