//! OpenGraphGym-MG reproduction library.
//!
//! A multi-device graph-RL framework (deep Q-learning + structure2vec) with
//! spatial parallelism: graph state is row-partitioned across P simulated
//! devices, the policy model runs as AOT-compiled JAX/Pallas stages on the
//! PJRT CPU client, and the Rust coordinator owns collectives, the replay
//! buffer, the training loop, and the inference loop. See DESIGN.md.

#![warn(missing_docs)]

/// Offline stand-ins for rand/serde/clap/criterion: RNG, timers, binary
/// tensor I/O, JSON writer, property-test harness, CLI parsing.
pub mod util;
/// L1 graph substrate: CSR/COO storage, generators, partitioning, packing,
/// edge-list I/O, dataset statistics.
pub mod graph;
/// Graph learning environments (MVC / MaxCut / MIS) and the `Scenario`
/// dispatch.
pub mod env;
/// Classical baselines: exact branch-and-bound, greedy, 2-approximation,
/// local search.
pub mod solvers;
/// Policy-model parameters, Adam, hyper-parameters, checkpoints.
pub mod model;
/// Simulated collectives and the α–β communication cost model.
pub mod collective;
/// PJRT stage runtime: artifact manifest + lazy-compiled executables.
pub mod runtime;
/// L3 coordinator: shard state, distributed fwd/bwd, selection, RL
/// inference/training loops, replay, metrics.
pub mod coordinator;
/// Rank-parallel execution engine: persistent worker ranks with per-rank
/// device residency and real collectives (DESIGN.md §9).
pub mod parallel;
/// Pluggable rank transport: framed wire protocol, in-process and TCP
/// links, process-separated workers (DESIGN.md §12).
pub mod transport;
/// Graph-level batched solve engine and its job-queue front-end.
pub mod batch;
/// Persistent solver service: incremental job admission, streaming
/// outcomes, unified `Options` (DESIGN.md §8).
pub mod service;
/// Networked serve front door: TCP listener, JSONL wire protocol,
/// continuous batching across connections (DESIGN.md §10).
pub mod net;
/// Closed-form performance/memory analysis helpers (paper §5).
pub mod analysis;
