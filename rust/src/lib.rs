//! OpenGraphGym-MG reproduction library.
//!
//! A multi-device graph-RL framework (deep Q-learning + structure2vec) with
//! spatial parallelism: graph state is row-partitioned across P simulated
//! devices, the policy model runs as AOT-compiled JAX/Pallas stages on the
//! PJRT CPU client, and the Rust coordinator owns collectives, the replay
//! buffer, the training loop, and the inference loop. See DESIGN.md.

pub mod util;
pub mod graph;
pub mod env;
pub mod solvers;
pub mod model;
pub mod collective;
pub mod runtime;
pub mod coordinator;
pub mod batch;
pub mod analysis;
