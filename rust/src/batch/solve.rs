//! Batched RL inference: Alg. 4 lifted to a pack of B graphs.
//!
//! Per step ("round"), ONE distributed forward pass evaluates every active
//! graph's scores at once — the pack shares the embedding/Q stages, so the
//! per-graph cost of kernel launch, upload, and collectives is amortized by
//! B. Selection, environment stepping, and shard updates then run per graph
//! on its own block, exactly mirroring `coordinator::infer::solve_env`; the
//! per-graph state trajectories are therefore identical to B sequential
//! single-graph runs (the block-diagonal pack has no cross-graph terms),
//! which `rust/tests/batch_equivalence.rs` asserts.
//!
//! Early-exit compaction: graphs finish at different steps. When enough have
//! finished that a smaller *compiled* batch capacity fits the survivors, the
//! pack is rebuilt without them (their padded blocks would otherwise ride
//! along in every remaining stage execution). Capacities come from the
//! artifact manifest, so compaction is exactly as fine-grained as the
//! compiled batch buckets.

use crate::batch::env::BatchEnv;
use crate::coordinator::engine::{Engine, EngineCfg, StepTiming};
use crate::coordinator::fwd::ThetaCache;
use crate::coordinator::selection::{select_count, top_d, SelectionPolicy};
use crate::coordinator::shard::{shards_for_pack, sparse_shards_for_pack, ShardSet, Storage};
use crate::env::Scenario;
use crate::graph::{Graph, PackLayout, Partition};
use crate::model::Params;
use crate::parallel::{ExecEngine, RankPool};
use crate::runtime::{ExecStats, Runtime};
use anyhow::{ensure, Result};
use std::time::Instant;

/// Batched-inference configuration.
#[derive(Debug, Clone, Copy)]
pub struct BatchCfg {
    /// Shared engine parameters (P, L, comm cost model).
    pub engine: EngineCfg,
    /// Node-selection policy applied per graph block.
    pub policy: SelectionPolicy,
    /// Elide layer-0 message stage (exact; see fwd.rs).
    pub skip_zero_layer: bool,
    /// Evict finished graphs and repack to smaller compiled capacities.
    pub compact: bool,
    /// Hold θ + adjacency state on device across rounds (exact; see fwd.rs
    /// `DeviceState`/`SparseDeviceState`). A compaction repack invalidates
    /// and rebuilds the device buffers.
    pub device_resident: bool,
    /// Per-shard storage mode (DESIGN.md §7): dense B×NI×N oracle or
    /// CSR-backed sparse tiles scaling O(E/P + NI).
    pub storage: Storage,
    /// Full pack re-solve attempts after a retryable fault before per-job
    /// errors are emitted (`--retries`, DESIGN.md §11). Retried solves are
    /// bit-identical to fault-free ones (selection is deterministic in θ).
    pub retries: usize,
    /// Per-pack rank-replacement budget for the rank-parallel pool
    /// (`--max-rank-restarts`, DESIGN.md §11).
    pub max_rank_restarts: usize,
    /// Remote-rank liveness deadline in seconds (`--rank-timeout`,
    /// DESIGN.md §12): a TCP peer silent for this long — no frames and no
    /// heartbeats — is declared dead. 0 disables liveness enforcement.
    pub rank_timeout: f64,
    /// Seconds the coordinator holds a vacated TCP rank slot open for a
    /// replacement worker (`--rejoin-window`, DESIGN.md §12) before the
    /// loss becomes a terminal error.
    pub rejoin_window: f64,
}

impl BatchCfg {
    /// Default configuration for `p` shards and `l` embedding layers.
    pub fn new(p: usize, l: usize) -> BatchCfg {
        BatchCfg {
            engine: EngineCfg::new(p, l),
            policy: SelectionPolicy::Single,
            skip_zero_layer: true,
            compact: true,
            device_resident: true,
            storage: Storage::Dense,
            retries: 1,
            max_rank_restarts: crate::parallel::DEFAULT_MAX_RANK_RESTARTS,
            rank_timeout: 30.0,
            rejoin_window: 30.0,
        }
    }
}

/// Outcome for one graph of the pack.
#[derive(Debug, Clone)]
pub struct BatchGraphResult {
    /// Solution mask over the graph's (unpadded) nodes.
    pub solution: Vec<bool>,
    /// Number of selected nodes |S|.
    pub solution_size: usize,
    /// Scenario objective (|S| except MaxCut: cut weight).
    pub objective: f64,
    /// Shared forward passes this graph participated in.
    pub evaluations: usize,
    /// Nodes selected in total.
    pub selections: usize,
    /// Structural validity (cover / independent set / always true for cut).
    pub valid: bool,
}

/// Outcome of solving one pack.
#[derive(Debug)]
pub struct BatchResult {
    /// Per-graph outcomes, in input order.
    pub per_graph: Vec<BatchGraphResult>,
    /// Shared forward passes executed (batched steps).
    pub rounds: usize,
    /// Compaction events (pack rebuilds evicting finished graphs).
    pub repacks: usize,
    /// Batch capacity of the first round (compiled bucket the pack opened at).
    pub initial_capacity: usize,
    /// Accumulated lockstep timing across rounds.
    pub timing: StepTiming,
    /// Simulated-parallel seconds, total.
    pub sim_total: f64,
    /// Wall-clock total.
    pub wall_total: f64,
    /// Runtime transfer/execution counters accumulated by this pack
    /// (h2d/d2h bytes, executions, exec time).
    pub exec: ExecStats,
    /// Host bytes of the initial shard state across all P shards (dense:
    /// B·NI·N adjacency + S/C; sparse: S/C/deg + edge tiles) — the §7
    /// memory-model observable.
    pub state_bytes: usize,
    /// Total undirected edges E packed initially (the sparse path's
    /// O(E/P + NI) scaling variable).
    pub pack_edges: usize,
}

/// Smallest compiled capacity that fits `want` graphs (capacities are the
/// manifest's ascending batch sizes for this bucket/shard shape).
fn capacity_for(caps: &[usize], want: usize) -> usize {
    caps.iter().copied().find(|&c| c >= want).unwrap_or_else(|| *caps.last().unwrap())
}

/// Layout of the current pack: one slot per packed graph, empty padding
/// slots as zero-size. The gathered score vector of each forward pass is
/// indexed exactly by the layout's packed ids, so all block slicing goes
/// through it.
fn pack_layout(
    benv: &BatchEnv,
    slots: &[usize],
    capacity: usize,
    bucket_n: usize,
) -> PackLayout {
    let mut sizes: Vec<usize> = slots.iter().map(|&gi| benv.graph(gi).n).collect();
    sizes.resize(capacity, 0);
    PackLayout::new(bucket_n, sizes)
}

/// Build the P shard states for the pack slots (padding empty slots with
/// zero-node blocks up to `capacity`), in the configured storage mode.
/// The sparse mode resolves its (chunk, edge-cap ladder) from the manifest
/// at the pack's batch capacity — repacks change the capacity, so each
/// rebuild re-resolves.
fn build_set(
    rt: &Runtime,
    storage: Storage,
    k: usize,
    benv: &BatchEnv,
    slots: &[usize],
    capacity: usize,
    part: Partition,
    empty: &Graph,
) -> Result<ShardSet> {
    let cand: Vec<Vec<bool>> = slots.iter().map(|&gi| benv.candidates(gi)).collect();
    let mut graphs: Vec<&Graph> = Vec::with_capacity(capacity);
    let mut removed: Vec<&[bool]> = Vec::with_capacity(capacity);
    let mut solution: Vec<&[bool]> = Vec::with_capacity(capacity);
    let mut candidates: Vec<&[bool]> = Vec::with_capacity(capacity);
    for (slot, &gi) in slots.iter().enumerate() {
        graphs.push(benv.graph(gi));
        removed.push(benv.env(gi).removed_mask());
        solution.push(benv.env(gi).solution_mask());
        candidates.push(&cand[slot]);
    }
    for _ in slots.len()..capacity {
        graphs.push(empty);
        removed.push(&[]);
        solution.push(&[]);
        candidates.push(&[]);
    }
    Ok(match storage {
        Storage::Dense => {
            ShardSet::Dense(shards_for_pack(part, &graphs, &removed, &solution, &candidates))
        }
        Storage::Sparse => {
            let (chunk, caps) = rt.manifest.sparse_config(capacity, part.ni(), k)?;
            ShardSet::Sparse(sparse_shards_for_pack(
                part, &graphs, &removed, &solution, &candidates, chunk, &caps,
            ))
        }
    })
}

/// Session-owned warm state a pack solve can reuse: the service's shared
/// θ cache (lockstep residency, DESIGN.md §8) and/or a persistent
/// [`RankPool`] (rank-parallel engine, DESIGN.md §9 — its per-rank θ
/// caches make the lockstep cache moot there).
#[derive(Clone, Copy, Default)]
pub struct SessionState<'a> {
    /// Shared θ namespace for the lockstep device state.
    pub theta: Option<&'a ThetaCache>,
    /// Persistent rank pool (required for [`Engine::RankParallel`] warm
    /// sessions; a transient pool is created per call otherwise).
    pub pool: Option<&'a RankPool>,
}

/// Solve a pack of graphs under one scenario with shared forward passes.
///
/// All graphs must fit `bucket_n`, and the pack must fit the largest batch
/// capacity compiled for (bucket_n, P) — the job queue (`batch::queue`)
/// handles chunking larger workloads into packs. Graphs are taken by value
/// and moved into the per-graph environments (no internal copies).
pub fn solve_pack(
    rt: &Runtime,
    cfg: &BatchCfg,
    params: &Params,
    scenario: Scenario,
    graphs: Vec<Graph>,
    bucket_n: usize,
) -> Result<BatchResult> {
    solve_pack_in(rt, cfg, params, scenario, graphs, bucket_n, None)
}

/// [`solve_pack`] with an optional shared θ residency: when `theta` is a
/// service-owned [`ThetaCache`], the pack's device state uploads θ through
/// it, so a warm runtime serves θ from cache instead of re-transferring it
/// per pack (DESIGN.md §8). Under the rank-parallel engine a transient
/// [`RankPool`] is created for this call; warm sessions pass one through
/// [`solve_pack_session`] instead.
pub fn solve_pack_in(
    rt: &Runtime,
    cfg: &BatchCfg,
    params: &Params,
    scenario: Scenario,
    graphs: Vec<Graph>,
    bucket_n: usize,
    theta: Option<&ThetaCache>,
) -> Result<BatchResult> {
    let transient = match cfg.engine.mode {
        Engine::Lockstep => None,
        Engine::RankParallel => Some(RankPool::new_with(
            rt.manifest.dir.clone(),
            cfg.engine.p,
            cfg.max_rank_restarts,
            crate::collective::fault::FaultPlan::from_env()?,
        )?),
    };
    solve_pack_session(
        rt,
        cfg,
        params,
        scenario,
        graphs,
        bucket_n,
        SessionState { theta, pool: transient.as_ref() },
    )
}

/// [`solve_pack`] over session-owned warm state (shared θ cache and/or a
/// persistent rank pool) — the entry the persistent
/// [`Service`](crate::service::Service) drives.
pub fn solve_pack_session(
    rt: &Runtime,
    cfg: &BatchCfg,
    params: &Params,
    scenario: Scenario,
    graphs: Vec<Graph>,
    bucket_n: usize,
    session: SessionState<'_>,
) -> Result<BatchResult> {
    let wall = Instant::now();
    let part = Partition::new(bucket_n, cfg.engine.p);
    let caps = rt.manifest.batch_sizes(bucket_n, part.ni());
    ensure!(
        !caps.is_empty(),
        "no compiled fwd stages at bucket N={bucket_n}, P={} (any batch size); \
         add shapes to python/compile/configs.py and re-run `make artifacts`",
        cfg.engine.p
    );
    let max_cap = *caps.last().unwrap();
    ensure!(
        !graphs.is_empty() && graphs.len() <= max_cap,
        "pack of {} graphs exceeds the largest compiled batch capacity {max_cap} \
         at bucket N={bucket_n} (the job queue chunks packs to capacity)",
        graphs.len()
    );
    for g in &graphs {
        ensure!(g.n <= bucket_n, "graph |V|={} exceeds bucket N={bucket_n}", g.n);
    }

    let stats0 = exec_snapshot(rt, &session, cfg.engine.mode)?;
    let mut benv = BatchEnv::new(scenario, graphs);
    let empty = Graph::empty(0);
    let mut evals = vec![0usize; benv.len()];
    let mut sels = vec![0usize; benv.len()];
    let mut timing = StepTiming::new(cfg.engine.p);
    let (mut rounds, mut repacks) = (0usize, 0usize);
    let mut sim_total = 0.0f64;

    // Slots: graph indices currently packed, in batch order.
    let mut slots: Vec<usize> = benv.active();
    let mut capacity = if slots.is_empty() { 0 } else { capacity_for(&caps, slots.len()) };
    let initial_capacity = capacity;
    let mut layout = pack_layout(&benv, &slots, capacity, bucket_n);
    let pack_edges = {
        let refs: Vec<&Graph> = slots.iter().map(|&gi| benv.graph(gi)).collect();
        layout.total_edges(&refs)
    };
    let mut set = if slots.is_empty() {
        ShardSet::Dense(Vec::new())
    } else {
        build_set(rt, cfg.storage, params.k, &benv, &slots, capacity, part, &empty)?
    };
    let state_bytes = set.bytes();
    let mut removed_prev: Vec<Vec<bool>> =
        slots.iter().map(|&gi| benv.env(gi).removed_mask().to_vec()).collect();

    // Execution context (DESIGN.md §6/§7/§9): θ + pack adjacency state
    // uploaded once — on the coordinator runtime (lockstep) or per rank
    // (rank-parallel) — and kept in sync by per-round deltas; a compaction
    // repack changes the batch capacity (every buffer shape), so it
    // explicitly invalidates and rebuilds the device buffers. The one-time
    // upload is booked like every other transfer so resident-vs-fresh
    // times stay comparable. An all-done-at-admission pack (empty set)
    // installs nothing; the round loop below never runs for it.
    let mut ctx = if set.is_empty() {
        None
    } else {
        let c = ExecEngine::install(
            rt,
            session.pool,
            &cfg.engine,
            params,
            &mut set,
            cfg.device_resident,
            session.theta,
            0,
        )?;
        let up_t = c.last_transfer_secs();
        timing.h2d += up_t;
        sim_total += up_t;
        Some(c)
    };

    while !benv.all_done() {
        // Early-exit compaction: rebuild the pack without finished graphs
        // once a smaller compiled capacity fits the survivors.
        let active: Vec<usize> = slots.iter().copied().filter(|&gi| !benv.done(gi)).collect();
        if active.is_empty() {
            break;
        }
        if cfg.compact {
            let want = capacity_for(&caps, active.len());
            if want < capacity {
                slots = active;
                capacity = want;
                layout = pack_layout(&benv, &slots, capacity, bucket_n);
                set = build_set(rt, cfg.storage, params.k, &benv, &slots, capacity, part, &empty)?;
                removed_prev =
                    slots.iter().map(|&gi| benv.env(gi).removed_mask().to_vec()).collect();
                repacks += 1;
                if let Some(c) = ctx.as_mut() {
                    c.rebuild(&mut set)?;
                    let up_t = c.last_transfer_secs();
                    timing.h2d += up_t;
                    sim_total += up_t;
                }
            }
        }
        // Push state deltas from the previous round's selections to the
        // device (dense: row/col masks; sparse: dirty tile live-masks).
        let c = ctx.as_mut().expect("active graphs but no execution context");
        c.sync(&mut set)?;
        let sync_t = c.last_transfer_secs();
        timing.h2d += sync_t;
        sim_total += sync_t;

        // ONE shared distributed policy evaluation for the whole pack.
        let skip0 = cfg.skip_zero_layer;
        let out = c.forward(&cfg.engine, params, &set, false, skip0)?;
        rounds += 1;
        sim_total += out.timing.simulated();
        timing.merge(&out.timing);

        // Per-graph selection + state update on each block (identical to
        // the sequential loop in coordinator::infer::solve_env).
        let t_host = Instant::now();
        for slot in 0..slots.len() {
            let gi = slots[slot];
            if benv.done(gi) {
                continue;
            }
            let gn = layout.sizes[slot];
            let block = &out.scores[layout.slot_range(slot)][..gn];
            let env = benv.env_mut(gi);
            evals[gi] += 1;
            // §4.5.1 thresholds compare |C| to the LIVE residual-graph
            // size of this block's graph — not its original node count
            // (which stays pinned across removals and repacks).
            let rm = env.removed_mask();
            let num_cand = (0..gn).filter(|&v| env.is_candidate(v)).count();
            let live = (0..gn).filter(|&v| !rm[v]).count();
            let d = select_count(cfg.policy, num_cand, live);
            let picked = top_d(block, |v| env.is_candidate(v), d);
            assert!(!picked.is_empty(), "no candidates but graph {gi} not done");
            for v in picked {
                if !env.is_candidate(v) {
                    continue;
                }
                let (_r, done) = env.step(v);
                sels[gi] += 1;
                set.mirror_selection(slot, v, &*env, &mut removed_prev[slot]);
                if done {
                    break;
                }
            }
            set.refresh_candidates(slot, |v| env.is_candidate(v));
        }
        let host_t = t_host.elapsed().as_secs_f64();
        timing.host += host_t;
        sim_total += host_t;
    }

    let per_graph = (0..benv.len())
        .map(|gi| {
            let env = benv.env(gi);
            BatchGraphResult {
                solution: env.solution_mask().to_vec(),
                solution_size: env.solution_size(),
                objective: env.objective(),
                evaluations: evals[gi],
                selections: sels[gi],
                valid: benv.validate(gi),
            }
        })
        .collect();
    // Drop the execution context before the final stats snapshot so a
    // rank-parallel uninstall's work is not racing the counter reads.
    drop(ctx);
    let exec = exec_snapshot(rt, &session, cfg.engine.mode)?.since(&stats0);
    Ok(BatchResult {
        per_graph,
        rounds,
        repacks,
        initial_capacity,
        timing,
        sim_total,
        wall_total: wall.elapsed().as_secs_f64(),
        exec,
        state_bytes,
        pack_edges,
    })
}

/// Runtime counters behind the configured engine: the coordinator runtime
/// (lockstep) or the summed worker runtimes (rank-parallel).
fn exec_snapshot(rt: &Runtime, session: &SessionState<'_>, mode: Engine) -> Result<ExecStats> {
    match (mode, session.pool) {
        (Engine::RankParallel, Some(pool)) => pool.stats(),
        _ => Ok(rt.stats()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capacity_for_picks_smallest_fit() {
        let caps = [1usize, 2, 4, 8];
        assert_eq!(capacity_for(&caps, 1), 1);
        assert_eq!(capacity_for(&caps, 3), 4);
        assert_eq!(capacity_for(&caps, 4), 4);
        assert_eq!(capacity_for(&caps, 5), 8);
        // Overfull falls back to the largest (caller enforces the bound).
        assert_eq!(capacity_for(&caps, 9), 8);
    }

    #[test]
    fn build_set_pads_empty_slots() {
        use crate::graph::Graph;
        let g = Graph::from_edges(4, &[(0, 1), (2, 3)]).unwrap();
        let benv = BatchEnv::new(Scenario::Mvc, vec![g]);
        let part = Partition::new(12, 2);
        let empty = Graph::empty(0);
        // Dense build needs no runtime lookups, so a manifest-less Runtime
        // is never touched: drive the dense arm through shards_for_pack via
        // the same slot/padding assembly build_set performs.
        let cand: Vec<Vec<bool>> = vec![benv.candidates(0)];
        let graphs: Vec<&Graph> = vec![benv.graph(0), &empty, &empty, &empty];
        let removed: Vec<&[bool]> = vec![benv.env(0).removed_mask(), &[], &[], &[]];
        let solution: Vec<&[bool]> = vec![benv.env(0).solution_mask(), &[], &[], &[]];
        let candidates: Vec<&[bool]> = vec![&cand[0], &[], &[], &[]];
        let shards = shards_for_pack(part, &graphs, &removed, &solution, &candidates);
        assert_eq!(shards.len(), 2);
        assert_eq!(shards[0].b, 4);
        // Slot 0 carries the graph; slots 1..4 are all-zero blocks.
        let (n, ni) = (12, 6);
        assert!(shards[0].a[..ni * n].iter().any(|&x| x == 1.0));
        assert!(shards[0].a[ni * n..].iter().all(|&x| x == 0.0));
        assert!(shards[0].c[ni..].iter().all(|&x| x == 0.0));
        // The sparse twin of the same pack keeps block isolation via the
        // per-batch-element live masks.
        let sparse = sparse_shards_for_pack(
            part, &graphs, &removed, &solution, &candidates, 6, &[96],
        );
        assert_eq!(sparse.len(), 2);
        assert_eq!(sparse[0].densify(0), &shards[0].a[..ni * n]);
        assert!(sparse[0].densify(1).iter().all(|&x| x == 0.0));
    }
}
