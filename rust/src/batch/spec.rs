//! Batch-solve manifest: the line-oriented job list `oggm batch-solve`
//! consumes (serde is unavailable offline, so the format is hand-parsed).
//!
//! One job per line; `#`/`%` comments and blank lines are skipped:
//!
//! ```text
//! # <source> [key=value ...] [scenario] [id=NAME] [max_latency_ms=MS]
//! file graphs/road.txt mvc id=road
//! gen er n=250 rho=0.15 seed=7 maxcut
//! gen ba n=120 d=4 seed=3 mis max_latency_ms=250
//! gen hk n=500 d=4 triad=0.25 seed=9
//! ```
//!
//! Scenario defaults to `mvc`, ids default to `job<line-index>`, generator
//! parameters default to the paper's values (rho=0.15, d=4, triad=0.25,
//! seed=line index). Generation is deterministic per (model, n, params,
//! seed) — reruns of a manifest reproduce the same graphs.

use crate::env::Scenario;
use crate::graph::{generators, io as gio, Graph};
use crate::util::rng::Pcg32;
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// Where a job's graph comes from.
#[derive(Debug, Clone, PartialEq)]
pub enum GraphSource {
    /// Edge-list file (NetworkRepository/SNAP format, see graph::io).
    File(PathBuf),
    /// Synthetic generator spec.
    Gen {
        /// Generator model (`er` | `ba` | `hk`).
        model: String,
        /// Node count.
        n: usize,
        /// ER edge probability.
        rho: f64,
        /// BA/HK attachment degree.
        d: usize,
        /// HK triad-closure probability.
        triad: f64,
        /// Generator seed.
        seed: u64,
    },
}

/// One parsed manifest line.
#[derive(Debug, Clone, PartialEq)]
pub struct JobSpec {
    /// Job identifier (explicit `id=` or generated).
    pub id: String,
    /// Scenario for this job (default MVC).
    pub scenario: Scenario,
    /// Where the graph comes from.
    pub source: GraphSource,
    /// Launch-deadline budget in milliseconds (`max_latency_ms=`): the
    /// job's pack launches at most this long after admission even if not
    /// full. None = no per-job deadline (fill / max-wait / flush decide).
    pub max_latency_ms: Option<u64>,
}

impl JobSpec {
    /// Materialize the graph (read the file or run the generator).
    pub fn materialize(&self) -> Result<Graph> {
        match &self.source {
            GraphSource::File(path) => gio::read_edge_list(path)
                .with_context(|| format!("job '{}': reading {}", self.id, path.display())),
            GraphSource::Gen { model, n, rho, d, triad, seed } => {
                // Dedicated stream so manifest jobs never alias the
                // training/inference RNG streams.
                let mut rng = Pcg32::new(*seed, 0xBA7C4);
                match model.as_str() {
                    "er" => Ok(generators::erdos_renyi(*n, *rho, &mut rng)),
                    "ba" => Ok(generators::barabasi_albert(*n, *d, &mut rng)),
                    "hk" => Ok(generators::holme_kim(*n, *d, *triad, &mut rng)),
                    other => bail!("job '{}': unknown generator '{other}' (er|ba|hk)", self.id),
                }
            }
        }
    }
}

/// Parse one manifest line incrementally (the `oggm serve` admission
/// path): `Ok(None)` for blank/comment lines, `Ok(Some(spec))` for a job.
/// `index` numbers the defaults (`id=job<index>`, generator seed) exactly
/// as [`parse_manifest`] does — pass the count of jobs parsed so far so a
/// streamed file yields the same specs as a batch-parsed one.
pub fn parse_job_line(raw: &str, index: usize) -> Result<Option<JobSpec>> {
    let line = raw.trim();
    if line.is_empty() || line.starts_with('#') || line.starts_with('%') {
        return Ok(None);
    }
    parse_line(line, index).map(Some)
}

/// Parse manifest text into job specs.
pub fn parse_manifest(text: &str) -> Result<Vec<JobSpec>> {
    let mut jobs = Vec::new();
    for (lineno, raw) in text.lines().enumerate() {
        if let Some(job) = parse_job_line(raw, jobs.len())
            .with_context(|| format!("manifest line {}: '{}'", lineno + 1, raw.trim()))?
        {
            jobs.push(job);
        }
    }
    if jobs.is_empty() {
        bail!("manifest contains no jobs");
    }
    Ok(jobs)
}

/// Load and parse `<path>`.
pub fn load_manifest(path: impl AsRef<Path>) -> Result<Vec<JobSpec>> {
    let text = std::fs::read_to_string(path.as_ref())
        .with_context(|| format!("read manifest {}", path.as_ref().display()))?;
    parse_manifest(&text)
}

fn parse_line(line: &str, index: usize) -> Result<JobSpec> {
    let mut toks = line.split_whitespace();
    let kind = toks.next().unwrap(); // non-empty by construction
    let mut id = format!("job{index}");
    let mut scenario = Scenario::Mvc;
    let mut max_latency_ms = None;
    let mut kv: Vec<(String, String)> = Vec::new();
    let mut bare: Vec<String> = Vec::new();
    for t in toks {
        if let Some((k, v)) = t.split_once('=') {
            if k == "id" {
                id = v.to_string();
            } else if k == "scenario" {
                scenario = Scenario::parse(v)?;
            } else if k == "max_latency_ms" {
                max_latency_ms = Some(v.parse().context("bad max_latency_ms=")?);
            } else {
                kv.push((k.to_string(), v.to_string()));
            }
        } else if let Ok(s) = Scenario::parse(t) {
            scenario = s;
        } else {
            bare.push(t.to_string());
        }
    }
    let get = |key: &str, default: &str| -> String {
        kv.iter()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.clone())
            .unwrap_or_else(|| default.to_string())
    };
    // Reject unknown keys: a typo'd `sed=7` must error, not silently run
    // the job with default parameters.
    let check_keys = |allowed: &[&str]| -> Result<()> {
        for (k, _) in &kv {
            if !allowed.contains(&k.as_str()) {
                let hint = if allowed.is_empty() {
                    "this source takes none".to_string()
                } else {
                    format!("allowed: {}=", allowed.join("=, "))
                };
                bail!("unknown key '{k}=' ({hint})");
            }
        }
        Ok(())
    };
    let source = match kind {
        "file" => {
            check_keys(&[])?;
            if bare.len() != 1 {
                bail!("'file' takes exactly one path, got {bare:?}");
            }
            GraphSource::File(PathBuf::from(&bare[0]))
        }
        "gen" => {
            check_keys(&["n", "rho", "d", "triad", "seed"])?;
            if bare.len() != 1 {
                bail!("'gen' takes exactly one model (er|ba|hk), got {bare:?}");
            }
            let model = bare[0].clone();
            if !matches!(model.as_str(), "er" | "ba" | "hk") {
                bail!("unknown generator '{model}' (er|ba|hk)");
            }
            GraphSource::Gen {
                model,
                n: get("n", "250").parse().context("bad n=")?,
                rho: get("rho", "0.15").parse().context("bad rho=")?,
                d: get("d", "4").parse().context("bad d=")?,
                triad: get("triad", "0.25").parse().context("bad triad=")?,
                seed: get("seed", &index.to_string()).parse().context("bad seed=")?,
            }
        }
        other => bail!("unknown job kind '{other}' (file|gen)"),
    };
    Ok(JobSpec { id, scenario, source, max_latency_ms })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_mixed_manifest() {
        let text = "\
# demo manifest
gen er n=20 rho=0.2 seed=7 maxcut id=alpha

% another comment style
gen ba n=30 d=4 mis
file graphs/road.txt
gen hk n=40 triad=0.5 scenario=mvc
";
        let jobs = parse_manifest(text).unwrap();
        assert_eq!(jobs.len(), 4);
        assert_eq!(jobs[0].id, "alpha");
        assert_eq!(jobs[0].scenario, Scenario::MaxCut);
        assert_eq!(
            jobs[0].source,
            GraphSource::Gen { model: "er".into(), n: 20, rho: 0.2, d: 4, triad: 0.25, seed: 7 }
        );
        assert_eq!(jobs[1].id, "job1");
        assert_eq!(jobs[1].scenario, Scenario::Mis);
        // seed defaults to the job index.
        match &jobs[1].source {
            GraphSource::Gen { seed, .. } => assert_eq!(*seed, 1),
            other => panic!("{other:?}"),
        }
        assert_eq!(jobs[2].source, GraphSource::File(PathBuf::from("graphs/road.txt")));
        assert_eq!(jobs[3].scenario, Scenario::Mvc);
    }

    #[test]
    fn incremental_line_parse_matches_batch_parse() {
        // The serve path parses line by line with a running job count; it
        // must yield the same specs (ids, default seeds) as parse_manifest.
        let text = "# header\ngen er n=20 seed=7\n\ngen ba n=30 d=4 mis\n% tail comment\n";
        let batch = parse_manifest(text).unwrap();
        let mut streamed = Vec::new();
        for raw in text.lines() {
            if let Some(j) = parse_job_line(raw, streamed.len()).unwrap() {
                streamed.push(j);
            }
        }
        assert_eq!(streamed, batch);
        assert!(parse_job_line("   ", 0).unwrap().is_none());
        assert!(parse_job_line("gen zz n=10", 0).is_err());
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(parse_manifest("").is_err());
        assert!(parse_manifest("solve er n=10").is_err());
        assert!(parse_manifest("gen zz n=10").is_err());
        assert!(parse_manifest("gen er n=abc").is_err());
        assert!(parse_manifest("file a.txt b.txt").is_err());
    }

    #[test]
    fn rejects_unknown_keys() {
        // Typos must error, not silently run with default parameters.
        let err = parse_manifest("gen er n=100 sed=7").unwrap_err();
        assert!(format!("{err:#}").contains("sed"), "{err:#}");
        assert!(parse_manifest("gen er rho0=0.3").is_err());
        assert!(parse_manifest("file a.txt n=30").is_err());
        // Known keys still pass.
        assert!(parse_manifest("gen er n=100 seed=7").is_ok());
    }

    #[test]
    fn max_latency_key_parses_on_any_source() {
        let jobs =
            parse_manifest("gen er n=20 max_latency_ms=250\nfile a.txt max_latency_ms=5 mis")
                .unwrap();
        assert_eq!(jobs[0].max_latency_ms, Some(250));
        assert_eq!(jobs[1].max_latency_ms, Some(5));
        assert_eq!(parse_manifest("gen er n=20").unwrap()[0].max_latency_ms, None);
        assert!(parse_manifest("gen er n=20 max_latency_ms=soon").is_err());
    }

    #[test]
    fn materialize_is_deterministic() {
        let jobs = parse_manifest("gen er n=40 rho=0.2 seed=11\ngen ba n=40 d=3 seed=11").unwrap();
        let a1 = jobs[0].materialize().unwrap();
        let a2 = jobs[0].materialize().unwrap();
        assert_eq!(a1, a2);
        let b = jobs[1].materialize().unwrap();
        assert_eq!(b.n, 40);
        assert_ne!(a1, b);
    }

    #[test]
    fn materialize_file_roundtrip() {
        let dir = std::env::temp_dir().join(format!("oggm_spec_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("g.txt");
        let g = generators::erdos_renyi(30, 0.2, &mut Pcg32::seeded(5));
        gio::write_edge_list(&p, &g).unwrap();
        let spec = JobSpec {
            id: "f".into(),
            scenario: Scenario::Mvc,
            source: GraphSource::File(p.clone()),
            max_latency_ms: None,
        };
        let g2 = spec.materialize().unwrap();
        assert_eq!(g.n, g2.n);
        assert_eq!(g.m, g2.m);
        std::fs::remove_dir_all(&dir).ok();
    }
}
