//! `BatchEnv`: B per-graph environments driven in lockstep by the batched
//! solve engine. The host owns all environment logic (as in Alg. 5); this
//! wrapper only adds per-graph bookkeeping over `env::GraphEnv` — which
//! graphs are still active, per-graph candidate vectors, and solution
//! extraction — so `batch::solve` can treat the pack uniformly.

use crate::env::{GraphEnv, Scenario};
use crate::graph::Graph;

/// B per-graph environments driven in lockstep (one per pack slot).
pub struct BatchEnv {
    /// Scenario shared by every environment in the batch.
    pub scenario: Scenario,
    envs: Vec<Box<dyn GraphEnv>>,
}

impl BatchEnv {
    /// Each graph is moved into its env — the pack holds exactly one copy.
    pub fn new(scenario: Scenario, graphs: Vec<Graph>) -> BatchEnv {
        let envs: Vec<Box<dyn GraphEnv>> =
            graphs.into_iter().map(|g| scenario.make_env(g)).collect();
        BatchEnv { scenario, envs }
    }

    /// Number of graphs in the batch.
    pub fn len(&self) -> usize {
        self.envs.len()
    }

    /// Whether the batch holds no graphs.
    pub fn is_empty(&self) -> bool {
        self.envs.is_empty()
    }

    /// Graph behind batch element i.
    pub fn graph(&self, i: usize) -> &Graph {
        self.envs[i].graph()
    }

    /// Environment of batch element i.
    pub fn env(&self, i: usize) -> &dyn GraphEnv {
        self.envs[i].as_ref()
    }

    /// Mutable environment of batch element i.
    pub fn env_mut(&mut self, i: usize) -> &mut dyn GraphEnv {
        self.envs[i].as_mut()
    }

    /// Whether batch element i has reached a complete solution.
    pub fn done(&self, i: usize) -> bool {
        self.envs[i].done()
    }

    /// Whether every batch element is done.
    pub fn all_done(&self) -> bool {
        self.envs.iter().all(|e| e.done())
    }

    /// Indices of graphs that still need solving, in batch order.
    pub fn active(&self) -> Vec<usize> {
        (0..self.len()).filter(|&i| !self.done(i)).collect()
    }

    /// Current candidate mask of graph `i` over its (unpadded) nodes.
    pub fn candidates(&self, i: usize) -> Vec<bool> {
        let env = self.env(i);
        (0..env.num_nodes()).map(|v| env.is_candidate(v)).collect()
    }

    /// Whether graph `i`'s final solution is structurally valid.
    pub fn validate(&self, i: usize) -> bool {
        let env = self.env(i);
        self.scenario.validate(env.graph(), env.solution_mask())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Graph;

    fn graphs() -> Vec<Graph> {
        vec![
            Graph::from_edges(3, &[(0, 1), (1, 2)]).unwrap(),
            Graph::from_edges(4, &[(0, 1), (1, 2), (2, 3), (0, 3)]).unwrap(),
        ]
    }

    #[test]
    fn tracks_per_graph_progress() {
        let mut benv = BatchEnv::new(Scenario::Mvc, graphs());
        assert_eq!(benv.len(), 2);
        assert_eq!(benv.active(), vec![0, 1]);
        assert!(!benv.all_done());
        benv.env_mut(0).step(1); // path covered by its center
        assert!(benv.done(0));
        assert_eq!(benv.active(), vec![1]);
        assert!(benv.validate(0));
        assert_eq!(benv.candidates(1), vec![true; 4]);
    }

    #[test]
    fn scenario_dispatch_per_batch() {
        let benv = BatchEnv::new(Scenario::Mis, graphs());
        // MIS: every node (even degree-0) is a candidate initially.
        assert_eq!(benv.candidates(0), vec![true; 3]);
        assert_eq!(benv.env(1).solution_size(), 0);
    }
}
