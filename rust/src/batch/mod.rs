//! Graph-level batched solve engine (the paper's §4.5 "graph-level batched
//! processing" headline optimization, grown into a subsystem).
//!
//! Many independent graphs are packed into one block-diagonal sharded state
//! (`graph::pack`) and driven through a *shared* embedding/Q forward pass
//! per step: per-graph environments (`env`), per-graph candidate masking and
//! adaptive multi-node selection, and early-exit compaction — finished
//! graphs are evicted from the pack so later steps shrink to a smaller
//! compiled batch capacity (`solve`). A job-queue front-end (`queue` +
//! `spec`) groups heterogeneous solve requests by (scenario, bucket), packs
//! them, and emits per-graph solutions + timing JSON; the `oggm batch-solve`
//! subcommand is its CLI surface, and `run_queue` itself is a one-shot
//! compatibility wrapper over the persistent `crate::service::Service`
//! (incremental admission + streaming outcomes). See DESIGN.md §4/§8.

/// B per-graph environments in lockstep.
pub mod env;
/// The batched solve engine (`solve_pack`).
pub mod solve;
/// Job-manifest parsing (`oggm batch-solve` input format).
pub mod spec;
/// The job queue: grouping, chunking, reporting.
pub mod queue;

pub use env::BatchEnv;
pub use queue::{run_queue, run_queue_with, Job, JobOutcome, PackStat, QueueReport};
pub use solve::{
    solve_pack, solve_pack_in, solve_pack_session, BatchCfg, BatchGraphResult, BatchResult,
    SessionState,
};
pub use spec::{load_manifest, parse_job_line, parse_manifest, GraphSource, JobSpec};
